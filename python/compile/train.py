"""L2 — the paper's training routine (Sec. 2.3, Eq. 4) as exportable graphs.

One ``train_step`` graph serves all three methods of Tables 1/2:

  Pruned — masks fix pruned weights at zero (set by the Rust coordinator
           after the pretrain phase), alpha_l1 = alpha_bl1 = 0
  l1     — alpha_l1 > 0 (element-wise l1 on the quantized weights)
  Bl1    — alpha_bl1 > 0 (the paper's bit-slice l1, Eq. 3)

Semantics follow Eq. 4 exactly: the master weights w stay full precision;
each step quantizes w -> q = Q(w) (Pallas kernels, Eqs. 1-2), runs the
forward/backward at q, and writes back w' = q - lr * step_direction — i.e.
gradients (with momentum) are applied to the *recovered quantized* weight.

Flattened I/O layout (what the AOT manifest records, and what the Rust
coordinator feeds):

  train_step inputs : [QW..., TP..., ST..., VQ..., VT..., MASK..., x, y,
                       lr, momentum, alpha_l1, alpha_bl1]
  train_step outputs: [QW'..., TP'..., ST'..., VQ'..., VT'...,
                       loss, ce, l1, bl1, correct]
  eval_step inputs  : [QW..., TP..., ST..., MASK..., x, y]
  eval_step outputs : [loss, correct]

QW = quantized-kind weights, TP = trainable plain params (biases, bn scale /
bias), ST = bn running stats, VQ/VT = momentum buffers, MASK = 0/1 pruning
masks over QW. y is int32 class labels; everything else is f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as model_lib
from .kernels import bitslice as bs
from .kernels import crossbar as xb
from .kernels import quantize as qz
from .kernels import ref


def _groups(model: model_lib.Model):
    qw = [s for s in model.param_specs if s.kind == model_lib.KIND_QWEIGHT]
    tp = [
        s
        for s in model.param_specs
        if s.kind in (model_lib.KIND_BIAS, model_lib.KIND_BN_SCALE, model_lib.KIND_BN_BIAS)
    ]
    st = [s for s in model.param_specs if s.kind in model_lib.STATE_KINDS]
    return qw, tp, st


def _cross_entropy(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _correct(logits, y):
    return jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))


def make_train_step(model: model_lib.Model):
    """Build ``train_step(*flat_inputs) -> flat_outputs`` for this model."""
    qw_specs, tp_specs, st_specs = _groups(model)
    nq, nt, ns = len(qw_specs), len(tp_specs), len(st_specs)

    def train_step(*args):
        idx = 0
        qws = list(args[idx : idx + nq]); idx += nq
        tps = list(args[idx : idx + nt]); idx += nt
        sts = list(args[idx : idx + ns]); idx += ns
        vqs = list(args[idx : idx + nq]); idx += nq
        vts = list(args[idx : idx + nt]); idx += nt
        masks = list(args[idx : idx + nq]); idx += nq
        x, y, lr, momentum, alpha_l1, alpha_bl1 = args[idx : idx + 6]

        # --- Eq. 1-2: quantize the (masked) master weights, per layer ---
        qs, steps = [], []
        for w, m in zip(qws, masks):
            q, _code, step = qz.quantize(w * m)
            qs.append(q)
            steps.append(step)

        def loss_fn(qs, tps):
            p = {s.name: v for s, v in zip(qw_specs, qs)}
            p.update({s.name: v for s, v in zip(tp_specs, tps)})
            p.update({s.name: v for s, v in zip(st_specs, sts)})
            logits, updates = model.apply(p, x, True)
            ce = _cross_entropy(logits, y)
            l1 = sum(jnp.sum(jnp.abs(q)) for q in qs)
            bl1 = sum(bs.bl1_ste(q, step) for q, step in zip(qs, steps))
            loss = ce + alpha_l1 * l1 + alpha_bl1 * bl1
            return loss, (ce, l1, bl1, _correct(logits, y), updates)

        # --- Eq. 4: gradients taken at q, applied to q ---
        (loss, (ce, l1, bl1, correct, updates)), (gq, gt) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True
        )(qs, tps)

        new_vqs = [momentum * v + g for v, g in zip(vqs, gq)]
        new_vts = [momentum * v + g for v, g in zip(vts, gt)]
        new_qws = [
            (q - lr * v) * m for q, v, m in zip(qs, new_vqs, masks)
        ]
        new_tps = [t - lr * v for t, v in zip(tps, new_vts)]
        new_sts = [
            jax.lax.stop_gradient(updates.get(s.name, old))
            for s, old in zip(st_specs, sts)
        ]
        return tuple(
            new_qws
            + new_tps
            + new_sts
            + new_vqs
            + new_vts
            + [loss, ce, l1, bl1, correct]
        )

    return train_step


def make_eval_step(model: model_lib.Model):
    """Deployment-accuracy eval: quantized weights, BN running stats."""
    qw_specs, tp_specs, st_specs = _groups(model)
    nq, nt, ns = len(qw_specs), len(tp_specs), len(st_specs)

    def eval_step(*args):
        idx = 0
        qws = list(args[idx : idx + nq]); idx += nq
        tps = list(args[idx : idx + nt]); idx += nt
        sts = list(args[idx : idx + ns]); idx += ns
        masks = list(args[idx : idx + nq]); idx += nq
        x, y = args[idx : idx + 2]

        p = {}
        for s, w, m in zip(qw_specs, qws, masks):
            q, _code, _step = qz.quantize(w * m)
            p[s.name] = q
        p.update({s.name: v for s, v in zip(tp_specs, tps)})
        p.update({s.name: v for s, v in zip(st_specs, sts)})
        logits, _ = model.apply(p, x, False)
        return (_cross_entropy(logits, y), _correct(logits, y))

    return eval_step


def make_sparsity_report(model: model_lib.Model):
    """Per-model bit-slice census: quantize every qweight and count non-zero
    elements per slice (LSB-first) plus totals. Output layout:

      [counts(4) per qweight ..., numel(1) per qweight ...]

    Cross-checks the Rust-side analyzer (rust/src/sparsity) bit-for-bit.
    """
    qw_specs, _tp, _st = _groups(model)
    nq = len(qw_specs)

    def report(*qws):
        assert len(qws) == nq
        outs = []
        numels = []
        for w in qws:
            _q, code, _step = qz.quantize(w)
            outs.append(bs.slice_nonzero_counts(code))
            numels.append(jnp.asarray(float(w.size)))
        return tuple(outs + numels)

    return report


# ---------------------------------------------------------------------------
# ReRAM-simulated inference (MLP) — validates the reduced-ADC deployment
# ---------------------------------------------------------------------------


def _act_quantize(x):
    """Quantize non-negative activations to 8-bit codes (dynamic range)."""
    m = jnp.maximum(jnp.max(x), ref._EPS)
    step = jnp.exp2(jnp.ceil(jnp.log2(m)) - ref.N_BITS)
    code = jnp.clip(jnp.floor(x / step), 0.0, ref.CODE_MAX)
    return code, step


def _reram_linear_tiled(x, w, b, adc_bits):
    """One linear layer on ReRAM crossbars, tiling rows into 128-row
    crossbars. ADC clipping happens per tile (physically: per bitline of
    each crossbar); tile partial sums are combined digitally."""
    a_code, a_step = _act_quantize(x)
    _qw, code, w_step = qz.quantize(w)
    slices = bs.bitslice(code)  # (4, R, C)
    pos = jnp.where(w > 0, slices, 0.0)
    neg = jnp.where(w < 0, slices, 0.0)
    rows = w.shape[0]
    out = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
    for r0 in range(0, rows, xb.XBAR_ROWS):
        r1 = min(r0 + xb.XBAR_ROWS, rows)
        out = out + xb.reram_linear(
            a_code[:, r0:r1],
            pos[:, r0:r1, :],
            neg[:, r0:r1, :],
            adc_bits,
            jnp.float32(1.0),
            jnp.float32(1.0),
        )
    return out * (w_step * a_step) + b


def make_reram_infer(model: model_lib.Model, adc_bits):
    """ReRAM-simulated MLP forward: logits under per-slice ADC resolutions.

    ``adc_bits`` is LSB-first, e.g. (3, 3, 3, 1) for the paper's Table 3
    deployment or (10, 10, 10, 10) for a lossless reference.
    Inputs: [fc1/w, fc1/b, fc2/w, fc2/b, x]; output: [logits].
    """
    if model.name != "mlp":
        raise ValueError("reram_infer graph is exported for the MLP only")

    def infer(w1, b1, w2, b2, x):
        h = _reram_linear_tiled(x, w1, b1, adc_bits)
        h = jax.nn.relu(h)
        logits = _reram_linear_tiled(h, w2, b2, adc_bits)
        return (logits,)

    return infer

"""AOT exporter: lower every graph the Rust coordinator needs to HLO text.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the pinned xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (under --out-dir, default ../artifacts):

  <model>_train.hlo.txt      train_step  (Eq. 4; all three methods)
  <model>_eval.hlo.txt       eval_step   (quantized deployment accuracy)
  <model>_sparsity.hlo.txt   per-slice non-zero census (cross-checks Rust)
  mlp_reram_paper.hlo.txt    ReRAM-sim inference, ADC = (3,3,3,1) LSB-first
  mlp_reram_lossless.hlo.txt ReRAM-sim inference, ADC = (10,10,10,10)
  kernel_*.hlo.txt           standalone kernel graphs for criterion benches
  manifest.json              input/output specs + parameter layout for Rust

Python runs ONCE at build time (`make artifacts`); nothing here is on the
request path.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import train as train_lib
from .kernels import bitslice as bs
from .kernels import crossbar as xb
from .kernels import quantize as qz

F32 = "f32"
I32 = "i32"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(name, shape, dtype=F32):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _lower(fn, in_specs):
    args = [
        sds(s["shape"], jnp.int32 if s["dtype"] == I32 else jnp.float32)
        for s in in_specs
    ]
    return to_hlo_text(jax.jit(fn).lower(*args))


def _write(out_dir: pathlib.Path, fname: str, text: str) -> str:
    path = out_dir / fname
    path.write_text(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")
    return fname


def export_model(model: model_lib.Model, batch: int, out_dir: pathlib.Path):
    qw, tp, st = train_lib._groups(model)
    x_shape = (batch,) + model.input_shape

    def pspecs(prefix, specs_):
        return [spec(f"{prefix}:{s.name}", s.shape) for s in specs_]

    scalars = [spec(n, ()) for n in ("lr", "momentum", "alpha_l1", "alpha_bl1")]
    train_in = (
        pspecs("qw", qw)
        + pspecs("tp", tp)
        + pspecs("st", st)
        + pspecs("vq", qw)
        + pspecs("vt", tp)
        + pspecs("mask", qw)
        + [spec("x", x_shape), spec("y", (batch,), I32)]
        + scalars
    )
    train_out = (
        pspecs("qw", qw)
        + pspecs("tp", tp)
        + pspecs("st", st)
        + pspecs("vq", qw)
        + pspecs("vt", tp)
        + [spec(n, ()) for n in ("loss", "ce", "l1", "bl1", "correct")]
    )
    eval_in = (
        pspecs("qw", qw)
        + pspecs("tp", tp)
        + pspecs("st", st)
        + pspecs("mask", qw)
        + [spec("x", x_shape), spec("y", (batch,), I32)]
    )
    eval_out = [spec("loss", ()), spec("correct", ())]
    sparsity_in = pspecs("qw", qw)
    sparsity_out = [
        spec(f"counts:{s.name}", (4,)) for s in qw
    ] + [spec(f"numel:{s.name}", ()) for s in qw]

    entry = {
        "batch": batch,
        "input_shape": list(model.input_shape),
        "num_classes": model.num_classes,
        "params": {
            "qw": [
                {"name": s.name, "shape": list(s.shape), "init_std": s.init_std,
                 "init_const": s.init_const}
                for s in qw
            ],
            "tp": [
                {"name": s.name, "shape": list(s.shape), "init_std": s.init_std,
                 "init_const": s.init_const}
                for s in tp
            ],
            "st": [
                {"name": s.name, "shape": list(s.shape), "init_std": s.init_std,
                 "init_const": s.init_const}
                for s in st
            ],
        },
        "graphs": {},
    }

    print(f"[{model.name}] lowering train_step (batch={batch}) ...")
    entry["graphs"]["train"] = {
        "path": _write(
            out_dir,
            f"{model.name}_train.hlo.txt",
            _lower(train_lib.make_train_step(model), train_in),
        ),
        "inputs": train_in,
        "outputs": train_out,
    }
    print(f"[{model.name}] lowering eval_step ...")
    entry["graphs"]["eval"] = {
        "path": _write(
            out_dir,
            f"{model.name}_eval.hlo.txt",
            _lower(train_lib.make_eval_step(model), eval_in),
        ),
        "inputs": eval_in,
        "outputs": eval_out,
    }
    print(f"[{model.name}] lowering sparsity_report ...")
    entry["graphs"]["sparsity"] = {
        "path": _write(
            out_dir,
            f"{model.name}_sparsity.hlo.txt",
            _lower(train_lib.make_sparsity_report(model), sparsity_in),
        ),
        "inputs": sparsity_in,
        "outputs": sparsity_out,
    }

    if model.name == "mlp":
        infer_in = [
            spec("qw:fc1/w", (784, 300)),
            spec("tp:fc1/b", (300,)),
            spec("qw:fc2/w", (300, 10)),
            spec("tp:fc2/b", (10,)),
            spec("x", x_shape),
        ]
        infer_out = [spec("logits", (batch, 10))]
        for tag, bits in (("paper", (3, 3, 3, 1)), ("lossless", (10, 10, 10, 10))):
            print(f"[{model.name}] lowering reram_infer ({tag}) ...")
            entry["graphs"][f"reram_{tag}"] = {
                "path": _write(
                    out_dir,
                    f"mlp_reram_{tag}.hlo.txt",
                    _lower(train_lib.make_reram_infer(model, bits), infer_in),
                ),
                "inputs": infer_in,
                "outputs": infer_out,
                "adc_bits": list(bits),
            }
    return entry


def export_kernels(out_dir: pathlib.Path):
    """Standalone kernel graphs for the Rust criterion micro-benches."""
    kernels = {}

    def k_quantize(w):
        q, code, step = qz.quantize(w)
        return (q, code, step)

    kernels["quantize_1m"] = {
        "path": _write(
            out_dir,
            "kernel_quantize_1m.hlo.txt",
            _lower(k_quantize, [spec("w", (1024, 1024))]),
        ),
        "inputs": [spec("w", (1024, 1024))],
        "outputs": [
            spec("q", (1024, 1024)),
            spec("code", (1024, 1024)),
            spec("step", ()),
        ],
    }

    def k_bl1(code):
        return (bs.bl1_penalty(code),)

    kernels["bl1_1m"] = {
        "path": _write(
            out_dir, "kernel_bl1_1m.hlo.txt", _lower(k_bl1, [spec("code", (1024, 1024))])
        ),
        "inputs": [spec("code", (1024, 1024))],
        "outputs": [spec("bl1", ())],
    }

    def k_xbar(a, wp, wn):
        return (xb.crossbar_mvm(a, wp, wn, adc_bits=3),)

    shape = (xb.BATCH_BLOCK, xb.XBAR_ROWS)
    wshape = (xb.XBAR_ROWS, xb.XBAR_COLS)
    kernels["crossbar_tile"] = {
        "path": _write(
            out_dir,
            "kernel_crossbar_tile.hlo.txt",
            _lower(k_xbar, [spec("a", shape), spec("wp", wshape), spec("wn", wshape)]),
        ),
        "inputs": [spec("a", shape), spec("wp", wshape), spec("wn", wshape)],
        "outputs": [spec("out", (xb.BATCH_BLOCK, xb.XBAR_COLS))],
    }
    return kernels


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="mlp,vgg11,resnet20")
    ap.add_argument("--mlp-batch", type=int, default=128)
    ap.add_argument("--cifar-batch", type=int, default=32)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {"models": {}, "kernels": {}}
    for name in [m for m in args.models.split(",") if m]:
        model = model_lib.get_model(name)
        batch = args.mlp_batch if name == "mlp" else args.cifar_batch
        manifest["models"][name] = export_model(model, batch, out_dir)
    manifest["kernels"] = export_kernels(out_dir)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()

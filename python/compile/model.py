"""L2 — the paper's models in pure JAX (paper Sec. 3).

Three models, matching the evaluation section:

  * ``mlp``      — the MNIST "toy model consisting of two linear layers"
                   (784-300-10).
  * ``vgg11``    — VGG-11 (configuration A) adapted to CIFAR-10 32x32 inputs.
  * ``resnet20`` — the standard CIFAR ResNet-20 (3 stages x 3 basic blocks,
                   16/32/64 channels) with batch norm.

Parameters are described by ``ParamSpec``s with a ``kind``:

  qweight — conv / linear kernels: quantized to 8-bit dynamic fixed point and
            bit-sliced onto ReRAM crossbars; the regularizers apply here.
  bias    — digital-domain biases (full precision, trained).
  bn_*    — batch-norm scale/bias (trained) and running mean/var (state,
            updated by the forward pass, never by the optimizer).

The ordering of ``param_specs`` is the canonical flattening used by the AOT
manifest and the Rust coordinator — keep it deterministic.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

BN_EPS = 1e-5
BN_MOMENTUM = 0.1

KIND_QWEIGHT = "qweight"
KIND_BIAS = "bias"
KIND_BN_SCALE = "bn_scale"
KIND_BN_BIAS = "bn_bias"
KIND_BN_MEAN = "bn_mean"
KIND_BN_VAR = "bn_var"

TRAINABLE_KINDS = (KIND_QWEIGHT, KIND_BIAS, KIND_BN_SCALE, KIND_BN_BIAS)
STATE_KINDS = (KIND_BN_MEAN, KIND_BN_VAR)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One parameter tensor: canonical name, shape, role, init."""

    name: str
    shape: tuple
    kind: str
    # Gaussian init std (0.0 => constant init_const instead).
    init_std: float = 0.0
    init_const: float = 0.0

    def init(self, key: jax.Array) -> jnp.ndarray:
        if self.init_std > 0.0:
            return self.init_std * jax.random.normal(
                key, self.shape, jnp.float32
            )
        return jnp.full(self.shape, self.init_const, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Model:
    """A model = canonical parameter list + a pure apply function.

    ``apply(params, x, train)`` takes a dict name->array and returns
    ``(logits, state_updates)`` where ``state_updates`` maps bn_mean/bn_var
    names to their new running values (empty in eval mode or for BN-free
    models).
    """

    name: str
    input_shape: tuple  # per-example, e.g. (784,) or (32, 32, 3)
    num_classes: int
    param_specs: tuple
    apply: Callable

    def init_params(self, seed: int) -> dict:
        key = jax.random.PRNGKey(seed)
        keys = jax.random.split(key, len(self.param_specs))
        return {
            s.name: s.init(k) for s, k in zip(self.param_specs, keys)
        }

    def specs_of_kind(self, *kinds) -> list:
        return [s for s in self.param_specs if s.kind in kinds]


def _he_std(fan_in: int) -> float:
    return math.sqrt(2.0 / fan_in)


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


def _linear(p, name, x):
    return x @ p[f"{name}/w"] + p[f"{name}/b"]


def _conv(p, name, x, stride=1):
    # NHWC, HWIO, SAME padding — the CIFAR 3x3 workhorse.
    return jax.lax.conv_general_dilated(
        x,
        p[f"{name}/w"],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + p[f"{name}/b"]


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _batchnorm(p, name, x, train, updates):
    scale = p[f"{name}/scale"]
    bias = p[f"{name}/bias"]
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        updates[f"{name}/mean"] = (
            (1.0 - BN_MOMENTUM) * p[f"{name}/mean"] + BN_MOMENTUM * mean
        )
        updates[f"{name}/var"] = (
            (1.0 - BN_MOMENTUM) * p[f"{name}/var"] + BN_MOMENTUM * var
        )
    else:
        mean = p[f"{name}/mean"]
        var = p[f"{name}/var"]
    inv = jax.lax.rsqrt(var + BN_EPS)
    return (x - mean) * inv * scale + bias


def _linear_specs(name, din, dout):
    return [
        ParamSpec(f"{name}/w", (din, dout), KIND_QWEIGHT, _he_std(din)),
        ParamSpec(f"{name}/b", (dout,), KIND_BIAS),
    ]


def _conv_specs(name, kh, kw, cin, cout):
    return [
        ParamSpec(
            f"{name}/w", (kh, kw, cin, cout), KIND_QWEIGHT, _he_std(kh * kw * cin)
        ),
        ParamSpec(f"{name}/b", (cout,), KIND_BIAS),
    ]


def _bn_specs(name, c):
    return [
        ParamSpec(f"{name}/scale", (c,), KIND_BN_SCALE, 0.0, 1.0),
        ParamSpec(f"{name}/bias", (c,), KIND_BN_BIAS, 0.0, 0.0),
        ParamSpec(f"{name}/mean", (c,), KIND_BN_MEAN, 0.0, 0.0),
        ParamSpec(f"{name}/var", (c,), KIND_BN_VAR, 0.0, 1.0),
    ]


# ---------------------------------------------------------------------------
# MNIST toy MLP (784-300-10)
# ---------------------------------------------------------------------------


def _mlp_apply(p, x, train):
    del train
    h = jax.nn.relu(_linear(p, "fc1", x))
    return _linear(p, "fc2", h), {}


def make_mlp(hidden: int = 300) -> Model:
    specs = _linear_specs("fc1", 784, hidden) + _linear_specs("fc2", hidden, 10)
    return Model("mlp", (784,), 10, tuple(specs), _mlp_apply)


# ---------------------------------------------------------------------------
# VGG-11 (configuration A) for CIFAR-10
# ---------------------------------------------------------------------------

_VGG11_CFG = (64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M")


def _vgg11_apply(p, x, train):
    del train
    h = x
    i = 0
    for c in _VGG11_CFG:
        if c == "M":
            h = _maxpool(h)
        else:
            h = jax.nn.relu(_conv(p, f"conv{i}", h))
            i += 1
    h = h.reshape(h.shape[0], -1)  # 1x1x512
    return _linear(p, "fc", h), {}


def make_vgg11() -> Model:
    specs = []
    cin = 3
    i = 0
    for c in _VGG11_CFG:
        if c == "M":
            continue
        specs += _conv_specs(f"conv{i}", 3, 3, cin, c)
        cin = c
        i += 1
    specs += _linear_specs("fc", 512, 10)
    return Model("vgg11", (32, 32, 3), 10, tuple(specs), _vgg11_apply)


# ---------------------------------------------------------------------------
# ResNet-20 for CIFAR-10
# ---------------------------------------------------------------------------

_RESNET20_STAGES = ((16, 1), (32, 2), (64, 2))  # (channels, first-stride)
_BLOCKS_PER_STAGE = 3


def _resnet20_apply(p, x, train):
    updates = {}
    h = _batchnorm(p, "bn0", _conv(p, "conv0", x), train, updates)
    h = jax.nn.relu(h)
    for si, (c, stride0) in enumerate(_RESNET20_STAGES):
        for bi in range(_BLOCKS_PER_STAGE):
            stride = stride0 if bi == 0 else 1
            name = f"s{si}b{bi}"
            inp = h
            h = _batchnorm(
                p, f"{name}/bn1", _conv(p, f"{name}/conv1", h, stride), train, updates
            )
            h = jax.nn.relu(h)
            h = _batchnorm(
                p, f"{name}/bn2", _conv(p, f"{name}/conv2", h), train, updates
            )
            if inp.shape != h.shape:
                # projection shortcut (option B) on shape change
                inp = _batchnorm(
                    p,
                    f"{name}/bnp",
                    _conv(p, f"{name}/proj", inp, stride),
                    train,
                    updates,
                )
            h = jax.nn.relu(h + inp)
    h = jnp.mean(h, axis=(1, 2))  # global average pool
    return _linear(p, "fc", h), updates


def make_resnet20() -> Model:
    specs = _conv_specs("conv0", 3, 3, 3, 16) + _bn_specs("bn0", 16)
    cin = 16
    for si, (c, _stride0) in enumerate(_RESNET20_STAGES):
        for bi in range(_BLOCKS_PER_STAGE):
            name = f"s{si}b{bi}"
            specs += _conv_specs(f"{name}/conv1", 3, 3, cin, c)
            specs += _bn_specs(f"{name}/bn1", c)
            specs += _conv_specs(f"{name}/conv2", 3, 3, c, c)
            specs += _bn_specs(f"{name}/bn2", c)
            if cin != c:
                specs += _conv_specs(f"{name}/proj", 1, 1, cin, c)
                specs += _bn_specs(f"{name}/bnp", c)
            cin = c
    specs += _linear_specs("fc", 64, 10)
    return Model("resnet20", (32, 32, 3), 10, tuple(specs), _resnet20_apply)


MODELS = {
    "mlp": make_mlp,
    "vgg11": make_vgg11,
    "resnet20": make_resnet20,
}


def get_model(name: str) -> Model:
    try:
        return MODELS[name]()
    except KeyError:
        raise ValueError(f"unknown model {name!r}; have {sorted(MODELS)}")

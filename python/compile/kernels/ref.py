"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against these references by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes/dtypes and asserts
allclose). The references implement the paper's equations directly:

  Eq. 1   S(W)   = ceil(log2(max |w|))          (dynamic range)
  Eq. 2   B(w)   = floor(|w| / Qstep)           (8-bit code, Qstep = 2^{S-n})
          Q(w)   = sign(w) * B(w) * Qstep       (recovered weight)
  Eq. 3   Bl1(W) = sum_{i,k} Bhat^{i,k}         (digit-sum over 2-bit slices)

plus the ReRAM crossbar MVM with bit-serial inputs and an ADC transfer
function (clip at 2^N - 1 LSBs), which the paper evaluates "in simulation".
"""

from __future__ import annotations

import jax.numpy as jnp

# Paper constants: 8-bit dynamic fixed point, 2 bits/cell -> 4 slices.
N_BITS = 8
SLICE_BITS = 2
N_SLICES = N_BITS // SLICE_BITS  # 4
SLICE_BASE = float(2**SLICE_BITS)  # 4.0
SLICE_MAX = SLICE_BASE - 1.0  # 3.0
CODE_MAX = float(2**N_BITS - 1)  # 255.0

# Guard for all-zero tensors: max|w| is clamped to 2^-20 so S(W) >= -20.
_EPS = 2.0**-20


def dynamic_range(w: jnp.ndarray) -> jnp.ndarray:
    """S(W) = ceil(log2(max_i |w_i|)), Eq. 1. Scalar (f32)."""
    m = jnp.maximum(jnp.max(jnp.abs(w)), _EPS)
    return jnp.ceil(jnp.log2(m))


def qstep(w: jnp.ndarray, n_bits: int = N_BITS) -> jnp.ndarray:
    """Quantization step Qstep = 2^{S(W) - n}."""
    return jnp.exp2(dynamic_range(w) - n_bits)


def quantize_code(w: jnp.ndarray, step: jnp.ndarray) -> jnp.ndarray:
    """B(w) = floor(|w| / Qstep), clipped into [0, 2^n - 1] (Eq. 2).

    Codes are returned as f32: values <= 255 are exactly representable and
    stay in the same dtype family as the surrounding graph.
    """
    return jnp.clip(jnp.floor(jnp.abs(w) / step), 0.0, CODE_MAX)


def quantize(w: jnp.ndarray, n_bits: int = N_BITS):
    """Full dynamic fixed-point quantization.

    Returns ``(q, code, step)`` where ``q = sign(w) * code * step`` is the
    recovered weight used in the forward pass (paper Sec. 2.3).
    """
    step = qstep(w, n_bits)
    code = quantize_code(w, step)
    q = jnp.sign(w) * code * step
    return q, code, step


def bitslice(code: jnp.ndarray) -> jnp.ndarray:
    """Split 8-bit codes into 2-bit slices, LSB-first.

    Input: codes in [0, 255] (f32). Output shape ``(N_SLICES,) + code.shape``
    with ``out[k] = (code >> 2k) & 3`` so ``code = sum_k out[k] * 4^k``.
    """
    ks = jnp.arange(N_SLICES, dtype=code.dtype).reshape(
        (N_SLICES,) + (1,) * code.ndim
    )
    return jnp.mod(jnp.floor(code[None, ...] / SLICE_BASE**ks), SLICE_BASE)


def bl1_penalty(code: jnp.ndarray) -> jnp.ndarray:
    """Bl1(W) = sum over elements and slices of the slice value (Eq. 3)."""
    return jnp.sum(bitslice(code))


# Sum_k 4^-k for k = 0..3: the STE surrogate slope of the digit sum w.r.t.
# the code value (each slice passes floor/mod through as identity).
STE_SLOPE = sum(SLICE_BASE**-k for k in range(N_SLICES))  # 85/64


def bl1_grad(q: jnp.ndarray, step: jnp.ndarray) -> jnp.ndarray:
    """Straight-through surrogate for d Bl1 / d q (see DESIGN.md Sec. 7).

    Bhat^k = mod(floor(|q|/Qstep / 4^k), 4); passing floor and mod through
    as identity gives d Bhat^k / d q = sign(q) / (Qstep * 4^k), hence
    d Bl1 / d q = sign(q) * (sum_k 4^-k) / Qstep. The 1/Qstep factor is what
    distinguishes Bl1 from a plain l1: the pull is proportional to the
    layer's quantized-domain magnitude.
    """
    return jnp.sign(q) * (STE_SLOPE / step)


def slice_nonzero_ratio(code: jnp.ndarray) -> jnp.ndarray:
    """Per-slice ratio of non-zero elements, shape (N_SLICES,) — the paper's
    Tables 1/2 columns Bhat^0..Bhat^3 (we return LSB-first)."""
    s = bitslice(code)
    return jnp.mean((s != 0).astype(jnp.float32), axis=tuple(range(1, s.ndim)))


# ---------------------------------------------------------------------------
# ReRAM crossbar MVM (functional simulator reference)
# ---------------------------------------------------------------------------


def adc(current: jnp.ndarray, adc_bits: int) -> jnp.ndarray:
    """ADC transfer function: clip the (integer-valued) bitline current at
    full-scale 2^N - 1 LSBs. 1 LSB = 1 unit of cell current (one minimum-
    conductance cell driven by a '1' input bit)."""
    return jnp.clip(current, 0.0, float(2**adc_bits - 1))


def crossbar_mvm(
    a_code: jnp.ndarray,
    w_pos: jnp.ndarray,
    w_neg: jnp.ndarray,
    adc_bits: int,
    a_bits: int = N_BITS,
) -> jnp.ndarray:
    """One bit-slice group's crossbar MVM with bit-serial inputs.

    a_code: (B, R) activation codes in [0, 2^a_bits - 1] (f32 integers).
    w_pos/w_neg: (R, C) cell conductances in [0, 3] — the positive and
        negative differential crossbars holding one 2-bit slice.
    Each input bit-plane drives one analog cycle; the bitline current is
    ADC-quantized *per plane* (that is where the physical ADC sits), then
    shift-added digitally.
    Returns (B, C) recombined slice contribution (signed).
    """
    acc = jnp.zeros((a_code.shape[0], w_pos.shape[1]), dtype=jnp.float32)
    for t in range(a_bits):
        bit = jnp.mod(jnp.floor(a_code / 2.0**t), 2.0)
        i_pos = adc(bit @ w_pos, adc_bits)
        i_neg = adc(bit @ w_neg, adc_bits)
        acc = acc + (i_pos - i_neg) * 2.0**t
    return acc


def reram_linear(
    a_code: jnp.ndarray,
    slices_pos: jnp.ndarray,
    slices_neg: jnp.ndarray,
    adc_bits_per_slice,
    w_step: jnp.ndarray,
    a_step: jnp.ndarray,
    a_bits: int = N_BITS,
) -> jnp.ndarray:
    """Full ReRAM linear layer: recombine all slice groups.

    slices_pos/neg: (N_SLICES, R, C); adc_bits_per_slice: sequence of 4 ints
    (LSB-first; paper Table 3 uses 3-bit for XB_{2,1,0} and 1-bit for XB_3).
    Result is rescaled back to real units with the weight/activation steps.
    """
    out = jnp.zeros((a_code.shape[0], slices_pos.shape[2]), dtype=jnp.float32)
    for k in range(N_SLICES):
        contrib = crossbar_mvm(
            a_code, slices_pos[k], slices_neg[k], int(adc_bits_per_slice[k]), a_bits
        )
        out = out + contrib * SLICE_BASE**k
    return out * w_step * a_step

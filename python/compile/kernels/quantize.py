"""Pallas kernels for dynamic fixed-point quantization (paper Sec. 2.1).

Two kernels:

  * ``maxabs``   — grid reduction computing ``max_i |w_i|`` (feeds Eq. 1).
  * ``quantize`` — element-wise Eq. 2: code ``B(w)`` and recovered ``Q(w)``.

Both are written TPU-style (2-D blocks sized for VMEM, scalar operand in a
(1,1) block) and lowered with ``interpret=True`` so they execute as plain HLO
on the CPU PJRT backend — real-TPU lowering would emit a Mosaic custom call
the CPU plugin cannot run (see DESIGN.md §Hardware-Adaptation).

``quantize_ste`` wraps the whole thing in the straight-through estimator the
training routine needs (paper Eq. 4): forward returns Q(w), backward passes
gradients through unchanged (the master weights live in full precision).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# interpret=True is mandatory on this testbed (CPU PJRT); kept as a module
# flag so a TPU build only flips one switch.
INTERPRET = True

# Default VMEM block: 512x1024 f32 = 2 MiB per operand block; with the two
# outputs that is ~6 MiB resident, under the ~16 MiB VMEM budget and still
# double-bufferable. (256 was the initial value; 512 halves the interpret
# grid iterations for ~2x on CPU — EXPERIMENTS.md §Perf iteration 4.)
BLOCK = 512
LANE = 1024


def _pad2d(x: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    """Pad a 2-D array up to block multiples (zeros are neutral for both the
    max-abs reduction and quantization, whose code for 0 is 0)."""
    m, n = x.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _as2d(w: jnp.ndarray, lane: int = LANE) -> jnp.ndarray:
    """Collapse an arbitrary-rank tensor to a lane-width 2-D layout.

    Element-wise kernels do not care about the logical shape, so we flatten
    and re-tile to rows of ``lane`` elements: padding waste is < ``lane``
    elements regardless of the original shape (a (3, 3, 512, 512) conv kernel
    reshaped naively to (3, 786432) would otherwise pad 3 rows up to a full
    block). Zero-padded; callers slice the flat prefix back out.
    """
    flat = w.reshape(-1)
    n = flat.shape[0]
    width = min(lane, n) if n > 0 else 1
    pad = (-n) % width
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, width)


def _from2d(x2d: jnp.ndarray, orig_shape) -> jnp.ndarray:
    """Inverse of ``_as2d`` + ``_pad2d``: drop padding, restore shape."""
    import numpy as _np

    n = int(_np.prod(orig_shape)) if orig_shape else 1
    return x2d.reshape(-1)[:n].reshape(orig_shape)


def _maxabs_kernel(x_ref, o_ref):
    # Sequential grid: TPU (and interpret mode) iterate grid points in order,
    # so accumulating into the single (1,1) output block is well-defined.
    i = pl.program_id(0)
    j = pl.program_id(1)
    block_max = jnp.max(jnp.abs(x_ref[...]))

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init():
        o_ref[0, 0] = block_max

    @pl.when(jnp.logical_or(i != 0, j != 0))
    def _acc():
        o_ref[0, 0] = jnp.maximum(o_ref[0, 0], block_max)


def maxabs(w: jnp.ndarray, block: int = BLOCK) -> jnp.ndarray:
    """max_i |w_i| as a Pallas grid reduction. Returns a f32 scalar."""
    x = _as2d(w.astype(jnp.float32))
    bm, bn = min(block, x.shape[0]), x.shape[1]
    x = _pad2d(x, bm, bn)
    m, n = x.shape
    out = pl.pallas_call(
        _maxabs_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=INTERPRET,
    )(x)
    return out[0, 0]


def _quantize_kernel(x_ref, step_ref, q_ref, code_ref):
    step = step_ref[0, 0]
    x = x_ref[...]
    code = jnp.clip(jnp.floor(jnp.abs(x) / step), 0.0, ref.CODE_MAX)
    code_ref[...] = code
    q_ref[...] = jnp.sign(x) * code * step


def quantize_with_step(w: jnp.ndarray, step: jnp.ndarray, block: int = BLOCK):
    """Element-wise Eq. 2 given a precomputed Qstep scalar.

    Returns ``(q, code)`` with the original shape/dtype layout of ``w``
    (both f32; codes are integers in [0, 255] stored exactly in f32).
    """
    orig_shape = w.shape
    x = _as2d(w.astype(jnp.float32))
    bm, bn = min(block, x.shape[0]), x.shape[1]
    x = _pad2d(x, bm, bn)
    m, n = x.shape
    step2d = jnp.asarray(step, jnp.float32).reshape(1, 1)
    q, code = pl.pallas_call(
        _quantize_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x, step2d)
    return _from2d(q, orig_shape), _from2d(code, orig_shape)


def quantize(w: jnp.ndarray, n_bits: int = ref.N_BITS, block: int = BLOCK):
    """Full dynamic fixed-point quantization (Eqs. 1-2) via Pallas.

    Returns ``(q, code, step)`` matching ``ref.quantize``.
    """
    m = jnp.maximum(maxabs(w, block), ref._EPS)
    step = jnp.exp2(jnp.ceil(jnp.log2(m)) - n_bits)
    q, code = quantize_with_step(w, step, block)
    return q, code, step


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantize_ste(w: jnp.ndarray, n_bits: int = ref.N_BITS):
    """Straight-through quantizer: forward Q(w), backward identity.

    This is the ``w -> q`` arrow in the paper's Fig. 1 training routine: the
    forward pass sees the quantized weight, while gradients flow back to the
    full-precision master copy unmodified (Eq. 4 applies them at q).
    """
    q, _code, _step = quantize(w, n_bits)
    return q


def _quantize_ste_fwd(w, n_bits):
    return quantize_ste(w, n_bits), None


def _quantize_ste_bwd(n_bits, _res, g):
    return (g,)


quantize_ste.defvjp(_quantize_ste_fwd, _quantize_ste_bwd)

"""Pallas kernel for the ReRAM crossbar MVM functional simulator.

This is the deployment-side hot spot: a 128x128 crossbar tile holding one
2-bit weight slice (differential positive/negative arrays), driven bit-
serially by the activation codes. Per input bit-plane the bitline currents
are formed analog-style (an MXU-shaped (B,R)x(R,C) matmul over small-integer
values, exact in f32), the ADC clips them at its full scale (2^N - 1 LSBs),
and the digital shift-and-add recombines the planes.

TPU mapping (DESIGN.md §Hardware-Adaptation): the crossbar tile is the
natural MXU tile (128x128); the bit-plane loop is a ``fori_loop`` inside the
kernel so the tile stays VMEM-resident across all planes instead of being
re-streamed from HBM per plane.

Lowered with ``interpret=True`` for the CPU PJRT backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .quantize import INTERPRET

# ReRAM array geometry (ISAAC-style): 128 wordlines x 128 bitlines.
XBAR_ROWS = 128
XBAR_COLS = 128
# Batch tile: 128 keeps the activation block MXU-shaped as well.
BATCH_BLOCK = 128


def _xbar_kernel(a_ref, wp_ref, wn_ref, o_ref, *, a_bits: int, adc_bits: int):
    a = a_ref[...]  # (bb, R) activation codes
    wp = wp_ref[...]  # (R, bc) positive cells
    wn = wn_ref[...]  # (R, bc) negative cells
    full_scale = float(2**adc_bits - 1)

    def plane(t, acc):
        # t-th input bit-plane: the 1-bit DAC drive for this cycle.
        bit = jnp.mod(jnp.floor(a / jnp.exp2(t.astype(jnp.float32))), 2.0)
        # Analog bitline accumulation == integer matmul, exact in f32.
        i_pos = jnp.clip(
            jnp.dot(bit, wp, preferred_element_type=jnp.float32),
            0.0,
            full_scale,
        )
        i_neg = jnp.clip(
            jnp.dot(bit, wn, preferred_element_type=jnp.float32),
            0.0,
            full_scale,
        )
        return acc + (i_pos - i_neg) * jnp.exp2(t.astype(jnp.float32))

    o_ref[...] = jax.lax.fori_loop(
        0, a_bits, plane, jnp.zeros_like(o_ref[...], jnp.float32)
    )


def crossbar_mvm(
    a_code: jnp.ndarray,
    w_pos: jnp.ndarray,
    w_neg: jnp.ndarray,
    adc_bits: int,
    a_bits: int = ref.N_BITS,
    batch_block: int = BATCH_BLOCK,
) -> jnp.ndarray:
    """One slice group's crossbar MVM; Pallas version of ``ref.crossbar_mvm``.

    a_code (B, R) f32 integer codes; w_pos/w_neg (R, C) cells in [0, 3].
    R must not exceed the crossbar row count (the mapper tiles larger layers
    into multiple crossbars and sums digitally — see rust/src/reram).
    """
    b, r = a_code.shape
    r2, c = w_pos.shape
    assert r == r2, (r, r2)
    assert r <= XBAR_ROWS, f"layer rows {r} exceed crossbar rows {XBAR_ROWS}"
    pb = (-b) % batch_block
    pc = (-c) % XBAR_COLS
    a_p = jnp.pad(a_code.astype(jnp.float32), ((0, pb), (0, 0)))
    wp_p = jnp.pad(w_pos.astype(jnp.float32), ((0, 0), (0, pc)))
    wn_p = jnp.pad(w_neg.astype(jnp.float32), ((0, 0), (0, pc)))
    bm = min(batch_block, a_p.shape[0])
    bc = min(XBAR_COLS, wp_p.shape[1])
    grid = (a_p.shape[0] // bm, wp_p.shape[1] // bc)
    out = pl.pallas_call(
        functools.partial(_xbar_kernel, a_bits=a_bits, adc_bits=adc_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((r, bc), lambda i, j: (0, j)),
            pl.BlockSpec((r, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a_p.shape[0], wp_p.shape[1]), jnp.float32),
        interpret=INTERPRET,
    )(a_p, wp_p, wn_p)
    return out[:b, :c]


def reram_linear(
    a_code: jnp.ndarray,
    slices_pos: jnp.ndarray,
    slices_neg: jnp.ndarray,
    adc_bits_per_slice,
    w_step: jnp.ndarray,
    a_step: jnp.ndarray,
    a_bits: int = ref.N_BITS,
) -> jnp.ndarray:
    """Full ReRAM linear layer over all four slice groups (LSB-first), with
    per-group ADC resolution — Pallas version of ``ref.reram_linear``."""
    out = jnp.zeros((a_code.shape[0], slices_pos.shape[2]), dtype=jnp.float32)
    for k in range(ref.N_SLICES):
        contrib = crossbar_mvm(
            a_code, slices_pos[k], slices_neg[k], int(adc_bits_per_slice[k]), a_bits
        )
        out = out + contrib * ref.SLICE_BASE**k
    return out * w_step * a_step

"""Pallas kernels for bit-slicing and the bit-slice l1 regularizer (Eq. 3).

  * ``bitslice``    — expand 8-bit codes into the four 2-bit slices the ReRAM
                      mapper stores on separate crossbar groups.
  * ``bl1_penalty`` — grid reduction of the digit sum  sum_{i,k} Bhat^{i,k}.
  * ``bl1_ste``     — the regularizer as a differentiable scalar: exact value
                      forward, straight-through surrogate gradient backward
                      (see DESIGN.md §7 and ``ref.bl1_grad``).

All element-wise slice math is VPU-shaped (no MXU); blocks are sized like the
quantize kernels (256x256 f32) so a slice pass streams HBM->VMEM once.
Lowered with ``interpret=True`` for the CPU PJRT backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref
from .quantize import BLOCK, LANE, INTERPRET, _as2d, _pad2d


def _bitslice_kernel(code_ref, s_ref):
    code = code_ref[...]
    # Unrolled over the 4 slices: (code >> 2k) & 3 in f32 arithmetic
    # (exact for code <= 255).
    for k in range(ref.N_SLICES):
        s_ref[k, ...] = jnp.mod(
            jnp.floor(code / ref.SLICE_BASE**k), ref.SLICE_BASE
        )


def bitslice(code: jnp.ndarray, block: int = BLOCK) -> jnp.ndarray:
    """Slice codes (f32 ints in [0,255]) into (N_SLICES,)+code.shape, LSB
    first — Pallas version of ``ref.bitslice``."""
    orig_shape = code.shape
    x = _as2d(code.astype(jnp.float32))
    bm, bn = min(block, x.shape[0]), x.shape[1]
    x = _pad2d(x, bm, bn)
    m, n = x.shape
    out = pl.pallas_call(
        _bitslice_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec(
            (ref.N_SLICES, bm, bn), lambda i, j: (0, i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((ref.N_SLICES, m, n), jnp.float32),
        interpret=INTERPRET,
    )(x)
    # un-pad and restore the original layout
    n_elems = int(np.prod(orig_shape)) if orig_shape else 1
    out = out.reshape(ref.N_SLICES, -1)[:, :n_elems]
    return out.reshape((ref.N_SLICES,) + orig_shape)


def _bl1_kernel(code_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    code = code_ref[...]
    # Digit-sum identity: sum of base-4 digits of B equals
    #   B - 3 * (floor(B/4) + floor(B/16) + floor(B/64))
    # — 3 floors instead of 4 (div, floor, mod) chains. (§Perf iteration 5.)
    shifted = (
        jnp.floor(code * (1.0 / 4.0))
        + jnp.floor(code * (1.0 / 16.0))
        + jnp.floor(code * (1.0 / 64.0))
    )
    total = jnp.sum(code - 3.0 * shifted)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init():
        o_ref[0, 0] = total

    @pl.when(jnp.logical_or(i != 0, j != 0))
    def _acc():
        o_ref[0, 0] = o_ref[0, 0] + total


def bl1_penalty(code: jnp.ndarray, block: int = BLOCK) -> jnp.ndarray:
    """Digit-sum reduction: Bl1(W) = sum_{i,k} Bhat^{i,k} (Eq. 3), as a
    sequential Pallas grid reduction. Zero padding contributes zero."""
    x = _as2d(code.astype(jnp.float32))
    bm, bn = min(block, x.shape[0]), x.shape[1]
    x = _pad2d(x, bm, bn)
    m, n = x.shape
    out = pl.pallas_call(
        _bl1_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=INTERPRET,
    )(x)
    return out[0, 0]


@jax.custom_vjp
def bl1_ste(q: jnp.ndarray, step: jnp.ndarray) -> jnp.ndarray:
    """Bit-slice l1 penalty of a quantized weight tensor, differentiable.

    Forward: the exact Eq. 3 digit sum of ``B = |q|/step`` (q is already a
    multiple of step, so the division recovers the integer code exactly).
    Backward: the straight-through surrogate ``sign(q) * (85/64) / step``
    (``ref.bl1_grad``); ``step`` itself gets no gradient (stop-gradient, as
    usual for dynamic-range parameters).
    """
    code = jnp.abs(q) / step
    return bl1_penalty(code)


def _bl1_fwd(q, step):
    return bl1_ste(q, step), (q, step)


def _bl1_bwd(res, g):
    q, step = res
    return (g * ref.bl1_grad(q, step), jnp.zeros_like(step))


bl1_ste.defvjp(_bl1_fwd, _bl1_bwd)


@functools.partial(jax.jit, static_argnums=(1,))
def slice_nonzero_counts(code: jnp.ndarray, block: int = BLOCK) -> jnp.ndarray:
    """Per-slice non-zero element counts (LSB-first, shape (4,)) — feeds the
    sparsity columns of Tables 1/2. Built on the Pallas bitslice kernel."""
    s = bitslice(code, block)
    return jnp.sum((s != 0).astype(jnp.float32), axis=tuple(range(1, s.ndim)))

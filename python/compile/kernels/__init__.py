"""L1 — Pallas kernels for bit-slice sparsity training and ReRAM deployment.

Modules:
  quantize — dynamic fixed-point quantization (Eqs. 1-2) + STE wrapper
  bitslice — 2-bit slice extraction + bit-slice l1 penalty (Eq. 3) + STE grad
  crossbar — ReRAM crossbar MVM functional simulator (bit-serial DAC + ADC)
  ref      — pure-jnp oracles every kernel is tested against
"""

from . import bitslice, crossbar, quantize, ref  # noqa: F401

"""Model definitions: shapes, parameter specs, BN state handling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module", params=["mlp", "vgg11", "resnet20"])
def model(request):
    return M.get_model(request.param)


def make_inputs(model, batch=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.uniform(0, 1, (batch,) + model.input_shape).astype(np.float32)
    )


def test_unknown_model_rejected():
    with pytest.raises(ValueError):
        M.get_model("alexnet")


def test_param_specs_are_unique_and_shaped(model):
    names = [s.name for s in model.param_specs]
    assert len(names) == len(set(names)), "duplicate param names"
    for s in model.param_specs:
        assert all(d > 0 for d in s.shape), s


def test_init_params_deterministic_and_spec_shaped(model):
    p1 = model.init_params(0)
    p2 = model.init_params(0)
    p3 = model.init_params(1)
    some_diff = False
    for s in model.param_specs:
        assert p1[s.name].shape == s.shape
        np.testing.assert_array_equal(np.asarray(p1[s.name]), np.asarray(p2[s.name]))
        if s.init_std > 0 and not np.array_equal(
            np.asarray(p1[s.name]), np.asarray(p3[s.name])
        ):
            some_diff = True
    assert some_diff, "different seeds gave identical weights"


def test_forward_shapes_and_finiteness(model):
    p = model.init_params(0)
    x = make_inputs(model, batch=2)
    logits, updates = model.apply(p, x, True)
    assert logits.shape == (2, model.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # eval mode produces no state updates
    logits_e, upd_e = model.apply(p, x, False)
    assert upd_e == {}
    assert logits_e.shape == (2, model.num_classes)


def test_bn_models_report_state_updates():
    m = M.get_model("resnet20")
    p = m.init_params(0)
    x = make_inputs(m)
    _, updates = m.apply(p, x, True)
    st_names = {s.name for s in m.specs_of_kind(*M.STATE_KINDS)}
    assert set(updates.keys()) == st_names
    # running stats moved toward batch stats (not equal to init)
    moved = any(
        not np.allclose(np.asarray(updates[n]), np.asarray(p[n])) for n in st_names
    )
    assert moved


def test_bn_free_models_have_no_state():
    for name in ["mlp", "vgg11"]:
        m = M.get_model(name)
        assert m.specs_of_kind(*M.STATE_KINDS) == []


def test_qweight_inventory_matches_paper_models():
    # MLP: two linear layers
    assert len(M.get_model("mlp").specs_of_kind(M.KIND_QWEIGHT)) == 2
    # VGG-11 config A: 8 convs + 1 fc
    assert len(M.get_model("vgg11").specs_of_kind(M.KIND_QWEIGHT)) == 9
    # ResNet-20: 1 stem + 9 blocks x 2 convs + 2 projections + fc = 22
    assert len(M.get_model("resnet20").specs_of_kind(M.KIND_QWEIGHT)) == 22


def test_param_counts_sane():
    def count(m):
        return sum(int(np.prod(s.shape)) for s in m.param_specs)

    assert 230_000 < count(M.get_model("mlp")) < 250_000
    assert 9_000_000 < count(M.get_model("vgg11")) < 10_000_000
    assert 250_000 < count(M.get_model("resnet20")) < 320_000


def test_gradients_flow_to_all_trainable_params():
    m = M.get_model("mlp")
    p = m.init_params(0)
    x = make_inputs(m, batch=4)
    y = jnp.asarray([0, 1, 2, 3], jnp.int32)

    def loss(p):
        logits, _ = m.apply(p, x, True)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    g = jax.grad(loss)(p)
    for s in m.specs_of_kind(*M.TRAINABLE_KINDS):
        assert float(jnp.max(jnp.abs(g[s.name]))) > 0.0, s.name

"""Kernel-vs-oracle correctness: the CORE correctness signal for L1.

Hypothesis sweeps shapes/scales/dtypes of the Pallas kernels and asserts
equality (these are exact integer/fixed-point computations — tolerances are
zero or ulp-level) against the pure-jnp references in ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitslice as bs
from compile.kernels import crossbar as xb
from compile.kernels import quantize as qz
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=20, deadline=None)


def arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(0.0, scale, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 400),
    n=st.integers(1, 400),
    scale=st.sampled_from([1e-4, 1e-2, 1.0, 37.5, 1e3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxabs_matches_ref(m, n, scale, seed):
    w = arr(np.random.default_rng(seed), (m, n), scale)
    assert float(qz.maxabs(w)) == float(jnp.max(jnp.abs(w)))


@settings(**SETTINGS)
@given(
    m=st.integers(1, 300),
    n=st.integers(1, 300),
    scale=st.sampled_from([1e-3, 0.1, 1.0, 12.3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(m, n, scale, seed):
    w = arr(np.random.default_rng(seed), (m, n), scale)
    q_r, c_r, s_r = ref.quantize(w)
    q_k, c_k, s_k = qz.quantize(w)
    assert float(s_r) == float(s_k)
    np.testing.assert_array_equal(np.asarray(q_r), np.asarray(q_k))
    np.testing.assert_array_equal(np.asarray(c_r), np.asarray(c_k))


@settings(**SETTINGS)
@given(
    shape=st.sampled_from([(7,), (64, 10), (3, 4, 5), (2, 3, 3, 8)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_arbitrary_rank(shape, seed):
    w = arr(np.random.default_rng(seed), shape, 0.5)
    q_r, c_r, _ = ref.quantize(w)
    q_k, c_k, _ = qz.quantize(w)
    np.testing.assert_array_equal(np.asarray(q_r), np.asarray(q_k))
    np.testing.assert_array_equal(np.asarray(c_r), np.asarray(c_k))


def test_quantize_code_range():
    rng = np.random.default_rng(1)
    w = arr(rng, (128, 128), 2.0)
    _, code, _ = qz.quantize(w)
    assert float(jnp.min(code)) >= 0.0
    assert float(jnp.max(code)) <= ref.CODE_MAX


def test_quantize_all_zero_tensor():
    w = jnp.zeros((33, 17), jnp.float32)
    q, code, step = qz.quantize(w)
    assert float(step) > 0.0  # EPS guard, no nan/inf
    np.testing.assert_array_equal(np.asarray(code), 0.0)
    np.testing.assert_array_equal(np.asarray(q), 0.0)


def test_quantize_error_bound():
    # |w - Q(w)| < Qstep for every element (floor quantization).
    rng = np.random.default_rng(2)
    w = arr(rng, (100, 100), 0.3)
    q, _, step = qz.quantize(w)
    assert float(jnp.max(jnp.abs(w - q))) < float(step)


def test_quantize_exact_power_of_two_max():
    # max|w| exactly 2^S must still produce codes <= 255 (clip of 256).
    w = jnp.asarray([[1.0, -1.0, 0.5, 0.25]], jnp.float32)
    _, code, step = qz.quantize(w)
    assert float(step) == 2.0**-8
    assert float(jnp.max(code)) == 255.0


def test_quantize_ste_gradient_is_identity():
    rng = np.random.default_rng(3)
    w = arr(rng, (50, 20), 0.1)
    g = jax.grad(lambda w: jnp.sum(qz.quantize_ste(w) * 3.0))(w)
    np.testing.assert_allclose(np.asarray(g), 3.0)


# ---------------------------------------------------------------------------
# bitslice / bl1
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 300),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitslice_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    code = jnp.asarray(rng.integers(0, 256, (m, n)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(ref.bitslice(code)), np.asarray(bs.bitslice(code))
    )


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitslice_recombination_invariant(m, n, seed):
    # sum_k Bhat^k * 4^k == B for every element
    rng = np.random.default_rng(seed)
    code = jnp.asarray(rng.integers(0, 256, (m, n)).astype(np.float32))
    s = bs.bitslice(code)
    recon = sum(s[k] * ref.SLICE_BASE**k for k in range(ref.N_SLICES))
    np.testing.assert_array_equal(np.asarray(recon), np.asarray(code))


def test_bitslice_slice_range():
    code = jnp.arange(256, dtype=jnp.float32).reshape(16, 16)
    s = bs.bitslice(code)
    assert float(jnp.min(s)) == 0.0
    assert float(jnp.max(s)) == ref.SLICE_MAX


def test_bitslice_known_values():
    # 0b11100100 = 228 -> slices LSB-first: 0, 1, 2, 3
    s = bs.bitslice(jnp.asarray([[228.0]]))
    np.testing.assert_array_equal(np.asarray(s).ravel(), [0.0, 1.0, 2.0, 3.0])


@settings(**SETTINGS)
@given(
    m=st.integers(1, 300),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_bl1_penalty_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    code = jnp.asarray(rng.integers(0, 256, (m, n)).astype(np.float32))
    np.testing.assert_allclose(
        float(bs.bl1_penalty(code)), float(ref.bl1_penalty(code)), rtol=1e-6
    )


def test_bl1_penalty_is_digit_sum():
    # single element 255 -> digit sum 3+3+3+3 = 12
    assert float(bs.bl1_penalty(jnp.asarray([[255.0]]))) == 12.0
    assert float(bs.bl1_penalty(jnp.asarray([[0.0]]))) == 0.0
    assert float(bs.bl1_penalty(jnp.asarray([[1.0]]))) == 1.0


def test_bl1_ste_value_and_grad():
    rng = np.random.default_rng(4)
    w = arr(rng, (40, 30), 0.2)
    q, code, step = qz.quantize(w)
    val, g = jax.value_and_grad(lambda q: bs.bl1_ste(q, step))(q)
    np.testing.assert_allclose(float(val), float(ref.bl1_penalty(code)), rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(g), np.asarray(ref.bl1_grad(q, step))
    )


def test_bl1_grad_sign_pulls_toward_zero():
    # gradient descent on Bl1 must shrink magnitudes: grad sign == weight sign
    rng = np.random.default_rng(5)
    w = arr(rng, (30, 30), 0.2)
    q, _, step = qz.quantize(w)
    g = jax.grad(lambda q: bs.bl1_ste(q, step))(q)
    nz = np.asarray(q) != 0
    assert np.all(np.sign(np.asarray(g))[nz] == np.sign(np.asarray(q))[nz])


def test_slice_nonzero_counts():
    code = jnp.asarray([[0.0, 1.0, 4.0, 16.0, 64.0, 255.0]])
    counts = bs.slice_nonzero_counts(code)
    # per slice LSB-first: slice0 nonzero for {1,255}; slice1 for {4,255};
    # slice2 for {16,255}; slice3 for {64,255}
    np.testing.assert_array_equal(np.asarray(counts), [2.0, 2.0, 2.0, 2.0])


# ---------------------------------------------------------------------------
# crossbar
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 64),
    r=st.integers(1, 128),
    c=st.integers(1, 200),
    adc_bits=st.sampled_from([1, 2, 3, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_crossbar_mvm_matches_ref(b, r, c, adc_bits, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 256, (b, r)).astype(np.float32))
    wp = jnp.asarray(rng.integers(0, 4, (r, c)).astype(np.float32))
    wn = jnp.asarray(rng.integers(0, 4, (r, c)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(ref.crossbar_mvm(a, wp, wn, adc_bits)),
        np.asarray(xb.crossbar_mvm(a, wp, wn, adc_bits)),
    )


def test_crossbar_high_resolution_is_exact():
    # With a big-enough ADC the crossbar computes the exact integer MVM.
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.integers(0, 256, (16, 100)).astype(np.float32))
    wp = jnp.asarray(rng.integers(0, 4, (100, 32)).astype(np.float32))
    wn = jnp.zeros((100, 32), jnp.float32)
    out = xb.crossbar_mvm(a, wp, wn, adc_bits=10)  # 2^10-1 > 100*3
    np.testing.assert_array_equal(np.asarray(out), np.asarray(a @ wp))


def test_crossbar_one_bit_adc_saturates():
    # Dense column with 1-bit ADC: every plane's current clips at 1.
    a = jnp.full((1, 128), 255.0)
    wp = jnp.full((128, 1), 3.0)
    wn = jnp.zeros((128, 1), jnp.float32)
    out = xb.crossbar_mvm(a, wp, wn, adc_bits=1)
    assert float(out[0, 0]) == 255.0  # sum over 8 planes of 1 * 2^t


def test_crossbar_rejects_oversized_rows():
    a = jnp.zeros((1, 129), jnp.float32)
    w = jnp.zeros((129, 4), jnp.float32)
    with pytest.raises(AssertionError):
        xb.crossbar_mvm(a, w, w, 8)


def test_reram_linear_matches_ref():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.integers(0, 256, (8, 64)).astype(np.float32))
    sp = jnp.asarray(rng.integers(0, 4, (4, 64, 40)).astype(np.float32))
    sn = jnp.asarray(rng.integers(0, 4, (4, 64, 40)).astype(np.float32))
    bits = [3, 3, 3, 1]
    ws = jnp.asarray(2.0**-8)
    as_ = jnp.asarray(2.0**-8)
    np.testing.assert_allclose(
        np.asarray(ref.reram_linear(a, sp, sn, bits, ws, as_)),
        np.asarray(xb.reram_linear(a, sp, sn, bits, ws, as_)),
        rtol=1e-6,
    )


def test_reram_linear_exact_when_high_adc():
    # The end-to-end deployment identity: with lossless ADC resolution the
    # ReRAM linear layer equals q_a @ q_w in real units.
    rng = np.random.default_rng(8)
    w = jnp.asarray(rng.normal(0, 0.1, (64, 24)).astype(np.float32))
    x = jnp.asarray(rng.uniform(0, 1, (4, 64)).astype(np.float32))
    qw, cw, sw = ref.quantize(w)
    qa, ca, sa = ref.quantize(x)
    slices = ref.bitslice(cw)
    pos = jnp.where(w > 0, slices, 0.0)
    neg = jnp.where(w < 0, slices, 0.0)
    out = xb.reram_linear(ca, pos, neg, [10, 10, 10, 10], sw, sa)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(qa @ qw), rtol=1e-4, atol=1e-5
    )

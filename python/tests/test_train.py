"""Training-step semantics (paper Eq. 4) and the exported graph contracts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T
from compile.kernels import ref


def setup_mlp(batch=8, seed=0):
    m = M.get_model("mlp")
    qw, tp, st = T._groups(m)
    rng = np.random.default_rng(seed)
    params = m.init_params(seed)
    qws = [params[s.name] for s in qw]
    tps = [params[s.name] for s in tp]
    sts = [params[s.name] for s in st]
    vqs = [jnp.zeros_like(w) for w in qws]
    vts = [jnp.zeros_like(t) for t in tps]
    masks = [jnp.ones_like(w) for w in qws]
    x = jnp.asarray(rng.uniform(0, 1, (batch, 784)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, batch).astype(np.int32))
    return m, (qws, tps, sts, vqs, vts, masks), (x, y)


def run_step(m, state, data, lr=0.1, mom=0.0, a1=0.0, ab=0.0):
    qws, tps, sts, vqs, vts, masks = state
    step = jax.jit(T.make_train_step(m))
    args = (
        qws + tps + sts + vqs + vts + masks
        + [data[0], data[1], jnp.float32(lr), jnp.float32(mom),
           jnp.float32(a1), jnp.float32(ab)]
    )
    outs = step(*args)
    nq, nt, ns = len(qws), len(tps), len(sts)
    i = 0
    new_qws = list(outs[i : i + nq]); i += nq
    new_tps = list(outs[i : i + nt]); i += nt
    new_sts = list(outs[i : i + ns]); i += ns
    new_vqs = list(outs[i : i + nq]); i += nq
    new_vts = list(outs[i : i + nt]); i += nt
    loss, ce, l1, bl1, correct = outs[i : i + 5]
    return (new_qws, new_tps, new_sts, new_vqs, new_vts, masks), {
        "loss": float(loss),
        "ce": float(ce),
        "l1": float(l1),
        "bl1": float(bl1),
        "correct": float(correct),
    }


def test_zero_lr_writes_back_quantized_weights():
    # Eq. 4 with lr=0: w' = Q(w) exactly (the quantize-replace of Fig. 1).
    m, state, data = setup_mlp()
    new_state, _ = run_step(m, state, data, lr=0.0)
    for w, w2 in zip(state[0], new_state[0]):
        q, _, _ = ref.quantize(w)
        np.testing.assert_array_equal(np.asarray(w2), np.asarray(q))


def test_reported_l1_and_bl1_match_reference():
    m, state, data = setup_mlp()
    _, metrics = run_step(m, state, data)
    want_l1 = sum(float(jnp.sum(jnp.abs(ref.quantize(w)[0]))) for w in state[0])
    want_bl1 = sum(float(ref.bl1_penalty(ref.quantize(w)[1])) for w in state[0])
    assert metrics["l1"] == pytest.approx(want_l1, rel=1e-5)
    assert metrics["bl1"] == pytest.approx(want_bl1, rel=1e-5)


def test_loss_composition():
    m, state, data = setup_mlp()
    a1, ab = 3e-5, 7e-7
    _, metrics = run_step(m, state, data, a1=a1, ab=ab)
    assert metrics["loss"] == pytest.approx(
        metrics["ce"] + a1 * metrics["l1"] + ab * metrics["bl1"], rel=1e-5
    )


def test_masks_freeze_weights_at_zero():
    m, state, data = setup_mlp()
    qws, tps, sts, vqs, vts, _ = state
    rng = np.random.default_rng(1)
    masks = [
        jnp.asarray((rng.uniform(size=w.shape) > 0.5).astype(np.float32))
        for w in qws
    ]
    state = (qws, tps, sts, vqs, vts, masks)
    new_state, _ = run_step(m, state, data, lr=0.5)
    for w2, mk in zip(new_state[0], masks):
        dead = np.asarray(w2)[np.asarray(mk) == 0.0]
        np.testing.assert_array_equal(dead, 0.0)


def test_repeated_steps_reduce_loss_on_fixed_batch():
    m, state, data = setup_mlp()
    losses = []
    for _ in range(12):
        state, metrics = run_step(m, state, data, lr=0.2, mom=0.9)
        losses.append(metrics["loss"])
    assert losses[-1] < losses[0] * 0.5, losses


def test_bl1_pressure_reduces_digit_sum():
    # strong alpha so the regularizer dominates the task gradient
    m, state, data = setup_mlp()
    bl1s = []
    for _ in range(15):
        state, metrics = run_step(m, state, data, lr=0.05, ab=3e-5)
        bl1s.append(metrics["bl1"])
    assert bl1s[-1] < bl1s[0], bl1s


def test_momentum_accumulates():
    m, state, data = setup_mlp()
    s1, _ = run_step(m, state, data, lr=0.1, mom=0.9)
    # velocity after first step equals the gradient (v = 0.9*0 + g) != 0
    assert any(float(jnp.max(jnp.abs(v))) > 0 for v in s1[3])


def test_eval_step_counts_correct_and_ignores_label_minus_one():
    m, state, data = setup_mlp()
    qws, tps, sts, _, _, masks = state
    ev = jax.jit(T.make_eval_step(m))
    x, y = data
    loss, correct = ev(*(qws + tps + sts + masks + [x, y]))
    assert 0.0 <= float(correct) <= x.shape[0]
    # label -1 rows can never be correct (evaluator wrap-fill contract)
    y_fill = jnp.full_like(y, -1)
    _, c2 = ev(*(qws + tps + sts + masks + [x, y_fill]))
    assert float(c2) == 0.0


def test_sparsity_report_matches_reference_counts():
    m, state, _ = setup_mlp()
    rep = jax.jit(T.make_sparsity_report(m))
    outs = rep(*state[0])
    nq = len(state[0])
    for i, w in enumerate(state[0]):
        counts = np.asarray(outs[i])
        _, code, _ = ref.quantize(w)
        want = np.asarray(
            jnp.sum((ref.bitslice(code) != 0).astype(jnp.float32), axis=(1, 2))
        )
        np.testing.assert_array_equal(counts, want)
        assert float(outs[nq + i]) == w.size


def test_reram_infer_graph_close_to_dense_quantized_forward():
    m, state, data = setup_mlp(batch=4)
    qws, tps, _, _, _, _ = state
    infer = jax.jit(T.make_reram_infer(m, (10, 10, 10, 10)))
    (logits,) = infer(qws[0], tps[0], qws[1], tps[1], data[0])
    # dense reference with quantized weights + quantized activations
    q1, _, _ = ref.quantize(qws[0])
    q2, _, _ = ref.quantize(qws[1])
    h = jnp.maximum(data[0] @ q1 + tps[0], 0.0)
    want = h @ q2 + tps[1]
    # activation quantization inside the reram path introduces small error
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(want), rtol=0.15, atol=0.05
    )


def test_reram_infer_rejects_non_mlp():
    with pytest.raises(ValueError):
        T.make_reram_infer(M.get_model("vgg11"), (3, 3, 3, 1))

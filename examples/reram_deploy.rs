//! ReRAM deployment study (Table 3 + ADC-accuracy validation).
//!
//! Takes a trained MLP checkpoint (trains a quick Bl1 one if none exists),
//! maps it onto 128x128 crossbars, and then:
//!
//!   * reports the measured per-slice ADC requirements (lossless and
//!     p99.9) and the Table-3 savings at the deployed resolutions;
//!   * validates the reduced-ADC deployment *functionally* through the
//!     unified `serve::InferenceBackend` seam — the AOT `mlp_reram_*`
//!     graphs (L1 Pallas crossbar kernel), the Rust crossbar simulator
//!     and the exact quantized reference all answer the same
//!     `serve::accuracy` call;
//!   * serves the test set through the batched `ServingEngine` and prints
//!     the throughput/latency report.
//!
//! With `--reorder`, the mapping additionally runs the wordline/column
//! reorder pass (`reram::reorder`) and the per-layer reorder table
//! (active wordlines/columns vs natural order) is printed.
//!
//! With `--replicate-budget F`, the planner's joint ADC/replica pass
//! (`PlannerConfig::replicate_budget`; F = multiples of the bottleneck
//! layer's fabricated cells, priced by `timing::factor_budget_cells`)
//! co-optimizes resolutions and replicas, and the serving section runs
//! the replica-sharded backend it selects.
//!
//! Run: `cargo run --release --example reram_deploy -- [--checkpoint DIR]
//!       [--reorder] [--replicate-budget 2.0]`

use std::sync::Arc;

use anyhow::Result;

use bitslice_reram::config::{Method, RunConfig};
use bitslice_reram::coordinator::{checkpoint, ModelState};
use bitslice_reram::data::Dataset;
use bitslice_reram::harness;
use bitslice_reram::report;
use bitslice_reram::reram::{timing, DeploymentPlan, ResolutionPolicy};
use bitslice_reram::runtime::{Engine, Manifest};
use bitslice_reram::serve::{
    self, CrossbarBackend, InferenceBackend, ReferenceBackend, ServeOptions, ServingEngine,
    SharedBackend, XlaBackend,
};
use bitslice_reram::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let ckpt_flag = args.str_opt("checkpoint");
    let reorder_cfg = if args.flag("reorder") {
        Some(bitslice_reram::reram::ReorderConfig::default())
    } else {
        None
    };
    let replicate_budget = args.f32_or("replicate-budget", 0.0)? as f64;
    let mut cfg = RunConfig::from_args(&args)?;
    args.finish()?;
    cfg.model = "mlp".into();
    cfg.out_dir = std::path::PathBuf::from("runs/deploy");

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let engine = Engine::cpu()?;
    let entry = manifest.model("mlp")?;

    // 1) get a trained state
    let state: ModelState = match ckpt_flag {
        Some(dir) => {
            let mut s = ModelState::init(entry, 0);
            let meta = checkpoint::load(std::path::Path::new(&dir), &mut s)?;
            println!("loaded checkpoint: {} ({}) @ step {}", meta.model, meta.method, meta.step);
            anyhow::ensure!(meta.model == "mlp", "this example deploys the MLP");
            s
        }
        None => {
            println!("no --checkpoint given; training a quick Bl1 MLP first");
            cfg.method = Method::Bl1;
            cfg.steps = 300;
            cfg.pretrain_steps = 150;
            let res = harness::run_training(&engine, &manifest, cfg.clone(), true)?;
            let mut s = ModelState::init(entry, 0);
            checkpoint::load(res.checkpoint_dir.as_ref().unwrap(), &mut s)?;
            s
        }
    };

    // 2) mapping + measured ADC requirements + Table 3 (reordered
    //    placement when --reorder is given)
    let deploy = harness::deploy_report(
        &state.named_qws(entry),
        ResolutionPolicy::Percentile(0.999),
        reorder_cfg,
        (replicate_budget > 0.0).then_some(replicate_budget),
    )?;
    println!(
        "mapping: {} crossbars; lossless bits (LSB..MSB) {:?}; p99.9 bits {:?}",
        deploy.crossbars, deploy.lossless_bits, deploy.deployed_bits
    );
    println!("{}", report::adc_table(&deploy.rows));
    println!(
        "{}",
        report::plan_table("per-layer deployment (p99.9 on each layer's census)", &deploy.plan_rows)
    );
    println!(
        "{}",
        report::storage_table("crossbar storage (density-chosen per tile)", &deploy.storage)
    );
    if let Some(rows) = &deploy.reorder {
        println!(
            "{}",
            report::reorder_table("wordline/column reorder (vs natural order)", rows)
        );
    }
    println!(
        "{}",
        report::timing_table("pipeline timing (latency x replicas)", &deploy.timing)
    );

    // 3) functional validation on the test set — every forward path is an
    //    InferenceBackend answering the same accuracy() call
    let test_ds = Dataset::auto("mnist", &cfg.data_dir, false, 1024, cfg.seed + 1)?;
    println!(
        "functional ADC validation on {} ({} examples):",
        test_ds.source,
        test_ds.len()
    );

    let stack = serve::dense_stack(&state.named_qws(entry), &state.tps)?;

    // 3a) AOT graphs (L1 Pallas crossbar kernel, interpret-lowered)
    for tag in ["reram_paper", "reram_lossless"] {
        let backend = XlaBackend::for_graph(&engine, &manifest, "mlp", tag, &state)?;
        let acc = serve::accuracy(&backend, &test_ds)?;
        println!("  {:24}: accuracy {:.2}%", backend.name(), acc.accuracy * 100.0);
    }

    // 3b) Rust simulator at the same operating points + exact reference,
    // deploying the report's own mapping (reordered iff the pass carried
    // permutations) — rebit shares it, so every operating point below
    // runs the same placement
    let plan = DeploymentPlan::uniform_for(&deploy.mapped, [3, 3, 3, 1]);
    let paper = CrossbarBackend::from_mapping("sim@paper(3,3,3,1)", deploy.mapped, &stack, plan)?;
    let lossless = paper.rebit("sim@lossless", [10, 10, 10, 10]);
    let reference = ReferenceBackend::new("quantized-reference", &stack)?;
    for backend in [&paper as &dyn InferenceBackend, &lossless, &reference] {
        let acc = serve::accuracy(backend, &test_ds)?;
        println!("  {:24}: accuracy {:.2}%", backend.name(), acc.accuracy * 100.0);
    }

    // 4) ADC-resolution sweep (ablation): where is the accuracy knee?
    println!("ADC-resolution sweep (uniform bits across slice groups):");
    println!("  bits | accuracy | whole-model energy saving");
    for bits in 1..=8u32 {
        let be = paper.rebit("sweep", [bits; 4]);
        let acc = serve::accuracy(&be, &test_ds)?;
        let e = bitslice_reram::reram::AdcModel::energy_saving(bits);
        println!("  {bits:>4} | {:>7.2}% | {e:.1}x", acc.accuracy * 100.0);
    }
    let measured = deploy.deployed_bits;
    let at_measured = paper.rebit("sim@p99.9", measured);
    let acc = serve::accuracy(&at_measured, &test_ds)?;
    let acc_lossless = serve::accuracy(&lossless, &test_ds)?;
    println!(
        "  measured p99.9 bits {:?}: accuracy {:.2}% (vs lossless {:.2}%)",
        measured,
        acc.accuracy * 100.0,
        acc_lossless.accuracy * 100.0
    );

    // 5) serve the test set through the batched engine (assemble the
    //    request load first so it is not charged to the serving window;
    //    intra_threads 1: the worker pool is the parallelism here)
    println!("batched serving (crossbar simulator at deployed bits):");
    let dim = test_ds.dim();
    let mut requests = Vec::with_capacity(test_ds.len());
    for i in 0..test_ds.len() {
        let mut x = vec![0.0f32; dim];
        test_ds.write_example(i, &mut x);
        requests.push(x);
    }
    // with a replication budget, serve the replica-sharded deployment the
    // planner's joint ADC/replica pass selects (PlannerConfig::
    // replicate_budget prices the budget through timing::
    // factor_budget_cells — the same anchor the deploy CLI uses, so the
    // example cannot drift from the search): batch rows fan out across
    // the bottleneck layers' Arc-shared copies
    let serve_backend = if replicate_budget > 0.0 {
        let search = bitslice_reram::reram::planner::plan_deployment_from(
            &at_measured,
            &reference,
            &test_ds,
            &bitslice_reram::reram::PlannerConfig {
                start_policy: ResolutionPolicy::Percentile(0.999),
                replicate_budget: Some(replicate_budget),
                ..Default::default()
            },
        )?;
        println!(
            "  joint ADC/replica search: {} replica cells spent, accuracy {:.2}%",
            search.replica_cells,
            search.accuracy * 100.0
        );
        println!(
            "{}",
            report::timing_table(
                "replicated pipeline timing (joint plan)",
                &timing::plan_timing(at_measured.mapped(), &search.plan)
            )
        );
        at_measured.replan("sim@joint-replicated", search.plan)?
    } else {
        at_measured
    };
    // engine workers x replica shards must not oversubscribe the cores:
    // replicas already parallelize inside each batch, so scale the batch
    // worker pool down by the replica fan-out
    let workers = (bitslice_reram::util::pool::worker_threads() / serve_backend.max_replicas())
        .clamp(1, 8);
    let shared: SharedBackend = Arc::new(serve_backend.with_intra_threads(1));
    let eng = ServingEngine::start(
        shared,
        ServeOptions {
            workers,
            ..ServeOptions::default()
        },
    )?;
    let responses = eng.infer_many(requests)?;
    let mut correct = 0usize;
    for (i, row) in responses.iter().enumerate() {
        let pred = (0..row.len())
            .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
            .unwrap();
        if pred as i32 == test_ds.labels[i] {
            correct += 1;
        }
    }
    let stats = eng.shutdown();
    println!(
        "  served {} requests, accuracy {:.2}%",
        stats.requests,
        100.0 * correct as f64 / test_ds.len() as f64
    );
    println!("{}", report::serving_table(&[stats.row()]));
    Ok(())
}

//! ReRAM deployment study (Table 3 + ADC-accuracy validation).
//!
//! Takes a trained MLP checkpoint (trains a quick Bl1 one if none exists),
//! maps it onto 128x128 crossbars, and then:
//!
//!   * reports the measured per-slice ADC requirements (lossless and
//!     p99.9) and the Table-3 savings at the deployed resolutions;
//!   * validates the reduced-ADC deployment *functionally*, comparing test
//!     accuracy under the paper's (1-bit MSB / 3-bit rest) ADCs against
//!     the lossless reference — using both the AOT `mlp_reram_*` graphs
//!     (L1 Pallas crossbar kernel) and the Rust `reram::sim` substrate,
//!     which are cross-checked against each other.
//!
//! Run: `cargo run --release --example reram_deploy -- [--checkpoint DIR]`

use anyhow::Result;

use bitslice_reram::config::{Method, RunConfig};
use bitslice_reram::coordinator::{checkpoint, ModelState};
use bitslice_reram::data::loader::EvalBatches;
use bitslice_reram::data::Dataset;
use bitslice_reram::harness;
use bitslice_reram::report;
use bitslice_reram::reram::{sim, ResolutionPolicy};
use bitslice_reram::runtime::{Engine, Manifest};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let ckpt_flag = args.str_opt("checkpoint");
    let mut cfg = RunConfig::from_args(&args)?;
    args.finish()?;
    cfg.model = "mlp".into();
    cfg.out_dir = std::path::PathBuf::from("runs/deploy");

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let engine = Engine::cpu()?;
    let entry = manifest.model("mlp")?;

    // 1) get a trained state
    let state: ModelState = match ckpt_flag {
        Some(dir) => {
            let mut s = ModelState::init(entry, 0);
            let meta = checkpoint::load(std::path::Path::new(&dir), &mut s)?;
            println!("loaded checkpoint: {} ({}) @ step {}", meta.model, meta.method, meta.step);
            anyhow::ensure!(meta.model == "mlp", "this example deploys the MLP");
            s
        }
        None => {
            println!("no --checkpoint given; training a quick Bl1 MLP first");
            cfg.method = Method::Bl1;
            cfg.steps = 300;
            cfg.pretrain_steps = 150;
            let res = harness::run_training(&engine, &manifest, cfg.clone(), true)?;
            let mut s = ModelState::init(entry, 0);
            checkpoint::load(res.checkpoint_dir.as_ref().unwrap(), &mut s)?;
            s
        }
    };

    // 2) mapping + measured ADC requirements + Table 3
    let deploy = harness::deploy_report(
        &state.named_qws(entry),
        ResolutionPolicy::Percentile(0.999),
    )?;
    println!(
        "mapping: {} crossbars; lossless bits (LSB..MSB) {:?}; p99.9 bits {:?}",
        deploy.crossbars, deploy.lossless_bits, deploy.deployed_bits
    );
    println!("{}", report::adc_table(&deploy.rows));

    // 3) functional validation on the test set
    let test_ds = Dataset::auto("mnist", &cfg.data_dir, false, 1024, cfg.seed + 1)?;
    println!(
        "functional ADC validation on {} ({} examples):",
        test_ds.source,
        test_ds.len()
    );

    // 3a) AOT graphs (L1 Pallas crossbar kernel, interpret-lowered)
    for tag in ["reram_paper", "reram_lossless"] {
        let acc = reram_graph_accuracy(&engine, &manifest, &state, &test_ds, tag)?;
        println!("  AOT {tag:16}: accuracy {:.2}%", acc * 100.0);
    }

    // 3b) Rust simulator at the same operating points
    for (label, bits) in [
        ("sim (3,3,3,1)", [3u32, 3, 3, 1]),
        ("sim lossless", [10, 10, 10, 10]),
    ] {
        let acc = rust_sim_accuracy(&state, &test_ds, &bits)?;
        println!("  {label:20}: accuracy {:.2}%", acc * 100.0);
    }

    // 4) ADC-resolution sweep (ablation): where is the accuracy knee?
    println!("ADC-resolution sweep (uniform bits across slice groups):");
    println!("  bits | accuracy | whole-model energy saving");
    for bits in 1..=8u32 {
        let acc = rust_sim_accuracy(&state, &test_ds, &[bits; 4])?;
        let e = bitslice_reram::reram::AdcModel::energy_saving(bits);
        println!("  {bits:>4} | {:>7.2}% | {e:.1}x", acc * 100.0);
    }
    let measured = deploy.deployed_bits;
    let acc = rust_sim_accuracy(&state, &test_ds, &measured)?;
    println!(
        "  measured p99.9 bits {:?}: accuracy {:.2}% (vs lossless {:.2}%)",
        measured,
        acc * 100.0,
        rust_sim_accuracy(&state, &test_ds, &[10; 4])? * 100.0
    );
    Ok(())
}

/// Accuracy via the AOT reram inference graph (fixed batch shape).
fn reram_graph_accuracy(
    engine: &Engine,
    manifest: &Manifest,
    state: &ModelState,
    ds: &Dataset,
    graph: &str,
) -> Result<f64> {
    let entry = manifest.model("mlp")?;
    let g = entry.graph(graph)?;
    let exe = engine.load(&g.path)?;
    // inputs: qw:fc1/w tp:fc1/b qw:fc2/w tp:fc2/b x
    let w1 = state.qws[0].to_literal()?;
    let b1 = state.tps[0].to_literal()?;
    let w2 = state.qws[1].to_literal()?;
    let b2 = state.tps[1].to_literal()?;
    let mut correct = 0usize;
    let mut total = 0usize;
    for eb in EvalBatches::new(ds, entry.batch) {
        let x = eb.batch.x.to_literal()?;
        let inputs: Vec<&xla::Literal> = vec![&w1, &b1, &w2, &b2, &x];
        let outs = exe.run(&inputs)?;
        let logits = Tensor::from_literal(&outs[0])?;
        for row in 0..eb.valid {
            let start = row * 10;
            let pred = (0..10)
                .max_by(|&a, &b| {
                    logits.data()[start + a]
                        .partial_cmp(&logits.data()[start + b])
                        .unwrap()
                })
                .unwrap();
            if pred as i32 == eb.batch.y.data()[row] {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Accuracy via the Rust crossbar simulator (reram::sim).
fn rust_sim_accuracy(state: &ModelState, ds: &Dataset, bits: &[u32; 4]) -> Result<f64> {
    let l1 = bitslice_reram::reram::mapper::map_layer("fc1/w", &state.qws[0])?;
    let l2 = bitslice_reram::reram::mapper::map_layer("fc2/w", &state.qws[1])?;
    let b1 = state.tps[0].data();
    let b2 = state.tps[1].data();
    let dim = ds.dim();
    let n = ds.len();
    let mut x = vec![0.0f32; n * dim];
    for i in 0..n {
        ds.write_example(i, &mut x[i * dim..(i + 1) * dim]);
    }
    let xt = Tensor::new(vec![n, dim], x)?;
    // layer 1 + bias + relu
    let mut h = sim::forward(&l1, &xt, bits);
    for (i, v) in h.data_mut().iter_mut().enumerate() {
        *v = (*v + b1[i % 300]).max(0.0);
    }
    // layer 2 + bias
    let mut logits = sim::forward(&l2, &h, bits);
    for (i, v) in logits.data_mut().iter_mut().enumerate() {
        *v += b2[i % 10];
    }
    let mut correct = 0usize;
    for i in 0..n {
        let row = &logits.data()[i * 10..(i + 1) * 10];
        let pred = (0..10)
            .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
            .unwrap();
        if pred as i32 == ds.labels[i] {
            correct += 1;
        }
    }
    Ok(correct as f64 / n as f64)
}

//! Table 1 end-to-end: Pruned vs l1 vs Bl1 on the MNIST toy MLP.
//!
//! Runs the three training routines of the paper's Table 1 back to back
//! (same seed, same data), prints the paper-format table, and saves
//! checkpoints under `runs/table1/` for later `analyze` / `deploy` runs.
//!
//! Flags: `--steps N --pretrain-steps N --seed N` (defaults: 400/200/42).
//! Run: `cargo run --release --example mnist_bitslice -- --steps 300`

use anyhow::Result;

use bitslice_reram::config::RunConfig;
use bitslice_reram::harness;
use bitslice_reram::report;
use bitslice_reram::runtime::{Engine, Manifest};
use bitslice_reram::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut cfg = RunConfig::from_args(&args)?;
    args.finish()?;
    cfg.model = "mlp".into();
    cfg.dataset = "mnist".into();
    cfg.out_dir = std::path::PathBuf::from("runs/table1");

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let engine = Engine::cpu()?;

    let results = harness::reproduce_sparsity_table(&engine, &manifest, &cfg)?;
    let rows: Vec<_> = results.iter().map(|r| r.method_row()).collect();
    println!(
        "{}",
        report::sparsity_table(
            &format!(
                "Table 1 — MNIST toy model, {} steps + {} pretrain ({})",
                cfg.steps, cfg.pretrain_steps, results[0].dataset_source
            ),
            &rows
        )
    );

    // The paper's headline: Bl1 roughly halves the average non-zero slice
    // ratio vs l1. Print the measured improvement factor.
    let l1_avg = rows[1].stats.mean_std().0;
    let bl1_avg = rows[2].stats.mean_std().0;
    if bl1_avg > 0.0 {
        println!(
            "Bl1 average-sparsity improvement over l1: {:.2}x (paper: ~1.3-2x)",
            l1_avg / bl1_avg
        );
    }
    for r in &results {
        if let Some(dir) = &r.checkpoint_dir {
            println!("checkpoint [{}]: {}", r.cfg.method.name(), dir.display());
        }
    }
    Ok(())
}

//! CIFAR-10 pipeline (Table 2 / Figure 2 workload) on a conv model.
//!
//! Trains ResNet-20 (default; `--model vgg11` for the bigger one) with the
//! l1 and Bl1 routines, tracing per-slice sparsity during training — the
//! series Figure 2 plots — and prints the Table-2 style rows at the end.
//!
//! Conv training on the CPU backend is the slow path, so the default step
//! counts are modest; scale `--steps` up on a real machine.
//!
//! Run: `cargo run --release --example cifar_pipeline -- --steps 80`

use anyhow::Result;

use bitslice_reram::config::{Method, RunConfig};
use bitslice_reram::harness;
use bitslice_reram::report;
use bitslice_reram::runtime::{Engine, Manifest};
use bitslice_reram::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut cfg = RunConfig::from_args(&args)?;
    args.finish()?;
    if cfg.model == "mlp" {
        cfg.model = "resnet20".into(); // conv default for this example
    }
    cfg.dataset = "cifar10".into();
    if cfg.trace_every == 0 {
        cfg.trace_every = (cfg.steps / 20).max(1);
    }
    cfg.out_dir = std::path::PathBuf::from("runs/cifar");

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let engine = Engine::cpu()?;

    let mut rows = Vec::new();
    let mut traces = Vec::new();
    for method in [Method::L1, Method::Bl1] {
        let mut c = cfg.clone();
        c.method = method;
        let res = harness::run_training(&engine, &manifest, c, true)?;
        traces.push((method.name().to_string(), res.trace.clone()));
        rows.push(res.method_row());
    }

    println!(
        "{}",
        report::sparsity_table(
            &format!("Table 2 (excerpt) — {} on CIFAR-10", cfg.model),
            &rows
        )
    );

    // Figure-2 style: show the sparsity trajectory head/tail per method.
    println!("Figure 2 — average non-zero slice ratio during training:");
    for (m, pts) in &traces {
        print!("  {m}:");
        for p in pts.iter().step_by((pts.len() / 6).max(1)) {
            print!(" {:.1}%", p.ratios.iter().sum::<f64>() / 4.0 * 100.0);
        }
        println!();
    }
    let csv = report::fig2_csv(&traces);
    let path = cfg.out_dir.join(format!("fig2-{}.csv", cfg.model));
    std::fs::write(&path, csv)?;
    println!("full series: {}", path.display());
    Ok(())
}

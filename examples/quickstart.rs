//! End-to-end driver (the repo's E2E validation workload).
//!
//! Exercises every layer of the stack on one real small workload:
//!
//!   1. load the AOT artifacts (L2 JAX graphs with L1 Pallas kernels)
//!   2. train the MNIST MLP with the paper's Bl1 routine (l1 pretrain ->
//!      bit-slice l1), logging the loss curve
//!   3. evaluate quantized deployment accuracy
//!   4. census the bit-slice sparsity (Table-1 row)
//!   5. map the weights onto 128x128 ReRAM crossbars, derive the required
//!      ADC resolutions, and print the Table-3 savings
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).
//! The printed loss curve + final report are recorded in EXPERIMENTS.md.

use anyhow::Result;

use bitslice_reram::config::{Method, RunConfig};
use bitslice_reram::coordinator::metrics::MetricsLog;
use bitslice_reram::coordinator::{evaluator, Trainer};
use bitslice_reram::data::Dataset;
use bitslice_reram::harness;
use bitslice_reram::report;
use bitslice_reram::reram::ResolutionPolicy;
use bitslice_reram::runtime::{Engine, Manifest};
use bitslice_reram::sparsity;

fn main() -> Result<()> {
    let mut cfg = RunConfig::defaults("mlp");
    cfg.method = Method::Bl1;
    cfg.steps = 300;
    cfg.pretrain_steps = 150;
    cfg.out_dir = std::path::PathBuf::from("runs/quickstart");

    println!("== bitslice-reram quickstart ==");
    println!("1) loading artifacts + PJRT CPU client");
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let engine = Engine::cpu()?;
    println!("   platform: {}", engine.platform());

    println!(
        "2) training {} with the Bl1 routine ({} + {} steps)",
        cfg.model, cfg.pretrain_steps, cfg.steps
    );
    let train_ds = Dataset::auto(&cfg.dataset, &cfg.data_dir, true, cfg.train_examples, cfg.seed)?;
    let test_ds =
        Dataset::auto(&cfg.dataset, &cfg.data_dir, false, cfg.test_examples, cfg.seed + 1)?;
    println!(
        "   data: {} ({} train / {} test)",
        train_ds.source,
        train_ds.len(),
        test_ds.len()
    );

    let mut log = MetricsLog::create(Some(&cfg.out_dir))?;
    let mut trainer = Trainer::new(&engine, &manifest, cfg.clone())?;
    let outcome = trainer.run(&train_ds, &mut log)?;

    println!("   loss curve (every 30 steps):");
    for m in log.history.iter().step_by(30) {
        println!(
            "     step {:>4} [{}] loss {:.4}  ce {:.4}  batch-acc {:.2}%",
            m.step,
            m.phase,
            m.loss,
            m.ce,
            m.batch_accuracy * 100.0
        );
    }
    println!(
        "   {} steps, mean step latency {:.1} ms",
        outcome.steps_run, outcome.mean_step_ms
    );

    println!("3) quantized deployment accuracy");
    let eval = evaluator::evaluate(&engine, &manifest, &cfg.model, &trainer.state, &test_ds)?;
    println!(
        "   accuracy {:.2}% over {} examples",
        eval.accuracy * 100.0,
        eval.examples
    );

    println!("4) bit-slice sparsity census (Table-1 row)");
    let stats = sparsity::census(&trainer.state.qws);
    println!(
        "{}",
        report::sparsity_table(
            "quickstart",
            &[report::MethodRow {
                method: "Bl1".into(),
                accuracy: eval.accuracy,
                stats: stats.clone(),
            }]
        )
    );

    println!("5) ReRAM deployment (128x128 crossbars, 2-bit cells)");
    let entry = manifest.model(&cfg.model)?;
    let deploy = harness::deploy_report(
        &trainer.state.named_qws(entry),
        ResolutionPolicy::Percentile(0.999),
        None,
        None,
    )?;
    println!(
        "   {} crossbars; lossless ADC bits (LSB..MSB) {:?}; p99.9 {:?}",
        deploy.crossbars, deploy.lossless_bits, deploy.deployed_bits
    );
    println!("{}", report::adc_table(&deploy.rows));
    let (e, t, a) = deploy.savings;
    println!(
        "   whole-model ADC savings vs 8-bit baseline: energy {e:.1}x, time {t:.2}x, area {a:.1}x"
    );

    println!("quickstart OK");
    Ok(())
}

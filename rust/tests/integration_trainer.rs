//! Integration: the full coordinator loop over the real AOT artifacts —
//! phases, pruning, masks, checkpointing, evaluation, BN calibration.
//!
//! Skips (with a note) when `artifacts/` is absent.

use bitslice_reram::config::{Method, RunConfig};
use bitslice_reram::coordinator::metrics::MetricsLog;
use bitslice_reram::coordinator::{checkpoint, evaluator, ModelState, Trainer};
use bitslice_reram::data::Dataset;
use bitslice_reram::harness;
use bitslice_reram::runtime::{Engine, Manifest};
use bitslice_reram::sparsity;

fn setup() -> Option<(Engine, Manifest)> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some((Engine::cpu().unwrap(), Manifest::load(&dir).unwrap()))
}

fn quick_cfg(method: Method) -> RunConfig {
    let mut cfg = RunConfig::defaults("mlp");
    cfg.method = method;
    cfg.steps = 40;
    cfg.pretrain_steps = 20;
    cfg.train_examples = 1024;
    cfg.test_examples = 256;
    cfg.out_dir = std::env::temp_dir().join(format!("itrainer-{}", std::process::id()));
    cfg
}

#[test]
fn baseline_training_learns_the_synthetic_task() {
    let Some((engine, manifest)) = setup() else { return };
    let mut cfg = quick_cfg(Method::Baseline);
    cfg.steps = 120;
    cfg.pretrain_steps = 0;
    let res = harness::run_training(&engine, &manifest, cfg, false).unwrap();
    assert!(
        res.eval.accuracy > 0.8,
        "baseline accuracy {} too low",
        res.eval.accuracy
    );
    assert_eq!(res.eval.examples, 256);
    assert!(res.outcome.final_loss.is_finite());
}

#[test]
fn bl1_phases_run_and_increase_slice_sparsity_vs_baseline() {
    let Some((engine, manifest)) = setup() else { return };
    let base = harness::run_training(&engine, &manifest, quick_cfg(Method::Baseline), false)
        .unwrap();
    let bl1 =
        harness::run_training(&engine, &manifest, quick_cfg(Method::Bl1), false).unwrap();
    let (b_avg, _) = base.stats.mean_std();
    let (r_avg, _) = bl1.stats.mean_std();
    assert!(
        r_avg < b_avg,
        "bl1 avg nonzero {r_avg} not sparser than baseline {b_avg}"
    );
}

#[test]
fn pruned_method_respects_masks_through_finetune() {
    let Some((engine, manifest)) = setup() else { return };
    let mut cfg = quick_cfg(Method::Pruned);
    cfg.prune_fraction = 0.8;
    let res = harness::run_training(&engine, &manifest, cfg, true).unwrap();
    // reload the checkpoint and verify masked weights stayed exactly zero
    let entry = manifest.model("mlp").unwrap();
    let mut state = ModelState::init(entry, 0);
    checkpoint::load(res.checkpoint_dir.as_ref().unwrap(), &mut state).unwrap();
    let mut masked = 0usize;
    let mut violations = 0usize;
    for (w, m) in state.qws.iter().zip(&state.masks) {
        for (wv, mv) in w.data().iter().zip(m.data()) {
            if *mv == 0.0 {
                masked += 1;
                if *wv != 0.0 {
                    violations += 1;
                }
            }
        }
    }
    let total: usize = state.qws.iter().map(|w| w.len()).sum();
    assert!(masked as f64 / total as f64 > 0.75, "masked {masked}/{total}");
    assert_eq!(violations, 0, "pruned weights resurrected");
}

#[test]
fn trace_points_are_recorded_and_monotone_in_step() {
    let Some((engine, manifest)) = setup() else { return };
    let mut cfg = quick_cfg(Method::L1);
    cfg.trace_every = 8;
    let train_ds = Dataset::auto("mnist", &cfg.data_dir, true, 1024, 1).unwrap();
    let mut log = MetricsLog::create(None).unwrap();
    let mut trainer = Trainer::new(&engine, &manifest, cfg).unwrap();
    trainer.run(&train_ds, &mut log).unwrap();
    assert!(!log.trace.is_empty());
    for w in log.trace.windows(2) {
        assert!(w[0].step < w[1].step);
    }
    for p in &log.trace {
        for r in p.ratios {
            assert!((0.0..=1.0).contains(&r));
        }
    }
}

#[test]
fn checkpoint_roundtrip_preserves_eval_accuracy() {
    let Some((engine, manifest)) = setup() else { return };
    let cfg = quick_cfg(Method::L1);
    let res = harness::run_training(&engine, &manifest, cfg.clone(), true).unwrap();
    let entry = manifest.model("mlp").unwrap();
    let mut state = ModelState::init(entry, 999);
    checkpoint::load(res.checkpoint_dir.as_ref().unwrap(), &mut state).unwrap();
    let test_ds = Dataset::auto("mnist", &cfg.data_dir, false, 256, cfg.seed + 1).unwrap();
    let eval = evaluator::evaluate(&engine, &manifest, "mlp", &state, &test_ds).unwrap();
    assert!(
        (eval.accuracy - res.eval.accuracy).abs() < 1e-9,
        "checkpoint accuracy {} != run accuracy {}",
        eval.accuracy,
        res.eval.accuracy
    );
}

#[test]
fn trainer_census_matches_final_state_census() {
    let Some((engine, manifest)) = setup() else { return };
    let cfg = quick_cfg(Method::Bl1);
    let train_ds = Dataset::auto("mnist", &cfg.data_dir, true, 1024, 2).unwrap();
    let mut log = MetricsLog::create(None).unwrap();
    let mut trainer = Trainer::new(&engine, &manifest, cfg).unwrap();
    trainer.run(&train_ds, &mut log).unwrap();
    let a = sparsity::census(&trainer.state.qws);
    let b = sparsity::census(&trainer.state.qws);
    assert_eq!(a, b); // deterministic + pure
    assert_eq!(a.numel, manifest.model("mlp").unwrap().qw_numel());
}

#[test]
fn resnet20_one_phase_runs_with_bn_state() {
    let Some((engine, manifest)) = setup() else { return };
    let mut cfg = RunConfig::defaults("resnet20");
    cfg.method = Method::Baseline;
    cfg.steps = 3;
    cfg.pretrain_steps = 0;
    cfg.train_examples = 128;
    cfg.test_examples = 64;
    cfg.out_dir = std::env::temp_dir().join(format!("itrainer-rn-{}", std::process::id()));
    let train_ds = Dataset::auto("cifar10", &cfg.data_dir, true, 128, 3).unwrap();
    let mut log = MetricsLog::create(None).unwrap();
    let mut trainer = Trainer::new(&engine, &manifest, cfg).unwrap();
    let out = trainer.run(&train_ds, &mut log).unwrap();
    assert_eq!(out.steps_run, 3);
    // BN running stats must have moved off their init values
    let moved = trainer
        .state
        .sts
        .iter()
        .any(|t| t.data().iter().any(|&v| v != 0.0 && v != 1.0));
    assert!(moved, "bn running stats never updated");
    // BN calibration must run without error and keep stats finite
    evaluator::bn_calibrate(&engine, &manifest, "resnet20", &mut trainer.state, &train_ds, 3, 1)
        .unwrap();
    for t in &trainer.state.sts {
        assert!(t.data().iter().all(|v| v.is_finite()));
    }
}

//! Integration: the ReRAM deployment stack against the AOT crossbar graphs
//! — the L1 Pallas crossbar kernel and the Rust simulator must agree
//! exactly, and the sparsity -> ADC-resolution -> savings chain must be
//! self-consistent on trained weights.

use bitslice_reram::quant;
use bitslice_reram::reram::{
    energy, mapper, reorder, resolution, sim, ReorderConfig, ResolutionPolicy, StorageFormat,
};
use bitslice_reram::runtime::{Engine, Manifest};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::fixtures;
use bitslice_reram::util::rng::Rng;

fn setup() -> Option<(Engine, Manifest)> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some((Engine::cpu().unwrap(), Manifest::load(&dir).unwrap()))
}

/// The AOT `kernel_crossbar_tile` graph (Pallas, adc_bits=3) must agree
/// exactly with the Rust crossbar simulator on the same tile.
#[test]
fn pallas_crossbar_kernel_matches_rust_sim_exactly() {
    let Some((engine, manifest)) = setup() else { return };
    let g = manifest.kernels.get("crossbar_tile").expect("kernel entry");
    let exe = engine.load(&g.path).unwrap();

    let mut rng = Rng::new(77);
    // activations: integer codes 0..255; weights: cells 0..3
    let a: Vec<f32> = (0..128 * 128).map(|_| rng.below(256) as f32).collect();
    let wp: Vec<f32> = (0..128 * 128).map(|_| rng.below(4) as f32).collect();
    let wn: Vec<f32> = (0..128 * 128).map(|_| rng.below(4) as f32).collect();

    let lits = [
        Tensor::new(vec![128, 128], a.clone()).unwrap().to_literal().unwrap(),
        Tensor::new(vec![128, 128], wp.clone()).unwrap().to_literal().unwrap(),
        Tensor::new(vec![128, 128], wn.clone()).unwrap().to_literal().unwrap(),
    ];
    let outs = exe.run(&lits).unwrap();
    let pallas_out = Tensor::from_literal(&outs[0]).unwrap();

    // Rust side: build the equivalent single-slice layer mapping by hand.
    let mut pos = bitslice_reram::reram::Crossbar::zeros(128, 128);
    let mut neg = bitslice_reram::reram::Crossbar::zeros(128, 128);
    for r in 0..128 {
        for c in 0..128 {
            pos.set(r, c, wp[r * 128 + c] as u8);
            neg.set(r, c, wn[r * 128 + c] as u8);
        }
    }
    let mut max_err = 0.0f32;
    let mut cur_p = vec![0u32; 128];
    let mut cur_n = vec![0u32; 128];
    for row in 0..128 {
        let code: Vec<u8> = (0..128).map(|i| a[row * 128 + i] as u8).collect();
        let mut acc = vec![0i64; 128];
        for t in 0..8u32 {
            let bits: Vec<u8> = code.iter().map(|&c| (c >> t) & 1).collect();
            pos.bitline_currents(&bits, &mut cur_p);
            neg.bitline_currents(&bits, &mut cur_n);
            for j in 0..128 {
                let ip = sim::adc_clip(cur_p[j], 3) as i64;
                let inn = sim::adc_clip(cur_n[j], 3) as i64;
                acc[j] += (ip - inn) << t;
            }
        }
        for j in 0..128 {
            max_err = max_err.max((pallas_out.at2(row, j) - acc[j] as f32).abs());
        }
    }
    assert_eq!(max_err, 0.0, "pallas kernel vs rust sim disagree");
}

/// Mapping + resolution + savings must be internally consistent on weights
/// that actually went through Bl1 training semantics (quantize + slice).
#[test]
fn deployment_chain_is_self_consistent() {
    let mut rng = Rng::new(3);
    // sparse-ish weights emulating a regularized layer
    let n = 784 * 300;
    let mut data = vec![0.0f32; n];
    for _ in 0..n / 50 {
        let i = rng.below(n);
        data[i] = rng.normal() * 0.05;
    }
    data[0] = 0.9;
    let w = Tensor::new(vec![784, 300], data).unwrap();

    let mapped = mapper::map_model(&[("w".into(), w.clone())]).unwrap();
    // cells in the mapping == slice nonzeros from the census
    let stats = bitslice_reram::sparsity::census(std::slice::from_ref(&w));
    for k in 0..4 {
        assert_eq!(mapped.layers[0].nonzero_cells(k), stats.nonzero[k]);
    }

    let lossless = resolution::required_bits(&mapped, ResolutionPolicy::Lossless);
    // lossless bits must actually be lossless in the functional sim:
    let x = Tensor::new(vec![4, 784], (0..4 * 784).map(|_| rng.next_f32()).collect()).unwrap();
    let out_lossless = sim::forward(&mapped.layers[0], &x, &lossless);
    let out_10bit = sim::forward(&mapped.layers[0], &x, &[10; 4]);
    for (a, b) in out_lossless.data().iter().zip(out_10bit.data()) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }

    // savings must be >= 1 when any group uses fewer bits than baseline
    let p999 = resolution::required_bits(&mapped, ResolutionPolicy::Percentile(0.999));
    let (e, t, ar) = energy::savings_vs_baseline(&mapped, p999);
    assert!(e >= 1.0 && t >= 1.0 && ar >= 1.0);
}

/// The `mlp_reram_lossless` AOT graph must agree with the Rust simulator
/// end to end (two layers, tiling, activation quantization, bias, relu).
#[test]
fn aot_reram_graph_matches_rust_end_to_end() {
    let Some((engine, manifest)) = setup() else { return };
    let entry = manifest.model("mlp").unwrap();
    let g = entry.graph("reram_lossless").unwrap();
    let exe = engine.load(&g.path).unwrap();

    let mut rng = Rng::new(9);
    let w1 = Tensor::new(vec![784, 300], rng.normal_vec(784 * 300, 0.03)).unwrap();
    let b1 = Tensor::new(vec![300], rng.normal_vec(300, 0.01)).unwrap();
    let w2 = Tensor::new(vec![300, 10], rng.normal_vec(3000, 0.05)).unwrap();
    let b2 = Tensor::new(vec![10], rng.normal_vec(10, 0.01)).unwrap();
    let batch = entry.batch;
    let x = Tensor::new(
        vec![batch, 784],
        (0..batch * 784).map(|_| rng.next_f32()).collect(),
    )
    .unwrap();

    let outs = exe
        .run(&[
            w1.to_literal().unwrap(),
            b1.to_literal().unwrap(),
            w2.to_literal().unwrap(),
            b2.to_literal().unwrap(),
            x.to_literal().unwrap(),
        ])
        .unwrap();
    let aot_logits = Tensor::from_literal(&outs[0]).unwrap();

    // rust path, lossless
    let bits = [10u32; 4];
    let l1 = mapper::map_layer("w1", &w1).unwrap();
    let l2 = mapper::map_layer("w2", &w2).unwrap();
    let mut h = sim::forward(&l1, &x, &bits);
    for (i, v) in h.data_mut().iter_mut().enumerate() {
        *v = (*v + b1.data()[i % 300]).max(0.0);
    }
    let mut logits = sim::forward(&l2, &h, &bits);
    for (i, v) in logits.data_mut().iter_mut().enumerate() {
        *v += b2.data()[i % 10];
    }
    let mut max_rel = 0.0f32;
    for (a, b) in aot_logits.data().iter().zip(logits.data()) {
        max_rel = max_rel.max((a - b).abs() / (b.abs().max(1e-2)));
    }
    // the two paths differ in accumulation order and — since the Rust sim
    // quantizes activations per example row while the AOT graph's
    // `_act_quantize` takes its qstep over the whole batch — in
    // quantization step whenever a row's max falls in a lower octave than
    // the batch max; the relative slack absorbs both. (`serve::XlaBackend`
    // neutralizes the batch-global census by dispatching one example per
    // run — see `reram_logits_invariant_under_batch_composition` — but
    // this test drives the graph directly at its native batch.)
    assert!(max_rel < 0.05, "AOT vs rust logits rel err {max_rel}");
}

/// A Bl1-regime sparse layer must map to mostly compressed tiles, shrink
/// its cell storage, and run the sparse execution path bit-identically to
/// a forced-dense layout of the same mapping — end to end through tiling,
/// partial edge tiles and both resolutions of interest.
#[test]
fn sparse_mapping_compresses_and_executes_bit_identically() {
    let mut rng = Rng::new(21);
    // ~2% of weights nonzero: the regime bit-slice L1 training reaches
    let n = 784 * 300;
    let mut data = vec![0.0f32; n];
    for _ in 0..n / 50 {
        let i = rng.below(n);
        data[i] = rng.normal() * 0.05;
    }
    data[0] = 0.9;
    let w = Tensor::new(vec![784, 300], data).unwrap();
    let mapped = mapper::map_layer("w", &w).unwrap();

    let stats = mapped.storage_stats();
    assert_eq!(stats.dense_tiles, 0, "a 2%-dense layer has no dense tiles");
    assert!(stats.compressed_tiles > 0);
    assert!(
        stats.bytes * 4 < stats.dense_bytes,
        "compressed storage {} bytes vs {} dense",
        stats.bytes,
        stats.dense_bytes
    );

    // the representation is invisible to execution: bit-exact against a
    // forced-dense clone at lossless and at the paper's operating point
    let dense = mapped.with_storage(StorageFormat::Dense);
    let x = Tensor::new(vec![3, 784], (0..3 * 784).map(|_| rng.next_f32()).collect()).unwrap();
    for bits in [[10u32; 4], [3, 3, 3, 1]] {
        let a = sim::forward(&mapped, &x, &bits);
        let b = sim::forward(&dense, &x, &bits);
        assert_eq!(a.data(), b.data(), "layouts disagree at {bits:?}");
    }

    // the census and the lossless resolution analysis read the same
    // cached counts regardless of layout (lossless = max column sum,
    // which zero columns never carry)
    for k in 0..4 {
        assert_eq!(mapped.nonzero_cells(k), dense.nonzero_cells(k));
    }
    let ma = mapper::MappedModel {
        layers: vec![std::sync::Arc::new(mapped)],
    };
    let mb = mapper::MappedModel {
        layers: vec![std::sync::Arc::new(dense)],
    };
    assert_eq!(
        resolution::required_bits(&ma, ResolutionPolicy::Lossless),
        resolution::required_bits(&mb, ResolutionPolicy::Lossless)
    );
    // the cost model bills what each layout *executes*: compressed tiles
    // convert only their nonzero-column index, a forced-dense clone
    // converts every column — so at ~2% density the chosen layout is
    // billed strictly less energy on the same tiles/geometry
    let ca = energy::deployment_cost(&ma, [3, 3, 3, 1]);
    let cb = energy::deployment_cost(&mb, [3, 3, 3, 1]);
    assert_eq!(ca.crossbars, cb.crossbars);
    assert_eq!(ca.skipped_tiles, cb.skipped_tiles);
    assert!(
        ca.energy < cb.energy,
        "compressed billing {} vs forced-dense {}",
        ca.energy,
        cb.energy
    );
}

/// Golden-stats regression for the reorder engine: on the fixed seeded
/// structured-sparse stack, reordering must cut active wordlines by at
/// least the fixture's recorded minimum and reach its recorded skipped-
/// tile floor. The thresholds live in `util::fixtures::reorder_golden` —
/// not inline — so a silently weakened clustering heuristic fails here,
/// and a deliberate heuristic change updates one reviewed place.
#[test]
fn reorder_golden_stats_meet_recorded_minimum() {
    let golden = fixtures::reorder_golden();
    let named: Vec<(String, Tensor)> = golden
        .stack
        .iter()
        .map(|l| (l.name.clone(), l.w.clone()))
        .collect();
    let natural = mapper::map_model(&named).unwrap();
    let reordered = mapper::map_model_with(&named, Some(ReorderConfig::default())).unwrap();

    let rows = reorder::reorder_rows(&natural, &reordered);
    assert_eq!(rows.len(), golden.stack.len());
    let (ns, rs) = (natural.storage_stats(), reordered.storage_stats());
    assert_eq!(rs.programmed_cells, ns.programmed_cells, "pure relocation");

    let wl_saving = ns.active_wordlines as f64 / rs.active_wordlines.max(1) as f64;
    assert!(
        wl_saving >= golden.min_wordline_saving,
        "active-wordline saving {wl_saving:.2}x below the recorded floor {:.2}x \
         ({} -> {} active wordlines) — the clustering heuristic regressed",
        golden.min_wordline_saving,
        ns.active_wordlines,
        rs.active_wordlines,
    );
    assert!(
        rs.skipped_tiles >= golden.min_skipped_tiles,
        "only {} tiles fully zero after reordering (fixture floor: {})",
        rs.skipped_tiles,
        golden.min_skipped_tiles,
    );
    // clustering may only *shrink* the fabricated deployment
    assert!(rs.skipped_tiles >= ns.skipped_tiles, "reorder un-skipped tiles");
    assert!(rs.active_columns <= ns.active_columns, "reorder grew active columns");

    // and the compacted placement is still the same function: bit-exact
    // forward agreement at lossless resolution, layer by layer
    let mut rng = Rng::new(31);
    let x = Tensor::new(vec![2, 784], (0..2 * 784).map(|_| rng.next_f32()).collect()).unwrap();
    let a = sim::forward(&natural.layers[0], &x, &[10; 4]);
    let b = sim::forward(&reordered.layers[0], &x, &[10; 4]);
    assert_eq!(a.data(), b.data(), "golden stack layer 1 diverged");
}

/// The deployment chain stays self-consistent on a reordered mapping:
/// census == slice nonzeros, lossless bits really are lossless, zero
/// columns clustered into skipped tiles cheapen the billed deployment.
#[test]
fn reordered_deployment_chain_is_self_consistent() {
    let golden = fixtures::reorder_golden();
    let w = golden.stack[0].w.clone();
    let natural = mapper::map_model(&[("w".into(), w.clone())]).unwrap();
    let reordered =
        mapper::map_model_with(&[("w".into(), w.clone())], Some(ReorderConfig::default()))
            .unwrap();

    // the mapped-cell census is placement-invariant
    let stats = bitslice_reram::sparsity::census(std::slice::from_ref(&w));
    for k in 0..4 {
        assert_eq!(reordered.layers[0].nonzero_cells(k), stats.nonzero[k]);
    }
    // column-only reordering relocates each column's per-tile partial
    // sums as units, so its lossless bits are placement-invariant; full
    // (row) reordering merges partials across row blocks and may
    // legitimately need *more* bits — assert only the invariant that
    // actually holds, then that the reordered bits really are lossless
    let cols_only =
        mapper::map_model_with(&[("w".into(), w.clone())], Some(ReorderConfig::cols_only()))
            .unwrap();
    let bits_n = resolution::required_bits(&natural, ResolutionPolicy::Lossless);
    let bits_c = resolution::required_bits(&cols_only, ResolutionPolicy::Lossless);
    assert_eq!(bits_n, bits_c, "cols-only lossless bits moved under reorder");
    let bits_r = resolution::required_bits(&reordered, ResolutionPolicy::Lossless);
    let mut rng = Rng::new(7);
    let x = Tensor::new(vec![3, 784], (0..3 * 784).map(|_| rng.next_f32()).collect()).unwrap();
    let out_lossless = sim::forward(&reordered.layers[0], &x, &bits_r);
    let out_10bit = sim::forward(&reordered.layers[0], &x, &[10; 4]);
    for (a, b) in out_lossless.data().iter().zip(out_10bit.data()) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
    // fewer programmed tiles -> the reordered deployment is never billed
    // more energy or area than the natural one at the same bits
    let cn = energy::deployment_cost(&natural, [3, 3, 3, 1]);
    let cr = energy::deployment_cost(&reordered, [3, 3, 3, 1]);
    assert!(cr.crossbars <= cn.crossbars);
    assert!(cr.energy <= cn.energy + 1e-9, "{} vs {}", cr.energy, cn.energy);
    assert!(cr.area <= cn.area + 1e-9);
    assert!(cr.skipped_tiles >= cn.skipped_tiles);
}

/// Regression (ROADMAP item 5b): reram logits must be invariant under
/// batch composition on *every* backend. The AOT reram graphs census the
/// whole batch for their activation qstep, so before `XlaBackend` went
/// per-row-dispatch, an example's logits changed with its batch mates —
/// splitting or reshuffling a batch moved the answers. Assert bit-exact
/// invariance under split-to-singles and reshuffle for the AOT graphs and
/// the Rust crossbar simulator alike.
#[test]
fn reram_logits_invariant_under_batch_composition() {
    use bitslice_reram::coordinator::ModelState;
    use bitslice_reram::serve::{dense_stack, CrossbarBackend, InferenceBackend, XlaBackend};

    fn assert_batch_invariant(backend: &dyn InferenceBackend, x: &Tensor) {
        let b = x.shape()[0];
        let dim: usize = x.shape()[1..].iter().product();
        let classes = backend.info().num_classes;
        let full = backend.infer_batch(x).unwrap();
        assert_eq!(full.shape(), [b, classes]);
        // split: each example alone must reproduce its batch logits
        for i in 0..b {
            let xi =
                Tensor::new(vec![1, dim], x.data()[i * dim..(i + 1) * dim].to_vec()).unwrap();
            let li = backend.infer_batch(&xi).unwrap();
            assert_eq!(
                li.data(),
                &full.data()[i * classes..(i + 1) * classes],
                "{}: example {i} depends on its batch mates",
                backend.name()
            );
        }
        // reshuffle: reversed batch, same per-example logits
        let mut rev = Vec::with_capacity(b * dim);
        for i in (0..b).rev() {
            rev.extend_from_slice(&x.data()[i * dim..(i + 1) * dim]);
        }
        let lr = backend.infer_batch(&Tensor::new(vec![b, dim], rev).unwrap()).unwrap();
        for i in 0..b {
            assert_eq!(
                &lr.data()[(b - 1 - i) * classes..(b - i) * classes],
                &full.data()[i * classes..(i + 1) * classes],
                "{}: example {i} moved under batch reshuffle",
                backend.name()
            );
        }
    }

    let Some((engine, manifest)) = setup() else { return };
    let entry = manifest.model("mlp").unwrap();
    let state = ModelState::init(entry, 42);
    let mut rng = Rng::new(5);
    let b = 6;
    let x = Tensor::new(
        vec![b, 784],
        (0..b * 784).map(|_| rng.next_f32()).collect(),
    )
    .unwrap();

    for tag in ["reram_paper", "reram_lossless"] {
        let be = XlaBackend::for_graph(&engine, &manifest, "mlp", tag, &state).unwrap();
        assert_batch_invariant(&be, &x);
    }
    let stack = dense_stack(&state.named_qws(entry), &state.tps).unwrap();
    let xbar = CrossbarBackend::new("xbar", &stack, ResolutionPolicy::Lossless).unwrap();
    assert_batch_invariant(&xbar, &x);
}

/// Quantize + slice through the Rust mirror matches what the deployed
/// crossbars hold (recombination of slices x signs recovers the codes).
#[test]
fn mapped_crossbars_recover_quantized_codes() {
    let mut rng = Rng::new(17);
    let w = Tensor::new(vec![200, 150], rng.normal_vec(30000, 0.1)).unwrap();
    let q = quant::quantize(&w);
    let m = mapper::map_layer("w", &w).unwrap();
    for r in 0..200 {
        for c in 0..150 {
            let mut acc = 0i64;
            for k in 0..4 {
                let (pos, neg) = &m.grids[k];
                let (tr, rr) = (r / 128, r % 128);
                let (tc, cc) = (c / 128, c % 128);
                let pv = pos.tile(tr, tc).get(rr, cc) as i64;
                let nv = neg.tile(tr, tc).get(rr, cc) as i64;
                acc += (pv - nv) << (2 * k);
            }
            let want = q.signs[r * 150 + c] as i64 * q.codes[r * 150 + c] as i64;
            assert_eq!(acc, want, "at ({r},{c})");
        }
    }
}

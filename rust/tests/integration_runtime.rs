//! Integration: AOT artifacts (jax 0.8 HLO text, Pallas interpret kernels
//! inside) load, compile and execute through the PJRT CPU client, and the
//! numbers agree with the Rust-side quant mirror.
//!
//! Requires `make artifacts`; tests skip (with a note) if absent so plain
//! `cargo test` stays green on a fresh checkout.

use bitslice_reram::quant;
use bitslice_reram::runtime::{artifact::DType, Engine, Manifest};
use bitslice_reram::tensor::{IntTensor, Tensor};
use bitslice_reram::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest parses"))
}

/// Build literal inputs for a graph: params from init spec, data random,
/// masks ones, scalars as given.
fn random_inputs(
    m: &Manifest,
    model: &str,
    graph: &str,
    scalars: &[(&str, f32)],
    seed: u64,
) -> (Vec<xla::Literal>, Vec<String>) {
    let entry = m.model(model).unwrap();
    let g = entry.graph(graph).unwrap();
    let mut rng = Rng::new(seed);
    let mut lits = Vec::new();
    let mut names = Vec::new();
    for spec in &g.inputs {
        names.push(spec.name.clone());
        let lit = match spec.dtype {
            DType::I32 => {
                let labels: Vec<i32> = (0..spec.numel())
                    .map(|_| rng.below(entry.num_classes) as i32)
                    .collect();
                IntTensor::new(spec.shape.clone(), labels)
                    .unwrap()
                    .to_literal()
                    .unwrap()
            }
            DType::F32 => {
                let data = if spec.name.starts_with("mask:") {
                    vec![1.0; spec.numel()]
                } else if let Some((_, v)) =
                    scalars.iter().find(|(n, _)| *n == spec.name)
                {
                    vec![*v; spec.numel().max(1)]
                } else if spec.name.starts_with("vq:") || spec.name.starts_with("vt:")
                {
                    vec![0.0; spec.numel()]
                } else if spec.name == "x" {
                    (0..spec.numel()).map(|_| rng.next_f32()).collect()
                } else {
                    // params: modest gaussian
                    rng.normal_vec(spec.numel(), 0.05)
                };
                Tensor::new(spec.shape.clone(), data)
                    .unwrap()
                    .to_literal()
                    .unwrap()
            }
        };
        lits.push(lit);
    }
    (lits, names)
}

#[test]
fn mlp_train_step_executes_and_improves_loss() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let entry = m.model("mlp").unwrap();
    let g = entry.graph("train").unwrap();
    let exe = engine.load(&g.path).expect("compile mlp_train");

    let scalars = [
        ("lr", 0.1f32),
        ("momentum", 0.9),
        ("alpha_l1", 0.0),
        ("alpha_bl1", 0.0),
    ];
    let (mut inputs, names) = random_inputs(&m, "mlp", "train", &scalars, 7);

    // run 20 steps, feeding state outputs back into inputs
    let n_state = entry.qw.len() * 2 + entry.tp.len() * 2 + entry.st.len();
    let loss_idx = g.output_index("loss").unwrap();
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for step in 0..20 {
        let outs = exe.run(&inputs).expect("execute");
        assert_eq!(outs.len(), g.outputs.len(), "output arity");
        let loss = outs[loss_idx].to_vec::<f32>().unwrap()[0];
        assert!(loss.is_finite(), "loss finite at step {step}");
        if first_loss.is_none() {
            first_loss = Some(loss);
        }
        last_loss = loss;
        for (i, lit) in outs.into_iter().take(n_state).enumerate() {
            inputs[i] = lit;
        }
        let _ = &names;
    }
    // same batch repeatedly: loss must drop clearly
    assert!(
        last_loss < first_loss.unwrap() * 0.7,
        "loss {} -> {last_loss} did not improve",
        first_loss.unwrap()
    );
}

#[test]
fn mlp_train_regularizers_report_and_shrink() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let entry = m.model("mlp").unwrap();
    let g = entry.graph("train").unwrap();
    let exe = engine.load(&g.path).unwrap();

    // strong bl1 pressure, no task learning (lr tiny for CE but alpha high)
    let scalars = [
        ("lr", 0.05f32),
        ("momentum", 0.0),
        ("alpha_l1", 0.0),
        ("alpha_bl1", 2e-5),
    ];
    let (mut inputs, _) = random_inputs(&m, "mlp", "train", &scalars, 11);
    let n_state = entry.qw.len() * 2 + entry.tp.len() * 2 + entry.st.len();
    let bl1_idx = g.output_index("bl1").unwrap();
    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..15 {
        let outs = exe.run(&inputs).unwrap();
        let bl1 = outs[bl1_idx].to_vec::<f32>().unwrap()[0];
        assert!(bl1 >= 0.0);
        if first.is_none() {
            first = Some(bl1);
        }
        last = bl1;
        for (i, lit) in outs.into_iter().take(n_state).enumerate() {
            inputs[i] = lit;
        }
    }
    assert!(
        last < first.unwrap(),
        "bl1 {} -> {last} did not shrink under bl1 pressure",
        first.unwrap()
    );
}

#[test]
fn sparsity_graph_matches_rust_quant_mirror() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let entry = m.model("mlp").unwrap();
    let g = entry.graph("sparsity").unwrap();
    let exe = engine.load(&g.path).unwrap();

    let mut rng = Rng::new(3);
    let mut inputs = Vec::new();
    let mut tensors = Vec::new();
    for p in &entry.qw {
        let t = Tensor::new(p.shape.clone(), rng.normal_vec(p.numel(), 0.07)).unwrap();
        inputs.push(t.to_literal().unwrap());
        tensors.push(t);
    }
    let outs = exe.run(&inputs).unwrap();
    // outputs: counts(4) per qw, then numel per qw
    for (i, t) in tensors.iter().enumerate() {
        let counts = outs[i].to_vec::<f32>().unwrap();
        let q = quant::quantize(t);
        let mine = q.slice_nonzero_counts();
        for k in 0..4 {
            assert_eq!(
                counts[k] as usize, mine[k],
                "tensor {i} slice {k}: python {} vs rust {}",
                counts[k], mine[k]
            );
        }
        let numel = outs[tensors.len() + i].to_vec::<f32>().unwrap()[0] as usize;
        assert_eq!(numel, t.len());
    }
}

#[test]
fn reram_infer_lossless_close_to_eval_forward() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let entry = m.model("mlp").unwrap();
    let g = entry.graph("reram_lossless").unwrap();
    let exe = engine.load(&g.path).unwrap();

    let mut rng = Rng::new(5);
    let mut inputs = Vec::new();
    for spec in &g.inputs {
        let data = if spec.name == "x" {
            (0..spec.numel()).map(|_| rng.next_f32()).collect()
        } else {
            rng.normal_vec(spec.numel(), 0.05)
        };
        inputs.push(
            Tensor::new(spec.shape.clone(), data)
                .unwrap()
                .to_literal()
                .unwrap(),
        );
    }
    let outs = exe.run(&inputs).unwrap();
    let logits = Tensor::from_literal(&outs[0]).unwrap();
    assert_eq!(logits.shape(), &[entry.batch, 10]);
    assert!(logits.data().iter().all(|v| v.is_finite()));
    // logits should have non-trivial magnitude (the sim isn't zeroing out)
    assert!(logits.max_abs() > 1e-3);
}

#[test]
fn kernel_artifacts_execute() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    for (name, g) in &m.kernels {
        let exe = engine.load(&g.path).unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut rng = Rng::new(9);
        let inputs: Vec<xla::Literal> = g
            .inputs
            .iter()
            .map(|s| {
                let data = if name.starts_with("crossbar") {
                    (0..s.numel()).map(|_| rng.below(4) as f32).collect()
                } else if name.starts_with("bl1") {
                    (0..s.numel()).map(|_| rng.below(256) as f32).collect()
                } else {
                    rng.normal_vec(s.numel(), 0.1)
                };
                Tensor::new(s.shape.clone(), data).unwrap().to_literal().unwrap()
            })
            .collect();
        let outs = exe.run(&inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(outs.len(), g.outputs.len(), "{name} arity");
    }
}

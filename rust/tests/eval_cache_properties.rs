//! Property suite for the incremental evaluation cache.
//!
//! The planner's prefix-cached scoring rests on one structural claim (the
//! evaluation-cache convention in the `reram` module docs): per-row
//! activation quantization makes every layer boundary depend only on the
//! resolutions upstream of it, so a cached re-run from a candidate's
//! first diverging layer is bit-exact against a from-scratch pass. These
//! properties pin that claim across everything that could plausibly break
//! it — random plans, all three tile storage layouts, reordered mappings,
//! replica-sharded serving, promote chains, and the early-abort floor.

use bitslice_reram::data::Dataset;
use bitslice_reram::reram::crossbar::StorageFormat;
use bitslice_reram::reram::planner::{DeploymentPlan, SearchStats};
use bitslice_reram::reram::{ReorderConfig, ResolutionPolicy};
use bitslice_reram::serve::{self, CrossbarBackend, EvalCache, InferenceBackend};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::check::{check, ensure};
use bitslice_reram::util::fixtures;
use bitslice_reram::util::rng::Rng;

/// Random labelled holdout for a stack (labels arbitrary — accuracy is a
/// count either way, and exactness is what is under test).
fn random_holdout(rng: &mut Rng, dim: usize, classes: usize, n: usize) -> Dataset {
    Dataset {
        features: std::sync::Arc::new((0..n * dim).map(|_| rng.next_f32()).collect()),
        labels: std::sync::Arc::new((0..n).map(|_| rng.below(classes) as i32).collect()),
        example_shape: vec![dim],
        num_classes: classes,
        source: "property-holdout".into(),
    }
}

/// Random candidate: lower a random subset of (layer, slice) resolutions
/// below the base plan's (never below 1 bit).
fn perturb_plan(rng: &mut Rng, base: &DeploymentPlan) -> DeploymentPlan {
    let mut p = base.clone();
    for l in &mut p.layers {
        for k in 0..4 {
            if rng.below(3) == 0 {
                l.adc_bits[k] = 1 + rng.below(l.adc_bits[k].max(1) as usize) as u32;
            }
        }
    }
    p
}

/// Ground truth for a candidate: a from-scratch accuracy pass on a
/// replanned clone of the same backend.
fn direct_accuracy(
    backend: &CrossbarBackend,
    cand: &DeploymentPlan,
    ds: &Dataset,
) -> Result<f64, String> {
    let b = backend
        .replan("direct", cand.clone())
        .map_err(|e| e.to_string())?;
    Ok(serve::accuracy(&b, ds).map_err(|e| e.to_string())?.accuracy)
}

/// Property: cached scoring equals the from-scratch accuracy **exactly**
/// (same f64, not approximately) for random candidate plans under all
/// three tile storage layouts, including across promote chains that move
/// the incumbent.
#[test]
fn cached_scores_are_bit_exact_across_storage_layouts() {
    check(6, |rng| {
        let seed = rng.next_u64();
        let dims = [10 + rng.below(60), 4 + rng.below(20), 2 + rng.below(8)];
        let stack = fixtures::sparse_stack(seed, &dims, 0.15);
        let ds = random_holdout(rng, dims[0], dims[2], 12 + rng.below(20));
        let base = CrossbarBackend::with_layer_policy("xbar", &stack, ResolutionPolicy::Lossless)
            .map_err(|e| e.to_string())?;
        for fmt in [
            StorageFormat::Dense,
            StorageFormat::Compressed,
            StorageFormat::BitPlanes,
        ] {
            let backend = CrossbarBackend::from_mapping(
                "xbar-fmt",
                base.mapped().with_storage(fmt),
                &stack,
                base.plan().clone(),
            )
            .map_err(|e| e.to_string())?;
            let mut stats = SearchStats::default();
            let mut cache =
                EvalCache::new(&backend, &ds, &mut stats).map_err(|e| e.to_string())?;
            ensure(
                cache.accuracy()
                    == serve::accuracy(&backend, &ds)
                        .map_err(|e| e.to_string())?
                        .accuracy,
                format!("{fmt:?}: cache build accuracy"),
            )?;
            // a chain of candidates; every few rounds one becomes the
            // incumbent, so later candidates splice against moved caches
            for round in 0..4 {
                let cand = perturb_plan(rng, backend.plan());
                let got = cache
                    .score(&cand, None, &mut stats)
                    .map_err(|e| e.to_string())?;
                let want = direct_accuracy(&backend, &cand, &ds)?;
                ensure(
                    got.accuracy == Some(want),
                    format!("{fmt:?} round {round}: cached {:?} vs direct {want}", got.accuracy),
                )?;
                if rng.below(2) == 0 {
                    cache.promote(&cand, &mut stats).map_err(|e| e.to_string())?;
                    ensure(
                        cache.accuracy() == want,
                        format!("{fmt:?} round {round}: promoted accuracy"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

/// Property: the same exactness holds on **reordered** mappings — the
/// wordline/column permutations move where codes land in the tiles, not
/// what the layer boundaries are.
#[test]
fn cached_scores_are_bit_exact_on_reordered_mappings() {
    check(4, |rng| {
        let seed = rng.next_u64();
        let dims = [40 + rng.below(160), 8 + rng.below(30), 2 + rng.below(8)];
        let stack = fixtures::sparse_stack(seed, &dims, 0.05);
        let ds = random_holdout(rng, dims[0], dims[2], 10 + rng.below(14));
        let backend = CrossbarBackend::with_layer_policy_reordered(
            "xbar-ro",
            &stack,
            ResolutionPolicy::Lossless,
            ReorderConfig::default(),
        )
        .map_err(|e| e.to_string())?;
        let mut stats = SearchStats::default();
        let mut cache = EvalCache::new(&backend, &ds, &mut stats).map_err(|e| e.to_string())?;
        // a tail-only candidate first: diverges at the last layer, so the
        // whole prefix must come from the cache
        let mut tail_only = backend.plan().clone();
        let last = tail_only.layers.len() - 1;
        tail_only.layers[last].adc_bits[0] = 1;
        let got = cache
            .score(&tail_only, None, &mut stats)
            .map_err(|e| e.to_string())?;
        ensure(
            got.accuracy == Some(direct_accuracy(&backend, &tail_only, &ds)?),
            "reordered: tail-only candidate",
        )?;
        ensure(stats.cache_hits > 0, "prefix reuse on the tail-only candidate")?;
        for _ in 0..3 {
            let cand = perturb_plan(rng, backend.plan());
            let got = cache
                .score(&cand, None, &mut stats)
                .map_err(|e| e.to_string())?;
            let want = direct_accuracy(&backend, &cand, &ds)?;
            ensure(
                got.accuracy == Some(want),
                format!("reordered: cached {:?} vs direct {want}", got.accuracy),
            )?;
            if rng.below(2) == 0 {
                cache.promote(&cand, &mut stats).map_err(|e| e.to_string())?;
            }
        }
        Ok(())
    });
}

/// Property: `forward_from_layer(0, x)` is bit-identical to
/// `infer_batch(x)` — including on replica-sharded plans, whose row
/// sharding must stay invisible (the cache relies on this when it
/// ignores replica counts in its divergence check).
#[test]
fn forward_from_layer_zero_is_infer_batch_even_with_replicas() {
    check(6, |rng| {
        let seed = rng.next_u64();
        let dims = [10 + rng.below(60), 4 + rng.below(20), 2 + rng.below(8)];
        let stack = fixtures::sparse_stack(seed, &dims, 0.15);
        let base = CrossbarBackend::with_layer_policy("xbar", &stack, ResolutionPolicy::Lossless)
            .map_err(|e| e.to_string())?;
        let mut plan = perturb_plan(rng, base.plan());
        for l in &mut plan.layers {
            l.replicas = 1 + rng.below(3);
        }
        let backend = base.replan("xbar-rep", plan).map_err(|e| e.to_string())?;
        let n = 1 + rng.below(8);
        let x = Tensor::new(
            vec![n, dims[0]],
            (0..n * dims[0]).map(|_| rng.next_f32()).collect(),
        )
        .map_err(|e| e.to_string())?;
        let full = backend.infer_batch(&x).map_err(|e| e.to_string())?;
        let from0 = backend
            .forward_from_layer(0, &x)
            .map_err(|e| e.to_string())?;
        ensure(full.data() == from0.data(), "forward_from_layer(0) == infer_batch")?;
        Ok(())
    });
}

/// Property: scoring against an accuracy floor never changes the verdict
/// a full scan would reach — an abort happens only when the candidate
/// provably cannot reach the floor, and completed scores carry the exact
/// full-scan accuracy.
#[test]
fn floor_scoring_is_decision_identical_to_full_scans() {
    check(6, |rng| {
        let seed = rng.next_u64();
        let dims = [10 + rng.below(60), 4 + rng.below(20), 2 + rng.below(8)];
        let stack = fixtures::sparse_stack(seed, &dims, 0.15);
        let ds = random_holdout(rng, dims[0], dims[2], 12 + rng.below(20));
        let backend =
            CrossbarBackend::with_layer_policy("xbar", &stack, ResolutionPolicy::Lossless)
                .map_err(|e| e.to_string())?;
        let mut stats = SearchStats::default();
        let mut cache = EvalCache::new(&backend, &ds, &mut stats).map_err(|e| e.to_string())?;
        for _ in 0..4 {
            let cand = perturb_plan(rng, backend.plan());
            let floor = rng.next_f32() as f64;
            let floored = cache
                .score(&cand, Some(floor), &mut stats)
                .map_err(|e| e.to_string())?;
            let want = direct_accuracy(&backend, &cand, &ds)?;
            ensure(
                floored.feasible == (want >= floor),
                format!("verdict at floor {floor}: {floored:?} vs direct {want}"),
            )?;
            match floored.accuracy {
                // completed scans report the exact accuracy
                Some(a) => ensure(a == want, format!("completed scan {a} vs {want}"))?,
                // aborts only fire on genuinely infeasible candidates
                None => ensure(want < floor, format!("aborted feasible {want} >= {floor}"))?,
            }
        }
        Ok(())
    });
}

//! Integration: cross-backend agreement and serving-engine batching.
//!
//! The exact quantized reference and the crossbar simulator share the same
//! quantization points (per-row activations, Eq. 1–2 weights) and — at
//! lossless ADC resolution — the same integer-domain arithmetic, so they
//! must agree within float-cast tolerance on random MLP states. The
//! batched serving engine must be a pure transport: whatever batches it
//! assembles, outputs are bit-identical to direct `infer_batch` calls.

use std::sync::Arc;

use bitslice_reram::reram::{ReorderConfig, ResolutionPolicy};
use bitslice_reram::serve::{
    accuracy, dense_stack, CrossbarBackend, DenseLayer, InferenceBackend, ReferenceBackend,
    ServeOptions, ServingEngine, SharedBackend,
};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::check::{check, ensure};
use bitslice_reram::util::fixtures;
use bitslice_reram::util::rng::Rng;

fn random_stack(rng: &mut Rng) -> Vec<DenseLayer> {
    let d_in = 1 + rng.below(80);
    let hidden = 1 + rng.below(50);
    let classes = 2 + rng.below(8);
    let w1 = Tensor::new(vec![d_in, hidden], rng.normal_vec(d_in * hidden, 0.15)).unwrap();
    let w2 = Tensor::new(vec![hidden, classes], rng.normal_vec(hidden * classes, 0.15)).unwrap();
    let b1 = Tensor::new(vec![hidden], rng.normal_vec(hidden, 0.03)).unwrap();
    let b2 = Tensor::new(vec![classes], rng.normal_vec(classes, 0.03)).unwrap();
    dense_stack(&[("fc1/w".into(), w1), ("fc2/w".into(), w2)], &[b1, b2]).unwrap()
}

fn random_batch(rng: &mut Rng, b: usize, dim: usize) -> Tensor {
    Tensor::new(vec![b, dim], (0..b * dim).map(|_| rng.next_f32()).collect()).unwrap()
}

/// Property: reference and crossbar-at-lossless agree on random MLPs.
#[test]
fn reference_and_crossbar_agree_at_lossless_resolution() {
    check(10, |rng| {
        let stack = random_stack(rng);
        let d_in = stack[0].w.shape()[0];
        let classes = stack[1].w.shape()[1];
        let reference =
            ReferenceBackend::new("ref", &stack).map_err(|e| e.to_string())?;
        let xbar = CrossbarBackend::new("xbar", &stack, ResolutionPolicy::Lossless)
            .map_err(|e| e.to_string())?;
        let b = 1 + rng.below(6);
        let x = random_batch(rng, b, d_in);
        let want = reference.infer_batch(&x).map_err(|e| e.to_string())?;
        let got = xbar.infer_batch(&x).map_err(|e| e.to_string())?;
        ensure(got.shape() == [b, classes], "output shape")?;
        for (g, w) in got.data().iter().zip(want.data()) {
            // same integer arithmetic, two float cast points: allow a hair
            let tol = 1e-5 * w.abs().max(1.0);
            ensure(
                (g - w).abs() <= tol,
                format!("crossbar {g} vs reference {w}"),
            )?;
        }
        Ok(())
    });
}

/// The per-layer plan path (each layer sized by its own census) at
/// lossless resolution must also agree with the reference exactly — and
/// its plan never asks for more bits than the whole-model policy.
#[test]
fn per_layer_lossless_plan_agrees_with_reference() {
    check(6, |rng| {
        let stack = random_stack(rng);
        let d_in = stack[0].w.shape()[0];
        let reference = ReferenceBackend::new("ref", &stack).map_err(|e| e.to_string())?;
        let planned =
            CrossbarBackend::with_layer_policy("xbar-plan", &stack, ResolutionPolicy::Lossless)
                .map_err(|e| e.to_string())?;
        let global =
            CrossbarBackend::new("xbar", &stack, ResolutionPolicy::Lossless)
                .map_err(|e| e.to_string())?;
        for layer in &planned.plan().layers {
            for k in 0..4 {
                ensure(
                    layer.adc_bits[k] <= global.adc_bits()[k],
                    format!("layer {} slice {k} exceeds the whole-model bits", layer.name),
                )?;
            }
        }
        let x = random_batch(rng, 1 + rng.below(4), d_in);
        let want = reference.infer_batch(&x).map_err(|e| e.to_string())?;
        let got = planned.infer_batch(&x).map_err(|e| e.to_string())?;
        for (g, w) in got.data().iter().zip(want.data()) {
            let tol = 1e-5 * w.abs().max(1.0);
            ensure((g - w).abs() <= tol, format!("planned {g} vs reference {w}"))?;
        }
        Ok(())
    });
}

/// Reduced (clipping) resolution must *not* silently equal lossless on a
/// dense model — the agreement above is meaningful, not vacuous.
#[test]
fn clipping_resolution_diverges_on_dense_weights() {
    let mut rng = Rng::new(23);
    let w1 = Tensor::new(vec![64, 16], vec![0.5; 64 * 16]).unwrap();
    let w2 = Tensor::new(vec![16, 4], vec![0.5; 64]).unwrap();
    let b1 = Tensor::zeros(vec![16]);
    let b2 = Tensor::zeros(vec![4]);
    let stack = dense_stack(&[("a".into(), w1), ("b".into(), w2)], &[b1, b2]).unwrap();
    let lossless = CrossbarBackend::new("l", &stack, ResolutionPolicy::Lossless).unwrap();
    let starved = lossless.rebit("s", [1; 4]);
    let x = random_batch(&mut rng, 2, 64);
    let a = lossless.infer_batch(&x).unwrap();
    let b = starved.infer_batch(&x).unwrap();
    assert_ne!(a.data(), b.data());
}

/// The serving engine's dynamic batches must reproduce direct backend
/// calls bit-for-bit, for both host backends.
#[test]
fn serving_engine_is_bit_identical_to_direct_calls() {
    let mut rng = Rng::new(31);
    let stack = random_stack(&mut rng);
    let d_in = stack[0].w.shape()[0];
    let classes = stack[1].w.shape()[1];
    let backends: Vec<SharedBackend> = vec![
        Arc::new(ReferenceBackend::new("ref", &stack).unwrap()),
        Arc::new(CrossbarBackend::new("xbar", &stack, ResolutionPolicy::Lossless).unwrap()),
    ];
    let n = 24;
    let x = random_batch(&mut rng, n, d_in);
    for backend in backends {
        let direct = backend.infer_batch(&x).unwrap();
        for (workers, max_batch) in [(1usize, 5usize), (3, 4), (4, 64)] {
            let eng = ServingEngine::start(
                backend.clone(),
                ServeOptions {
                    max_batch,
                    workers,
                    queue_depth: 8,
                    ..ServeOptions::default()
                },
            )
            .unwrap();
            let requests: Vec<Vec<f32>> = (0..n)
                .map(|i| x.data()[i * d_in..(i + 1) * d_in].to_vec())
                .collect();
            let out = eng.infer_many(requests).unwrap();
            let stats = eng.shutdown();
            assert_eq!(stats.requests, n);
            assert_eq!(stats.errors, 0);
            for (i, row) in out.iter().enumerate() {
                assert_eq!(
                    row.as_slice(),
                    &direct.data()[i * classes..(i + 1) * classes],
                    "{} row {i} (workers {workers}, max_batch {max_batch})",
                    backend.name()
                );
            }
        }
    }
}

/// Cross-backend agreement for a **reordered** crossbar deployment: the
/// wordline/column permutations must be invisible against the exact
/// quantized reference at lossless resolution — on random sparse MLPs,
/// directly and through the serving engine's dynamic batching.
#[test]
fn reordered_crossbar_agrees_with_reference() {
    check(6, |rng| {
        let seed = rng.next_u64();
        let dims = [1 + rng.below(200), 1 + rng.below(40), 2 + rng.below(8)];
        let stack = fixtures::sparse_stack(seed, &dims, 0.05);
        let reference = ReferenceBackend::new("ref", &stack).map_err(|e| e.to_string())?;
        let reordered = CrossbarBackend::with_layer_policy_reordered(
            "xbar-ro",
            &stack,
            ResolutionPolicy::Lossless,
            ReorderConfig::default(),
        )
        .map_err(|e| e.to_string())?;
        let b = 1 + rng.below(5);
        let x = random_batch(rng, b, dims[0]);
        let want = reference.infer_batch(&x).map_err(|e| e.to_string())?;
        let got = reordered.infer_batch(&x).map_err(|e| e.to_string())?;
        for (g, w) in got.data().iter().zip(want.data()) {
            let tol = 1e-5 * w.abs().max(1.0);
            ensure(
                (g - w).abs() <= tol,
                format!("reordered crossbar {g} vs reference {w}"),
            )?;
        }
        // and bit-identical to the natural-order crossbar at lossless
        let natural =
            CrossbarBackend::with_layer_policy("xbar", &stack, ResolutionPolicy::Lossless)
                .map_err(|e| e.to_string())?;
        ensure(
            natural.infer_batch(&x).map_err(|e| e.to_string())?.data() == got.data(),
            "reordered vs natural-order crossbar at lossless",
        )?;
        Ok(())
    });
}

/// The serving engine is a pure transport over a reordered backend too:
/// whatever batches it assembles, outputs are bit-identical to direct
/// `infer_batch` calls on the same reordered deployment.
#[test]
fn serving_engine_is_bit_identical_over_reordered_backend() {
    let stack = fixtures::sparse_stack(0x5EED, &[120, 30, 6], 0.04);
    let reordered = CrossbarBackend::with_layer_policy_reordered(
        "xbar-ro",
        &stack,
        ResolutionPolicy::Lossless,
        ReorderConfig::default(),
    )
    .unwrap();
    assert!(reordered.is_reordered(), "4%-dense scattered stack reorders");
    let backend: SharedBackend = Arc::new(reordered);
    let mut rng = Rng::new(43);
    let n = 24;
    let x = random_batch(&mut rng, n, 120);
    let direct = backend.infer_batch(&x).unwrap();
    for (workers, max_batch) in [(1usize, 5usize), (3, 4), (4, 64)] {
        let eng = ServingEngine::start(
            backend.clone(),
            ServeOptions {
                max_batch,
                workers,
                queue_depth: 8,
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let requests: Vec<Vec<f32>> = (0..n)
            .map(|i| x.data()[i * 120..(i + 1) * 120].to_vec())
            .collect();
        let out = eng.infer_many(requests).unwrap();
        let stats = eng.shutdown();
        assert_eq!(stats.requests, n);
        assert_eq!(stats.errors, 0);
        for (i, row) in out.iter().enumerate() {
            assert_eq!(
                row.as_slice(),
                &direct.data()[i * 6..(i + 1) * 6],
                "row {i} (workers {workers}, max_batch {max_batch})"
            );
        }
    }
}

/// Replica-sharded serving is bit-identical to the single-replica path:
/// the replication planner puts extra copies on the bottleneck-skewed
/// fixture's wide layer, and a multi-threaded `ServingEngine` over the
/// sharded backend returns exactly the logits the unreplicated backend
/// computes directly — whatever batches the workers assemble.
#[test]
fn replica_sharded_serving_is_bit_identical_to_single_replica() {
    use bitslice_reram::reram::timing;

    let stack = fixtures::bottleneck_stack(0x7173);
    let single = CrossbarBackend::with_bits("xbar", &stack, [3, 3, 3, 1])
        .unwrap()
        .with_intra_threads(1);
    let model = single.mapped().clone();
    let mut plan = single.plan().clone();
    let timing0 = timing::plan_timing(&model, &plan);
    let b = timing0.bottleneck().expect("programmed stack");
    assert_eq!(timing0.layers[b].layer, "fc2/w", "fixture bottleneck");
    let spent = timing::fill_replicas(&model, &mut plan, 2 * model.layers[b].fabricated_cells());
    assert!(spent > 0);
    assert!(plan.layers[b].replicas >= 2, "budget buys replicas");
    let sharded = single.replan("xbar-rep", plan).unwrap().with_intra_threads(1);

    let mut rng = Rng::new(59);
    let n = 24;
    let x = random_batch(&mut rng, n, 64);
    let direct = single.infer_batch(&x).unwrap();
    // direct sharded call agrees bit-for-bit...
    assert_eq!(sharded.infer_batch(&x).unwrap().data(), direct.data());
    // ...and so does every batching the multi-threaded engine picks
    let backend: SharedBackend = Arc::new(sharded);
    for (workers, max_batch) in [(1usize, 6usize), (3, 4), (4, 64)] {
        let eng = ServingEngine::start(
            backend.clone(),
            ServeOptions {
                max_batch,
                workers,
                queue_depth: 8,
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let requests: Vec<Vec<f32>> = (0..n)
            .map(|i| x.data()[i * 64..(i + 1) * 64].to_vec())
            .collect();
        let out = eng.infer_many(requests).unwrap();
        let stats = eng.shutdown();
        assert_eq!(stats.requests, n);
        assert_eq!(stats.errors, 0);
        for (i, row) in out.iter().enumerate() {
            assert_eq!(
                row.as_slice(),
                &direct.data()[i * 10..(i + 1) * 10],
                "row {i} (workers {workers}, max_batch {max_batch})"
            );
        }
    }
}

/// The shared accuracy driver gives the same answer for the same backend
/// regardless of the (flexible) batch slicing it chooses.
#[test]
fn accuracy_driver_consistent_across_backends_on_synthetic_data() {
    let ds = bitslice_reram::data::synthetic::mnist(128, 9);
    let mut rng = Rng::new(41);
    let w1 = Tensor::new(vec![784, 32], rng.normal_vec(784 * 32, 0.05)).unwrap();
    let w2 = Tensor::new(vec![32, 10], rng.normal_vec(320, 0.1)).unwrap();
    let b1 = Tensor::zeros(vec![32]);
    let b2 = Tensor::zeros(vec![10]);
    let stack = dense_stack(&[("fc1/w".into(), w1), ("fc2/w".into(), w2)], &[b1, b2]).unwrap();
    let reference = ReferenceBackend::new("ref", &stack).unwrap();
    let xbar = CrossbarBackend::new("xbar", &stack, ResolutionPolicy::Lossless).unwrap();
    let ra = accuracy(&reference, &ds).unwrap();
    let xa = accuracy(&xbar, &ds).unwrap();
    assert_eq!(ra.examples, 128);
    assert_eq!(xa.examples, 128);
    // bit-identical logits -> identical argmax accuracy
    assert_eq!(ra.accuracy, xa.accuracy);
}

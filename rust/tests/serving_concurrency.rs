//! Serving-engine concurrency edges: racing `try_submit` against a full
//! bounded queue, shutdown with requests still queued, and a backend
//! that panics inside the work-stealing executor path.
//!
//! The gated backend (blocks inside `infer_batch` until released over a
//! channel) makes the queue states deterministic: with `workers: 1`,
//! `max_batch: 1` the worker is provably stuck inside the backend after
//! one `started` handshake, so whatever the bounded queue holds at that
//! point stays put until the gate opens.

use std::sync::Arc;

use anyhow::Result;
use bitslice_reram::serve::{
    BackendInfo, InferenceBackend, ServeOptions, ServingEngine, SharedBackend,
};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::pool::{bounded, os_threads_spawned, parallel_map, Receiver, Sender};

/// Blocks inside `infer_batch` until released; answers zeros.
struct GateBackend {
    started: Sender<()>,
    release: Receiver<()>,
}

impl InferenceBackend for GateBackend {
    fn name(&self) -> &str {
        "gate"
    }
    fn info(&self) -> BackendInfo {
        BackendInfo {
            input_dim: 1,
            num_classes: 1,
            native_batch: None,
            logits: true,
        }
    }
    fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
        let _ = self.started.send(());
        self.release.recv(); // hold the worker until released
        Tensor::new(vec![x.shape()[0], 1], vec![0.0; x.shape()[0]])
    }
}

/// Start a 1-worker, 1-deep engine and park its worker inside the
/// backend; returns the engine, the parked request, and the gates.
fn parked_engine(queue_depth: usize) -> (ServingEngine, Receiver<()>, Sender<()>) {
    let (started_tx, started_rx) = bounded::<()>(64);
    let (release_tx, release_rx) = bounded::<()>(64);
    let backend: SharedBackend = Arc::new(GateBackend {
        started: started_tx,
        release: release_rx,
    });
    let eng = ServingEngine::start(
        backend,
        ServeOptions {
            max_batch: 1,
            workers: 1,
            queue_depth,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    (eng, started_rx, release_tx)
}

/// Many producers hammering `try_submit` against a provably full queue
/// must all shed with `Ok(None)` — no blocking, no panic, no phantom
/// acceptance — and the queue must accept again once drained.
#[test]
fn racing_try_submit_sheds_cleanly_on_a_full_queue() {
    let (eng, started_rx, release_tx) = parked_engine(1);
    // the worker holds r1 inside the backend, r2 fills the single slot
    let r1 = eng.submit(vec![0.0]).unwrap();
    started_rx.recv().expect("worker entered the backend");
    let r2 = eng.submit(vec![0.0]).unwrap();
    const PRODUCERS: usize = 8;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|_| {
                scope.spawn(|| {
                    (0..50)
                        .map(|_| eng.try_submit(vec![0.0]).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for attempt in h.join().unwrap() {
                assert!(attempt.is_none(), "full queue must shed every racer");
            }
        }
    });
    // open the gate: both accepted requests complete...
    release_tx.send(()).unwrap();
    release_tx.send(()).unwrap();
    assert!(r1.wait().is_ok());
    assert!(r2.wait().is_ok());
    // ...and with room again a try_submit goes through
    let r3 = eng.try_submit(vec![0.0]).unwrap().expect("drained queue accepts");
    let _ = started_rx.recv();
    release_tx.send(()).unwrap();
    assert!(r3.wait().is_ok());
    let stats = eng.shutdown();
    assert_eq!(stats.requests, 3, "shed attempts never reach the backend");
}

/// Shutdown with requests still queued behind a stuck worker: every
/// outstanding waiter resolves (the drain serves them), none hang.
#[test]
fn shutdown_drains_queued_requests_and_resolves_waiters() {
    let (eng, started_rx, release_tx) = parked_engine(4);
    let r1 = eng.submit(vec![0.0]).unwrap();
    started_rx.recv().expect("worker entered the backend");
    // these sit in the queue while shutdown begins
    let r2 = eng.submit(vec![0.0]).unwrap();
    let r3 = eng.submit(vec![0.0]).unwrap();
    let shutdown = std::thread::spawn(move || eng.shutdown());
    // the worker is released batch by batch; shutdown is blocked joining
    // it until the queue drains
    for _ in 0..3 {
        release_tx.send(()).unwrap();
    }
    assert!(r1.wait().is_ok(), "in-flight request resolves");
    assert!(r2.wait().is_ok(), "queued request resolves");
    assert!(r3.wait().is_ok(), "queued request resolves");
    let stats = shutdown.join().unwrap();
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.errors, 0);
}

/// Panics on examples with a negative first feature, inside an executor
/// task — the panic unwinds through `parallel_map` into the serving
/// worker's catch.
struct PoisonBackend;

impl InferenceBackend for PoisonBackend {
    fn name(&self) -> &str {
        "poison"
    }
    fn info(&self) -> BackendInfo {
        BackendInfo {
            input_dim: 2,
            num_classes: 1,
            native_batch: None,
            logits: true,
        }
    }
    fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
        let b = x.shape()[0];
        let data = x.data();
        let mut out = Vec::with_capacity(b);
        for i in 0..b {
            let (v0, v1) = (data[i * 2], data[i * 2 + 1]);
            // 8 tasks on 4 lanes so the executor scope really engages,
            // whatever batch size the engine assembled
            let parts = parallel_map(8, 4, |k| {
                assert!(v0 >= 0.0, "poisoned example");
                if k == 0 {
                    v0 + v1
                } else {
                    0.0
                }
            });
            out.push(parts.iter().sum::<f32>());
        }
        Tensor::new(vec![b, 1], out)
    }
}

/// A backend panicking inside the work-stealing path fails its batch as
/// a per-request error; the executor's workers survive the unwind (no
/// respawn) and keep serving later requests bit-correctly.
#[test]
fn backend_panic_under_work_stealing_fails_the_batch_not_the_pool() {
    let backend: SharedBackend = Arc::new(PoisonBackend);
    let eng = ServingEngine::start(
        backend,
        ServeOptions {
            max_batch: 4,
            workers: 1,
            queue_depth: 16,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    // warm the executor pool, then freeze the spawn counter
    assert_eq!(eng.infer_many(vec![vec![1.0, 2.0]]).unwrap(), vec![vec![3.0]]);
    let spawned = os_threads_spawned();
    let poisoned = eng.submit(vec![-1.0, 0.0]).unwrap();
    let err = poisoned.wait().expect_err("poisoned example must error");
    assert!(err.to_string().contains("panicked"), "{err}");
    // the pool and the serving worker both survived
    let after = eng.infer_many(vec![vec![2.0, 3.0], vec![4.0, 5.0]]).unwrap();
    assert_eq!(after, vec![vec![5.0], vec![9.0]]);
    assert_eq!(os_threads_spawned(), spawned, "panic must not respawn workers");
    let stats = eng.shutdown();
    assert_eq!(stats.requests, 4);
    assert_eq!(stats.errors, 1);
}

//! Golden test for the deploy CLI's serving/timing serializations: a
//! fixed [`ServingRow`] pair (with and without an SLO policy) and the
//! `timing` object of `plan.json` must serialize byte-for-byte to the
//! committed `tests/golden/serving.json` — the rsjsonnet-style binary
//! golden ROADMAP item 3 asks for.
//!
//! The golden pins the artifact *shape* (alphabetical key order of the
//! JSON writer, the `slo` sub-object vs `null`, integer-vs-decimal
//! number formatting) against literal inputs whose every float prints
//! exactly. A deliberate format change regenerates the file in one
//! reviewed place: paste the `left` value the assertion prints.
//!
//! A second test drives the real deploy chain — bottleneck fixture →
//! [`CrossbarBackend::timing`] → [`SloPolicy::from_timing`] →
//! [`ServingEngine`] → `stats.row()` → the same serializers — and checks
//! the structure (not the timing-dependent numbers) of what the CLI
//! would write.
//!
//! [`ServingRow`]: bitslice_reram::report::ServingRow

use std::sync::Arc;

use bitslice_reram::report::{serving_json, timing_json, PipelineTiming, ServingRow, TimingRow};
use bitslice_reram::serve::{
    CrossbarBackend, ServeOptions, ServingEngine, SharedBackend, SloPolicy,
};
use bitslice_reram::util::fixtures;
use bitslice_reram::util::json::obj;

const GOLDEN: &str = include_str!("golden/serving.json");

fn serving_rows() -> Vec<ServingRow> {
    vec![
        ServingRow {
            backend: "crossbar@lossless".into(),
            max_batch: 32,
            workers: 4,
            requests: 1000,
            errors: 7,
            mean_batch: 12.5,
            throughput_rps: 842.0,
            latency_mean_ms: 3.2,
            latency_p50_ms: 2.9,
            latency_p99_ms: 9.4,
            slo_ms: None,
            slo_violations: 0,
        },
        ServingRow {
            backend: "crossbar@slo".into(),
            max_batch: 16,
            workers: 2,
            requests: 500,
            errors: 0,
            mean_batch: 8.0,
            throughput_rps: 610.5,
            latency_mean_ms: 4.25,
            latency_p50_ms: 4.0,
            latency_p99_ms: 11.75,
            slo_ms: Some(12.0),
            slo_violations: 3,
        },
    ]
}

fn timing_fixture() -> PipelineTiming {
    PipelineTiming {
        layers: vec![
            TimingRow {
                layer: "fc1/w".into(),
                replicas: 1,
                latency_cycles: 800,
                conversion_cycles: 800,
            },
            TimingRow {
                layer: "fc2/w".into(),
                replicas: 2,
                latency_cycles: 2000,
                conversion_cycles: 6000,
            },
        ],
    }
}

#[test]
fn serving_and_timing_json_match_golden() {
    let doc = obj(vec![
        ("serving", serving_json(&serving_rows())),
        ("timing", timing_json(&timing_fixture())),
    ]);
    assert_eq!(
        doc.to_string(),
        GOLDEN.trim_end(),
        "serving/timing serialization drifted from tests/golden/serving.json — \
         if the change is deliberate, commit the new serialization as the golden file"
    );
}

/// The full chain the deploy CLI runs: plan timing prices an SLO policy,
/// the engine serves under it, and the row/timing serializers produce a
/// document with the golden's shape.
#[test]
fn deploy_chain_produces_golden_shaped_document() {
    let stack = fixtures::bottleneck_stack(0xD0C5);
    let xbar = CrossbarBackend::with_bits("xbar@deploy", &stack, [3, 3, 3, 1]).unwrap();
    let timing = xbar.timing();
    let policy = SloPolicy::from_timing(&timing, 250.0, 1e-3);
    assert!(policy.predicted_service_ms(1) > 0.0, "fixture converts somewhere");
    let backend: SharedBackend = Arc::new(xbar);
    let eng = ServingEngine::start(
        backend,
        ServeOptions {
            max_batch: 8,
            workers: 2,
            slo: Some(policy),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    let out = eng.infer_many((0..12).map(|i| vec![i as f32 / 12.0; 64]).collect()).unwrap();
    assert_eq!(out.len(), 12);
    let stats = eng.shutdown();
    let doc = obj(vec![
        ("serving", serving_json(&[stats.row()])),
        ("timing", timing_json(&timing)),
    ]);
    let back = bitslice_reram::util::json::parse(&doc.to_string()).unwrap();
    let row = &back.get("serving").unwrap().as_arr().unwrap()[0];
    assert_eq!(row.get("backend").unwrap().as_str(), Some("xbar@deploy"));
    assert_eq!(row.get("requests").unwrap().as_usize(), Some(12));
    let slo = row.get("slo").unwrap();
    assert_eq!(slo.get("target_ms").unwrap().as_f64(), Some(250.0));
    assert!(slo.get("violations").unwrap().as_usize().is_some());
    let t = back.get("timing").unwrap();
    assert!(t.get("bottleneck_layer").unwrap().as_str().is_some());
    assert!(t.get("pipeline_fill_cycles").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(
        t.get("layers").unwrap().as_arr().unwrap().len(),
        timing.layers.len()
    );
}

//! Golden test for the `audit.json` artifact: the planted-MLP fixture
//! deploy (the exact chain `bitslice-reram audit --fixture planted
//! --reorder --replicate-budget 2.0` runs) must serialize byte-for-byte
//! to the committed `tests/golden/audit.json`.
//!
//! The golden pins two things at once: the deploy is *clean* (no
//! diagnostics — a regression in mapper/reorder/planner invariants shows
//! up here first) and the artifact's shape is *stable* (key order,
//! summary fields, the 64-tile scan of the 784x11 + 11x10 stack). A
//! deliberate change to either regenerates the file in one reviewed
//! place: paste the `left` value the assertion prints.

use bitslice_reram::data::synthetic;
use bitslice_reram::report;
use bitslice_reram::reram::audit;
use bitslice_reram::reram::planner::DeploymentPlan;
use bitslice_reram::reram::timing;
use bitslice_reram::reram::{mapper, ReorderConfig, ResolutionPolicy};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::fixtures;

const GOLDEN: &str = include_str!("golden/audit.json");

#[test]
fn planted_fixture_audit_json_matches_golden() {
    let train = synthetic::mnist(2000, 11);
    let stack = fixtures::planted_class_stack(&train);
    let named: Vec<(String, Tensor)> = stack
        .iter()
        .map(|l| (l.name.clone(), l.w.clone()))
        .collect();
    let mapped = mapper::map_model_with(&named, Some(ReorderConfig::default()))
        .expect("planted fixture maps");
    let mut plan = DeploymentPlan::from_policy(&mapped, ResolutionPolicy::Percentile(0.999));
    let budget = timing::factor_budget_cells(&mapped, &plan, 2.0);
    timing::fill_replicas(&mapped, &mut plan, budget);
    let rep = audit::audit_deployment(&mapped, &plan);
    assert_eq!(
        report::audit_json(&rep).to_string(),
        GOLDEN.trim_end(),
        "audit.json drifted from tests/golden/audit.json — if the change \
         is deliberate, commit the new serialization as the golden file"
    );
}

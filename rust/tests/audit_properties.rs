//! Mutation properties for the `reram::audit` static verifier: every
//! diagnostic code (A001–A011) gets one seeded property that plants its
//! violation class into an otherwise-clean deployment artifact — via the
//! test-gated corruption hooks on `Crossbar`, the raw `Permutation`
//! constructor, plan mutations, or replica-view tampering — and asserts
//! the audit reports it. The clean-artifact tests at the bottom close the
//! loop: a well-formed end-to-end deploy (all three `CellArray` layouts,
//! reorder and replication on) produces zero diagnostics.

use std::sync::Arc;

use bitslice_reram::reram::audit::{self, AuditCode, Severity};
use bitslice_reram::reram::crossbar::{Crossbar, StorageFormat, CELL_MAX};
use bitslice_reram::reram::mapper::{self, LayerMapping, MappedModel};
use bitslice_reram::reram::planner::DeploymentPlan;
use bitslice_reram::reram::reorder::{LayerReorder, Permutation};
use bitslice_reram::reram::timing::{self, MAX_REPLICAS};
use bitslice_reram::reram::{ReorderConfig, ResolutionPolicy};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::check::{check, ensure};
use bitslice_reram::util::fixtures;
use bitslice_reram::util::rng::Rng;

/// One mapped 160x96 layer (2x1 row tiles, so one tile has 32 padded
/// rows) at the given element density.
fn mapped_layer(rng: &mut Rng, density: f64) -> LayerMapping {
    let w = fixtures::weights_at_density(rng, 160, 96, density);
    mapper::map_layer("fc1/w", &w).expect("fixture layer maps")
}

fn model_of(layer: LayerMapping) -> MappedModel {
    MappedModel {
        layers: vec![Arc::new(layer)],
    }
}

/// Locate a programmed tile in `fmt`, as (slice, sign, tile) indices.
fn find_tile(layer: &LayerMapping, fmt: StorageFormat) -> Option<(usize, usize, usize)> {
    for (k, (pos, neg)) in layer.grids.iter().enumerate() {
        for (s, grid) in [pos, neg].into_iter().enumerate() {
            for (i, t) in grid.tiles.iter().enumerate() {
                if t.nonzero_cells() > 0 && t.format() == fmt {
                    return Some((k, s, i));
                }
            }
        }
    }
    None
}

fn tile_mut(layer: &mut LayerMapping, at: (usize, usize, usize)) -> &mut Crossbar {
    let (pos, neg) = &mut layer.grids[at.0];
    let grid = if at.1 == 0 { pos } else { neg };
    &mut grid.tiles[at.2]
}

/// Corrupt one programmed tile of `layer` (forced into `fmt` first so the
/// layout-specific hook applies) and return the audit of the result.
fn audit_corrupted(
    rng: &mut Rng,
    fmt: StorageFormat,
    corrupt: impl Fn(&mut Rng, &mut Crossbar),
) -> Result<audit::AuditReport, String> {
    let mut layer = mapped_layer(rng, 0.3).with_storage(fmt);
    let at = find_tile(&layer, fmt).ok_or("fixture layer has no programmed tile")?;
    corrupt(rng, tile_mut(&mut layer, at));
    Ok(audit::audit_model(&model_of(layer)))
}

fn ensure_flags(rep: &audit::AuditReport, code: AuditCode) -> Result<(), String> {
    ensure(rep.has(code), format!("{} not reported:\n{rep}", code.code()))?;
    ensure(rep.summary.errors > 0, format!("no errors counted:\n{rep}"))
}

#[test]
fn a001_cell_value_out_of_range_detected() {
    check(6, |rng| {
        let rep = audit_corrupted(rng, StorageFormat::Dense, |rng, t| {
            let (r, c) = (rng.below(t.rows()), rng.below(t.cols()));
            t.corrupt_dense_value(r, c, CELL_MAX + 1 + rng.below(200) as u8);
        })?;
        ensure_flags(&rep, AuditCode::CellValueOutOfRange)
    });
}

#[test]
fn a002_census_mismatch_detected() {
    check(6, |rng| {
        // the census desync must surface in whatever CellArray the tile
        // holds, so sweep all three layouts
        let fmt = [
            StorageFormat::Dense,
            StorageFormat::Compressed,
            StorageFormat::BitPlanes,
        ][rng.below(3)];
        let mut layer = mapped_layer(rng, 0.2 + rng.next_f32() as f64 * 0.4).with_storage(fmt);
        let at = find_tile(&layer, fmt).ok_or("no programmed tile")?;
        tile_mut(&mut layer, at).corrupt_census(1 + rng.below(5) as isize);
        ensure_flags(
            &audit::audit_model(&model_of(layer)),
            AuditCode::CensusMismatch,
        )
    });
}

#[test]
fn a003_compressed_index_inconsistent_detected() {
    check(6, |rng| {
        let rep = audit_corrupted(rng, StorageFormat::Compressed, |_, t| {
            t.corrupt_drop_active_col();
        })?;
        ensure_flags(&rep, AuditCode::CompressedIndexInconsistent)
    });
}

#[test]
fn a004_bit_plane_mask_mismatch_detected() {
    check(6, |rng| {
        // flip a stray padding bit past the tile's rows: unambiguously a
        // mask fault (an in-range flip may legally read as census drift)
        let mut layer = mapped_layer(rng, 0.3).with_storage(StorageFormat::BitPlanes);
        let at = (0..layer.grids.len())
            .flat_map(|k| [(k, 0usize), (k, 1usize)])
            .find_map(|(k, s)| {
                let grid = if s == 0 { &layer.grids[k].0 } else { &layer.grids[k].1 };
                grid.tiles
                    .iter()
                    .position(|t| t.nonzero_cells() > 0 && t.rows() < 128)
                    .map(|i| (k, s, i))
            })
            .ok_or("no short-row programmed tile (fixture is 160 rows)")?;
        let tile = tile_mut(&mut layer, at);
        let pad_row = tile.rows() + rng.below(128 - tile.rows());
        let col = tile.active_cols().and_then(|ac| ac.first().copied()).ok_or("no active col")?;
        tile.corrupt_flip_plane_bit(pad_row, col as usize);
        ensure_flags(
            &audit::audit_model(&model_of(layer)),
            AuditCode::BitPlaneMaskMismatch,
        )
    });
}

#[test]
fn a005_permutation_not_bijective_detected() {
    check(10, |rng| {
        let mut layer = mapped_layer(rng, 0.3);
        let n = layer.rows;
        let ident: Vec<u32> = (0..n as u32).collect();
        let (mut to_new, mut to_old, mut flag) = (ident.clone(), ident.clone(), true);
        match rng.below(5) {
            0 => {
                // wrong length
                to_new.pop();
                to_old.pop();
            }
            1 => to_new[0] = n as u32, // out of bounds
            2 => {
                to_new[0] = to_new[1]; // two rows share a wordline
            }
            3 => {
                to_old.swap(0, 1); // inverse drifts
            }
            _ => flag = false, // cached flag denies identity contents
        }
        layer.reorder = Some(LayerReorder {
            rows: Permutation::from_raw_parts(to_new, to_old, flag),
            cols: Permutation::identity(layer.cols),
        });
        ensure_flags(
            &audit::audit_model(&model_of(layer)),
            AuditCode::PermutationNotBijective,
        )
    });
}

#[test]
fn a006_plan_shape_mismatch_detected() {
    check(6, |rng| {
        let model = model_of(mapped_layer(rng, 0.3));
        let mut plan = DeploymentPlan::from_policy(&model, ResolutionPolicy::Lossless);
        if rng.below(2) == 0 {
            plan.layers.pop(); // layer-count drift
        } else {
            plan.layers[0].replicas = MAX_REPLICAS + 1 + rng.below(8);
        }
        let diags = audit::audit_plan(&model, &plan);
        ensure(
            diags
                .iter()
                .any(|d| d.code == AuditCode::PlanShapeMismatch && d.severity == Severity::Error),
            format!("A006 not reported: {diags:?}"),
        )
    });
}

#[test]
fn a007_resolution_out_of_bounds_detected() {
    check(6, |rng| {
        let model = model_of(mapped_layer(rng, 0.3));
        let mut plan = DeploymentPlan::from_policy(&model, ResolutionPolicy::Lossless);
        plan.layers[0].adc_bits[rng.below(4)] = 0;
        let rep = audit::audit_deployment(&model, &plan);
        ensure_flags(&rep, AuditCode::ResolutionOutOfBounds)
    });
}

#[test]
fn a008_replica_alias_broken_detected() {
    check(6, |rng| {
        let model = model_of(mapped_layer(rng, 0.3));
        let plan = DeploymentPlan::from_policy(&model, ResolutionPolicy::Lossless);
        let mut rep = model.replicated(&[plan.layers[0].replicas]);
        if rng.below(2) == 0 {
            // an extra handle the plan never fabricated
            rep.layers[0].push(Arc::clone(&model.layers[0]));
        } else {
            // a deep clone where an alias is required
            rep.layers[0][0] = Arc::new((*model.layers[0]).clone());
        }
        let diags = audit::audit_replicas(&model, &plan, &rep);
        ensure(
            diags.iter().any(|d| d.code == AuditCode::ReplicaAliasBroken),
            format!("A008 not reported: {diags:?}"),
        )
    });
}

#[test]
fn a009_format_band_drift_is_warning_only() {
    check(6, |rng| {
        // 10% weights land well inside the Compressed band; forcing Dense
        // drifts every programmed tile without breaking any invariant
        let layer = mapped_layer(rng, 0.1).with_storage(StorageFormat::Dense);
        let rep = audit::audit_model(&model_of(layer));
        ensure(
            rep.has(AuditCode::FormatBandDrift),
            format!("A009 not reported:\n{rep}"),
        )?;
        ensure(
            rep.summary.errors == 0,
            format!("band drift must never be an error:\n{rep}"),
        )
    });
}

#[test]
fn a010_timing_bill_mismatch_detected() {
    check(6, |rng| {
        // dropping an active column starves the conversion bill while the
        // store still holds conductance in that column
        let rep = audit_corrupted(rng, StorageFormat::BitPlanes, |_, t| {
            t.corrupt_drop_active_col();
        })?;
        ensure_flags(&rep, AuditCode::TimingBillMismatch)
    });
}

#[test]
fn a011_replica_budget_underflow_detected() {
    check(4, |rng| {
        let stack = fixtures::bottleneck_stack(rng.next_u64());
        let named: Vec<(String, Tensor)> =
            stack.iter().map(|l| (l.name.clone(), l.w.clone())).collect();
        let model = mapper::map_model(&named).expect("fixture maps");
        let mut plan = DeploymentPlan::from_policy(&model, ResolutionPolicy::Percentile(0.999));
        // any factor under 1.0 prices below one bottleneck copy
        let factor = 0.05 + rng.next_f32() as f64 * 0.9;
        let budget = timing::factor_budget_cells(&model, &plan, factor);
        let spent = timing::fill_replicas(&model, &mut plan, budget);
        ensure(spent == 0, format!("underflow budget bought {spent} cells"))?;
        let d = audit::replica_budget_diagnostic(&model, &plan, factor, spent)
            .ok_or("A011 not reported")?;
        ensure(
            d.code == AuditCode::ReplicaBudgetUnderflow && d.severity == Severity::Error,
            format!("wrong diagnostic: {d}"),
        )
    });
}

/// The acceptance bar's clean half: a mixed-density stack whose mapping
/// holds tiles in all three `CellArray` layouts, deployed end to end with
/// reorder and replication enabled, audits with zero diagnostics.
#[test]
fn clean_mixed_layout_deploy_audits_clean() {
    let mut rng = Rng::new(0xA0D1);
    // One layer per density band. The sign split and bit-slicing dilute a
    // layer's element density by ~2x (sign) x ~1/4 (zero slice chunks),
    // so: 8% mixed-sign -> ~3% cell density (Compressed band), 90%
    // mixed-sign -> ~34% (BitPlanes band), and the Dense band (> 60%)
    // needs an all-positive layer with high codes (~75% cell density).
    let dense_w: Vec<f32> = (0..64 * 10).map(|_| 0.5 + 0.5 * rng.next_f32()).collect();
    let named: Vec<(String, Tensor)> = vec![
        (
            "fc1/w".to_string(),
            fixtures::weights_at_density(&mut rng, 160, 96, 0.08),
        ),
        (
            "fc2/w".to_string(),
            fixtures::weights_at_density(&mut rng, 96, 64, 0.90),
        ),
        (
            "fc3/w".to_string(),
            Tensor::new(vec![64, 10], dense_w).expect("fixture shape"),
        ),
    ];
    let mapped =
        mapper::map_model_with(&named, Some(ReorderConfig::default())).expect("stack maps");

    let mut formats = std::collections::BTreeSet::new();
    for layer in &mapped.layers {
        for (pos, neg) in &layer.grids {
            for t in [pos, neg].into_iter().flat_map(|g| &g.tiles) {
                if t.nonzero_cells() > 0 {
                    formats.insert(format!("{:?}", t.format()));
                }
            }
        }
    }
    assert_eq!(
        formats.len(),
        3,
        "fixture must exercise all three layouts, got {formats:?}"
    );

    let mut plan = DeploymentPlan::from_policy(&mapped, ResolutionPolicy::Percentile(0.999));
    let budget = timing::factor_budget_cells(&mapped, &plan, 2.0);
    let spent = timing::fill_replicas(&mapped, &mut plan, budget);
    assert!(spent > 0, "a 2x budget must buy at least one replica");
    let rep = audit::audit_deployment(&mapped, &plan);
    assert!(rep.is_clean(), "clean deploy reported findings:\n{rep}");
    assert!(rep.summary.tiles > 0);
}

//! Figure 2 regeneration bench: per-slice sparsity traces under l1 vs Bl1
//! on the MNIST MLP (bench-scale; the paper plots VGG-11 — same code path
//! via `reproduce fig2 --model vgg11`).
//!
//! Also serves as the regularizer ablation: it reports how fast each
//! regularizer drives the average non-zero-slice ratio down, which is the
//! claim Figure 2 makes ("bit-slice l1 reduces the number of non-zero
//! bit-slices faster ... from the very beginning").
//!
//! Run: `cargo bench --bench fig2_curve`

use bitslice_reram::config::RunConfig;
use bitslice_reram::harness as hx;
use bitslice_reram::runtime::{Engine, Manifest};

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::defaults("mlp");
    cfg.steps = 150;
    cfg.pretrain_steps = 0; // Fig. 2 starts both regularizers from scratch
    cfg.trace_every = 10;
    cfg.out_dir = std::path::PathBuf::from("/tmp/bench-fig2");
    let manifest = match Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("SKIP: run `make artifacts` first");
            return Ok(());
        }
    };
    let engine = Engine::cpu()?;

    let traces = hx::reproduce_fig2(&engine, &manifest, &cfg)?;
    println!("\nFigure 2 (bench-scale) — average non-zero slice ratio over training:");
    println!("{:>6} | {:>8} | {:>8}", "step", "l1", "bl1");
    let l1 = &traces[0].1;
    let bl1 = &traces[1].1;
    for (a, b) in l1.iter().zip(bl1.iter()) {
        println!(
            "{:>6} | {:>7.2}% | {:>7.2}%",
            a.step,
            a.ratios.iter().sum::<f64>() / 4.0 * 100.0,
            b.ratios.iter().sum::<f64>() / 4.0 * 100.0
        );
    }
    // the figure's claim, quantified at the end of the trace:
    if let (Some(a), Some(b)) = (l1.last(), bl1.last()) {
        let ra = a.ratios.iter().sum::<f64>() / 4.0;
        let rb = b.ratios.iter().sum::<f64>() / 4.0;
        println!(
            "\nfinal average non-zero: l1 {:.2}% vs bl1 {:.2}% ({:.2}x sparser)",
            ra * 100.0,
            rb * 100.0,
            ra / rb.max(1e-9)
        );
    }
    Ok(())
}

//! §Perf + reproduction: the per-layer ADC deployment planner.
//!
//! Builds an MNIST-scale MLP whose weights are bit-slice sparse *by
//! construction* (the regime Bl1 training reaches: discriminative weights
//! live in the two low slices, the MSB group is nearly empty), then runs
//! `reram::planner::plan_deployment` against the synthetic MNIST holdout
//! across a sweep of accuracy budgets. Verifies the acceptance bar — at a
//! 0.5 pt budget the planner lands on an operating point at least as cheap
//! (by `energy::deployment_cost`) as the paper's hand-picked uniform
//! `[3,3,3,1]` — times the search, and writes the per-layer `PlanRow`
//! report to `BENCH_planner.json`.
//!
//! Run: `cargo bench --bench planner_sweep`

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use bitslice_reram::data::{synthetic, Dataset};
use bitslice_reram::report;
use bitslice_reram::reram::planner::{plan_deployment, PlannerConfig, PAPER_BITS};
use bitslice_reram::reram::{energy, mapper};
use bitslice_reram::serve::{self, dense_stack, DenseLayer, ReferenceBackend};
use bitslice_reram::tensor::Tensor;

/// A class-template MLP, bit-slice sparse by construction.
///
/// Layer 1 (784 -> 11): column `c < 10` holds, per 128-row tile, the two
/// most positive and two most negative (class-mean - global-mean) pixels
/// at code 12 = 0b1100 — slice 1 only, tile-column currents <= 6, so the
/// discriminative weights clip nowhere at the paper's 3-bit low-slice
/// ADCs. Column 10 holds the single dynamic-range pin (code 255); its
/// output is killed by a large negative bias and feeds nothing, so MSB
/// clipping on the pin never reaches the logits. Layer 2 (11 -> 10) is the
/// identity on the class units — a single code-255 cell per column, whose
/// MSB clipping is a uniform monotone rescale that preserves the argmax.
fn planted_stack(train: &Dataset) -> Vec<DenseLayer> {
    let dim = train.dim();
    let classes = train.num_classes;
    let hidden = classes + 1; // class units + the range-pin unit

    let mut mean = vec![0.0f64; classes * dim];
    let mut count = vec![0usize; classes];
    for i in 0..train.len() {
        let c = train.labels[i] as usize;
        count[c] += 1;
        for (j, &v) in train.features[i * dim..(i + 1) * dim].iter().enumerate() {
            mean[c * dim + j] += v as f64;
        }
    }
    for c in 0..classes {
        let inv = 1.0 / count[c].max(1) as f64;
        for j in 0..dim {
            mean[c * dim + j] *= inv;
        }
    }
    let mut gmean = vec![0.0f64; dim];
    for c in 0..classes {
        for j in 0..dim {
            gmean[j] += mean[c * dim + j] / classes as f64;
        }
    }

    let small = 12.0f32 / 256.0; // code 12 at qstep 2^-8 (pin = 1.0)
    let mut w1 = vec![0.0f32; dim * hidden];
    for c in 0..classes {
        let mut t0 = 0;
        while t0 < dim {
            let t1 = (t0 + 128).min(dim);
            let mut idx: Vec<usize> = (t0..t1).collect();
            idx.sort_by(|&a, &b| {
                let da = mean[c * dim + a] - gmean[a];
                let db = mean[c * dim + b] - gmean[b];
                db.partial_cmp(&da).unwrap()
            });
            for &j in idx.iter().take(2) {
                w1[j * hidden + c] = small;
            }
            for &j in idx.iter().rev().take(2) {
                w1[j * hidden + c] = -small;
            }
            t0 = t1;
        }
    }
    w1[classes] = 1.0; // row 0, pin column: sets the layer's dynamic range

    let mut b1 = vec![0.0f32; hidden];
    b1[classes] = -1e4; // the pin unit never survives the ReLU

    let mut w2 = vec![0.0f32; hidden * classes];
    for c in 0..classes {
        w2[c * classes + c] = 1.0;
    }

    dense_stack(
        &[
            ("fc1/w".into(), Tensor::new(vec![dim, hidden], w1).unwrap()),
            ("fc2/w".into(), Tensor::new(vec![hidden, classes], w2).unwrap()),
        ],
        &[
            Tensor::new(vec![hidden], b1).unwrap(),
            Tensor::new(vec![classes], vec![0.0; classes]).unwrap(),
        ],
    )
    .unwrap()
}

fn main() -> anyhow::Result<()> {
    let train = synthetic::mnist(2000, 11);
    let holdout = synthetic::mnist(512, 12);
    let stack = planted_stack(&train);

    let mapped = mapper::map_model(&[
        ("fc1/w".into(), stack[0].w.clone()),
        ("fc2/w".into(), stack[1].w.clone()),
    ])?;
    let paper_cost = energy::deployment_cost(&mapped, PAPER_BITS);

    harness::section("holdout baseline (exact quantized reference)");
    let reference = ReferenceBackend::new("reference", &stack)?;
    let base_acc = serve::accuracy(&reference, &holdout)?;
    println!(
        "reference accuracy on {}: {:.2}% ({} examples)",
        holdout.source,
        base_acc.accuracy * 100.0,
        base_acc.examples
    );

    harness::section("planner sweep over accuracy budgets");
    println!("budget (pt) | accuracy | evals | energy saving | vs uniform [3,3,3,1] energy");
    let mut headline = None;
    let mut sweep_ms = Vec::new();
    for budget_pts in [0.0f64, 0.5, 2.0, 100.0] {
        // eval_examples 0: search on the full holdout, so every
        // accept/reject margin is measured on the same set the acceptance
        // assertions below use
        let cfg = PlannerConfig {
            accuracy_budget: budget_pts / 100.0,
            eval_examples: 0,
            ..PlannerConfig::default()
        };
        let t0 = Instant::now();
        let res = plan_deployment(&stack, &holdout, &cfg)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        sweep_ms.push(ms);
        let (e, _, _) = res.savings();
        println!(
            "{:>11.1} | {:>7.2}% | {:>5} | {:>12.1}x | {:.3} ({:.1} ms)",
            budget_pts,
            res.accuracy * 100.0,
            res.evaluations,
            e,
            res.cost.energy / paper_cost.energy,
            ms,
        );
        if budget_pts == 0.5 {
            headline = Some(res);
        }
    }
    let headline = headline.expect("0.5 pt budget is in the sweep");

    harness::section("selected plan at the 0.5 pt budget");
    let plan_rows = energy::layer_costs(&mapped, &headline.plan);
    println!("{}", report::plan_table("planned per-layer deployment", &plan_rows));
    println!("plan: {}", headline.plan);

    // Acceptance bar: within a 0.5 pt drop budget the planner must find an
    // operating point at least as cheap as the paper's uniform [3,3,3,1].
    assert!(
        headline.accuracy >= headline.baseline_accuracy - 0.005 - 1e-12,
        "budget violated: {} vs baseline {}",
        headline.accuracy,
        headline.baseline_accuracy
    );
    assert!(
        headline.cost.energy <= paper_cost.energy,
        "planned energy {} exceeds uniform [3,3,3,1] energy {}",
        headline.cost.energy,
        paper_cost.energy
    );
    println!(
        "OK: planned energy {:.0} <= uniform [3,3,3,1] energy {:.0} within 0.5 pt budget",
        headline.cost.energy, paper_cost.energy
    );

    harness::section("plan roll-up cost");
    harness::bench(
        "energy::plan_cost (784x11 + 11x10 mapping)",
        std::time::Duration::from_millis(300),
        || {
            let _ = std::hint::black_box(energy::plan_cost(&mapped, &headline.plan));
        },
    );

    let json = report::planner_json(
        &plan_rows,
        headline.baseline_accuracy,
        headline.accuracy,
        0.005,
        headline.savings(),
        headline.evaluations,
    );
    std::fs::write("BENCH_planner.json", json.to_string())?;
    println!(
        "wrote BENCH_planner.json ({} layers, search {:.1} ms)",
        plan_rows.len(),
        sweep_ms[1]
    );
    Ok(())
}

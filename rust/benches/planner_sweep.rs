//! §Perf + reproduction: the per-layer ADC deployment planner.
//!
//! Builds an MNIST-scale MLP whose weights are bit-slice sparse *by
//! construction* (the regime Bl1 training reaches: discriminative weights
//! live in the two low slices, the MSB group is nearly empty), then runs
//! `reram::planner::plan_deployment` against the synthetic MNIST holdout
//! across a sweep of accuracy budgets. Verifies the acceptance bar — at a
//! 0.5 pt budget the planner lands on an operating point at least as cheap
//! (by `energy::deployment_cost`) as the paper's hand-picked uniform
//! `[3,3,3,1]` — times the search, and writes the per-layer `PlanRow`
//! report to `BENCH_planner.json`.
//!
//! Run: `cargo bench --bench planner_sweep`

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use bitslice_reram::data::synthetic;
use bitslice_reram::report;
use bitslice_reram::reram::planner::{plan_deployment, PlannerConfig, PAPER_BITS};
use bitslice_reram::reram::{energy, mapper};
use bitslice_reram::serve::{self, ReferenceBackend};
use bitslice_reram::util::fixtures;

fn main() -> anyhow::Result<()> {
    let train = synthetic::mnist(2000, 11);
    let holdout = synthetic::mnist(512, 12);
    // the shared class-template MLP, bit-slice sparse by construction
    // (see `util::fixtures::planted_class_stack` for the construction)
    let stack = fixtures::planted_class_stack(&train);

    let mapped = mapper::map_model(&[
        ("fc1/w".into(), stack[0].w.clone()),
        ("fc2/w".into(), stack[1].w.clone()),
    ])?;
    let paper_cost = energy::deployment_cost(&mapped, PAPER_BITS);

    harness::section("holdout baseline (exact quantized reference)");
    let reference = ReferenceBackend::new("reference", &stack)?;
    let base_acc = serve::accuracy(&reference, &holdout)?;
    println!(
        "reference accuracy on {}: {:.2}% ({} examples)",
        holdout.source,
        base_acc.accuracy * 100.0,
        base_acc.examples
    );

    harness::section("planner sweep over accuracy budgets");
    println!("budget (pt) | accuracy | evals | energy saving | vs uniform [3,3,3,1] energy");
    let mut headline = None;
    let mut sweep_ms = Vec::new();
    for budget_pts in [0.0f64, 0.5, 2.0, 100.0] {
        // eval_examples 0: search on the full holdout, so every
        // accept/reject margin is measured on the same set the acceptance
        // assertions below use
        let cfg = PlannerConfig {
            accuracy_budget: budget_pts / 100.0,
            eval_examples: 0,
            ..PlannerConfig::default()
        };
        let t0 = Instant::now();
        let res = plan_deployment(&stack, &holdout, &cfg)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        sweep_ms.push(ms);
        let (e, _, _) = res.savings();
        println!(
            "{:>11.1} | {:>7.2}% | {:>5} | {:>12.1}x | {:.3} ({:.1} ms)",
            budget_pts,
            res.accuracy * 100.0,
            res.evaluations,
            e,
            res.cost.energy / paper_cost.energy,
            ms,
        );
        if budget_pts == 0.5 {
            headline = Some(res);
        }
    }
    let headline = headline.expect("0.5 pt budget is in the sweep");

    harness::section("selected plan at the 0.5 pt budget");
    let plan_rows = energy::layer_costs(&mapped, &headline.plan);
    println!("{}", report::plan_table("planned per-layer deployment", &plan_rows));
    println!("plan: {}", headline.plan);

    // Acceptance bar: within a 0.5 pt drop budget the planner must find an
    // operating point at least as cheap as the paper's uniform [3,3,3,1].
    assert!(
        headline.accuracy >= headline.baseline_accuracy - 0.005 - 1e-12,
        "budget violated: {} vs baseline {}",
        headline.accuracy,
        headline.baseline_accuracy
    );
    assert!(
        headline.cost.energy <= paper_cost.energy,
        "planned energy {} exceeds uniform [3,3,3,1] energy {}",
        headline.cost.energy,
        paper_cost.energy
    );
    println!(
        "OK: planned energy {:.0} <= uniform [3,3,3,1] energy {:.0} within 0.5 pt budget",
        headline.cost.energy, paper_cost.energy
    );

    harness::section("plan roll-up cost");
    harness::bench(
        "energy::plan_cost (784x11 + 11x10 mapping)",
        std::time::Duration::from_millis(300),
        || {
            let _ = std::hint::black_box(energy::plan_cost(&mapped, &headline.plan));
        },
    );

    let json = report::planner_json(
        &plan_rows,
        headline.baseline_accuracy,
        headline.accuracy,
        0.005,
        headline.savings(),
        headline.evaluations,
        &bitslice_reram::reram::timing::plan_timing(&mapped, &headline.plan),
    );
    std::fs::write("BENCH_planner.json", json.to_string())?;
    println!(
        "wrote BENCH_planner.json ({} layers, search {:.1} ms)",
        plan_rows.len(),
        sweep_ms[1]
    );
    Ok(())
}

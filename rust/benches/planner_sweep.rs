//! §Perf + reproduction: the per-layer ADC deployment planner.
//!
//! Builds an MNIST-scale MLP whose weights are bit-slice sparse *by
//! construction* (the regime Bl1 training reaches: discriminative weights
//! live in the two low slices, the MSB group is nearly empty), then runs
//! `reram::planner::plan_deployment` against the synthetic MNIST holdout
//! across a sweep of accuracy budgets. Verifies three acceptance bars:
//!
//! 1. at a 0.5 pt budget the planner lands on an operating point at least
//!    as cheap (by `energy::deployment_cost`) as the paper's hand-picked
//!    uniform `[3,3,3,1]`;
//! 2. the incremental evaluator (prefix-cached layer re-runs + exact
//!    early-abort scoring) selects the **identical** plan to the uncached
//!    search, and — in the full run — spends >= 3x fewer crossbar
//!    layer-forwards or finishes >= 2x faster in wall-clock;
//! 3. under one replica cell budget, the joint ADC/replica pass meets (or
//!    beats) the sequential bits-then-replicas pipeline in steady-state
//!    throughput on the bottleneck-skewed fixture.
//!
//! Writes the plan report plus the incremental/joint evidence to
//! `BENCH_planner.json`.
//!
//! Run: `cargo bench --bench planner_sweep` (`-- --smoke` shrinks the
//! datasets and records the ratios without gating on them — the CI path).

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use bitslice_reram::data::{synthetic, Dataset};
use bitslice_reram::report;
use bitslice_reram::reram::planner::{plan_deployment, DeploymentPlan, PlannerConfig, PAPER_BITS};
use bitslice_reram::reram::{energy, mapper, timing};
use bitslice_reram::serve::{self, DenseLayer, InferenceBackend, ReferenceBackend};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::fixtures;
use bitslice_reram::util::json::{num, obj, Json};
use bitslice_reram::util::rng::Rng;

/// A holdout whose labels are the stack's own lossless argmax — every
/// example is classified correctly at the starting plan, so the accuracy
/// floor bites exactly when a candidate's clipping flips a prediction.
fn oracle_dataset(stack: &[DenseLayer], n: usize, seed: u64) -> anyhow::Result<Dataset> {
    let dim = stack[0].w.shape()[0];
    let classes = stack.last().expect("non-empty stack").w.shape()[1];
    let mut rng = Rng::new(seed);
    let feats: Vec<f32> = (0..n * dim).map(|_| rng.next_f32()).collect();
    let reference = ReferenceBackend::new("oracle", stack)?;
    let logits = reference.infer_batch(&Tensor::new(vec![n, dim], feats.clone())?)?;
    let labels: Vec<i32> = (0..n)
        .map(|i| {
            let row = &logits.data()[i * classes..(i + 1) * classes];
            // last max on ties — `serve::correct_by_argmax` semantics
            (0..classes)
                .max_by(|&a, &b| {
                    row[a]
                        .partial_cmp(&row[b])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap_or(0) as i32
        })
        .collect();
    Ok(Dataset {
        features: std::sync::Arc::new(feats),
        labels: std::sync::Arc::new(labels),
        example_shape: vec![dim],
        num_classes: classes,
        source: "oracle-bottleneck".into(),
    })
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (train_n, holdout_n) = if smoke { (600, 160) } else { (2000, 512) };
    let train = synthetic::mnist(train_n, 11);
    let holdout = synthetic::mnist(holdout_n, 12);
    // the shared class-template MLP, bit-slice sparse by construction
    // (see `util::fixtures::planted_class_stack` for the construction)
    let stack = fixtures::planted_class_stack(&train);

    let mapped = mapper::map_model(&[
        ("fc1/w".into(), stack[0].w.clone()),
        ("fc2/w".into(), stack[1].w.clone()),
    ])?;
    let paper_cost = energy::deployment_cost(&mapped, PAPER_BITS);

    harness::section("holdout baseline (exact quantized reference)");
    let reference = ReferenceBackend::new("reference", &stack)?;
    let base_acc = serve::accuracy(&reference, &holdout)?;
    println!(
        "reference accuracy on {}: {:.2}% ({} examples{})",
        holdout.source,
        base_acc.accuracy * 100.0,
        base_acc.examples,
        if smoke { ", smoke" } else { "" }
    );

    harness::section("planner sweep over accuracy budgets");
    println!("budget (pt) | accuracy | evals | energy saving | vs uniform [3,3,3,1] energy");
    let mut headline = None;
    for budget_pts in [0.0f64, 0.5, 2.0, 100.0] {
        // eval_examples 0: search on the full holdout, so every
        // accept/reject margin is measured on the same set the acceptance
        // assertions below use
        let cfg = PlannerConfig {
            accuracy_budget: budget_pts / 100.0,
            eval_examples: 0,
            ..PlannerConfig::default()
        };
        let t0 = Instant::now();
        let res = plan_deployment(&stack, &holdout, &cfg)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let (e, _, _) = res.savings();
        println!(
            "{:>11.1} | {:>7.2}% | {:>5} | {:>12.1}x | {:.3} ({:.1} ms)",
            budget_pts,
            res.accuracy * 100.0,
            res.stats.evaluations,
            e,
            res.cost.energy / paper_cost.energy,
            ms,
        );
        if budget_pts == 0.5 {
            headline = Some((res, ms));
        }
    }
    let (headline, cached_ms) = headline.expect("0.5 pt budget is in the sweep");

    harness::section("selected plan at the 0.5 pt budget");
    let plan_rows = energy::layer_costs(&mapped, &headline.plan);
    println!("{}", report::plan_table("planned per-layer deployment", &plan_rows));
    println!("plan: {}", headline.plan);
    println!("search cost: {}", report::search_stats_line(&headline.stats));

    // Acceptance bar 1: within a 0.5 pt drop budget the planner must find
    // an operating point at least as cheap as the paper's uniform [3,3,3,1].
    assert!(
        headline.accuracy >= headline.baseline_accuracy - 0.005 - 1e-12,
        "budget violated: {} vs baseline {}",
        headline.accuracy,
        headline.baseline_accuracy
    );
    assert!(
        headline.cost.energy <= paper_cost.energy,
        "planned energy {} exceeds uniform [3,3,3,1] energy {}",
        headline.cost.energy,
        paper_cost.energy
    );
    println!(
        "OK: planned energy {:.0} <= uniform [3,3,3,1] energy {:.0} within 0.5 pt budget",
        headline.cost.energy, paper_cost.energy
    );

    harness::section("incremental vs uncached search (same config, same holdout)");
    // the 0.5 pt sweep row above IS the cached run (incremental defaults
    // on); this re-runs the identical search through the from-scratch
    // evaluator
    let t0 = Instant::now();
    let uncached = plan_deployment(
        &stack,
        &holdout,
        &PlannerConfig {
            accuracy_budget: 0.005,
            eval_examples: 0,
            incremental: false,
            ..PlannerConfig::default()
        },
    )?;
    let uncached_ms = t0.elapsed().as_secs_f64() * 1e3;
    // Acceptance bar 2a: the cache must never change the outcome.
    assert_eq!(headline.plan, uncached.plan, "incremental search changed the plan");
    assert_eq!(
        headline.accuracy, uncached.accuracy,
        "incremental search changed the measured accuracy"
    );
    assert_eq!(uncached.stats.cache_hits, 0);
    let forwards_ratio =
        uncached.stats.layer_forwards as f64 / headline.stats.layer_forwards.max(1) as f64;
    let wallclock_ratio = uncached_ms / cached_ms.max(1e-9);
    println!(
        "cached   {:>9} layer-forwards ({:>8.1} ms)  [{} cache hits, {} early-aborted]",
        headline.stats.layer_forwards,
        cached_ms,
        headline.stats.cache_hits,
        headline.stats.aborted_evals
    );
    println!(
        "uncached {:>9} layer-forwards ({:>8.1} ms)",
        uncached.stats.layer_forwards, uncached_ms
    );
    println!(
        "ratios: {forwards_ratio:.2}x layer-forwards, {wallclock_ratio:.2}x wall-clock"
    );
    // Acceptance bar 2b (full run only — the smoke datasets are too small
    // for stable ratios): the machinery must actually pay for itself.
    if !smoke {
        assert!(
            forwards_ratio >= 3.0 || wallclock_ratio >= 2.0,
            "incremental evaluation saved too little: {forwards_ratio:.2}x forwards, \
             {wallclock_ratio:.2}x wall-clock"
        );
        println!("OK: >= 3x fewer layer-forwards or >= 2x wall-clock");
    }

    harness::section("joint ADC/replica pass vs sequential bits-then-replicas");
    let bstack = fixtures::bottleneck_stack(0xBEEF);
    let ds = oracle_dataset(&bstack, if smoke { 24 } else { 64 }, 9)?;
    let jcfg = PlannerConfig {
        eval_examples: 0,
        ..PlannerConfig::default()
    };
    let seq = plan_deployment(&bstack, &ds, &jcfg)?;
    let joint = plan_deployment(
        &bstack,
        &ds,
        &PlannerConfig {
            replicate_budget: Some(2.0),
            ..jcfg
        },
    )?;
    // the budget both pipelines get: 2x the starting plan's bottleneck
    // cells (exactly what the joint pass anchored)
    let named: Vec<(String, Tensor)> = bstack
        .iter()
        .map(|l| (l.name.clone(), l.w.clone()))
        .collect();
    let bmodel = mapper::map_model(&named)?;
    let start = DeploymentPlan::from_policy(&bmodel, jcfg.start_policy);
    let b = timing::plan_timing(&bmodel, &start)
        .bottleneck()
        .expect("bottleneck fixture has layers");
    let budget_cells = 2 * bmodel.layers[b].fabricated_cells();
    assert!(joint.replica_cells > 0, "the budget bought no replicas");
    assert!(joint.replica_cells <= budget_cells, "budget overspent");
    let mut seq_plan = seq.plan.clone();
    timing::fill_replicas(&bmodel, &mut seq_plan, budget_cells);
    let seq_tp = timing::plan_timing(&bmodel, &seq_plan).throughput_per_kcycle();
    let joint_tp = timing::plan_timing(&bmodel, &joint.plan).throughput_per_kcycle();
    println!(
        "joint {joint_tp:.3} vs sequential {seq_tp:.3} examples/kcycle \
         (budget {budget_cells} cells, joint spent {})",
        joint.replica_cells
    );
    // Acceptance bar 3: joint never loses to sequential under the same
    // budget (float-noise slack only).
    assert!(
        joint_tp >= seq_tp * 0.999,
        "joint pass lost throughput: {joint_tp} vs {seq_tp}"
    );
    println!("OK: joint >= sequential throughput under one budget");

    harness::section("plan roll-up cost");
    harness::bench(
        "energy::plan_cost (784x11 + 11x10 mapping)",
        std::time::Duration::from_millis(300),
        || {
            let _ = std::hint::black_box(energy::plan_cost(&mapped, &headline.plan));
        },
    );

    let plan_json = report::planner_json(
        &plan_rows,
        headline.baseline_accuracy,
        headline.accuracy,
        0.005,
        headline.savings(),
        &headline.stats,
        &timing::plan_timing(&mapped, &headline.plan),
    );
    let json = obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("plan", plan_json),
        (
            "incremental",
            obj(vec![
                ("cached_layer_forwards", num(headline.stats.layer_forwards as f64)),
                ("uncached_layer_forwards", num(uncached.stats.layer_forwards as f64)),
                ("forwards_ratio", num(forwards_ratio)),
                ("cached_ms", num(cached_ms)),
                ("uncached_ms", num(uncached_ms)),
                ("wallclock_ratio", num(wallclock_ratio)),
                ("cache_hits", num(headline.stats.cache_hits as f64)),
                ("aborted_evals", num(headline.stats.aborted_evals as f64)),
                ("plans_identical", Json::Bool(true)),
            ]),
        ),
        (
            "joint",
            obj(vec![
                ("budget_cells", num(budget_cells as f64)),
                ("replica_cells", num(joint.replica_cells as f64)),
                ("joint_throughput_per_kcycle", num(joint_tp)),
                ("sequential_throughput_per_kcycle", num(seq_tp)),
                ("throughput_ratio", num(joint_tp / seq_tp.max(1e-12))),
            ]),
        ),
    ]);
    std::fs::write("BENCH_planner.json", json.to_string())?;
    println!(
        "wrote BENCH_planner.json ({} layers, cached search {:.1} ms)",
        plan_rows.len(),
        cached_ms
    );
    Ok(())
}

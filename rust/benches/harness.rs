//! Shared timing harness for the `cargo bench` targets.
//!
//! criterion is not vendored in this sandbox, so the benches use this
//! small harness: warmup + calibrated iteration count + mean/p50/min/p95
//! reporting, one aligned row per benchmark. Wall-clock timing via
//! `std::time::Instant`.

use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    // not every bench target uses every helper; the file is #[path]-included
    #[allow(dead_code)]
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
}

/// Run `f` repeatedly for ~`target` total time (after 2 warmup calls),
/// then report distribution stats.
pub fn bench<F: FnMut()>(name: &str, target: Duration, mut f: F) -> Stats {
    // warmup (compile caches, page-in)
    f();
    f();
    // calibrate
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (target.as_secs_f64() / one.as_secs_f64()).ceil().max(3.0) as usize;
    let iters = iters.min(10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    let stats = Stats {
        iters,
        mean,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
        min: samples[0],
    };
    println!(
        "{name:<44} {:>8.3} ms/iter  (p50 {:>8.3}, p95 {:>8.3}, min {:>8.3}; n={})",
        stats.mean.as_secs_f64() * 1e3,
        stats.p50.as_secs_f64() * 1e3,
        stats.p95.as_secs_f64() * 1e3,
        stats.min.as_secs_f64() * 1e3,
        stats.iters
    );
    stats
}

/// Report a throughput line computed from a stats row.
#[allow(dead_code)]
pub fn throughput(name: &str, stats: &Stats, units_per_iter: f64, unit: &str) {
    let per_sec = units_per_iter / stats.mean.as_secs_f64();
    println!("{name:<44} {:>12.3e} {unit}/s", per_sec);
}

/// Section header.
pub fn section(title: &str) {
    println!("\n== {title} ==");
}

//! Table 2 regeneration bench: l1 vs Bl1 on ResNet-20 (CIFAR-10 class) at
//! bench-scale step counts, plus the per-step latency of the conv train
//! graphs — the expensive path of the reproduction.
//!
//! The full-scale run is `cargo run --release -- reproduce table2`.
//! Run: `cargo bench --bench table2_cifar`

use std::time::Instant;

use bitslice_reram::config::{Method, RunConfig};
use bitslice_reram::harness as hx;
use bitslice_reram::report;
use bitslice_reram::runtime::{Engine, Manifest};

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::defaults("resnet20");
    cfg.steps = 30;
    cfg.pretrain_steps = 10;
    cfg.train_examples = 1024;
    cfg.test_examples = 256;
    cfg.out_dir = std::path::PathBuf::from("/tmp/bench-table2");
    let manifest = match Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("SKIP: run `make artifacts` first");
            return Ok(());
        }
    };
    let engine = Engine::cpu()?;

    let mut rows = Vec::new();
    for method in [Method::L1, Method::Bl1] {
        let mut c = cfg.clone();
        c.method = method;
        let t0 = Instant::now();
        let res = hx::run_training(&engine, &manifest, c, false)?;
        println!(
            "resnet20/{:<4} {:>6.1}s wall, {:>7.1} ms/step, acc {:.2}%",
            method.name(),
            t0.elapsed().as_secs_f64(),
            res.outcome.mean_step_ms,
            res.eval.accuracy * 100.0
        );
        rows.push(res.method_row());
    }
    println!(
        "\n{}",
        report::sparsity_table("Table 2 excerpt (bench-scale, ResNet-20)", &rows)
    );
    Ok(())
}

//! §Perf L3: the simulator's bit-plane tile hot path, the training hot
//! path and the standalone kernel graphs.
//!
//! The first section needs no XLA artifacts: it sweeps the mid density
//! band (25-60% programmed cells, where neither zero-skip leverage nor
//! the compressed scan applies) on a single 128x128 tile, measuring the
//! byte-wise Dense scan against the popcount `BitPlanes` path, asserts
//! bit-exact agreement across all three storage layouts at every swept
//! density and resolution, and writes `BENCH_bitplane.json` (CI runs it
//! with `--smoke`). The acceptance bar: >= 1.5x over the Dense byte path
//! at 40% cell density.
//!
//! The remaining sections measure (a) one full coordinator step — batch
//! assembly + literal conversion + `train_step` execution + metric
//! extraction — against (b) the bare executable call, isolating
//! coordinator overhead, plus the standalone L1 kernel graphs (quantize /
//! bl1 / crossbar tile) and the AOT inference path through the unified
//! `serve::InferenceBackend` seam; they SKIP when `make artifacts` has
//! not run.
//!
//! Run: `cargo bench --bench runtime_hot_path [-- --smoke]`

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use bitslice_reram::config::{Method, RunConfig};
use bitslice_reram::coordinator::metrics::MetricsLog;
use bitslice_reram::coordinator::Trainer;
use bitslice_reram::data::loader::{assemble, BatchPlan};
use bitslice_reram::data::Dataset;
use bitslice_reram::quant::N_SLICES;
use bitslice_reram::reram::crossbar::{pack_wave, Crossbar, StorageFormat, XBAR_COLS, XBAR_ROWS};
use bitslice_reram::report;
use bitslice_reram::reram::{audit, mapper, sim};
use bitslice_reram::runtime::{Engine, Manifest};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::json::{num, obj, Json};
use bitslice_reram::util::rng::Rng;

const LOSSLESS: [u32; N_SLICES] = [10, 10, 10, 10];

/// A full 128x128 tile with exactly `round(density * 128 * 128)` cells
/// programmed to random nonzero values at uniformly random positions.
fn tile_at_density(rng: &mut Rng, density: f64) -> Crossbar {
    let cells = XBAR_ROWS * XBAR_COLS;
    let n = (density * cells as f64).round() as usize;
    // Fisher-Yates over the flat cell index: exactly n distinct slots
    let mut slots: Vec<usize> = (0..cells).collect();
    for i in (1..cells).rev() {
        slots.swap(i, rng.below(i + 1));
    }
    let mut xb = Crossbar::zeros(XBAR_ROWS, XBAR_COLS);
    for &s in slots.iter().take(n) {
        xb.set(s / XBAR_COLS, s % XBAR_COLS, 1 + rng.below(3) as u8);
    }
    xb
}

/// The artifact-independent bit-plane hot-path sweep (see module docs).
fn bitplane_sweep(smoke: bool) -> anyhow::Result<()> {
    let mut rng = Rng::new(29);
    let target = Duration::from_millis(if smoke { 150 } else { 600 });
    harness::section("bit-plane popcount scan vs dense byte scan (mid-band tile densities)");
    let mut rows_json: Vec<Json> = Vec::new();
    let mut speedup_at_040 = None;
    for density in [0.25f64, 0.30, 0.40, 0.50, 0.60] {
        let tile = tile_at_density(&mut rng, density);
        let dense = tile.in_format(StorageFormat::Dense);
        let bp = tile.in_format(StorageFormat::BitPlanes);
        let comp = tile.in_format(StorageFormat::Compressed);
        // a half-on activation plane, the byte form and its packed wave
        let bits: Vec<u8> = (0..XBAR_ROWS).map(|_| rng.below(2) as u8).collect();
        let wave = pack_wave(&bits);

        let mut out = vec![0u32; XBAR_COLS];
        let sd = harness::bench(&format!("dense byte scan d={density}"), target, || {
            dense.bitline_currents(&bits, &mut out);
            std::hint::black_box(&out);
        });
        let mut out_bp = vec![0u32; XBAR_COLS];
        let sb = harness::bench(&format!("bit-plane wave scan d={density}"), target, || {
            let _ = bp.bitline_currents_wave(&wave, &mut out_bp);
            std::hint::black_box(&out_bp);
        });
        let speedup = sd.mean.as_secs_f64() / sb.mean.as_secs_f64();

        // tile-level bit-exactness: every layout, byte and wave entry
        // points, one shared answer
        dense.bitline_currents(&bits, &mut out);
        let _ = bp.bitline_currents_wave(&wave, &mut out_bp);
        assert_eq!(out, out_bp, "dense byte vs bit-plane wave at d={density}");
        let mut check = vec![0u32; XBAR_COLS];
        comp.bitline_currents(&bits, &mut check);
        assert_eq!(out, check, "compressed byte scan at d={density}");
        bp.bitline_currents(&bits, &mut check);
        assert_eq!(out, check, "bit-plane byte entry point at d={density}");
        let _ = dense.bitline_currents_wave(&wave, &mut check);
        assert_eq!(out, check, "dense wave entry point at d={density}");

        println!(
            "-> cell density {density}: {} bytes dense / {} bit-plane, speedup {speedup:.2}x",
            dense.storage_bytes(),
            bp.storage_bytes(),
        );
        if density == 0.40 {
            speedup_at_040 = Some(speedup);
        }
        rows_json.push(obj(vec![
            ("cell_density", num(density)),
            ("dense_ms", num(sd.mean_ms())),
            ("bitplane_ms", num(sb.mean_ms())),
            ("speedup", num(speedup)),
            ("dense_bytes", num(dense.storage_bytes() as f64)),
            ("bitplane_bytes", num(bp.storage_bytes() as f64)),
        ]));
    }

    // forward-level bit-exactness across the same band, all three
    // layouts, at clipping and non-clipping ADC resolutions
    let batch = if smoke { 2 } else { 8 };
    let mut audit_tiles = 0usize;
    let x = Tensor::new(
        vec![batch, 256],
        (0..batch * 256).map(|_| rng.next_f32()).collect(),
    )?;
    for density in [0.25f64, 0.40, 0.60] {
        let mut data = vec![0.0f32; 256 * 96];
        for v in data.iter_mut() {
            if (rng.below(1000) as f64) < density * 1000.0 {
                *v = (rng.next_f32() - 0.5) * 2.0;
            }
        }
        let w = Tensor::new(vec![256, 96], data)?;
        let layer = mapper::map_layer("w", &w)?;
        // every mapped artifact the sweep exercises passes the static
        // verifier before any current is sampled from it
        let layer_audit = audit::audit_model(&mapper::MappedModel {
            layers: vec![std::sync::Arc::new(layer.clone())],
        });
        assert!(
            layer_audit.is_clean(),
            "mapped layer at weight density {density} failed its audit — {layer_audit}"
        );
        audit_tiles += layer_audit.summary.tiles;
        for bits in [LOSSLESS, [3, 3, 3, 1], [2, 2, 2, 2]] {
            let auto = sim::forward(&layer, &x, &bits);
            for fmt in [
                StorageFormat::Dense,
                StorageFormat::Compressed,
                StorageFormat::BitPlanes,
            ] {
                let forced = sim::forward(&layer.with_storage(fmt), &x, &bits);
                assert_eq!(
                    forced.data(),
                    auto.data(),
                    "{fmt:?} disagrees at weight density {density}, adc {bits:?}"
                );
            }
        }
    }
    println!("OK: all three layouts bit-exact at every swept density and resolution");

    // Acceptance bar: the popcount path must beat the byte-wise Dense
    // scan by >= 1.5x in the middle of the band
    let speedup = speedup_at_040.expect("0.40 is in the sweep");
    assert!(
        speedup >= 1.5,
        "bit-plane path only {speedup:.2}x over the dense byte scan at 40% cell density"
    );
    println!("OK: {speedup:.2}x over the dense byte scan at 40% cell density");

    let doc = obj(vec![
        (
            "tile",
            obj(vec![
                ("rows", num(XBAR_ROWS as f64)),
                ("cols", num(XBAR_COLS as f64)),
            ]),
        ),
        ("smoke", Json::Bool(smoke)),
        ("speedup_at_040_density", num(speedup)),
        (
            "audit",
            report::audit_summary_json(&audit::AuditSummary {
                tiles: audit_tiles,
                errors: 0,
                warnings: 0,
            }),
        ),
        ("sweep", Json::Arr(rows_json)),
    ]);
    std::fs::write("BENCH_bitplane.json", doc.to_string())?;
    println!("wrote BENCH_bitplane.json");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // runs first: needs no artifacts, and CI exercises exactly this part
    bitplane_sweep(smoke)?;

    let cfg = RunConfig::defaults("mlp");
    let manifest = match Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("SKIP remaining sections: run `make artifacts` first");
            return Ok(());
        }
    };
    let engine = Engine::cpu()?;

    harness::section("coordinator step loop (mlp, batch 128)");
    {
        let ds = Dataset::auto("mnist", &cfg.data_dir, true, 4096, 1)?;
        let mut c = cfg.clone();
        c.method = Method::Baseline;
        c.steps = 1;
        c.pretrain_steps = 0;
        // full coordinator step, including logging, via Trainer on a
        // 1-step config repeated by the harness
        let mut log = MetricsLog::create(None)?;
        let mut trainer = Trainer::new(&engine, &manifest, c.clone())?;
        harness::bench("trainer: 1 full step (incl. setup amortized)", Duration::from_secs(3), || {
            let mut l = MetricsLog::create(None).unwrap();
            let mut cfg1 = c.clone();
            cfg1.steps = 1;
            trainer.cfg = cfg1;
            trainer.run(&ds, &mut l).unwrap();
        });
        let _ = (&mut log,);
    }

    harness::section("bare executable vs coordinator (mlp train graph)");
    {
        let entry = manifest.model("mlp")?;
        let g = entry.graph("train")?;
        let exe = engine.load(&g.path)?;
        let ds = Dataset::auto("mnist", &cfg.data_dir, true, 4096, 1)?;
        let plan = BatchPlan::new(ds.len(), entry.batch, 7);

        // fixed inputs
        let state = bitslice_reram::coordinator::ModelState::init(entry, 3);
        let state_lits = state.to_train_literals()?;
        let scalars = [
            Tensor::scalar(0.05).to_literal()?,
            Tensor::scalar(0.9).to_literal()?,
            Tensor::scalar(0.0).to_literal()?,
            Tensor::scalar(0.0).to_literal()?,
        ];
        let batch = assemble(&ds, &plan.indices(0));
        let x = batch.x.to_literal()?;
        let y = batch.y.to_literal()?;
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        inputs.extend(state_lits.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.extend(scalars.iter());

        let exec = harness::bench(
            "execute only (state resident, fixed batch)",
            Duration::from_secs(3),
            || {
                let _ = exe.run(&inputs).unwrap();
            },
        );

        let asm = harness::bench("batch assembly + literal conversion", Duration::from_secs(1), || {
            let b = assemble(&ds, &plan.indices(1));
            let _ = b.x.to_literal().unwrap();
            let _ = b.y.to_literal().unwrap();
        });
        println!(
            "-> coordinator overhead per step: {:.3} ms ({:.1}% of execute)",
            asm.mean_ms(),
            100.0 * asm.mean_ms() / exec.mean_ms()
        );
    }

    harness::section("standalone L1 kernel graphs");
    {
        let mut rng = Rng::new(5);
        type Gen = Box<dyn Fn(&mut Rng, usize) -> Vec<f32>>;
        let cases: Vec<(&str, Gen)> = vec![
            ("quantize_1m", Box::new(|r, n| r.normal_vec(n, 0.1))),
            ("bl1_1m", Box::new(|r, n| (0..n).map(|_| r.below(256) as f32).collect())),
            ("crossbar_tile", Box::new(|r, n| (0..n).map(|_| r.below(4) as f32).collect())),
        ];
        for (name, gen) in cases {
            let Some(g) = manifest.kernels.get(name) else { continue };
            let exe = engine.load(&g.path)?;
            let lits: Vec<xla::Literal> = g
                .inputs
                .iter()
                .map(|s| {
                    Tensor::new(s.shape.clone(), gen(&mut rng, s.numel()))
                        .unwrap()
                        .to_literal()
                        .unwrap()
                })
                .collect();
            let elems: usize = g.inputs.iter().map(|s| s.numel()).max().unwrap_or(0);
            let st = harness::bench(&format!("kernel {name}"), Duration::from_secs(2), || {
                let _ = exe.run(&lits).unwrap();
            });
            harness::throughput(&format!("kernel {name} throughput"), &st, elems as f64, "elem");
        }
    }

    harness::section("AOT inference through serve::InferenceBackend (mlp)");
    {
        use bitslice_reram::serve::{self, InferenceBackend, XlaBackend};
        let entry = manifest.model("mlp")?;
        let state = bitslice_reram::coordinator::ModelState::init(entry, 3);
        let ds = Dataset::auto("mnist", &cfg.data_dir, false, 1024, 2)?;
        for tag in ["eval", "reram_lossless"] {
            if entry.graph(tag).is_err() {
                continue;
            }
            let backend = match tag {
                "eval" => XlaBackend::for_eval(&engine, &manifest, "mlp", &state)?,
                _ => XlaBackend::for_graph(&engine, &manifest, "mlp", tag, &state)?,
            };
            let st = harness::bench(
                &format!("{} accuracy over {} examples", backend.name(), ds.len()),
                Duration::from_secs(3),
                || {
                    let _ = std::hint::black_box(serve::accuracy(&backend, &ds).unwrap());
                },
            );
            harness::throughput(
                &format!("{} throughput", backend.name()),
                &st,
                ds.len() as f64,
                "example",
            );
        }
    }
    Ok(())
}

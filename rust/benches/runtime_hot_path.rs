//! §Perf L3: the training hot path and the standalone kernel graphs.
//!
//! Measures (a) one full coordinator step — batch assembly + literal
//! conversion + `train_step` execution + metric extraction — against (b)
//! the bare executable call, isolating coordinator overhead, plus the
//! standalone L1 kernel graphs (quantize / bl1 / crossbar tile) and the
//! AOT inference path through the unified `serve::InferenceBackend` seam.
//!
//! Run: `cargo bench --bench runtime_hot_path`

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use bitslice_reram::config::{Method, RunConfig};
use bitslice_reram::coordinator::metrics::MetricsLog;
use bitslice_reram::coordinator::Trainer;
use bitslice_reram::data::loader::{assemble, BatchPlan};
use bitslice_reram::data::Dataset;
use bitslice_reram::runtime::{Engine, Manifest};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig::defaults("mlp");
    let manifest = match Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("SKIP: run `make artifacts` first");
            return Ok(());
        }
    };
    let engine = Engine::cpu()?;

    harness::section("coordinator step loop (mlp, batch 128)");
    {
        let ds = Dataset::auto("mnist", &cfg.data_dir, true, 4096, 1)?;
        let mut c = cfg.clone();
        c.method = Method::Baseline;
        c.steps = 1;
        c.pretrain_steps = 0;
        // full coordinator step, including logging, via Trainer on a
        // 1-step config repeated by the harness
        let mut log = MetricsLog::create(None)?;
        let mut trainer = Trainer::new(&engine, &manifest, c.clone())?;
        harness::bench("trainer: 1 full step (incl. setup amortized)", Duration::from_secs(3), || {
            let mut l = MetricsLog::create(None).unwrap();
            let mut cfg1 = c.clone();
            cfg1.steps = 1;
            trainer.cfg = cfg1;
            trainer.run(&ds, &mut l).unwrap();
        });
        let _ = (&mut log,);
    }

    harness::section("bare executable vs coordinator (mlp train graph)");
    {
        let entry = manifest.model("mlp")?;
        let g = entry.graph("train")?;
        let exe = engine.load(&g.path)?;
        let ds = Dataset::auto("mnist", &cfg.data_dir, true, 4096, 1)?;
        let plan = BatchPlan::new(ds.len(), entry.batch, 7);

        // fixed inputs
        let state = bitslice_reram::coordinator::ModelState::init(entry, 3);
        let state_lits = state.to_train_literals()?;
        let scalars = [
            Tensor::scalar(0.05).to_literal()?,
            Tensor::scalar(0.9).to_literal()?,
            Tensor::scalar(0.0).to_literal()?,
            Tensor::scalar(0.0).to_literal()?,
        ];
        let batch = assemble(&ds, &plan.indices(0));
        let x = batch.x.to_literal()?;
        let y = batch.y.to_literal()?;
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        inputs.extend(state_lits.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.extend(scalars.iter());

        let exec = harness::bench(
            "execute only (state resident, fixed batch)",
            Duration::from_secs(3),
            || {
                let _ = exe.run(&inputs).unwrap();
            },
        );

        let asm = harness::bench("batch assembly + literal conversion", Duration::from_secs(1), || {
            let b = assemble(&ds, &plan.indices(1));
            let _ = b.x.to_literal().unwrap();
            let _ = b.y.to_literal().unwrap();
        });
        println!(
            "-> coordinator overhead per step: {:.3} ms ({:.1}% of execute)",
            asm.mean_ms(),
            100.0 * asm.mean_ms() / exec.mean_ms()
        );
    }

    harness::section("standalone L1 kernel graphs");
    {
        let mut rng = Rng::new(5);
        type Gen = Box<dyn Fn(&mut Rng, usize) -> Vec<f32>>;
        let cases: Vec<(&str, Gen)> = vec![
            ("quantize_1m", Box::new(|r, n| r.normal_vec(n, 0.1))),
            ("bl1_1m", Box::new(|r, n| (0..n).map(|_| r.below(256) as f32).collect())),
            ("crossbar_tile", Box::new(|r, n| (0..n).map(|_| r.below(4) as f32).collect())),
        ];
        for (name, gen) in cases {
            let Some(g) = manifest.kernels.get(name) else { continue };
            let exe = engine.load(&g.path)?;
            let lits: Vec<xla::Literal> = g
                .inputs
                .iter()
                .map(|s| {
                    Tensor::new(s.shape.clone(), gen(&mut rng, s.numel()))
                        .unwrap()
                        .to_literal()
                        .unwrap()
                })
                .collect();
            let elems: usize = g.inputs.iter().map(|s| s.numel()).max().unwrap_or(0);
            let st = harness::bench(&format!("kernel {name}"), Duration::from_secs(2), || {
                let _ = exe.run(&lits).unwrap();
            });
            harness::throughput(&format!("kernel {name} throughput"), &st, elems as f64, "elem");
        }
    }

    harness::section("AOT inference through serve::InferenceBackend (mlp)");
    {
        use bitslice_reram::serve::{self, InferenceBackend, XlaBackend};
        let entry = manifest.model("mlp")?;
        let state = bitslice_reram::coordinator::ModelState::init(entry, 3);
        let ds = Dataset::auto("mnist", &cfg.data_dir, false, 1024, 2)?;
        for tag in ["eval", "reram_lossless"] {
            if entry.graph(tag).is_err() {
                continue;
            }
            let backend = match tag {
                "eval" => XlaBackend::for_eval(&engine, &manifest, "mlp", &state)?,
                _ => XlaBackend::for_graph(&engine, &manifest, "mlp", tag, &state)?,
            };
            let st = harness::bench(
                &format!("{} accuracy over {} examples", backend.name(), ds.len()),
                Duration::from_secs(3),
                || {
                    let _ = std::hint::black_box(serve::accuracy(&backend, &ds).unwrap());
                },
            );
            harness::throughput(
                &format!("{} throughput", backend.name()),
                &st,
                ds.len() as f64,
                "example",
            );
        }
    }
    Ok(())
}

//! Table 3 bench: the ADC cost model and the sparsity -> resolution link.
//!
//! (a) regenerates the paper's Table 3 rows exactly (they are analytic);
//! (b) sweeps synthetic models at controlled bit-slice sparsity levels and
//!     reports the measured required ADC bits + whole-model savings — the
//!     quantitative version of the paper's "the resulting sparsity allows
//!     the ADC resolution to be reduced";
//! (c) times the analysis itself (mapping + column-current census).
//!
//! Run: `cargo bench --bench table3_adc`

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use bitslice_reram::reram::{energy, mapper, resolution, ResolutionPolicy};
use bitslice_reram::report;
use bitslice_reram::serve::{self, CrossbarBackend, InferenceBackend, ReferenceBackend};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::rng::Rng;

/// Build a 784x300 weight tensor with approximately the given non-zero
/// ratio and magnitudes spread across all slices.
fn sparse_weights(rng: &mut Rng, nonzero: f64) -> Tensor {
    let n = 784 * 300;
    let mut data = vec![0.0f32; n];
    let k = (n as f64 * nonzero) as usize;
    for _ in 0..k {
        let i = rng.below(n);
        data[i] = (rng.next_f32() * 2.0 - 1.0) * rng.next_f32();
    }
    data[0] = 1.0; // pin dynamic range
    Tensor::new(vec![784, 300], data).unwrap()
}

fn main() -> anyhow::Result<()> {
    harness::section("Table 3 — paper operating point (analytic, exact)");
    println!(
        "{}",
        report::adc_table(&[energy::saving_row(3, 1), energy::saving_row(2, 3)])
    );

    harness::section("sparsity -> required ADC bits sweep (784x300 layer)");
    println!("nonzero | lossless bits (LSB..MSB) | p99.9 bits | energy saving @p99.9");
    let mut rng = Rng::new(11);
    for nonzero in [0.5, 0.2, 0.1, 0.05, 0.02, 0.01, 0.005] {
        let w = sparse_weights(&mut rng, nonzero);
        let m = mapper::map_model(&[("w".into(), w)])?;
        let lossless = resolution::required_bits(&m, ResolutionPolicy::Lossless);
        let p999 = resolution::required_bits(&m, ResolutionPolicy::Percentile(0.999));
        let (e, _t, _a) = energy::savings_vs_baseline(&m, p999);
        println!(
            "{:>7.1}% | {:?} | {:?} | {:.1}x",
            nonzero * 100.0,
            lossless,
            p999,
            e
        );
    }

    harness::section("deployed forward cost through InferenceBackend (784x300x10 MLP)");
    {
        let w1 = sparse_weights(&mut rng, 0.05);
        let w2 = Tensor::new(vec![300, 10], rng.normal_vec(3000, 0.05)).unwrap();
        let b1 = Tensor::zeros(vec![300]);
        let b2 = Tensor::zeros(vec![10]);
        let stack = serve::dense_stack(
            &[("fc1/w".into(), w1), ("fc2/w".into(), w2)],
            &[b1, b2],
        )?;
        let x = Tensor::new(
            vec![64, 784],
            (0..64 * 784).map(|_| rng.next_f32()).collect(),
        )?;
        let reference = ReferenceBackend::new("reference", &stack)?;
        let xbar = CrossbarBackend::new("crossbar@p99.9", &stack, ResolutionPolicy::Percentile(0.999))?;
        let paper = xbar.rebit("crossbar@paper(3,3,3,1)", [3, 3, 3, 1]);
        assert!(
            std::sync::Arc::ptr_eq(xbar.mapped(), paper.mapped()),
            "rebit must share the mapping"
        );
        for backend in [&reference as &dyn InferenceBackend, &xbar, &paper] {
            harness::bench(
                &format!("{} infer_batch(64)", backend.name()),
                Duration::from_secs(2),
                || {
                    let _ = std::hint::black_box(backend.infer_batch(&x).unwrap());
                },
            );
        }

        // ADC sweep setup cost: `rebit` shares the mapped tiles via Arc
        // instead of deep-cloning them, so a sweep point costs roughly a
        // plan clone (microseconds), not a 784x300x4x2 tile copy.
        harness::bench(
            "rebit (shared-mapping sweep point)",
            Duration::from_millis(300),
            || {
                let _ = std::hint::black_box(xbar.rebit("sweep", [3, 3, 3, 1]));
            },
        );
    }

    harness::section("analysis cost");
    let w = sparse_weights(&mut rng, 0.05);
    let mapped = mapper::map_model(&[("w".into(), w.clone())])?;
    harness::bench(
        "column-current census + bits (784x300)",
        Duration::from_secs(2),
        || {
            let _ = std::hint::black_box(resolution::required_bits(
                &mapped,
                ResolutionPolicy::Percentile(0.999),
            ));
        },
    );
    harness::bench("deployment cost roll-up", Duration::from_secs(1), || {
        let _ = std::hint::black_box(energy::deployment_cost(&mapped, [3, 3, 3, 1]));
    });
    Ok(())
}

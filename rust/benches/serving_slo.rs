//! §Perf: persistent work-stealing executor + SLO-aware batch assembly.
//!
//! Serves the bottleneck-skewed fixture (replica-sharded, so every batch
//! fans out across replica lanes) under two regimes:
//!
//! * **baseline** — `ParallelMode::ScopedSpawn` (fresh OS threads per
//!   parallel region, the pre-executor behavior) with the greedy
//!   drain-now batcher;
//! * **executor** — the persistent work-stealing pool
//!   (`ParallelMode::Executor`) with the SLO-aware batcher
//!   ([`SloPolicy::from_timing`], priced from the plan's `reram::timing`
//!   cycle model and calibrated against a measured batch).
//!
//! Acceptance bars (full run, recorded-not-enforced under `--smoke`):
//!
//! * outputs **bit-identical** across both modes at every sweep point;
//! * >= 1.3x p99 latency at a fixed paced offered load;
//! * >= 1.2x throughput at small batches (`max_batch` <= 4);
//! * **zero OS-thread creation** inside the steady-state executor-mode
//!   serving loop (the pool's spawn counter must not move).
//!
//! Results land in `BENCH_slo.json`.
//!
//! Run: `cargo bench --bench serving_slo [-- --smoke]`

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::{Duration, Instant};

use bitslice_reram::report;
use bitslice_reram::reram::timing;
use bitslice_reram::serve::{
    CrossbarBackend, InferenceBackend, ServeOptions, ServingEngine, SharedBackend, SloPolicy,
};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::fixtures;
use bitslice_reram::util::json::{num, obj, s, Json};
use bitslice_reram::util::pool::{
    os_threads_spawned, set_parallel_mode, worker_threads, ParallelMode,
};
use bitslice_reram::util::rng::Rng;

const IN_DIM: usize = 64;
const P99_FLOOR: f64 = 1.3;
const SMALL_BATCH_FLOOR: f64 = 1.2;

/// Submit `requests` at a fixed pace (open-loop offered load), wait for
/// every response, return (outputs, serving row).
fn drive_paced(
    backend: SharedBackend,
    opts: ServeOptions,
    requests: &[Vec<f32>],
    interval: Duration,
) -> (Vec<Vec<f32>>, report::ServingRow) {
    let eng = ServingEngine::start(backend, opts).expect("start serving engine");
    let mut pending = Vec::with_capacity(requests.len());
    let start = Instant::now();
    for (i, x) in requests.iter().enumerate() {
        // pace against the schedule, not the previous send, so a slow
        // server cannot slow the offered load down
        let due = interval * i as u32;
        if let Some(wait) = due.checked_sub(start.elapsed()) {
            std::thread::sleep(wait);
        }
        pending.push(eng.submit(x.clone()).expect("submit"));
    }
    let out: Vec<Vec<f32>> = pending
        .into_iter()
        .map(|p| p.wait().expect("response"))
        .collect();
    let stats = eng.shutdown();
    println!(
        "{:<24}: p50 {:.3} ms, p99 {:.3} ms, mean batch {:.1}, {} violations",
        stats.backend,
        stats.latency_ms(0.50),
        stats.latency_ms(0.99),
        stats.mean_batch,
        stats.slo_violations,
    );
    (out, stats.row())
}

/// Closed-loop small-batch serving: submit everything, wait for all.
fn drive_closed(
    backend: SharedBackend,
    opts: ServeOptions,
    requests: &[Vec<f32>],
) -> (Vec<Vec<f32>>, report::ServingRow) {
    let eng = ServingEngine::start(backend, opts).expect("start serving engine");
    let out = eng.infer_many(requests.to_vec()).expect("serving requests");
    let stats = eng.shutdown();
    println!(
        "{:<24}: {:>8.0} req/s, p99 {:.3} ms, mean batch {:.1}",
        stats.backend,
        stats.throughput_rps,
        stats.latency_ms(0.99),
        stats.mean_batch
    );
    (out, stats.row())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let paced_n = if smoke { 64 } else { 384 };
    let closed_n = if smoke { 96 } else { 512 };
    let stack = fixtures::bottleneck_stack(0x510);

    // replica-sharded deployment: every batch fans out across lanes, so
    // per-call thread spawning (the baseline) sits on the hot path
    let base = CrossbarBackend::with_bits("xbar@slo", &stack, [3, 3, 3, 1])?;
    let model = base.mapped().clone();
    let mut plan = base.plan().clone();
    let timing0 = timing::plan_timing(&model, &plan);
    let bneck = timing0.bottleneck().expect("programmed stack");
    timing::fill_replicas(&model, &mut plan, 2 * model.layers[bneck].fabricated_cells());
    assert!(plan.layers[bneck].replicas >= 2, "budget buys replicas");
    let sharded = base.replan("xbar@slo", plan.clone())?;
    let timing1 = timing::plan_timing(&model, &plan);
    let backend: SharedBackend = Arc::new(sharded);

    // reference outputs, computed once on the executor path
    set_parallel_mode(ParallelMode::Executor);
    let mut rng = Rng::new(11);
    let paced_reqs: Vec<Vec<f32>> = (0..paced_n)
        .map(|_| (0..IN_DIM).map(|_| rng.next_f32()).collect())
        .collect();
    let closed_reqs: Vec<Vec<f32>> = (0..closed_n)
        .map(|_| (0..IN_DIM).map(|_| rng.next_f32()).collect())
        .collect();

    // calibrate the cycle model against one measured single-example
    // batch, so the SLO policy prices service time in real wall ms
    harness::section("calibration (executor mode, batch 1)");
    let x1 = Tensor::new(vec![1, IN_DIM], paced_reqs[0].clone())?;
    let cal = harness::bench("sharded infer_batch b=1", Duration::from_millis(200), || {
        let _ = std::hint::black_box(backend.infer_batch(&x1).unwrap());
    });
    let m1_ms = cal.mean.as_secs_f64() * 1e3;
    let model_ms_per_example =
        (timing1.pipeline_fill_cycles() as f64 + timing1.bottleneck_cycles()) / 1000.0;
    let ms_per_kcycle = m1_ms / model_ms_per_example.max(1e-12);
    let max_batch = 8usize;
    let mut policy = SloPolicy::from_timing(&timing1, 0.0, ms_per_kcycle);
    // target: the predicted full-batch service plus ~4 arrivals of slack
    policy.target_ms = policy.predicted_service_ms(max_batch) + 4.0 * m1_ms;
    let interval = Duration::from_secs_f64(m1_ms / 1e3);
    println!(
        "batch-1 mean {m1_ms:.3} ms -> {ms_per_kcycle:.4} ms/kcycle, \
         SLO target {:.3} ms, offered interval {:.3} ms",
        policy.target_ms,
        interval.as_secs_f64() * 1e3
    );

    // fixed offered load: executor + SLO batcher vs scoped-spawn + greedy
    harness::section(&format!("paced load: {paced_n} requests, 1 worker"));
    let paced_opts = |slo: Option<SloPolicy>| ServeOptions {
        max_batch,
        workers: 1,
        queue_depth: 1024,
        slo,
        ..ServeOptions::default()
    };
    set_parallel_mode(ParallelMode::ScopedSpawn);
    let (paced_base_out, paced_base_row) =
        drive_paced(backend.clone(), paced_opts(None), &paced_reqs, interval);
    set_parallel_mode(ParallelMode::Executor);
    // warm the pool, then freeze the spawn counter over the whole
    // steady-state loop — the executor must not create a single thread
    let _ = backend.infer_batch(&x1)?;
    let spawned_before = os_threads_spawned();
    let (paced_exec_out, paced_exec_row) =
        drive_paced(backend.clone(), paced_opts(Some(policy)), &paced_reqs, interval);
    let spawned_after = os_threads_spawned();
    assert_eq!(
        spawned_after, spawned_before,
        "steady-state serving must not spawn OS threads (executor pool only)"
    );
    assert_eq!(
        paced_base_out, paced_exec_out,
        "paced sweep point: outputs must be bit-identical across modes"
    );
    let p99_speedup = paced_base_row.latency_p99_ms / paced_exec_row.latency_p99_ms.max(1e-12);
    println!(
        "p99: {:.3} -> {:.3} ms ({p99_speedup:.2}x)",
        paced_base_row.latency_p99_ms, paced_exec_row.latency_p99_ms
    );

    // small-batch throughput: closed loop, max_batch <= 4
    harness::section(&format!("small batches: {closed_n} requests, max_batch 4"));
    let small_opts = ServeOptions {
        max_batch: 4,
        workers: 2,
        queue_depth: 1024,
        ..ServeOptions::default()
    };
    set_parallel_mode(ParallelMode::ScopedSpawn);
    let (small_base_out, small_base_row) = drive_closed(backend.clone(), small_opts, &closed_reqs);
    set_parallel_mode(ParallelMode::Executor);
    let (small_exec_out, small_exec_row) = drive_closed(backend.clone(), small_opts, &closed_reqs);
    assert_eq!(
        small_base_out, small_exec_out,
        "small-batch sweep point: outputs must be bit-identical across modes"
    );
    let small_speedup = small_exec_row.throughput_rps / small_base_row.throughput_rps.max(1e-12);
    println!(
        "small-batch throughput: {:.0} -> {:.0} req/s ({small_speedup:.2}x)",
        small_base_row.throughput_rps, small_exec_row.throughput_rps
    );

    let cores = worker_threads();
    if smoke {
        println!("(smoke run: speedup floors recorded, not enforced)");
    } else if cores < 2 {
        println!("(single-core host: no parallel regions to accelerate, floors skipped)");
    } else {
        assert!(
            p99_speedup >= P99_FLOOR,
            "SLO-aware executor serving only {p99_speedup:.2}x p99 (floor {P99_FLOOR}x)"
        );
        assert!(
            small_speedup >= SMALL_BATCH_FLOOR,
            "executor small-batch serving only {small_speedup:.2}x (floor {SMALL_BATCH_FLOOR}x)"
        );
        println!(
            "OK: p99 {p99_speedup:.2}x >= {P99_FLOOR}x, \
             small-batch {small_speedup:.2}x >= {SMALL_BATCH_FLOOR}x ({cores} cores)"
        );
    }

    let doc = obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("cores", num(cores as f64)),
        ("paced_requests", num(paced_n as f64)),
        ("closed_requests", num(closed_n as f64)),
        ("batch1_mean_ms", num(m1_ms)),
        ("ms_per_kcycle", num(ms_per_kcycle)),
        ("slo_target_ms", num(policy.target_ms)),
        ("offered_interval_ms", num(interval.as_secs_f64() * 1e3)),
        ("p99_speedup", num(p99_speedup)),
        ("p99_floor", num(P99_FLOOR)),
        ("small_batch_speedup", num(small_speedup)),
        ("small_batch_floor", num(SMALL_BATCH_FLOOR)),
        ("threads_spawned_in_loop", num((spawned_after - spawned_before) as f64)),
        ("bit_identical", Json::Bool(true)),
        ("bottleneck_layer", s(&timing1.layers[bneck].layer)),
        ("timing", report::timing_json(&timing1)),
        (
            "serving",
            report::serving_json(&[
                paced_base_row,
                paced_exec_row,
                small_base_row,
                small_exec_row,
            ]),
        ),
    ]);
    std::fs::write("BENCH_slo.json", doc.to_string())?;
    println!("wrote BENCH_slo.json");
    Ok(())
}

//! §Perf: the batched serving engine across backends and batch sizes.
//!
//! Spins up a `ServingEngine` over the host inference backends (exact
//! quantized reference, crossbar simulator at lossless and at the paper's
//! ADC operating point), pushes a fixed request load through it per
//! `max_batch` setting, and reports requests/sec plus p50/p99 end-to-end
//! latency. Results are printed as the serving table and written to
//! `BENCH_serving.json`.
//!
//! Run: `cargo bench --bench serving_throughput`

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;

use bitslice_reram::report;
use bitslice_reram::serve::{
    dense_stack, CrossbarBackend, DenseLayer, InferenceBackend, ReferenceBackend, ServeOptions,
    ServingEngine, SharedBackend,
};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::rng::Rng;

const IN_DIM: usize = 784;
const HIDDEN: usize = 300;
const CLASSES: usize = 10;
const REQUESTS: usize = 512;

/// MLP-shaped stack with bit-slice-sparse-ish weights.
fn stack(rng: &mut Rng) -> Vec<DenseLayer> {
    let mut sparse = |n: usize, keep: f64, scale: f32| -> Vec<f32> {
        (0..n)
            .map(|_| {
                if (rng.next_f32() as f64) < keep {
                    rng.normal() * scale
                } else {
                    0.0
                }
            })
            .collect()
    };
    let w1 = Tensor::new(vec![IN_DIM, HIDDEN], sparse(IN_DIM * HIDDEN, 0.10, 0.05)).unwrap();
    let w2 = Tensor::new(vec![HIDDEN, CLASSES], sparse(HIDDEN * CLASSES, 0.25, 0.08)).unwrap();
    let b1 = Tensor::new(vec![HIDDEN], (0..HIDDEN).map(|_| rng.normal() * 0.01).collect()).unwrap();
    let b2 = Tensor::new(vec![CLASSES], (0..CLASSES).map(|_| rng.normal() * 0.01).collect()).unwrap();
    dense_stack(
        &[("fc1/w".into(), w1), ("fc2/w".into(), w2)],
        &[b1, b2],
    )
    .unwrap()
}

fn drive(backend: SharedBackend, max_batch: usize, requests: &[Vec<f32>]) -> report::ServingRow {
    let eng = ServingEngine::start(
        backend,
        ServeOptions {
            max_batch,
            workers: 0,
            queue_depth: 256,
            ..ServeOptions::default()
        },
    )
    .expect("start serving engine");
    let out = eng
        .infer_many(requests.to_vec())
        .expect("serving requests");
    assert_eq!(out.len(), requests.len());
    let stats = eng.shutdown();
    println!(
        "{:<28} max_batch {:>4}: {:>8.0} req/s, p50 {:.3} ms, p99 {:.3} ms, mean batch {:.1}",
        stats.backend,
        max_batch,
        stats.throughput_rps,
        stats.latency_ms(0.50),
        stats.latency_ms(0.99),
        stats.mean_batch
    );
    stats.row()
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);
    let layers = stack(&mut rng);

    let requests: Vec<Vec<f32>> = (0..REQUESTS)
        .map(|_| (0..IN_DIM).map(|_| rng.next_f32()).collect())
        .collect();

    // intra_threads 1: the engine's worker pool is the parallelism under
    // test; nested per-batch fan-out would only oversubscribe the cores
    // and muddy the latency numbers.
    let reference: SharedBackend =
        Arc::new(ReferenceBackend::new("reference", &layers)?.with_intra_threads(1));
    let xbar_lossless = CrossbarBackend::with_bits("crossbar@lossless", &layers, [10; 4])?
        .with_intra_threads(1);
    let xbar_paper: SharedBackend =
        Arc::new(xbar_lossless.rebit("crossbar@paper(3,3,3,1)", [3, 3, 3, 1]));
    let xbar_lossless: SharedBackend = Arc::new(xbar_lossless);

    let mut rows = Vec::new();
    for backend in [reference, xbar_lossless, xbar_paper] {
        harness::section(&format!("serving {}", backend.name()));
        for max_batch in [1usize, 8, 32, 128] {
            rows.push(drive(backend.clone(), max_batch, &requests));
        }
    }

    harness::section("serving summary");
    println!("{}", report::serving_table(&rows));
    let json = report::serving_json(&rows).to_string();
    std::fs::write("BENCH_serving.json", &json)?;
    println!("wrote BENCH_serving.json ({} rows)", rows.len());
    Ok(())
}

//! §Perf: wordline/column reordering + zero-column ADC skip.
//!
//! Sweeps a 784x300 MLP layer across sparsity regimes — unstructured
//! random fills and the structured (dead-row x dead-column) patterns
//! bit-slice L1 training produces — and maps each point twice: natural
//! order, and through `reram::reorder`'s greedy column-similarity
//! clustering (`mapper::map_layer_with`). Both run the same simulator (so
//! both already enjoy the per-tile zero-column ADC skip); the reordered
//! mapping must additionally compact active wordlines/columns into fewer
//! tiles. Forward results are asserted bit-exact between the two layouts
//! at lossless resolution at every point.
//!
//! Acceptance bar: at >= 85% mean slice zeros, the reordered + column-skip
//! forward must be >= 1.3x over the natural-order compressed path (PR 3's
//! execution engine). Results (per-point timings, speedups, active-line
//! censuses) are written to `BENCH_reorder.json`.
//!
//! Run: `cargo bench --bench reorder_sim`

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use bitslice_reram::quant::N_SLICES;
use bitslice_reram::report;
use bitslice_reram::reram::mapper;
use bitslice_reram::reram::reorder::{self, ReorderConfig};
use bitslice_reram::reram::sim;
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::fixtures;
use bitslice_reram::util::json::{num, obj, s, Json};
use bitslice_reram::util::rng::Rng;

const LOSSLESS: [u32; N_SLICES] = [10, 10, 10, 10];
const ROWS: usize = 784;
const COLS: usize = 300;
const BATCH: usize = 32;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(13);
    let x = Tensor::new(
        vec![BATCH, ROWS],
        (0..BATCH * ROWS).map(|_| rng.next_f32()).collect(),
    )?;

    // (label, weights): unstructured fills for context, structured
    // dead-line patterns — the regime reordering targets — for the bar
    let points: Vec<(String, Tensor)> = vec![
        (
            "random d=0.25".into(),
            fixtures::weights_at_density(&mut rng, ROWS, COLS, 0.25),
        ),
        (
            "random d=0.05".into(),
            fixtures::weights_at_density(&mut rng, ROWS, COLS, 0.05),
        ),
        (
            "structured 50%x50% fill 0.5".into(),
            fixtures::structured_sparse_weights(&mut rng, ROWS, COLS, 0.5, 0.5, 0.5),
        ),
        (
            "structured 20%x20% fill 0.4".into(),
            fixtures::structured_sparse_weights(&mut rng, ROWS, COLS, 0.2, 0.2, 0.4),
        ),
        (
            "structured 15%x15% fill 0.3".into(),
            fixtures::structured_sparse_weights(&mut rng, ROWS, COLS, 0.15, 0.15, 0.3),
        ),
    ];

    harness::section("reorder sweep: natural-order vs reordered mapping forward");
    let mut rows_json: Vec<Json> = Vec::new();
    let mut best_sparse: Option<(f64, f64, String)> = None; // (zeros, speedup, label)
    for (label, w) in &points {
        let natural = mapper::map_layer("w", w)?;
        let reordered = mapper::map_layer_with("w", w, Some(ReorderConfig::default()))?;
        let zero_frac = fixtures::mean_slice_zero_fraction(&natural);

        // the permute/un-permute pair must cancel exactly: bit-exact
        // agreement with the unreordered mapping at lossless resolution
        let a = sim::forward(&natural, &x, &LOSSLESS);
        let b = sim::forward(&reordered, &x, &LOSSLESS);
        assert_eq!(a.data(), b.data(), "layouts disagree at {label}");

        let sn = harness::bench(
            &format!("natural   forward b={BATCH} [{label}]"),
            Duration::from_millis(1200),
            || {
                let _ = std::hint::black_box(sim::forward(&natural, &x, &LOSSLESS));
            },
        );
        let sr = harness::bench(
            &format!("reordered forward b={BATCH} [{label}]"),
            Duration::from_millis(1200),
            || {
                let _ = std::hint::black_box(sim::forward(&reordered, &x, &LOSSLESS));
            },
        );
        let speedup = sn.mean.as_secs_f64() / sr.mean.as_secs_f64();

        let (ns, rs) = (natural.storage_stats(), reordered.storage_stats());
        println!(
            "-> {label}: slice zeros {:.1}%, active WL {} -> {}, active cols {} -> {}, \
             skipped tiles {} -> {}, speedup {speedup:.2}x",
            zero_frac * 100.0,
            ns.active_wordlines,
            rs.active_wordlines,
            ns.active_columns,
            rs.active_columns,
            ns.skipped_tiles,
            rs.skipped_tiles,
        );
        if zero_frac >= 0.85 {
            let better = best_sparse
                .as_ref()
                .map(|(_, s, _)| speedup > *s)
                .unwrap_or(true);
            if better {
                best_sparse = Some((zero_frac, speedup, label.clone()));
            }
        }
        rows_json.push(obj(vec![
            ("label", s(label)),
            ("slice_zero_fraction", num(zero_frac)),
            ("active_wordlines_natural", num(ns.active_wordlines as f64)),
            ("active_wordlines_reordered", num(rs.active_wordlines as f64)),
            ("active_columns_natural", num(ns.active_columns as f64)),
            ("active_columns_reordered", num(rs.active_columns as f64)),
            ("skipped_tiles_natural", num(ns.skipped_tiles as f64)),
            ("skipped_tiles_reordered", num(rs.skipped_tiles as f64)),
            ("natural_ms", num(sn.mean_ms())),
            ("reordered_ms", num(sr.mean_ms())),
            ("speedup", num(speedup)),
        ]));
    }

    harness::section("reorder effect on the golden structured stack");
    {
        let golden = fixtures::reorder_golden();
        let named: Vec<(String, Tensor)> = golden
            .stack
            .iter()
            .map(|l| (l.name.clone(), l.w.clone()))
            .collect();
        let natural = mapper::map_model(&named)?;
        let reordered = mapper::map_model_with(&named, Some(ReorderConfig::default()))?;
        let rows = reorder::reorder_rows(&natural, &reordered);
        println!(
            "{}",
            report::reorder_table("golden stack (784->300->10, 15% lines, fill 0.3)", &rows)
        );
    }

    // Acceptance bar: >= 1.3x over the natural-order compressed path at
    // Bl1-level slice sparsity (>= 85% zeros); bit-exactness was asserted
    // at every point above.
    let (zeros, speedup, label) =
        best_sparse.expect("sweep reaches >= 85% slice zeros");
    assert!(
        speedup >= 1.3,
        "reordered+column-skip path only {speedup:.2}x at {:.1}% slice zeros ({label})",
        zeros * 100.0
    );
    println!(
        "OK: {speedup:.2}x over the natural-order compressed forward at {:.1}% mean slice \
         zeros ({label})",
        zeros * 100.0
    );

    let doc = obj(vec![
        ("layer", obj(vec![("rows", num(ROWS as f64)), ("cols", num(COLS as f64))])),
        ("batch", num(BATCH as f64)),
        ("bl1_level_speedup", num(speedup)),
        ("bl1_level_zero_fraction", num(zeros)),
        ("bl1_level_label", s(&label)),
        ("acceptance_min_speedup", num(1.3)),
        ("sweep", Json::Arr(rows_json)),
    ]);
    std::fs::write("BENCH_reorder.json", doc.to_string())?;
    println!("wrote BENCH_reorder.json");
    Ok(())
}

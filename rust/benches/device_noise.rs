//! §Reproduction: device non-ideality robustness (the noise sweep).
//!
//! The paper's central claim carried into the robustness regime: bit-slice
//! sparsity means fewer active cells per bitline, hence less accumulated
//! conductance variance reaching each ADC. This bench measures it: the
//! bit-slice-sparse planted stack and a dense-random stack of identical
//! geometry, each labeled by its own ideal argmax (so ideal accuracy is
//! 100% for both and any drop is pure noise damage), swept over matched
//! lognormal conductance sigmas with `harness::noise_report` Monte-Carlo
//! trials per point.
//!
//! Acceptance bars (asserted, smoke and full alike):
//!
//! 1. at sigma 0 the attached device model is *exactly* the ideal path —
//!    zero accuracy drop, trial for trial, on both stacks;
//! 2. the sparse stack loses strictly less accuracy than the dense stack
//!    at >= 2 of the nonzero sigma points (the headline claim).
//!
//! Writes the two accuracy-vs-variation series (Fig-2-style) plus the
//! headline verdict to `BENCH_noise.json`.
//!
//! Run: `cargo bench --bench device_noise` (`-- --smoke` shrinks the
//! datasets and trial counts — the CI path).

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;

use bitslice_reram::data::{synthetic, Dataset};
use bitslice_reram::harness as exp;
use bitslice_reram::report;
use bitslice_reram::reram::{DeviceConfig, ResolutionPolicy};
use bitslice_reram::serve::{self, CrossbarBackend, DenseLayer, InferenceBackend};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::fixtures;
use bitslice_reram::util::json::{num, obj, Json};
use bitslice_reram::util::rng::Rng;

/// A dense-random MLP with the planted stack's exact geometry — the
/// control arm: same tiling, same layer shapes, no bit-slice structure.
fn dense_random_stack(dim: usize, hidden: usize, classes: usize, seed: u64) -> Vec<DenseLayer> {
    let mut rng = Rng::new(seed);
    let w1 = Tensor::new(vec![dim, hidden], rng.normal_vec(dim * hidden, 0.08)).unwrap();
    let w2 = Tensor::new(vec![hidden, classes], rng.normal_vec(hidden * classes, 0.3)).unwrap();
    serve::dense_stack(
        &[("fc1/w".into(), w1), ("fc2/w".into(), w2)],
        &[
            Tensor::new(vec![hidden], vec![0.0; hidden]).unwrap(),
            Tensor::new(vec![classes], vec![0.0; classes]).unwrap(),
        ],
    )
    .expect("control stack")
}

/// Label `feats` with the backend's *own* ideal argmax (last max on ties
/// — `serve::correct_by_argmax` semantics), so the ideal crossbar scores
/// exactly 100% and every accuracy drop in the sweep is pure noise
/// damage, never a quantization disagreement with a float reference.
fn oracle_labels(backend: &CrossbarBackend, feats: &Arc<Vec<f32>>, dim: usize) -> Dataset {
    let classes = backend.info().num_classes;
    let n = feats.len() / dim;
    let logits = backend
        .infer_batch(&Tensor::new(vec![n, dim], feats.as_ref().clone()).unwrap())
        .expect("oracle forward");
    let labels: Vec<i32> = (0..n)
        .map(|i| {
            let row = &logits.data()[i * classes..(i + 1) * classes];
            (0..classes)
                .max_by(|&a, &b| {
                    row[a]
                        .partial_cmp(&row[b])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap_or(0) as i32
        })
        .collect();
    Dataset {
        features: feats.clone(),
        labels: Arc::new(labels),
        example_shape: vec![dim],
        num_classes: classes,
        source: "oracle-noise".into(),
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (train_n, eval_n, trials) = if smoke { (600, 96, 3) } else { (2000, 384, 8) };
    let sigmas = [0.0f32, 0.1, 0.2, 0.3, 0.4];

    let train = synthetic::mnist(train_n, 11);
    let sparse_stack = fixtures::planted_class_stack(&train);
    let dim = sparse_stack[0].w.shape()[0];
    let hidden = sparse_stack[0].w.shape()[1];
    let classes = sparse_stack[1].w.shape()[1];
    let dense_stack = dense_random_stack(dim, hidden, classes, 0xD05E);

    let sparse_be = CrossbarBackend::new("sparse", &sparse_stack, ResolutionPolicy::Lossless)?;
    let dense_be = CrossbarBackend::new("dense", &dense_stack, ResolutionPolicy::Lossless)?;

    // one shared feature set with class structure (the planted stack's
    // margins are designed against the synthetic class means — uniform
    // noise inputs would erase them), per-backend oracle labels
    let feats = synthetic::mnist(eval_n, 12).features;
    let sparse_ds = oracle_labels(&sparse_be, &feats, dim);
    let dense_ds = oracle_labels(&dense_be, &feats, dim);

    harness::section(&format!(
        "noise sweep ({} examples, {trials} trials per point{})",
        eval_n,
        if smoke { ", smoke" } else { "" }
    ));
    let sweep = |be: &CrossbarBackend, ds: &Dataset| -> anyhow::Result<Vec<report::NoiseRow>> {
        sigmas
            .iter()
            .map(|&sigma| {
                exp::noise_report(
                    be,
                    ds,
                    DeviceConfig {
                        sigma,
                        read_sigma: 0.0,
                        fault_rate: 0.0,
                        seed: 0xBE5E,
                    },
                    trials,
                )
            })
            .collect()
    };
    let sparse_rows = sweep(&sparse_be, &sparse_ds)?;
    let dense_rows = sweep(&dense_be, &dense_ds)?;
    println!(
        "{}",
        report::noise_table("bit-slice sparse (planted stack)", &sparse_rows)
    );
    println!(
        "{}",
        report::noise_table("dense random (matched geometry)", &dense_rows)
    );

    // Acceptance bar 1: sigma 0 is the ideal path exactly — the attached
    // device model may not move a single trial of either stack.
    for (name, rows) in [("sparse", &sparse_rows), ("dense", &dense_rows)] {
        let r0 = &rows[0];
        assert!(
            r0.trial_accuracies.iter().all(|&a| a == r0.ideal_accuracy),
            "{name}: sigma 0 device model diverged from the ideal path"
        );
        assert_eq!(
            r0.ideal_accuracy, 1.0,
            "{name}: oracle labels must score 100% on the ideal backend"
        );
    }
    println!("OK: sigma 0 attached = ideal path, bit for bit, on both stacks");

    // Acceptance bar 2: the headline claim — at matched sigma the sparse
    // stack degrades strictly less at >= 2 of the nonzero sigma points.
    let mut sparse_better = 0usize;
    for (s, d) in sparse_rows.iter().zip(&dense_rows).skip(1) {
        let verdict = s.mean_drop() < d.mean_drop();
        println!(
            "sigma {:.1}: sparse drop {:.2} pt vs dense {:.2} pt  {}",
            s.config.sigma,
            s.mean_drop() * 100.0,
            d.mean_drop() * 100.0,
            if verdict { "sparse better" } else { "-" }
        );
        sparse_better += verdict as usize;
    }
    assert!(
        sparse_better >= 2,
        "headline claim failed: sparse degraded less at only {sparse_better} sigma point(s)"
    );
    println!("OK: sparse loses less accuracy than dense at {sparse_better}/4 sigma points");

    harness::section("forward cost: ideal vs attached device");
    let x = Tensor::new(vec![eval_n, dim], feats.as_ref().clone())?;
    harness::bench("infer_batch ideal (no device)", Duration::from_millis(300), || {
        let _ = std::hint::black_box(sparse_be.infer_batch(&x).unwrap());
    });
    let noisy_be = sparse_be.with_device(
        "sparse-noisy",
        Arc::new(bitslice_reram::reram::DeviceModel::for_model(
            sparse_be.mapped(),
            DeviceConfig {
                sigma: 0.2,
                read_sigma: 0.1,
                fault_rate: 0.001,
                seed: 0xBE5E,
            },
        )),
    )?;
    harness::bench("infer_batch with device attached", Duration::from_millis(300), || {
        let _ = std::hint::black_box(noisy_be.infer_batch(&x).unwrap());
    });

    let json = obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("trials", num(trials as f64)),
        ("examples", num(eval_n as f64)),
        ("sparse", report::noise_json(&sparse_rows)),
        ("dense", report::noise_json(&dense_rows)),
        (
            "headline",
            obj(vec![
                ("nonzero_sigma_points", num((sigmas.len() - 1) as f64)),
                ("sparse_better_points", num(sparse_better as f64)),
                ("claim_holds", Json::Bool(sparse_better >= 2)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_noise.json", json.to_string())?;
    println!("\nnoise study written to BENCH_noise.json");
    Ok(())
}

//! §Perf: pipeline timing model + replica-sharded serving.
//!
//! Deploys the bottleneck-skewed fixture stack (`util::fixtures::
//! bottleneck_stack`: the wide fc2 carries ~4x every other layer's ADC
//! conversion load), prices it with the `reram::timing` cycle model,
//! water-fills a replication budget of **2x the bottleneck layer's
//! fabricated cells** onto the pipeline (`timing::fill_replicas`), and
//! then serves an identical request load through the batched
//! `ServingEngine` twice — unreplicated vs replica-sharded — on a
//! single-worker engine so the replicas' parallelism is the only
//! difference.
//!
//! Acceptance bar (full run): the replica-sharded deployment is
//! **bit-identical** to the unsharded path and >= 1.5x its serving
//! throughput on hosts with >= 3 cores (a 2-core host caps the bottleneck
//! at 2 shards, where ~1.5x is the theoretical ceiling, so a reduced
//! floor applies; a single core has nowhere to shard and skips the
//! floor). `--smoke` runs a short load for per-PR CI
//! visibility: bit-exactness is still asserted, the throughput floor is
//! recorded in the JSON instead of enforced. Results land in
//! `BENCH_pipeline.json`.
//!
//! Run: `cargo bench --bench pipeline_throughput [-- --smoke]`

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;

use bitslice_reram::report;
use bitslice_reram::reram::{audit, timing};
use bitslice_reram::serve::{
    CrossbarBackend, InferenceBackend, ServeOptions, ServingEngine, SharedBackend,
};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::fixtures;
use bitslice_reram::util::json::{num, obj, s, Json};
use bitslice_reram::util::pool::worker_threads;
use bitslice_reram::util::rng::Rng;

const IN_DIM: usize = 64;
const MIN_SPEEDUP: f64 = 1.5;

fn drive(backend: SharedBackend, requests: &[Vec<f32>]) -> (Vec<Vec<f32>>, report::ServingRow) {
    // one worker, no intra-batch fan-out: the replicas (or their absence)
    // are the only source of parallelism under test
    let eng = ServingEngine::start(
        backend,
        ServeOptions {
            max_batch: 128,
            workers: 1,
            queue_depth: 512,
            ..ServeOptions::default()
        },
    )
    .expect("start serving engine");
    let out = eng.infer_many(requests.to_vec()).expect("serving requests");
    let stats = eng.shutdown();
    println!(
        "{:<28}: {:>8.0} req/s, p50 {:.3} ms, p99 {:.3} ms, mean batch {:.1}",
        stats.backend,
        stats.throughput_rps,
        stats.latency_ms(0.50),
        stats.latency_ms(0.99),
        stats.mean_batch
    );
    (out, stats.row())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let requests_n = if smoke { 96 } else { 512 };
    let stack = fixtures::bottleneck_stack(0xBEEF);

    // deploy at the paper's operating point; the timing model prices the
    // plan's own resolutions
    let base =
        CrossbarBackend::with_bits("xbar@paper", &stack, [3, 3, 3, 1])?.with_intra_threads(1);
    let plan = base.plan().clone();
    let model = base.mapped().clone();

    harness::section("pipeline timing (unreplicated)");
    let timing0 = timing::plan_timing(&model, &plan);
    println!("{}", report::timing_table("unreplicated", &timing0));
    let bneck = timing0.bottleneck().expect("programmed stack");
    assert_eq!(
        timing0.layers[bneck].layer, "fc2/w",
        "the fixture's wide layer must be the bottleneck"
    );

    // water-fill a budget of 2x the bottleneck layer's fabricated cells
    let bneck_cells = model.layers[bneck].fabricated_cells();
    let budget = 2 * bneck_cells;
    let mut plan_r = plan.clone();
    let spent = timing::fill_replicas(&model, &mut plan_r, budget);
    let replicas = plan_r.layers[bneck].replicas;
    assert!(
        replicas >= 2,
        "a 2x-cells budget must afford at least one extra bottleneck copy"
    );
    assert!(spent <= budget, "water-fill overspent: {spent} > {budget}");

    harness::section("pipeline timing (replicated)");
    let timing1 = timing::plan_timing(&model, &plan_r);
    println!("{}", report::timing_table("replicated", &timing1));
    let model_speedup = timing0.bottleneck_cycles() / timing1.bottleneck_cycles();
    println!(
        "model throughput: {:.2} -> {:.2} examples/kcycle ({model_speedup:.2}x), \
         {replicas} replicas of {} ({spent} of {budget} cells spent)",
        timing0.throughput_per_kcycle(),
        timing1.throughput_per_kcycle(),
        timing0.layers[bneck].layer,
    );

    // static audit of the replicated artifact this bench is about to
    // serve — a faulty deployment would make every number below fiction
    let audit_rep = audit::audit_deployment(&model, &plan_r);
    assert!(
        audit_rep.summary.errors == 0,
        "replicated deployment failed its static audit — {audit_rep}"
    );

    // the sharded backend: same Arc-shared mapping, replicated plan
    let sharded = base.replan("xbar@replicated", plan_r.clone())?.with_intra_threads(1);
    assert!(Arc::ptr_eq(base.mapped(), sharded.mapped()));

    // bit-exactness on a direct batch before any serving
    let mut rng = Rng::new(7);
    let b = 64;
    let x = Tensor::new(
        vec![b, IN_DIM],
        (0..b * IN_DIM).map(|_| rng.next_f32()).collect(),
    )?;
    assert_eq!(
        base.infer_batch(&x)?.data(),
        sharded.infer_batch(&x)?.data(),
        "replica-sharded infer_batch must be bit-identical"
    );

    harness::section("direct infer_batch, batch 64 (1 host thread vs replica shards)");
    let target = Duration::from_millis(if smoke { 300 } else { 1200 });
    let s0 = harness::bench("unreplicated infer_batch", target, || {
        let _ = std::hint::black_box(base.infer_batch(&x).unwrap());
    });
    let s1 = harness::bench("replica-sharded infer_batch", target, || {
        let _ = std::hint::black_box(sharded.infer_batch(&x).unwrap());
    });
    let batch_speedup = s0.mean.as_secs_f64() / s1.mean.as_secs_f64();
    println!("direct-batch speedup: {batch_speedup:.2}x");

    harness::section(&format!("serving {requests_n} requests, 1 engine worker"));
    let requests: Vec<Vec<f32>> = (0..requests_n)
        .map(|_| (0..IN_DIM).map(|_| rng.next_f32()).collect())
        .collect();
    let unsharded: SharedBackend = Arc::new(base);
    let sharded: SharedBackend = Arc::new(sharded);
    let (out0, row0) = drive(unsharded, &requests);
    let (out1, row1) = drive(sharded, &requests);
    assert_eq!(
        out0, out1,
        "replica-sharded serving must be bit-identical to the unsharded path"
    );
    let serving_speedup = row1.throughput_rps / row0.throughput_rps;
    println!(
        "serving throughput: {:.0} -> {:.0} req/s ({serving_speedup:.2}x)",
        row0.throughput_rps, row1.throughput_rps
    );

    // the floor is cores-aware: on a 2-core host the bottleneck layer can
    // use at most 2 of its replicas, so ~1.5x is the *theoretical* ceiling
    // (Amdahl over the ~70% bottleneck share) — enforcing the full floor
    // there would fail a correct implementation. 3+ cores clear 1.5x with
    // margin; 1 core has nowhere to shard at all.
    let cores = worker_threads();
    let floor = if cores >= 3 { MIN_SPEEDUP } else { 1.2 };
    if smoke {
        println!("(smoke run: throughput floor recorded, not enforced)");
    } else if cores < 2 {
        println!("(single-core host: nowhere to shard, throughput floor skipped)");
    } else {
        assert!(
            serving_speedup >= floor,
            "replica-sharded serving only {serving_speedup:.2}x (floor {floor}x, \
             {cores} cores)"
        );
        println!("OK: {serving_speedup:.2}x >= {floor}x ({cores} cores)");
    }

    let doc = obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("requests", num(requests_n as f64)),
        ("cores", num(cores as f64)),
        ("bottleneck_layer", s(&timing0.layers[bneck].layer)),
        ("bottleneck_replicas", num(replicas as f64)),
        ("budget_cells", num(budget as f64)),
        ("spent_cells", num(spent as f64)),
        ("model_speedup", num(model_speedup)),
        ("batch_speedup", num(batch_speedup)),
        ("serving_speedup", num(serving_speedup)),
        ("acceptance_min_speedup", num(MIN_SPEEDUP)),
        ("enforced_floor", num(floor)),
        ("audit", report::audit_summary_json(&audit_rep.summary)),
        ("unreplicated", report::timing_json(&timing0)),
        ("replicated", report::timing_json(&timing1)),
        ("serving", report::serving_json(&[row0, row1])),
    ]);
    std::fs::write("BENCH_pipeline.json", doc.to_string())?;
    println!("wrote BENCH_pipeline.json");
    Ok(())
}

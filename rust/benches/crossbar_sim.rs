//! §Perf: the Rust ReRAM crossbar simulator (reram::sim + reram::crossbar).
//!
//! Measures bitline-current accumulation throughput (cell-ops/s), the
//! single-example mapped-layer forward, and the parallel batched forward —
//! the pieces behind the Table 3 functional validation. DESIGN.md §Perf
//! targets >= 1e8 cell-ops/s for the column accumulation.
//!
//! Run: `cargo bench --bench crossbar_sim`

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use bitslice_reram::reram::crossbar::Crossbar;
use bitslice_reram::reram::{mapper, sim};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(1);

    harness::section("bitline current accumulation (128x128, dense)");
    {
        let mut xb = Crossbar::zeros(128, 128);
        for r in 0..128 {
            for c in 0..128 {
                xb.set(r, c, rng.below(4) as u8);
            }
        }
        let bits: Vec<u8> = (0..128).map(|_| rng.below(2) as u8).collect();
        let mut out = vec![0u32; 128];
        let st = harness::bench("dense 128x128 bitline_currents", Duration::from_secs(2), || {
            xb.bitline_currents(&bits, &mut out);
            std::hint::black_box(&out);
        });
        harness::throughput("dense cell-ops", &st, (128 * 128) as f64, "cell-op");
    }

    harness::section("mapped-layer forward (784x300 MLP fc1)");
    {
        let w = Tensor::new(vec![784, 300], rng.normal_vec(784 * 300, 0.05))?;
        let layer = mapper::map_layer("fc1/w", &w)?;
        let code: Vec<u8> = (0..784).map(|_| rng.below(256) as u8).collect();
        let bits = [3u32, 3, 3, 1];
        let st = harness::bench("forward_codes one example", Duration::from_millis(1500), || {
            let _ = std::hint::black_box(sim::forward_codes(&layer, &code, &bits));
        });
        // 4 slices x 2 signs x 8 bit-planes x cells
        let cell_ops = (784 * 300 * 4 * 2 * 8) as f64;
        harness::throughput("forward_codes cell-ops", &st, cell_ops, "cell-op");

        let x = Tensor::new(
            vec![64, 784],
            (0..64 * 784).map(|_| rng.next_f32()).collect(),
        )?;
        let stb = harness::bench("forward batch=64 (parallel rows)", Duration::from_secs(3), || {
            let _ = std::hint::black_box(sim::forward(&layer, &x, &bits));
        });
        harness::throughput("batched cell-ops", &stb, cell_ops * 64.0, "cell-op");
        println!(
            "-> parallel speedup vs 64x single: {:.2}x",
            64.0 * st.mean.as_secs_f64() / stb.mean.as_secs_f64()
        );
    }

    harness::section("weight -> crossbar mapping");
    {
        let w = Tensor::new(vec![784, 300], rng.normal_vec(784 * 300, 0.05))?;
        harness::bench("map_layer 784x300 (all slices+signs)", Duration::from_secs(2), || {
            let _ = std::hint::black_box(mapper::map_layer("w", &w).unwrap());
        });
    }
    Ok(())
}

//! §Perf: sparsity-aware crossbar storage (Dense vs BitPlanes vs
//! Compressed tiles).
//!
//! Sweeps weight density on a 784x300 MLP layer from dense-random through
//! the mid band (25-60%, where the density-chosen mapping packs
//! bit-planes) down to Bl1-level bit-slice sparsity, maps each point
//! twice — once forced to row-major dense tiles, once with the
//! density-chosen (packed) formats — and times the batched simulator
//! forward on both. The layouts must agree bit-exactly (integer
//! accumulation commutes); the packed layout must be >= 2x faster once
//! the mean slice sparsity reaches 85% zeros (the mid-band popcount win
//! has its own bar in `runtime_hot_path` / `BENCH_bitplane.json`).
//! Results (per-density timings, speedups, tile-format census, storage
//! bytes) are written to `BENCH_sparse.json`.
//!
//! Run: `cargo bench --bench sparse_sim`

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use bitslice_reram::quant::N_SLICES;
use bitslice_reram::reram::crossbar::{Crossbar, StorageFormat};
use bitslice_reram::reram::{mapper, sim};
use bitslice_reram::tensor::Tensor;
use bitslice_reram::util::fixtures;
use bitslice_reram::util::json::{num, obj, Json};
use bitslice_reram::util::rng::Rng;

const LOSSLESS: [u32; N_SLICES] = [10, 10, 10, 10];
const ROWS: usize = 784;
const COLS: usize = 300;
const BATCH: usize = 32;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);
    let x = Tensor::new(
        vec![BATCH, ROWS],
        (0..BATCH * ROWS).map(|_| rng.next_f32()).collect(),
    )?;

    harness::section("single-tile bitline scan (128x128, 90% zeros)");
    {
        let mut xb = Crossbar::zeros(128, 128);
        for r in 0..128 {
            for c in 0..128 {
                if rng.below(10) == 0 {
                    xb.set(r, c, 1 + rng.below(3) as u8);
                }
            }
        }
        let comp = xb.in_format(StorageFormat::Compressed);
        let bits: Vec<u8> = (0..128).map(|_| rng.below(2) as u8).collect();
        let mut out = vec![0u32; 128];
        let sd = harness::bench("dense tile bitline_currents", Duration::from_millis(600), || {
            xb.bitline_currents(&bits, &mut out);
            std::hint::black_box(&out);
        });
        let mut out2 = vec![0u32; 128];
        let sc = harness::bench("compressed tile bitline_currents", Duration::from_millis(600), || {
            comp.bitline_currents(&bits, &mut out2);
            std::hint::black_box(&out2);
        });
        xb.bitline_currents(&bits, &mut out);
        comp.bitline_currents(&bits, &mut out2);
        assert_eq!(out, out2, "tile representations disagree");
        println!(
            "-> tile scan speedup at 90% zeros: {:.2}x ({} -> {} bytes)",
            sd.mean.as_secs_f64() / sc.mean.as_secs_f64(),
            xb.storage_bytes(),
            comp.storage_bytes(),
        );
    }

    harness::section("density sweep: packed (density-chosen) vs forced-dense forward");
    let mut rows_json: Vec<Json> = Vec::new();
    let mut sparse_point: Option<(f64, f64)> = None; // (zero_frac, speedup)
    for density in [1.0f64, 0.6, 0.5, 0.4, 0.3, 0.25, 0.10, 0.05, 0.02] {
        let w = fixtures::weights_at_density(&mut rng, ROWS, COLS, density);
        let packed = mapper::map_layer("w", &w)?;
        let dense = packed.with_storage(StorageFormat::Dense);

        // paper-style mean slice sparsity of the mapping
        let zero_frac = fixtures::mean_slice_zero_fraction(&packed);
        let stats = packed.storage_stats();

        let label_d = format!("dense  forward b={BATCH} d={density}");
        let sd = harness::bench(&label_d, Duration::from_millis(1200), || {
            let _ = std::hint::black_box(sim::forward(&dense, &x, &LOSSLESS));
        });
        let label_p = format!("packed forward b={BATCH} d={density}");
        let sp = harness::bench(&label_p, Duration::from_millis(1200), || {
            let _ = std::hint::black_box(sim::forward(&packed, &x, &LOSSLESS));
        });
        let speedup = sd.mean.as_secs_f64() / sp.mean.as_secs_f64();

        // the layouts must be a pure representation change: bit-exact
        let a = sim::forward(&dense, &x, &LOSSLESS);
        let b = sim::forward(&packed, &x, &LOSSLESS);
        assert_eq!(a.data(), b.data(), "layouts disagree at density {density}");

        println!(
            "-> density {density}: slice zeros {:.1}%, tiles {} dense / {} bit-plane / \
             {} compressed / {} skipped, bytes {} vs {} dense, speedup {speedup:.2}x",
            zero_frac * 100.0,
            stats.dense_tiles,
            stats.bitplane_tiles,
            stats.compressed_tiles,
            stats.skipped_tiles,
            stats.bytes,
            stats.dense_bytes,
        );
        if zero_frac >= 0.85 && sparse_point.is_none() {
            sparse_point = Some((zero_frac, speedup));
        }
        rows_json.push(obj(vec![
            ("weight_density", num(density)),
            ("slice_zero_fraction", num(zero_frac)),
            ("dense_tiles", num(stats.dense_tiles as f64)),
            ("bitplane_tiles", num(stats.bitplane_tiles as f64)),
            ("compressed_tiles", num(stats.compressed_tiles as f64)),
            ("skipped_tiles", num(stats.skipped_tiles as f64)),
            ("bytes", num(stats.bytes as f64)),
            ("dense_bytes", num(stats.dense_bytes as f64)),
            ("dense_ms", num(sd.mean_ms())),
            ("packed_ms", num(sp.mean_ms())),
            ("speedup", num(speedup)),
        ]));
    }

    // Acceptance bar: >= 2x over the dense baseline at Bl1-level slice
    // sparsity (>= 85% zeros), bit-exactness already asserted above.
    let (zero_frac, speedup) = sparse_point.expect("sweep reaches >= 85% slice zeros");
    assert!(
        speedup >= 2.0,
        "compressed path only {speedup:.2}x at {:.1}% slice zeros",
        zero_frac * 100.0
    );
    println!(
        "OK: {speedup:.2}x over dense forward at {:.1}% mean slice zeros",
        zero_frac * 100.0
    );

    let doc = obj(vec![
        ("layer", obj(vec![("rows", num(ROWS as f64)), ("cols", num(COLS as f64))])),
        ("batch", num(BATCH as f64)),
        ("bl1_level_speedup", num(speedup)),
        ("bl1_level_zero_fraction", num(zero_frac)),
        ("sweep", Json::Arr(rows_json)),
    ]);
    std::fs::write("BENCH_sparse.json", doc.to_string())?;
    println!("wrote BENCH_sparse.json");
    Ok(())
}

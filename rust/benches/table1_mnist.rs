//! Table 1 regeneration bench: the full Pruned / l1 / Bl1 pipeline on the
//! MNIST toy MLP, at bench-scale step counts.
//!
//! Prints the paper-format table from a short schedule (the full-scale run
//! is `cargo run --release -- reproduce table1`) plus end-to-end wall time
//! per method — the "regenerate the table" harness in bench form.
//!
//! Run: `cargo bench --bench table1_mnist`

use std::time::Instant;

use bitslice_reram::config::{Method, RunConfig};
use bitslice_reram::harness as hx;
use bitslice_reram::report;
use bitslice_reram::runtime::{Engine, Manifest};

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::defaults("mlp");
    cfg.steps = 120;
    cfg.pretrain_steps = 60;
    cfg.out_dir = std::path::PathBuf::from("/tmp/bench-table1");
    let manifest = match Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("SKIP: run `make artifacts` first");
            return Ok(());
        }
    };
    let engine = Engine::cpu()?;

    let mut rows = Vec::new();
    for method in [Method::Pruned, Method::L1, Method::Bl1] {
        let mut c = cfg.clone();
        c.method = method;
        let t0 = Instant::now();
        let res = hx::run_training(&engine, &manifest, c, false)?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:<8} {:>6.1}s wall, {:>6.1} ms/step, acc {:.2}%",
            method.name(),
            wall,
            res.outcome.mean_step_ms,
            res.eval.accuracy * 100.0
        );
        rows.push(res.method_row());
    }
    println!(
        "\n{}",
        report::sparsity_table("Table 1 (bench-scale schedule)", &rows)
    );
    let l1_avg = rows[1].stats.mean_std().0;
    let bl1_avg = rows[2].stats.mean_std().0;
    if bl1_avg > 0.0 {
        println!("Bl1 vs l1 average-sparsity improvement: {:.2}x", l1_avg / bl1_avg);
    }
    Ok(())
}

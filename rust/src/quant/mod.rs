//! Dynamic fixed-point quantization + bit-slicing — Rust mirror of the L1
//! Pallas kernels (paper Sec. 2.1/2.2).
//!
//! The coordinator re-implements Eq. 1–3 natively for everything that is
//! *not* on the training path: sparsity analysis of checkpoints (Tables
//! 1/2, Fig. 2), crossbar mapping, and the deployment cost model. The
//! integration tests cross-check this module bit-for-bit against the
//! `*_sparsity.hlo.txt` graphs, so the two implementations cannot drift.

use crate::tensor::Tensor;

/// Paper constants: 8-bit dynamic fixed point, 2-bit cells -> 4 slices.
pub const N_BITS: u32 = 8;
pub const SLICE_BITS: u32 = 2;
pub const N_SLICES: usize = (N_BITS / SLICE_BITS) as usize;
pub const SLICE_MAX: u8 = (1 << SLICE_BITS) - 1; // 3
pub const CODE_MAX: u32 = (1 << N_BITS) - 1; // 255

/// Guard for all-zero tensors (mirrors ref._EPS).
const EPS: f32 = 1.0 / (1 << 20) as f32;

/// Eq. 1: S(W) = ceil(log2(max |w|)), clamped for all-zero tensors.
pub fn dynamic_range(w: &[f32]) -> i32 {
    let m = w.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(EPS);
    m.log2().ceil() as i32
}

/// Qstep = 2^{S - n}.
pub fn qstep(w: &[f32]) -> f32 {
    ((dynamic_range(w) - N_BITS as i32) as f32).exp2()
}

/// Quantized view of one tensor: codes, signs, step.
#[derive(Debug, Clone)]
pub struct Quantized {
    /// B(w) in [0, 255], per element (row-major like the source tensor).
    pub codes: Vec<u8>,
    /// sign(w) in {-1, 0, +1}; zero-code elements keep sign 0.
    pub signs: Vec<i8>,
    /// Qstep = 2^{S-8}.
    pub step: f32,
    pub shape: Vec<usize>,
}

/// Eq. 2 over a whole tensor.
pub fn quantize(w: &Tensor) -> Quantized {
    let step = qstep(w.data());
    let inv = 1.0 / step;
    let mut codes = Vec::with_capacity(w.len());
    let mut signs = Vec::with_capacity(w.len());
    for &v in w.data() {
        let code = ((v.abs() * inv).floor()).min(CODE_MAX as f32) as u32 as u8;
        codes.push(code);
        signs.push(if code == 0 || v == 0.0 {
            0
        } else if v > 0.0 {
            1
        } else {
            -1
        });
    }
    Quantized {
        codes,
        signs,
        step,
        shape: w.shape().to_vec(),
    }
}

impl Quantized {
    /// Q(w) = sign * B * Qstep — the recovered weight tensor.
    pub fn recover(&self) -> Tensor {
        let data = self
            .codes
            .iter()
            .zip(&self.signs)
            .map(|(&c, &s)| s as f32 * c as f32 * self.step)
            .collect();
        Tensor::new(self.shape.clone(), data).expect("shape preserved")
    }

    /// Extract slice k (LSB-first): (code >> 2k) & 3.
    pub fn slice(&self, k: usize) -> Vec<u8> {
        debug_assert!(k < N_SLICES);
        self.codes
            .iter()
            .map(|&c| ((c as u32 >> (SLICE_BITS * k as u32)) & SLICE_MAX as u32) as u8)
            .collect()
    }

    /// Per-slice non-zero counts (LSB-first) — one pass over the codes.
    pub fn slice_nonzero_counts(&self) -> [usize; N_SLICES] {
        let mut counts = [0usize; N_SLICES];
        for &c in &self.codes {
            let c = c as u32;
            for (k, cnt) in counts.iter_mut().enumerate() {
                if (c >> (SLICE_BITS * k as u32)) & SLICE_MAX as u32 != 0 {
                    *cnt += 1;
                }
            }
        }
        counts
    }

    /// Eq. 3: the bit-slice l1 value (digit sum over all slices).
    pub fn bl1(&self) -> u64 {
        self.codes
            .iter()
            .map(|&c| {
                (0..N_SLICES)
                    .map(|k| ((c as u32 >> (SLICE_BITS * k as u32)) & SLICE_MAX as u32) as u64)
                    .sum::<u64>()
            })
            .sum()
    }

    pub fn numel(&self) -> usize {
        self.codes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, ensure, ensure_close};

    fn t(data: Vec<f32>) -> Tensor {
        let n = data.len();
        Tensor::new(vec![n], data).unwrap()
    }

    #[test]
    fn dynamic_range_matches_paper_eq1() {
        assert_eq!(dynamic_range(&[0.7]), 0); // ceil(log2 0.7) = 0
        assert_eq!(dynamic_range(&[1.0]), 0);
        assert_eq!(dynamic_range(&[1.1]), 1);
        assert_eq!(dynamic_range(&[0.25]), -2);
        assert_eq!(dynamic_range(&[-3.0, 0.5]), 2);
    }

    #[test]
    fn all_zero_tensor_is_safe() {
        let q = quantize(&t(vec![0.0; 10]));
        assert!(q.step > 0.0);
        assert!(q.codes.iter().all(|&c| c == 0));
        assert_eq!(q.bl1(), 0);
    }

    #[test]
    fn codes_bounded_and_recover_close() {
        check(50, |rng| {
            let n = 1 + rng.below(500);
            let scale = [1e-3f32, 0.1, 1.0, 40.0][rng.below(4)];
            let data = rng.normal_vec(n, scale);
            let w = t(data.clone());
            let q = quantize(&w);
            ensure(q.codes.iter().all(|&c| c as u32 <= CODE_MAX), "code range")?;
            let rec = q.recover();
            for (a, b) in data.iter().zip(rec.data()) {
                // floor quantization: |w - Q(w)| < step, sign preserved
                ensure((a - b).abs() < q.step, format!("err {} vs {}", a, b))?;
                ensure(
                    b.abs() <= a.abs() + 1e-7,
                    "magnitude never grows under floor",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn slices_recombine_to_code() {
        check(50, |rng| {
            let n = 1 + rng.below(300);
            let w = t(rng.normal_vec(n, 0.3));
            let q = quantize(&w);
            for i in 0..n {
                let mut acc = 0u32;
                for k in 0..N_SLICES {
                    acc += (q.slice(k)[i] as u32) << (SLICE_BITS * k as u32);
                }
                ensure(acc == q.codes[i] as u32, format!("recombine at {i}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn bl1_equals_slice_sums() {
        check(30, |rng| {
            let n = 1 + rng.below(300);
            let w = t(rng.normal_vec(n, 0.5));
            let q = quantize(&w);
            let by_slices: u64 = (0..N_SLICES)
                .map(|k| q.slice(k).iter().map(|&v| v as u64).sum::<u64>())
                .sum();
            ensure(q.bl1() == by_slices, "bl1 == sum of slices")?;
            Ok(())
        });
    }

    #[test]
    fn nonzero_counts_match_slices() {
        check(30, |rng| {
            let n = 1 + rng.below(300);
            let w = t(rng.normal_vec(n, 0.5));
            let q = quantize(&w);
            let counts = q.slice_nonzero_counts();
            for k in 0..N_SLICES {
                let direct = q.slice(k).iter().filter(|&&v| v != 0).count();
                ensure(counts[k] == direct, format!("slice {k}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn known_example_228() {
        // code 228 = 0b11100100 -> slices LSB-first 0,1,2,3
        // build a tensor whose max is exactly 1.0 => step 2^-8, w = 228/256
        let w = t(vec![228.0 / 256.0, 1.0]);
        let q = quantize(&w);
        assert_eq!(q.step, 2.0f32.powi(-8));
        assert_eq!(q.codes[0], 228);
        assert_eq!(
            (0..4).map(|k| q.slice(k)[0]).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
    }

    #[test]
    fn step_scales_with_dynamic_range() {
        let q1 = quantize(&t(vec![0.9]));
        let q2 = quantize(&t(vec![3.6]));
        ensure_close(q2.step / q1.step, 4.0, 1e-6, "step ratio").unwrap();
    }
}

//! High-level experiment harness: one call per paper artifact.
//!
//! The CLI (`main.rs`), the examples and the benches all drive experiments
//! through these functions so "reproduce Table 1" means the same thing
//! everywhere.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::config::{Method, RunConfig};
use crate::coordinator::evaluator::{self, EvalResult};
use crate::coordinator::metrics::MetricsLog;
use crate::coordinator::{checkpoint, TrainOutcome, Trainer};
use crate::data::Dataset;
use crate::report::{MethodRow, NoiseRow, PlanRow, StorageRow};
use crate::reram::device::{DeviceConfig, DeviceModel};
use crate::reram::planner::{self, DeploymentPlan};
use crate::reram::reorder::{self, ReorderConfig, ReorderRow};
use crate::reram::timing::{self, PipelineTiming};
use crate::reram::{audit, energy, mapper, resolution, ResolutionPolicy};
use crate::runtime::{Engine, Manifest};
use crate::sparsity::{self, SliceStats, TracePoint};
use crate::util::pool::{parallel_map, worker_threads};

/// Everything a single training run produces.
pub struct RunResult {
    pub cfg: RunConfig,
    pub outcome: TrainOutcome,
    pub eval: EvalResult,
    pub stats: SliceStats,
    pub trace: Vec<TracePoint>,
    pub dataset_source: String,
    pub checkpoint_dir: Option<PathBuf>,
}

impl std::fmt::Debug for RunResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RunResult")
            .field("model", &self.cfg.model)
            .field("method", &self.cfg.method.name())
            .field("steps_run", &self.outcome.steps_run)
            .field("accuracy", &self.eval.accuracy)
            .field("dataset_source", &self.dataset_source)
            .field("checkpoint_dir", &self.checkpoint_dir)
            .finish_non_exhaustive()
    }
}

impl RunResult {
    pub fn method_row(&self) -> MethodRow {
        MethodRow {
            method: self.cfg.method.name().to_string(),
            accuracy: self.eval.accuracy,
            stats: self.stats.clone(),
        }
    }
}

/// Train one (model, method) pair end to end: data -> phases -> eval ->
/// sparsity census -> optional checkpoint under `<out>/<model>-<method>/`.
pub fn run_training(
    engine: &Engine,
    manifest: &Manifest,
    cfg: RunConfig,
    save_checkpoint: bool,
) -> Result<RunResult> {
    let train_ds = Dataset::auto(
        &cfg.dataset,
        &cfg.data_dir,
        true,
        cfg.train_examples,
        cfg.seed,
    )?;
    let test_ds = Dataset::auto(
        &cfg.dataset,
        &cfg.data_dir,
        false,
        cfg.test_examples,
        cfg.seed.wrapping_add(1),
    )?;
    eprintln!(
        "[{}] training on {} ({} examples), {} total steps",
        cfg.label(),
        train_ds.source,
        train_ds.len(),
        crate::coordinator::PhasePlan::for_config(&cfg).total_steps()
    );

    let run_dir = cfg.out_dir.join(cfg.label());
    let mut log = MetricsLog::create(Some(&run_dir))?;
    let mut trainer = Trainer::new(engine, manifest, cfg.clone())?;
    let outcome = trainer.run(&train_ds, &mut log)?;
    log.flush()?;

    // BN re-estimation before eval (no-op for BN-free models): short
    // schedules leave running stats stale (see evaluator::bn_calibrate).
    evaluator::bn_calibrate(
        engine,
        manifest,
        &cfg.model,
        &mut trainer.state,
        &train_ds,
        40,
        cfg.seed ^ 0xCA11B,
    )?;
    let eval = evaluator::evaluate(engine, manifest, &cfg.model, &trainer.state, &test_ds)?;
    let stats = sparsity::census(&trainer.state.qws);

    let checkpoint_dir = if save_checkpoint {
        let dir = run_dir.join("checkpoint");
        checkpoint::save(
            &dir,
            &trainer.state,
            &checkpoint::Meta {
                model: cfg.model.clone(),
                method: cfg.method.name().to_string(),
                step: outcome.steps_run,
                dataset_source: train_ds.source.clone(),
            },
        )?;
        Some(dir)
    } else {
        None
    };
    if !log.trace.is_empty() {
        log.write_trace_csv(&run_dir.join("trace.csv"))?;
    }

    eprintln!(
        "[{}] done: loss {:.4}, test acc {:.2}% ({} ex), mean step {:.1} ms",
        cfg.label(),
        outcome.final_loss,
        eval.accuracy * 100.0,
        eval.examples,
        outcome.mean_step_ms
    );

    Ok(RunResult {
        cfg,
        outcome,
        eval,
        stats,
        trace: log.trace.clone(),
        dataset_source: train_ds.source,
        checkpoint_dir,
    })
}

/// Table 1 / Table 2 rows: run Pruned, l1 and Bl1 on one model.
pub fn reproduce_sparsity_table(
    engine: &Engine,
    manifest: &Manifest,
    base_cfg: &RunConfig,
) -> Result<Vec<RunResult>> {
    let mut results = Vec::new();
    for method in [Method::Pruned, Method::L1, Method::Bl1] {
        let mut cfg = base_cfg.clone();
        cfg.method = method;
        results.push(run_training(engine, manifest, cfg, true)?);
    }
    Ok(results)
}

/// Figure 2: l1-vs-Bl1 sparsity traces on one model.
pub fn reproduce_fig2(
    engine: &Engine,
    manifest: &Manifest,
    base_cfg: &RunConfig,
) -> Result<Vec<(String, Vec<TracePoint>)>> {
    let mut traces = Vec::new();
    for method in [Method::L1, Method::Bl1] {
        let mut cfg = base_cfg.clone();
        cfg.method = method;
        if cfg.trace_every == 0 {
            cfg.trace_every = (cfg.steps / 40).max(1);
        }
        let res = run_training(engine, manifest, cfg, false)?;
        traces.push((method.name().to_string(), res.trace));
    }
    Ok(traces)
}

/// Deployment report for a trained state: crossbar mapping, measured ADC
/// requirements (whole-model and per-layer), Table-3 savings.
pub struct DeployReport {
    /// the crossbar mapping every other field of this report describes
    /// (the reordered one when `reorder` is `Some`) — deploy it via
    /// `serve::CrossbarBackend::from_mapping` instead of re-mapping the
    /// stack
    pub mapped: mapper::MappedModel,
    /// fabricated crossbars (programmed tiles only — matches the billing
    /// in `energy::deployment_cost` and the plan rows below)
    pub crossbars: usize,
    /// fully-zero tiles the mapper laid out but no deployment fabricates
    pub unprogrammed_tiles: usize,
    /// lossless per-slice bits (LSB-first, whole-model census)
    pub lossless_bits: [u32; 4],
    /// percentile-policy bits actually deployed (LSB-first, whole-model)
    pub deployed_bits: [u32; 4],
    pub rows: Vec<energy::AdcSavingRow>,
    /// whole-model savings (energy, time, area) vs the 8-bit baseline
    pub savings: (f64, f64, f64),
    /// per-layer plan: `policy` applied to each layer's own census
    pub plan: DeploymentPlan,
    /// per-layer savings rows of `plan` (the `PlanRow` report)
    pub plan_rows: Vec<PlanRow>,
    /// savings of `plan` vs the 8-bit baseline
    pub plan_savings: (f64, f64, f64),
    /// per-layer tile storage census (dense vs compressed vs skipped —
    /// the `report::storage_table` body)
    pub storage: Vec<StorageRow>,
    /// per-layer reorder effect (reordered vs natural-order census) when
    /// the deployment mapped with `--reorder`; `None` otherwise. When
    /// present, every other field of this report describes the
    /// *reordered* mapping.
    pub reorder: Option<Vec<ReorderRow>>,
    /// pipeline timing of `plan` (replica counts applied when a
    /// replication budget was given) — the `report::timing_table` body
    pub timing: PipelineTiming,
    /// fabricated cells spent on extra replicas (0 without a budget)
    pub replica_cells: usize,
    /// static audit of the final (mapped, plan) deployment — every report
    /// built here ran on a verified artifact, and `audit.errors == 0` is
    /// guaranteed (a faulty artifact makes `deploy_report` fail instead)
    pub audit: audit::AuditReport,
}

impl std::fmt::Debug for DeployReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeployReport")
            .field("crossbars", &self.crossbars)
            .field("unprogrammed_tiles", &self.unprogrammed_tiles)
            .field("lossless_bits", &self.lossless_bits)
            .field("deployed_bits", &self.deployed_bits)
            .field("reordered", &self.reorder.is_some())
            .field("replica_cells", &self.replica_cells)
            .field("audit", &self.audit.summary)
            .finish_non_exhaustive()
    }
}

/// Build the deployment report for a set of quantized weights.
/// `replicate_budget` water-fills extra crossbar replicas onto the
/// pipeline's bottleneck layers ([`timing::fill_replicas`]); its unit is
/// multiples of the **bottleneck layer's** fabricated cells, so `2.0`
/// buys about two extra copies of the slowest layer.
pub fn deploy_report(
    named_qws: &[(String, crate::tensor::Tensor)],
    policy: ResolutionPolicy,
    reorder_cfg: Option<ReorderConfig>,
    replicate_budget: Option<f64>,
) -> Result<DeployReport> {
    let natural = mapper::map_model(named_qws)?;
    let (mapped, reorder) = match reorder_cfg {
        // report reorder rows only when the pass actually carries
        // permutations — on an already-clustered or fully dense stack it
        // normalizes to the identity on every layer, and claiming a
        // reordered deployment there would contradict the mapping itself
        Some(cfg) => {
            let reordered = mapper::map_model_with(named_qws, Some(cfg))?;
            if reordered.is_reordered() {
                let rows = reorder::reorder_rows(&natural, &reordered);
                (reordered, Some(rows))
            } else {
                (natural, None)
            }
        }
        None => (natural, None),
    };
    let lossless_bits = resolution::required_bits(&mapped, ResolutionPolicy::Lossless);
    let deployed_bits = resolution::required_bits(&mapped, policy);
    let rows = (0..4)
        .rev()
        .map(|k| energy::saving_row(k, deployed_bits[k]))
        .collect();
    let savings = energy::savings_vs_baseline(&mapped, deployed_bits);
    let mut plan = DeploymentPlan::from_policy(&mapped, policy);
    let budget_cells =
        timing::factor_budget_cells(&mapped, &plan, replicate_budget.unwrap_or(0.0));
    let replica_cells = timing::fill_replicas(&mapped, &mut plan, budget_cells);
    // a positive budget that buys zero replicas is a config error (the
    // budget is below one copy of the bottleneck layer) — fail loudly
    // instead of shipping a silently unreplicated plan
    if let Some(factor) = replicate_budget {
        if let Some(d) = audit::replica_budget_diagnostic(&mapped, &plan, factor, replica_cells) {
            anyhow::bail!(
                "{d}\nhint: --replicate-budget is in multiples of the bottleneck layer's \
                 fabricated cells; give at least 1.0 to buy one extra copy, or drop the flag"
            );
        }
    }
    let audit = audit::audit_deployment(&mapped, &plan);
    anyhow::ensure!(
        audit.summary.errors == 0,
        "deployment artifact failed its static audit — {audit}"
    );
    let timing = timing::plan_timing(&mapped, &plan);
    let plan_rows = energy::layer_costs(&mapped, &plan);
    let plan_savings = energy::plan_savings_vs_baseline(&mapped, &plan);
    let cost = energy::plan_cost(&mapped, &plan);
    let storage = mapped.storage_rows();
    Ok(DeployReport {
        mapped,
        crossbars: cost.crossbars,
        unprogrammed_tiles: cost.skipped_tiles,
        lossless_bits,
        deployed_bits,
        rows,
        savings,
        plan,
        plan_rows,
        plan_savings,
        storage,
        reorder,
        timing,
        replica_cells,
        audit,
    })
}

/// Planner-search deployment report: the searched per-layer plan plus the
/// savings rows and pipeline timing the deploy CLI prints for
/// `--plan-budget`. Replicas are already part of `search.plan` when the
/// config granted a replica budget (the joint pass spends it inside the
/// search), so the rows and timing here price exactly what would be
/// fabricated.
pub struct PlanSearchReport {
    /// the search outcome: selected plan, accuracies, costs, the
    /// [`planner::SearchStats`] instrumentation and the replica spend
    pub search: planner::PlanSearch,
    /// per-layer savings rows of the selected plan (replicas included)
    pub plan_rows: Vec<PlanRow>,
    /// pipeline timing of the selected plan
    pub timing: PipelineTiming,
}

impl std::fmt::Debug for PlanSearchReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanSearchReport")
            .field("plan", &self.search.plan.to_string())
            .field("accuracy", &self.search.accuracy)
            .field("stats", &self.search.stats)
            .field("replica_cells", &self.search.replica_cells)
            .finish_non_exhaustive()
    }
}

/// Run the budgeted planner search on an already-mapped backend and roll
/// its outcome up into report form — the `--plan-budget` half of the
/// deploy CLI, shared with the planner bench. Pass
/// `cfg.replicate_budget` to co-optimize ADC bits and pipeline replicas
/// under one cell budget instead of filling replicas after the search.
pub fn plan_search_report(
    base: &crate::serve::CrossbarBackend,
    reference: &crate::serve::ReferenceBackend,
    holdout: &Dataset,
    cfg: &planner::PlannerConfig,
) -> Result<PlanSearchReport> {
    let search = planner::plan_deployment_from(base, reference, holdout, cfg)?;
    let mapped = base.mapped();
    let plan_rows = energy::layer_costs(mapped, &search.plan);
    let timing = timing::plan_timing(mapped, &search.plan);
    Ok(PlanSearchReport {
        search,
        plan_rows,
        timing,
    })
}

/// Monte-Carlo robustness of one deployment: attach `trials` seeded
/// realizations of `config` to `backend`
/// ([`crate::serve::CrossbarBackend::with_device`]), score each on `ds`,
/// and roll up mean/worst accuracy plus the per-layer slice-group
/// variance of the sampled conductances. Fully deterministic: same
/// backend, dataset, config and trial count always reproduce the same
/// row, trial for trial — each trial's realization is seeded by its own
/// index, so scoring them in parallel on the executor
/// ([`crate::util::pool::parallel_map`], which returns results in trial
/// order) changes nothing about the numbers.
pub fn noise_report(
    backend: &crate::serve::CrossbarBackend,
    ds: &Dataset,
    config: DeviceConfig,
    trials: usize,
) -> Result<NoiseRow> {
    anyhow::ensure!(trials >= 1, "noise report needs at least one trial");
    let ideal_accuracy = crate::serve::accuracy(backend, ds)?.accuracy;
    let trial_results = parallel_map(trials, worker_threads(), |i| {
        let dm = DeviceModel::for_model(backend.mapped(), config.trial(i));
        // the variance roll-up is trial-0's realization, as before
        let variance = (i == 0).then(|| {
            backend
                .mapped()
                .layers
                .iter()
                .zip(dm.layer_variances())
                .map(|(l, v)| (l.name.clone(), v))
                .collect::<Vec<_>>()
        });
        let noisy = backend.with_device(&format!("mc-trial-{i}"), Arc::new(dm))?;
        let accuracy = crate::serve::accuracy(&noisy, ds)?.accuracy;
        Ok::<_, anyhow::Error>((accuracy, variance))
    });
    let mut trial_accuracies = Vec::with_capacity(trials);
    let mut layer_variance = Vec::new();
    for result in trial_results {
        let (accuracy, variance) = result?;
        if let Some(v) = variance {
            layer_variance = v;
        }
        trial_accuracies.push(accuracy);
    }
    let mean_accuracy = trial_accuracies.iter().sum::<f64>() / trials as f64;
    let worst_accuracy = trial_accuracies.iter().copied().fold(f64::INFINITY, f64::min);
    Ok(NoiseRow {
        config,
        ideal_accuracy,
        trial_accuracies,
        mean_accuracy,
        worst_accuracy,
        layer_variance,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::ResolutionPolicy;
    use crate::serve::CrossbarBackend;
    use crate::util::fixtures;
    use crate::util::rng::Rng;

    /// The Monte-Carlo accuracy study is a pure function of (backend,
    /// dataset, config, trials): two runs reproduce every trial accuracy
    /// and the layer variance roll-up bit for bit.
    #[test]
    fn noise_report_is_reproducible_across_runs() {
        let stack = fixtures::sparse_stack(9, &[24, 16, 6], 0.5);
        let backend = CrossbarBackend::new("mc", &stack, ResolutionPolicy::Lossless).unwrap();
        let n = 40usize;
        let mut rng = Rng::new(123);
        let ds = Dataset {
            features: Arc::new((0..n * 24).map(|_| rng.next_f32()).collect()),
            labels: Arc::new((0..n).map(|i| (i % 6) as i32).collect()),
            example_shape: vec![24],
            num_classes: 6,
            source: "mc-repro".into(),
        };
        let config = DeviceConfig {
            sigma: 0.25,
            read_sigma: 1.0,
            fault_rate: 0.02,
            seed: 0xAB,
        };
        let a = noise_report(&backend, &ds, config, 4).unwrap();
        let b = noise_report(&backend, &ds, config, 4).unwrap();
        assert_eq!(a.trial_accuracies, b.trial_accuracies);
        assert_eq!(a.ideal_accuracy, b.ideal_accuracy);
        assert_eq!(a.mean_accuracy, b.mean_accuracy);
        assert_eq!(a.worst_accuracy, b.worst_accuracy);
        assert_eq!(a.layer_variance, b.layer_variance);
        assert_eq!(a.trial_accuracies.len(), 4);
        // distinct trial seeds: the model sampled for trial 0 is not the
        // model sampled for trial 1
        assert_ne!(config.trial(0).seed, config.trial(1).seed);
    }
}

//! Unified inference serving: one seam over every forward path.
//!
//! The repo has three ways to run a deployed model — the AOT XLA graphs
//! ([`xla::XlaBackend`]), the Rust crossbar simulator
//! ([`crossbar::CrossbarBackend`]) and the exact quantized matmul
//! reference ([`reference::ReferenceBackend`]). Before this module each
//! caller (evaluator, examples, benches, tests) carried its own batching,
//! padding and dispatch loop; now they all speak [`InferenceBackend`], and
//! the batched request path is [`engine::ServingEngine`].
//!
//! # Backend contract (shapes and padding)
//!
//! * `infer_batch(x)` takes a tensor whose **leading axis is the batch**;
//!   the remaining axes flatten row-major to the backend's
//!   [`BackendInfo::input_dim`] features per example. It returns logits of
//!   shape `(batch, num_classes)` with the same leading order.
//! * Any batch size `>= 1` is accepted. Backends with a graph-fixed
//!   [`BackendInfo::native_batch`] split the input into native-size chunks
//!   and **zero-pad** the final chunk internally; pad rows never leak into
//!   the returned logits. (This absorbs the fixed-shape wrap-fill logic
//!   that used to live in `coordinator/evaluator.rs`.)
//! * `eval_batch(x, y)` returns the number of correct predictions among
//!   rows whose label is `>= 0`; rows labelled `-1` are padding and can
//!   never count. The default implementation is `infer_batch` + host-side
//!   argmax; the XLA eval-graph backend overrides it because its graph
//!   emits a `correct` count instead of logits (its
//!   [`BackendInfo::logits`] is `false`).
//! * Host backends quantize activations **per example row**, so results
//!   are invariant under batch composition: `infer_batch` over a
//!   concatenation equals the concatenation of per-row calls bit-for-bit.
//!   The serving engine's dynamic batching relies on this.

pub mod crossbar;
pub mod engine;
pub mod evalcache;
pub mod reference;
pub mod xla;

use anyhow::Result;

use crate::data::Dataset;
use crate::tensor::Tensor;
use crate::util::pool::{parallel_map, with_scratch};

pub use self::crossbar::CrossbarBackend;
pub use self::evalcache::EvalCache;
pub use self::engine::{PendingInference, ServeOptions, ServingEngine, ServingStats, SloPolicy};
pub use self::reference::ReferenceBackend;
pub use self::xla::XlaBackend;

/// Capability metadata a backend reports about itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendInfo {
    /// flattened features per example
    pub input_dim: usize,
    /// logits per example
    pub num_classes: usize,
    /// graph-fixed batch the backend pads/splits to internally; `None`
    /// means any batch size runs natively
    pub native_batch: Option<usize>,
    /// whether `infer_batch` (logits) is available; `false` for
    /// eval-graph-only backends that can only count correct predictions
    pub logits: bool,
}

/// One forward path a deployed model can run on.
pub trait InferenceBackend {
    /// Short identity for reports, e.g. `"xla:mlp/eval"` or
    /// `"crossbar@p99.9"`.
    fn name(&self) -> &str;

    /// Shape/capability metadata (see the module doc for the contract).
    fn info(&self) -> BackendInfo;

    /// Run a batch: `(b, ...) -> (b, num_classes)` logits.
    fn infer_batch(&self, x: &Tensor) -> Result<Tensor>;

    /// Count correct predictions for a labelled batch (`y[i] == -1` marks
    /// padding rows that never count).
    fn eval_batch(&self, x: &Tensor, y: &[i32]) -> Result<f64> {
        let logits = self.infer_batch(x)?;
        Ok(correct_by_argmax(&logits, y, self.info().num_classes))
    }
}

/// A backend shared across serving-engine worker threads.
pub type SharedBackend = std::sync::Arc<dyn InferenceBackend + Send + Sync>;

/// The one argmax used for every accuracy count: greatest logit wins,
/// the **last** maximum on exact ties (`max_by` semantics). Shared by
/// [`correct_by_argmax`] and the evaluation cache so cached and
/// from-scratch scoring can never disagree on a tie.
pub(crate) fn argmax_row(r: &[f32]) -> usize {
    (0..r.len())
        .max_by(|&a, &b| r[a].partial_cmp(&r[b]).unwrap_or(std::cmp::Ordering::Equal))
        .unwrap_or(0)
}

/// Host-side argmax accuracy count (the default `eval_batch` body).
pub fn correct_by_argmax(logits: &Tensor, y: &[i32], num_classes: usize) -> f64 {
    let mut correct = 0.0;
    for (row, &label) in y.iter().enumerate() {
        if label < 0 {
            continue;
        }
        let r = &logits.data()[row * num_classes..(row + 1) * num_classes];
        if argmax_row(r) as i32 == label {
            correct += 1.0;
        }
    }
    correct
}

/// One dense (fully-connected) layer of the host backends' stack.
#[derive(Debug, Clone)]
pub struct DenseLayer {
    pub name: String,
    /// rank-2 weight matrix (fan-in x fan-out)
    pub w: Tensor,
    /// per-output bias (length = fan-out)
    pub bias: Option<Tensor>,
    pub relu: bool,
}

/// Pair a model's quantized-weight matrices with their biases into the
/// dense stack the host backends run: ReLU between layers, none after the
/// last. Only MLP-shaped models qualify (rank-2 weights, one bias each).
pub fn dense_stack(weights: &[(String, Tensor)], biases: &[Tensor]) -> Result<Vec<DenseLayer>> {
    anyhow::ensure!(!weights.is_empty(), "empty weight stack");
    anyhow::ensure!(
        weights.len() == biases.len(),
        "dense stack wants one bias per weight matrix ({} weights, {} biases) \
         — the host backends serve MLP-shaped models only",
        weights.len(),
        biases.len()
    );
    let n = weights.len();
    let mut layers = Vec::with_capacity(n);
    for (i, ((name, w), b)) in weights.iter().zip(biases).enumerate() {
        anyhow::ensure!(
            w.shape().len() == 2,
            "layer {name:?} has rank {} weights; dense stacks are rank-2",
            w.shape().len()
        );
        let cols = w.shape()[1];
        anyhow::ensure!(
            b.len() == cols,
            "layer {name:?}: bias length {} != fan-out {cols}",
            b.len()
        );
        if i > 0 {
            anyhow::ensure!(
                weights[i - 1].1.shape()[1] == w.shape()[0],
                "layer {name:?}: fan-in {} does not chain from previous fan-out {}",
                w.shape()[0],
                weights[i - 1].1.shape()[1]
            );
        }
        layers.push(DenseLayer {
            name: name.clone(),
            w: w.clone(),
            bias: Some(b.clone()),
            relu: i + 1 < n,
        });
    }
    Ok(layers)
}

/// Accuracy of a backend over a whole dataset.
#[derive(Debug, Clone, Copy)]
pub struct AccuracyReport {
    pub accuracy: f64,
    pub examples: usize,
}

/// Evaluate a backend over `ds`: sequential batches sized to the backend's
/// native batch (or a default for flexible backends). The final batch is
/// simply short — padding to a graph's fixed shape is the backend's job
/// (the single padding implementation, per the module contract). This is
/// the one evaluation driver behind the CLI, the examples and the benches.
pub fn accuracy(backend: &dyn InferenceBackend, ds: &Dataset) -> Result<AccuracyReport> {
    let batch = backend
        .info()
        .native_batch
        .unwrap_or_else(|| ds.len().clamp(1, 256));
    let dim = ds.dim();
    let mut correct = 0.0f64;
    let mut pos = 0usize;
    while pos < ds.len() {
        let b = (ds.len() - pos).min(batch);
        let mut x = vec![0.0f32; b * dim];
        for r in 0..b {
            ds.write_example(pos + r, &mut x[r * dim..(r + 1) * dim]);
        }
        let mut shape = vec![b];
        shape.extend_from_slice(&ds.example_shape);
        let xt = Tensor::new(shape, x)?;
        correct += backend.eval_batch(&xt, &ds.labels[pos..pos + b])?;
        pos += b;
    }
    Ok(AccuracyReport {
        accuracy: if pos == 0 { 0.0 } else { correct / pos as f64 },
        examples: pos,
    })
}

/// Shared per-row batch driver for the host backends: validates the batch
/// shape, splits rows into per-thread chunks, and reassembles
/// `(b, out_dim)` logits. Each chunk borrows its scratch state `S` from
/// the running thread's persistent slot
/// ([`crate::util::pool::with_scratch`]): on the long-lived executor
/// workers and serving-engine threads the wave-pack buffers of one batch
/// are reused by the next instead of reallocated per call. `threads = 1`
/// runs inline with no task submission — the right setting when a
/// `ServingEngine` worker pool already provides the parallelism.
pub(crate) fn rows_parallel<S, F>(
    name: &str,
    x: &Tensor,
    input_dim: usize,
    out_dim: usize,
    threads: usize,
    per_row: F,
) -> Result<Tensor>
where
    S: Default + 'static,
    F: Fn(&mut S, &[f32]) -> Vec<f32> + Sync,
{
    let shape = x.shape();
    anyhow::ensure!(!shape.is_empty(), "batch tensor wants a leading axis");
    let b = shape[0];
    let dim: usize = shape[1..].iter().product();
    anyhow::ensure!(
        dim == input_dim,
        "{name}: example dim {dim} != expected {input_dim}"
    );
    let data = x.data();
    let run_chunk = |lo: usize, hi: usize| -> Vec<f32> {
        with_scratch::<S, _>(|state| {
            let mut part = Vec::with_capacity((hi - lo) * out_dim);
            for i in lo..hi {
                part.extend(per_row(state, &data[i * dim..(i + 1) * dim]));
            }
            part
        })
    };
    let threads = threads.clamp(1, b.max(1));
    let out = if threads == 1 {
        run_chunk(0, b)
    } else {
        let chunk = b.div_ceil(threads);
        let parts = parallel_map(b.div_ceil(chunk), threads, |ci| {
            run_chunk(ci * chunk, ((ci + 1) * chunk).min(b))
        });
        let mut out = Vec::with_capacity(b * out_dim);
        for p in parts {
            out.extend(p);
        }
        out
    };
    Tensor::new(vec![b, out_dim], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    /// Test backend: predicts `floor(sum(features)) mod classes`.
    struct StubBackend {
        dim: usize,
        classes: usize,
    }

    impl InferenceBackend for StubBackend {
        fn name(&self) -> &str {
            "stub"
        }
        fn info(&self) -> BackendInfo {
            BackendInfo {
                input_dim: self.dim,
                num_classes: self.classes,
                native_batch: None,
                logits: true,
            }
        }
        fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
            let b = x.shape()[0];
            let mut out = vec![0.0f32; b * self.classes];
            for i in 0..b {
                let s: f32 = x.data()[i * self.dim..(i + 1) * self.dim].iter().sum();
                let cls = (s.abs().floor() as usize) % self.classes;
                out[i * self.classes + cls] = 1.0;
            }
            Tensor::new(vec![b, self.classes], out)
        }
    }

    #[test]
    fn correct_by_argmax_skips_padding_labels() {
        let logits = Tensor::new(vec![3, 2], vec![0.1, 0.9, 0.8, 0.2, 0.3, 0.7]).unwrap();
        // row0 -> 1, row1 -> 0, row2 -> 1 (but padded out)
        assert_eq!(correct_by_argmax(&logits, &[1, 0, -1], 2), 2.0);
        assert_eq!(correct_by_argmax(&logits, &[0, 0, 1], 2), 2.0);
    }

    #[test]
    fn default_eval_batch_matches_manual_argmax() {
        let be = StubBackend { dim: 4, classes: 3 };
        let x = Tensor::new(vec![2, 4], vec![0.6, 0.6, 0.0, 0.0, 1.2, 1.0, 0.0, 0.0]).unwrap();
        // sums 1.2 -> class 1, 2.2 -> class 2
        assert_eq!(be.eval_batch(&x, &[1, 2]).unwrap(), 2.0);
        assert_eq!(be.eval_batch(&x, &[1, -1]).unwrap(), 1.0);
    }

    #[test]
    fn accuracy_counts_only_real_examples() {
        let ds = synthetic::mnist(50, 3);
        let be = StubBackend {
            dim: 784,
            classes: 10,
        };
        let rep = accuracy(&be, &ds).unwrap();
        assert_eq!(rep.examples, 50);
        assert!((0.0..=1.0).contains(&rep.accuracy));
        // deterministic backend + dataset -> deterministic accuracy
        let rep2 = accuracy(&be, &ds).unwrap();
        assert_eq!(rep.accuracy, rep2.accuracy);
    }

    #[test]
    fn dense_stack_validates_shapes() {
        let w1 = Tensor::zeros(vec![8, 5]);
        let w2 = Tensor::zeros(vec![5, 3]);
        let b1 = Tensor::zeros(vec![5]);
        let b2 = Tensor::zeros(vec![3]);
        let stack = dense_stack(
            &[("fc1/w".into(), w1.clone()), ("fc2/w".into(), w2.clone())],
            &[b1.clone(), b2.clone()],
        )
        .unwrap();
        assert_eq!(stack.len(), 2);
        assert!(stack[0].relu && !stack[1].relu);

        // bias length mismatch
        assert!(dense_stack(
            &[("fc1/w".into(), w1.clone()), ("fc2/w".into(), w2.clone())],
            &[b2.clone(), b1.clone()],
        )
        .is_err());
        // broken chain
        let w_bad = Tensor::zeros(vec![7, 3]);
        assert!(dense_stack(
            &[("fc1/w".into(), w1), ("fc2/w".into(), w_bad)],
            &[b1, b2],
        )
        .is_err());
    }
}

//! Exact quantized-matmul reference backend.
//!
//! The closed form every other forward path approximates: activations
//! quantized to 8-bit codes (per example row), weights quantized to 8-bit
//! dynamic fixed point (Eq. 1–2), and the product accumulated exactly in
//! the integer domain before one scale back to real units. At lossless ADC
//! resolution the crossbar simulator recombines to the same integers, so
//! the two backends agree bit-for-bit — the cross-backend agreement tests
//! lean on that. Previously this logic lived as ad-hoc `exact_matmul`
//! duplicates inside test modules; it is now a real, reusable module.

use anyhow::Result;

use crate::quant;
use crate::reram::sim::act_quantize_into;
use crate::tensor::Tensor;

use super::{BackendInfo, DenseLayer, InferenceBackend};

/// One quantized dense layer: signed integer codes + the shared Qstep.
struct RefLayer {
    rows: usize,
    cols: usize,
    /// `sign * code` per element, row-major (fan-in x fan-out)
    qcodes: Vec<i64>,
    step: f32,
    bias: Option<Vec<f32>>,
    relu: bool,
}

/// Exact quantized inference over a dense stack.
pub struct ReferenceBackend {
    name: String,
    layers: Vec<RefLayer>,
    input_dim: usize,
    num_classes: usize,
    intra_threads: usize,
}

impl std::fmt::Debug for ReferenceBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReferenceBackend")
            .field("name", &self.name)
            .field("layers", &self.layers.len())
            .field("input_dim", &self.input_dim)
            .field("num_classes", &self.num_classes)
            .field("intra_threads", &self.intra_threads)
            .finish()
    }
}

impl ReferenceBackend {
    pub fn new(name: &str, stack: &[DenseLayer]) -> Result<Self> {
        anyhow::ensure!(!stack.is_empty(), "empty dense stack");
        let mut layers = Vec::with_capacity(stack.len());
        for l in stack {
            anyhow::ensure!(
                l.w.shape().len() == 2,
                "layer {:?} is not rank-2",
                l.name
            );
            let (rows, cols) = (l.w.shape()[0], l.w.shape()[1]);
            let q = quant::quantize(&l.w);
            let qcodes = q
                .codes
                .iter()
                .zip(&q.signs)
                .map(|(&c, &s)| s as i64 * c as i64)
                .collect();
            layers.push(RefLayer {
                rows,
                cols,
                qcodes,
                step: q.step,
                bias: l.bias.as_ref().map(|b| b.data().to_vec()),
                relu: l.relu,
            });
        }
        Ok(ReferenceBackend {
            name: name.to_string(),
            input_dim: layers[0].rows,
            num_classes: layers[layers.len() - 1].cols,
            layers,
            intra_threads: crate::util::pool::worker_threads(),
        })
    }

    /// Cap the threads one `infer_batch` call may use (see
    /// [`super::CrossbarBackend::with_intra_threads`]).
    pub fn with_intra_threads(mut self, threads: usize) -> Self {
        self.intra_threads = threads.max(1);
        self
    }

    /// One example through the whole stack (integer-exact per layer);
    /// `acc`/`codes` are reused across layers and examples by the caller.
    fn infer_one(&self, row: &[f32], acc: &mut Vec<i64>, codes: &mut Vec<u8>) -> Vec<f32> {
        let mut act: Vec<f32> = row.to_vec();
        for layer in &self.layers {
            let a_step = act_quantize_into(&act, codes);
            let scale = layer.step * a_step;
            acc.clear();
            acc.resize(layer.cols, 0);
            for (k, &c) in codes.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                let c = c as i64;
                let wrow = &layer.qcodes[k * layer.cols..(k + 1) * layer.cols];
                for (a, &w) in acc.iter_mut().zip(wrow) {
                    *a += c * w;
                }
            }
            act.clear();
            act.extend(acc.iter().map(|&v| v as f32 * scale));
            if let Some(bias) = &layer.bias {
                for (v, &b) in act.iter_mut().zip(bias) {
                    *v += b;
                }
            }
            if layer.relu {
                for v in act.iter_mut() {
                    *v = v.max(0.0);
                }
            }
        }
        act
    }
}

impl InferenceBackend for ReferenceBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn info(&self) -> BackendInfo {
        BackendInfo {
            input_dim: self.input_dim,
            num_classes: self.num_classes,
            native_batch: None,
            logits: true,
        }
    }

    fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
        super::rows_parallel(
            &self.name,
            x,
            self.input_dim,
            self.num_classes,
            self.intra_threads,
            |state: &mut (Vec<i64>, Vec<u8>), row| {
                let (acc, codes) = state;
                self.infer_one(row, acc, codes)
            },
        )
    }
}

/// Standalone exact quantized matmul in real units, with **per-example**
/// activation quantization — the one semantic every host forward path
/// shares (`reram::sim::forward`, [`CrossbarBackend`], this backend):
/// quantize `w` (Eq. 2), quantize each row of `x` with its own qstep,
/// accumulate codes exactly, scale back. Batch-composition invariant by
/// construction; the oracle the simulator's lossless tests compare
/// against.
///
/// [`CrossbarBackend`]: super::CrossbarBackend
pub fn quantized_matmul(x: &Tensor, w: &Tensor) -> Result<Tensor> {
    anyhow::ensure!(x.shape().len() == 2 && w.shape().len() == 2, "rank-2 only");
    let (b, rows) = (x.shape()[0], x.shape()[1]);
    let cols = w.shape()[1];
    anyhow::ensure!(rows == w.shape()[0], "inner dims {rows} vs {}", w.shape()[0]);
    let q = quant::quantize(w);
    let mut out = vec![0.0f32; b * cols];
    let mut codes = Vec::with_capacity(rows);
    let mut acc = vec![0i64; cols];
    for i in 0..b {
        let a_step = act_quantize_into(&x.data()[i * rows..(i + 1) * rows], &mut codes);
        let scale = q.step * a_step;
        acc.fill(0);
        for (k, &code) in codes.iter().enumerate() {
            if code == 0 {
                continue;
            }
            let c = code as i64;
            for j in 0..cols {
                let idx = k * cols + j;
                acc[j] += c * q.signs[idx] as i64 * q.codes[idx] as i64;
            }
        }
        for j in 0..cols {
            out[i * cols + j] = acc[j] as f32 * scale;
        }
    }
    Tensor::new(vec![b, cols], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::sim::act_quantize;
    use crate::serve::dense_stack;
    use crate::util::rng::Rng;

    fn toy_stack(rng: &mut Rng) -> Vec<DenseLayer> {
        let w1 = Tensor::new(vec![12, 7], rng.normal_vec(84, 0.2)).unwrap();
        let w2 = Tensor::new(vec![7, 4], rng.normal_vec(28, 0.2)).unwrap();
        let b1 = Tensor::new(vec![7], rng.normal_vec(7, 0.05)).unwrap();
        let b2 = Tensor::new(vec![4], rng.normal_vec(4, 0.05)).unwrap();
        dense_stack(
            &[("fc1/w".into(), w1), ("fc2/w".into(), w2)],
            &[b1, b2],
        )
        .unwrap()
    }

    #[test]
    fn batching_is_composition_invariant() {
        let mut rng = Rng::new(5);
        let stack = toy_stack(&mut rng);
        let be = ReferenceBackend::new("ref", &stack).unwrap();
        let x = Tensor::new(vec![6, 12], (0..72).map(|_| rng.next_f32()).collect()).unwrap();
        let all = be.infer_batch(&x).unwrap();
        for i in 0..6 {
            let row = Tensor::new(vec![1, 12], x.data()[i * 12..(i + 1) * 12].to_vec()).unwrap();
            let one = be.infer_batch(&row).unwrap();
            assert_eq!(&all.data()[i * 4..(i + 1) * 4], one.data(), "row {i}");
        }
    }

    #[test]
    fn quantized_matmul_matches_float_reference_within_quant_error() {
        let mut rng = Rng::new(9);
        let w = Tensor::new(vec![30, 8], rng.normal_vec(240, 0.2)).unwrap();
        let x = Tensor::new(vec![3, 30], (0..90).map(|_| rng.next_f32()).collect()).unwrap();
        let got = quantized_matmul(&x, &w).unwrap();
        // float reference on the recovered quantized operands, with the
        // same per-row activation quantization
        let qw = quant::quantize(&w).recover();
        for i in 0..3 {
            let (codes, step) = act_quantize(&x.data()[i * 30..(i + 1) * 30]);
            for j in 0..8 {
                let mut want = 0.0f64;
                for k in 0..30 {
                    want += (codes[k] as f64 * step as f64) * qw.at2(k, j) as f64;
                }
                let got = got.at2(i, j) as f64;
                assert!(
                    (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "({i},{j}): {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn relu_and_bias_applied_between_layers() {
        // single negative weight, large positive bias: relu must keep the
        // biased value, not the raw negative product
        let w1 = Tensor::new(vec![1, 1], vec![-0.5]).unwrap();
        let w2 = Tensor::new(vec![1, 1], vec![1.0]).unwrap();
        let b1 = Tensor::new(vec![1], vec![2.0]).unwrap();
        let b2 = Tensor::new(vec![1], vec![0.0]).unwrap();
        let stack = dense_stack(
            &[("a".into(), w1), ("b".into(), w2)],
            &[b1, b2],
        )
        .unwrap();
        let be = ReferenceBackend::new("ref", &stack).unwrap();
        let out = be.infer_batch(&Tensor::new(vec![1, 1], vec![1.0]).unwrap()).unwrap();
        // layer1: -0.5 * 1 + 2.0 = 1.5 (relu keeps), layer2: ~1.5
        assert!(out.data()[0] > 1.0, "got {}", out.data()[0]);
    }
}

//! XLA backend: AOT-compiled graphs behind the backend trait.
//!
//! Two graph flavors exist in the manifest:
//!
//! * **logits graphs** (`reram_paper`, `reram_lossless`, ...): inputs are
//!   named state tensors plus a trailing `x`, output is `logits`. These
//!   support [`InferenceBackend::infer_batch`].
//! * the per-model **eval graph**: inputs are the eval-ordered state
//!   (QW TP ST MASK) plus `x`/`y`, outputs `loss`/`correct`. It cannot
//!   produce logits ([`super::BackendInfo::logits`] is `false`), but its
//!   `eval_batch` is exact and cheap.
//!
//! Both flavors have a graph-fixed batch shape; this backend owns the
//! split/zero-pad logic that previously lived in `coordinator/evaluator.rs`
//! (pad rows carry label `-1`, so they never count as correct).
//!
//! The reram logits graphs are dispatched **one example per run**: their
//! `_act_quantize` censuses the whole batch for the activation qstep,
//! while every Rust backend quantizes per example row, so multi-row
//! dispatch made a row's logits depend on its batch mates. Single-row
//! dispatch (zero-padded to the graph's fixed batch) collapses the
//! batch-global census to the row's own — see `XlaBackend::per_row`.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::state::ModelState;
use crate::runtime::{Engine, Executable, Manifest};
use crate::tensor::{IntTensor, Tensor};

use super::{correct_by_argmax, BackendInfo, InferenceBackend};

#[derive(Clone, Copy)]
enum Mode {
    /// graph maps (state..., x) -> logits at `output index`
    Logits { idx: usize },
    /// eval graph maps (state..., x, y) -> correct count at `output index`
    Eval { idx: usize },
}

/// An AOT graph + resident state literals, padded/chunked to the graph's
/// fixed batch shape.
pub struct XlaBackend {
    name: String,
    exe: Arc<Executable>,
    /// state literals in the graph's input order (everything before x/y)
    fixed: Vec<::xla::Literal>,
    mode: Mode,
    native_batch: usize,
    input_dim: usize,
    num_classes: usize,
    /// dispatch one example per graph run, zero-padded to the fixed batch
    /// shape. The reram graphs' `_act_quantize` takes its activation
    /// qstep over the *whole batch*, while every Rust backend quantizes
    /// per example row — so a row's logits used to depend on which other
    /// rows shared its batch. With a single real row per dispatch the
    /// batch-global census reduces to that row's own (zero pad rows never
    /// raise a max-abs census), restoring batch-composition invariance at
    /// the cost of one graph run per example.
    per_row: bool,
}

impl std::fmt::Debug for XlaBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaBackend")
            .field("name", &self.name)
            .field("native_batch", &self.native_batch)
            .field("input_dim", &self.input_dim)
            .field("num_classes", &self.num_classes)
            .finish_non_exhaustive()
    }
}

impl XlaBackend {
    /// Wrap the model's `eval` graph (accuracy counting only).
    pub fn for_eval(
        engine: &Engine,
        manifest: &Manifest,
        model: &str,
        state: &ModelState,
    ) -> Result<XlaBackend> {
        let entry = manifest.model(model)?;
        let graph = entry.graph("eval")?;
        let exe = engine.load(&graph.path).context("compiling eval graph")?;
        let idx = graph.output_index("correct")?;
        Ok(XlaBackend {
            name: format!("xla:{model}/eval"),
            exe,
            fixed: state.to_eval_literals()?,
            mode: Mode::Eval { idx },
            native_batch: entry.batch,
            input_dim: entry.input_numel(),
            num_classes: entry.num_classes,
            per_row: false,
        })
    }

    /// Wrap a logits graph (e.g. `reram_paper`, `reram_lossless`): state
    /// inputs are matched to the model state **by name** from the graph's
    /// input specs, `x` must be the trailing input.
    pub fn for_graph(
        engine: &Engine,
        manifest: &Manifest,
        model: &str,
        graph_name: &str,
        state: &ModelState,
    ) -> Result<XlaBackend> {
        let entry = manifest.model(model)?;
        let graph = entry.graph(graph_name)?;
        let exe = engine
            .load(&graph.path)
            .with_context(|| format!("compiling {model}/{graph_name}"))?;
        let idx = graph.output_index("logits")?;

        // manifest spec names carry the group prefix, e.g. "qw:fc1/w"
        let mut by_name: Vec<(String, &Tensor)> = Vec::new();
        for (p, t) in entry.qw.iter().zip(&state.qws) {
            by_name.push((format!("qw:{}", p.name), t));
        }
        for (p, t) in entry.tp.iter().zip(&state.tps) {
            by_name.push((format!("tp:{}", p.name), t));
        }
        for (p, t) in entry.st.iter().zip(&state.sts) {
            by_name.push((format!("st:{}", p.name), t));
        }

        anyhow::ensure!(!graph.inputs.is_empty(), "graph {graph_name} has no inputs");
        let last = graph.inputs.len() - 1;
        anyhow::ensure!(
            graph.inputs[last].name == "x",
            "graph {graph_name}: expected trailing input \"x\", got {:?}",
            graph.inputs[last].name
        );
        let mut fixed = Vec::with_capacity(last);
        for spec in &graph.inputs[..last] {
            let t = by_name
                .iter()
                .find(|(n, _)| *n == spec.name)
                .map(|(_, t)| *t)
                .with_context(|| {
                    format!(
                        "graph {graph_name} input {:?} not found in model state",
                        spec.name
                    )
                })?;
            fixed.push(t.to_literal()?);
        }
        let x_spec = &graph.inputs[last];
        anyhow::ensure!(!x_spec.shape.is_empty(), "x input is rank-0");
        let num_classes = graph.outputs[idx]
            .shape
            .last()
            .copied()
            .unwrap_or(entry.num_classes);
        Ok(XlaBackend {
            name: format!("xla:{model}/{graph_name}"),
            exe,
            fixed,
            mode: Mode::Logits { idx },
            native_batch: x_spec.shape[0],
            input_dim: x_spec.shape[1..].iter().product(),
            num_classes,
            // reram graphs quantize activations with a batch-global qstep
            // — see the `per_row` field: single-row dispatch makes their
            // outputs batch-composition invariant and consistent with the
            // Rust backends' per-row quantization
            per_row: graph_name.starts_with("reram"),
        })
    }

    /// Split `x` into native-batch chunks (single-example chunks when
    /// `per_row` is set), zero-padding the tail of each; calls `run` with
    /// (chunk literal, rows valid in this chunk).
    fn for_chunks<F>(&self, x: &Tensor, mut run: F) -> Result<()>
    where
        F: FnMut(&Tensor, usize, usize) -> Result<()>,
    {
        let shape = x.shape();
        anyhow::ensure!(!shape.is_empty(), "batch tensor wants a leading axis");
        let b = shape[0];
        let dim: usize = shape[1..].iter().product();
        anyhow::ensure!(
            dim == self.input_dim,
            "{}: example dim {dim} != expected {}",
            self.name,
            self.input_dim
        );
        let step = if self.per_row { 1 } else { self.native_batch };
        let data = x.data();
        let mut chunk_shape = vec![self.native_batch];
        chunk_shape.extend_from_slice(&shape[1..]);
        let mut pos = 0usize;
        while pos < b {
            let valid = (b - pos).min(step);
            let mut chunk = vec![0.0f32; self.native_batch * dim];
            chunk[..valid * dim].copy_from_slice(&data[pos * dim..(pos + valid) * dim]);
            let xt = Tensor::new(chunk_shape.clone(), chunk)?;
            run(&xt, pos, valid)?;
            pos += valid;
        }
        Ok(())
    }
}

impl InferenceBackend for XlaBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn info(&self) -> BackendInfo {
        BackendInfo {
            input_dim: self.input_dim,
            num_classes: self.num_classes,
            native_batch: Some(self.native_batch),
            logits: matches!(self.mode, Mode::Logits { .. }),
        }
    }

    fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
        let Mode::Logits { idx } = self.mode else {
            anyhow::bail!(
                "{}: eval graph exposes no logits (use eval_batch, or a reram_* graph)",
                self.name
            );
        };
        let b = x.shape()[0];
        let mut out = Vec::with_capacity(b * self.num_classes);
        self.for_chunks(x, |xt, _pos, valid| {
            let x_lit = xt.to_literal()?;
            let mut inputs: Vec<&::xla::Literal> = self.fixed.iter().collect();
            inputs.push(&x_lit);
            let outs = self.exe.run(&inputs)?;
            let logits = Tensor::from_literal(&outs[idx])?;
            out.extend_from_slice(&logits.data()[..valid * self.num_classes]);
            Ok(())
        })?;
        Tensor::new(vec![b, self.num_classes], out)
    }

    fn eval_batch(&self, x: &Tensor, y: &[i32]) -> Result<f64> {
        anyhow::ensure!(
            y.len() == x.shape()[0],
            "{}: {} labels for batch of {}",
            self.name,
            y.len(),
            x.shape()[0]
        );
        match self.mode {
            Mode::Logits { .. } => {
                let logits = self.infer_batch(x)?;
                Ok(correct_by_argmax(&logits, y, self.num_classes))
            }
            Mode::Eval { idx } => {
                let mut correct = 0.0f64;
                self.for_chunks(x, |xt, pos, valid| {
                    // pad labels with -1: never equal to an argmax in 0..C
                    let mut labels = vec![-1i32; self.native_batch];
                    labels[..valid].copy_from_slice(&y[pos..pos + valid]);
                    let y_lit = IntTensor::new(vec![self.native_batch], labels)?.to_literal()?;
                    let x_lit = xt.to_literal()?;
                    let mut inputs: Vec<&::xla::Literal> =
                        Vec::with_capacity(self.fixed.len() + 2);
                    inputs.extend(self.fixed.iter());
                    inputs.push(&x_lit);
                    inputs.push(&y_lit);
                    let outs = self.exe.run(&inputs)?;
                    correct += outs[idx].to_vec::<f32>()?[0] as f64;
                    Ok(())
                })?;
                Ok(correct)
            }
        }
    }
}

//! Crossbar-simulator backend: the deployed-hardware forward path.
//!
//! Maps a dense stack onto 128x128 ReRAM crossbars ([`crate::reram::mapper`])
//! and runs every layer through the functional simulator
//! ([`crate::reram::sim`]) — bit-serial activations, per-crossbar ADC
//! clipping at the configured resolution, digital recombination. The ADC
//! resolutions come from a [`DeploymentPlan`] — per-layer x per-slice bits
//! (LSB-first, see the bit-order docs in [`crate::reram`]) — which a
//! [`ResolutionPolicy`] over the column-current census or the
//! [`crate::reram::planner`] search produces; uniform-bits constructors
//! are kept as thin wrappers.
//!
//! The weight mapping is held behind an `Arc`: [`CrossbarBackend::replan`]
//! and [`CrossbarBackend::rebit`] share it instead of deep-cloning every
//! tile, so ADC sweeps and the planner's many candidate evaluations re-map
//! zero times.
//!
//! # Replica-sharded batches
//!
//! When the plan carries per-layer replicas
//! ([`crate::reram::planner::PlanLayer::replicas`] > 1 anywhere),
//! `infer_batch` switches to a layer-major path: each layer processes the
//! whole batch before the next starts, with one **lane** per replica
//! handle ([`mapper::MappedModel::replicated`] — `Arc`s on the same
//! tiles). Lanes run as tasks on the persistent executor
//! ([`crate::util::pool::parallel_map`]) and claim batch rows
//! dynamically off a shared atomic counter — work stealing, not static
//! even sharding: a lane that draws cheap rows simply claims more, so the
//! slowest replica no longer sets the whole batch's latency. Each lane
//! writes its finished rows back **by row index** into the layer's output
//! buffer and every lane runs the exact per-row pipeline of the unsharded
//! path, so the result is **bit-identical** to it regardless of claim
//! order — replication buys wall-clock on the bottleneck layers, never a
//! different answer. Lanes are capped at the host's worker count:
//! simulated replicas beyond the cores can't run anywhere (physical ones
//! would).

use std::sync::Arc;

use anyhow::Result;

use crate::quant::N_SLICES;
use crate::reram::device::{DeviceModel, LayerDevice};
use crate::reram::mapper::{self, MappedModel, StorageRow, StorageStats};
use crate::reram::planner::DeploymentPlan;
use crate::reram::reorder::ReorderConfig;
use crate::reram::sim::{self, SimScratch};
use crate::reram::{resolution, ResolutionPolicy};
use crate::tensor::Tensor;

use super::{BackendInfo, DenseLayer, InferenceBackend};

/// Per-layer bias/activation metadata (everything of a [`DenseLayer`] that
/// is not the mapped weights), shared across `replan`/`rebit` clones and
/// with the incremental evaluation cache ([`super::EvalCache`]).
#[derive(Debug)]
pub(crate) struct StackMeta {
    pub(crate) bias: Option<Vec<f32>>,
    pub(crate) relu: bool,
}

/// Functional crossbar inference at configurable ADC resolutions.
#[derive(Debug)]
pub struct CrossbarBackend {
    name: String,
    model: Arc<MappedModel>,
    meta: Arc<Vec<StackMeta>>,
    plan: DeploymentPlan,
    /// attached device non-ideality realization ([`crate::reram::device`]);
    /// `None` = the ideal device, the byte-for-byte unperturbed path
    device: Option<Arc<DeviceModel>>,
    input_dim: usize,
    num_classes: usize,
    intra_threads: usize,
}

impl CrossbarBackend {
    /// Map the stack and deploy it under an explicit per-layer plan.
    pub fn with_plan(name: &str, stack: &[DenseLayer], plan: DeploymentPlan) -> Result<Self> {
        let mapped = Self::map_stack(stack, None)?;
        Self::assemble(name, mapped, stack, plan)
    }

    /// Map the stack and size one global resolution set by `policy` over
    /// the **whole model's** column-current distribution (the Table-3
    /// single-operating-point semantics), deployed uniformly per layer.
    pub fn new(name: &str, stack: &[DenseLayer], policy: ResolutionPolicy) -> Result<Self> {
        let mapped = Self::map_stack(stack, None)?;
        let adc_bits = resolution::required_bits(&mapped, policy);
        let plan = DeploymentPlan::uniform_for(&mapped, adc_bits);
        Self::assemble(name, mapped, stack, plan)
    }

    /// Map the stack and size each layer by `policy` over **its own**
    /// census — the planner's starting point.
    pub fn with_layer_policy(
        name: &str,
        stack: &[DenseLayer],
        policy: ResolutionPolicy,
    ) -> Result<Self> {
        let mapped = Self::map_stack(stack, None)?;
        let plan = DeploymentPlan::from_policy(&mapped, policy);
        Self::assemble(name, mapped, stack, plan)
    }

    /// Map the stack and deploy at explicit uniform per-slice resolutions
    /// (LSB-first), e.g. the paper's `[3, 3, 3, 1]` operating point.
    pub fn with_bits(name: &str, stack: &[DenseLayer], adc_bits: [u32; N_SLICES]) -> Result<Self> {
        let mapped = Self::map_stack(stack, None)?;
        let plan = DeploymentPlan::uniform_for(&mapped, adc_bits);
        Self::assemble(name, mapped, stack, plan)
    }

    /// Map the stack with the wordline/column reorder pass
    /// ([`crate::reram::reorder`]) and deploy at explicit uniform
    /// per-slice resolutions.
    pub fn with_bits_reordered(
        name: &str,
        stack: &[DenseLayer],
        adc_bits: [u32; N_SLICES],
        reorder: ReorderConfig,
    ) -> Result<Self> {
        let mapped = Self::map_stack(stack, Some(reorder))?;
        let plan = DeploymentPlan::uniform_for(&mapped, adc_bits);
        Self::assemble(name, mapped, stack, plan)
    }

    /// Map the stack with the reorder pass and size each layer by `policy`
    /// over its own (reordered) census — the reordered planner's starting
    /// point.
    pub fn with_layer_policy_reordered(
        name: &str,
        stack: &[DenseLayer],
        policy: ResolutionPolicy,
        reorder: ReorderConfig,
    ) -> Result<Self> {
        let mapped = Self::map_stack(stack, Some(reorder))?;
        let plan = DeploymentPlan::from_policy(&mapped, policy);
        Self::assemble(name, mapped, stack, plan)
    }

    /// Deploy an already-mapped model (e.g. a reordered mapping built
    /// through [`mapper::map_model_with`]) under `plan`; `stack` supplies
    /// the bias/activation metadata and must match the mapping layer for
    /// layer.
    pub fn from_mapping(
        name: &str,
        mapped: MappedModel,
        stack: &[DenseLayer],
        plan: DeploymentPlan,
    ) -> Result<Self> {
        anyhow::ensure!(
            mapped.layers.len() == stack.len(),
            "mapping has {} layers, stack has {}",
            mapped.layers.len(),
            stack.len()
        );
        for (layer, dense) in mapped.layers.iter().zip(stack) {
            let (rows, cols) = mapper::matrix_view(dense.w.shape())?;
            anyhow::ensure!(
                layer.rows == rows && layer.cols == cols,
                "mapping layer {:?} is {}x{}, stack layer {:?} is {rows}x{cols}",
                layer.name,
                layer.rows,
                layer.cols,
                dense.name
            );
        }
        Self::assemble(name, mapped, stack, plan)
    }

    /// Same mapping, different deployment plan — for sweeps and the
    /// planner's candidate evaluations. The mapped tiles are shared via
    /// `Arc`, so this never re-maps or clones weights.
    pub fn replan(&self, name: &str, plan: DeploymentPlan) -> Result<CrossbarBackend> {
        anyhow::ensure!(
            plan.layers.len() == self.model.layers.len(),
            "plan has {} layers, mapping has {}",
            plan.layers.len(),
            self.model.layers.len()
        );
        Ok(CrossbarBackend {
            name: name.to_string(),
            model: Arc::clone(&self.model),
            meta: Arc::clone(&self.meta),
            plan,
            device: self.device.clone(),
            input_dim: self.input_dim,
            num_classes: self.num_classes,
            intra_threads: self.intra_threads,
        })
    }

    /// Same mapping, same plan, with a device non-ideality realization
    /// attached ([`crate::reram::device`]): every subsequent forward reads
    /// through the realization's perturbed conductances and read noise
    /// instead of the exact programmed cells. The realization must be
    /// built from **this backend's mapping**
    /// (`DeviceModel::for_model(backend.mapped(), cfg)`) — a layer-count
    /// mismatch is rejected here, a deeper structural mismatch panics at
    /// read time. The `Arc` is shared by `replan`/`rebit` clones, so the
    /// planner's Monte-Carlo candidate evaluations reuse one realization
    /// across thousands of plans.
    pub fn with_device(&self, name: &str, device: Arc<DeviceModel>) -> Result<CrossbarBackend> {
        anyhow::ensure!(
            device.layers.len() == self.model.layers.len(),
            "device model has {} layers, mapping has {}",
            device.layers.len(),
            self.model.layers.len()
        );
        Ok(CrossbarBackend {
            name: name.to_string(),
            model: Arc::clone(&self.model),
            meta: Arc::clone(&self.meta),
            plan: self.plan.clone(),
            device: Some(device),
            input_dim: self.input_dim,
            num_classes: self.num_classes,
            intra_threads: self.intra_threads,
        })
    }

    /// The attached device realization, if any (`None` = ideal device).
    pub fn device(&self) -> Option<&Arc<DeviceModel>> {
        self.device.as_ref()
    }

    /// Layer `li`'s slice of the attached device realization.
    #[inline]
    pub(crate) fn layer_device(&self, li: usize) -> Option<&LayerDevice> {
        self.device.as_deref().map(|d| &d.layers[li])
    }

    /// Same mapping at uniform per-slice resolutions — thin wrapper over
    /// [`Self::replan`].
    pub fn rebit(&self, name: &str, adc_bits: [u32; N_SLICES]) -> CrossbarBackend {
        self.replan(name, DeploymentPlan::uniform_for(&self.model, adc_bits))
            .expect("uniform plan always matches its own mapping")
    }

    /// Cap the threads one `infer_batch` call may use. Set to 1 when a
    /// `ServingEngine` worker pool already provides the parallelism —
    /// nested fan-out would only oversubscribe the cores.
    ///
    /// This knob governs the **row-major** (unreplicated) path only. A
    /// plan with replicas deliberately ignores it: the replica-sharded
    /// path's fan-out is the replica count itself (capped at the host's
    /// cores) — that parallelism is the hardware being modelled, not a
    /// host tuning knob. Callers that put a replicated backend behind a
    /// worker pool should scale the pool down by
    /// [`Self::max_replicas`] instead (see the reram_deploy example).
    pub fn with_intra_threads(mut self, threads: usize) -> Self {
        self.intra_threads = threads.max(1);
        self
    }

    /// The per-layer deployment plan this backend runs.
    pub fn plan(&self) -> &DeploymentPlan {
        &self.plan
    }

    /// The shared crossbar mapping (use [`Arc::ptr_eq`] to verify that
    /// sweep clones really share it).
    pub fn mapped(&self) -> &Arc<MappedModel> {
        &self.model
    }

    /// The first layer's per-slice resolutions (LSB-first) — equal to
    /// every layer's under a uniform plan; see [`Self::plan`] for the
    /// general case.
    pub fn adc_bits(&self) -> [u32; N_SLICES] {
        self.plan.layers[0].adc_bits
    }

    /// Per-layer storage/format census of the shared mapping — which
    /// tiles are dense vs bit-plane vs compressed, the bytes each layout
    /// occupies and how many fully-zero tiles the simulator skips
    /// (rendered by `report::storage_table`).
    pub fn storage_rows(&self) -> Vec<StorageRow> {
        self.model.storage_rows()
    }

    /// Whole-model storage census (the roll-up of [`Self::storage_rows`]).
    pub fn storage_stats(&self) -> StorageStats {
        self.model.storage_stats()
    }

    /// Whether the shared mapping carries map-time wordline/column
    /// permutations on any layer.
    pub fn is_reordered(&self) -> bool {
        self.model.is_reordered()
    }

    /// Largest per-layer replica count in the deployed plan (1 = no
    /// replication; the batch path stays row-major).
    pub fn max_replicas(&self) -> usize {
        self.plan
            .layers
            .iter()
            .map(|l| l.replicas.max(1))
            .max()
            .unwrap_or(1)
    }

    /// Pipeline timing of the deployed plan on the shared mapping (the
    /// `report::timing_table` body).
    pub fn timing(&self) -> crate::reram::timing::PipelineTiming {
        crate::reram::timing::plan_timing(&self.model, &self.plan)
    }

    /// The shared per-layer bias/activation metadata — what the
    /// evaluation cache needs to re-run layer steps under candidate
    /// resolutions without a backend clone.
    pub(crate) fn stack_meta(&self) -> &Arc<Vec<StackMeta>> {
        &self.meta
    }

    /// Run layers `from_layer..` over a batch whose rows are already
    /// layer-`from_layer` **input activations** (post-bias/ReLU outputs
    /// of layer `from_layer - 1`; the raw features when `from_layer` is
    /// 0), returning the final logits. `forward_from_layer(0, x)` is
    /// exactly `infer_batch(x)` on the row-major path.
    ///
    /// This is the layer-at-a-time entry point behind
    /// [`super::EvalCache`]: per-row activation quantization makes every
    /// layer boundary depend only on the resolutions *upstream* of it
    /// (see the evaluation-cache convention in [`crate::reram`]), so a
    /// caller holding the incumbent plan's boundary activations can
    /// resume a candidate that first diverges at layer `from_layer`
    /// right here, bit-exactly.
    pub fn forward_from_layer(&self, from_layer: usize, x: &Tensor) -> Result<Tensor> {
        anyhow::ensure!(
            from_layer < self.model.layers.len(),
            "{}: layer {from_layer} out of range ({} layers)",
            self.name,
            self.model.layers.len()
        );
        let in_dim = self.model.layers[from_layer].rows;
        super::rows_parallel(
            &self.name,
            x,
            in_dim,
            self.num_classes,
            self.intra_threads,
            |state: &mut (SimScratch, Vec<i64>, Vec<u8>), row| {
                let (scratch, raw, codes) = state;
                self.infer_tail(from_layer, row, scratch, raw, codes)
            },
        )
    }

    fn map_stack(stack: &[DenseLayer], reorder: Option<ReorderConfig>) -> Result<MappedModel> {
        anyhow::ensure!(!stack.is_empty(), "empty dense stack");
        let layers = stack
            .iter()
            .map(|l| mapper::map_layer_with(&l.name, &l.w, reorder).map(Arc::new))
            .collect::<Result<Vec<_>>>()?;
        Ok(MappedModel { layers })
    }

    fn assemble(
        name: &str,
        mapped: MappedModel,
        stack: &[DenseLayer],
        plan: DeploymentPlan,
    ) -> Result<Self> {
        anyhow::ensure!(
            plan.layers.len() == mapped.layers.len(),
            "plan has {} layers, stack has {}",
            plan.layers.len(),
            mapped.layers.len()
        );
        // a backend only ever deploys a verified artifact: run the full
        // static audit and refuse any Error-severity finding (warnings —
        // e.g. a deliberate off-band `with_storage` conversion — pass).
        // `replan`/`rebit` clones skip this on purpose: they share the
        // already-audited mapping and the planner's candidate loop calls
        // them thousands of times.
        let report = crate::reram::audit::audit_deployment(&mapped, &plan);
        anyhow::ensure!(
            report.summary.errors == 0,
            "refusing to deploy a faulty artifact — {report}"
        );
        let input_dim = mapped.layers[0].rows;
        let num_classes = mapped.layers[mapped.layers.len() - 1].cols;
        let meta = stack
            .iter()
            .map(|l| StackMeta {
                bias: l.bias.as_ref().map(|b| b.data().to_vec()),
                relu: l.relu,
            })
            .collect();
        Ok(CrossbarBackend {
            name: name.to_string(),
            model: Arc::new(mapped),
            meta: Arc::new(meta),
            plan,
            device: None,
            input_dim,
            num_classes,
            intra_threads: crate::util::pool::worker_threads(),
        })
    }

    /// One example through layers `from_layer..` at each layer's own
    /// resolutions (`from_layer` = 0 is the whole stack);
    /// `scratch`/`raw`/`codes` are reused across layers and examples by
    /// the caller.
    fn infer_tail(
        &self,
        from_layer: usize,
        row: &[f32],
        scratch: &mut SimScratch,
        raw: &mut Vec<i64>,
        codes: &mut Vec<u8>,
    ) -> Vec<f32> {
        let mut act: Vec<f32> = row.to_vec();
        let mut next: Vec<f32> = Vec::new();
        for (li, ((mapping, meta), pl)) in self
            .model
            .layers
            .iter()
            .zip(self.meta.iter())
            .zip(&self.plan.layers)
            .enumerate()
            .skip(from_layer)
        {
            Self::layer_step(
                mapping,
                meta,
                &pl.adc_bits,
                self.layer_device(li),
                &act,
                scratch,
                raw,
                codes,
                &mut next,
            );
            std::mem::swap(&mut act, &mut next);
        }
        act
    }

    /// One layer's step for one activation row: quantize, run the mapped
    /// crossbars (through `device`'s perturbed conductances when a
    /// realization is attached), rescale, bias, ReLU — exactly one
    /// iteration of [`Self::infer_tail`]'s loop, shared by the sharded
    /// path and the evaluation cache so every caller runs the identical
    /// per-row float operations.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn layer_step(
        mapping: &mapper::LayerMapping,
        meta: &StackMeta,
        adc_bits: &[u32; N_SLICES],
        device: Option<&LayerDevice>,
        row: &[f32],
        scratch: &mut SimScratch,
        raw: &mut Vec<i64>,
        codes: &mut Vec<u8>,
        out: &mut Vec<f32>,
    ) {
        let a_step = sim::act_quantize_into(row, codes);
        let scale = mapping.step * a_step;
        sim::forward_codes_device_into(mapping, codes, adc_bits, device, scratch, raw);
        out.clear();
        out.extend(raw.iter().map(|&v| v as f32 * scale));
        if let Some(bias) = &meta.bias {
            for (v, &b) in out.iter_mut().zip(bias) {
                *v += b;
            }
        }
        if meta.relu {
            for v in out.iter_mut() {
                *v = v.max(0.0);
            }
        }
    }

    /// Layer-major batch path for replicated plans: every layer runs the
    /// whole batch, with one lane per replica handle claiming rows off a
    /// shared counter (work stealing — see the module docs). Lanes write
    /// by row index, so the result is bit-identical to the row-major path
    /// no matter which lane ends up computing which row.
    fn infer_batch_sharded(&self, x: &Tensor) -> Result<Tensor> {
        let shape = x.shape();
        anyhow::ensure!(!shape.is_empty(), "batch tensor wants a leading axis");
        let b = shape[0];
        let dim: usize = shape[1..].iter().product();
        anyhow::ensure!(
            dim == self.input_dim,
            "{}: example dim {dim} != expected {}",
            self.name,
            self.input_dim
        );
        let cores = crate::util::pool::worker_threads();
        let replicas: Vec<usize> = self.plan.layers.iter().map(|l| l.replicas).collect();
        // one Arc handle per replica, all on the same tiles — the mapper's
        // replica view is what each lane drives
        let rep = self.model.replicated(&replicas);
        let mut act: Vec<f32> = x.data().to_vec();
        let mut width = dim;
        for (li, ((handles, meta), pl)) in rep
            .layers
            .iter()
            .zip(self.meta.iter())
            .zip(&self.plan.layers)
            .enumerate()
        {
            let out_w = handles[0].cols;
            let lanes = handles.len().min(cores).min(b.max(1)).max(1);
            let device = self.layer_device(li);
            let next_row = std::sync::atomic::AtomicUsize::new(0);
            let act_ref: &[f32] = &act;
            // Each lane owns one replica handle and claims rows one at a
            // time; a lane stuck on an expensive row simply claims fewer.
            let run_lane = |lane: usize| -> Vec<(usize, Vec<f32>)> {
                let mapping: &mapper::LayerMapping = &handles[lane % handles.len()];
                crate::util::pool::with_scratch::<(SimScratch, Vec<i64>, Vec<u8>), _>(|state| {
                    let (scratch, raw, codes) = state;
                    let mut part = Vec::new();
                    let mut row_out = Vec::new();
                    loop {
                        let i = next_row.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= b {
                            return part;
                        }
                        Self::layer_step(
                            mapping,
                            meta,
                            &pl.adc_bits,
                            device,
                            &act_ref[i * width..(i + 1) * width],
                            scratch,
                            raw,
                            codes,
                            &mut row_out,
                        );
                        part.push((i, std::mem::take(&mut row_out)));
                    }
                })
            };
            let mut next = vec![0.0f32; b * out_w];
            if lanes <= 1 {
                for (i, row) in run_lane(0) {
                    next[i * out_w..(i + 1) * out_w].copy_from_slice(&row);
                }
            } else {
                for part in crate::util::pool::parallel_map(lanes, lanes, run_lane) {
                    for (i, row) in part {
                        next[i * out_w..(i + 1) * out_w].copy_from_slice(&row);
                    }
                }
            }
            act = next;
            width = out_w;
        }
        Tensor::new(vec![b, width], act)
    }
}

impl InferenceBackend for CrossbarBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn info(&self) -> BackendInfo {
        BackendInfo {
            input_dim: self.input_dim,
            num_classes: self.num_classes,
            native_batch: None,
            logits: true,
        }
    }

    fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
        if self.max_replicas() > 1 {
            return self.infer_batch_sharded(x);
        }
        super::rows_parallel(
            &self.name,
            x,
            self.input_dim,
            self.num_classes,
            self.intra_threads,
            |state: &mut (SimScratch, Vec<i64>, Vec<u8>), row| {
                let (scratch, raw, codes) = state;
                self.infer_tail(0, row, scratch, raw, codes)
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::dense_stack;
    use crate::util::rng::Rng;

    fn toy_stack(rng: &mut Rng) -> Vec<DenseLayer> {
        let w1 = Tensor::new(vec![20, 9], rng.normal_vec(180, 0.15)).unwrap();
        let w2 = Tensor::new(vec![9, 5], rng.normal_vec(45, 0.15)).unwrap();
        let b1 = Tensor::new(vec![9], rng.normal_vec(9, 0.02)).unwrap();
        let b2 = Tensor::new(vec![5], rng.normal_vec(5, 0.02)).unwrap();
        dense_stack(&[("fc1/w".into(), w1), ("fc2/w".into(), w2)], &[b1, b2]).unwrap()
    }

    #[test]
    fn lossless_policy_never_clips() {
        let mut rng = Rng::new(11);
        let stack = toy_stack(&mut rng);
        let lossless = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let wide = lossless.rebit("xb-wide", [32; 4]);
        let x = Tensor::new(vec![4, 20], (0..80).map(|_| rng.next_f32()).collect()).unwrap();
        let a = lossless.infer_batch(&x).unwrap();
        let b = wide.infer_batch(&x).unwrap();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn batching_is_composition_invariant() {
        let mut rng = Rng::new(13);
        let stack = toy_stack(&mut rng);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let x = Tensor::new(vec![5, 20], (0..100).map(|_| rng.next_f32()).collect()).unwrap();
        let all = be.infer_batch(&x).unwrap();
        for i in 0..5 {
            let row = Tensor::new(vec![1, 20], x.data()[i * 20..(i + 1) * 20].to_vec()).unwrap();
            let one = be.infer_batch(&row).unwrap();
            assert_eq!(&all.data()[i * 5..(i + 1) * 5], one.data(), "row {i}");
        }
    }

    #[test]
    fn reduced_resolution_changes_dense_outputs() {
        let mut rng = Rng::new(17);
        // dense weights so 1-bit ADCs clip hard
        let stack = toy_stack(&mut rng);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let starved = be.rebit("xb-1bit", [1; 4]);
        assert_eq!(starved.adc_bits(), [1; 4]);
        let x = Tensor::new(vec![2, 20], vec![0.9; 40]).unwrap();
        let a = be.infer_batch(&x).unwrap();
        let b = starved.infer_batch(&x).unwrap();
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn rebit_and_replan_share_the_mapping() {
        let mut rng = Rng::new(21);
        let stack = toy_stack(&mut rng);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let swept = be.rebit("xb-sweep", [3, 3, 3, 1]);
        assert!(
            Arc::ptr_eq(be.mapped(), swept.mapped()),
            "rebit must share tiles, not deep-clone them"
        );
        let plan = DeploymentPlan::uniform_for(be.mapped(), [2, 2, 2, 1]);
        let replanned = be.replan("xb-plan", plan).unwrap();
        assert!(Arc::ptr_eq(be.mapped(), replanned.mapped()));

        // a plan with the wrong layer count is rejected, not misapplied
        let mut short = replanned.plan().clone();
        short.layers.pop();
        assert!(be.replan("bad", short).is_err());
    }

    #[test]
    fn per_layer_plan_applies_bits_per_layer() {
        let mut rng = Rng::new(23);
        let stack = toy_stack(&mut rng);
        let lossless = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let x = Tensor::new(vec![3, 20], vec![0.8; 60]).unwrap();
        let want = lossless.infer_batch(&x).unwrap();

        // starving only the *second* layer must change the output...
        let mut plan = lossless.plan().clone();
        plan.layers[1].adc_bits = [1; 4];
        let starved_l2 = lossless.replan("xb-l2", plan).unwrap();
        assert_ne!(want.data(), starved_l2.infer_batch(&x).unwrap().data());

        // ...and per-layer lossless bits reproduce whole-model lossless
        // exactly (neither clips anywhere)
        let per_layer =
            CrossbarBackend::with_layer_policy("xb-pl", &stack, ResolutionPolicy::Lossless)
                .unwrap();
        assert_eq!(want.data(), per_layer.infer_batch(&x).unwrap().data());
        // the per-layer plan is genuinely non-uniform on this stack or at
        // least never exceeds the whole-model bits
        for l in &per_layer.plan().layers {
            for k in 0..N_SLICES {
                assert!(l.adc_bits[k] <= lossless.adc_bits()[k]);
            }
        }
    }

    #[test]
    fn storage_rows_expose_the_mapping_census() {
        let mut rng = Rng::new(29);
        let stack = toy_stack(&mut rng);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let rows = be.storage_rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].layer, "fc1/w");
        assert_eq!(rows[1].layer, "fc2/w");
        let total = be.storage_stats();
        let summed: usize = rows.iter().map(|r| r.stats.bytes).sum();
        assert_eq!(total.bytes, summed);
        assert!(total.programmed_cells > 0);
        // replan clones share the mapping, so they report the same census
        let swept = be.rebit("xb-sweep", [3, 3, 3, 1]);
        assert_eq!(swept.storage_stats(), total);
    }

    #[test]
    fn rejects_wrong_input_dim() {
        let mut rng = Rng::new(19);
        let stack = toy_stack(&mut rng);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let x = Tensor::new(vec![2, 7], vec![0.1; 14]).unwrap();
        assert!(be.infer_batch(&x).is_err());
    }

    #[test]
    fn reordered_backend_is_bit_identical_at_lossless() {
        let mut rng = Rng::new(31);
        let stack = toy_stack(&mut rng);
        let natural =
            CrossbarBackend::with_layer_policy("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let reordered = CrossbarBackend::with_layer_policy_reordered(
            "xb-ro",
            &stack,
            ResolutionPolicy::Lossless,
            ReorderConfig::default(),
        )
        .unwrap();
        let x = Tensor::new(vec![4, 20], (0..80).map(|_| rng.next_f32()).collect()).unwrap();
        assert_eq!(
            natural.infer_batch(&x).unwrap().data(),
            reordered.infer_batch(&x).unwrap().data(),
            "reordered placement must be invisible at lossless resolution"
        );
        // rebit/replan clones keep the reordered mapping
        let swept = reordered.rebit("xb-ro-sweep", [3, 3, 3, 1]);
        assert!(Arc::ptr_eq(reordered.mapped(), swept.mapped()));
        assert_eq!(swept.is_reordered(), reordered.is_reordered());
    }

    /// A replicated plan shards batch rows across `Arc` replica handles:
    /// the answer is bit-identical to the row-major path on the same
    /// shared mapping, for multi-row and single-row batches alike.
    #[test]
    fn replicated_plan_is_bit_identical_and_shares_tiles() {
        let mut rng = Rng::new(41);
        let stack = toy_stack(&mut rng);
        let base = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        assert_eq!(base.max_replicas(), 1);
        let mut plan = base.plan().clone();
        plan.layers[0].replicas = 3;
        plan.layers[1].replicas = 2;
        let sharded = base.replan("xb-rep", plan).unwrap();
        assert_eq!(sharded.max_replicas(), 3);
        assert!(
            Arc::ptr_eq(base.mapped(), sharded.mapped()),
            "replicas share the mapping, never re-map"
        );
        for b in [1usize, 2, 7, 16] {
            let x = Tensor::new(vec![b, 20], (0..b * 20).map(|_| rng.next_f32()).collect())
                .unwrap();
            assert_eq!(
                base.infer_batch(&x).unwrap().data(),
                sharded.infer_batch(&x).unwrap().data(),
                "batch of {b}"
            );
        }
        // the timing roll-up sees the plan's replicas
        let t = sharded.timing();
        assert_eq!(t.layers[0].replicas, 3);
        assert_eq!(t.layers[1].replicas, 2);
        assert!(t.layers[0].latency_cycles > 0);
        assert!(
            t.layers[0].effective_cycles() < t.layers[0].latency_cycles as f64,
            "replication divides the stage latency"
        );
    }

    /// `forward_from_layer(0, x)` is the whole forward; resuming at
    /// layer 1 from the hand-computed layer-0 boundary reproduces the
    /// final logits bit-exactly — the contract the evaluation cache
    /// builds on.
    #[test]
    fn forward_from_layer_matches_full_forward() {
        let mut rng = Rng::new(53);
        let stack = toy_stack(&mut rng);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let x = Tensor::new(vec![4, 20], (0..80).map(|_| rng.next_f32()).collect()).unwrap();
        let full = be.infer_batch(&x).unwrap();
        assert_eq!(be.forward_from_layer(0, &x).unwrap().data(), full.data());

        // layer-0 boundary by hand, one layer_step per row
        let mut scratch = SimScratch::default();
        let (mut raw, mut codes, mut row_out) = (Vec::new(), Vec::new(), Vec::new());
        let mut boundary = Vec::new();
        for i in 0..4 {
            CrossbarBackend::layer_step(
                &be.model.layers[0],
                &be.meta[0],
                &be.plan.layers[0].adc_bits,
                None,
                &x.data()[i * 20..(i + 1) * 20],
                &mut scratch,
                &mut raw,
                &mut codes,
                &mut row_out,
            );
            boundary.extend_from_slice(&row_out);
        }
        let mid = Tensor::new(vec![4, 9], boundary).unwrap();
        assert_eq!(be.forward_from_layer(1, &mid).unwrap().data(), full.data());

        // out-of-range resume layers are rejected, not misapplied
        assert!(be.forward_from_layer(2, &mid).is_err());
    }

    /// Device-model contract at the backend level: an all-zero config
    /// attached is bit-identical to no device at all; a real sigma changes
    /// the logits but stays deterministic (same realization, same answer —
    /// including through the replica-sharded path, which shards the same
    /// realization); `replan` clones keep the attachment.
    #[test]
    fn device_attachment_is_exact_at_zero_and_deterministic() {
        use crate::reram::device::{DeviceConfig, DeviceModel};
        let mut rng = Rng::new(61);
        let stack = toy_stack(&mut rng);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let x = Tensor::new(vec![4, 20], (0..80).map(|_| rng.next_f32()).collect()).unwrap();
        let want = be.infer_batch(&x).unwrap();

        let ideal = Arc::new(DeviceModel::for_model(
            be.mapped(),
            DeviceConfig {
                seed: 7,
                ..DeviceConfig::default()
            },
        ));
        let attached = be.with_device("xb-ideal", ideal).unwrap();
        assert_eq!(
            attached.infer_batch(&x).unwrap().data(),
            want.data(),
            "sigma=0 / fault-rate=0 attached must be bit-exact to the ideal path"
        );

        let cfg = DeviceConfig {
            sigma: 0.4,
            read_sigma: 0.3,
            fault_rate: 0.05,
            seed: 7,
        };
        let noisy = be
            .with_device("xb-noisy", Arc::new(DeviceModel::for_model(be.mapped(), cfg)))
            .unwrap();
        let a = noisy.infer_batch(&x).unwrap();
        assert_ne!(a.data(), want.data(), "a real sigma must perturb the logits");
        assert_eq!(
            a.data(),
            noisy.infer_batch(&x).unwrap().data(),
            "one realization, one answer"
        );
        // replan keeps the attachment (the planner's MC loop relies on it)
        let replanned = noisy.replan("xb-noisy-replan", noisy.plan().clone()).unwrap();
        assert!(replanned.device().is_some());
        assert_eq!(replanned.infer_batch(&x).unwrap().data(), a.data());
        // the replica-sharded path runs the same realization bit-identically
        let mut plan = noisy.plan().clone();
        plan.layers[0].replicas = 3;
        let sharded = noisy.replan("xb-noisy-rep", plan).unwrap();
        assert_eq!(sharded.infer_batch(&x).unwrap().data(), a.data());
        // a realization for a different mapping is rejected
        let other = CrossbarBackend::new(
            "xb2",
            &toy_stack(&mut rng)[..1],
            ResolutionPolicy::Lossless,
        )
        .unwrap();
        let wrong = Arc::new(DeviceModel::for_model(other.mapped(), cfg));
        assert!(be.with_device("bad", wrong).is_err());
    }

    #[test]
    fn from_mapping_validates_stack_shapes() {
        use crate::reram::mapper;
        let mut rng = Rng::new(37);
        let stack = toy_stack(&mut rng);
        let named: Vec<(String, Tensor)> = stack
            .iter()
            .map(|l| (l.name.clone(), l.w.clone()))
            .collect();
        let mapped =
            mapper::map_model_with(&named, Some(ReorderConfig::default())).unwrap();
        let plan = DeploymentPlan::uniform_for(&mapped, [10; 4]);
        let be =
            CrossbarBackend::from_mapping("xb-m", mapped.clone(), &stack, plan.clone()).unwrap();
        let x = Tensor::new(vec![2, 20], (0..40).map(|_| rng.next_f32()).collect()).unwrap();
        // same answer as mapping the stack directly at the same bits
        let direct = CrossbarBackend::with_bits_reordered(
            "xb-d",
            &stack,
            [10; 4],
            ReorderConfig::default(),
        )
        .unwrap();
        assert_eq!(
            be.infer_batch(&x).unwrap().data(),
            direct.infer_batch(&x).unwrap().data()
        );
        // a stack that does not match the mapping is rejected
        assert!(CrossbarBackend::from_mapping("bad", mapped, &stack[..1], plan).is_err());
    }
}

//! Crossbar-simulator backend: the deployed-hardware forward path.
//!
//! Maps a dense stack onto 128x128 ReRAM crossbars ([`crate::reram::mapper`])
//! and runs every layer through the functional simulator
//! ([`crate::reram::sim`]) — bit-serial activations, per-crossbar ADC
//! clipping at the configured resolution, digital recombination. The ADC
//! resolution comes from a [`ResolutionPolicy`] applied to the mapped
//! model's column-current census (exactly what `harness::deploy_report`
//! measures) or from explicit per-slice bits.

use anyhow::Result;

use crate::quant::N_SLICES;
use crate::reram::mapper::{self, LayerMapping, MappedModel};
use crate::reram::sim::{self, SimScratch};
use crate::reram::{resolution, ResolutionPolicy};
use crate::tensor::Tensor;

use super::{BackendInfo, DenseLayer, InferenceBackend};

struct XbarLayer {
    mapping: LayerMapping,
    bias: Option<Vec<f32>>,
    relu: bool,
}

/// Functional crossbar inference at a configurable ADC resolution.
pub struct CrossbarBackend {
    name: String,
    layers: Vec<XbarLayer>,
    adc_bits: [u32; N_SLICES],
    input_dim: usize,
    num_classes: usize,
    intra_threads: usize,
}

impl CrossbarBackend {
    /// Map the stack and size the ADCs by `policy` over the whole model's
    /// column-current distribution (the Table-3 deployment semantics).
    pub fn new(name: &str, stack: &[DenseLayer], policy: ResolutionPolicy) -> Result<Self> {
        let mapped = Self::map_stack(stack)?;
        let adc_bits = resolution::required_bits(&mapped, policy);
        Self::assemble(name, mapped, stack, adc_bits)
    }

    /// Map the stack and deploy at explicit per-slice resolutions
    /// (LSB-first), e.g. the paper's `[3, 3, 3, 1]` operating point.
    pub fn with_bits(name: &str, stack: &[DenseLayer], adc_bits: [u32; N_SLICES]) -> Result<Self> {
        let mapped = Self::map_stack(stack)?;
        Self::assemble(name, mapped, stack, adc_bits)
    }

    /// Same mapping, different ADC resolutions — for sweeps, without
    /// re-mapping the weights per point.
    pub fn rebit(&self, name: &str, adc_bits: [u32; N_SLICES]) -> CrossbarBackend {
        CrossbarBackend {
            name: name.to_string(),
            layers: self
                .layers
                .iter()
                .map(|l| XbarLayer {
                    mapping: l.mapping.clone(),
                    bias: l.bias.clone(),
                    relu: l.relu,
                })
                .collect(),
            adc_bits,
            input_dim: self.input_dim,
            num_classes: self.num_classes,
            intra_threads: self.intra_threads,
        }
    }

    /// Cap the threads one `infer_batch` call may use. Set to 1 when a
    /// `ServingEngine` worker pool already provides the parallelism —
    /// nested fan-out would only oversubscribe the cores.
    pub fn with_intra_threads(mut self, threads: usize) -> Self {
        self.intra_threads = threads.max(1);
        self
    }

    /// The per-slice ADC resolutions this backend deploys (LSB-first).
    pub fn adc_bits(&self) -> [u32; N_SLICES] {
        self.adc_bits
    }

    fn map_stack(stack: &[DenseLayer]) -> Result<MappedModel> {
        anyhow::ensure!(!stack.is_empty(), "empty dense stack");
        let layers = stack
            .iter()
            .map(|l| mapper::map_layer(&l.name, &l.w))
            .collect::<Result<Vec<_>>>()?;
        Ok(MappedModel { layers })
    }

    fn assemble(
        name: &str,
        mapped: MappedModel,
        stack: &[DenseLayer],
        adc_bits: [u32; N_SLICES],
    ) -> Result<Self> {
        let input_dim = mapped.layers[0].rows;
        let num_classes = mapped.layers[mapped.layers.len() - 1].cols;
        let layers = mapped
            .layers
            .into_iter()
            .zip(stack)
            .map(|(mapping, l)| XbarLayer {
                mapping,
                bias: l.bias.as_ref().map(|b| b.data().to_vec()),
                relu: l.relu,
            })
            .collect();
        Ok(CrossbarBackend {
            name: name.to_string(),
            layers,
            adc_bits,
            input_dim,
            num_classes,
            intra_threads: super::default_intra_threads(),
        })
    }

    /// One example through the stack; `scratch`/`raw` are reused across
    /// layers and examples by the caller.
    fn infer_one(&self, row: &[f32], scratch: &mut SimScratch, raw: &mut Vec<i64>) -> Vec<f32> {
        let mut act: Vec<f32> = row.to_vec();
        for layer in &self.layers {
            let (codes, a_step) = sim::act_quantize(&act);
            let scale = layer.mapping.step * a_step;
            sim::forward_codes_into(&layer.mapping, &codes, &self.adc_bits, scratch, raw);
            act.clear();
            act.extend(raw.iter().map(|&v| v as f32 * scale));
            if let Some(bias) = &layer.bias {
                for (v, &b) in act.iter_mut().zip(bias) {
                    *v += b;
                }
            }
            if layer.relu {
                for v in act.iter_mut() {
                    *v = v.max(0.0);
                }
            }
        }
        act
    }
}

impl InferenceBackend for CrossbarBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn info(&self) -> BackendInfo {
        BackendInfo {
            input_dim: self.input_dim,
            num_classes: self.num_classes,
            native_batch: None,
            logits: true,
        }
    }

    fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
        super::rows_parallel(
            &self.name,
            x,
            self.input_dim,
            self.num_classes,
            self.intra_threads,
            || (SimScratch::default(), Vec::new()),
            |(scratch, raw), row| self.infer_one(row, scratch, raw),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::dense_stack;
    use crate::util::rng::Rng;

    fn toy_stack(rng: &mut Rng) -> Vec<DenseLayer> {
        let w1 = Tensor::new(vec![20, 9], rng.normal_vec(180, 0.15)).unwrap();
        let w2 = Tensor::new(vec![9, 5], rng.normal_vec(45, 0.15)).unwrap();
        let b1 = Tensor::new(vec![9], rng.normal_vec(9, 0.02)).unwrap();
        let b2 = Tensor::new(vec![5], rng.normal_vec(5, 0.02)).unwrap();
        dense_stack(&[("fc1/w".into(), w1), ("fc2/w".into(), w2)], &[b1, b2]).unwrap()
    }

    #[test]
    fn lossless_policy_never_clips() {
        let mut rng = Rng::new(11);
        let stack = toy_stack(&mut rng);
        let lossless = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let wide = lossless.rebit("xb-wide", [32; 4]);
        let x = Tensor::new(vec![4, 20], (0..80).map(|_| rng.next_f32()).collect()).unwrap();
        let a = lossless.infer_batch(&x).unwrap();
        let b = wide.infer_batch(&x).unwrap();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn batching_is_composition_invariant() {
        let mut rng = Rng::new(13);
        let stack = toy_stack(&mut rng);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let x = Tensor::new(vec![5, 20], (0..100).map(|_| rng.next_f32()).collect()).unwrap();
        let all = be.infer_batch(&x).unwrap();
        for i in 0..5 {
            let row = Tensor::new(vec![1, 20], x.data()[i * 20..(i + 1) * 20].to_vec()).unwrap();
            let one = be.infer_batch(&row).unwrap();
            assert_eq!(&all.data()[i * 5..(i + 1) * 5], one.data(), "row {i}");
        }
    }

    #[test]
    fn reduced_resolution_changes_dense_outputs() {
        let mut rng = Rng::new(17);
        // dense weights so 1-bit ADCs clip hard
        let stack = toy_stack(&mut rng);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let starved = be.rebit("xb-1bit", [1; 4]);
        assert_eq!(starved.adc_bits(), [1; 4]);
        let x = Tensor::new(vec![2, 20], vec![0.9; 40]).unwrap();
        let a = be.infer_batch(&x).unwrap();
        let b = starved.infer_batch(&x).unwrap();
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn rejects_wrong_input_dim() {
        let mut rng = Rng::new(19);
        let stack = toy_stack(&mut rng);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let x = Tensor::new(vec![2, 7], vec![0.1; 14]).unwrap();
        assert!(be.infer_batch(&x).is_err());
    }
}

//! Batched serving engine: enqueue single-example requests, serve them in
//! dynamically assembled fixed-cost batches.
//!
//! Requests land in a bounded queue ([`crate::util::pool::bounded`]);
//! worker threads pull with `recv_batch` (block for the first request,
//! drain whatever else is queued up to `max_batch`), assemble one batch
//! tensor, run the backend's `infer_batch` once, and complete each
//! request with its logits row. Per-request latency (enqueue → response)
//! and aggregate throughput are recorded and exported as
//! [`crate::report::ServingRow`]s.
//!
//! With an [`SloPolicy`] attached ([`ServeOptions::slo`]), batch assembly
//! becomes latency-aware: workers pull with
//! [`crate::util::pool::Receiver::recv_batch_by`], keeping a batch open
//! until the oldest queued request's age plus the predicted service time
//! (a linear model priced from the plan's [`crate::reram::timing`]
//! cycles) approaches the SLO target — a batch closes when waiting longer
//! would endanger the deadline, not only when `max_batch` fills.
//!
//! Because host backends are batch-composition invariant (see the
//! `serve` module contract), a request's result does not depend on which
//! batch the engine happened to pack it into.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::report::ServingRow;
use crate::tensor::Tensor;
use crate::util::pool::{bounded, Receiver, Sender};

use super::{InferenceBackend as _, SharedBackend};

/// Serving-engine knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// largest batch a worker will assemble from the queue
    pub max_batch: usize,
    /// worker threads; 0 = one per available core, capped at `worker_cap`
    pub workers: usize,
    /// request-queue capacity: [`ServingEngine::submit`] blocks beyond it
    /// and [`ServingEngine::try_submit`] sheds — never unbounded growth
    pub queue_depth: usize,
    /// ceiling on the auto-sized pool (`workers == 0`); explicit `workers`
    /// values are taken as-is
    pub worker_cap: usize,
    /// latency target; `None` keeps the greedy drain-now batcher
    pub slo: Option<SloPolicy>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_batch: 64,
            workers: 0,
            queue_depth: 256,
            worker_cap: 8,
            slo: None,
        }
    }
}

/// Latency SLO for batch assembly: a target plus a linear service-time
/// model (`fixed + per_example * batch`). [`Self::from_timing`] prices
/// the model from the active plan's [`crate::reram::timing`] cycle
/// counts: the pipeline-fill latency is the fixed term and the
/// bottleneck stage's effective cycles the per-example term.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloPolicy {
    /// enqueue→response latency target (ms) a request should meet
    pub target_ms: f64,
    /// batch-size-independent service cost (ms)
    pub service_fixed_ms: f64,
    /// marginal service cost per batched example (ms)
    pub service_per_example_ms: f64,
}

impl SloPolicy {
    /// A bare target with zero service estimates: batches stay open until
    /// the oldest request's age alone reaches the target.
    pub fn new(target_ms: f64) -> SloPolicy {
        SloPolicy {
            target_ms,
            service_fixed_ms: 0.0,
            service_per_example_ms: 0.0,
        }
    }

    /// Price the service model from a plan's pipeline timing.
    /// `ms_per_kcycle` converts model cycles to wall milliseconds (the
    /// deployment's clock; calibrate against a measured batch when
    /// simulating).
    pub fn from_timing(
        timing: &crate::reram::timing::PipelineTiming,
        target_ms: f64,
        ms_per_kcycle: f64,
    ) -> SloPolicy {
        SloPolicy {
            target_ms,
            service_fixed_ms: timing.pipeline_fill_cycles() as f64 / 1000.0 * ms_per_kcycle,
            service_per_example_ms: timing.bottleneck_cycles() / 1000.0 * ms_per_kcycle,
        }
    }

    /// Predicted wall-clock service time (ms) for a batch of `batch`.
    pub fn predicted_service_ms(&self, batch: usize) -> f64 {
        self.service_fixed_ms + self.service_per_example_ms * batch as f64
    }

    /// Latest instant a batch holding a request enqueued at `enqueued`
    /// may stay open: waiting past it leaves less than the predicted
    /// worst-case (`max_batch`-sized) service time before the target.
    fn close_deadline(&self, enqueued: Instant, max_batch: usize) -> Instant {
        let slack_ms = (self.target_ms - self.predicted_service_ms(max_batch)).max(0.0);
        enqueued + Duration::from_secs_f64(slack_ms / 1e3)
    }
}

struct InferRequest {
    x: Vec<f32>,
    enqueued: Instant,
    tx: Sender<Result<Vec<f32>>>,
}

/// Handle to a submitted request; `wait` blocks for the logits row.
pub struct PendingInference {
    rx: Receiver<Result<Vec<f32>>>,
}

impl std::fmt::Debug for PendingInference {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PendingInference").finish_non_exhaustive()
    }
}

impl PendingInference {
    pub fn wait(self) -> Result<Vec<f32>> {
        self.rx
            .recv()
            .unwrap_or_else(|| Err(anyhow::anyhow!("serving engine dropped the request")))
    }
}

#[derive(Default)]
struct StatsInner {
    latencies: Vec<Duration>,
    batches: usize,
    batched_examples: usize,
    errors: usize,
    infer_time: Duration,
}

/// Aggregate serving statistics, snapshotted at shutdown.
#[derive(Debug, Clone)]
pub struct ServingStats {
    pub backend: String,
    pub max_batch: usize,
    pub workers: usize,
    pub requests: usize,
    pub batches: usize,
    pub errors: usize,
    /// wall time from engine start to shutdown
    pub elapsed: Duration,
    /// time spent inside `infer_batch` summed over workers
    pub infer_time: Duration,
    pub throughput_rps: f64,
    pub mean_batch: f64,
    /// the SLO target the engine served under, when one was set (ms)
    pub slo_ms: Option<f64>,
    /// requests whose enqueue→response latency exceeded the target
    pub slo_violations: usize,
    /// per-request enqueue→response latencies, sorted ascending (ms)
    pub latencies_ms: Vec<f64>,
}

impl ServingStats {
    /// Latency percentile in milliseconds, `p` in [0, 1]. Ceiling
    /// nearest-rank — the repo-wide percentile convention shared with
    /// `SliceCurrents::percentile` (p99 of 100 samples is the 99th
    /// smallest, never interpolated between observations).
    pub fn latency_ms(&self, p: f64) -> f64 {
        let n = self.latencies_ms.len();
        if n == 0 {
            return 0.0;
        }
        let rank = (p.clamp(0.0, 1.0) * n as f64).ceil() as usize;
        self.latencies_ms[rank.saturating_sub(1).min(n - 1)]
    }

    pub fn mean_latency_ms(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        self.latencies_ms.iter().sum::<f64>() / self.latencies_ms.len() as f64
    }

    /// Export as a report row (the serving table / BENCH_serving.json).
    pub fn row(&self) -> ServingRow {
        ServingRow {
            backend: self.backend.clone(),
            max_batch: self.max_batch,
            workers: self.workers,
            requests: self.requests,
            errors: self.errors,
            mean_batch: self.mean_batch,
            throughput_rps: self.throughput_rps,
            latency_mean_ms: self.mean_latency_ms(),
            latency_p50_ms: self.latency_ms(0.50),
            latency_p99_ms: self.latency_ms(0.99),
            slo_ms: self.slo_ms,
            slo_violations: self.slo_violations,
        }
    }
}

/// The batched serving engine.
pub struct ServingEngine {
    tx: Option<Sender<InferRequest>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
    started: Instant,
    input_dim: usize,
    num_classes: usize,
    backend_name: String,
    opts: ServeOptions,
    resolved_workers: usize,
}

impl std::fmt::Debug for ServingEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServingEngine")
            .field("backend", &self.backend_name)
            .field("workers", &self.resolved_workers)
            .field("input_dim", &self.input_dim)
            .field("num_classes", &self.num_classes)
            .field("accepting", &self.tx.is_some())
            .finish_non_exhaustive()
    }
}

impl ServingEngine {
    /// Spawn the worker pool over `backend`. Fails fast on backends that
    /// cannot produce logits (the eval-graph-only `XlaBackend` flavor) —
    /// otherwise every request would error after the workload is running.
    pub fn start(backend: SharedBackend, opts: ServeOptions) -> Result<ServingEngine> {
        let info = backend.info();
        anyhow::ensure!(
            info.logits,
            "backend {} exposes no logits and cannot serve inference requests",
            backend.name()
        );
        let workers = if opts.workers == 0 {
            // shared policy with sim + backends, capped for the pool — the
            // cap is a config knob, not a constant
            crate::util::pool::worker_threads().min(opts.worker_cap.max(1))
        } else {
            opts.workers
        };
        let (tx, rx) = bounded::<InferRequest>(opts.queue_depth.max(1));
        let rx = Arc::new(rx);
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = rx.clone();
            let backend = backend.clone();
            let stats = stats.clone();
            let max_batch = opts.max_batch.max(1);
            let slo = opts.slo;
            let dim = info.input_dim;
            let classes = info.num_classes;
            let handle = std::thread::Builder::new()
                .name(format!("serve-{w}"))
                .spawn(move || {
                    let next_batch = || match slo {
                        Some(policy) => rx.recv_batch_by(max_batch, |req: &InferRequest| {
                            Some(policy.close_deadline(req.enqueued, max_batch))
                        }),
                        None => rx.recv_batch(max_batch),
                    };
                    while let Some(reqs) = next_batch() {
                        let b = reqs.len();
                        let mut xdata = Vec::with_capacity(b * dim);
                        for r in &reqs {
                            xdata.extend_from_slice(&r.x);
                        }
                        let t0 = Instant::now();
                        // a panicking backend must fail the batch, not kill
                        // the worker — queued requests would hang forever
                        let result = Tensor::new(vec![b, dim], xdata).and_then(|xt| {
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                backend.infer_batch(&xt)
                            }))
                            .unwrap_or_else(|_| {
                                Err(anyhow::anyhow!("backend panicked during infer_batch"))
                            })
                        });
                        let result = result.and_then(|logits| {
                            anyhow::ensure!(
                                logits.len() == b * classes,
                                "backend returned {} logits for batch of {b} x {classes}",
                                logits.len()
                            );
                            Ok(logits)
                        });
                        let infer_time = t0.elapsed();
                        let now = Instant::now();
                        let mut latencies = Vec::with_capacity(b);
                        let mut errors = 0usize;
                        match result {
                            Ok(logits) => {
                                for (i, req) in reqs.into_iter().enumerate() {
                                    let row =
                                        logits.data()[i * classes..(i + 1) * classes].to_vec();
                                    latencies.push(now.duration_since(req.enqueued));
                                    // a dropped waiter is not an error
                                    let _ = req.tx.send(Ok(row));
                                }
                            }
                            Err(e) => {
                                let msg = format!("{e:#}");
                                for req in reqs {
                                    errors += 1;
                                    latencies.push(now.duration_since(req.enqueued));
                                    let _ = req
                                        .tx
                                        .send(Err(anyhow::anyhow!("inference failed: {msg}")));
                                }
                            }
                        }
                        let mut s = stats.lock().unwrap();
                        s.batches += 1;
                        s.batched_examples += b;
                        s.errors += errors;
                        s.infer_time += infer_time;
                        s.latencies.extend(latencies);
                    }
                })
                .expect("spawn serving worker");
            handles.push(handle);
        }
        Ok(ServingEngine {
            tx: Some(tx),
            workers: handles,
            stats,
            started: Instant::now(),
            input_dim: info.input_dim,
            num_classes: info.num_classes,
            backend_name: backend.name().to_string(),
            opts,
            resolved_workers: workers,
        })
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn make_request(&self, x: Vec<f32>) -> Result<(InferRequest, PendingInference)> {
        anyhow::ensure!(
            x.len() == self.input_dim,
            "request dim {} != backend input dim {}",
            x.len(),
            self.input_dim
        );
        let (tx, rx) = bounded::<Result<Vec<f32>>>(1);
        let req = InferRequest {
            x,
            enqueued: Instant::now(),
            tx,
        };
        Ok((req, PendingInference { rx }))
    }

    /// Enqueue one example (flattened features). Blocks when the queue is
    /// at capacity (backpressure on the client).
    pub fn submit(&self, x: Vec<f32>) -> Result<PendingInference> {
        let (req, pending) = self.make_request(x)?;
        self.tx
            .as_ref()
            .expect("engine is running")
            .send(req)
            .map_err(|_| anyhow::anyhow!("serving queue closed"))?;
        Ok(pending)
    }

    /// Non-blocking [`Self::submit`]: `Ok(None)` when the bounded request
    /// queue is at capacity — the caller sheds or retries instead of
    /// blocking (the backpressure path for latency-sensitive producers).
    pub fn try_submit(&self, x: Vec<f32>) -> Result<Option<PendingInference>> {
        let (req, pending) = self.make_request(x)?;
        match self.tx.as_ref().expect("engine is running").try_send(req) {
            Ok(()) => Ok(Some(pending)),
            Err(crate::util::pool::TrySendError::Full(_)) => Ok(None),
            Err(crate::util::pool::TrySendError::Closed(_)) => {
                Err(anyhow::anyhow!("serving queue closed"))
            }
        }
    }

    /// Convenience: submit a whole set and wait for every response, in
    /// submission order.
    pub fn infer_many(&self, xs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let pending = xs
            .into_iter()
            .map(|x| self.submit(x))
            .collect::<Result<Vec<_>>>()?;
        pending.into_iter().map(|p| p.wait()).collect()
    }

    /// Close the queue, drain in-flight work, join workers, and return the
    /// aggregate statistics.
    pub fn shutdown(mut self) -> ServingStats {
        self.tx.take(); // closes the queue; workers exit once drained
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        let elapsed = self.started.elapsed();
        let inner = self.stats.lock().unwrap();
        let mut latencies_ms: Vec<f64> =
            inner.latencies.iter().map(|d| d.as_secs_f64() * 1e3).collect();
        latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let requests = inner.latencies.len();
        let slo_ms = self.opts.slo.map(|p| p.target_ms);
        let slo_violations = match slo_ms {
            Some(target) => latencies_ms.iter().filter(|&&l| l > target).count(),
            None => 0,
        };
        ServingStats {
            backend: self.backend_name.clone(),
            max_batch: self.opts.max_batch.max(1),
            workers: self.resolved_workers,
            requests,
            batches: inner.batches,
            errors: inner.errors,
            elapsed,
            infer_time: inner.infer_time,
            throughput_rps: if elapsed.as_secs_f64() > 0.0 {
                requests as f64 / elapsed.as_secs_f64()
            } else {
                0.0
            },
            mean_batch: if inner.batches == 0 {
                0.0
            } else {
                inner.batched_examples as f64 / inner.batches as f64
            },
            slo_ms,
            slo_violations,
            latencies_ms,
        }
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{BackendInfo, InferenceBackend};

    /// Deterministic stub: logits[c] = sum(x) + c (argmax = last class).
    struct SumBackend {
        dim: usize,
        classes: usize,
        fail: bool,
    }

    impl InferenceBackend for SumBackend {
        fn name(&self) -> &str {
            "sum-stub"
        }
        fn info(&self) -> BackendInfo {
            BackendInfo {
                input_dim: self.dim,
                num_classes: self.classes,
                native_batch: None,
                logits: true,
            }
        }
        fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
            anyhow::ensure!(!self.fail, "stub failure");
            let b = x.shape()[0];
            let mut out = Vec::with_capacity(b * self.classes);
            for i in 0..b {
                let s: f32 = x.data()[i * self.dim..(i + 1) * self.dim].iter().sum();
                for c in 0..self.classes {
                    out.push(s + c as f32);
                }
            }
            Tensor::new(vec![b, self.classes], out)
        }
    }

    fn engine(workers: usize, max_batch: usize, fail: bool) -> ServingEngine {
        let backend: crate::serve::SharedBackend = Arc::new(SumBackend {
            dim: 3,
            classes: 2,
            fail,
        });
        ServingEngine::start(
            backend,
            ServeOptions {
                max_batch,
                workers,
                queue_depth: 32,
                ..ServeOptions::default()
            },
        )
        .unwrap()
    }

    /// A backend that reports `logits: false` must be rejected at start.
    struct NoLogits;
    impl InferenceBackend for NoLogits {
        fn name(&self) -> &str {
            "no-logits"
        }
        fn info(&self) -> BackendInfo {
            BackendInfo {
                input_dim: 1,
                num_classes: 1,
                native_batch: None,
                logits: false,
            }
        }
        fn infer_batch(&self, _x: &Tensor) -> Result<Tensor> {
            anyhow::bail!("no logits")
        }
    }

    #[test]
    fn start_rejects_logitless_backends() {
        let backend: crate::serve::SharedBackend = Arc::new(NoLogits);
        assert!(ServingEngine::start(backend, ServeOptions::default()).is_err());
    }

    #[test]
    fn serves_requests_and_matches_direct_compute() {
        let eng = engine(2, 4, false);
        let mut pending = Vec::new();
        for i in 0..20 {
            pending.push(eng.submit(vec![i as f32, 1.0, 2.0]).unwrap());
        }
        for (i, p) in pending.into_iter().enumerate() {
            let row = p.wait().unwrap();
            let s = i as f32 + 3.0;
            assert_eq!(row, vec![s, s + 1.0]);
        }
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 20);
        assert_eq!(stats.errors, 0);
        assert!(stats.batches >= 5, "max_batch 4 -> at least 5 batches");
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.latency_ms(0.5) <= stats.latency_ms(0.99));
        assert!(stats.mean_batch >= 1.0 && stats.mean_batch <= 4.0);
    }

    #[test]
    fn infer_many_preserves_submission_order() {
        let eng = engine(3, 8, false);
        let xs: Vec<Vec<f32>> = (0..17).map(|i| vec![i as f32, 0.0, 0.0]).collect();
        let out = eng.infer_many(xs).unwrap();
        for (i, row) in out.iter().enumerate() {
            assert_eq!(row[0], i as f32);
        }
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 17);
    }

    #[test]
    fn backend_errors_propagate_per_request() {
        let eng = engine(1, 4, true);
        let p = eng.submit(vec![0.0; 3]).unwrap();
        assert!(p.wait().is_err());
        let stats = eng.shutdown();
        assert_eq!(stats.errors, 1);
    }

    #[test]
    fn rejects_wrong_request_dim() {
        let eng = engine(1, 4, false);
        assert!(eng.submit(vec![0.0; 5]).is_err());
        assert!(eng.try_submit(vec![0.0; 5]).is_err());
        let _ = eng.shutdown();
    }

    /// The auto-sized pool honors the configurable cap instead of the old
    /// hard-coded 8.
    #[test]
    fn worker_cap_bounds_the_auto_sized_pool() {
        let backend: crate::serve::SharedBackend = Arc::new(SumBackend {
            dim: 3,
            classes: 2,
            fail: false,
        });
        let eng = ServingEngine::start(
            backend,
            ServeOptions {
                workers: 0,
                worker_cap: 2,
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let stats = eng.shutdown();
        assert!(stats.workers <= 2, "cap 2, got {}", stats.workers);
        assert!(stats.workers >= 1);
    }

    /// A backend gated on a channel lets us fill the bounded queue
    /// deterministically: `try_submit` sheds with `Ok(None)` instead of
    /// blocking, and completes normally once the queue drains.
    #[test]
    fn try_submit_sheds_when_the_queue_is_full() {
        use crate::util::pool::bounded as chan;

        struct GateBackend {
            started: crate::util::pool::Sender<()>,
            release: crate::util::pool::Receiver<()>,
        }
        impl InferenceBackend for GateBackend {
            fn name(&self) -> &str {
                "gate"
            }
            fn info(&self) -> BackendInfo {
                BackendInfo {
                    input_dim: 1,
                    num_classes: 1,
                    native_batch: None,
                    logits: true,
                }
            }
            fn infer_batch(&self, x: &Tensor) -> Result<Tensor> {
                let _ = self.started.send(());
                self.release.recv(); // hold the worker until released
                Tensor::new(vec![x.shape()[0], 1], vec![0.0; x.shape()[0]])
            }
        }

        let (started_tx, started_rx) = chan::<()>(16);
        let (release_tx, release_rx) = chan::<()>(16);
        let backend: crate::serve::SharedBackend = Arc::new(GateBackend {
            started: started_tx,
            release: release_rx,
        });
        let eng = ServingEngine::start(
            backend,
            ServeOptions {
                max_batch: 1,
                workers: 1,
                queue_depth: 1,
                ..ServeOptions::default()
            },
        )
        .unwrap();
        // r1 is picked up by the worker (blocks inside the backend)...
        let r1 = eng.submit(vec![0.0]).unwrap();
        started_rx.recv().expect("worker entered the backend");
        // ...r2 occupies the queue's single slot...
        let r2 = eng.submit(vec![0.0]).unwrap();
        // ...so the next non-blocking submit must shed, not hang
        assert!(eng.try_submit(vec![0.0]).unwrap().is_none());
        // release both batches and drain
        release_tx.send(()).unwrap();
        release_tx.send(()).unwrap();
        assert!(r1.wait().is_ok());
        assert!(r2.wait().is_ok());
        // with room again, try_submit enqueues
        let r3 = eng.try_submit(vec![0.0]).unwrap().expect("queue drained");
        let _ = started_rx.recv();
        release_tx.send(()).unwrap();
        assert!(r3.wait().is_ok());
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 3);
    }

    #[test]
    fn stats_row_exports_report_fields() {
        let eng = engine(2, 4, false);
        let _ = eng.infer_many((0..8).map(|_| vec![0.0; 3]).collect()).unwrap();
        let stats = eng.shutdown();
        let row = stats.row();
        assert_eq!(row.backend, "sum-stub");
        assert_eq!(row.requests, 8);
        assert_eq!(row.workers, 2);
        assert!(row.latency_p50_ms <= row.latency_p99_ms);
        assert_eq!(row.slo_ms, None);
        assert_eq!(row.slo_violations, 0);
    }

    /// Ceiling nearest-rank, the `SliceCurrents::percentile` convention:
    /// p50 of 10 samples is the 5th smallest, p99 the 10th — never an
    /// interpolation between observations.
    #[test]
    fn latency_percentiles_use_ceiling_nearest_rank() {
        let stats = ServingStats {
            backend: "x".into(),
            max_batch: 1,
            workers: 1,
            requests: 10,
            batches: 10,
            errors: 0,
            elapsed: Duration::from_secs(1),
            infer_time: Duration::ZERO,
            throughput_rps: 10.0,
            mean_batch: 1.0,
            slo_ms: None,
            slo_violations: 0,
            latencies_ms: (1..=10).map(f64::from).collect(),
        };
        assert_eq!(stats.latency_ms(0.50), 5.0);
        assert_eq!(stats.latency_ms(0.99), 10.0);
        assert_eq!(stats.latency_ms(0.0), 1.0);
        assert_eq!(stats.latency_ms(1.0), 10.0);
        assert_eq!(stats.latency_ms(0.11), 2.0);
    }

    /// The linear service model priced from a pipeline timing: fill
    /// cycles are the fixed term, bottleneck effective cycles the
    /// per-example term.
    #[test]
    fn slo_policy_prices_service_time_from_timing() {
        use crate::reram::timing::{LayerTiming, PipelineTiming};
        let timing = PipelineTiming {
            layers: vec![LayerTiming {
                layer: "fc1/w".into(),
                replicas: 2,
                latency_cycles: 2000,
                conversion_cycles: 2000,
            }],
        };
        let policy = SloPolicy::from_timing(&timing, 10.0, 1.0);
        assert_eq!(policy.target_ms, 10.0);
        assert_eq!(policy.service_fixed_ms, 2.0);
        assert_eq!(policy.service_per_example_ms, 1.0);
        assert_eq!(policy.predicted_service_ms(4), 6.0);
    }

    /// With an SLO target far above the workload, a worker holds the
    /// first request's batch open for late arrivals instead of draining
    /// immediately — the whole set lands in one full batch.
    #[test]
    fn slo_batcher_holds_batches_open_for_late_arrivals() {
        let backend: crate::serve::SharedBackend = Arc::new(SumBackend {
            dim: 3,
            classes: 2,
            fail: false,
        });
        let eng = ServingEngine::start(
            backend,
            ServeOptions {
                max_batch: 4,
                workers: 1,
                slo: Some(SloPolicy::new(10_000.0)),
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let first = eng.submit(vec![0.0; 3]).unwrap();
        // the worker has ~10s of slack: these arrive well inside it
        std::thread::sleep(Duration::from_millis(30));
        let rest: Vec<_> = (0..3).map(|_| eng.submit(vec![0.0; 3]).unwrap()).collect();
        assert!(first.wait().is_ok());
        for p in rest {
            assert!(p.wait().is_ok());
        }
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 4);
        assert_eq!(stats.batches, 1, "batch should close on max_batch, not early");
        assert_eq!(stats.mean_batch, 4.0);
        assert_eq!(stats.slo_ms, Some(10_000.0));
    }

    /// An unmeetable target (0 ms) drains batches immediately and counts
    /// every request as a violation.
    #[test]
    fn slo_violations_are_counted_against_the_target() {
        let backend: crate::serve::SharedBackend = Arc::new(SumBackend {
            dim: 3,
            classes: 2,
            fail: false,
        });
        let eng = ServingEngine::start(
            backend,
            ServeOptions {
                max_batch: 4,
                workers: 1,
                slo: Some(SloPolicy::new(0.0)),
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let _ = eng.infer_many((0..6).map(|_| vec![0.0; 3]).collect()).unwrap();
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 6);
        assert_eq!(stats.slo_violations, 6, "0 ms target: every request violates");
        let row = stats.row();
        assert_eq!(row.slo_ms, Some(0.0));
        assert_eq!(row.slo_violations, 6);
    }
}

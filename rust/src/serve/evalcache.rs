//! Incremental plan-evaluation cache for the ADC planner.
//!
//! The planner's descent perturbs exactly one (layer, slice-group)
//! resolution per candidate, yet scoring a candidate used to re-run the
//! *entire* network over the *entire* holdout. This module keeps the
//! incumbent plan's per-layer boundary activations for the whole holdout
//! ([`EvalCache`]) and exploits two exact structural facts:
//!
//! 1. **Prefix reuse.** Activations are quantized per example row, so a
//!    layer boundary depends only on the resolutions *upstream* of it
//!    (see the evaluation-cache convention in [`crate::reram`]). A
//!    candidate whose bits first diverge from the incumbent at layer `j`
//!    reuses the cached boundaries for layers `0..=j` bit-exactly and
//!    re-runs only layers `j..` — a cache hit per (example, skipped
//!    layer).
//! 2. **Early abort.** Against a fixed accuracy floor, examples are
//!    scored hardest-first (incumbent-incorrect, then ascending logit
//!    margin) and the scan stops as soon as the remaining examples could
//!    not lift the candidate to the floor. Set accuracy is order
//!    invariant and the cutoff only fires when infeasibility is already
//!    decided, so the feasible/infeasible verdict — and therefore the
//!    search's selected plan — is identical to a full scan.
//!
//! Completed feasible candidates double-buffer their recomputed tail
//! boundaries; [`EvalCache::promote`] splices them in when the search
//! accepts that candidate, so an accepted move costs no extra forwards.
//! All scoring shares [`CrossbarBackend::layer_step`] and the one argmax
//! (`serve::argmax_row`) with the from-scratch path, keeping cached and
//! uncached accuracy bit-for-bit equal.

use std::sync::Arc;

use anyhow::Result;

use crate::data::Dataset;
use crate::quant::N_SLICES;
use crate::reram::device::DeviceModel;
use crate::reram::mapper::MappedModel;
use crate::reram::planner::{DeploymentPlan, SearchStats};
use crate::reram::sim::SimScratch;
use crate::util::pool::{parallel_map, with_scratch, worker_threads};

use super::crossbar::{CrossbarBackend, StackMeta};

/// Verdict of one cached candidate evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CachedScore {
    /// whether the candidate holds the floor it was scored against
    /// (always `true` when scored without a floor)
    pub feasible: bool,
    /// measured holdout accuracy; `None` when the scan aborted early —
    /// the candidate was already provably below the floor
    pub accuracy: Option<f64>,
}

/// Tail boundaries of the last feasible *completed* candidate, kept until
/// the search either promotes it (splice, no recompute) or moves on.
#[derive(Debug)]
struct Pending {
    /// the candidate's per-layer resolutions
    bits: Vec<[u32; N_SLICES]>,
    /// first layer whose bits diverge from the incumbent
    diverge: usize,
    /// recomputed boundaries for layers `diverge+1 ..= L`, example-major
    bufs: Vec<Vec<f32>>,
    correct: Vec<bool>,
    accuracy: f64,
}

/// The incumbent plan's holdout state: every layer-boundary activation,
/// per-example correctness, and the hardness order the early-abort scan
/// walks. See the module docs for the reuse and abort arguments.
#[derive(Debug)]
pub struct EvalCache {
    model: Arc<MappedModel>,
    meta: Arc<Vec<StackMeta>>,
    /// the backend's device realization at build time — every cached
    /// boundary and every rescored tail reads through the same (possibly
    /// ideal) device, so the cache stays exact for noisy backends too
    device: Option<Arc<DeviceModel>>,
    labels: Vec<i32>,
    num_classes: usize,
    /// `dims[l]` = input width of layer l; `dims[L]` = logit width
    dims: Vec<usize>,
    /// `acts[0]` = features … `acts[L]` = logits, each example-major
    /// (`n * dims[l]`), under the incumbent bits
    acts: Vec<Vec<f32>>,
    /// incumbent per-layer resolutions (replicas are irrelevant to the
    /// math and deliberately not part of the divergence check)
    bits: Vec<[u32; N_SLICES]>,
    correct: Vec<bool>,
    accuracy: f64,
    /// example indices, hardest first: incumbent-incorrect, then
    /// ascending logit margin — any order is exact, this one aborts soon
    order: Vec<usize>,
    pending: Option<Pending>,
}

/// Run one example from layer `from` (given its layer-`from` input
/// activation) through the stack under per-layer `bits`, returning the
/// boundaries it produces for layers `from+1 ..= L` (the last entry is
/// the logits).
#[allow(clippy::too_many_arguments)]
fn run_tail(
    model: &MappedModel,
    meta: &[StackMeta],
    bits: &[[u32; N_SLICES]],
    device: Option<&DeviceModel>,
    from: usize,
    input: &[f32],
    scratch: &mut SimScratch,
    raw: &mut Vec<i64>,
    codes: &mut Vec<u8>,
) -> Vec<Vec<f32>> {
    let mut act = input.to_vec();
    let mut outs = Vec::with_capacity(model.layers.len() - from);
    for l in from..model.layers.len() {
        let mut out = Vec::new();
        CrossbarBackend::layer_step(
            &model.layers[l],
            &meta[l],
            &bits[l],
            device.map(|d| &d.layers[l]),
            &act,
            scratch,
            raw,
            codes,
            &mut out,
        );
        act.clone_from(&out);
        outs.push(out);
    }
    outs
}

/// Run the examples `idxs` from layer `from` in parallel worker chunks;
/// `input` is the example-major boundary buffer they start from. Returns
/// `(example, tail boundaries)` pairs.
#[allow(clippy::too_many_arguments)]
fn run_examples(
    model: &MappedModel,
    meta: &[StackMeta],
    bits: &[[u32; N_SLICES]],
    device: Option<&DeviceModel>,
    from: usize,
    input: &[f32],
    in_dim: usize,
    idxs: &[usize],
) -> Vec<(usize, Vec<Vec<f32>>)> {
    let threads = worker_threads();
    let chunk = idxs.len().div_ceil(threads.max(1)).max(1);
    let n_chunks = idxs.len().div_ceil(chunk);
    let run_chunk = |ci: usize| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(idxs.len());
        with_scratch::<(SimScratch, Vec<i64>, Vec<u8>), _>(|state| {
            let (scratch, raw, codes) = state;
            let mut part = Vec::with_capacity(hi - lo);
            for &e in &idxs[lo..hi] {
                let row = &input[e * in_dim..(e + 1) * in_dim];
                let tail = run_tail(model, meta, bits, device, from, row, scratch, raw, codes);
                part.push((e, tail));
            }
            part
        })
    };
    if n_chunks <= 1 {
        run_chunk(0)
    } else {
        parallel_map(n_chunks, threads, run_chunk)
            .into_iter()
            .flatten()
            .collect()
    }
}

impl EvalCache {
    /// Build the cache for `backend`'s current plan over `ds`: one full
    /// forward of every example, recording every layer boundary. Counts
    /// `layers x examples` onto `stats.layer_forwards` — the same price a
    /// plain `serve::accuracy` pass would pay, now amortized over every
    /// later candidate.
    pub fn new(
        backend: &CrossbarBackend,
        ds: &Dataset,
        stats: &mut SearchStats,
    ) -> Result<EvalCache> {
        anyhow::ensure!(!ds.is_empty(), "evaluation cache wants a non-empty holdout");
        let model = Arc::clone(backend.mapped());
        let meta = Arc::clone(backend.stack_meta());
        let device = backend.device().cloned();
        let layers = model.layers.len();
        let n = ds.len();
        let dim = ds.dim();
        anyhow::ensure!(
            dim == model.layers[0].rows,
            "dataset dim {dim} != model input {}",
            model.layers[0].rows
        );
        let mut dims = Vec::with_capacity(layers + 1);
        dims.push(dim);
        for l in &model.layers {
            dims.push(l.cols);
        }
        let num_classes = dims[layers];

        let mut feats = vec![0.0f32; n * dim];
        for e in 0..n {
            ds.write_example(e, &mut feats[e * dim..(e + 1) * dim]);
        }
        let bits: Vec<[u32; N_SLICES]> =
            backend.plan().layers.iter().map(|l| l.adc_bits).collect();

        let idxs: Vec<usize> = (0..n).collect();
        let results = run_examples(&model, &meta, &bits, device.as_deref(), 0, &feats, dim, &idxs);
        stats.layer_forwards += layers * n;

        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(layers + 1);
        acts.push(feats);
        for l in 0..layers {
            acts.push(vec![0.0f32; n * dims[l + 1]]);
        }
        for (e, outs) in results {
            for (off, out) in outs.into_iter().enumerate() {
                let d = dims[off + 1];
                acts[off + 1][e * d..(e + 1) * d].copy_from_slice(&out);
            }
        }

        let labels = ds.labels.to_vec();
        let logits = &acts[layers];
        let correct: Vec<bool> = (0..n)
            .map(|e| {
                labels[e] >= 0
                    && super::argmax_row(&logits[e * num_classes..(e + 1) * num_classes]) as i32
                        == labels[e]
            })
            .collect();
        let accuracy = correct.iter().filter(|&&c| c).count() as f64 / n as f64;

        let mut cache = EvalCache {
            model,
            meta,
            device,
            labels,
            num_classes,
            dims,
            acts,
            bits,
            correct,
            accuracy,
            order: Vec::new(),
            pending: None,
        };
        cache.reorder_hardness();
        Ok(cache)
    }

    /// Holdout accuracy of the incumbent plan (bit-for-bit what
    /// `serve::accuracy` measures for it).
    pub fn accuracy(&self) -> f64 {
        self.accuracy
    }

    /// Cached examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Always `false` — construction rejects an empty holdout.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Sort examples hardest-first under the incumbent logits: ascending
    /// margin `logit[label] - max_other`, which puts incorrect examples
    /// (margin <= 0) before barely-correct ones. Padding labels sort
    /// first — they can never become correct.
    fn reorder_hardness(&mut self) {
        let classes = self.num_classes;
        let logits = &self.acts[self.acts.len() - 1];
        let mut keyed: Vec<(f32, usize)> = (0..self.labels.len())
            .map(|e| {
                let r = &logits[e * classes..(e + 1) * classes];
                let key = match self.labels[e] {
                    l if l >= 0 && (l as usize) < classes => {
                        let li = l as usize;
                        let best_other = r
                            .iter()
                            .enumerate()
                            .filter(|&(c, _)| c != li)
                            .map(|(_, &v)| v)
                            .fold(f32::NEG_INFINITY, f32::max);
                        r[li] - best_other
                    }
                    _ => f32::NEG_INFINITY,
                };
                (key, e)
            })
            .collect();
        keyed.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        self.order = keyed.into_iter().map(|(_, e)| e).collect();
    }

    /// Score a candidate plan against the cache. Layers before the first
    /// diverging resolution are cache hits; the rest re-run. With a
    /// `floor`, the hardest-first scan aborts as soon as the candidate
    /// provably cannot reach it (`stats.aborted_evals`); without one it
    /// always completes. A completed feasible candidate's tail
    /// boundaries are kept for a free [`Self::promote`].
    pub fn score(
        &mut self,
        cand: &DeploymentPlan,
        floor: Option<f64>,
        stats: &mut SearchStats,
    ) -> Result<CachedScore> {
        let layers = self.model.layers.len();
        anyhow::ensure!(
            cand.layers.len() == layers,
            "candidate has {} layers, cache has {layers}",
            cand.layers.len()
        );
        let cand_bits: Vec<[u32; N_SLICES]> = cand.layers.iter().map(|l| l.adc_bits).collect();
        let n = self.labels.len();
        let Some(diverge) = (0..layers).find(|&l| cand_bits[l] != self.bits[l]) else {
            // the incumbent itself: every (example, layer) is a hit
            stats.cache_hits += layers * n;
            return Ok(CachedScore {
                feasible: floor.is_none_or(|f| self.accuracy >= f),
                accuracy: Some(self.accuracy),
            });
        };
        stats.cache_hits += diverge * n;

        let tail = layers - diverge;
        let mut bufs: Vec<Vec<f32>> = (0..tail)
            .map(|off| vec![0.0f32; n * self.dims[diverge + 1 + off]])
            .collect();
        let mut correct = vec![false; n];
        let mut correct_so_far = 0usize;
        let mut scanned = 0usize;
        let block = (n / 8).clamp(32, 256);
        while scanned < n {
            if let Some(f) = floor {
                // even a perfect tail cannot reach the floor: the final
                // accuracy is bounded by this same ratio, so the verdict
                // is already decided
                if ((correct_so_far + (n - scanned)) as f64) / (n as f64) < f {
                    stats.aborted_evals += 1;
                    return Ok(CachedScore {
                        feasible: false,
                        accuracy: None,
                    });
                }
            }
            let hi = (scanned + block).min(n);
            let idxs = &self.order[scanned..hi];
            let results = run_examples(
                &self.model,
                &self.meta,
                &cand_bits,
                self.device.as_deref(),
                diverge,
                &self.acts[diverge],
                self.dims[diverge],
                idxs,
            );
            stats.layer_forwards += tail * idxs.len();
            for (e, outs) in results {
                let logits = outs.last().expect("tail has at least one layer");
                let ok = self.labels[e] >= 0
                    && super::argmax_row(logits) as i32 == self.labels[e];
                correct[e] = ok;
                if ok {
                    correct_so_far += 1;
                }
                for (off, out) in outs.into_iter().enumerate() {
                    let d = self.dims[diverge + 1 + off];
                    bufs[off][e * d..(e + 1) * d].copy_from_slice(&out);
                }
            }
            scanned = hi;
        }

        let accuracy = correct_so_far as f64 / n as f64;
        let feasible = floor.is_none_or(|f| accuracy >= f);
        if feasible {
            self.pending = Some(Pending {
                bits: cand_bits,
                diverge,
                bufs,
                correct,
                accuracy,
            });
        }
        Ok(CachedScore {
            feasible,
            accuracy: Some(accuracy),
        })
    }

    /// Make `cand` the incumbent. When its completed evaluation is still
    /// double-buffered the tail boundaries splice in for free; otherwise
    /// (never scored, or a later candidate overwrote the buffer) one full
    /// no-floor [`Self::score`] re-derives them. Clears the buffer either
    /// way — a new incumbent invalidates any pending tail.
    pub fn promote(&mut self, cand: &DeploymentPlan, stats: &mut SearchStats) -> Result<()> {
        let cand_bits: Vec<[u32; N_SLICES]> = cand.layers.iter().map(|l| l.adc_bits).collect();
        if cand_bits == self.bits {
            self.pending = None;
            return Ok(());
        }
        match self.pending.take() {
            Some(p) if p.bits == cand_bits => {
                for (off, buf) in p.bufs.into_iter().enumerate() {
                    self.acts[p.diverge + 1 + off] = buf;
                }
                self.correct = p.correct;
                self.accuracy = p.accuracy;
                self.bits = cand_bits;
                self.reorder_hardness();
                Ok(())
            }
            _ => {
                let rescored = self.score(cand, None, stats)?;
                debug_assert!(rescored.feasible, "no-floor scores always complete");
                self.promote(cand, stats)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::ResolutionPolicy;
    use crate::serve::{self, dense_stack, DenseLayer, InferenceBackend, ReferenceBackend};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn toy_stack(rng: &mut Rng) -> Vec<DenseLayer> {
        let w1 = Tensor::new(vec![20, 9], rng.normal_vec(180, 0.15)).unwrap();
        let w2 = Tensor::new(vec![9, 5], rng.normal_vec(45, 0.15)).unwrap();
        let b1 = Tensor::new(vec![9], rng.normal_vec(9, 0.02)).unwrap();
        let b2 = Tensor::new(vec![5], rng.normal_vec(5, 0.02)).unwrap();
        dense_stack(&[("fc1/w".into(), w1), ("fc2/w".into(), w2)], &[b1, b2]).unwrap()
    }

    fn oracle_dataset(stack: &[DenseLayer], n: usize, seed: u64) -> Dataset {
        let dim = stack[0].w.shape()[0];
        let classes = stack[stack.len() - 1].w.shape()[1];
        let mut rng = Rng::new(seed);
        let feats: Vec<f32> = (0..n * dim).map(|_| rng.next_f32()).collect();
        let x = Tensor::new(vec![n, dim], feats.clone()).unwrap();
        let reference = ReferenceBackend::new("oracle", stack).unwrap();
        let logits = reference.infer_batch(&x).unwrap();
        let labels: Vec<i32> = (0..n)
            .map(|i| {
                super::super::argmax_row(&logits.data()[i * classes..(i + 1) * classes]) as i32
            })
            .collect();
        Dataset {
            features: Arc::new(feats),
            labels: Arc::new(labels),
            example_shape: vec![dim],
            num_classes: classes,
            source: "oracle".into(),
        }
    }

    #[test]
    fn cached_scores_match_full_accuracy_passes() {
        let mut rng = Rng::new(61);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 40, 5);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let mut stats = SearchStats::default();
        let mut cache = EvalCache::new(&be, &ds, &mut stats).unwrap();
        assert_eq!(stats.layer_forwards, 2 * 40, "build is one full pass");
        assert_eq!(cache.len(), 40);
        assert!(!cache.is_empty());
        assert_eq!(
            cache.accuracy(),
            serve::accuracy(&be, &ds).unwrap().accuracy,
            "incumbent accuracy must be the full-pass measure"
        );

        // the incumbent itself: a pure cache hit, no forwards
        let before = stats.layer_forwards;
        let s = cache.score(be.plan(), None, &mut stats).unwrap();
        assert!(s.feasible);
        assert_eq!(s.accuracy, Some(cache.accuracy()));
        assert_eq!(stats.layer_forwards, before);
        assert_eq!(stats.cache_hits, 2 * 40);

        // candidates diverging at layer 1 and at layer 0 both agree
        // bit-for-bit with an uncached replan + accuracy pass
        for (l, bits) in [(1usize, [2u32, 2, 2, 1]), (0, [1, 1, 1, 1])] {
            let mut cand = be.plan().clone();
            cand.layers[l].adc_bits = bits;
            let before = stats.layer_forwards;
            let s = cache.score(&cand, None, &mut stats).unwrap();
            let direct = serve::accuracy(
                &be.replan("cand", cand.clone()).unwrap(),
                &ds,
            )
            .unwrap()
            .accuracy;
            assert_eq!(s.accuracy, Some(direct), "diverge at layer {l}");
            assert_eq!(
                stats.layer_forwards - before,
                (2 - l) * 40,
                "only layers >= {l} re-run"
            );
        }
    }

    #[test]
    fn abort_fires_only_when_provably_infeasible() {
        let mut rng = Rng::new(67);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 48, 7);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let mut stats = SearchStats::default();
        let mut cache = EvalCache::new(&be, &ds, &mut stats).unwrap();
        let mut cand = be.plan().clone();
        cand.layers[0].adc_bits = [1, 1, 1, 1];

        // an unreachable floor aborts before any forward runs
        let before = stats.layer_forwards;
        let s = cache.score(&cand, Some(2.0), &mut stats).unwrap();
        assert!(!s.feasible);
        assert_eq!(s.accuracy, None);
        assert_eq!(stats.aborted_evals, 1);
        assert_eq!(stats.layer_forwards, before, "aborted at zero scanned");

        // a floor of zero always completes, with the true accuracy
        let s = cache.score(&cand, Some(0.0), &mut stats).unwrap();
        assert!(s.feasible);
        let direct = serve::accuracy(&be.replan("cand", cand.clone()).unwrap(), &ds)
            .unwrap()
            .accuracy;
        assert_eq!(s.accuracy, Some(direct));
        assert_eq!(stats.aborted_evals, 1, "no new abort");
    }

    #[test]
    fn promote_splices_and_fallback_rescores() {
        let mut rng = Rng::new(71);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 32, 9);
        let be = CrossbarBackend::new("xb", &stack, ResolutionPolicy::Lossless).unwrap();
        let mut stats = SearchStats::default();
        let mut cache = EvalCache::new(&be, &ds, &mut stats).unwrap();

        // promote straight from the double buffer: no extra forwards
        let mut cand = be.plan().clone();
        cand.layers[1].adc_bits = [3, 3, 3, 1];
        let s = cache.score(&cand, None, &mut stats).unwrap();
        let before = stats.layer_forwards;
        cache.promote(&cand, &mut stats).unwrap();
        assert_eq!(stats.layer_forwards, before, "buffered promote is free");
        assert_eq!(cache.accuracy(), s.accuracy.unwrap());
        // the promoted plan is now the incumbent — scoring it is a hit
        let s2 = cache.score(&cand, None, &mut stats).unwrap();
        assert_eq!(s2.accuracy, Some(cache.accuracy()));

        // promoting a plan that was never scored falls back to one full
        // rescore and still lands on the exact uncached measure
        let mut other = be.plan().clone();
        other.layers[0].adc_bits = [2, 2, 2, 2];
        cache.promote(&other, &mut stats).unwrap();
        let direct = serve::accuracy(&be.replan("other", other.clone()).unwrap(), &ds)
            .unwrap()
            .accuracy;
        assert_eq!(cache.accuracy(), direct);
    }
}

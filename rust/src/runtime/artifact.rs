//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the contract between the AOT compile path and the Rust
//! coordinator: per model it records the canonical parameter layout (three
//! groups: `qw` quantized weights, `tp` trainable plain params, `st` batch-
//! norm state) and, per graph, the exact flattened input/output tensor
//! specs the executable expects.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// dtype of a graph I/O tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One input or output tensor of a graph.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let name = j.req("name")?.as_str().context("spec name")?.to_string();
        let shape = j
            .req("shape")?
            .as_arr()
            .context("spec shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = match j.req("dtype")?.as_str() {
            Some("f32") => DType::F32,
            Some("i32") => DType::I32,
            other => anyhow::bail!("unknown dtype {other:?}"),
        };
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One AOT graph: artifact path + I/O layout.
#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl GraphSpec {
    fn from_json(dir: &Path, j: &Json) -> Result<Self> {
        let rel = j.req("path")?.as_str().context("graph path")?;
        let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
            j.req(key)?
                .as_arr()
                .context("spec list")?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(GraphSpec {
            path: dir.join(rel),
            inputs: parse_list("inputs")?,
            outputs: parse_list("outputs")?,
        })
    }

    /// Index of a named input (errors list what exists — debugging aid).
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| {
                format!(
                    "graph has no input {name:?}; inputs: {:?}",
                    self.inputs.iter().map(|s| &s.name).collect::<Vec<_>>()
                )
            })
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("graph has no output {name:?}"))
    }
}

/// One parameter tensor in the canonical layout.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Gaussian init std; 0.0 means constant `init_const`.
    pub init_std: f32,
    pub init_const: f32,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(ParamEntry {
            name: j.req("name")?.as_str().context("param name")?.to_string(),
            shape: j
                .req("shape")?
                .as_arr()
                .context("param shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?,
            init_std: j.req("init_std")?.as_f64().context("init_std")? as f32,
            init_const: j.req("init_const")?.as_f64().context("init_const")? as f32,
        })
    }
}

/// One model entry: parameter groups + graphs.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub qw: Vec<ParamEntry>,
    pub tp: Vec<ParamEntry>,
    pub st: Vec<ParamEntry>,
    pub graphs: std::collections::BTreeMap<String, GraphSpec>,
}

impl ModelEntry {
    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs.get(name).with_context(|| {
            format!(
                "model {} has no graph {name:?}; have {:?}",
                self.name,
                self.graphs.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Total quantized-weight element count (the paper's sparsity universe).
    pub fn qw_numel(&self) -> usize {
        self.qw.iter().map(|p| p.numel()).sum()
    }

    /// Per-example input element count.
    pub fn input_numel(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: std::collections::BTreeMap<String, ModelEntry>,
    pub kernels: std::collections::BTreeMap<String, GraphSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let j = crate::util::json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;

        let mut models = std::collections::BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj().context("models")? {
            let params = m.req("params")?;
            let parse_group = |key: &str| -> Result<Vec<ParamEntry>> {
                params
                    .req(key)?
                    .as_arr()
                    .context("param group")?
                    .iter()
                    .map(ParamEntry::from_json)
                    .collect()
            };
            let mut graphs = std::collections::BTreeMap::new();
            for (gname, g) in m.req("graphs")?.as_obj().context("graphs")? {
                graphs.insert(gname.clone(), GraphSpec::from_json(dir, g)?);
            }
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    batch: m.req("batch")?.as_usize().context("batch")?,
                    input_shape: m
                        .req("input_shape")?
                        .as_arr()
                        .context("input_shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<Vec<_>>>()?,
                    num_classes: m.req("num_classes")?.as_usize().context("nc")?,
                    qw: parse_group("qw")?,
                    tp: parse_group("tp")?,
                    st: parse_group("st")?,
                    graphs,
                },
            );
        }

        let mut kernels = std::collections::BTreeMap::new();
        for (name, g) in j.req("kernels")?.as_obj().context("kernels")? {
            kernels.insert(name.clone(), GraphSpec::from_json(dir, g)?);
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            models,
            kernels,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).with_context(|| {
            format!(
                "manifest has no model {name:?}; have {:?} (re-run `make artifacts`?)",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(dir) = manifest_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let mlp = m.model("mlp").unwrap();
        assert_eq!(mlp.qw.len(), 2);
        assert_eq!(mlp.qw[0].name, "fc1/w");
        assert_eq!(mlp.qw[0].shape, vec![784, 300]);
        assert!(mlp.qw[0].init_std > 0.0);
        let train = mlp.graph("train").unwrap();
        // layout: qw tp st vq vt mask x y + 4 scalars
        assert_eq!(train.inputs.len(), 2 + 2 + 0 + 2 + 2 + 2 + 2 + 4);
        assert_eq!(train.inputs.last().unwrap().name, "alpha_bl1");
        assert_eq!(train.input_index("x").is_ok(), true);
        assert!(train.path.exists());
        // outputs end with the 5 metrics
        let names: Vec<_> = train.outputs.iter().rev().take(5).map(|s| s.name.clone()).collect();
        assert_eq!(names, ["correct", "bl1", "l1", "ce", "loss"]);
    }

    #[test]
    fn kernel_entries_present() {
        let Some(dir) = manifest_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.kernels.contains_key("quantize_1m"));
        assert!(m.kernels.contains_key("crossbar_tile"));
    }
}

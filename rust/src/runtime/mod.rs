//! PJRT runtime: load AOT artifacts and execute them on the request path.
//!
//! `python/compile/aot.py` lowers every graph to **HLO text** (the only
//! interchange xla_extension 0.5.1 accepts from jax >= 0.5 — serialized
//! protos carry 64-bit instruction ids it rejects) plus `manifest.json`
//! describing inputs/outputs and the parameter layout. This module:
//!
//! * [`Engine`] — owns the `PjRtClient` and an executable cache keyed by
//!   artifact path (compiling a graph once per process).
//! * [`artifact`] — typed view of `manifest.json`.
//! * [`Executable::run`] — literal-in/literal-out execution (analysis,
//!   one-shot graphs).
//! * [`Executable::run_buffers`] — buffer-in/buffer-out execution: the
//!   training loop keeps its state device-resident between steps and only
//!   syncs to host for checkpoints/metrics (the L3 hot-path optimization,
//!   DESIGN.md §Perf).

pub mod artifact;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

pub use artifact::{GraphSpec, Manifest, ModelEntry, ParamEntry, TensorSpec};

/// PJRT client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<Executable>>>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.client.platform_name())
            .finish_non_exhaustive()
    }
}

impl Engine {
    /// CPU PJRT client (the testbed backend; see DESIGN.md §Hardware).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached per path).
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let exe = std::sync::Arc::new(Executable { exe });
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Copy a host literal to the device (for `run_buffers` state setup).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }
}

/// A compiled graph. All AOT graphs are lowered with `return_tuple=True`,
/// so execution yields a single tuple literal that we decompose.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").finish_non_exhaustive()
    }
}

impl Executable {
    /// Literal-in, literal-out execution (host round-trip both ways).
    /// Accepts owned or borrowed literals so callers can reuse resident
    /// state without cloning.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute::<L>(inputs)?;
        let tuple = out[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Buffer-in execution; returns the raw output tuple buffer, still on
    /// device. Use [`Self::split_outputs`] or keep feeding buffers.
    pub fn run_buffers(&self, inputs: &[xla::PjRtBuffer]) -> Result<xla::PjRtBuffer> {
        let mut out = self.exe.execute_b::<xla::PjRtBuffer>(inputs)?;
        Ok(out.swap_remove(0).swap_remove(0))
    }

    /// Sync a tuple output buffer to host literals.
    pub fn split_outputs(&self, tuple: &xla::PjRtBuffer) -> Result<Vec<xla::Literal>> {
        Ok(tuple.to_literal_sync()?.to_tuple()?)
    }
}

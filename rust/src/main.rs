//! `bitslice-reram` — the L3 coordinator CLI.
//!
//! Subcommands (all flags optional; see `config::RunConfig` for defaults):
//!
//! ```text
//! train      --model mlp|vgg11|resnet20 --method baseline|pruned|l1|bl1
//!            [--steps N --pretrain-steps N --lr F --alpha-l1 F --alpha-bl1 F
//!             --prune-fraction F --seed N --trace-every N --out-dir D ...]
//! eval       --checkpoint runs/mlp-bl1/checkpoint
//! analyze    --checkpoint ...            sparsity census + required ADC bits
//! deploy     --checkpoint ... [--percentile 0.999]   crossbar mapping + Table 3
//!            [--plan-budget 0.5 --plan-examples 256]  per-layer ADC planner
//!            (budget in accuracy percentage points; writes <out>/plan.json;
//!            the planner search itself runs for mlp checkpoints only)
//!            [--reorder]  map with the wordline/column reorder pass
//!            (active-row compaction + zero-column clustering; prints the
//!            reorder table and writes <out>/reorder.json)
//!            [--replicate-budget 2.0]  water-fill extra crossbar replicas
//!            onto the pipeline's bottleneck layers (unit: multiples of the
//!            bottleneck layer's fabricated cells; per-layer
//!            latency/replica/throughput rows land in plan.json)
//!            [--audit]  print the static audit table and write
//!            <out>/audit.json beside the other deploy artifacts
//!            [--device-sigma 0.3 --fault-rate 0.01 --mc-trials 8]
//!            device non-idealities (reram::device): run the Monte-Carlo
//!            noise study at the deployed resolutions (writes
//!            <out>/noise.json) and make the planner search reject plans
//!            that only hold the budget on perfect devices
//! audit      --checkpoint ... | --fixture planted|bottleneck
//!            [--reorder --replicate-budget F --percentile F]
//!            static verification only: map, plan, audit, exit non-zero on
//!            any Error-severity diagnostic (--fixture needs the `bench`
//!            feature; it audits the seeded fixture stacks with no
//!            checkpoint or artifacts required — the CI smoke path)
//! reproduce  table1|table2|table3|fig2 [--quick] [table2: --model vgg11]
//! bench-adc                              ADC cost model sweep (1..8 bits)
//! ```
//!
//! # Verifying a deployment
//!
//! Every deployment artifact this CLI builds is statically verified by
//! `reram::audit` before anything runs: `deploy` audits the final
//! (mapping, plan) pair inside `harness::deploy_report` and fails on any
//! Error-severity diagnostic, and serving construction re-checks the
//! artifact it is handed. The `audit` subcommand runs *only* that pass —
//! walk every tile, permutation, plan row and replica handle, print the
//! findings table (`report::audit_table`), write `<out>/audit.json`, and
//! exit non-zero if the artifact is faulty. The diagnostic catalogue
//! (stable `A0xx` codes → the convention each enforces) lives in the
//! `reram` module docs.
//!
//! Python never runs here: all compute graphs come from `artifacts/`
//! (`make artifacts`), loaded through the PJRT CPU client.

use anyhow::{Context, Result};

use bitslice_reram::config::RunConfig;
use bitslice_reram::coordinator::{checkpoint, ModelState};
use bitslice_reram::data::Dataset;
use bitslice_reram::harness;
use bitslice_reram::report;
use bitslice_reram::reram::planner::{self, PlannerConfig};
use bitslice_reram::reram::{audit, energy, mapper, timing, AdcModel, ResolutionPolicy};
use bitslice_reram::runtime::{Engine, Manifest};
use bitslice_reram::serve::{self, CrossbarBackend, InferenceBackend, ReferenceBackend};
use bitslice_reram::sparsity;
use bitslice_reram::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("deploy") => cmd_deploy(&args),
        Some("audit") => cmd_audit(&args),
        Some("reproduce") => cmd_reproduce(&args),
        Some("bench-adc") => cmd_bench_adc(&args),
        other => {
            eprintln!(
                "usage: bitslice-reram <train|eval|analyze|deploy|audit|reproduce|bench-adc> \
                 [flags]"
            );
            anyhow::bail!("unknown subcommand {other:?}");
        }
    }
}

fn engine_and_manifest(cfg: &RunConfig) -> Result<(Engine, Manifest)> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let engine = Engine::cpu()?;
    Ok((engine, manifest))
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    args.finish()?;
    let (engine, manifest) = engine_and_manifest(&cfg)?;
    let res = harness::run_training(&engine, &manifest, cfg, true)?;
    let row = res.method_row();
    println!(
        "{}",
        report::sparsity_table(
            &format!(
                "{} on {} ({})",
                res.cfg.model, res.cfg.dataset, res.dataset_source
            ),
            &[row]
        )
    );
    if let Some(dir) = &res.checkpoint_dir {
        println!("checkpoint: {}", dir.display());
    }
    Ok(())
}

/// Load a checkpoint into a fresh state for its model.
fn load_checkpoint(
    manifest: &Manifest,
    dir: &std::path::Path,
) -> Result<(ModelState, checkpoint::Meta)> {
    let meta = checkpoint::load_meta(dir)?;
    let entry = manifest.model(&meta.model)?;
    let mut state = ModelState::init(entry, 0);
    let meta = checkpoint::load(dir, &mut state)?;
    Ok((state, meta))
}

fn cmd_eval(args: &Args) -> Result<()> {
    let ckpt = args
        .str_opt("checkpoint")
        .context("--checkpoint is required")?;
    let cfg = RunConfig::from_args(args)?;
    args.finish()?;
    let (engine, manifest) = engine_and_manifest(&cfg)?;
    let (state, meta) = load_checkpoint(&manifest, std::path::Path::new(&ckpt))?;
    let dataset_kind = if meta.model == "mlp" { "mnist" } else { "cifar10" };
    let test_ds = Dataset::auto(
        dataset_kind,
        &cfg.data_dir,
        false,
        cfg.test_examples,
        cfg.seed.wrapping_add(1),
    )?;
    let res = bitslice_reram::coordinator::evaluator::evaluate(
        &engine, &manifest, &meta.model, &state, &test_ds,
    )?;
    println!(
        "{} ({} @ step {}): accuracy {:.2}% on {} ({} examples)",
        meta.model,
        meta.method,
        meta.step,
        res.accuracy * 100.0,
        test_ds.source,
        res.examples
    );
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let ckpt = args
        .str_opt("checkpoint")
        .context("--checkpoint is required")?;
    let cfg = RunConfig::from_args(args)?;
    args.finish()?;
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let (state, meta) = load_checkpoint(&manifest, std::path::Path::new(&ckpt))?;
    let stats = sparsity::census(&state.qws);
    println!(
        "{}",
        report::sparsity_table(
            &format!("{} ({}) slice sparsity", meta.model, meta.method),
            &[report::MethodRow {
                method: meta.method.clone(),
                accuracy: f64::NAN,
                stats: stats.clone(),
            }]
        )
    );
    let entry = manifest.model(&meta.model)?;
    let deploy = harness::deploy_report(
        &state.named_qws(entry),
        ResolutionPolicy::Percentile(0.999),
        None,
        None,
    )?;
    println!("measured ADC requirements (p99.9 of bitline currents):");
    println!("{}", report::resolution_summary(deploy.deployed_bits));
    Ok(())
}

fn cmd_deploy(args: &Args) -> Result<()> {
    let ckpt = args
        .str_opt("checkpoint")
        .context("--checkpoint is required")?;
    let pct = args.f32_or("percentile", 0.999)? as f64;
    // planner knobs: accuracy-drop budget in percentage points and the
    // held-out example cap per candidate evaluation
    let plan_budget = args.f32_or("plan-budget", 0.5)? as f64 / 100.0;
    let plan_examples = args.usize_or("plan-examples", 256)?;
    // map-time wordline/column reordering (active-row compaction +
    // zero-column clustering)
    let reorder_cfg = if args.flag("reorder") {
        Some(bitslice_reram::reram::ReorderConfig::default())
    } else {
        None
    };
    // replication budget: multiples of the bottleneck layer's fabricated
    // cells, water-filled onto bottleneck layers for pipeline throughput
    let replicate_budget = args.f32_or("replicate-budget", 0.0)? as f64;
    let replicate_budget = (replicate_budget > 0.0).then_some(replicate_budget);
    // device non-idealities: lognormal conductance spread + stuck-at
    // faults, Monte-Carlo-sampled over --mc-trials seeded realizations
    // (reram::device). When either knob is nonzero the deploy runs the
    // noise study and the planner search validates every candidate under
    // the same noise (PlannerConfig::device).
    let device_cfg = bitslice_reram::reram::DeviceConfig {
        sigma: args.f32_or("device-sigma", 0.0)?,
        fault_rate: args.f32_or("fault-rate", 0.0)?,
        ..Default::default()
    };
    let mc_trials = args.usize_or("mc-trials", 8)?;
    // print the static verifier's findings and write <out>/audit.json
    // (the audit itself always runs inside deploy_report)
    let show_audit = args.flag("audit");
    let cfg = RunConfig::from_args(args)?;
    args.finish()?;
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let (state, meta) = load_checkpoint(&manifest, std::path::Path::new(&ckpt))?;
    let entry = manifest.model(&meta.model)?;
    let deploy = harness::deploy_report(
        &state.named_qws(entry),
        ResolutionPolicy::Percentile(pct),
        reorder_cfg,
        replicate_budget,
    )?;
    println!(
        "deployment of {} ({}): {} crossbars (128x128, 2-bit cells, differential; \
         {} fully-zero tiles not fabricated{})",
        meta.model,
        meta.method,
        deploy.crossbars,
        deploy.unprogrammed_tiles,
        if deploy.reorder.is_some() {
            "; wordline/column reordered"
        } else {
            ""
        }
    );
    println!(
        "{}",
        report::storage_table("crossbar storage (per layer)", &deploy.storage)
    );
    std::fs::create_dir_all(&cfg.out_dir)?;
    let storage_path = cfg.out_dir.join("storage.json");
    std::fs::write(&storage_path, report::storage_json(&deploy.storage).to_string())?;
    println!("storage census written to {}", storage_path.display());
    if show_audit {
        println!(
            "{}",
            report::audit_table("deployment audit (static verifier)", &deploy.audit)
        );
        let audit_path = cfg.out_dir.join("audit.json");
        std::fs::write(&audit_path, report::audit_json(&deploy.audit).to_string())?;
        println!("audit report written to {}", audit_path.display());
    }
    if let Some(rows) = &deploy.reorder {
        println!(
            "{}",
            report::reorder_table("wordline/column reorder (vs natural order)", rows)
        );
        let reorder_path = cfg.out_dir.join("reorder.json");
        std::fs::write(&reorder_path, report::reorder_json(rows).to_string())?;
        println!("reorder census written to {}", reorder_path.display());
    }
    println!(
        "lossless ADC bits (LSB..MSB): {:?}; deployed at p{:.1}: {:?}",
        deploy.lossless_bits,
        pct * 100.0,
        deploy.deployed_bits
    );
    println!("{}", report::adc_table(&deploy.rows));
    let (e, t, a) = deploy.savings;
    println!(
        "whole-model ADC savings vs 8-bit baseline: energy {e:.1}x, time {t:.2}x, area {a:.1}x"
    );
    println!(
        "{}",
        report::plan_table(
            &format!("per-layer deployment at p{:.1} (each layer's own census)", pct * 100.0),
            &deploy.plan_rows
        )
    );
    let (pe, pt, pa) = deploy.plan_savings;
    println!("per-layer plan savings: energy {pe:.1}x, time {pt:.2}x, area {pa:.1}x");
    println!(
        "{}",
        report::timing_table("pipeline timing (latency x replicas)", &deploy.timing)
    );
    if deploy.replica_cells > 0 {
        println!(
            "replication spent {} fabricated cells on extra copies of the bottleneck layers",
            deploy.replica_cells
        );
    }

    // Functional validation through the unified backend seam: deployed
    // crossbar resolution vs the exact quantized reference on the test
    // set, then the budgeted per-layer planner search.
    if meta.model == "mlp" {
        let test_ds = Dataset::auto(
            "mnist",
            &cfg.data_dir,
            false,
            cfg.test_examples,
            cfg.seed.wrapping_add(1),
        )?;
        let stack = serve::dense_stack(&state.named_qws(entry), &state.tps)?;
        // deploy the report's own mapping (already reordered when the
        // pass carried permutations; `deploy.reorder` is None when it
        // normalized to the identity) — no re-map, no second guard
        let name = if deploy.reorder.is_some() {
            "crossbar-reordered"
        } else {
            "crossbar"
        };
        let plan =
            planner::DeploymentPlan::uniform_for(&deploy.mapped, deploy.deployed_bits);
        let xbar = CrossbarBackend::from_mapping(name, deploy.mapped, &stack, plan)?;
        let reference = ReferenceBackend::new("reference", &stack)?;
        let xa = serve::accuracy(&xbar, &test_ds)?;
        let ra = serve::accuracy(&reference, &test_ds)?;
        println!(
            "functional accuracy on {} ({} examples): {} {:.2}% vs {} {:.2}%",
            test_ds.source,
            xa.examples,
            xbar.name(),
            xa.accuracy * 100.0,
            reference.name(),
            ra.accuracy * 100.0,
        );

        // Monte-Carlo noise study: accuracy over seeded device
        // realizations at the deployed resolutions, plus where the
        // conductance spread lands per layer and slice group
        if !device_cfg.is_ideal() {
            let row = harness::noise_report(&xbar, &test_ds, device_cfg, mc_trials)?;
            println!(
                "{}",
                report::noise_table(
                    &format!(
                        "Monte-Carlo noise study ({mc_trials} trials, sigma {:.2}, \
                         fault rate {:.3})",
                        device_cfg.sigma, device_cfg.fault_rate
                    ),
                    std::slice::from_ref(&row)
                )
            );
            let noise_path = cfg.out_dir.join("noise.json");
            std::fs::write(
                &noise_path,
                report::noise_json(std::slice::from_ref(&row)).to_string(),
            )?;
            println!("noise study written to {}", noise_path.display());
        }

        let planner_cfg = PlannerConfig {
            accuracy_budget: plan_budget,
            eval_examples: plan_examples,
            // record reorder intent only when the mapping actually carries
            // permutations (the pass may normalize to the identity)
            reorder: if xbar.is_reordered() { reorder_cfg } else { None },
            // hand the replication budget to the search itself: the joint
            // pass trades ADC bits against replicas under one cell budget
            // instead of water-filling after the fact
            replicate_budget,
            // with non-ideality knobs set, the search must also hold the
            // floor on the seeded device realizations — perfect-device
            // plans are rejected (SearchStats::noise_rejections)
            device: (!device_cfg.is_ideal()).then_some(
                bitslice_reram::reram::DeviceValidation {
                    config: device_cfg,
                    trials: mc_trials,
                    ..Default::default()
                },
            ),
            ..PlannerConfig::default()
        };
        // reuse xbar's mapping and the reference's quantized weights —
        // the search itself never re-maps
        let psr = harness::plan_search_report(&xbar, &reference, &test_ds, &planner_cfg)?;
        let search = &psr.search;
        if !search.within_budget {
            println!(
                "warning: no plan within the {:.2} pt budget (best drop {:.2} pt)",
                plan_budget * 100.0,
                (search.baseline_accuracy - search.accuracy) * 100.0
            );
        }
        // the pre-search deployment above already hard-failed on a
        // too-small budget; the searched plan can still underflow if the
        // search moved the bottleneck to a bigger layer — warn, the plan
        // itself is sound
        if let Some(f) = replicate_budget {
            let diag = audit::replica_budget_diagnostic(
                xbar.mapped(),
                &search.plan,
                f,
                search.replica_cells,
            );
            if let Some(d) = diag {
                println!("warning: {d} (searched plan)");
            }
        }
        println!(
            "{}",
            report::plan_table(
                &format!(
                    "planned deployment (budget {:.2} pt, {} candidate evaluations)",
                    plan_budget * 100.0,
                    search.stats.evaluations
                ),
                &psr.plan_rows
            )
        );
        println!("search cost: {}", report::search_stats_line(&search.stats));
        println!(
            "{}",
            report::timing_table("planned pipeline timing", &psr.timing)
        );
        let (se, st, sa) = search.savings();
        println!(
            "planned accuracy {:.2}% (reference {:.2}%); savings: energy {se:.1}x, \
             time {st:.2}x, area {sa:.1}x",
            search.accuracy * 100.0,
            search.baseline_accuracy * 100.0,
        );
        let json = report::planner_json(
            &psr.plan_rows,
            search.baseline_accuracy,
            search.accuracy,
            plan_budget,
            search.savings(),
            &search.stats,
            &psr.timing,
        );
        std::fs::create_dir_all(&cfg.out_dir)?;
        let path = cfg.out_dir.join("plan.json");
        std::fs::write(&path, json.to_string())?;
        println!("plan report written to {}", path.display());
    } else {
        println!(
            "(planner skipped: --plan-budget/--plan-examples drive the MLP host stack only)"
        );
    }
    Ok(())
}

/// The seeded fixture stacks the CI smoke audit drives — no checkpoint or
/// XLA artifacts needed. Compiled only with the `bench` feature, which
/// exposes `util::fixtures` outside tests.
#[cfg(feature = "bench")]
fn fixture_stack(
    which: &str,
) -> Result<(String, Vec<(String, bitslice_reram::tensor::Tensor)>)> {
    use bitslice_reram::util::fixtures;
    let stack = match which {
        "planted" => {
            let train = bitslice_reram::data::synthetic::mnist(2000, 11);
            fixtures::planted_class_stack(&train)
        }
        "bottleneck" => fixtures::bottleneck_stack(0xF1A7),
        other => anyhow::bail!("--fixture {other:?} (planted|bottleneck)"),
    };
    let named = stack.iter().map(|l| (l.name.clone(), l.w.clone())).collect();
    Ok((format!("fixture {which}"), named))
}

#[cfg(not(feature = "bench"))]
fn fixture_stack(
    which: &str,
) -> Result<(String, Vec<(String, bitslice_reram::tensor::Tensor)>)> {
    anyhow::bail!(
        "--fixture {which} needs the `bench` feature: \
         cargo run --features bench -- audit --fixture {which}"
    )
}

/// Static verification only: map, plan, audit, report — no inference. The
/// process exits non-zero on any Error-severity diagnostic, so CI can run
/// this as a gate.
fn cmd_audit(args: &Args) -> Result<()> {
    let ckpt = args.str_opt("checkpoint");
    let fixture = args.str_opt("fixture");
    let pct = args.f32_or("percentile", 0.999)? as f64;
    let reorder_cfg = if args.flag("reorder") {
        Some(bitslice_reram::reram::ReorderConfig::default())
    } else {
        None
    };
    let replicate_budget = args.f32_or("replicate-budget", 0.0)? as f64;
    let cfg = RunConfig::from_args(args)?;
    args.finish()?;

    let (label, named) = match (&ckpt, &fixture) {
        (Some(dir), None) => {
            let manifest = Manifest::load(&cfg.artifacts_dir)?;
            let (state, meta) = load_checkpoint(&manifest, std::path::Path::new(dir))?;
            let entry = manifest.model(&meta.model)?;
            (
                format!("{} ({})", meta.model, meta.method),
                state.named_qws(entry),
            )
        }
        (None, Some(fix)) => fixture_stack(fix)?,
        _ => anyhow::bail!("audit wants exactly one of --checkpoint or --fixture"),
    };

    let mapped = mapper::map_model_with(&named, reorder_cfg)?;
    let mut plan =
        planner::DeploymentPlan::from_policy(&mapped, ResolutionPolicy::Percentile(pct));
    let budget = timing::factor_budget_cells(&mapped, &plan, replicate_budget);
    let spent = timing::fill_replicas(&mapped, &mut plan, budget);
    let mut rep = audit::audit_deployment(&mapped, &plan);
    // fold a budget underflow into the report so it reaches the table,
    // the JSON artifact and the exit code alike
    if let Some(d) = audit::replica_budget_diagnostic(&mapped, &plan, replicate_budget, spent) {
        rep.push(d);
    }
    println!("{}", report::audit_table(&format!("audit of {label}"), &rep));
    std::fs::create_dir_all(&cfg.out_dir)?;
    let path = cfg.out_dir.join("audit.json");
    std::fs::write(&path, report::audit_json(&rep).to_string())?;
    println!("audit report written to {}", path.display());
    anyhow::ensure!(
        rep.summary.errors == 0,
        "audit found {} error(s) — the artifact is faulty",
        rep.summary.errors
    );
    Ok(())
}

fn cmd_reproduce(args: &Args) -> Result<()> {
    let target = args.target.clone().unwrap_or_default();
    let quick = args.flag("quick");
    match target.as_str() {
        "table1" => reproduce_table1(args, quick),
        "table2" => reproduce_table2(args, quick),
        "table3" => reproduce_table3(args),
        "fig2" => reproduce_fig2(args, quick),
        other => anyhow::bail!("reproduce target {other:?} (table1|table2|table3|fig2)"),
    }
}

fn reproduce_table1(args: &Args, quick: bool) -> Result<()> {
    let mut cfg = RunConfig::from_args(args)?;
    args.finish()?;
    cfg.model = "mlp".into();
    cfg.dataset = "mnist".into();
    if quick {
        cfg.steps = 120;
        cfg.pretrain_steps = 60;
    }
    let (engine, manifest) = engine_and_manifest(&cfg)?;
    let results = harness::reproduce_sparsity_table(&engine, &manifest, &cfg)?;
    let rows: Vec<_> = results.iter().map(|r| r.method_row()).collect();
    println!(
        "{}",
        report::sparsity_table(
            &format!("Table 1 — MNIST ({})", results[0].dataset_source),
            &rows
        )
    );
    Ok(())
}

fn reproduce_table2(args: &Args, quick: bool) -> Result<()> {
    let model = args.str_or("model", "both");
    let models: Vec<&str> = match model.as_str() {
        "both" => vec!["vgg11", "resnet20"],
        "vgg11" => vec!["vgg11"],
        "resnet20" => vec!["resnet20"],
        other => anyhow::bail!("table2 model {other:?}"),
    };
    for m in models {
        let mut cfg = RunConfig::from_args(args)?;
        cfg.model = m.into();
        cfg.dataset = "cifar10".into();
        if quick {
            cfg.steps = 60;
            cfg.pretrain_steps = 30;
        }
        let (engine, manifest) = engine_and_manifest(&cfg)?;
        let results = harness::reproduce_sparsity_table(&engine, &manifest, &cfg)?;
        let rows: Vec<_> = results.iter().map(|r| r.method_row()).collect();
        println!(
            "{}",
            report::sparsity_table(
                &format!("Table 2 — {} on CIFAR-10 ({})", m, results[0].dataset_source),
                &rows
            )
        );
    }
    Ok(())
}

fn reproduce_table3(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    args.finish()?;
    // Paper Table 3 is the analytic ADC model at the paper's operating
    // point (1-bit MSB, 3-bit rest). Print that, then — if a Bl1 MLP
    // checkpoint exists — the measured variant derived from its mapping.
    println!("Table 3 — ADC overhead saving (paper operating point):");
    println!(
        "{}",
        report::adc_table(&[energy::saving_row(3, 1), energy::saving_row(2, 3)])
    );

    let ckpt = cfg.out_dir.join("mlp-bl1").join("checkpoint");
    if ckpt.exists() {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let (state, meta) = load_checkpoint(&manifest, &ckpt)?;
        let entry = manifest.model(&meta.model)?;
        let deploy = harness::deploy_report(
            &state.named_qws(entry),
            ResolutionPolicy::Percentile(0.999),
            None,
            None,
        )?;
        println!(
            "measured on {} ({}): lossless bits {:?}, p99.9 bits {:?}",
            meta.model, meta.method, deploy.lossless_bits, deploy.deployed_bits
        );
        println!("{}", report::adc_table(&deploy.rows));
        let (e, t, a) = deploy.savings;
        println!("whole-model savings: energy {e:.1}x, time {t:.2}x, area {a:.1}x");
        println!(
            "{}",
            report::plan_table("per-layer operating point (p99.9 per layer)", &deploy.plan_rows)
        );

        // accuracy at the deployed resolutions, via the backend seam
        let test_ds = Dataset::auto(
            "mnist",
            &cfg.data_dir,
            false,
            cfg.test_examples,
            cfg.seed.wrapping_add(1),
        )?;
        let stack = serve::dense_stack(&state.named_qws(entry), &state.tps)?;
        let deployed =
            CrossbarBackend::with_bits("crossbar@p99.9", &stack, deploy.deployed_bits)?;
        let lossless = deployed.rebit("crossbar@lossless", deploy.lossless_bits);
        let da = serve::accuracy(&deployed, &test_ds)?;
        let la = serve::accuracy(&lossless, &test_ds)?;
        println!(
            "simulated accuracy on {}: {:.2}% at p99.9 bits vs {:.2}% lossless",
            test_ds.source,
            da.accuracy * 100.0,
            la.accuracy * 100.0,
        );
    } else {
        println!(
            "(no mlp-bl1 checkpoint under {} — run `reproduce table1` first for measured bits)",
            cfg.out_dir.display()
        );
    }
    Ok(())
}

fn reproduce_fig2(args: &Args, quick: bool) -> Result<()> {
    let mut cfg = RunConfig::from_args(args)?;
    args.finish()?;
    if quick {
        cfg.steps = 150;
        cfg.trace_every = 5;
    }
    // Fig. 2 compares the regularizers from scratch: no l1 pretraining
    // inside the Bl1 run (the figure's x-axis starts at epoch 0).
    cfg.pretrain_steps = 0;
    let (engine, manifest) = engine_and_manifest(&cfg)?;
    let traces = harness::reproduce_fig2(&engine, &manifest, &cfg)?;
    let csv = report::fig2_csv(&traces);
    std::fs::create_dir_all(&cfg.out_dir)?;
    let path = cfg.out_dir.join(format!("fig2-{}.csv", cfg.model));
    std::fs::write(&path, &csv)?;
    println!("fig2 series written to {}", path.display());
    for (m, pts) in &traces {
        if let (Some(first), Some(last)) = (pts.first(), pts.last()) {
            println!(
                "{m}: avg nonzero {:.2}% (step {}) -> {:.2}% (step {})",
                first.ratios.iter().sum::<f64>() / 4.0 * 100.0,
                first.step,
                last.ratios.iter().sum::<f64>() / 4.0 * 100.0,
                last.step
            );
        }
    }
    Ok(())
}

fn cmd_bench_adc(args: &Args) -> Result<()> {
    args.finish()?;
    println!("ADC cost model sweep (relative to 8-bit ISAAC baseline):");
    println!("| bits | power (rel) | energy saving | speedup | area saving |");
    println!("|------|-------------|---------------|---------|-------------|");
    for bits in 1..=8u32 {
        println!(
            "| {bits} | {:.2} | {:.1}x | {:.2}x | {:.1}x |",
            AdcModel::power(bits),
            AdcModel::energy_saving(bits),
            AdcModel::speedup(bits),
            AdcModel::area_saving(bits),
        );
    }
    Ok(())
}

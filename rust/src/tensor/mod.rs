//! Host tensors: the coordinator-side data container.
//!
//! A [`Tensor`] is a dense row-major f32 array with an explicit shape. The
//! training state, datasets, checkpoints and analysis all speak `Tensor`;
//! [`crate::runtime`] converts to/from `xla::Literal` at the device
//! boundary. Labels use [`IntTensor`] (i32) to match the graphs' y input.

use anyhow::Result;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(
            n == data.len(),
            "shape {:?} wants {} elements, got {}",
            shape,
            n,
            data.len()
        );
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![v; n],
        }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Scalar extraction (rank-0 or single-element tensors).
    pub fn item(&self) -> Result<f32> {
        anyhow::ensure!(self.data.len() == 1, "item() on {:?}", self.shape);
        Ok(self.data[0])
    }

    /// 2-D indexed access (row-major); debug-checked.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        self.shape = shape;
        Ok(self)
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Convert to an XLA literal with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // rank-0: reshape to scalar
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Convert from an XLA literal (must be f32).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Tensor::new(dims, data)
    }
}

/// Dense row-major i32 tensor (labels).
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == data.len(), "shape/data mismatch");
        Ok(IntTensor { shape, data })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_element_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn zeros_full_scalar() {
        assert_eq!(Tensor::zeros(vec![4, 2]).len(), 8);
        assert_eq!(Tensor::full(vec![3], 2.5).data(), &[2.5, 2.5, 2.5]);
        assert_eq!(Tensor::scalar(7.0).item().unwrap(), 7.0);
    }

    #[test]
    fn at2_row_major() {
        let t = Tensor::new(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]).unwrap();
        assert_eq!(t.at2(0, 2), 2.0);
        assert_eq!(t.at2(1, 0), 3.0);
    }

    #[test]
    fn reshape_checks() {
        let t = Tensor::zeros(vec![6]);
        assert!(t.clone().reshape(vec![2, 3]).is_ok());
        assert!(t.reshape(vec![4, 2]).is_err());
    }

    #[test]
    fn max_abs_works() {
        let t = Tensor::new(vec![3], vec![-2.0, 1.0, 0.5]).unwrap();
        assert_eq!(t.max_abs(), 2.0);
    }
}

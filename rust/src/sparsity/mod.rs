//! Bit-slice sparsity statistics — the measurement side of Tables 1/2 and
//! Figure 2.
//!
//! The paper reports, per method, the **ratio of non-zero weights in each
//! 2-bit slice across the whole model** (B̂³ … B̂⁰, MSB to LSB) plus the
//! average ± standard deviation over the four slices. This module computes
//! those from a set of quantized weight tensors.

use crate::quant::{self, Quantized, N_SLICES};
use crate::tensor::Tensor;

/// Whole-model slice census.
#[derive(Debug, Clone, PartialEq)]
pub struct SliceStats {
    /// Non-zero element count per slice (LSB-first), summed over tensors.
    pub nonzero: [usize; N_SLICES],
    /// Total weight elements in the census.
    pub numel: usize,
}

impl SliceStats {
    pub fn zero() -> Self {
        SliceStats {
            nonzero: [0; N_SLICES],
            numel: 0,
        }
    }

    pub fn add(&mut self, q: &Quantized) {
        let counts = q.slice_nonzero_counts();
        for k in 0..N_SLICES {
            self.nonzero[k] += counts[k];
        }
        self.numel += q.numel();
    }

    /// Non-zero ratio for slice k (LSB-first), in [0, 1].
    pub fn ratio(&self, k: usize) -> f64 {
        if self.numel == 0 {
            0.0
        } else {
            self.nonzero[k] as f64 / self.numel as f64
        }
    }

    /// Ratios MSB-first — the paper's column order (B̂³, B̂², B̂¹, B̂⁰).
    pub fn ratios_msb_first(&self) -> [f64; N_SLICES] {
        let mut out = [0.0; N_SLICES];
        for k in 0..N_SLICES {
            out[k] = self.ratio(N_SLICES - 1 - k);
        }
        out
    }

    /// (mean, std) of the four slice ratios — the paper's Average column.
    /// Population std over the 4 slices (matches the ± in Tables 1/2).
    pub fn mean_std(&self) -> (f64, f64) {
        let rs = self.ratios_msb_first();
        let mean = rs.iter().sum::<f64>() / N_SLICES as f64;
        let var = rs.iter().map(|r| (r - mean).powi(2)).sum::<f64>() / N_SLICES as f64;
        (mean, var.sqrt())
    }

    /// Element-wise (full-weight) non-zero ratio: an element is non-zero if
    /// any slice is — for comparison with weight-grade pruning numbers.
    pub fn any_nonzero_ratio(qs: &[Quantized]) -> f64 {
        let mut nz = 0usize;
        let mut total = 0usize;
        for q in qs {
            nz += q.codes.iter().filter(|&&c| c != 0).count();
            total += q.numel();
        }
        if total == 0 {
            0.0
        } else {
            nz as f64 / total as f64
        }
    }
}

/// Census over a set of weight tensors (quantizing each per-tensor, as the
/// paper does per-layer).
pub fn census(tensors: &[Tensor]) -> SliceStats {
    let mut stats = SliceStats::zero();
    for t in tensors {
        stats.add(&quant::quantize(t));
    }
    stats
}

/// One Figure-2 style trace point: step index + per-slice ratios.
#[derive(Debug, Clone)]
pub struct TracePoint {
    pub step: usize,
    /// MSB-first ratios, matching the paper's B̂³..B̂⁰ panels.
    pub ratios: [f64; N_SLICES],
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, ensure};
    use crate::util::rng::Rng;

    fn t(data: Vec<f32>) -> Tensor {
        let n = data.len();
        Tensor::new(vec![n], data).unwrap()
    }

    #[test]
    fn zero_model_is_fully_sparse() {
        let stats = census(&[t(vec![0.0; 100])]);
        assert_eq!(stats.numel, 100);
        assert_eq!(stats.ratios_msb_first(), [0.0; 4]);
        let (mean, std) = stats.mean_std();
        assert_eq!((mean, std), (0.0, 0.0));
    }

    #[test]
    fn dense_max_code_model_is_fully_dense() {
        // every weight at max magnitude -> code 255 -> all slices non-zero
        let stats = census(&[t(vec![0.999; 64])]);
        for k in 0..4 {
            assert!(stats.ratio(k) > 0.99, "slice {k}: {}", stats.ratio(k));
        }
    }

    #[test]
    fn ratios_sum_over_multiple_tensors() {
        // tensor A: codes only in LSB slice; tensor B: zeros
        // max 1.0 fixes step = 2^-8; values k/256 give code k
        let a = t(vec![1.0 / 256.0, 2.0 / 256.0, 3.0 / 256.0, 1.0]);
        let b = t(vec![0.0; 4]);
        let stats = census(&[a, b]);
        assert_eq!(stats.numel, 8);
        // LSB slice: codes 1,2,3 and 255 -> 4 nonzero
        assert_eq!(stats.nonzero[0], 4);
        // MSB slice: only the 255 element
        assert_eq!(stats.nonzero[3], 1);
    }

    #[test]
    fn mean_std_matches_manual_computation() {
        check(20, |rng| {
            let w = t(rng.normal_vec(500, 0.2));
            let stats = census(&[w]);
            let rs = stats.ratios_msb_first();
            let mean = rs.iter().sum::<f64>() / 4.0;
            let var = rs.iter().map(|r| (r - mean).powi(2)).sum::<f64>() / 4.0;
            let (m, s) = stats.mean_std();
            ensure((m - mean).abs() < 1e-12, "mean")?;
            ensure((s - var.sqrt()).abs() < 1e-12, "std")?;
            Ok(())
        });
    }

    #[test]
    fn msb_slice_is_sparsest_for_gaussian_weights() {
        // Gaussian weights: large codes are rare, so the MSB slice must be
        // the sparsest — the structural fact the paper's Fig. 2 shows.
        let mut rng = Rng::new(42);
        let stats = census(&[t(rng.normal_vec(50_000, 0.1))]);
        let rs = stats.ratios_msb_first(); // [b3, b2, b1, b0]
        assert!(rs[0] < rs[1] && rs[1] < rs[2], "{rs:?}");
    }

    #[test]
    fn any_nonzero_ratio_bounds_slice_ratios() {
        let mut rng = Rng::new(7);
        let w = t(rng.normal_vec(10_000, 0.1));
        let q = quant::quantize(&w);
        let stats = census(&[w]);
        let full = SliceStats::any_nonzero_ratio(&[q]);
        for k in 0..4 {
            assert!(stats.ratio(k) <= full + 1e-12);
        }
    }
}

//! Quantized deployment accuracy over a test set.
//!
//! Uses the `eval_step` graph (weights quantized + masked, BN running
//! stats) over sequential fixed-shape batches. The final partial batch is
//! wrap-filled to the graph's static shape; fill rows get label -1 so they
//! can never count as correct, and accuracy is normalized by the number of
//! real examples.

use anyhow::{Context, Result};

use crate::coordinator::state::ModelState;
use crate::data::loader::{assemble, BatchPlan, EvalBatches};
use crate::data::Dataset;
use crate::runtime::{Engine, Manifest};
use crate::tensor::{IntTensor, Tensor};

/// Evaluation result.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub accuracy: f64,
    pub examples: usize,
}

/// Re-estimate batch-norm running statistics ("BN calibration").
///
/// Short schedules leave the exponential running stats far behind the
/// activation distribution the final weights actually produce (the gap
/// compounds through deep networks and wrecks eval-mode accuracy). The
/// standard fix is to re-run forward passes with frozen weights and let
/// the running stats converge. We reuse the `train_step` graph with
/// `lr = 0` and absorb *only* its updated-ST outputs: weights, velocities
/// and masks are left untouched. No-op for BN-free models.
pub fn bn_calibrate(
    engine: &Engine,
    manifest: &Manifest,
    model: &str,
    state: &mut ModelState,
    dataset: &Dataset,
    steps: usize,
    seed: u64,
) -> Result<()> {
    if state.sts.is_empty() || steps == 0 {
        return Ok(());
    }
    let entry = manifest.model(model)?;
    let graph = entry.graph("train")?;
    let exe = engine.load(&graph.path)?;
    let (nq, nt, ns) = (state.qws.len(), state.tps.len(), state.sts.len());

    let fixed = state.to_train_literals()?; // qw tp st vq vt mask
    let scalars = [
        Tensor::scalar(0.0).to_literal()?, // lr = 0: stats move, weights don't
        Tensor::scalar(0.0).to_literal()?,
        Tensor::scalar(0.0).to_literal()?,
        Tensor::scalar(0.0).to_literal()?,
    ];
    let plan = BatchPlan::new(dataset.len(), entry.batch, seed);
    let mut st_lits: Vec<xla::Literal> = Vec::new();
    for step in 0..steps {
        let batch = assemble(dataset, &plan.indices(step));
        let x_lit = batch.x.to_literal()?;
        let y_lit = batch.y.to_literal()?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(fixed.len() + 6);
        inputs.extend(fixed.iter().take(nq + nt));
        if st_lits.is_empty() {
            inputs.extend(fixed.iter().skip(nq + nt).take(ns));
        } else {
            inputs.extend(st_lits.iter());
        }
        inputs.extend(fixed.iter().skip(nq + nt + ns));
        inputs.push(&x_lit);
        inputs.push(&y_lit);
        inputs.extend(scalars.iter());
        let mut outs = exe.run(&inputs)?;
        // keep only the updated running stats
        st_lits = outs.drain(nq + nt..nq + nt + ns).collect();
    }
    for (slot, lit) in state.sts.iter_mut().zip(&st_lits) {
        *slot = Tensor::from_literal(lit)?;
    }
    Ok(())
}

/// Evaluate `state` on `dataset` with the model's `eval` graph.
pub fn evaluate(
    engine: &Engine,
    manifest: &Manifest,
    model: &str,
    state: &ModelState,
    dataset: &Dataset,
) -> Result<EvalResult> {
    let entry = manifest.model(model)?;
    let graph = entry.graph("eval")?;
    let exe = engine.load(&graph.path).context("compiling eval graph")?;
    let idx_correct = graph.output_index("correct")?;

    let state_lits = state.to_eval_literals()?;
    let mut correct = 0.0f64;
    let mut total = 0usize;
    for eb in EvalBatches::new(dataset, entry.batch) {
        // kill wrap-fill rows: label -1 never matches an argmax in 0..C
        let mut labels = eb.batch.y.data().to_vec();
        for l in labels.iter_mut().skip(eb.valid) {
            *l = -1;
        }
        let y = IntTensor::new(vec![entry.batch], labels)?;

        let x_lit = eb.batch.x.to_literal()?;
        let y_lit = y.to_literal()?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(state_lits.len() + 2);
        inputs.extend(state_lits.iter());
        inputs.push(&x_lit);
        inputs.push(&y_lit);
        let outs = exe.run(&inputs)?;
        correct += outs[idx_correct].to_vec::<f32>()?[0] as f64;
        total += eb.valid;
    }
    Ok(EvalResult {
        accuracy: if total == 0 { 0.0 } else { correct / total as f64 },
        examples: total,
    })
}


//! Quantized deployment accuracy over a test set.
//!
//! Uses the `eval_step` graph (weights quantized + masked, BN running
//! stats) behind the [`crate::serve::InferenceBackend`] seam: the
//! fixed-shape padding and batch dispatch live in
//! [`crate::serve::XlaBackend`] / [`crate::serve::accuracy`], so this
//! module only owns what is eval-specific — BN re-calibration.

use anyhow::Result;

use crate::coordinator::state::ModelState;
use crate::data::loader::{assemble, BatchPlan};
use crate::data::Dataset;
use crate::runtime::{Engine, Manifest};
use crate::serve::{self, XlaBackend};
use crate::tensor::Tensor;

/// Evaluation result.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub accuracy: f64,
    pub examples: usize,
}

/// Re-estimate batch-norm running statistics ("BN calibration").
///
/// Short schedules leave the exponential running stats far behind the
/// activation distribution the final weights actually produce (the gap
/// compounds through deep networks and wrecks eval-mode accuracy). The
/// standard fix is to re-run forward passes with frozen weights and let
/// the running stats converge. We reuse the `train_step` graph with
/// `lr = 0` and absorb *only* its updated-ST outputs: weights, velocities
/// and masks are left untouched. No-op for BN-free models.
pub fn bn_calibrate(
    engine: &Engine,
    manifest: &Manifest,
    model: &str,
    state: &mut ModelState,
    dataset: &Dataset,
    steps: usize,
    seed: u64,
) -> Result<()> {
    if state.sts.is_empty() || steps == 0 {
        return Ok(());
    }
    let entry = manifest.model(model)?;
    let graph = entry.graph("train")?;
    let exe = engine.load(&graph.path)?;
    let (nq, nt, ns) = (state.qws.len(), state.tps.len(), state.sts.len());

    let fixed = state.to_train_literals()?; // qw tp st vq vt mask
    let scalars = [
        Tensor::scalar(0.0).to_literal()?, // lr = 0: stats move, weights don't
        Tensor::scalar(0.0).to_literal()?,
        Tensor::scalar(0.0).to_literal()?,
        Tensor::scalar(0.0).to_literal()?,
    ];
    let plan = BatchPlan::new(dataset.len(), entry.batch, seed);
    let mut st_lits: Vec<xla::Literal> = Vec::new();
    for step in 0..steps {
        let batch = assemble(dataset, &plan.indices(step));
        let x_lit = batch.x.to_literal()?;
        let y_lit = batch.y.to_literal()?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(fixed.len() + 6);
        inputs.extend(fixed.iter().take(nq + nt));
        if st_lits.is_empty() {
            inputs.extend(fixed.iter().skip(nq + nt).take(ns));
        } else {
            inputs.extend(st_lits.iter());
        }
        inputs.extend(fixed.iter().skip(nq + nt + ns));
        inputs.push(&x_lit);
        inputs.push(&y_lit);
        inputs.extend(scalars.iter());
        let mut outs = exe.run(&inputs)?;
        // keep only the updated running stats
        st_lits = outs.drain(nq + nt..nq + nt + ns).collect();
    }
    for (slot, lit) in state.sts.iter_mut().zip(&st_lits) {
        *slot = Tensor::from_literal(lit)?;
    }
    Ok(())
}

/// Evaluate `state` on `dataset` with the model's `eval` graph, routed
/// through the unified backend seam.
pub fn evaluate(
    engine: &Engine,
    manifest: &Manifest,
    model: &str,
    state: &ModelState,
    dataset: &Dataset,
) -> Result<EvalResult> {
    let backend = XlaBackend::for_eval(engine, manifest, model, state)?;
    let rep = serve::accuracy(&backend, dataset)?;
    Ok(EvalResult {
        accuracy: rep.accuracy,
        examples: rep.examples,
    })
}


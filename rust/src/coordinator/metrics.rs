//! Step metrics (JSONL) and Fig-2 sparsity traces (CSV).
//!
//! Every training run writes `<out>/metrics.jsonl` (one JSON object per
//! logged step: loss, ce, regularizer values, throughput) and, when
//! tracing is on, `<out>/trace.csv` with per-slice non-zero ratios over
//! training — the series Figure 2 plots.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::quant::N_SLICES;
use crate::sparsity::TracePoint;
use crate::util::json::{num, obj, s, Json};

/// One logged training step.
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: usize,
    pub phase: &'static str,
    pub loss: f32,
    pub ce: f32,
    pub l1: f32,
    pub bl1: f32,
    pub batch_accuracy: f32,
    pub step_ms: f64,
}

/// Appending metrics writer + in-memory history.
pub struct MetricsLog {
    file: Option<std::io::BufWriter<std::fs::File>>,
    pub history: Vec<StepMetrics>,
    pub trace: Vec<TracePoint>,
}

impl std::fmt::Debug for MetricsLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsLog")
            .field("steps", &self.history.len())
            .field("trace_points", &self.trace.len())
            .field("to_file", &self.file.is_some())
            .finish()
    }
}

impl MetricsLog {
    /// `dir = None` keeps everything in memory (tests, benches).
    pub fn create(dir: Option<&Path>) -> Result<Self> {
        let file = match dir {
            Some(d) => {
                std::fs::create_dir_all(d)?;
                Some(std::io::BufWriter::new(std::fs::File::create(
                    d.join("metrics.jsonl"),
                )?))
            }
            None => None,
        };
        Ok(MetricsLog {
            file,
            history: Vec::new(),
            trace: Vec::new(),
        })
    }

    pub fn log_step(&mut self, m: StepMetrics) -> Result<()> {
        if let Some(f) = &mut self.file {
            let j = obj(vec![
                ("step", num(m.step as f64)),
                ("phase", s(m.phase)),
                ("loss", num(m.loss as f64)),
                ("ce", num(m.ce as f64)),
                ("l1", num(m.l1 as f64)),
                ("bl1", num(m.bl1 as f64)),
                ("batch_acc", num(m.batch_accuracy as f64)),
                ("step_ms", num(m.step_ms)),
            ]);
            writeln!(f, "{j}")?;
        }
        self.history.push(m);
        Ok(())
    }

    pub fn log_trace(&mut self, p: TracePoint) {
        self.trace.push(p);
    }

    /// Write the Fig-2 trace as CSV: step,b3,b2,b1,b0 (MSB-first ratios).
    pub fn write_trace_csv(&self, path: &Path) -> Result<()> {
        let mut out = String::from("step,b3,b2,b1,b0\n");
        for p in &self.trace {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{:.6}\n",
                p.step, p.ratios[0], p.ratios[1], p.ratios[2], p.ratios[3]
            ));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(f) = &mut self.file {
            f.flush()?;
        }
        Ok(())
    }

    /// Mean step latency (ms) over the logged history — §Perf metric.
    pub fn mean_step_ms(&self) -> f64 {
        if self.history.is_empty() {
            return 0.0;
        }
        self.history.iter().map(|m| m.step_ms).sum::<f64>() / self.history.len() as f64
    }
}

/// Parse a metrics.jsonl back (used by the reproduce harness & tests).
pub fn read_jsonl(path: &Path) -> Result<Vec<Json>> {
    let text = std::fs::read_to_string(path)?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(crate::util::json::parse)
        .collect()
}

/// Trace point helper assembled from slice ratios.
pub fn trace_point(step: usize, ratios_msb_first: [f64; N_SLICES]) -> TracePoint {
    TracePoint {
        step,
        ratios: ratios_msb_first,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(step: usize) -> StepMetrics {
        StepMetrics {
            step,
            phase: "test",
            loss: 1.5,
            ce: 1.2,
            l1: 100.0,
            bl1: 200.0,
            batch_accuracy: 0.5,
            step_ms: 3.25,
        }
    }

    #[test]
    fn in_memory_log_works_without_dir() {
        let mut log = MetricsLog::create(None).unwrap();
        log.log_step(m(0)).unwrap();
        log.log_step(m(1)).unwrap();
        assert_eq!(log.history.len(), 2);
        assert!((log.mean_step_ms() - 3.25).abs() < 1e-12);
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join(format!("metrics-test-{}", std::process::id()));
        let mut log = MetricsLog::create(Some(&dir)).unwrap();
        for i in 0..3 {
            log.log_step(m(i)).unwrap();
        }
        log.flush().unwrap();
        let rows = read_jsonl(&dir.join("metrics.jsonl")).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[2].get("step").unwrap().as_usize(), Some(2));
        assert_eq!(rows[0].get("phase").unwrap().as_str(), Some("test"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn trace_csv_format() {
        let dir = std::env::temp_dir().join(format!("trace-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut log = MetricsLog::create(None).unwrap();
        log.log_trace(trace_point(0, [0.01, 0.05, 0.08, 0.17]));
        log.log_trace(trace_point(50, [0.005, 0.04, 0.04, 0.09]));
        let path = dir.join("trace.csv");
        log.write_trace_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "step,b3,b2,b1,b0");
        assert!(lines[1].starts_with("0,0.010000,"));
        assert_eq!(lines.len(), 3);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! The training loop: phases -> prefetched batches -> `train_step`.
//!
//! State is converted to XLA literals once per phase and then *cycled*:
//! each step's state outputs feed the next step's inputs directly, so the
//! per-step host work is only the batch tensors and four scalars. Host
//! round-trips of the full parameter set happen only at phase boundaries,
//! trace points and checkpoints.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::RunConfig;
use crate::coordinator::metrics::{MetricsLog, StepMetrics};
use crate::coordinator::pruning;
use crate::coordinator::schedule::PhasePlan;
use crate::coordinator::state::ModelState;
use crate::data::loader::BatchStream;
use crate::data::Dataset;
use crate::runtime::{Engine, Executable, Manifest, ModelEntry};
use crate::sparsity;
use crate::tensor::Tensor;

/// Summary of a completed run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub steps_run: usize,
    pub final_loss: f32,
    pub mean_step_ms: f64,
}

/// Drives one model's training according to a [`RunConfig`].
pub struct Trainer<'e> {
    engine: &'e Engine,
    pub entry: ModelEntry,
    exe_train: std::sync::Arc<Executable>,
    pub cfg: RunConfig,
    pub state: ModelState,
    // output indices resolved once from the manifest
    idx_loss: usize,
    idx_ce: usize,
    idx_l1: usize,
    idx_bl1: usize,
    idx_correct: usize,
    n_state_out: usize,
}

impl std::fmt::Debug for Trainer<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Trainer")
            .field("model", &self.cfg.model)
            .field("method", &self.cfg.method.name())
            .finish_non_exhaustive()
    }
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, manifest: &Manifest, cfg: RunConfig) -> Result<Self> {
        let entry = manifest.model(&cfg.model)?.clone();
        let graph = entry.graph("train")?;
        let exe_train = engine.load(&graph.path).context("compiling train graph")?;
        let state = ModelState::init(&entry, cfg.seed);
        let n_state_out = state.train_state_outputs();
        Ok(Trainer {
            engine,
            idx_loss: graph.output_index("loss")?,
            idx_ce: graph.output_index("ce")?,
            idx_l1: graph.output_index("l1")?,
            idx_bl1: graph.output_index("bl1")?,
            idx_correct: graph.output_index("correct")?,
            exe_train,
            entry,
            cfg,
            state,
            n_state_out,
        })
    }

    /// Run the full phase plan on `dataset`, logging to `log`.
    pub fn run(&mut self, dataset: &Dataset, log: &mut MetricsLog) -> Result<TrainOutcome> {
        anyhow::ensure!(
            dataset.dim() == self.entry.input_numel(),
            "dataset dim {} != model input {}",
            dataset.dim(),
            self.entry.input_numel()
        );
        let plan = PhasePlan::for_config(&self.cfg);
        let mut global_step = 0usize;
        let mut final_loss = 0.0f32;

        for (pi, phase) in plan.phases.iter().enumerate() {
            if let Some(frac) = phase.prune_before {
                let pruned = pruning::prune_by_magnitude(&mut self.state, frac);
                eprintln!(
                    "[{}] phase {}: pruned {:.1}% of weights",
                    self.cfg.label(),
                    phase.name,
                    pruned * 100.0
                );
            }
            self.state.reset_velocity();

            // Phase-constant scalar literals.
            let scalars = [
                Tensor::scalar(self.cfg.lr).to_literal()?,
                Tensor::scalar(self.cfg.momentum).to_literal()?,
                Tensor::scalar(phase.alpha_l1).to_literal()?,
                Tensor::scalar(phase.alpha_bl1).to_literal()?,
            ];

            // State enters the device world once per phase...
            let mut state_lits = self.state.to_train_literals()?;

            let stream = BatchStream::new(
                dataset.clone(),
                self.entry.batch,
                phase.steps,
                self.cfg.seed ^ ((pi as u64 + 1) << 32),
                self.cfg.prefetch,
            );

            // Mask literals are phase-constant too (masks only change at
            // phase boundaries).
            let mask_lits: Vec<xla::Literal> = self
                .state
                .masks
                .iter()
                .map(|m| m.to_literal())
                .collect::<Result<_>>()?;
            // state_lits ends with the masks; strip them — they are
            // re-borrowed from mask_lits each step.
            state_lits.truncate(self.n_state_out);

            for _ in 0..phase.steps {
                let batch = stream.next().context("batch stream ended early")?;
                let t0 = Instant::now();
                let x_lit = batch.x.to_literal()?;
                let y_lit = batch.y.to_literal()?;
                let mut inputs: Vec<&xla::Literal> =
                    Vec::with_capacity(state_lits.len() + mask_lits.len() + 6);
                inputs.extend(state_lits.iter());
                inputs.extend(mask_lits.iter());
                inputs.push(&x_lit);
                inputs.push(&y_lit);
                inputs.extend(scalars.iter());

                let outs = self.exe_train.run(&inputs)?;
                let step_ms = t0.elapsed().as_secs_f64() * 1e3;

                let loss = scalar_out(&outs, self.idx_loss)?;
                anyhow::ensure!(
                    loss.is_finite(),
                    "loss diverged at step {global_step} (lr too high?)"
                );
                final_loss = loss;
                log.log_step(StepMetrics {
                    step: global_step,
                    phase: phase.name,
                    loss,
                    ce: scalar_out(&outs, self.idx_ce)?,
                    l1: scalar_out(&outs, self.idx_l1)?,
                    bl1: scalar_out(&outs, self.idx_bl1)?,
                    batch_accuracy: scalar_out(&outs, self.idx_correct)?
                        / self.entry.batch as f32,
                    step_ms,
                })?;

                // Cycle state: the first n_state_out outputs are the new
                // state, in input order.
                state_lits = outs;
                state_lits.truncate(self.n_state_out);

                if self.cfg.trace_every > 0 && global_step % self.cfg.trace_every == 0 {
                    let stats = self.census_from_literals(&state_lits)?;
                    log.log_trace(crate::coordinator::metrics::trace_point(
                        global_step,
                        stats.ratios_msb_first(),
                    ));
                }
                global_step += 1;
            }

            // ...and leaves it at the phase end.
            self.absorb(&state_lits)?;
        }

        Ok(TrainOutcome {
            steps_run: global_step,
            final_loss,
            mean_step_ms: log.mean_step_ms(),
        })
    }

    fn absorb(&mut self, state_lits: &[xla::Literal]) -> Result<()> {
        self.state.absorb_train_outputs(state_lits)
    }

    fn census_from_literals(&self, state_lits: &[xla::Literal]) -> Result<sparsity::SliceStats> {
        let mut tensors = Vec::with_capacity(self.entry.qw.len());
        for lit in state_lits.iter().take(self.entry.qw.len()) {
            tensors.push(Tensor::from_literal(lit)?);
        }
        Ok(sparsity::census(&tensors))
    }

    /// Engine accessor (for follow-up evaluation with the same client).
    pub fn engine(&self) -> &Engine {
        self.engine
    }
}

fn scalar_out(outs: &[xla::Literal], idx: usize) -> Result<f32> {
    Ok(outs[idx].to_vec::<f32>()?[0])
}

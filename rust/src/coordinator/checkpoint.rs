//! Checkpoints: binary tensor snapshots + JSON metadata.
//!
//! Format (`state.bin`): magic "BSRK1\n", then per tensor a header line
//! `<group>:<index> <ndims> <dims...> <byte-len>\n` followed by raw
//! little-endian f32 data. `meta.json` records model/method/step so a
//! checkpoint is self-describing.

use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::state::ModelState;
use crate::tensor::Tensor;
use crate::util::json::{num, obj, s};

const MAGIC: &[u8] = b"BSRK1\n";

/// Checkpoint metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct Meta {
    pub model: String,
    pub method: String,
    pub step: usize,
    pub dataset_source: String,
}

fn write_tensor<W: Write>(w: &mut W, group: &str, idx: usize, t: &Tensor) -> Result<()> {
    write!(w, "{group}:{idx} {}", t.shape().len())?;
    for d in t.shape() {
        write!(w, " {d}")?;
    }
    writeln!(w, " {}", t.len() * 4)?;
    for v in t.data() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_line<R: Read>(r: &mut R) -> Result<String> {
    let mut line = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        r.read_exact(&mut byte).context("checkpoint truncated")?;
        if byte[0] == b'\n' {
            break;
        }
        line.push(byte[0]);
        anyhow::ensure!(line.len() < 4096, "header line too long");
    }
    Ok(String::from_utf8(line)?)
}

fn read_tensor<R: Read>(r: &mut R, want_group: &str, want_idx: usize) -> Result<Tensor> {
    let header = read_line(r)?;
    let mut parts = header.split_whitespace();
    let tag = parts.next().context("missing tag")?;
    anyhow::ensure!(
        tag == format!("{want_group}:{want_idx}"),
        "checkpoint order mismatch: expected {want_group}:{want_idx}, got {tag}"
    );
    let ndims: usize = parts.next().context("ndims")?.parse()?;
    let mut shape = Vec::with_capacity(ndims);
    for _ in 0..ndims {
        shape.push(parts.next().context("dim")?.parse()?);
    }
    let bytes: usize = parts.next().context("len")?.parse()?;
    let numel: usize = shape.iter().product();
    anyhow::ensure!(bytes == numel * 4, "byte-length mismatch");
    let mut raw = vec![0u8; bytes];
    r.read_exact(&mut raw).context("tensor data truncated")?;
    let data: Vec<f32> = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Tensor::new(shape, data)
}

/// Save state + metadata into `dir`.
pub fn save(dir: &Path, state: &ModelState, meta: &Meta) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut w = BufWriter::new(std::fs::File::create(dir.join("state.bin"))?);
    w.write_all(MAGIC)?;
    for (group, tensors) in [
        ("qw", &state.qws),
        ("tp", &state.tps),
        ("st", &state.sts),
        ("vq", &state.vqs),
        ("vt", &state.vts),
        ("mask", &state.masks),
    ] {
        for (i, t) in tensors.iter().enumerate() {
            write_tensor(&mut w, group, i, t)?;
        }
    }
    w.flush()?;
    let j = obj(vec![
        ("model", s(&meta.model)),
        ("method", s(&meta.method)),
        ("step", num(meta.step as f64)),
        ("dataset_source", s(&meta.dataset_source)),
    ]);
    std::fs::write(dir.join("meta.json"), format!("{j}\n"))?;
    Ok(())
}

/// Load a checkpoint into an existing (shape-compatible) state.
pub fn load(dir: &Path, state: &mut ModelState) -> Result<Meta> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(dir.join("state.bin"))
            .with_context(|| format!("opening checkpoint {}", dir.display()))?,
    );
    let mut magic = [0u8; 6];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(magic == MAGIC, "bad checkpoint magic");
    for (group, tensors) in [
        ("qw", &mut state.qws),
        ("tp", &mut state.tps),
        ("st", &mut state.sts),
        ("vq", &mut state.vqs),
        ("vt", &mut state.vts),
        ("mask", &mut state.masks),
    ] {
        for (i, slot) in tensors.iter_mut().enumerate() {
            let t = read_tensor(&mut r, group, i)?;
            anyhow::ensure!(
                t.shape() == slot.shape(),
                "{group}:{i} shape {:?} != expected {:?}",
                t.shape(),
                slot.shape()
            );
            *slot = t;
        }
    }
    let meta_text = std::fs::read_to_string(dir.join("meta.json"))?;
    let j = crate::util::json::parse(&meta_text)?;
    Ok(Meta {
        model: j.req("model")?.as_str().context("model")?.to_string(),
        method: j.req("method")?.as_str().context("method")?.to_string(),
        step: j.req("step")?.as_usize().context("step")?,
        dataset_source: j
            .req("dataset_source")?
            .as_str()
            .context("source")?
            .to_string(),
    })
}

/// Read just the metadata.
pub fn load_meta(dir: &Path) -> Result<Meta> {
    let meta_text = std::fs::read_to_string(dir.join("meta.json"))?;
    let j = crate::util::json::parse(&meta_text)?;
    Ok(Meta {
        model: j.req("model")?.as_str().context("model")?.to_string(),
        method: j.req("method")?.as_str().context("method")?.to_string(),
        step: j.req("step")?.as_usize().context("step")?,
        dataset_source: j
            .req("dataset_source")?
            .as_str()
            .context("source")?
            .to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{ModelEntry, ParamEntry};

    fn entry() -> ModelEntry {
        ModelEntry {
            name: "toy".into(),
            batch: 2,
            input_shape: vec![4],
            num_classes: 2,
            qw: vec![ParamEntry {
                name: "w".into(),
                shape: vec![4, 3],
                init_std: 0.3,
                init_const: 0.0,
            }],
            tp: vec![ParamEntry {
                name: "b".into(),
                shape: vec![3],
                init_std: 0.0,
                init_const: 0.1,
            }],
            st: vec![],
            graphs: Default::default(),
        }
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tmpdir("rt");
        let state = ModelState::init(&entry(), 5);
        let meta = Meta {
            model: "toy".into(),
            method: "bl1".into(),
            step: 123,
            dataset_source: "synthetic-mnist".into(),
        };
        save(&dir, &state, &meta).unwrap();
        let mut loaded = ModelState::init(&entry(), 999); // different seed
        let got_meta = load(&dir, &mut loaded).unwrap();
        assert_eq!(got_meta, meta);
        assert_eq!(loaded.qws[0], state.qws[0]);
        assert_eq!(loaded.tps[0], state.tps[0]);
        assert_eq!(loaded.masks[0], state.masks[0]);
        assert_eq!(load_meta(&dir).unwrap().step, 123);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = tmpdir("shape");
        let state = ModelState::init(&entry(), 5);
        let meta = Meta {
            model: "toy".into(),
            method: "l1".into(),
            step: 1,
            dataset_source: "x".into(),
        };
        save(&dir, &state, &meta).unwrap();
        let mut other_entry = entry();
        other_entry.qw[0].shape = vec![4, 4];
        let mut other = ModelState::init(&other_entry, 1);
        assert!(load(&dir, &mut other).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_magic_rejected() {
        let dir = tmpdir("magic");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("state.bin"), b"NOTCK\n").unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"model":"m","method":"x","step":0,"dataset_source":"s"}"#,
        )
        .unwrap();
        let mut state = ModelState::init(&entry(), 1);
        assert!(load(&dir, &mut state).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_checkpoint_errors_cleanly() {
        let mut state = ModelState::init(&entry(), 1);
        let err = load(Path::new("/no/such/ckpt"), &mut state).unwrap_err();
        assert!(err.to_string().contains("opening checkpoint"));
    }
}

//! Magnitude pruning (the tables' "Pruned" baseline, Han et al. style).
//!
//! Per layer: zero the `fraction` smallest-magnitude weights by setting
//! their mask entries to 0; fine-tuning then proceeds with the mask applied
//! both in the forward quantization and the update (see train.py). Already-
//! masked weights stay pruned.

use crate::coordinator::state::ModelState;

/// Per-layer magnitude threshold at the given prune fraction.
pub fn magnitude_threshold(weights: &[f32], fraction: f32) -> f32 {
    if weights.is_empty() || fraction <= 0.0 {
        return 0.0;
    }
    let mut mags: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = ((mags.len() as f64) * fraction as f64).floor() as usize;
    if k == 0 {
        0.0
    } else if k >= mags.len() {
        f32::INFINITY
    } else {
        mags[k]
    }
}

/// Prune `fraction` of each qw layer in-place (masks + weights).
/// Returns the overall fraction of weights now masked out.
pub fn prune_by_magnitude(state: &mut ModelState, fraction: f32) -> f64 {
    let mut masked = 0usize;
    let mut total = 0usize;
    for (w, m) in state.qws.iter_mut().zip(state.masks.iter_mut()) {
        let thr = magnitude_threshold(w.data(), fraction);
        for (wv, mv) in w.data_mut().iter_mut().zip(m.data_mut()) {
            if wv.abs() < thr || *mv == 0.0 {
                *mv = 0.0;
                *wv = 0.0;
            }
        }
        masked += m.data().iter().filter(|&&v| v == 0.0).count();
        total += m.data().len();
    }
    masked as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{ModelEntry, ParamEntry};
    use crate::util::check::{check, ensure};

    fn state(n: usize, seed: u64) -> ModelState {
        let entry = ModelEntry {
            name: "toy".into(),
            batch: 1,
            input_shape: vec![n],
            num_classes: 2,
            qw: vec![ParamEntry {
                name: "w".into(),
                shape: vec![n],
                init_std: 1.0,
                init_const: 0.0,
            }],
            tp: vec![],
            st: vec![],
            graphs: Default::default(),
        };
        ModelState::init(&entry, seed)
    }

    #[test]
    fn threshold_is_order_statistic() {
        let w = vec![0.1, -0.5, 0.3, 0.2, -0.05];
        // fraction 0.4 -> k = 2 smallest pruned -> threshold = 3rd mag
        let thr = magnitude_threshold(&w, 0.4);
        assert!((thr - 0.2).abs() < 1e-7);
        assert_eq!(magnitude_threshold(&w, 0.0), 0.0);
        assert_eq!(magnitude_threshold(&[], 0.5), 0.0);
    }

    #[test]
    fn prunes_requested_fraction() {
        check(20, |rng| {
            let n = 50 + rng.below(500);
            let mut s = state(n, rng.next_u64());
            let got = prune_by_magnitude(&mut s, 0.9);
            ensure(
                (got - 0.9).abs() < 0.02,
                format!("pruned fraction {got} != 0.9"),
            )?;
            // masked weights are exactly the small ones
            let kept: Vec<f32> = s.qws[0]
                .data()
                .iter()
                .filter(|&&v| v != 0.0)
                .map(|v| v.abs())
                .collect();
            let dropped_max = s.qws[0]
                .data()
                .iter()
                .zip(s.masks[0].data())
                .filter(|(_, &m)| m == 0.0)
                .map(|(w, _)| w.abs())
                .fold(0.0f32, f32::max);
            if let Some(kept_min) = kept.iter().cloned().reduce(f32::min) {
                ensure(dropped_max <= kept_min, "order preserved")?;
            }
            Ok(())
        });
    }

    #[test]
    fn repruning_keeps_already_masked() {
        let mut s = state(100, 3);
        prune_by_magnitude(&mut s, 0.5);
        let masks1: Vec<f32> = s.masks[0].data().to_vec();
        prune_by_magnitude(&mut s, 0.0);
        for (a, b) in masks1.iter().zip(s.masks[0].data()) {
            assert!(!(*a == 0.0 && *b != 0.0), "mask resurrected");
        }
    }

    #[test]
    fn full_fraction_handled() {
        let mut s = state(10, 4);
        // fraction just below 1 prunes everything but the max element(s)
        let got = prune_by_magnitude(&mut s, 0.99);
        assert!(got >= 0.89);
    }
}

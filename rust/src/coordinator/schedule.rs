//! Method -> phase plan (the paper's Sec. 2.3 training routine).
//!
//! * Baseline: one unregularized phase.
//! * l1:       one phase with alpha_l1 (applied to the quantized weights).
//! * Bl1:      an l1 pretraining phase, then the bit-slice l1 phase — "it
//!             would be more efficient in reaching higher sparsity by
//!             starting from a pretrained, element-wise sparse model".
//! * Pruned:   unregularized pretraining, magnitude pruning, masked
//!             fine-tuning (the classic Han-style baseline in the tables).

use crate::config::{Method, RunConfig};

/// One contiguous stretch of training with fixed hyperparameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    pub name: &'static str,
    pub steps: usize,
    pub alpha_l1: f32,
    pub alpha_bl1: f32,
    /// magnitude-prune this fraction per layer *before* the phase starts
    pub prune_before: Option<f32>,
}

/// The full plan for a run.
#[derive(Debug, Clone, PartialEq)]
pub struct PhasePlan {
    pub phases: Vec<Phase>,
}

impl PhasePlan {
    pub fn for_config(cfg: &RunConfig) -> PhasePlan {
        let phases = match cfg.method {
            Method::Baseline => vec![Phase {
                name: "train",
                steps: cfg.steps,
                alpha_l1: 0.0,
                alpha_bl1: 0.0,
                prune_before: None,
            }],
            Method::L1 => vec![Phase {
                name: "l1",
                steps: cfg.steps,
                alpha_l1: cfg.alpha_l1,
                alpha_bl1: 0.0,
                prune_before: None,
            }],
            Method::Bl1 => vec![
                Phase {
                    name: "l1-pretrain",
                    steps: cfg.pretrain_steps,
                    alpha_l1: cfg.alpha_l1,
                    alpha_bl1: 0.0,
                    prune_before: None,
                },
                Phase {
                    name: "bl1",
                    steps: cfg.steps,
                    alpha_l1: 0.0,
                    alpha_bl1: cfg.alpha_bl1,
                    prune_before: None,
                },
            ],
            Method::Pruned => vec![
                Phase {
                    name: "pretrain",
                    steps: cfg.pretrain_steps,
                    alpha_l1: 0.0,
                    alpha_bl1: 0.0,
                    prune_before: None,
                },
                Phase {
                    name: "finetune",
                    steps: cfg.steps,
                    alpha_l1: 0.0,
                    alpha_bl1: 0.0,
                    prune_before: Some(cfg.prune_fraction),
                },
            ],
        };
        PhasePlan { phases }
    }

    pub fn total_steps(&self) -> usize {
        self.phases.iter().map(|p| p.steps).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn cfg(method: Method) -> RunConfig {
        let mut c = RunConfig::defaults("mlp");
        c.method = method;
        c.steps = 100;
        c.pretrain_steps = 40;
        c
    }

    #[test]
    fn bl1_plan_pretrains_with_l1() {
        let p = PhasePlan::for_config(&cfg(Method::Bl1));
        assert_eq!(p.phases.len(), 2);
        assert!(p.phases[0].alpha_l1 > 0.0);
        assert_eq!(p.phases[0].alpha_bl1, 0.0);
        assert_eq!(p.phases[1].alpha_l1, 0.0);
        assert!(p.phases[1].alpha_bl1 > 0.0);
        assert_eq!(p.total_steps(), 140);
    }

    #[test]
    fn pruned_plan_prunes_before_finetune() {
        let p = PhasePlan::for_config(&cfg(Method::Pruned));
        assert_eq!(p.phases.len(), 2);
        assert_eq!(p.phases[0].prune_before, None);
        assert_eq!(p.phases[1].prune_before, Some(0.90));
        assert_eq!(p.phases[1].alpha_l1, 0.0);
    }

    #[test]
    fn single_phase_methods() {
        assert_eq!(PhasePlan::for_config(&cfg(Method::Baseline)).phases.len(), 1);
        let l1 = PhasePlan::for_config(&cfg(Method::L1));
        assert_eq!(l1.phases.len(), 1);
        assert!(l1.phases[0].alpha_l1 > 0.0);
    }
}

//! The training coordinator — the L3 runtime that drives the AOT graphs.
//!
//! * [`state`]      — the device-facing model state (params, velocities,
//!                    masks) in the manifest's canonical flattened order.
//! * [`schedule`]   — method -> phase plan (pretrain / regularize / prune /
//!                    fine-tune), implementing the paper's Sec. 2.3 routine.
//! * [`trainer`]    — the step loop: prefetched batches in, state cycled
//!                    through the `train_step` executable, metrics out.
//! * [`pruning`]    — per-layer magnitude pruning (the "Pruned" baseline).
//! * [`evaluator`]  — quantized deployment accuracy over a test set.
//! * [`checkpoint`] — binary tensor snapshots + JSON metadata.
//! * [`metrics`]    — JSONL step metrics and Fig-2 sparsity traces.

pub mod checkpoint;
pub mod evaluator;
pub mod metrics;
pub mod pruning;
pub mod schedule;
pub mod state;
pub mod trainer;

pub use schedule::{Phase, PhasePlan};
pub use state::ModelState;
pub use trainer::{TrainOutcome, Trainer};

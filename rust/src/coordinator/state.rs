//! Model state in the manifest's canonical flattened order.
//!
//! The `train_step` graph's input layout is
//! `[QW..., TP..., ST..., VQ..., VT..., MASK..., x, y, scalars...]` and its
//! first `QW+TP+ST+VQ+VT` outputs are the updated state in the same order
//! (see python/compile/train.py). [`ModelState`] owns those tensors on the
//! host and knows how to initialize, snapshot and reload them.

use anyhow::Result;

use crate::runtime::artifact::{ModelEntry, ParamEntry};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Host copy of all state tensors for one model.
#[derive(Debug, Clone)]
pub struct ModelState {
    /// quantized-kind weights (trained, regularized, mapped to ReRAM)
    pub qws: Vec<Tensor>,
    /// trainable plain params (biases, bn scale/bias)
    pub tps: Vec<Tensor>,
    /// bn running stats
    pub sts: Vec<Tensor>,
    /// momentum buffers for qws / tps
    pub vqs: Vec<Tensor>,
    pub vts: Vec<Tensor>,
    /// 0/1 pruning masks over qws
    pub masks: Vec<Tensor>,
}

fn init_tensor(p: &ParamEntry, rng: &mut Rng) -> Tensor {
    if p.init_std > 0.0 {
        Tensor::new(p.shape.clone(), rng.normal_vec(p.numel(), p.init_std))
            .expect("init shape")
    } else {
        Tensor::full(p.shape.clone(), p.init_const)
    }
}

impl ModelState {
    /// Fresh state: He-normal weights (init specs from the manifest),
    /// zero velocities, all-ones masks.
    pub fn init(entry: &ModelEntry, seed: u64) -> ModelState {
        let mut root = Rng::new(seed);
        let mut init_group = |ps: &[ParamEntry], tag: u64| -> Vec<Tensor> {
            ps.iter()
                .enumerate()
                .map(|(i, p)| init_tensor(p, &mut root.fork(tag * 1000 + i as u64)))
                .collect()
        };
        let qws = init_group(&entry.qw, 1);
        let tps = init_group(&entry.tp, 2);
        let sts = init_group(&entry.st, 3);
        let vqs = entry.qw.iter().map(|p| Tensor::zeros(p.shape.clone())).collect();
        let vts = entry.tp.iter().map(|p| Tensor::zeros(p.shape.clone())).collect();
        let masks = entry.qw.iter().map(|p| Tensor::full(p.shape.clone(), 1.0)).collect();
        ModelState {
            qws,
            tps,
            sts,
            vqs,
            vts,
            masks,
        }
    }

    /// Number of leading `train_step` outputs that are state tensors.
    pub fn train_state_outputs(&self) -> usize {
        self.qws.len() + self.tps.len() + self.sts.len() + self.vqs.len() + self.vts.len()
    }

    /// The state literals in `train_step` input order (before x/y/scalars).
    pub fn to_train_literals(&self) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::new();
        for group in [&self.qws, &self.tps, &self.sts, &self.vqs, &self.vts, &self.masks] {
            for t in group.iter() {
                lits.push(t.to_literal()?);
            }
        }
        Ok(lits)
    }

    /// The state literals in `eval_step` input order: QW TP ST MASK.
    pub fn to_eval_literals(&self) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::new();
        for group in [&self.qws, &self.tps, &self.sts, &self.masks] {
            for t in group.iter() {
                lits.push(t.to_literal()?);
            }
        }
        Ok(lits)
    }

    /// Absorb the state outputs of one `train_step` execution (the leading
    /// `train_state_outputs()` literals, in order).
    pub fn absorb_train_outputs(&mut self, outs: &[xla::Literal]) -> Result<()> {
        let mut idx = 0;
        for group in [
            &mut self.qws,
            &mut self.tps,
            &mut self.sts,
            &mut self.vqs,
            &mut self.vts,
        ] {
            for slot in group.iter_mut() {
                *slot = Tensor::from_literal(&outs[idx])?;
                idx += 1;
            }
        }
        Ok(())
    }

    /// Reset momentum (used at phase boundaries — the paper restarts the
    /// optimizer when switching regularizers).
    pub fn reset_velocity(&mut self) {
        for v in self.vqs.iter_mut().chain(self.vts.iter_mut()) {
            *v = Tensor::zeros(v.shape().to_vec());
        }
    }

    /// Apply masks to the weights (after pruning, so the next quantize
    /// sees zeros immediately).
    pub fn apply_masks(&mut self) {
        for (w, m) in self.qws.iter_mut().zip(&self.masks) {
            for (wv, mv) in w.data_mut().iter_mut().zip(m.data()) {
                *wv *= mv;
            }
        }
    }

    /// Named qw tensors (for mapping / analysis).
    pub fn named_qws(&self, entry: &ModelEntry) -> Vec<(String, Tensor)> {
        entry
            .qw
            .iter()
            .zip(&self.qws)
            .map(|(p, t)| (p.name.clone(), t.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ParamEntry;

    fn entry() -> ModelEntry {
        ModelEntry {
            name: "toy".into(),
            batch: 4,
            input_shape: vec![8],
            num_classes: 3,
            qw: vec![
                ParamEntry {
                    name: "fc1/w".into(),
                    shape: vec![8, 5],
                    init_std: 0.5,
                    init_const: 0.0,
                },
                ParamEntry {
                    name: "fc2/w".into(),
                    shape: vec![5, 3],
                    init_std: 0.6,
                    init_const: 0.0,
                },
            ],
            tp: vec![ParamEntry {
                name: "fc1/b".into(),
                shape: vec![5],
                init_std: 0.0,
                init_const: 0.0,
            }],
            st: vec![ParamEntry {
                name: "bn/var".into(),
                shape: vec![5],
                init_std: 0.0,
                init_const: 1.0,
            }],
            graphs: Default::default(),
        }
    }

    #[test]
    fn init_respects_specs() {
        let s = ModelState::init(&entry(), 1);
        assert_eq!(s.qws.len(), 2);
        assert_eq!(s.qws[0].shape(), &[8, 5]);
        assert!(s.qws[0].max_abs() > 0.0);
        assert_eq!(s.tps[0].data().iter().sum::<f32>(), 0.0);
        assert!(s.sts[0].data().iter().all(|&v| v == 1.0));
        assert!(s.masks.iter().all(|m| m.data().iter().all(|&v| v == 1.0)));
        assert_eq!(s.train_state_outputs(), 2 + 1 + 1 + 2 + 1);
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let a = ModelState::init(&entry(), 7);
        let b = ModelState::init(&entry(), 7);
        assert_eq!(a.qws[0], b.qws[0]);
        let c = ModelState::init(&entry(), 8);
        assert_ne!(a.qws[0], c.qws[0]);
    }

    #[test]
    fn apply_masks_zeroes_weights() {
        let mut s = ModelState::init(&entry(), 1);
        s.masks[0].data_mut()[0] = 0.0;
        let w0_before = s.qws[0].data()[0];
        assert!(w0_before != 0.0);
        s.apply_masks();
        assert_eq!(s.qws[0].data()[0], 0.0);
        assert_ne!(s.qws[0].data()[1], 0.0);
    }

    #[test]
    fn reset_velocity_zeroes_buffers() {
        let mut s = ModelState::init(&entry(), 1);
        s.vqs[0].data_mut()[3] = 5.0;
        s.reset_velocity();
        assert!(s.vqs[0].data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn literal_roundtrip_preserves_order() {
        let s = ModelState::init(&entry(), 2);
        let lits = s.to_train_literals().unwrap();
        // qw(2) tp(1) st(1) vq(2) vt(1) mask(2) = 9
        assert_eq!(lits.len(), 9);
        let t = Tensor::from_literal(&lits[0]).unwrap();
        assert_eq!(t, s.qws[0]);
        let eval = s.to_eval_literals().unwrap();
        assert_eq!(eval.len(), 2 + 1 + 1 + 2);
    }
}

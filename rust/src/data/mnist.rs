//! MNIST IDX parser (LeCun et al. format).
//!
//! Expects the classic four files under the given directory (optionally
//! without the `-idx?-ubyte` suffix variations):
//!   train-images-idx3-ubyte  train-labels-idx1-ubyte
//!   t10k-images-idx3-ubyte   t10k-labels-idx1-ubyte
//! Pixels are scaled to [0, 1]; examples are flattened to 784 features.

use std::path::Path;

use anyhow::{Context, Result};

use super::Dataset;

const IMAGES_MAGIC: u32 = 0x0000_0803;
const LABELS_MAGIC: u32 = 0x0000_0801;

fn read_u32(bytes: &[u8], off: usize) -> Result<u32> {
    let b = bytes
        .get(off..off + 4)
        .context("IDX file truncated (header)")?;
    Ok(u32::from_be_bytes([b[0], b[1], b[2], b[3]]))
}

/// Parse an IDX3 image file into (n, rows, cols, pixels).
pub fn parse_images(bytes: &[u8]) -> Result<(usize, usize, usize, Vec<f32>)> {
    anyhow::ensure!(read_u32(bytes, 0)? == IMAGES_MAGIC, "bad IDX3 magic");
    let n = read_u32(bytes, 4)? as usize;
    let rows = read_u32(bytes, 8)? as usize;
    let cols = read_u32(bytes, 12)? as usize;
    let want = n * rows * cols;
    let data = bytes.get(16..16 + want).context("IDX3 truncated (data)")?;
    anyhow::ensure!(bytes.len() == 16 + want, "IDX3 trailing bytes");
    Ok((
        n,
        rows,
        cols,
        data.iter().map(|&b| b as f32 / 255.0).collect(),
    ))
}

/// Parse an IDX1 label file.
pub fn parse_labels(bytes: &[u8]) -> Result<Vec<i32>> {
    anyhow::ensure!(read_u32(bytes, 0)? == LABELS_MAGIC, "bad IDX1 magic");
    let n = read_u32(bytes, 4)? as usize;
    let data = bytes.get(8..8 + n).context("IDX1 truncated (data)")?;
    anyhow::ensure!(bytes.len() == 8 + n, "IDX1 trailing bytes");
    let labels: Vec<i32> = data.iter().map(|&b| b as i32).collect();
    anyhow::ensure!(
        labels.iter().all(|&l| (0..10).contains(&l)),
        "label out of range"
    );
    Ok(labels)
}

/// Load the train or test split from `dir`.
pub fn load(dir: &Path, train: bool) -> Result<Dataset> {
    let (img_name, lbl_name) = if train {
        ("train-images-idx3-ubyte", "train-labels-idx1-ubyte")
    } else {
        ("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte")
    };
    let img_bytes = std::fs::read(dir.join(img_name))
        .with_context(|| format!("reading {}", dir.join(img_name).display()))?;
    let lbl_bytes = std::fs::read(dir.join(lbl_name))?;
    let (n, rows, cols, features) = parse_images(&img_bytes)?;
    let labels = parse_labels(&lbl_bytes)?;
    anyhow::ensure!(n == labels.len(), "image/label count mismatch");
    anyhow::ensure!(rows == 28 && cols == 28, "expected 28x28 MNIST");
    Ok(Dataset {
        features: std::sync::Arc::new(features),
        labels: std::sync::Arc::new(labels),
        example_shape: vec![rows * cols],
        num_classes: 10,
        source: "mnist".to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx3(n: usize, rows: usize, cols: usize, pix: &[u8]) -> Vec<u8> {
        let mut v = Vec::new();
        v.extend_from_slice(&IMAGES_MAGIC.to_be_bytes());
        v.extend_from_slice(&(n as u32).to_be_bytes());
        v.extend_from_slice(&(rows as u32).to_be_bytes());
        v.extend_from_slice(&(cols as u32).to_be_bytes());
        v.extend_from_slice(pix);
        v
    }

    fn idx1(labels: &[u8]) -> Vec<u8> {
        let mut v = Vec::new();
        v.extend_from_slice(&LABELS_MAGIC.to_be_bytes());
        v.extend_from_slice(&(labels.len() as u32).to_be_bytes());
        v.extend_from_slice(labels);
        v
    }

    #[test]
    fn parses_wellformed_idx() {
        let pix: Vec<u8> = (0..2 * 4).map(|i| (i * 32) as u8).collect();
        let (n, r, c, f) = parse_images(&idx3(2, 2, 2, &pix)).unwrap();
        assert_eq!((n, r, c), (2, 2, 2));
        assert!((f[1] - 32.0 / 255.0).abs() < 1e-6);
        let labels = parse_labels(&idx1(&[3, 9])).unwrap();
        assert_eq!(labels, vec![3, 9]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(parse_images(&[0, 0, 8, 4, 0, 0, 0, 0]).is_err());
        let mut good = idx3(1, 2, 2, &[1, 2, 3, 4]);
        good.pop();
        assert!(parse_images(&good).is_err());
        assert!(parse_labels(&idx1(&[10])).is_err()); // label out of range
    }

    #[test]
    fn roundtrip_through_files() {
        let dir = std::env::temp_dir().join(format!("mnist-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let pix: Vec<u8> = vec![128; 28 * 28 * 3];
        std::fs::write(dir.join("train-images-idx3-ubyte"), idx3(3, 28, 28, &pix)).unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), idx1(&[0, 1, 2])).unwrap();
        let ds = load(&dir, true).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 784);
        assert_eq!(ds.source, "mnist");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_files_error() {
        assert!(load(Path::new("/definitely/missing"), true).is_err());
    }
}

//! Dataset substrate: MNIST / CIFAR-10 parsers, a deterministic synthetic
//! fallback, and the batching/prefetching pipeline.
//!
//! The sandbox has no network, so real dataset files may be absent; in that
//! case [`Dataset::auto`] falls back to [`synthetic`] — deterministic,
//! class-templated data with the same shapes and cardinality (see DESIGN.md
//! §Substitutions). EXPERIMENTS.md records which source each run used.

pub mod cifar;
pub mod loader;
pub mod mnist;
pub mod synthetic;

use anyhow::Result;

use crate::tensor::{IntTensor, Tensor};

/// An in-memory labelled dataset (images flattened row-major).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `n * dim` features in [0, 1].
    pub features: std::sync::Arc<Vec<f32>>,
    pub labels: std::sync::Arc<Vec<i32>>,
    /// per-example shape, e.g. [784] or [32, 32, 3]
    pub example_shape: Vec<usize>,
    pub num_classes: usize,
    /// provenance, recorded in metrics ("mnist", "synthetic-mnist", ...)
    pub source: String,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.example_shape.iter().product()
    }

    /// Copy example `i`'s features into `out`.
    pub fn write_example(&self, i: usize, out: &mut [f32]) {
        let d = self.dim();
        out.copy_from_slice(&self.features[i * d..(i + 1) * d]);
    }

    /// Load the named dataset, preferring real files under `data_dir` and
    /// falling back to the synthetic equivalent (`n_fallback` examples).
    pub fn auto(
        kind: &str,
        data_dir: &std::path::Path,
        train: bool,
        n_fallback: usize,
        seed: u64,
    ) -> Result<Dataset> {
        match kind {
            "mnist" => {
                let dir = data_dir.join("mnist");
                match mnist::load(&dir, train) {
                    Ok(ds) => Ok(ds),
                    Err(_) => Ok(synthetic::mnist(n_fallback, seed ^ train as u64)),
                }
            }
            "cifar10" => {
                let dir = data_dir.join("cifar10");
                match cifar::load(&dir, train) {
                    Ok(ds) => Ok(ds),
                    Err(_) => Ok(synthetic::cifar10(n_fallback, seed ^ train as u64)),
                }
            }
            other => anyhow::bail!("unknown dataset kind {other:?}"),
        }
    }
}

/// One training batch, shaped for the AOT graphs.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Tensor,
    pub y: IntTensor,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_falls_back_to_synthetic() {
        let dir = std::path::PathBuf::from("/nonexistent-data-dir");
        let ds = Dataset::auto("mnist", &dir, true, 256, 1).unwrap();
        assert_eq!(ds.source, "synthetic-mnist");
        assert_eq!(ds.len(), 256);
        assert_eq!(ds.example_shape, vec![784]);
        let ds = Dataset::auto("cifar10", &dir, false, 64, 1).unwrap();
        assert_eq!(ds.source, "synthetic-cifar10");
        assert_eq!(ds.example_shape, vec![32, 32, 3]);
    }

    #[test]
    fn unknown_kind_errors() {
        let dir = std::path::PathBuf::from("/tmp");
        assert!(Dataset::auto("imagenet", &dir, true, 1, 1).is_err());
    }
}

//! Batching + prefetching pipeline.
//!
//! [`BatchPlan`] deterministically maps a step index to the example indices
//! of its batch (reshuffling every epoch with a per-epoch fork of the seed),
//! and [`BatchStream`] materializes batches on a background thread with
//! bounded lookahead — the XLA step is the consumer, so batch assembly
//! overlaps compute (DESIGN.md §Perf L3).

use crate::tensor::{IntTensor, Tensor};
use crate::util::pool::Prefetcher;
use crate::util::rng::Rng;

use super::{Batch, Dataset};

/// Deterministic step -> example-indices mapping.
#[derive(Debug, Clone)]
pub struct BatchPlan {
    n: usize,
    batch: usize,
    seed: u64,
}

impl BatchPlan {
    pub fn new(n: usize, batch: usize, seed: u64) -> Self {
        assert!(n > 0 && batch > 0);
        BatchPlan { n, batch, seed }
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.n / self.batch.min(self.n).max(1)
    }

    /// Example indices for step `step` (0-based, increasing forever).
    /// Batches never straddle epochs; short datasets wrap within the epoch.
    pub fn indices(&self, step: usize) -> Vec<usize> {
        let bpe = self.batches_per_epoch().max(1);
        let epoch = step / bpe;
        let slot = step % bpe;
        let mut order: Vec<usize> = (0..self.n).collect();
        let mut rng = Rng::new(self.seed).fork(epoch as u64);
        rng.shuffle(&mut order);
        (0..self.batch)
            .map(|j| order[(slot * self.batch + j) % self.n])
            .collect()
    }
}

/// Assemble the batch tensors for a list of example indices.
pub fn assemble(ds: &Dataset, indices: &[usize]) -> Batch {
    let dim = ds.dim();
    let mut x = vec![0.0f32; indices.len() * dim];
    for (row, &i) in indices.iter().enumerate() {
        ds.write_example(i, &mut x[row * dim..(row + 1) * dim]);
    }
    let mut shape = vec![indices.len()];
    shape.extend_from_slice(&ds.example_shape);
    let y: Vec<i32> = indices.iter().map(|&i| ds.labels[i]).collect();
    Batch {
        x: Tensor::new(shape, x).expect("assembled shape"),
        y: IntTensor::new(vec![indices.len()], y).expect("labels shape"),
    }
}

/// Background-prefetched stream of `steps` batches.
pub struct BatchStream {
    inner: Prefetcher<Batch>,
}

impl std::fmt::Debug for BatchStream {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BatchStream").field("inner", &self.inner).finish()
    }
}

impl BatchStream {
    pub fn new(ds: Dataset, batch: usize, steps: usize, seed: u64, depth: usize) -> Self {
        let plan = BatchPlan::new(ds.len(), batch, seed);
        let inner = Prefetcher::spawn(steps, depth, move |step| {
            assemble(&ds, &plan.indices(step))
        });
        BatchStream { inner }
    }

    pub fn next(&self) -> Option<Batch> {
        self.inner.next()
    }
}

// Sequential evaluation batching lives in `crate::serve::accuracy`: exact
// batch slices here, fixed-shape padding inside the backend that needs it.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn plan_is_deterministic_and_epochwise_shuffled() {
        let plan = BatchPlan::new(100, 10, 7);
        assert_eq!(plan.indices(3), plan.indices(3));
        // within an epoch, batches partition the dataset
        let mut seen: Vec<usize> = (0..10).flat_map(|s| plan.indices(s)).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
        // different epochs use different orders
        assert_ne!(plan.indices(0), plan.indices(10));
    }

    #[test]
    fn assemble_shapes_and_content() {
        let ds = synthetic::mnist(32, 1);
        let b = assemble(&ds, &[0, 5, 9]);
        assert_eq!(b.x.shape(), &[3, 784]);
        assert_eq!(b.y.shape(), &[3]);
        assert_eq!(b.y.data()[1], ds.labels[5]);
        let mut want = vec![0.0; 784];
        ds.write_example(9, &mut want);
        assert_eq!(&b.x.data()[2 * 784..], &want[..]);
    }

    #[test]
    fn stream_yields_exactly_steps_batches() {
        let ds = synthetic::mnist(64, 2);
        let stream = BatchStream::new(ds, 16, 7, 3, 2);
        let mut n = 0;
        while let Some(b) = stream.next() {
            assert_eq!(b.x.shape()[0], 16);
            n += 1;
        }
        assert_eq!(n, 7);
    }

    #[test]
    fn small_dataset_wraps_within_epoch() {
        let plan = BatchPlan::new(5, 8, 1);
        let idx = plan.indices(0);
        assert_eq!(idx.len(), 8);
        assert!(idx.iter().all(|&i| i < 5));
    }
}

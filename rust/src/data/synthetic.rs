//! Deterministic synthetic datasets (DESIGN.md §Substitutions).
//!
//! The sandbox cannot download MNIST/CIFAR-10, so each is substituted by a
//! class-templated generator with the same shapes, value range and
//! cardinality: every class gets a smooth pseudo-random template; examples
//! are `clip(template * strength + noise)`. The tasks are learnable but not
//! trivial (templates overlap, noise is substantial), which is what the
//! regularizer-vs-accuracy trade-off needs to be exercised meaningfully.
//! Fully deterministic in (n, seed).

use crate::util::rng::Rng;

use super::Dataset;

/// Smooth a flat image in-place with a separable 3-tap box blur (makes
/// templates spatially coherent instead of white noise).
fn smooth2d(img: &mut [f32], h: usize, w: usize, ch: usize, passes: usize) {
    let mut tmp = vec![0.0f32; img.len()];
    for _ in 0..passes {
        // horizontal
        for y in 0..h {
            for x in 0..w {
                for c in 0..ch {
                    let mut acc = 0.0;
                    let mut cnt = 0.0;
                    for dx in -1i64..=1 {
                        let xx = x as i64 + dx;
                        if (0..w as i64).contains(&xx) {
                            acc += img[(y * w + xx as usize) * ch + c];
                            cnt += 1.0;
                        }
                    }
                    tmp[(y * w + x) * ch + c] = acc / cnt;
                }
            }
        }
        // vertical
        for y in 0..h {
            for x in 0..w {
                for c in 0..ch {
                    let mut acc = 0.0;
                    let mut cnt = 0.0;
                    for dy in -1i64..=1 {
                        let yy = y as i64 + dy;
                        if (0..h as i64).contains(&yy) {
                            acc += tmp[(yy as usize * w + x) * ch + c];
                            cnt += 1.0;
                        }
                    }
                    img[(y * w + x) * ch + c] = acc / cnt;
                }
            }
        }
    }
}

fn normalize01(t: &mut [f32]) {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &v in t.iter() {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-6);
    for v in t.iter_mut() {
        *v = (*v - lo) / span;
    }
}

/// Class templates share a common base (`1 - delta` of the energy); only a
/// `delta` fraction is class-specific. Small delta + heavy per-example
/// noise keeps classification non-trivial (accuracy lands well below 100%),
/// which the tables' accuracy column needs to differentiate methods.
fn make_templates(
    classes: usize,
    h: usize,
    w: usize,
    ch: usize,
    delta: f32,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut base_rng = Rng::new(seed ^ 0xBA5E_BA5E);
    let mut base: Vec<f32> = (0..h * w * ch).map(|_| base_rng.next_f32()).collect();
    smooth2d(&mut base, h, w, ch, 2);
    (0..classes)
        .map(|c| {
            let mut rng = Rng::new(seed ^ (0xC1A5_5000 + c as u64));
            let mut uniq: Vec<f32> = (0..h * w * ch).map(|_| rng.next_f32()).collect();
            smooth2d(&mut uniq, h, w, ch, 2);
            let mut t: Vec<f32> = base
                .iter()
                .zip(&uniq)
                .map(|(b, u)| (1.0 - delta) * b + delta * u)
                .collect();
            normalize01(&mut t);
            t
        })
        .collect()
}

fn generate(
    n: usize,
    h: usize,
    w: usize,
    ch: usize,
    classes: usize,
    delta: f32,
    noise: f32,
    seed: u64,
    source: &str,
) -> Dataset {
    let templates = make_templates(classes, h, w, ch, delta, seed);
    let dim = h * w * ch;
    let mut rng = Rng::new(seed ^ 0xDA7A_5E7);
    let mut features = Vec::with_capacity(n * dim);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % classes; // balanced classes
        let t = &templates[class];
        // Confuser blending + heavy noise keep the task non-trivial: the
        // true template carries ~55-75% of the signal, a random other class
        // ~25%, and the noise floor is comparable to the signal gap.
        let confuser = &templates[rng.below(classes)];
        let strength = 0.55 + 0.2 * rng.next_f32();
        let mix = 0.25;
        for (&tv, &cv) in t.iter().zip(confuser.iter()) {
            let v = tv * strength + cv * mix + noise * (rng.next_f32() - 0.5);
            features.push(v.clamp(0.0, 1.0));
        }
        labels.push(class as i32);
    }
    // shuffle example order (labels and features together)
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut sf = Vec::with_capacity(n * dim);
    let mut sl = Vec::with_capacity(n);
    for &i in &order {
        sf.extend_from_slice(&features[i * dim..(i + 1) * dim]);
        sl.push(labels[i]);
    }
    Dataset {
        features: std::sync::Arc::new(sf),
        labels: std::sync::Arc::new(sl),
        example_shape: if ch == 1 {
            vec![h * w]
        } else {
            vec![h, w, ch]
        },
        num_classes: classes,
        source: source.to_string(),
    }
}

/// Synthetic stand-in for MNIST: 28x28 grayscale, flattened to 784.
pub fn mnist(n: usize, seed: u64) -> Dataset {
    generate(n, 28, 28, 1, 10, 0.35, 0.7, seed, "synthetic-mnist")
}

/// Synthetic stand-in for CIFAR-10: 32x32x3.
pub fn cifar10(n: usize, seed: u64) -> Dataset {
    generate(n, 32, 32, 3, 10, 0.30, 0.8, seed, "synthetic-cifar10")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = mnist(64, 5);
        let b = mnist(64, 5);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
        let c = mnist(64, 6);
        assert_ne!(a.features, c.features);
    }

    #[test]
    fn shapes_and_ranges() {
        let ds = cifar10(40, 1);
        assert_eq!(ds.len(), 40);
        assert_eq!(ds.dim(), 32 * 32 * 3);
        assert!(ds.features.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(ds.labels.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn classes_are_balanced() {
        let ds = mnist(1000, 2);
        let mut counts = [0usize; 10];
        for &l in ds.labels.iter() {
            counts[l as usize] += 1;
        }
        assert_eq!(counts, [100; 10]);
    }

    #[test]
    fn classes_are_separable_by_template_distance() {
        // nearest-template classification on held-out samples should beat
        // chance by a wide margin — the task is learnable.
        let ds = mnist(500, 3);
        let templates = make_templates(10, 28, 28, 1, 0.35, 3);
        let dim = ds.dim();
        let mut correct = 0;
        for i in 0..ds.len() {
            let x = &ds.features[i * dim..(i + 1) * dim];
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = templates[a]
                        .iter()
                        .zip(x)
                        .map(|(t, v)| (t - v) * (t - v))
                        .sum();
                    let db: f32 = templates[b]
                        .iter()
                        .zip(x)
                        .map(|(t, v)| (t - v) * (t - v))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == ds.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.len() as f64;
        assert!(acc > 0.6, "nearest-template accuracy {acc}");
    }

    #[test]
    fn noise_makes_examples_differ_within_class() {
        let ds = mnist(20, 4);
        let dim = ds.dim();
        // find two examples of the same class
        let mut by_class: std::collections::HashMap<i32, Vec<usize>> = Default::default();
        for (i, &l) in ds.labels.iter().enumerate() {
            by_class.entry(l).or_default().push(i);
        }
        let pair = by_class.values().find(|v| v.len() >= 2).unwrap();
        let (a, b) = (pair[0], pair[1]);
        assert_ne!(
            &ds.features[a * dim..(a + 1) * dim],
            &ds.features[b * dim..(b + 1) * dim]
        );
    }
}

//! CIFAR-10 binary-format parser.
//!
//! The canonical `cifar-10-batches-bin` layout: each record is 1 label byte
//! followed by 3072 pixel bytes in CHW order (1024 R, 1024 G, 1024 B).
//! Our models take NHWC, so records are transposed to HWC on load and
//! scaled to [0, 1].

use std::path::Path;

use anyhow::{Context, Result};

use super::Dataset;

pub const RECORD: usize = 1 + 3 * 32 * 32;

/// Parse one batch file's bytes, appending to features/labels.
pub fn parse_batch(bytes: &[u8], features: &mut Vec<f32>, labels: &mut Vec<i32>) -> Result<usize> {
    anyhow::ensure!(
        bytes.len() % RECORD == 0,
        "CIFAR batch size {} not a multiple of record size {RECORD}",
        bytes.len()
    );
    let n = bytes.len() / RECORD;
    features.reserve(n * 3072);
    for rec in bytes.chunks_exact(RECORD) {
        let label = rec[0] as i32;
        anyhow::ensure!((0..10).contains(&label), "label {label} out of range");
        labels.push(label);
        let pix = &rec[1..];
        // CHW -> HWC
        for hw in 0..1024 {
            for c in 0..3 {
                features.push(pix[c * 1024 + hw] as f32 / 255.0);
            }
        }
    }
    Ok(n)
}

/// Load train (data_batch_1..5.bin) or test (test_batch.bin) split.
pub fn load(dir: &Path, train: bool) -> Result<Dataset> {
    let names: Vec<String> = if train {
        (1..=5).map(|i| format!("data_batch_{i}.bin")).collect()
    } else {
        vec!["test_batch.bin".to_string()]
    };
    let mut features = Vec::new();
    let mut labels = Vec::new();
    for name in &names {
        let bytes = std::fs::read(dir.join(name))
            .with_context(|| format!("reading {}", dir.join(name).display()))?;
        parse_batch(&bytes, &mut features, &mut labels)?;
    }
    anyhow::ensure!(!labels.is_empty(), "no CIFAR examples found");
    Ok(Dataset {
        features: std::sync::Arc::new(features),
        labels: std::sync::Arc::new(labels),
        example_shape: vec![32, 32, 3],
        num_classes: 10,
        source: "cifar10".to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(label: u8, fill: u8) -> Vec<u8> {
        let mut v = vec![label];
        v.extend(std::iter::repeat(fill).take(3072));
        v
    }

    #[test]
    fn parses_records_and_transposes_chw_to_hwc() {
        let mut rec = vec![7u8];
        // R plane = 10, G plane = 20, B plane = 30
        rec.extend(std::iter::repeat(10u8).take(1024));
        rec.extend(std::iter::repeat(20u8).take(1024));
        rec.extend(std::iter::repeat(30u8).take(1024));
        let mut f = Vec::new();
        let mut l = Vec::new();
        assert_eq!(parse_batch(&rec, &mut f, &mut l).unwrap(), 1);
        assert_eq!(l, vec![7]);
        // first pixel: (R, G, B) scaled
        assert!((f[0] - 10.0 / 255.0).abs() < 1e-6);
        assert!((f[1] - 20.0 / 255.0).abs() < 1e-6);
        assert!((f[2] - 30.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_partial_record_and_bad_label() {
        let mut f = Vec::new();
        let mut l = Vec::new();
        assert!(parse_batch(&record(0, 0)[..100], &mut f, &mut l).is_err());
        assert!(parse_batch(&record(11, 0), &mut f, &mut l).is_err());
    }

    #[test]
    fn loads_multi_batch_train_split() {
        let dir = std::env::temp_dir().join(format!("cifar-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for i in 1..=5 {
            let mut bytes = record((i % 10) as u8, 100);
            bytes.extend(record(((i + 1) % 10) as u8, 50));
            std::fs::write(dir.join(format!("data_batch_{i}.bin")), bytes).unwrap();
        }
        let ds = load(&dir, true).unwrap();
        assert_eq!(ds.len(), 10);
        assert_eq!(ds.example_shape, vec![32, 32, 3]);
        assert_eq!(ds.source, "cifar10");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_errors() {
        assert!(load(Path::new("/definitely/missing"), false).is_err());
    }
}

//! Flag-style CLI parser (the sandbox has no clap).
//!
//! Grammar: `binary <subcommand> [--key value]... [--switch]...`.
//! Typed getters with defaults; unknown flags are an error so typos fail
//! loudly rather than silently using a default.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    /// second positional (e.g. `reproduce table1`)
    pub target: Option<String>,
    flags: BTreeMap<String, String>,
    /// flags that were actually read by the program
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
                if let Some(second) = it.peek() {
                    if !second.starts_with("--") {
                        out.target = it.next();
                    }
                }
            }
        }
        while let Some(arg) = it.next() {
            let Some(key) = arg.strip_prefix("--") else {
                anyhow::bail!("positional argument {arg:?} not expected here");
            };
            if key.is_empty() {
                anyhow::bail!("empty flag name");
            }
            // --key=value or --key value or bare switch
            if let Some((k, v)) = key.split_once('=') {
                out.flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                out.flags.insert(key.to_string(), it.next().unwrap());
            } else {
                out.flags.insert(key.to_string(), "true".to_string());
            }
        }
        Ok(out)
    }

    pub fn from_env() -> anyhow::Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> anyhow::Result<f32> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a float, got {v:?}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Call after all getters: rejects flags the program never looked at.
    pub fn finish(&self) -> anyhow::Result<()> {
        let seen = self.seen.borrow();
        for key in self.flags.keys() {
            if !seen.iter().any(|s| s == key) {
                anyhow::bail!("unknown flag --{key}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(argv("train --model mlp --steps 300 --fresh")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_or("model", "x"), "mlp");
        assert_eq!(a.usize_or("steps", 0).unwrap(), 300);
        assert!(a.flag("fresh"));
        a.finish().unwrap();
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(argv("run --lr=0.05 --alpha-bl1=1e-5")).unwrap();
        assert!((a.f32_or("lr", 0.0).unwrap() - 0.05).abs() < 1e-9);
        assert!((a.f32_or("alpha-bl1", 0.0).unwrap() - 1e-5).abs() < 1e-12);
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply_when_missing() {
        let a = Args::parse(argv("eval")).unwrap();
        assert_eq!(a.usize_or("steps", 123).unwrap(), 123);
        assert!(!a.flag("fresh"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_is_rejected_by_finish() {
        let a = Args::parse(argv("train --tpyo 3")).unwrap();
        let _ = a.usize_or("steps", 1);
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(argv("x --steps many")).unwrap();
        assert!(a.usize_or("steps", 1).is_err());
    }

    #[test]
    fn positional_after_flags_rejected() {
        assert!(Args::parse(argv("train --a 1 stray")).is_err());
    }

    #[test]
    fn second_positional_becomes_target() {
        let a = Args::parse(argv("reproduce table1 --quick")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("reproduce"));
        assert_eq!(a.target.as_deref(), Some("table1"));
        assert!(a.flag("quick"));
        a.finish().unwrap();
    }
}

//! Support substrates the offline sandbox has no crates for.
//!
//! The vendored registry only carries the `xla` crate's dependency tree
//! (no serde/clap/tokio/criterion/proptest), so the pieces a production
//! coordinator would normally pull in are implemented here:
//!
//! * [`json`]   — a small, strict JSON parser/serializer (reads the AOT
//!   manifest written by `python/compile/aot.py`, writes metrics).
//! * [`rng`]    — deterministic SplitMix64/normal sampler (param init,
//!   synthetic datasets, shuffling).
//! * [`cli`]    — flag-style argument parser for the `bitslice-reram`
//!   binary and the examples.
//! * [`pool`]   — scoped thread pool + SPSC prefetch channel (the data
//!   pipeline's async substrate, replacing tokio).
//! * [`check`]  — mini property-testing harness (seeded case generation
//!   with failure-seed reporting), used by the unit tests in place of
//!   proptest.
//! * [`fixtures`] — shared seeded generators for the constructed
//!   bit-slice-sparse layer stacks the benches, integration tests and
//!   property tests all exercise (compiled for tests and under the
//!   `bench` feature only — the dev-dependency on ourselves turns it on
//!   for every `cargo test` / `cargo bench` build).

pub mod check;
pub mod cli;
#[cfg(any(test, feature = "bench"))]
pub mod fixtures;
pub mod json;
pub mod pool;
pub mod rng;

//! Shared seeded fixtures: the constructed bit-slice-sparse layer stacks
//! the benches (`sparse_sim`, `planner_sweep`, `reorder_sim`), the
//! integration tests and the property suites all exercise.
//!
//! Before this module each bench/test carried its own copy of "weights at
//! an exact density with a dynamic-range pin" and "a class-template MLP
//! that is bit-slice sparse by construction"; one seeded generator here
//! keeps the regimes identical everywhere, parameterized by density (and,
//! for the reorder fixtures, by row/column structure). Everything is
//! deterministic from the caller's [`Rng`] or seed.
//!
//! Compiled for unit tests and under the `bench` feature; the crate's
//! dev-dependency on itself enables the feature for every `cargo test`,
//! `cargo bench` and example build.

use crate::data::Dataset;
use crate::quant::N_SLICES;
use crate::reram::mapper::LayerMapping;
use crate::serve::{dense_stack, DenseLayer};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Weights with an exact fraction `density` of nonzero elements (random
/// magnitudes spanning all slices) plus a fixed dynamic-range pin at
/// element 0, so the qstep — and therefore the mapped codes of shared
/// elements — is density-invariant across a sweep.
pub fn weights_at_density(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Tensor {
    let n = rows * cols;
    let mut data = vec![0.0f32; n];
    let target = ((n as f64) * density) as usize;
    let mut placed = 1usize; // the pin below
    data[0] = 1.0;
    while placed < target {
        let i = rng.below(n);
        if data[i] == 0.0 {
            data[i] = (rng.next_f32() - 0.5) * 2.0;
            placed += 1;
        }
    }
    Tensor::new(vec![rows, cols], data).expect("fixture shape")
}

/// Structured-sparse weights: nonzeros live only on a scattered subset of
/// rows (`row_frac`) crossed with a scattered subset of columns
/// (`col_frac`), filled at `fill` within the active block — the "dead
/// neuron / dead feature" structure bit-slice L1 training produces, and
/// the regime where wordline/column reordering pays (the active lines are
/// scattered across every tile until the permutation clusters them). The
/// dynamic-range pin sits on the first active (row, col) so the qstep is
/// structure-invariant.
pub fn structured_sparse_weights(
    rng: &mut Rng,
    rows: usize,
    cols: usize,
    row_frac: f64,
    col_frac: f64,
    fill: f64,
) -> Tensor {
    let pick = |n: usize, frac: f64, rng: &mut Rng| -> Vec<usize> {
        let want = (((n as f64) * frac).round() as usize).clamp(1, n);
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let mut chosen = idx[..want].to_vec();
        chosen.sort_unstable();
        chosen
    };
    let active_rows = pick(rows, row_frac, rng);
    let active_cols = pick(cols, col_frac, rng);
    let mut data = vec![0.0f32; rows * cols];
    for &r in &active_rows {
        for &c in &active_cols {
            if (rng.next_f32() as f64) < fill {
                data[r * cols + c] = (rng.next_f32() - 0.5) * 2.0;
            }
        }
    }
    // pin the dynamic range inside the active block
    data[active_rows[0] * cols + active_cols[0]] = 1.0;
    Tensor::new(vec![rows, cols], data).expect("fixture shape")
}

/// Zero biases for a stack of the given fan-outs.
fn zero_biases(dims: &[usize]) -> Vec<Tensor> {
    dims.iter().map(|&d| Tensor::zeros(vec![d])).collect()
}

/// An MLP stack (`dims[0] -> dims[1] -> ...`) of [`weights_at_density`]
/// layers with zero biases — the serving/agreement tests' sparse model.
pub fn sparse_stack(seed: u64, dims: &[usize], density: f64) -> Vec<DenseLayer> {
    assert!(dims.len() >= 2, "a stack needs at least one layer");
    let mut rng = Rng::new(seed);
    let weights: Vec<(String, Tensor)> = dims
        .windows(2)
        .enumerate()
        .map(|(i, w)| {
            (
                format!("fc{}/w", i + 1),
                weights_at_density(&mut rng, w[0], w[1], density),
            )
        })
        .collect();
    dense_stack(&weights, &zero_biases(&dims[1..])).expect("fixture stack")
}

/// An MLP stack of [`structured_sparse_weights`] layers with zero biases
/// — the reorder benches'/tests' structured model.
pub fn structured_stack(
    seed: u64,
    dims: &[usize],
    row_frac: f64,
    col_frac: f64,
    fill: f64,
) -> Vec<DenseLayer> {
    assert!(dims.len() >= 2, "a stack needs at least one layer");
    let mut rng = Rng::new(seed);
    let weights: Vec<(String, Tensor)> = dims
        .windows(2)
        .enumerate()
        .map(|(i, w)| {
            (
                format!("fc{}/w", i + 1),
                structured_sparse_weights(&mut rng, w[0], w[1], row_frac, col_frac, fill),
            )
        })
        .collect();
    dense_stack(&weights, &zero_biases(&dims[1..])).expect("fixture stack")
}

/// A bottleneck-skewed MLP stack (64 -> 32 -> 512 -> 32 -> 10) shared by
/// the pipeline-timing bench and tests: the wide fc2 (32x512, moderately
/// dense) carries ~4x the per-tile ADC conversion load of every other
/// layer — its tiles convert ~128 columns where the narrow layers convert
/// <= 32 — so it is the pipeline bottleneck by construction, and most of
/// the simulator's wall-clock lives there too (which is what makes
/// replica-sharding measurably faster, not just cheaper on paper). fc3 is
/// extremely sparse: a wide hidden layer forces many rows on its
/// successor, and the sparsity keeps that successor off the critical
/// path.
pub fn bottleneck_stack(seed: u64) -> Vec<DenseLayer> {
    let mut rng = Rng::new(seed);
    let specs: [(usize, usize, f64); 4] = [
        (64, 32, 0.35),
        (32, 512, 0.35),
        (512, 32, 0.02),
        (32, 10, 0.3),
    ];
    let weights: Vec<(String, Tensor)> = specs
        .iter()
        .enumerate()
        .map(|(i, &(rows, cols, density))| {
            (
                format!("fc{}/w", i + 1),
                weights_at_density(&mut rng, rows, cols, density),
            )
        })
        .collect();
    dense_stack(&weights, &zero_biases(&[32, 512, 32, 10])).expect("fixture stack")
}

/// Paper-style mean slice-zero fraction of a mapped layer (the quantity
/// the density sweeps report on their x axis).
pub fn mean_slice_zero_fraction(layer: &LayerMapping) -> f64 {
    let numel = (layer.rows * layer.cols) as f64;
    (0..N_SLICES)
        .map(|k| 1.0 - layer.nonzero_cells(k) as f64 / numel)
        .sum::<f64>()
        / N_SLICES as f64
}

/// A class-template MLP, bit-slice sparse by construction — the planner
/// bench's model (moved here from `benches/planner_sweep.rs` so the
/// regime is shared).
///
/// Layer 1 (dim -> classes + 1): column `c < classes` holds, per 128-row
/// tile, the two most positive and two most negative
/// (class-mean - global-mean) pixels at code 12 = 0b1100 — slice 1 only,
/// tile-column currents <= 6, so the discriminative weights clip nowhere
/// at the paper's 3-bit low-slice ADCs. The last column holds the single
/// dynamic-range pin (code 255); its output is killed by a large negative
/// bias and feeds nothing, so MSB clipping on the pin never reaches the
/// logits. Layer 2 is the identity on the class units — a single code-255
/// cell per column, whose MSB clipping is a uniform monotone rescale that
/// preserves the argmax.
pub fn planted_class_stack(train: &Dataset) -> Vec<DenseLayer> {
    let dim = train.dim();
    let classes = train.num_classes;
    let hidden = classes + 1; // class units + the range-pin unit

    let mut mean = vec![0.0f64; classes * dim];
    let mut count = vec![0usize; classes];
    for i in 0..train.len() {
        let c = train.labels[i] as usize;
        count[c] += 1;
        for (j, &v) in train.features[i * dim..(i + 1) * dim].iter().enumerate() {
            mean[c * dim + j] += v as f64;
        }
    }
    for c in 0..classes {
        let inv = 1.0 / count[c].max(1) as f64;
        for j in 0..dim {
            mean[c * dim + j] *= inv;
        }
    }
    let mut gmean = vec![0.0f64; dim];
    for c in 0..classes {
        for j in 0..dim {
            gmean[j] += mean[c * dim + j] / classes as f64;
        }
    }

    let small = 12.0f32 / 256.0; // code 12 at qstep 2^-8 (pin = 1.0)
    let mut w1 = vec![0.0f32; dim * hidden];
    for c in 0..classes {
        let mut t0 = 0;
        while t0 < dim {
            let t1 = (t0 + 128).min(dim);
            let mut idx: Vec<usize> = (t0..t1).collect();
            idx.sort_by(|&a, &b| {
                let da = mean[c * dim + a] - gmean[a];
                let db = mean[c * dim + b] - gmean[b];
                db.partial_cmp(&da).unwrap()
            });
            for &j in idx.iter().take(2) {
                w1[j * hidden + c] = small;
            }
            for &j in idx.iter().rev().take(2) {
                w1[j * hidden + c] = -small;
            }
            t0 = t1;
        }
    }
    w1[classes] = 1.0; // row 0, pin column: sets the layer's dynamic range

    let mut b1 = vec![0.0f32; hidden];
    b1[classes] = -1e4; // the pin unit never survives the ReLU

    let mut w2 = vec![0.0f32; hidden * classes];
    for c in 0..classes {
        w2[c * classes + c] = 1.0;
    }

    dense_stack(
        &[
            (
                "fc1/w".into(),
                Tensor::new(vec![dim, hidden], w1).expect("fixture shape"),
            ),
            (
                "fc2/w".into(),
                Tensor::new(vec![hidden, classes], w2).expect("fixture shape"),
            ),
        ],
        &[
            Tensor::new(vec![hidden], b1).expect("fixture shape"),
            Tensor::new(vec![classes], vec![0.0; classes]).expect("fixture shape"),
        ],
    )
    .expect("fixture stack")
}

/// The golden reorder fixture: a fixed seeded structured-sparse stack
/// plus the minimum savings the reorder engine must achieve on it. The
/// regression test asserts *from these recorded fields* — not from magic
/// constants inline — so a silently weakened clustering heuristic fails
/// the build, and a deliberate change to the heuristic updates the
/// recorded floor here, in one reviewed place.
#[derive(Debug)]
pub struct ReorderGolden {
    pub stack: Vec<DenseLayer>,
    /// active wordlines, natural / reordered, whole model — the floor the
    /// clustering must clear
    pub min_wordline_saving: f64,
    /// fully-zero (skipped) tiles the reordered mapping must reach, at
    /// minimum, across the model
    pub min_skipped_tiles: usize,
}

/// Fixed parameters: 784 -> 300 -> 10, 15% of rows and columns active,
/// 30% fill inside the active block (~0.7% element density — the Bl1
/// regime with dead-line structure). On this stack the greedy clustering
/// compacts the ~118 scattered active rows and ~45 active columns of
/// layer 1 into one tile region per grid; anything below a 1.5x
/// active-wordline saving means the heuristic regressed.
pub fn reorder_golden() -> ReorderGolden {
    ReorderGolden {
        stack: structured_stack(0xB175_11CE, &[784, 300, 10], 0.15, 0.15, 0.3),
        min_wordline_saving: 1.5,
        min_skipped_tiles: 60,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;

    #[test]
    fn weights_at_density_hits_exact_count_and_pins_range() {
        let mut rng = Rng::new(3);
        let w = weights_at_density(&mut rng, 50, 40, 0.1);
        let nonzero = w.data().iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nonzero, 200);
        assert_eq!(w.data()[0], 1.0);
        // the pin fixes the qstep at 2^-8 regardless of density
        assert_eq!(quant::quantize(&w).step, 2.0f32.powi(-8));
        let w2 = weights_at_density(&mut rng, 50, 40, 0.9);
        assert_eq!(quant::quantize(&w2).step, 2.0f32.powi(-8));
    }

    #[test]
    fn structured_weights_confine_nonzeros_to_active_lines() {
        let mut rng = Rng::new(5);
        let w = structured_sparse_weights(&mut rng, 200, 100, 0.2, 0.2, 0.5);
        let data = w.data();
        let active_rows: Vec<usize> = (0..200)
            .filter(|&r| (0..100).any(|c| data[r * 100 + c] != 0.0))
            .collect();
        let active_cols: Vec<usize> = (0..100)
            .filter(|&c| (0..200).any(|r| data[r * 100 + c] != 0.0))
            .collect();
        assert!(!active_rows.is_empty() && active_rows.len() <= 40);
        assert!(!active_cols.is_empty() && active_cols.len() <= 20);
        assert!(data.iter().any(|&v| v == 1.0), "pin present");
    }

    #[test]
    fn bottleneck_stack_chains_and_is_deterministic() {
        let a = bottleneck_stack(3);
        let b = bottleneck_stack(3);
        assert_eq!(a.len(), 4);
        assert_eq!(a[0].w.shape(), &[64, 32]);
        assert_eq!(a[1].w.shape(), &[32, 512]);
        assert_eq!(a[2].w.shape(), &[512, 32]);
        assert_eq!(a[3].w.shape(), &[32, 10]);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.w.data(), y.w.data(), "same seed, same stack");
        }
        // fc2 is dense-ish, fc3 nearly empty — the skew the name promises
        let nz = |t: &Tensor| t.data().iter().filter(|&&v| v != 0.0).count() as f64;
        assert!(nz(&a[1].w) / (32.0 * 512.0) > 0.3);
        assert!(nz(&a[2].w) / (512.0 * 32.0) < 0.03);
    }

    #[test]
    fn stacks_chain_and_are_deterministic() {
        let a = sparse_stack(7, &[30, 20, 5], 0.1);
        let b = sparse_stack(7, &[30, 20, 5], 0.1);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].w.shape(), &[30, 20]);
        assert_eq!(a[1].w.shape(), &[20, 5]);
        assert!(a[0].relu && !a[1].relu);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.w.data(), y.w.data(), "same seed, same stack");
        }
        let s = structured_stack(9, &[64, 32, 4], 0.25, 0.25, 0.5);
        assert_eq!(s.len(), 2);

        let g1 = reorder_golden();
        let g2 = reorder_golden();
        assert_eq!(g1.stack[0].w.data(), g2.stack[0].w.data());
        assert!(g1.min_wordline_saving > 1.0);
    }
}

//! Minimal strict JSON: enough to read the AOT manifest and write metrics.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Numbers are stored as `f64` — the manifest
//! only carries shapes, counts and init constants, all exactly
//! representable. Object key order is preserved (insertion order) so
//! round-trips are stable.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    // BTreeMap keeps deterministic iteration; manifest consumers index by
    // key, never by position.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `get` that errors with the key name — manifest parsing wants loud
    /// failures, not silent defaults.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key {key:?}"))
    }
}

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage rejected).
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!(
                "expected {:?} at byte {}, got {:?}",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }
    fn eat_keyword(&mut self, kw: &str) -> anyhow::Result<()> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(())
        } else {
            anyhow::bail!("invalid keyword at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => {
                self.eat_keyword("true")?;
                Ok(Json::Bool(true))
            }
            Some(b'f') => {
                self.eat_keyword("false")?;
                Ok(Json::Bool(false))
            }
            Some(b'n') => {
                self.eat_keyword("null")?;
                Ok(Json::Null)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other, self.pos),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => anyhow::bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => anyhow::bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| {
                                    anyhow::anyhow!("bad \\u escape")
                                })?;
                        }
                        // Surrogate pairs: manifest is ASCII, but be correct.
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump()? as char;
                                low = low * 16
                                    + c.to_digit(16).ok_or_else(|| {
                                        anyhow::anyhow!("bad \\u escape")
                                    })?;
                            }
                            let c =
                                0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(code)
                        };
                        out.push(ch.ok_or_else(|| {
                            anyhow::anyhow!("invalid unicode escape")
                        })?);
                    }
                    c => anyhow::bail!("bad escape \\{}", c as char),
                },
                c if c < 0x20 => anyhow::bail!("control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.pos = start + len;
                        let s = std::str::from_utf8(
                            &self.bytes[start..start + len],
                        )?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

/// Serialize (compact). Used for metrics JSONL and checkpoints metadata.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience constructors for emitting metrics.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"models": {"mlp": {"batch": 128, "params": {"qw": [
            {"name": "fc1/w", "shape": [784, 300], "init_std": 0.0505}]}}},
            "ok": true, "none": null, "neg": -2.5e-3}"#;
        let v = parse(doc).unwrap();
        assert_eq!(
            v.get("models")
                .unwrap()
                .get("mlp")
                .unwrap()
                .get("batch")
                .unwrap()
                .as_usize(),
            Some(128)
        );
        let qw = v.get("models").unwrap().get("mlp").unwrap().get("params")
            .unwrap().get("qw").unwrap().as_arr().unwrap();
        assert_eq!(qw[0].get("name").unwrap().as_str(), Some("fc1/w"));
        assert_eq!(
            qw[0].get("shape").unwrap().as_arr().unwrap()[0].as_usize(),
            Some(784)
        );
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(v.get("none"), Some(&Json::Null));
        assert_eq!(v.get("neg").unwrap().as_f64(), Some(-2.5e-3));
    }

    #[test]
    fn roundtrips_strings_with_escapes() {
        let v = parse(r#"{"s": "a\"b\\c\ndAé"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a\"b\\c\ndAé"));
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = parse("{\"s\": \"héllo→\"}").unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("héllo→"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn parses_nested_arrays_and_numbers() {
        let v = parse("[[1,2],[3.5,-4],[],[2e3]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap()[1].as_f64(), Some(2.0));
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64(), Some(3.5));
        assert_eq!(a[2].as_arr().unwrap().len(), 0);
        assert_eq!(a[3].as_arr().unwrap()[0].as_f64(), Some(2000.0));
    }

    #[test]
    fn display_is_reparseable() {
        let v = obj(vec![
            ("step", num(17.0)),
            ("loss", num(0.123456)),
            ("tag", s("fig2/bl1")),
        ]);
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn req_reports_missing_key() {
        let v = parse("{}").unwrap();
        let err = v.req("nope").unwrap_err().to_string();
        assert!(err.contains("nope"));
    }
}

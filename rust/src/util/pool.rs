//! Threading substrate: bounded SPSC channel + parallel-for.
//!
//! Replaces tokio for the two places the coordinator needs concurrency:
//!
//! * [`Prefetcher`] — a producer thread materializes batches ahead of the
//!   training loop with bounded backpressure (the XLA step is the consumer).
//! * [`parallel_for_chunks`] — fan simulation/analysis work (crossbar
//!   column sums, dataset generation) across cores with scoped threads.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Shared<T> {
    queue: Mutex<QueueState<T>>,
    cond: Condvar,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
    cap: usize,
}

/// Bounded blocking queue (MPSC-capable, used as SPSC).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

impl<T> std::fmt::Debug for Sender<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Sender").finish_non_exhaustive()
    }
}

pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

impl<T> std::fmt::Debug for Receiver<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Receiver").finish_non_exhaustive()
    }
}

pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        queue: Mutex::new(QueueState {
            items: VecDeque::new(),
            closed: false,
            cap: cap.max(1),
        }),
        cond: Condvar::new(),
    });
    (
        Sender {
            shared: shared.clone(),
        },
        Receiver { shared },
    )
}

/// Why [`Sender::try_send`] handed the item back.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// queue at capacity — the backpressure signal; retry or shed load
    Full(T),
    /// receiver gone; no send can ever succeed again
    Closed(T),
}

impl<T> Sender<T> {
    /// Non-blocking send: enqueue if there is room, otherwise hand the
    /// item straight back with the reason — the bounded-queue
    /// backpressure path for producers that must not block.
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        let mut q = self.shared.queue.lock().unwrap();
        if q.closed {
            return Err(TrySendError::Closed(item));
        }
        if q.items.len() >= q.cap {
            return Err(TrySendError::Full(item));
        }
        q.items.push_back(item);
        self.shared.cond.notify_all();
        Ok(())
    }

    /// Blocks while the queue is full. Returns Err if the receiver is gone.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if q.closed {
                return Err(item);
            }
            if q.items.len() < q.cap {
                q.items.push_back(item);
                self.shared.cond.notify_all();
                return Ok(());
            }
            q = self.shared.cond.wait(q).unwrap();
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().closed = true;
        self.shared.cond.notify_all();
    }
}

impl<T> Receiver<T> {
    /// Blocks until an item arrives; `None` once closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if let Some(item) = q.items.pop_front() {
                self.shared.cond.notify_all();
                return Some(item);
            }
            if q.closed {
                return None;
            }
            q = self.shared.cond.wait(q).unwrap();
        }
    }

    /// Dynamic-batch receive: blocks until at least one item is available,
    /// then drains whatever else is already queued, up to `max` items.
    /// `None` once closed and drained. Safe to call from several consumer
    /// threads sharing one `Arc<Receiver>` (the serving-engine workers).
    pub fn recv_batch(&self, max: usize) -> Option<Vec<T>> {
        let max = max.max(1);
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if !q.items.is_empty() {
                let take = q.items.len().min(max);
                let items: Vec<T> = q.items.drain(..take).collect();
                self.shared.cond.notify_all();
                return Some(items);
            }
            if q.closed {
                return None;
            }
            q = self.shared.cond.wait(q).unwrap();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().closed = true;
        self.shared.cond.notify_all();
    }
}

/// Background producer: runs `make_item(i)` for i in 0..n on a worker
/// thread, keeping at most `depth` results queued ahead of the consumer.
pub struct Prefetcher<T: Send + 'static> {
    rx: Receiver<T>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl<T: Send + 'static> std::fmt::Debug for Prefetcher<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Prefetcher")
            .field("worker_alive", &self.handle.is_some())
            .finish_non_exhaustive()
    }
}

impl<T: Send + 'static> Prefetcher<T> {
    pub fn spawn<F>(n: usize, depth: usize, mut make_item: F) -> Self
    where
        F: FnMut(usize) -> T + Send + 'static,
    {
        let (tx, rx) = bounded(depth);
        let handle = std::thread::Builder::new()
            .name("prefetch".into())
            .spawn(move || {
                for i in 0..n {
                    if tx.send(make_item(i)).is_err() {
                        break; // consumer dropped early
                    }
                }
            })
            .expect("spawn prefetch thread");
        Prefetcher {
            rx,
            handle: Some(handle),
        }
    }

    pub fn next(&self) -> Option<T> {
        self.rx.recv()
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        // Close the channel first so a blocked producer unblocks.
        self.rx.shared.queue.lock().unwrap().closed = true;
        self.rx.shared.cond.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The one worker-count policy shared by every parallel consumer — the
/// batched simulator forward (`reram::sim::forward`), the host backends'
/// intra-batch fan-out and the serving engine's worker pool: available
/// hardware parallelism, falling back to 4 when the platform cannot
/// report it. Callers that want fewer threads clamp the result (e.g. the
/// serving engine caps its pool at 8); none should consult
/// `available_parallelism` directly, so sim and serving always agree.
pub fn worker_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Parallel-for over disjoint chunks of a slice, scoped (no 'static bound).
pub fn parallel_for_chunks<T: Send, F>(data: &mut [T], chunk: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk = chunk.max(1);
    std::thread::scope(|scope| {
        for (ci, part) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || f(ci * chunk, part));
        }
    });
}

/// Map over index ranges in parallel, collecting results in order.
pub fn parallel_map<R: Send, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let per = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for part in out.chunks_mut(per).enumerate() {
            let (ti, slot) = part;
            let f = &f;
            scope.spawn(move || {
                for (j, s) in slot.iter_mut().enumerate() {
                    *s = Some(f(ti * per + j));
                }
            });
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn channel_delivers_in_order() {
        let (tx, rx) = bounded(4);
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                tx.send(i).unwrap();
            }
        });
        let got: Vec<usize> = std::iter::from_fn(|| rx.recv()).collect();
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn channel_applies_backpressure() {
        let (tx, rx) = bounded(2);
        let inflight = Arc::new(AtomicUsize::new(0));
        let inf = inflight.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..50 {
                tx.send(i).unwrap();
                inf.fetch_add(1, Ordering::SeqCst);
            }
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        // with cap 2 the producer can be at most ~3 sends ahead
        assert!(inflight.load(Ordering::SeqCst) <= 3);
        let mut n = 0;
        while rx.recv().is_some() {
            n += 1;
        }
        assert_eq!(n, 50);
        producer.join().unwrap();
    }

    #[test]
    fn receiver_drop_unblocks_producer() {
        let (tx, rx) = bounded(1);
        drop(rx);
        assert!(tx.send(1).is_err());
    }

    #[test]
    fn try_send_distinguishes_full_from_closed() {
        let (tx, rx) = bounded(2);
        assert_eq!(tx.try_send(1), Ok(()));
        assert_eq!(tx.try_send(2), Ok(()));
        // at capacity: the item comes straight back, nothing blocks
        assert_eq!(tx.try_send(3), Err(TrySendError::Full(3)));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(tx.try_send(3), Ok(()));
        drop(rx);
        assert_eq!(tx.try_send(4), Err(TrySendError::Closed(4)));
    }

    #[test]
    fn recv_batch_drains_up_to_max_then_closes() {
        let (tx, rx) = bounded(16);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        // first call takes what is queued, bounded by max
        assert_eq!(rx.recv_batch(3), Some(vec![0, 1, 2]));
        assert_eq!(rx.recv_batch(8), Some(vec![3, 4]));
        drop(tx);
        assert_eq!(rx.recv_batch(4), None);
    }

    #[test]
    fn recv_batch_wakes_on_late_send() {
        let (tx, rx) = bounded(4);
        let consumer = std::thread::spawn(move || rx.recv_batch(10));
        std::thread::sleep(std::time::Duration::from_millis(20));
        tx.send(7usize).unwrap();
        drop(tx);
        assert_eq!(consumer.join().unwrap(), Some(vec![7]));
    }

    #[test]
    fn prefetcher_yields_all_items_then_none() {
        let p = Prefetcher::spawn(10, 3, |i| i * i);
        let got: Vec<usize> = std::iter::from_fn(|| p.next()).collect();
        assert_eq!(got, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn prefetcher_early_drop_joins_cleanly() {
        let p = Prefetcher::spawn(1000, 2, |i| i);
        assert_eq!(p.next(), Some(0));
        drop(p); // must not deadlock
    }

    #[test]
    fn parallel_for_chunks_touches_every_element() {
        let mut data = vec![0usize; 1000];
        parallel_for_chunks(&mut data, 128, |base, part| {
            for (j, v) in part.iter_mut().enumerate() {
                *v = base + j;
            }
        });
        assert_eq!(data, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(257, 8, |i| i * 3);
        assert_eq!(out, (0..257).map(|i| i * 3).collect::<Vec<_>>());
    }
}

//! Threading substrate: persistent work-stealing executor, bounded
//! channel + parallel-for.
//!
//! Concurrency in this crate flows through three primitives:
//!
//! * [`executor`] — ONE long-lived worker pool per process, per-worker
//!   deques with idle-steal, and a scoped submission API ([`Executor::scope`])
//!   that accepts non-`'static` closures exactly like `std::thread::scope`.
//!   Every hot parallel region ([`parallel_map`], [`parallel_for_chunks`],
//!   the serving backends' intra-batch fan-out, the simulator's batched
//!   forward, the planner's candidate evaluation, the Monte-Carlo noise
//!   trials) runs as executor tasks: the steady-state serving loop creates
//!   **zero** OS threads (asserted by `benches/serving_slo.rs` via
//!   [`os_threads_spawned`]).
//! * [`bounded`] — bounded blocking queue (MPSC-capable): backpressure for
//!   the [`Prefetcher`] and the serving engine's request queue, including
//!   the deadline-bounded batch assembly ([`Receiver::recv_batch_by`])
//!   behind SLO-aware serving.
//! * [`Prefetcher`] — a producer thread materializes batches ahead of the
//!   training loop with bounded backpressure.
//!
//! # Determinism
//!
//! Executor-backed [`parallel_map`] / [`parallel_for_chunks`] write results
//! by index into pre-split chunks, so the output is **bit-identical** to
//! the sequential loop regardless of which worker runs which chunk or in
//! what order steals happen. [`set_parallel_mode`] can force the legacy
//! per-call `std::thread::scope` spawning — the measured baseline of the
//! serving bench — and both modes produce identical results by
//! construction.
//!
//! # Worker count
//!
//! [`worker_threads`] is the one worker-count policy: the `RERAM_THREADS`
//! environment variable when set to a positive integer (CI and benches pin
//! parallelism deterministically with it), otherwise the platform's
//! available parallelism, falling back to 4. The value is read **once** per
//! process (the executor is sized from it); changing the variable after
//! the first parallel region has no effect.

use std::any::Any;
use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Worker-count policy + OS-thread accounting
// ---------------------------------------------------------------------------

/// Process-wide count of OS threads this module has created (executor
/// workers, prefetcher producers, legacy scoped spawns). The serving bench
/// snapshots it around the steady-state loop to prove the executor path
/// spawns nothing per batch.
static OS_THREADS: AtomicUsize = AtomicUsize::new(0);

/// How many OS threads `util::pool` has created so far in this process.
pub fn os_threads_spawned() -> usize {
    OS_THREADS.load(Ordering::SeqCst)
}

/// Pure policy behind [`worker_threads`], split out so the `RERAM_THREADS`
/// parsing is unit-testable without process-global env mutation: a positive
/// integer overrides, anything else falls back.
fn threads_policy(env: Option<&str>, fallback: usize) -> usize {
    match env.and_then(|v| v.trim().parse::<usize>().ok()) {
        Some(n) if n >= 1 => n,
        _ => fallback,
    }
}

/// The one worker-count policy shared by every parallel consumer — the
/// batched simulator forward (`reram::sim::forward`), the host backends'
/// intra-batch fan-out, the serving engine's worker pool and the
/// [`executor`] itself: the `RERAM_THREADS` env override when set to a
/// positive integer, else available hardware parallelism, falling back to
/// 4 when the platform cannot report it. Cached on first call (the
/// executor is sized from it), so the whole process always agrees.
/// Callers that want fewer threads clamp the result (e.g. the serving
/// engine caps its pool at `ServeOptions::worker_cap`); none should
/// consult `available_parallelism` directly.
pub fn worker_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        let fallback = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        threads_policy(std::env::var("RERAM_THREADS").ok().as_deref(), fallback)
    })
}

// ---------------------------------------------------------------------------
// Persistent work-stealing executor
// ---------------------------------------------------------------------------

/// A unit of scoped work. The closure's true lifetime is the spawning
/// scope's `'scope`; it is transmuted to `'static` for storage and the
/// scope's wait loop guarantees it runs (or is dropped) before `'scope`
/// ends.
struct Task {
    scope: Arc<ScopeState>,
    run: Box<dyn FnOnce() + Send + 'static>,
}

/// Shared completion state of one [`Executor::scope`] call.
struct ScopeState {
    /// spawned-but-not-finished task count
    pending: AtomicUsize,
    /// event counter: bumped on every spawn *and* every completion of this
    /// scope's tasks, so the waiter's sleep/re-scan protocol can never miss
    /// a task parked in a deque (see [`Executor::wait_scope`])
    events: Mutex<u64>,
    done: Condvar,
    /// first panic payload from any task (resumed by the scope owner)
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
}

impl ScopeState {
    fn new() -> ScopeState {
        ScopeState {
            pending: AtomicUsize::new(0),
            events: Mutex::new(0),
            done: Condvar::new(),
            panic: Mutex::new(None),
        }
    }

    fn bump(&self) {
        *self.events.lock().unwrap() += 1;
        self.done.notify_all();
    }
}

/// Run one task, capturing its panic into the scope and signalling
/// completion last (so `pending == 0` implies the panic slot is final).
fn execute(task: Task) {
    let scope = task.scope;
    if let Err(p) = catch_unwind(AssertUnwindSafe(task.run)) {
        let mut slot = scope.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(p);
        }
    }
    scope.pending.fetch_sub(1, Ordering::AcqRel);
    scope.bump();
}

struct ExecShared {
    /// one deque per worker; submissions are distributed round-robin and
    /// idle workers steal from siblings' tails
    deques: Vec<Mutex<VecDeque<Task>>>,
    /// wake generation: bumped under the lock on every submission so a
    /// worker that scanned empty deques can detect a racing push before it
    /// sleeps
    idle: Mutex<u64>,
    wake: Condvar,
    next: AtomicUsize,
}

impl ExecShared {
    /// Pop from `home`'s own deque, else steal from siblings (oldest
    /// first, round-robin from `home + 1`).
    fn find_task(&self, home: usize) -> Option<Task> {
        let n = self.deques.len();
        if let Some(t) = self.deques[home % n].lock().unwrap().pop_front() {
            return Some(t);
        }
        for off in 1..n {
            let j = (home + off) % n;
            if let Some(t) = self.deques[j].lock().unwrap().pop_back() {
                return Some(t);
            }
        }
        None
    }

    /// Remove one queued task belonging to `scope`, newest first — the
    /// scope owner's help-first wait steals its own work back so a scope
    /// can always make progress even when every worker is busy (or blocked
    /// waiting on a *nested* scope — the no-deadlock argument).
    fn steal_scope_task(&self, scope: &Arc<ScopeState>) -> Option<Task> {
        for dq in &self.deques {
            let mut dq = dq.lock().unwrap();
            if let Some(pos) = dq.iter().rposition(|t| Arc::ptr_eq(&t.scope, scope)) {
                return dq.remove(pos);
            }
        }
        None
    }
}

thread_local! {
    /// This thread's executor worker index (worker threads only) — used to
    /// keep a worker's own spawns on its own deque.
    static WORKER_HOME: RefCell<Option<usize>> = const { RefCell::new(None) };
}

/// The persistent work-stealing executor: one long-lived pool of
/// [`worker_threads`] workers per process ([`executor`]), per-worker
/// deques with idle-steal, and the scoped no-`'static` submission API
/// ([`Executor::scope`]). Workers live for the whole process — the hot
/// paths never pay thread creation.
pub struct Executor {
    shared: Arc<ExecShared>,
    workers: usize,
    /// executor worker threads created (== `workers` after construction;
    /// never grows again — the assertion behind the serving bench's
    /// zero-spawn gate)
    spawned: AtomicUsize,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("workers", &self.workers)
            .finish_non_exhaustive()
    }
}

/// The process-wide executor, created on first use and sized by
/// [`worker_threads`]. Workers are never torn down.
pub fn executor() -> &'static Executor {
    static EXECUTOR: OnceLock<Executor> = OnceLock::new();
    EXECUTOR.get_or_init(|| Executor::new(worker_threads()))
}

impl Executor {
    fn new(workers: usize) -> Executor {
        let workers = workers.max(1);
        let shared = Arc::new(ExecShared {
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            idle: Mutex::new(0),
            wake: Condvar::new(),
            next: AtomicUsize::new(0),
        });
        let exec = Executor {
            shared: shared.clone(),
            workers,
            spawned: AtomicUsize::new(0),
        };
        for w in 0..workers {
            let shared = shared.clone();
            OS_THREADS.fetch_add(1, Ordering::SeqCst);
            exec.spawned.fetch_add(1, Ordering::SeqCst);
            std::thread::Builder::new()
                .name(format!("xb-worker-{w}"))
                .spawn(move || {
                    WORKER_HOME.with(|h| *h.borrow_mut() = Some(w));
                    loop {
                        // record the wake generation BEFORE scanning: a push
                        // that lands after the scan bumps it, so the sleep
                        // check below cannot miss it
                        let gen = *shared.idle.lock().unwrap();
                        if let Some(t) = shared.find_task(w) {
                            execute(t);
                            continue;
                        }
                        let mut idle = shared.idle.lock().unwrap();
                        while *idle == gen {
                            idle = shared.wake.wait(idle).unwrap();
                        }
                    }
                })
                .expect("spawn executor worker");
        }
        exec
    }

    /// Worker-pool size (fixed for the process lifetime).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Executor worker threads created so far — stays equal to
    /// [`Self::workers`] forever; the serving bench asserts the
    /// process-wide [`os_threads_spawned`] counter around its steady-state
    /// loop.
    pub fn threads_spawned(&self) -> usize {
        self.spawned.load(Ordering::SeqCst)
    }

    fn inject(&self, task: Task) {
        let slot = WORKER_HOME
            .with(|h| *h.borrow())
            .unwrap_or_else(|| self.shared.next.fetch_add(1, Ordering::Relaxed));
        self.shared.deques[slot % self.workers]
            .lock()
            .unwrap()
            .push_back(task);
        // bump the wake generation under the lock so sleeping workers
        // can't miss the push
        *self.shared.idle.lock().unwrap() += 1;
        self.shared.wake.notify_all();
    }

    /// Scoped task submission, `std::thread::scope`-shaped: tasks may
    /// borrow from the caller's stack (no `'static` bound); `scope` does
    /// not return until every spawned task has finished, and the first
    /// task panic (or the closure's own) is propagated to the caller.
    ///
    /// While waiting, the calling thread **helps**: it steals back tasks
    /// belonging to its own scope and runs them inline. That keeps small
    /// fan-outs latency-bound by the caller itself, and makes nested
    /// scopes deadlock-free — a worker blocked in an inner `scope` drains
    /// that inner scope's queue with its own hands.
    pub fn scope<'env, T>(
        &'static self,
        f: impl for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> T,
    ) -> T {
        let state = Arc::new(ScopeState::new());
        let scope = Scope {
            exec: self,
            state: state.clone(),
            _scope: std::marker::PhantomData,
            _env: std::marker::PhantomData,
        };
        let result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        // ALWAYS drain before returning/unwinding: spawned closures borrow
        // the caller's stack and must not outlive this frame
        self.wait_scope(&state);
        match result {
            Err(p) => resume_unwind(p),
            Ok(v) => {
                if let Some(p) = state.panic.lock().unwrap().take() {
                    resume_unwind(p);
                }
                v
            }
        }
    }

    fn wait_scope(&self, state: &Arc<ScopeState>) {
        loop {
            if state.pending.load(Ordering::Acquire) == 0 {
                return;
            }
            if let Some(t) = self.shared.steal_scope_task(state) {
                execute(t);
                continue;
            }
            // every remaining task is currently executing on a worker (or
            // was spawned after our scan — spawns bump the event counter):
            // sleep until an event, then re-scan
            let e0 = {
                let events = state.events.lock().unwrap();
                *events
            };
            if state.pending.load(Ordering::Acquire) == 0 {
                return;
            }
            if self.shared.steal_scope_task(state).is_none() {
                let mut events = state.events.lock().unwrap();
                while *events == e0 && state.pending.load(Ordering::Acquire) > 0 {
                    events = state.done.wait(events).unwrap();
                }
            } else {
                continue;
            }
        }
    }
}

/// Handle for spawning tasks inside one [`Executor::scope`] call.
pub struct Scope<'scope, 'env: 'scope> {
    exec: &'static Executor,
    state: Arc<ScopeState>,
    _scope: std::marker::PhantomData<&'scope mut &'scope ()>,
    _env: std::marker::PhantomData<&'env mut &'env ()>,
}

impl std::fmt::Debug for Scope<'_, '_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scope")
            .field("pending", &self.state.pending.load(Ordering::Relaxed))
            .finish_non_exhaustive()
    }
}

impl<'scope, 'env> Scope<'scope, 'env> {
    /// Submit one task. It may run on any worker or inline on the scope
    /// owner while it waits; panics are captured and re-thrown by `scope`.
    pub fn spawn<F>(&'scope self, f: F)
    where
        F: FnOnce() + Send + 'scope,
    {
        self.state.pending.fetch_add(1, Ordering::AcqRel);
        let run: Box<dyn FnOnce() + Send + 'scope> = Box::new(f);
        // SAFETY: `Executor::scope` blocks until `pending` reaches zero
        // before its stack frame (and thus anything `f` borrows from
        // `'scope`/`'env`) can be invalidated — including when the scope
        // closure itself panics. The transmute only erases the lifetime
        // bound; layout of the fat pointer is unchanged.
        let run: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(run) };
        self.exec.inject(Task {
            scope: self.state.clone(),
            run,
        });
        // wake the scope owner too: it may be sleeping in `wait_scope`
        // after a nested task spawned this one
        self.state.bump();
    }
}

// ---------------------------------------------------------------------------
// Worker-local scratch
// ---------------------------------------------------------------------------

thread_local! {
    static SCRATCH: RefCell<HashMap<std::any::TypeId, Box<dyn Any>>> =
        RefCell::new(HashMap::new());
}

/// Borrow this thread's scratch slot of type `T`, creating it with
/// `Default` on first use. On persistent executor workers (and the serving
/// engine's long-lived worker threads) the slot survives across tasks and
/// batches — the wave-pack buffers and `SimScratch` allocations of one
/// batch are reused by the next instead of being reallocated per call.
///
/// The slot is *taken out* for the duration of `f` (a nested `with_scratch`
/// of the same `T` on the same thread simply gets a fresh value), and it is
/// dropped if `f` panics. Callers must not assume anything about the
/// scratch's contents beyond `T`'s own reuse contract — every user resets
/// what it reads.
pub fn with_scratch<T, R>(f: impl FnOnce(&mut T) -> R) -> R
where
    T: Default + 'static,
{
    let key = std::any::TypeId::of::<T>();
    let mut v: Box<T> = SCRATCH
        .with(|m| m.borrow_mut().remove(&key))
        .and_then(|b| b.downcast::<T>().ok())
        .unwrap_or_default();
    let r = f(&mut v);
    SCRATCH.with(|m| m.borrow_mut().insert(key, v));
    r
}

// ---------------------------------------------------------------------------
// Parallel-for front ends
// ---------------------------------------------------------------------------

/// Which engine the parallel-for front ends run on. The default
/// ([`ParallelMode::Executor`]) submits chunk tasks to the persistent
/// [`executor`]; [`ParallelMode::ScopedSpawn`] is the legacy per-call
/// `std::thread::scope` spawning, kept as the measured baseline for
/// `benches/serving_slo.rs` and for A/B bit-exactness checks. Results are
/// bit-identical across modes by construction (chunking and write-by-index
/// are shared); only thread-creation cost differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelMode {
    /// persistent work-stealing executor (the default)
    Executor,
    /// spawn scoped OS threads per call (legacy baseline)
    ScopedSpawn,
}

static MODE: AtomicU8 = AtomicU8::new(0);

/// Current engine for [`parallel_map`] / [`parallel_for_chunks`].
pub fn parallel_mode() -> ParallelMode {
    if MODE.load(Ordering::Relaxed) == 1 {
        ParallelMode::ScopedSpawn
    } else {
        ParallelMode::Executor
    }
}

/// Switch the parallel-for engine process-wide. Benchmark/test knob —
/// production code never calls this; callers that flip it must restore
/// [`ParallelMode::Executor`].
pub fn set_parallel_mode(mode: ParallelMode) {
    MODE.store(
        match mode {
            ParallelMode::Executor => 0,
            ParallelMode::ScopedSpawn => 1,
        },
        Ordering::Relaxed,
    );
}

/// Parallel-for over disjoint chunks of a slice (no `'static` bound).
/// Chunk tasks run on the persistent executor (see [`ParallelMode`]).
pub fn parallel_for_chunks<T: Send, F>(data: &mut [T], chunk: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk = chunk.max(1);
    if data.len() <= chunk {
        if !data.is_empty() {
            f(0, data);
        }
        return;
    }
    match parallel_mode() {
        ParallelMode::ScopedSpawn => {
            std::thread::scope(|scope| {
                for (ci, part) in data.chunks_mut(chunk).enumerate() {
                    let f = &f;
                    OS_THREADS.fetch_add(1, Ordering::SeqCst);
                    scope.spawn(move || f(ci * chunk, part));
                }
            });
        }
        ParallelMode::Executor => {
            executor().scope(|s| {
                for (ci, part) in data.chunks_mut(chunk).enumerate() {
                    let f = &f;
                    s.spawn(move || f(ci * chunk, part));
                }
            });
        }
    }
}

/// Map over index ranges in parallel, collecting results in order. The
/// result is bit-identical to `(0..n).map(f).collect()` regardless of
/// engine, worker count or steal order (each index writes its own slot).
pub fn parallel_map<R: Send, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let per = n.div_ceil(threads);
    match parallel_mode() {
        ParallelMode::ScopedSpawn => {
            std::thread::scope(|scope| {
                for (ti, slot) in out.chunks_mut(per).enumerate() {
                    let f = &f;
                    OS_THREADS.fetch_add(1, Ordering::SeqCst);
                    scope.spawn(move || {
                        for (j, s) in slot.iter_mut().enumerate() {
                            *s = Some(f(ti * per + j));
                        }
                    });
                }
            });
        }
        ParallelMode::Executor => {
            executor().scope(|s| {
                for (ti, slot) in out.chunks_mut(per).enumerate() {
                    let f = &f;
                    s.spawn(move || {
                        for (j, sl) in slot.iter_mut().enumerate() {
                            *sl = Some(f(ti * per + j));
                        }
                    });
                }
            });
        }
    }
    out.into_iter().map(|x| x.unwrap()).collect()
}

// ---------------------------------------------------------------------------
// Bounded channel
// ---------------------------------------------------------------------------

struct Shared<T> {
    queue: Mutex<QueueState<T>>,
    cond: Condvar,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
    cap: usize,
}

/// Bounded blocking queue (MPSC-capable, used as SPSC).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

impl<T> std::fmt::Debug for Sender<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Sender").finish_non_exhaustive()
    }
}

pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

impl<T> std::fmt::Debug for Receiver<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Receiver").finish_non_exhaustive()
    }
}

pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        queue: Mutex::new(QueueState {
            items: VecDeque::new(),
            closed: false,
            cap: cap.max(1),
        }),
        cond: Condvar::new(),
    });
    (
        Sender {
            shared: shared.clone(),
        },
        Receiver { shared },
    )
}

/// Why [`Sender::try_send`] handed the item back.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// queue at capacity — the backpressure signal; retry or shed load
    Full(T),
    /// receiver gone; no send can ever succeed again
    Closed(T),
}

impl<T> Sender<T> {
    /// Non-blocking send: enqueue if there is room, otherwise hand the
    /// item straight back with the reason — the bounded-queue
    /// backpressure path for producers that must not block.
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        let mut q = self.shared.queue.lock().unwrap();
        if q.closed {
            return Err(TrySendError::Closed(item));
        }
        if q.items.len() >= q.cap {
            return Err(TrySendError::Full(item));
        }
        q.items.push_back(item);
        self.shared.cond.notify_all();
        Ok(())
    }

    /// Blocks while the queue is full. Returns Err if the receiver is gone.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if q.closed {
                return Err(item);
            }
            if q.items.len() < q.cap {
                q.items.push_back(item);
                self.shared.cond.notify_all();
                return Ok(());
            }
            q = self.shared.cond.wait(q).unwrap();
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().closed = true;
        self.shared.cond.notify_all();
    }
}

impl<T> Receiver<T> {
    /// Blocks until an item arrives; `None` once closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if let Some(item) = q.items.pop_front() {
                self.shared.cond.notify_all();
                return Some(item);
            }
            if q.closed {
                return None;
            }
            q = self.shared.cond.wait(q).unwrap();
        }
    }

    /// Dynamic-batch receive: blocks until at least one item is available,
    /// then drains whatever else is already queued, up to `max` items.
    /// `None` once closed and drained. Safe to call from several consumer
    /// threads sharing one `Arc<Receiver>` (the serving-engine workers).
    pub fn recv_batch(&self, max: usize) -> Option<Vec<T>> {
        self.recv_batch_by(max, |_| None)
    }

    /// Deadline-bounded dynamic-batch receive — SLO-aware batch assembly.
    ///
    /// Blocks until at least one item is queued, then asks `deadline_of`
    /// for the **oldest** queued item's close deadline:
    ///
    /// * `None` — drain immediately (plain [`Self::recv_batch`] behavior).
    /// * `Some(deadline)` — keep the batch open, waiting for more items,
    ///   until it holds `max` items, the queue closes, or `deadline`
    ///   passes; then drain up to `max`.
    ///
    /// The serving engine derives the deadline from the oldest request's
    /// enqueue time plus the plan's predicted service time, so a batch
    /// closes exactly when waiting longer would endanger the SLO — not
    /// only when `max` fills. `None` once closed and drained.
    pub fn recv_batch_by<F>(&self, max: usize, deadline_of: F) -> Option<Vec<T>>
    where
        F: Fn(&T) -> Option<Instant>,
    {
        let max = max.max(1);
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if !q.items.is_empty() {
                if let Some(deadline) = deadline_of(&q.items[0]) {
                    while q.items.len() < max && !q.closed {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        let (guard, _timeout) = self
                            .shared
                            .cond
                            .wait_timeout(q, deadline - now)
                            .unwrap();
                        q = guard;
                    }
                }
                let take = q.items.len().min(max);
                let items: Vec<T> = q.items.drain(..take).collect();
                self.shared.cond.notify_all();
                return Some(items);
            }
            if q.closed {
                return None;
            }
            q = self.shared.cond.wait(q).unwrap();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().closed = true;
        self.shared.cond.notify_all();
    }
}

/// Background producer: runs `make_item(i)` for i in 0..n on a worker
/// thread, keeping at most `depth` results queued ahead of the consumer.
pub struct Prefetcher<T: Send + 'static> {
    rx: Receiver<T>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl<T: Send + 'static> std::fmt::Debug for Prefetcher<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Prefetcher")
            .field("worker_alive", &self.handle.is_some())
            .finish_non_exhaustive()
    }
}

impl<T: Send + 'static> Prefetcher<T> {
    pub fn spawn<F>(n: usize, depth: usize, mut make_item: F) -> Self
    where
        F: FnMut(usize) -> T + Send + 'static,
    {
        let (tx, rx) = bounded(depth);
        OS_THREADS.fetch_add(1, Ordering::SeqCst);
        let handle = std::thread::Builder::new()
            .name("prefetch".into())
            .spawn(move || {
                for i in 0..n {
                    if tx.send(make_item(i)).is_err() {
                        break; // consumer dropped early
                    }
                }
            })
            .expect("spawn prefetch thread");
        Prefetcher {
            rx,
            handle: Some(handle),
        }
    }

    pub fn next(&self) -> Option<T> {
        self.rx.recv()
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        // Close the channel first so a blocked producer unblocks.
        self.rx.shared.queue.lock().unwrap().closed = true;
        self.rx.shared.cond.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn channel_delivers_in_order() {
        let (tx, rx) = bounded(4);
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                tx.send(i).unwrap();
            }
        });
        let got: Vec<usize> = std::iter::from_fn(|| rx.recv()).collect();
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn channel_applies_backpressure() {
        let (tx, rx) = bounded(2);
        let inflight = Arc::new(AtomicUsize::new(0));
        let inf = inflight.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..50 {
                tx.send(i).unwrap();
                inf.fetch_add(1, Ordering::SeqCst);
            }
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        // with cap 2 the producer can be at most ~3 sends ahead
        assert!(inflight.load(Ordering::SeqCst) <= 3);
        let mut n = 0;
        while rx.recv().is_some() {
            n += 1;
        }
        assert_eq!(n, 50);
        producer.join().unwrap();
    }

    #[test]
    fn receiver_drop_unblocks_producer() {
        let (tx, rx) = bounded(1);
        drop(rx);
        assert!(tx.send(1).is_err());
    }

    #[test]
    fn try_send_distinguishes_full_from_closed() {
        let (tx, rx) = bounded(2);
        assert_eq!(tx.try_send(1), Ok(()));
        assert_eq!(tx.try_send(2), Ok(()));
        // at capacity: the item comes straight back, nothing blocks
        assert_eq!(tx.try_send(3), Err(TrySendError::Full(3)));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(tx.try_send(3), Ok(()));
        drop(rx);
        assert_eq!(tx.try_send(4), Err(TrySendError::Closed(4)));
    }

    #[test]
    fn recv_batch_drains_up_to_max_then_closes() {
        let (tx, rx) = bounded(16);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        // first call takes what is queued, bounded by max
        assert_eq!(rx.recv_batch(3), Some(vec![0, 1, 2]));
        assert_eq!(rx.recv_batch(8), Some(vec![3, 4]));
        drop(tx);
        assert_eq!(rx.recv_batch(4), None);
    }

    #[test]
    fn recv_batch_wakes_on_late_send() {
        let (tx, rx) = bounded(4);
        let consumer = std::thread::spawn(move || rx.recv_batch(10));
        std::thread::sleep(std::time::Duration::from_millis(20));
        tx.send(7usize).unwrap();
        drop(tx);
        assert_eq!(consumer.join().unwrap(), Some(vec![7]));
    }

    /// With a deadline in the future, the batch stays open until more
    /// items arrive (closing at `max`), and an expired deadline closes it
    /// with whatever is queued.
    #[test]
    fn recv_batch_by_waits_for_deadline_or_max() {
        let (tx, rx) = bounded(16);
        tx.send(1usize).unwrap();
        let consumer = std::thread::spawn(move || {
            rx.recv_batch_by(3, |_| Some(Instant::now() + Duration::from_secs(10)))
        });
        // the consumer holds the batch open while the deadline is far out;
        // two more sends hit `max` and close it
        std::thread::sleep(Duration::from_millis(30));
        tx.send(2).unwrap();
        tx.send(3).unwrap();
        assert_eq!(consumer.join().unwrap(), Some(vec![1, 2, 3]));

        // an already-expired deadline drains immediately, even below max
        let (tx2, rx2) = bounded(4);
        tx2.send(9usize).unwrap();
        let got = rx2.recv_batch_by(3, |_| Some(Instant::now() - Duration::from_millis(1)));
        assert_eq!(got, Some(vec![9]));
        drop(tx2);
    }

    /// A closed queue releases a deadline-bounded batch immediately — the
    /// shutdown path must not sit out the whole SLO window.
    #[test]
    fn recv_batch_by_returns_on_close() {
        let (tx, rx) = bounded(4);
        tx.send(1usize).unwrap();
        let t0 = Instant::now();
        let consumer = std::thread::spawn(move || {
            rx.recv_batch_by(8, |_| Some(Instant::now() + Duration::from_secs(30)))
        });
        std::thread::sleep(Duration::from_millis(20));
        drop(tx); // close: the open batch must drain now
        assert_eq!(consumer.join().unwrap(), Some(vec![1]));
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn prefetcher_yields_all_items_then_none() {
        let p = Prefetcher::spawn(10, 3, |i| i * i);
        let got: Vec<usize> = std::iter::from_fn(|| p.next()).collect();
        assert_eq!(got, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn prefetcher_early_drop_joins_cleanly() {
        let p = Prefetcher::spawn(1000, 2, |i| i);
        assert_eq!(p.next(), Some(0));
        drop(p); // must not deadlock
    }

    #[test]
    fn parallel_for_chunks_touches_every_element() {
        let mut data = vec![0usize; 1000];
        parallel_for_chunks(&mut data, 128, |base, part| {
            for (j, v) in part.iter_mut().enumerate() {
                *v = base + j;
            }
        });
        assert_eq!(data, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(257, 8, |i| i * 3);
        assert_eq!(out, (0..257).map(|i| i * 3).collect::<Vec<_>>());
    }

    /// Executor and legacy scoped-spawn engines produce identical results
    /// (the cross-path bit-exactness contract the serving bench asserts at
    /// every sweep point).
    #[test]
    fn parallel_map_modes_agree() {
        let want: Vec<usize> = (0..1000).map(|i| i.wrapping_mul(2654435761)).collect();
        let a = parallel_map(1000, 7, |i| i.wrapping_mul(2654435761));
        set_parallel_mode(ParallelMode::ScopedSpawn);
        let b = parallel_map(1000, 7, |i| i.wrapping_mul(2654435761));
        set_parallel_mode(ParallelMode::Executor);
        assert_eq!(a, want);
        assert_eq!(b, want);
    }

    /// The executor is persistent: after warmup, repeated parallel regions
    /// create no further executor threads.
    #[test]
    fn executor_never_respawns_workers() {
        let exec = executor();
        let _ = parallel_map(64, 8, |i| i); // warm
        let spawned = exec.threads_spawned();
        assert_eq!(spawned, exec.workers());
        for _ in 0..50 {
            let _ = parallel_map(64, 8, |i| i * i);
        }
        assert_eq!(exec.threads_spawned(), spawned);
    }

    /// Scoped tasks may borrow the caller's stack, and steal order never
    /// changes the result.
    #[test]
    fn executor_scope_borrows_locals() {
        let data: Vec<u64> = (0..513).collect();
        let mut out = vec![0u64; 513];
        executor().scope(|s| {
            for (slot, chunk) in out.chunks_mut(64).zip(data.chunks(64)) {
                s.spawn(move || {
                    for (o, &v) in slot.iter_mut().zip(chunk) {
                        *o = v * v;
                    }
                });
            }
        });
        assert_eq!(out, (0..513).map(|v| v * v).collect::<Vec<u64>>());
    }

    /// Nested scopes must not deadlock even when tasks outnumber workers:
    /// the inner scope's owner steals its own tasks back and runs them
    /// inline.
    #[test]
    fn executor_nested_scopes_make_progress() {
        let n = executor().workers().max(2) * 4;
        let total: usize = parallel_map(n, n, |i| {
            // inner parallel region from inside an executor task
            parallel_map(8, 8, move |j| i + j).into_iter().sum::<usize>()
        })
        .into_iter()
        .sum();
        let want: usize = (0..n).map(|i| (0..8).map(|j| i + j).sum::<usize>()).sum();
        assert_eq!(total, want);
    }

    /// A panicking task propagates to the scope owner (like
    /// `std::thread::scope`) and the pool survives to run later work.
    #[test]
    fn executor_propagates_task_panics_and_survives() {
        let result = std::panic::catch_unwind(|| {
            executor().scope(|s| {
                for i in 0..8 {
                    s.spawn(move || {
                        if i == 5 {
                            panic!("boom {i}");
                        }
                    });
                }
            });
        });
        assert!(result.is_err(), "task panic must reach the scope owner");
        // the executor still works afterwards
        assert_eq!(parallel_map(100, 4, |i| i + 1).iter().sum::<usize>(), 5050);
    }

    #[test]
    fn with_scratch_reuses_per_thread_state() {
        // first use: default; the pushed value survives to the next call
        // on the same thread
        with_scratch::<Vec<u32>, _>(|v| {
            assert!(v.is_empty());
            v.push(7);
        });
        with_scratch::<Vec<u32>, _>(|v| {
            assert_eq!(v.as_slice(), &[7]);
            v.clear();
        });
        // nested use of the same type gets a fresh value, not a RefCell
        // panic
        with_scratch::<Vec<u32>, _>(|outer| {
            outer.push(1);
            with_scratch::<Vec<u32>, _>(|inner| assert!(inner.is_empty()));
        });
    }

    #[test]
    fn threads_policy_parses_override() {
        assert_eq!(threads_policy(Some("3"), 8), 3);
        assert_eq!(threads_policy(Some(" 12 "), 8), 12);
        // zero, junk or absent fall back
        assert_eq!(threads_policy(Some("0"), 8), 8);
        assert_eq!(threads_policy(Some("lots"), 8), 8);
        assert_eq!(threads_policy(None, 8), 8);
    }
}

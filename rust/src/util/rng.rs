//! Deterministic RNG substrate: SplitMix64 + Box-Muller normals.
//!
//! Used for parameter initialization (He-normal, per the manifest's
//! `init_std`), the synthetic datasets, and shuffling. Deterministic across
//! runs and platforms so every experiment in EXPERIMENTS.md is exactly
//! reproducible from its seed.

/// SplitMix64 — tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second normal from Box-Muller
    spare: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
            spare: None,
        }
    }

    /// Derive an independent stream (e.g. per parameter tensor / per shard).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits of uniformity.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift; bias is < 2^-32 for our n.
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Vector of N(0, std^2) samples.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs = r.normal_vec(n, 1.0);
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}

//! Mini property-testing harness (proptest is not vendored).
//!
//! `check(cases, |rng| ...)` runs a property over `cases` seeded random
//! inputs; on failure it panics with the failing case's seed so the case
//! can be replayed exactly with `check_one(seed, ...)`.

use super::rng::Rng;

/// Run `prop` over `cases` independent seeded RNGs. The property returns
/// `Result<(), String>`; an `Err` aborts with the failing seed.
pub fn check<F>(cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    check_with_base(0xB17_51_1CE, cases, prop)
}

/// Same, with an explicit base seed (use to replay a whole suite).
pub fn check_with_base<F>(base: u64, cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed on case {case} (replay: check_one({seed:#x}, ...)):\n{msg}"
            );
        }
    }
}

/// Replay a single failing case.
pub fn check_one<F>(seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed for seed {seed:#x}:\n{msg}");
    }
}

/// Helper: assert closeness inside a property.
pub fn ensure_close(a: f32, b: f32, tol: f32, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Helper: plain boolean assertion with message.
pub fn ensure(cond: bool, what: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(what.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let n = std::cell::Cell::new(0usize);
        check(25, |rng| {
            let _ = rng.next_u64();
            n.set(n.get() + 1);
            Ok(())
        });
        assert_eq!(n.get(), 25);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(10, |rng| {
            ensure(rng.next_f32() < 2.0, "always true")?;
            Err("deliberate".to_string())
        });
    }

    #[test]
    fn ensure_close_tolerates_within_bound() {
        assert!(ensure_close(1.0, 1.0005, 1e-3, "x").is_ok());
        assert!(ensure_close(1.0, 1.1, 1e-3, "x").is_err());
    }
}

//! # bitslice-reram
//!
//! Reproduction of *"Exploring Bit-Slice Sparsity in Deep Neural Networks
//! for Efficient ReRAM-Based Deployment"* (Zhang et al., 2019) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! This crate is **Layer 3**: the coordinator that owns the training loop,
//! data pipeline, sparsity analysis and the ReRAM deployment substrate. The
//! compute graphs (Layer 2 JAX models calling Layer 1 Pallas kernels) are
//! AOT-lowered to HLO text by `python/compile/aot.py` and executed through
//! the PJRT CPU client ([`runtime`]); Python is never on the run path.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`runtime`]     — PJRT client, artifact manifest, executable cache
//! * [`tensor`]      — host tensors and conversions to/from XLA literals
//! * [`data`]        — MNIST/CIFAR-10 loaders + deterministic synthetic
//!                     fallback, batching and prefetching
//! * [`quant`]       — dynamic fixed-point quantization + bit-slicing
//!                     (Rust mirror of the L1 kernels, used for analysis
//!                     and crossbar mapping)
//! * [`sparsity`]    — per-slice non-zero statistics (Tables 1/2, Fig. 2)
//! * [`reram`]       — crossbar arrays, weight mapper, ADC cost model,
//!                     bitline-current/resolution analyzer (Table 3)
//! * [`coordinator`] — trainer phases, schedules, pruning, checkpoints,
//!                     metrics, evaluation
//! * [`serve`]       — the unified inference layer: the
//!                     `InferenceBackend` trait (XLA graphs, crossbar
//!                     simulator, exact quantized reference) and the
//!                     batched `ServingEngine` request path
//! * [`report`]      — paper-style table/figure emitters + serving stats
//! * [`config`]      — run configuration (CLI + TOML-ish files)
//! * [`util`]        — substrates the sandbox lacks crates for: JSON
//!                     parser, CLI args, RNG, thread pool

pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod quant;
pub mod report;
pub mod reram;
pub mod runtime;
pub mod serve;
pub mod sparsity;
pub mod tensor;
pub mod util;

//! Deployment cost roll-up: Table 3 and the whole-model variant.
//!
//! Table 3 reports per-crossbar-group ratios (energy / sensing-time / area
//! saving of the reduced-resolution ADC against the ISAAC 8-bit baseline).
//! The model-level roll-up weighs each slice group by its ADC conversion
//! count (converting columns x activation bit-planes), which is what an
//! end-to-end deployment would see. Unprogrammed (fully-zero) tiles —
//! e.g. the empty negative-sign grid of an all-positive layer — are never
//! fabricated, so they contribute no crossbar, no conversions and no
//! area; structurally-zero columns of *compressed* and *bit-plane* tiles
//! are skipped by the per-tile nonzero-column index, so they are not
//! billed either (dense tiles carry no index and convert — and pay for —
//! every column, exactly like the simulator's dense ADC loop).
//!
//! Costs can be rolled up at one uniform per-slice resolution
//! ([`deployment_cost`]) or per layer under a
//! [`super::planner::DeploymentPlan`] ([`plan_cost`], [`layer_costs`]).
//! Bit arrays are LSB-first; see the bit-order convention in the
//! [`crate::reram`] module docs.

use crate::quant::N_SLICES;

use super::adc::AdcModel;
use super::mapper::{LayerMapping, MappedModel};
use super::planner::DeploymentPlan;

/// One row of Table 3.
#[derive(Debug, Clone)]
pub struct AdcSavingRow {
    /// which crossbar group, MSB-first label (3 = XB_3 = MSB slice)
    pub group: usize,
    pub baseline_bits: u32,
    pub bits: u32,
    pub energy_saving: f64,
    pub speedup: f64,
    pub area_saving: f64,
}

/// Compute a Table-3 row for one slice group.
pub fn saving_row(group: usize, bits: u32) -> AdcSavingRow {
    AdcSavingRow {
        group,
        baseline_bits: super::adc::BASELINE_BITS,
        bits,
        energy_saving: AdcModel::energy_saving(bits),
        speedup: AdcModel::speedup(bits),
        area_saving: AdcModel::area_saving(bits),
    }
}

/// Whole-model deployment summary.
#[derive(Debug, Clone)]
pub struct DeploymentCost {
    /// fabricated crossbars (programmed tiles only)
    pub crossbars: usize,
    /// fully-zero tiles excluded from the roll-up
    pub skipped_tiles: usize,
    /// total ADC energy, relative units (sum over conversions of power)
    pub energy: f64,
    /// total sensing time, relative units
    pub time: f64,
    /// total ADC area, relative units (one ADC per crossbar, ISAAC-style
    /// column-multiplexed)
    pub area: f64,
}

/// Per-layer roll-up row under a plan: the layer's resolutions, crossbar
/// count and savings against the 8-bit baseline on the same mapping.
/// `crossbars` and `area` cover every fabricated replica; `energy`/`time`
/// stay per example (each example runs on exactly one replica), so
/// replication shows up as an area price, never an energy discount.
#[derive(Debug, Clone)]
pub struct LayerCost {
    pub layer: String,
    /// per-slice resolutions this layer deploys, LSB-first
    pub adc_bits: [u32; N_SLICES],
    /// fabricated copies of the layer (>= 1)
    pub replicas: usize,
    pub crossbars: usize,
    pub energy: f64,
    pub time: f64,
    pub area: f64,
    pub energy_saving: f64,
    pub time_saving: f64,
    pub area_saving: f64,
}

/// ADC conversions (**converting** columns x 8 activation bit-planes) of
/// slice group `k` of one layer, counting programmed tiles only. This is
/// the weight of one (layer, slice) group in the energy roll-up — the
/// planner scores its candidate moves by
/// `conversions * (power(bits) - power(bits - 1))`.
///
/// The billing matches execution exactly
/// ([`crate::reram::crossbar::Crossbar::converting_columns`]): compressed
/// and bit-plane tiles convert only their nonzero-column index — the
/// simulator skips structurally-zero columns outright via
/// [`crate::reram::crossbar::Crossbar::bitline_currents_active`], and
/// with wordline/column reordering they cluster into whole unbilled
/// tiles — while dense tiles carry no index and convert every column.
/// Both counts are cached per tile, so the tally is O(tiles).
pub fn slice_conversions(layer: &LayerMapping, k: usize) -> f64 {
    let (pos, neg) = &layer.grids[k];
    [pos, neg]
        .iter()
        .flat_map(|g| &g.tiles)
        .filter(|t| t.nonzero_cells() > 0)
        .map(|t| (t.converting_columns() * 8) as f64)
        .sum()
}

/// Tally one layer at per-slice resolutions `bits`:
/// (crossbars, skipped_tiles, energy, time, area). The zero-tile test is
/// the cached census (O(1) per tile) and conversions count converting
/// columns only (see [`slice_conversions`]).
fn tally_layer(layer: &LayerMapping, bits: &[u32; N_SLICES]) -> (usize, usize, f64, f64, f64) {
    let mut crossbars = 0usize;
    let mut skipped = 0usize;
    let (mut energy, mut time, mut area) = (0.0, 0.0, 0.0);
    for (k, (pos, neg)) in layer.grids.iter().enumerate() {
        let b = bits[k];
        for grid in [pos, neg] {
            for tile in &grid.tiles {
                if tile.nonzero_cells() == 0 {
                    skipped += 1;
                    continue;
                }
                crossbars += 1;
                // one ADC per crossbar; conversions = converting columns
                // x 8 planes (what the ADC loop actually executes under
                // this tile's layout)
                let conversions = (tile.converting_columns() * 8) as f64;
                energy += conversions * AdcModel::power(b);
                time += conversions * AdcModel::sensing_time(b);
                area += AdcModel::area(b);
            }
        }
    }
    (crossbars, skipped, energy, time, area)
}

/// Roll up a mapped model under a per-layer deployment plan.
pub fn plan_cost(model: &MappedModel, plan: &DeploymentPlan) -> DeploymentCost {
    assert_eq!(
        plan.layers.len(),
        model.layers.len(),
        "plan has {} layers, mapping has {}",
        plan.layers.len(),
        model.layers.len()
    );
    let mut out = DeploymentCost {
        crossbars: 0,
        skipped_tiles: 0,
        energy: 0.0,
        time: 0.0,
        area: 0.0,
    };
    for (layer, pl) in model.layers.iter().zip(&plan.layers) {
        let (xb, skipped, e, t, a) = tally_layer(layer, &pl.adc_bits);
        // replication fabricates `r` copies of the layer's arrays: the
        // static/area side scales, the per-example conversion cost does
        // not (each example runs on exactly one replica)
        let r = pl.replicas.max(1);
        out.crossbars += xb * r;
        out.skipped_tiles += skipped * r;
        out.energy += e;
        out.time += t;
        out.area += a * r as f64;
    }
    out
}

/// Roll up a mapped model at uniform per-slice resolutions (every layer
/// deploys the same `adc_bits`) — thin wrapper over [`plan_cost`].
pub fn deployment_cost(model: &MappedModel, adc_bits: [u32; N_SLICES]) -> DeploymentCost {
    plan_cost(model, &DeploymentPlan::uniform_for(model, adc_bits))
}

/// Savings ratio with a zero-cost guard: 1.0 when both sides are zero
/// (nothing deployed on either), infinite when only ours is.
pub(crate) fn ratio(base: f64, ours: f64) -> f64 {
    if ours == 0.0 {
        if base == 0.0 {
            1.0
        } else {
            f64::INFINITY
        }
    } else {
        base / ours
    }
}

/// Per-layer cost rows for a plan, each with savings vs the 8-bit baseline
/// on the same layer — the body of the `PlanRow` deployment report.
pub fn layer_costs(model: &MappedModel, plan: &DeploymentPlan) -> Vec<LayerCost> {
    assert_eq!(plan.layers.len(), model.layers.len(), "plan/mapping layer count");
    model
        .layers
        .iter()
        .zip(&plan.layers)
        .map(|(layer, pl)| {
            let (xb, _, e, t, a) = tally_layer(layer, &pl.adc_bits);
            let (_, _, be, bt, ba) = tally_layer(layer, &[super::adc::BASELINE_BITS; N_SLICES]);
            // the 8-bit baseline is unreplicated, so extra replicas eat
            // into the layer's area saving — area is the price of the
            // throughput the timing model credits
            let r = pl.replicas.max(1);
            let area = a * r as f64;
            LayerCost {
                layer: layer.name.clone(),
                adc_bits: pl.adc_bits,
                replicas: r,
                crossbars: xb * r,
                energy: e,
                time: t,
                area,
                energy_saving: ratio(be, e),
                time_saving: ratio(bt, t),
                area_saving: ratio(ba, area),
            }
        })
        .collect()
}

/// Savings of a per-layer plan against the 8-bit baseline on the same
/// mapping: (energy, time, area).
pub fn plan_savings_vs_baseline(model: &MappedModel, plan: &DeploymentPlan) -> (f64, f64, f64) {
    let ours = plan_cost(model, plan);
    let base = deployment_cost(model, [super::adc::BASELINE_BITS; N_SLICES]);
    (
        ratio(base.energy, ours.energy),
        ratio(base.time, ours.time),
        ratio(base.area, ours.area),
    )
}

/// Savings of a uniform deployment against the 8-bit baseline.
pub fn savings_vs_baseline(model: &MappedModel, adc_bits: [u32; N_SLICES]) -> (f64, f64, f64) {
    plan_savings_vs_baseline(model, &DeploymentPlan::uniform_for(model, adc_bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::mapper::map_model;
    use crate::reram::resolution::{self, ResolutionPolicy};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn mapped() -> MappedModel {
        let mut rng = Rng::new(1);
        let w = Tensor::new(vec![256, 100], rng.normal_vec(25600, 0.1)).unwrap();
        map_model(&[("w".into(), w)]).unwrap()
    }

    #[test]
    fn table3_rows_match_paper() {
        let msb = saving_row(3, 1);
        assert!((msb.energy_saving - 28.4).abs() < 0.1);
        assert!((msb.speedup - 8.0).abs() < 1e-12);
        assert!((msb.area_saving - 2.0).abs() < 1e-12);
        let low = saving_row(2, 3);
        assert!((low.energy_saving - 14.2).abs() < 0.05);
        assert!((low.speedup - 8.0 / 3.0).abs() < 1e-12);
        assert!((low.area_saving - 2.0).abs() < 1e-12);
    }

    #[test]
    fn baseline_cost_is_identity_saving() {
        let m = mapped();
        let (e, t, a) = savings_vs_baseline(&m, [8, 8, 8, 8]);
        assert!((e - 1.0).abs() < 1e-12);
        assert!((t - 1.0).abs() < 1e-12);
        assert!((a - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_operating_point_saves_in_expected_band() {
        let m = mapped();
        // LSB-first (3,3,3,1): three groups at 14.2x, one at 28.4x energy
        let (e, t, a) = savings_vs_baseline(&m, [3, 3, 3, 1]);
        assert!(e > 14.0 && e < 29.0, "energy saving {e}");
        assert!(t > 2.5 && t < 8.1, "speedup {t}");
        assert!((a - 2.0).abs() < 1e-9, "area saving {a}");
    }

    #[test]
    fn cost_scales_with_crossbar_count() {
        let mut rng = Rng::new(2);
        let w1 = Tensor::new(vec![128, 128], rng.normal_vec(128 * 128, 0.1)).unwrap();
        let m1 = map_model(&[("a".into(), w1.clone())]).unwrap();
        let m2 = map_model(&[("a".into(), w1.clone()), ("b".into(), w1)]).unwrap();
        let c1 = deployment_cost(&m1, [3, 3, 3, 1]);
        let c2 = deployment_cost(&m2, [3, 3, 3, 1]);
        assert!((c2.energy / c1.energy - 2.0).abs() < 1e-9);
        assert_eq!(c2.crossbars, 2 * c1.crossbars);
    }

    #[test]
    fn zero_tiles_are_not_billed() {
        // all-positive layer: every negative-sign grid is fully zero; no
        // array is fabricated for it, so it must not count as a crossbar
        // nor contribute ADC conversions or area
        let w = Tensor::new(vec![64, 32], vec![0.5; 64 * 32]).unwrap();
        let m = map_model(&[("p".into(), w)]).unwrap();
        let cost = deployment_cost(&m, [3, 3, 3, 1]);
        assert_eq!(cost.crossbars, 4, "one pos tile per slice group");
        assert_eq!(cost.skipped_tiles, 4, "one empty neg tile per group");

        // the billed census matches the nonzero-cell-bearing tiles exactly
        let programmed: usize = m.layers[0]
            .grids
            .iter()
            .flat_map(|(p, n)| [p, n])
            .flat_map(|g| &g.tiles)
            .filter(|t| t.nonzero_cells() > 0)
            .count();
        assert_eq!(cost.crossbars, programmed);

        // mixed-sign layer: everything is programmed, nothing skipped
        let mut rng = Rng::new(9);
        let w = Tensor::new(vec![64, 32], rng.normal_vec(64 * 32, 0.2)).unwrap();
        let m = map_model(&[("m".into(), w)]).unwrap();
        let cost = deployment_cost(&m, [3, 3, 3, 1]);
        assert_eq!(cost.crossbars, 8);
        assert_eq!(cost.skipped_tiles, 0);
    }

    #[test]
    fn plan_cost_matches_uniform_wrapper_and_orders_by_bits() {
        let m = mapped();
        let uniform = deployment_cost(&m, [3, 3, 3, 1]);
        let plan = DeploymentPlan::uniform_for(&m, [3, 3, 3, 1]);
        let via_plan = plan_cost(&m, &plan);
        assert_eq!(uniform.crossbars, via_plan.crossbars);
        assert!((uniform.energy - via_plan.energy).abs() < 1e-9);
        assert!((uniform.time - via_plan.time).abs() < 1e-9);
        assert!((uniform.area - via_plan.area).abs() < 1e-9);

        // lowering any layer's bits can only lower energy and time
        let mut cheaper = plan.clone();
        cheaper.layers[0].adc_bits = [2, 2, 2, 1];
        let c = plan_cost(&m, &cheaper);
        assert!(c.energy < via_plan.energy);
        assert!(c.time < via_plan.time);
    }

    #[test]
    fn layer_costs_roll_up_to_plan_cost() {
        let mut rng = Rng::new(5);
        let w1 = Tensor::new(vec![200, 60], rng.normal_vec(200 * 60, 0.1)).unwrap();
        let w2 = Tensor::new(vec![60, 30], rng.normal_vec(60 * 30, 0.1)).unwrap();
        let m = map_model(&[("a".into(), w1), ("b".into(), w2)]).unwrap();
        let plan = DeploymentPlan::from_policy(&m, ResolutionPolicy::Percentile(0.999));
        let rows = layer_costs(&m, &plan);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].layer, "a");
        let total = plan_cost(&m, &plan);
        let e: f64 = rows.iter().map(|r| r.energy).sum();
        let xb: usize = rows.iter().map(|r| r.crossbars).sum();
        assert!((e - total.energy).abs() < 1e-9);
        assert_eq!(xb, total.crossbars);
        for r in &rows {
            assert!(r.energy_saving >= 1.0, "{}: {}", r.layer, r.energy_saving);
        }
    }

    /// Replication fabricates copies: crossbars and area scale with the
    /// replica count, per-example conversion energy/time do not, and the
    /// layer row's area saving pays for the copies.
    #[test]
    fn replication_scales_area_not_energy() {
        let m = mapped();
        let mut plan = DeploymentPlan::uniform_for(&m, [3, 3, 3, 1]);
        let base = plan_cost(&m, &plan);
        let base_rows = layer_costs(&m, &plan);
        plan.layers[0].replicas = 3;
        let rep = plan_cost(&m, &plan);
        assert_eq!(rep.crossbars, 3 * base.crossbars);
        assert!((rep.area - 3.0 * base.area).abs() < 1e-9);
        assert_eq!(rep.energy, base.energy);
        assert_eq!(rep.time, base.time);
        let rows = layer_costs(&m, &plan);
        assert_eq!(rows[0].replicas, 3);
        assert_eq!(rows[0].crossbars, 3 * base_rows[0].crossbars);
        assert!(
            (rows[0].area_saving - base_rows[0].area_saving / 3.0).abs() < 1e-9,
            "replicas eat the area saving"
        );
        assert_eq!(rows[0].energy_saving, base_rows[0].energy_saving);
    }

    #[test]
    fn structurally_zero_columns_are_not_billed() {
        // one populated column + a pin: the other 30 columns of the tile
        // never convert, so they must not weigh in the energy roll-up
        let mut data = vec![0.0f32; 64 * 32];
        for r in 0..64 {
            data[r * 32] = 0.5;
        }
        data[63 * 32 + 31] = 1.0; // pin
        let w = Tensor::new(vec![64, 32], data).unwrap();
        let m = map_model(&[("z".into(), w.clone())]).unwrap();
        // code(0.5) = 128: only slice 3 holds column 0; slices 0..2 hold
        // just the pin column -> 1 conversion column x 8 planes
        for k in 0..3 {
            assert_eq!(slice_conversions(&m.layers[0], k), 8.0, "slice {k}");
        }
        assert_eq!(slice_conversions(&m.layers[0], 3), 16.0, "msb slice");

        // single-row-block layer: reordering relocates columns 1:1, so
        // the per-tile active-column census — and the billing — is exact
        let r = crate::reram::mapper::map_model_with(
            &[("z".into(), w)],
            Some(crate::reram::reorder::ReorderConfig::default()),
        )
        .unwrap();
        for k in 0..4 {
            assert_eq!(
                slice_conversions(&r.layers[0], k),
                slice_conversions(&m.layers[0], k),
                "slice {k} conversions changed under reorder"
            );
        }
    }

    #[test]
    fn slice_conversions_count_programmed_columns() {
        let w = Tensor::new(vec![64, 32], vec![0.5; 64 * 32]).unwrap();
        let m = map_model(&[("p".into(), w)]).unwrap();
        for k in 0..N_SLICES {
            // only the pos tile (32 columns) is programmed: 32 x 8 planes
            assert_eq!(slice_conversions(&m.layers[0], k), 256.0);
        }
        // consistency with the resolution census column count
        let currents = resolution::layer_slice_currents(&m.layers[0]);
        for k in 0..N_SLICES {
            assert_eq!(
                slice_conversions(&m.layers[0], k),
                (currents[k].sums.len() * 8) as f64
            );
        }
    }
}

//! Deployment cost roll-up: Table 3 and the whole-model variant.
//!
//! Table 3 reports per-crossbar-group ratios (energy / sensing-time / area
//! saving of the reduced-resolution ADC against the ISAAC 8-bit baseline).
//! The model-level roll-up weighs each slice group by its ADC conversion
//! count (columns x activation bit-planes), which is what an end-to-end
//! deployment would see.

use crate::quant::N_SLICES;

use super::adc::AdcModel;
use super::mapper::MappedModel;

/// One row of Table 3.
#[derive(Debug, Clone)]
pub struct AdcSavingRow {
    /// which crossbar group, MSB-first label (3 = XB_3 = MSB slice)
    pub group: usize,
    pub baseline_bits: u32,
    pub bits: u32,
    pub energy_saving: f64,
    pub speedup: f64,
    pub area_saving: f64,
}

/// Compute a Table-3 row for one slice group.
pub fn saving_row(group: usize, bits: u32) -> AdcSavingRow {
    AdcSavingRow {
        group,
        baseline_bits: super::adc::BASELINE_BITS,
        bits,
        energy_saving: AdcModel::energy_saving(bits),
        speedup: AdcModel::speedup(bits),
        area_saving: AdcModel::area_saving(bits),
    }
}

/// Whole-model deployment summary.
#[derive(Debug, Clone)]
pub struct DeploymentCost {
    /// per-slice (LSB-first) ADC resolutions used
    pub adc_bits: [u32; N_SLICES],
    /// total crossbars
    pub crossbars: usize,
    /// total ADC energy, relative units (sum over conversions of power)
    pub energy: f64,
    /// total sensing time, relative units
    pub time: f64,
    /// total ADC area, relative units (one ADC per crossbar, ISAAC-style
    /// column-multiplexed)
    pub area: f64,
}

/// Roll up a mapped model at the given per-slice resolutions.
pub fn deployment_cost(model: &MappedModel, adc_bits: [u32; N_SLICES]) -> DeploymentCost {
    let mut energy = 0.0;
    let mut time = 0.0;
    let mut area = 0.0;
    let mut crossbars = 0usize;
    for layer in &model.layers {
        for (k, (pos, neg)) in layer.grids.iter().enumerate() {
            let bits = adc_bits[k];
            for grid in [pos, neg] {
                for tile in &grid.tiles {
                    crossbars += 1;
                    // one ADC per crossbar; conversions = columns x 8 planes
                    let conversions = (tile.cols() * 8) as f64;
                    energy += conversions * AdcModel::power(bits);
                    time += conversions * AdcModel::sensing_time(bits);
                    area += AdcModel::area(bits);
                }
            }
        }
    }
    DeploymentCost {
        adc_bits,
        crossbars,
        energy,
        time,
        area,
    }
}

/// Savings of a deployment against the 8-bit baseline on the same mapping.
pub fn savings_vs_baseline(model: &MappedModel, adc_bits: [u32; N_SLICES]) -> (f64, f64, f64) {
    let ours = deployment_cost(model, adc_bits);
    let base = deployment_cost(model, [8, 8, 8, 8]);
    (
        base.energy / ours.energy,
        base.time / ours.time,
        base.area / ours.area,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::mapper::map_model;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn mapped() -> MappedModel {
        let mut rng = Rng::new(1);
        let w = Tensor::new(vec![256, 100], rng.normal_vec(25600, 0.1)).unwrap();
        map_model(&[("w".into(), w)]).unwrap()
    }

    #[test]
    fn table3_rows_match_paper() {
        let msb = saving_row(3, 1);
        assert!((msb.energy_saving - 28.4).abs() < 0.1);
        assert!((msb.speedup - 8.0).abs() < 1e-12);
        assert!((msb.area_saving - 2.0).abs() < 1e-12);
        let low = saving_row(2, 3);
        assert!((low.energy_saving - 14.2).abs() < 0.05);
        assert!((low.speedup - 8.0 / 3.0).abs() < 1e-12);
        assert!((low.area_saving - 2.0).abs() < 1e-12);
    }

    #[test]
    fn baseline_cost_is_identity_saving() {
        let m = mapped();
        let (e, t, a) = savings_vs_baseline(&m, [8, 8, 8, 8]);
        assert!((e - 1.0).abs() < 1e-12);
        assert!((t - 1.0).abs() < 1e-12);
        assert!((a - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_operating_point_saves_in_expected_band() {
        let m = mapped();
        // LSB-first (3,3,3,1): three groups at 14.2x, one at 28.4x energy
        let (e, t, a) = savings_vs_baseline(&m, [3, 3, 3, 1]);
        assert!(e > 14.0 && e < 29.0, "energy saving {e}");
        assert!(t > 2.5 && t < 8.1, "speedup {t}");
        assert!((a - 2.0).abs() < 1e-9, "area saving {a}");
    }

    #[test]
    fn cost_scales_with_crossbar_count() {
        let mut rng = Rng::new(2);
        let w1 = Tensor::new(vec![128, 128], rng.normal_vec(128 * 128, 0.1)).unwrap();
        let m1 = map_model(&[("a".into(), w1.clone())]).unwrap();
        let m2 = map_model(&[("a".into(), w1.clone()), ("b".into(), w1)]).unwrap();
        let c1 = deployment_cost(&m1, [3, 3, 3, 1]);
        let c2 = deployment_cost(&m2, [3, 3, 3, 1]);
        assert!((c2.energy / c1.energy - 2.0).abs() < 1e-9);
        assert_eq!(c2.crossbars, 2 * c1.crossbars);
    }
}

//! ReRAM deployment substrate (paper Sec. 3 "in simulation" + Table 3).
//!
//! The paper maps the quantized 8-bit weights, 2 bits per cell, onto four
//! groups of 128x128 crossbars (XB₃…XB₀, MSB to LSB slice) and sizes the
//! per-crossbar ADCs by the bit-slice sparsity the training achieved. This
//! module is that deployment stack:
//!
//! * [`crossbar`]   — the array model: cells, differential pos/neg pairs,
//!                    bitline current accumulation.
//! * [`mapper`]     — tile a layer's slice matrices onto 128x128 arrays.
//! * [`adc`]        — the ADC cost model of [17]: power ∝ 2^N/(N+1),
//!                    sensing time ∝ N, area halves at 6 bits (Table 3).
//! * [`resolution`] — bitline-current analysis: the ADC resolution each
//!                    crossbar group needs at the achieved sparsity.
//! * [`sim`]        — functional simulator: run a mapped layer bit-serially
//!                    through the ADC transfer function (validates accuracy
//!                    under reduced resolution; mirrors the L1 crossbar
//!                    kernel and is cross-checked against it).
//! * [`energy`]     — whole-deployment roll-up: energy / latency / area
//!                    vs the ISAAC-style 8-bit-ADC baseline.

pub mod adc;
pub mod crossbar;
pub mod energy;
pub mod mapper;
pub mod resolution;
pub mod sim;

pub use adc::AdcModel;
pub use crossbar::{Crossbar, XBAR_COLS, XBAR_ROWS};
pub use mapper::{LayerMapping, MappedModel};
pub use resolution::ResolutionPolicy;

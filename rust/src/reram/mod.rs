//! ReRAM deployment substrate (paper Sec. 3 "in simulation" + Table 3).
//!
//! The paper maps the quantized 8-bit weights, 2 bits per cell, onto four
//! groups of 128x128 crossbars (XB₃…XB₀, MSB to LSB slice) and sizes the
//! per-crossbar ADCs by the bit-slice sparsity the training achieved. This
//! module is that deployment stack:
//!
//! * [`crossbar`]   — the array model: cells, differential pos/neg pairs,
//!                    bitline current accumulation over polymorphic tile
//!                    storage (dense, bit-plane or compressed — see below).
//! * [`mapper`]     — tile a layer's slice matrices onto 128x128 arrays,
//!                    choosing each tile's storage format from its density.
//! * [`adc`]        — the ADC cost model of [17]: power ∝ 2^N/(N+1),
//!                    sensing time ∝ N, area halves at 6 bits (Table 3).
//! * [`resolution`] — bitline-current analysis: the ADC resolution each
//!                    crossbar group needs at the achieved sparsity.
//! * [`sim`]        — functional simulator: run a mapped layer bit-serially
//!                    through the ADC transfer function (validates accuracy
//!                    under reduced resolution; mirrors the L1 crossbar
//!                    kernel and is cross-checked against it).
//! * [`energy`]     — whole-deployment roll-up: energy / latency / area
//!                    vs the ISAAC-style 8-bit-ADC baseline.
//! * [`planner`]    — per-layer ADC deployment planner: searches a
//!                    [`planner::DeploymentPlan`] (per-layer x per-slice
//!                    resolutions) under an accuracy-drop budget, scored by
//!                    the [`energy`] cost model.
//! * [`reorder`]    — map-time wordline/column permutation engine: greedy
//!                    column-similarity clustering concentrates nonzero
//!                    cells into fewer tiles, active wordlines and active
//!                    columns (arXiv:2511.14202-style placement).
//! * [`timing`]     — pipeline cycle model over the same conversion
//!                    census the energy model bills, plus the replication
//!                    planner that water-fills an area budget onto
//!                    bottleneck layers for throughput.
//! * [`audit`]      — static verifier over the finished artifacts: walks
//!                    every tile, plan row and replica handle *without
//!                    running inference* and emits typed diagnostics for
//!                    any convention the sections below state that the
//!                    artifacts no longer satisfy.
//! * [`device`]     — seeded non-ideality model: lognormal conductance
//!                    spread, additive read noise and stuck-at faults per
//!                    programmed cell, applied at read time when a
//!                    [`device::DeviceModel`] is attached (see the
//!                    device-model convention below).
//!
//! # Storage-format selection (Dense vs BitPlanes vs Compressed tiles)
//!
//! Bit-slice L1 training drives each 2-bit slice toward ~90%+ zeros, so
//! tile cells live behind a polymorphic `CellArray` inside [`Crossbar`]
//! with three layouts: row-major **dense** bytes, column-major packed
//! **bit-planes** (below), or **compressed** per-row packed `(col, val)`
//! pairs with a nonzero-wordline index that lets `bitline_currents` touch
//! only programmed cells on active wordlines. The format is chosen *per
//! tile at map time* from the tile's measured density as a three-band
//! policy ([`crossbar::chosen_format`] is the single definition): at or
//! below [`crossbar::COMPRESS_MAX_DENSITY`] (25%) the tile compresses, in
//! the mid band up to [`crossbar::BITPLANE_MAX_DENSITY`] (60%) it packs
//! bit-planes, above that it stays dense. The lower threshold comes from
//! the measured crossover: one compressed entry costs 3 bytes (parallel
//! `u16`/`u8` column/value arrays — no tuple padding) and a scattered add
//! vs one byte and a sequential add per dense cell, so memory parity sits
//! at 1/3 density and the scan wins well below it. The mid band is where
//! neither skip-style leverage nor the naive byte walk helps —
//! dense-random slices (~37% density per sign grid) land here — and the
//! popcount path's cost is density-independent, so it takes the whole
//! band; the dense byte layout above 60% keeps the canonical
//! near-full-tile representation (and the honest naive baseline the
//! benches compare against). The programmed-cell census is cached per
//! tile (maintained by `set`, established by `from_cells`), which makes
//! the zero-tile skips in [`sim`], [`energy`] and [`resolution`] O(1) and
//! the planner's scoring loop O(tiles). Fully-zero tiles are never
//! fabricated: the simulator skips them, the cost model doesn't bill
//! them, and `report::storage_table` lists them as "skipped". Compressed
//! and bit-plane tiles additionally cache a nonzero-**column** index: the
//! per-tile ADC/recombination loop converts only columns that hold a
//! programmed cell ([`crossbar::Crossbar::bitline_currents_active`]), and
//! [`energy`] / [`resolution`] / [`timing`] bill and census exactly the
//! columns that convert under each tile's layout
//! ([`crossbar::Crossbar::converting_columns`] — all of them for dense
//! tiles, which carry no index).
//!
//! # BitPlanes packing convention (word order, row→bit mapping)
//!
//! A bit-plane tile stores, per physical column, two 128-bit masks packed
//! as `[u64; 2]`: `plane0` holds each cell's low bit, `plane1` its high
//! bit, so `cell(r, c) = bit(plane1[c], r) << 1 | bit(plane0[c], r)`.
//! Physical tile row `r` (0-based within the tile, *after* any reorder
//! permutation has been applied at programming time) maps to bit `r & 63`
//! of word `r >> 6` — word 0 covers rows 0..64, word 1 rows 64..128,
//! little-endian within a word — and rows `>= tile.rows()` are zero
//! padding. Activation bit-planes are packed into the *same* shape once
//! per (plane, 128-row block) by [`crossbar::pack_wave`] (the simulator
//! reuses them across every tile and sign grid of a row block), so a
//! column's current is two AND+popcounts:
//! `popcount(plane0 & wave) + (popcount(plane1 & wave) << 1)`. Because
//! both weight planes and activation waves are built from already-
//! permuted positions, reordering needs no extra handling on this path —
//! the packed planes are bit-exact with the byte layouts' permuted cells,
//! and a wave whose mask is all-zero over a block is skipped outright
//! (zero currents convert to zero; see `sim`'s zero-wave skip).
//!
//! # Reorder convention (where codes are permuted, where sums come back)
//!
//! Mapping with a [`reorder::ReorderConfig`]
//! ([`mapper::map_layer_with`] / [`mapper::map_model_with`], the
//! `--reorder` deploy flag) plans one wordline [`reorder::Permutation`]
//! and one column permutation **per layer**, shared by all four slice
//! groups and both signs, and programs every cell at its permuted
//! position. The simulator applies them only at the layer boundary:
//! activation codes are permuted into physical wordline order once per
//! example *before* the bit-planes are built, the accumulator runs in
//! physical column order, and the final scatter restores logical column
//! order — the tile loop never indexes through a permutation. Column
//! reordering is bit-exact at every ADC resolution; wordline reordering
//! moves rows across 128-row tile blocks and is bit-exact at
//! non-clipping resolutions (see [`reorder`] for the full argument).
//!
//! # Timing / replication convention (what a cycle is, how replicas share)
//!
//! One **cycle** = one ADC bit-resolution step, so a column conversion at
//! resolution `b` costs `b` cycles ([`adc::AdcModel::sensing_time`]).
//! Each example drives [`timing::PLANES`] (= 8) bit-serial wordline
//! waves; within a wave, a tile's single column-multiplexed ADC serially
//! converts the tile's **converting** columns — exactly the columns
//! [`crossbar::Crossbar::bitline_currents_active`] converts, so the cycle
//! price, the energy bill and the executed work all count the same set.
//! Tiles run in parallel (one ADC each): a layer's per-example latency is
//! its slowest tile, and the layer pipeline's steady-state throughput is
//! set by the bottleneck stage's *effective* latency, `latency /
//! replicas`.
//!
//! **Replicas** ([`planner::PlanLayer::replicas`], chosen by
//! [`timing::fill_replicas`] water-filling an area budget onto bottleneck
//! layers) are fabricated copies of one layer's arrays: area, crossbar
//! and skipped-tile counts scale by the replica count, per-example
//! conversion energy does not. In simulation a replica is an `Arc` handle
//! on the same tiles ([`mapper::MappedModel::replicated`]) — never a deep
//! clone — and the serving backend shards batch rows across the handles,
//! which is bit-identical to the unsharded path because rows are
//! independent and each runs the exact same per-row pipeline. In
//! `plan.json`, the `timing` object carries one row per layer
//! (`layer`, `replicas`, `latency_cycles`, `effective_cycles`,
//! `conversion_cycles`) plus the `bottleneck_layer`,
//! `bottleneck_cycles`, `throughput_per_kcycle` and
//! `pipeline_fill_cycles` roll-ups.
//!
//! # Threading / scheduling convention (executor lifecycle, determinism)
//!
//! Every hot parallel region — batch rows in the serving backends,
//! `sim::forward` chunks, replica lanes in the sharded path, evaluation-
//! cache candidate scoring, Monte-Carlo noise trials — runs on **one
//! long-lived work-stealing executor**
//! ([`crate::util::pool::executor`]): per-worker deques, round-robin
//! injection, idle workers steal, and nested scopes help-first steal
//! their own tasks so a region started from inside a worker can never
//! deadlock. The pool spawns its [`crate::util::pool::worker_threads`]
//! workers once per process (override with the `RERAM_THREADS` env var;
//! CI and benches use it to pin parallelism) and **never again** —
//! steady-state serving creates zero OS threads, which
//! [`crate::util::pool::os_threads_spawned`] asserts in the SLO bench. A
//! task panic fails its submitting scope, not the pool: workers catch
//! the unwind and keep serving.
//!
//! **Determinism:** scheduling is free, results are not. Every parallel
//! region assigns output **by index** (chunk index, batch-row index, or
//! replica-lane row claims scattered back by row) and keeps each item's
//! reduction order fixed, so executor, scoped-spawn
//! ([`crate::util::pool::ParallelMode`] — the A/B baseline kept for
//! benches) and serial execution are bit-identical, whatever order
//! steals happen in.
//!
//! **Scratch reuse:** workers own persistent type-keyed scratch slots
//! ([`crate::util::pool::with_scratch`]); the wave-pack buffers in
//! [`sim::SimScratch`] and the quantize/accumulate vectors are borrowed
//! from the slot for a chunk and returned, so they are reused not just
//! within one batch but **across** batches and callers — the hot path
//! stops paying per-call allocation exactly where it stopped paying
//! per-call thread spawns.
//!
//! # Bit-order convention (LSB-first `adc_bits` vs MSB-first `XB_k`)
//!
//! Every per-slice array in this codebase — `adc_bits: [u32; N_SLICES]`,
//! [`planner::PlanLayer::adc_bits`], the censuses in [`resolution`], the
//! grids in [`mapper::LayerMapping`] — is indexed **LSB-first**: index
//! `k` is the slice holding weight bits `2k` and `2k+1`, so `k = 0` is the
//! least-significant slice and `k = 3` the most-significant. The paper's
//! Table 3 labels groups **MSB-first** as `XB_3 … XB_0`, where `XB_3` is
//! the MSB group; conveniently `XB_k` *is* index `k` — the label number
//! and the LSB-first index coincide — but rendered tables list `XB_3`
//! first while arrays print `[b0, b1, b2, b3]`. The paper's operating
//! point "1-bit MSB, 3-bit rest" is therefore written `[3, 3, 3, 1]`
//! ([`planner::PAPER_BITS`]) in array form. Report emitters
//! (`report::adc_table`, `report::plan_table`, `resolution_summary`)
//! always render MSB-first with explicit `XB_k` labels.
//!
//! # Evaluation-cache convention (prefix reuse, exact early abort)
//!
//! The planner's holdout scoring exploits a structural property of the
//! serving pipeline: activations are quantized **per row** (each layer's
//! input codes depend only on that row's upstream arithmetic, never on
//! the batch or on downstream layers), so two deployment plans that
//! agree on `adc_bits` for layers `0..j` produce **bit-identical**
//! layer-`j` inputs for every example. [`crate::serve::EvalCache`]
//! caches the incumbent plan's per-layer activations for the whole
//! holdout and scores a candidate by re-running only the suffix from
//! its first diverging layer
//! ([`crate::serve::CrossbarBackend::forward_from_layer`]); replica
//! counts are deliberately ignored by the divergence check because
//! sharded serving is bit-identical to unsharded (see the timing
//! section above). Scoring against an accuracy floor aborts the scan as
//! soon as `correct_so_far + examples_remaining < floor ×
//! examples_total` — a monotone bound, so the abort decision is exactly
//! the decision a full scan would reach, and cached search selects the
//! **identical plan** to uncached search by construction. Examples are
//! scanned hardest-first (ascending incumbent margin) so infeasible
//! candidates die early; the order only affects *when* the abort fires,
//! never the verdict. [`planner::SearchStats`] counts the work
//! (`layer_forwards`, `cache_hits`, `aborted_evals`) and the `search`
//! object in `plan.json` reports it.
//!
//! # Device-model convention (seeds, perturbation point, stuck-at zeros)
//!
//! A [`device::DeviceModel`] is one sampled realization of the
//! non-idealities in a [`device::DeviceConfig`] over a mapped model, and
//! every draw in it is a **pure function of physical coordinates** — no
//! sequential RNG stream ever spans two cells, tiles or examples, so the
//! realization cannot depend on storage layout, tile visit order or batch
//! composition. Per-cell streams are seeded by folding `(seed, layer,
//! slice group k, sign, tile row, tile col, row, col)` through a
//! SplitMix64 finalizer; the first uniform draw classifies stuck-at
//! faults (`u < rate/2` → stuck OFF at conductance 0, `u < rate` → stuck
//! ON at [`crossbar::CELL_MAX`]), and healthy cells read back `v *
//! exp(sigma * N(0,1))` (the lognormal `R_deviation` shape). Coordinates
//! are *physical* — post-reorder — so a reordered mapping is a different
//! device realization, but any fixed mapping perturbs identically across
//! all three storage layouts (cells are enumerated through the layout-
//! neutral row-major triples).
//!
//! The perturbation point is the bitline read: with a model attached,
//! [`sim`] routes every programmed tile through the device's
//! fractional-conductance accumulation (wave-gated sum of perturbed
//! conductances, plus per-conversion read noise seeded by `(tile, plane,
//! wave content, column)`), rounds to the nearest current LSB, and only
//! then applies the ADC clip — slices, signs and planes recombine
//! downstream exactly as in the ideal path. Detached, the integer path
//! runs untouched (zero overhead); attached with an all-zero config, the
//! float path reproduces the integer path bit-exactly (sums of exact
//! small integers, identity rounding).
//!
//! Stuck-at semantics for zero cells: a structurally-zero cell is never
//! fabricated, so it cannot fault or add noise — faults apply to
//! *programmed* cells only, an unprogrammed column is never sensed (read
//! noise covers only columns holding a programmed cell, mirroring the
//! active-column ADC skip), and the zero-wave / zero-tile skips remain
//! valid under noise because an undriven wordline and an unfabricated
//! tile contribute no current on any device.
//!
//! # Audit invariant catalogue (code → invariant → convention enforced)
//!
//! [`audit`] turns each convention above into a machine-checked invariant
//! with a stable diagnostic code. `Error`-severity findings mean the
//! deployment would execute incorrectly (or panic); serving construction
//! ([`serve::CrossbarBackend`](crate::serve::CrossbarBackend)) refuses
//! them, the mapper debug-asserts their absence after
//! [`mapper::map_model_with`], and the `audit` CLI subcommand / `deploy
//! --audit` flag reports them. The codes are stable — tests, CI and
//! downstream tooling key on the `A0xx` strings:
//!
//! * **A001 `CellValueOutOfRange`** — every stored cell value lies in
//!   `1..=CELL_MAX` (2-bit cells; zero cells are *absent*, not stored).
//!   Enforces the cell model of the storage-format section.
//! * **A002 `CensusMismatch`** — the cached programmed-cell census equals
//!   a recount over the raw store, and all three layouts round-trip to
//!   identical logical cells. Enforces the cached-census convention the
//!   O(1) zero-tile skips and the planner's scoring loop rely on.
//! * **A003 `CompressedIndexInconsistent`** — CSR row offsets are
//!   monotone and the entry/active-wordline/active-column indexes are
//!   sorted, deduped, in-bounds and exactly match the entries. Enforces
//!   the compressed layout of the storage-format section.
//! * **A004 `BitPlaneMaskMismatch`** — plane vectors are tile-shaped,
//!   padding rows `>= tile.rows()` are zero, and the nonzero-column index
//!   matches the masks. Enforces the BitPlanes packing convention.
//! * **A005 `PermutationNotBijective`** — each layer's wordline/column
//!   permutations are bijections whose cached inverse round-trips
//!   exactly. Enforces the reorder convention.
//! * **A006 `PlanShapeMismatch`** — the plan carries one row per mapped
//!   layer with sane replica counts (`<=` [`timing::MAX_REPLICAS`]).
//!   Enforces the plan/mapping pairing every cost and timing API asserts.
//! * **A007 `ResolutionOutOfBounds`** — every planned ADC resolution is
//!   priceable by [`adc::AdcModel`] (`>= 1` bit; `> 32` warns — the clip
//!   saturates there). Enforces the ADC cost-model domain.
//! * **A008 `ReplicaAliasBroken`** — replica handles `Arc::ptr_eq` their
//!   source layer (a replica is an alias, never a deep clone) and the
//!   fabricated-crossbar accounting matches [`energy`]'s static bill.
//!   Enforces the replication convention.
//! * **A009 `FormatBandDrift`** (warning) — each tile's storage layout is
//!   what the three-band density policy ([`crossbar::chosen_format`])
//!   would choose; explicit `with_storage` conversions legitimately trip
//!   this, mapper output never should. Enforces the format-selection
//!   policy.
//! * **A010 `TimingBillMismatch`** — each tile's converting-column count
//!   (the quantity [`energy`] bills and [`timing`] prices) equals an
//!   independent recount of columns holding conductance. Enforces the
//!   "cycle price = energy bill = executed work" identity of the timing
//!   convention.
//! * **A011 `ReplicaBudgetUnderflow`** — a positive `--replicate-budget`
//!   fabricates at least one replica; a budget below one bottleneck copy
//!   is a hard deploy error, not a silent no-replica plan.

pub mod adc;
pub mod audit;
pub mod crossbar;
pub mod device;
pub mod energy;
pub mod mapper;
pub mod planner;
pub mod reorder;
pub mod resolution;
pub mod sim;
pub mod timing;

pub use adc::{AdcModel, ResolutionError};
pub use audit::{AuditCode, AuditReport, AuditSummary, Diagnostic, Severity};
pub use crossbar::{pack_wave, Crossbar, StorageFormat, XBAR_COLS, XBAR_ROWS};
pub use device::{DeviceConfig, DeviceModel};
pub use mapper::{LayerMapping, MappedModel, StorageRow, StorageStats};
pub use planner::{DeploymentPlan, DescentStrategy, DeviceValidation, PlannerConfig};
pub use reorder::{LayerReorder, Permutation, ReorderConfig, ReorderRow};
pub use resolution::ResolutionPolicy;
pub use timing::{LayerTiming, PipelineTiming};

//! Per-layer ADC deployment planner.
//!
//! The paper finds its headline operating point — 1-bit ADCs on the MSB
//! crossbar group, 3-bit on the rest — by hand from a whole-model current
//! census. This module automates and refines that search *per layer*: each
//! layer's own column-current census ([`super::resolution`]) sets a
//! starting [`DeploymentPlan`], and a descent then lowers (layer,
//! slice-group) resolutions wherever held-out accuracy (the crossbar
//! simulator evaluated through `serve::accuracy` against the exact
//! quantized [`crate::serve::ReferenceBackend`] baseline) stays within a
//! configurable drop budget. Candidate moves are scored by their
//! [`super::energy`] saving, so the cheapest profitable reduction is
//! always tried first. The descent comes in two flavours
//! ([`DescentStrategy`]): the original one-bit-at-a-time greedy loop, and
//! the default per-group binary search that finds each group's lowest
//! budget-holding resolution in logarithmically many held-out
//! evaluations. The paper's hand-picked point ([`PAPER_BITS`]) serves as
//! a warm start: when it already holds the budget, the search jumps there
//! and can only improve on it.
//!
//! All bit arrays are LSB-first (see the bit-order convention in the
//! [`crate::reram`] module docs).

use anyhow::Result;

use crate::data::Dataset;
use crate::quant::N_SLICES;
use crate::serve::{self, CrossbarBackend, DenseLayer, ReferenceBackend};

use super::adc::AdcModel;
use super::energy;
use super::mapper::MappedModel;
use super::resolution::{self, ResolutionPolicy};

/// The paper's Table-3 operating point, LSB-first: 3-bit ADCs on
/// XB_0..XB_2, 1-bit on the MSB group XB_3.
pub const PAPER_BITS: [u32; N_SLICES] = [3, 3, 3, 1];

/// Per-slice ADC resolutions of one layer, LSB-first, plus the number of
/// fabricated copies of the layer's crossbars.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanLayer {
    pub name: String,
    pub adc_bits: [u32; N_SLICES],
    /// Fabricated copies of this layer (>= 1). Extra replicas buy pipeline
    /// throughput — the bottleneck stage advances `replicas` examples per
    /// latency — at `replicas` x the layer's area/static cost; per-example
    /// conversion energy is unchanged (each example still converts once).
    /// Chosen by [`crate::reram::timing::fill_replicas`] water-filling an
    /// area budget onto bottleneck layers; replicas share one set of
    /// tiles in simulation ([`super::mapper::MappedModel::replicated`]).
    pub replicas: usize,
}

/// Per-layer x per-slice ADC resolutions for a whole deployment — the
/// generalization of the single global `adc_bits: [u32; N_SLICES]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeploymentPlan {
    pub layers: Vec<PlanLayer>,
}

impl DeploymentPlan {
    /// Every layer at the same per-slice resolutions (the pre-planner
    /// whole-model semantics).
    pub fn uniform_for(model: &MappedModel, adc_bits: [u32; N_SLICES]) -> DeploymentPlan {
        DeploymentPlan {
            layers: model
                .layers
                .iter()
                .map(|l| PlanLayer {
                    name: l.name.clone(),
                    adc_bits,
                    replicas: 1,
                })
                .collect(),
        }
    }

    /// Each layer at the resolutions its own column-current census
    /// requires under `policy` — the planner's starting point.
    pub fn from_policy(model: &MappedModel, policy: ResolutionPolicy) -> DeploymentPlan {
        DeploymentPlan {
            layers: model
                .layers
                .iter()
                .map(|l| PlanLayer {
                    name: l.name.clone(),
                    adc_bits: resolution::layer_required_bits(l, policy),
                    replicas: 1,
                })
                .collect(),
        }
    }

    /// The shared per-slice resolutions if every layer agrees, else `None`.
    pub fn uniform_bits(&self) -> Option<[u32; N_SLICES]> {
        let first = self.layers.first()?.adc_bits;
        self.layers
            .iter()
            .all(|l| l.adc_bits == first)
            .then_some(first)
    }
}

impl std::fmt::Display for DeploymentPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, l) in self.layers.iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{}:{:?}", l.name, l.adc_bits)?;
            if l.replicas > 1 {
                write!(f, "x{}", l.replicas)?;
            }
        }
        Ok(())
    }
}

/// How the search descends (layer, slice-group) resolutions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DescentStrategy {
    /// Lower the best-gain group one bit at a time, re-scoring after
    /// every accepted move — evaluation count is linear in the total
    /// bits shed.
    Linear,
    /// Binary-search each group's lowest budget-holding resolution (best
    /// energy gain first, one group at a time, then freeze it) —
    /// logarithmically many held-out evaluations per group. Within one
    /// group feasibility is monotone in its own bits (fewer bits only
    /// clip more columns), so the search is exact there; it can differ
    /// from [`DescentStrategy::Linear`] only through cross-group
    /// interactions, and either way the selected plan is re-validated
    /// against the budget.
    Binary,
}

/// Planner search knobs.
#[derive(Debug, Clone, Copy)]
pub struct PlannerConfig {
    /// Held-out accuracy may drop at most this far below the exact
    /// quantized reference (fraction: 0.005 = 0.5 percentage points).
    pub accuracy_budget: f64,
    /// Floor for any slice-group resolution.
    pub min_bits: u32,
    /// Policy setting each layer's starting resolutions from its census.
    pub start_policy: ResolutionPolicy,
    /// Cap on held-out examples per candidate evaluation (0 = all).
    pub eval_examples: usize,
    /// Map-time wordline/column reordering for the planned deployment
    /// (`None` = natural order). [`plan_deployment`] maps the stack
    /// accordingly, so the census-derived starting plan and every
    /// candidate evaluation run on the reordered tiles and the selected
    /// resolutions size the ADCs the reordered layout actually
    /// fabricates. [`plan_deployment_from`] plans on the caller's
    /// already-mapped backend and *rejects* a config asking for
    /// reordering when that mapping is natural-order — silently sizing
    /// ADCs for the wrong per-tile current distribution is the failure
    /// mode this field exists to prevent.
    pub reorder: Option<super::reorder::ReorderConfig>,
    /// How each (layer, slice-group) resolution descends toward the
    /// budget floor (see [`DescentStrategy`]).
    pub descent: DescentStrategy,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            accuracy_budget: 0.005,
            min_bits: 1,
            start_policy: ResolutionPolicy::Lossless,
            eval_examples: 256,
            reorder: None,
            descent: DescentStrategy::Binary,
        }
    }
}

/// Everything one planner run produces.
#[derive(Debug, Clone)]
pub struct PlanSearch {
    /// the selected per-layer operating point
    pub plan: DeploymentPlan,
    /// accuracy of the exact quantized reference on the validation slice
    /// (the unseen holdout tail when the search subsampled, else the full
    /// holdout)
    pub baseline_accuracy: f64,
    /// accuracy at the starting (census-derived) plan, measured on the
    /// search's eval subsample
    pub start_accuracy: f64,
    /// accuracy at the selected plan on the validation slice
    pub accuracy: f64,
    /// cost of the selected plan
    pub cost: energy::DeploymentCost,
    /// cost of the uniform 8-bit ISAAC baseline on the same mapping
    pub baseline_cost: energy::DeploymentCost,
    /// candidate accuracy evaluations spent by the search
    pub evaluations: usize,
    /// whether the selected plan holds the accuracy budget on the
    /// validation slice. Can be false even with a lossless
    /// `start_policy`: a lossy start can put the *starting* plan below
    /// the floor, and when `eval_examples` subsamples the holdout, moves
    /// accepted on the search slice can re-measure below the floor on the
    /// unseen tail. The search returns its best plan and flags it here
    /// instead of failing silently.
    pub within_budget: bool,
}

impl PlanSearch {
    /// (energy, time, area) savings of the selected plan vs the 8-bit
    /// baseline.
    pub fn savings(&self) -> (f64, f64, f64) {
        (
            energy::ratio(self.baseline_cost.energy, self.cost.energy),
            energy::ratio(self.baseline_cost.time, self.cost.time),
            energy::ratio(self.baseline_cost.area, self.cost.area),
        )
    }
}

/// Examples `lo..hi` of a dataset.
fn slice(ds: &Dataset, lo: usize, hi: usize) -> Dataset {
    let d = ds.dim();
    Dataset {
        features: std::sync::Arc::new(ds.features[lo * d..hi * d].to_vec()),
        labels: std::sync::Arc::new(ds.labels[lo..hi].to_vec()),
        example_shape: ds.example_shape.clone(),
        num_classes: ds.num_classes,
        source: format!("{}[{lo}..{hi}]", ds.source),
    }
}

/// First `n` examples of a dataset (0 = all) — the planner's evaluation
/// subsample.
fn head(ds: &Dataset, n: usize) -> Dataset {
    if n == 0 || n >= ds.len() {
        ds.clone()
    } else {
        slice(ds, 0, n)
    }
}

/// Smallest value in `[lo, hi]` accepted by `feasible`, assuming
/// feasibility is monotone over the range (everything at or above the
/// answer holds, everything below fails) and that `feasible(hi)` is
/// already known to hold — `hi` itself is never probed. Probes
/// `ceil(log2(hi - lo + 1))` values, the [`DescentStrategy::Binary`]
/// evaluation bound.
fn lowest_feasible(
    lo: u32,
    hi: u32,
    mut feasible: impl FnMut(u32) -> Result<bool>,
) -> Result<u32> {
    let (mut lo, mut hi) = (lo, hi);
    while lo < hi {
        let mid = (lo + hi) / 2;
        if feasible(mid)? {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Ok(hi)
}

/// Search a per-layer ADC deployment plan for `stack` under `cfg`,
/// validating every candidate on `holdout`. Maps the stack once — in
/// reordered layout when `cfg.reorder` asks for it — quantizes the
/// reference once, then delegates to [`plan_deployment_from`].
pub fn plan_deployment(
    stack: &[DenseLayer],
    holdout: &Dataset,
    cfg: &PlannerConfig,
) -> Result<PlanSearch> {
    let base = match cfg.reorder {
        Some(rc) => {
            CrossbarBackend::with_layer_policy_reordered("planner", stack, cfg.start_policy, rc)?
        }
        None => CrossbarBackend::with_layer_policy("planner", stack, cfg.start_policy)?,
    };
    let reference = ReferenceBackend::new("planner-reference", stack)?;
    // the reorder pass may normalize to the identity on every layer (tiny
    // or already-clustered stacks) — then the natural mapping *is* the
    // reordered one, and the consistency guard below must not fire
    let mut cfg = *cfg;
    if !base.is_reordered() {
        cfg.reorder = None;
    }
    plan_deployment_from(&base, &reference, holdout, &cfg)
}

/// Search starting from an already-mapped backend and reference — callers
/// that hold both (e.g. the deploy CLI path) reuse their mapping and
/// quantized weights instead of re-mapping the stack. The starting plan is
/// `cfg.start_policy` applied per layer to `base`'s mapping; `base`'s own
/// plan is irrelevant.
///
/// The mapping is shared across every candidate through
/// [`CrossbarBackend::replan`] (`Arc`-shared tiles), so the search
/// re-maps zero times. When `cfg.eval_examples` subsamples `holdout`, the
/// search selects on the head slice and the reported
/// `baseline_accuracy`/`accuracy`/`within_budget` are re-measured on the
/// *unseen tail* (falling back to the full holdout when the tail is too
/// small to be meaningful), so the headline numbers are not
/// selection-biased.
pub fn plan_deployment_from(
    base: &CrossbarBackend,
    reference: &ReferenceBackend,
    holdout: &Dataset,
    cfg: &PlannerConfig,
) -> Result<PlanSearch> {
    anyhow::ensure!(!holdout.is_empty(), "planner needs a non-empty held-out set");
    anyhow::ensure!(cfg.min_bits >= 1, "ADC resolutions start at 1 bit");
    anyhow::ensure!(
        cfg.reorder.is_none() || base.is_reordered(),
        "cfg.reorder asks for a reordered deployment but the supplied mapping is \
         natural-order — map the backend with reordering (or use plan_deployment)"
    );
    let ds = head(holdout, cfg.eval_examples);

    let base = base.replan(
        "planner",
        DeploymentPlan::from_policy(base.mapped(), cfg.start_policy),
    )?;
    let model = base.mapped().clone();
    let baseline_accuracy = serve::accuracy(reference, &ds)?.accuracy;
    let start_accuracy = serve::accuracy(&base, &ds)?.accuracy;
    let floor = baseline_accuracy - cfg.accuracy_budget;

    let mut plan = base.plan().clone();
    let mut accuracy = start_accuracy;
    let mut evaluations = 0usize;

    // candidate-move weights: conversions per (layer, slice group); the
    // tally reads the cached per-tile census, so scoring is O(tiles)
    let conversions: Vec<[f64; N_SLICES]> = model
        .layers
        .iter()
        .map(|l| std::array::from_fn(|k| energy::slice_conversions(l, k)))
        .collect();

    let eval = |cand: &DeploymentPlan, evaluations: &mut usize| -> Result<f64> {
        let be = base.replan("planner-candidate", cand.clone())?;
        *evaluations += 1;
        Ok(serve::accuracy(&be, &ds)?.accuracy)
    };

    // Paper warm start: the hand-picked Table-3 point, clipped into
    // [min_bits, start bits] per group. If it holds the budget, jump —
    // the greedy descent below can only improve on it.
    let mut warm = plan.clone();
    for l in &mut warm.layers {
        for (k, b) in l.adc_bits.iter_mut().enumerate() {
            *b = (*b).min(PAPER_BITS[k].max(cfg.min_bits));
        }
    }
    if warm != plan {
        let a = eval(&warm, &mut evaluations)?;
        if a >= floor {
            plan = warm;
            accuracy = a;
        }
    }

    // Moves are scored by the energy a one-bit reduction buys at the
    // group's current resolution; higher gain descends first.
    let score = |plan: &DeploymentPlan, frozen: &[[bool; N_SLICES]]| {
        let mut moves: Vec<(f64, usize, usize)> = Vec::new();
        for (l, pl) in plan.layers.iter().enumerate() {
            for k in 0..N_SLICES {
                let b = pl.adc_bits[k];
                if frozen[l][k] || b <= cfg.min_bits {
                    continue;
                }
                let gain = conversions[l][k] * (AdcModel::power(b) - AdcModel::power(b - 1));
                moves.push((gain, l, k));
            }
        }
        moves.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
        moves
    };

    match cfg.descent {
        // Greedy descent: repeatedly try to lower one (layer, slice
        // group) by one bit, best energy saving first. A group that fails
        // the budget is frozen — lowering *other* groups never makes it
        // more affordable.
        DescentStrategy::Linear => {
            let mut frozen = vec![[false; N_SLICES]; plan.layers.len()];
            loop {
                let moves = score(&plan, &frozen);
                let mut progressed = false;
                for &(_, l, k) in &moves {
                    let mut cand = plan.clone();
                    cand.layers[l].adc_bits[k] -= 1;
                    let a = eval(&cand, &mut evaluations)?;
                    if a >= floor {
                        plan = cand;
                        accuracy = a;
                        progressed = true;
                        break; // re-score remaining moves against the new plan
                    }
                    frozen[l][k] = true;
                }
                if !progressed {
                    break;
                }
            }
        }
        // Per-group binary search, best energy gain first. A group's gain
        // depends only on its *own* current bits, so fully descending one
        // group never re-orders the remaining ones — a single sorted pass
        // visits the same groups the greedy loop would.
        DescentStrategy::Binary => {
            let frozen = vec![[false; N_SLICES]; plan.layers.len()];
            for &(_, l, k) in &score(&plan, &frozen) {
                let b = plan.layers[l].adc_bits[k];
                // accuracies of the feasible probes, so the accepted
                // resolution's accuracy needs no re-evaluation
                let mut probed: Vec<(u32, f64)> = Vec::new();
                let best = lowest_feasible(cfg.min_bits, b, |v| {
                    let mut cand = plan.clone();
                    cand.layers[l].adc_bits[k] = v;
                    let a = eval(&cand, &mut evaluations)?;
                    let ok = a >= floor;
                    if ok {
                        probed.push((v, a));
                    }
                    Ok(ok)
                })?;
                if best < b {
                    plan.layers[l].adc_bits[k] = best;
                    accuracy = probed
                        .iter()
                        .find(|&&(v, _)| v == best)
                        .expect("accepted resolution was probed feasible")
                        .1;
                }
            }
        }
    }

    // Final validation: the greedy loop selects on the (possibly
    // subsampled) eval set, so a plan can overfit its accept/reject
    // margins to those exact examples. When a subsample was used,
    // re-measure the selected plan and the reference on the *unseen tail*
    // of the holdout — unless the tail is a statistically meaningless
    // sliver (fewer than 32 examples or under a quarter of the holdout),
    // in which case the full holdout is the stabler validation set even
    // though it includes the search slice.
    let (baseline_accuracy, accuracy) = if ds.len() == holdout.len() {
        (baseline_accuracy, accuracy)
    } else {
        let tail_len = holdout.len() - ds.len();
        let val = if tail_len >= 32 && tail_len * 4 >= holdout.len() {
            slice(holdout, ds.len(), holdout.len())
        } else {
            holdout.clone()
        };
        let selected = base.replan("planner-selected", plan.clone())?;
        evaluations += 1;
        (
            serve::accuracy(reference, &val)?.accuracy,
            serve::accuracy(&selected, &val)?.accuracy,
        )
    };

    let cost = energy::plan_cost(&model, &plan);
    let baseline_cost = energy::deployment_cost(&model, [super::adc::BASELINE_BITS; N_SLICES]);
    Ok(PlanSearch {
        plan,
        baseline_accuracy,
        start_accuracy,
        accuracy,
        cost,
        baseline_cost,
        evaluations,
        within_budget: accuracy >= baseline_accuracy - cfg.accuracy_budget,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::mapper::map_model;
    use crate::serve::{dense_stack, InferenceBackend};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn toy_stack(rng: &mut Rng) -> Vec<DenseLayer> {
        let w1 = Tensor::new(vec![8, 5], rng.normal_vec(40, 0.2)).unwrap();
        let w2 = Tensor::new(vec![5, 3], rng.normal_vec(15, 0.2)).unwrap();
        let b1 = Tensor::zeros(vec![5]);
        let b2 = Tensor::zeros(vec![3]);
        dense_stack(&[("fc1/w".into(), w1), ("fc2/w".into(), w2)], &[b1, b2]).unwrap()
    }

    /// Held-out set labelled by the exact reference's own argmax, so the
    /// baseline accuracy is 1.0 by construction and the budget measures
    /// pure ADC-clipping disagreement.
    fn oracle_dataset(stack: &[DenseLayer], n: usize, seed: u64) -> Dataset {
        let dim = stack[0].w.shape()[0];
        let classes = stack[stack.len() - 1].w.shape()[1];
        let mut rng = Rng::new(seed);
        let feats: Vec<f32> = (0..n * dim).map(|_| rng.next_f32()).collect();
        let x = Tensor::new(vec![n, dim], feats.clone()).unwrap();
        let reference = ReferenceBackend::new("oracle", stack).unwrap();
        let logits = reference.infer_batch(&x).unwrap();
        let labels: Vec<i32> = (0..n)
            .map(|i| {
                let row = &logits.data()[i * classes..(i + 1) * classes];
                (0..classes)
                    .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
                    .unwrap() as i32
            })
            .collect();
        Dataset {
            features: std::sync::Arc::new(feats),
            labels: std::sync::Arc::new(labels),
            example_shape: vec![dim],
            num_classes: classes,
            source: "oracle".into(),
        }
    }

    #[test]
    fn uniform_plan_reports_uniform_bits() {
        let mut rng = Rng::new(3);
        let w = Tensor::new(vec![20, 9], rng.normal_vec(180, 0.1)).unwrap();
        let m = map_model(&[("a".into(), w.clone()), ("b".into(), w)]).unwrap();
        let plan = DeploymentPlan::uniform_for(&m, [3, 3, 3, 1]);
        assert_eq!(plan.uniform_bits(), Some([3, 3, 3, 1]));
        let mut uneven = plan.clone();
        uneven.layers[1].adc_bits = [2, 2, 2, 1];
        assert_eq!(uneven.uniform_bits(), None);
        let shown = format!("{uneven}");
        assert!(shown.contains("a:[3, 3, 3, 1]"), "{shown}");
        assert!(shown.contains("b:[2, 2, 2, 1]"), "{shown}");
    }

    #[test]
    fn from_policy_uses_each_layers_own_census() {
        // layer "dense" needs many MSB bits, layer "tiny" needs few — a
        // whole-model census would force the max onto both
        let mut rng = Rng::new(5);
        let dense = Tensor::new(
            vec![128, 16],
            (0..128 * 16)
                .map(|_| if rng.next_f32() > 0.5 { 0.99 } else { -0.99 })
                .collect(),
        )
        .unwrap();
        let mut data = vec![0.0f32; 64 * 8];
        data[0] = 1.0;
        let tiny = Tensor::new(vec![64, 8], data).unwrap();
        let m = map_model(&[("dense".into(), dense), ("tiny".into(), tiny)]).unwrap();
        let plan = DeploymentPlan::from_policy(&m, ResolutionPolicy::Lossless);
        assert!(
            plan.layers[0].adc_bits[3] > plan.layers[1].adc_bits[3],
            "dense {:?} vs tiny {:?}",
            plan.layers[0].adc_bits,
            plan.layers[1].adc_bits
        );
        let global = resolution::required_bits(&m, ResolutionPolicy::Lossless);
        assert_eq!(plan.layers[0].adc_bits[3], global[3]);
    }

    #[test]
    fn unlimited_budget_collapses_to_min_bits() {
        let mut rng = Rng::new(11);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 12, 77);
        let cfg = PlannerConfig {
            accuracy_budget: 1.0,
            ..PlannerConfig::default()
        };
        let res = plan_deployment(&stack, &ds, &cfg).unwrap();
        assert_eq!(res.plan.uniform_bits(), Some([1, 1, 1, 1]));
        assert!(res.evaluations > 0);
        assert!(res.cost.energy < res.baseline_cost.energy);
        let (e, t, a) = res.savings();
        assert!(e > 1.0 && t > 1.0 && a > 1.0);
    }

    #[test]
    fn search_respects_budget_and_never_raises_bits() {
        let mut rng = Rng::new(13);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 24, 99);
        let cfg = PlannerConfig::default(); // 0.5 pt budget, lossless start
        let res = plan_deployment(&stack, &ds, &cfg).unwrap();
        assert!((res.baseline_accuracy - 1.0).abs() < 1e-12, "oracle labels");
        assert!(
            res.accuracy >= res.baseline_accuracy - cfg.accuracy_budget - 1e-12,
            "accuracy {} vs baseline {}",
            res.accuracy,
            res.baseline_accuracy
        );
        let start = DeploymentPlan::from_policy(
            &map_model(&[
                ("fc1/w".into(), stack[0].w.clone()),
                ("fc2/w".into(), stack[1].w.clone()),
            ])
            .unwrap(),
            cfg.start_policy,
        );
        for (sel, st) in res.plan.layers.iter().zip(&start.layers) {
            for k in 0..N_SLICES {
                assert!(sel.adc_bits[k] <= st.adc_bits[k], "{:?}", sel);
                assert!(sel.adc_bits[k] >= cfg.min_bits);
            }
        }
        // lossless start agrees with the exact reference bit-for-bit
        assert_eq!(res.start_accuracy, res.baseline_accuracy);
        // no subsampling in this test, so the lossless start guarantees it
        assert!(res.within_budget);
    }

    #[test]
    fn zero_budget_keeps_exact_agreement() {
        let mut rng = Rng::new(17);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 16, 5);
        let cfg = PlannerConfig {
            accuracy_budget: 0.0,
            ..PlannerConfig::default()
        };
        let res = plan_deployment(&stack, &ds, &cfg).unwrap();
        assert_eq!(res.accuracy, res.baseline_accuracy);
    }

    /// The planner's census and search run on reordered tiles when asked:
    /// a lossless start on the reordered mapping still agrees exactly
    /// with the reference at zero budget, and the selected plan never
    /// exceeds the reordered layout's own starting bits.
    #[test]
    fn reordered_planner_search_stays_exact_at_zero_budget() {
        use crate::reram::reorder::ReorderConfig;
        let mut rng = Rng::new(19);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 16, 7);
        let cfg = PlannerConfig {
            accuracy_budget: 0.0,
            reorder: Some(ReorderConfig::default()),
            ..PlannerConfig::default()
        };
        let res = plan_deployment(&stack, &ds, &cfg).unwrap();
        assert_eq!(res.accuracy, res.baseline_accuracy);
        assert!(res.within_budget);
    }

    #[test]
    fn lowest_feasible_is_exact_and_logarithmic() {
        // cliff at 6 within [1, 9]: found in at most ceil(log2(9)) probes
        let mut probes = 0usize;
        let v = lowest_feasible(1, 9, |v| {
            probes += 1;
            Ok(v >= 6)
        })
        .unwrap();
        assert_eq!(v, 6);
        assert!(probes <= 4, "{probes} probes");
        // nothing below hi feasible: stays at the known-good hi
        let mut probes = 0usize;
        let v = lowest_feasible(1, 9, |v| {
            probes += 1;
            Ok(v >= 9)
        })
        .unwrap();
        assert_eq!(v, 9);
        assert!(probes <= 4, "{probes} probes");
        // everything feasible: collapses to lo; degenerate range: 0 probes
        assert_eq!(lowest_feasible(1, 9, |_| Ok(true)).unwrap(), 1);
        assert_eq!(lowest_feasible(3, 3, |_| panic!("no probe")).unwrap(), 3);
    }

    /// Satellite: on the planted class-template fixture (the planner
    /// bench's model, bit-slice sparse by construction) the binary
    /// descent selects exactly the plan the linear descent selects,
    /// without spending more held-out evaluations.
    #[test]
    fn binary_descent_matches_linear_on_planted_fixture() {
        use crate::data::synthetic;
        use crate::util::fixtures;
        let train = synthetic::mnist(600, 11);
        let holdout = synthetic::mnist(160, 12);
        let stack = fixtures::planted_class_stack(&train);
        let run = |descent| {
            let cfg = PlannerConfig {
                eval_examples: 0, // search on the full holdout
                descent,
                ..PlannerConfig::default()
            };
            plan_deployment(&stack, &holdout, &cfg).unwrap()
        };
        let linear = run(DescentStrategy::Linear);
        let binary = run(DescentStrategy::Binary);
        assert_eq!(binary.plan, linear.plan, "descent strategies diverged");
        assert!(
            binary.evaluations <= linear.evaluations,
            "binary spent {} evaluations, linear {}",
            binary.evaluations,
            linear.evaluations
        );
        assert!(binary.within_budget && linear.within_budget);
    }
}

//! Per-layer ADC deployment planner.
//!
//! The paper finds its headline operating point — 1-bit ADCs on the MSB
//! crossbar group, 3-bit on the rest — by hand from a whole-model current
//! census. This module automates and refines that search *per layer*: each
//! layer's own column-current census ([`super::resolution`]) sets a
//! starting [`DeploymentPlan`], and a descent then lowers (layer,
//! slice-group) resolutions wherever held-out accuracy (the crossbar
//! simulator evaluated through `serve::accuracy` against the exact
//! quantized [`crate::serve::ReferenceBackend`] baseline) stays within a
//! configurable drop budget. Candidate moves are scored by their
//! [`super::energy`] saving, so the cheapest profitable reduction is
//! always tried first. The descent comes in two flavours
//! ([`DescentStrategy`]): the original one-bit-at-a-time greedy loop, and
//! the default per-group binary search that finds each group's lowest
//! budget-holding resolution in logarithmically many held-out
//! evaluations. The paper's hand-picked point ([`PAPER_BITS`]) serves as
//! a warm start: when it already holds the budget, the search jumps there
//! and can only improve on it.
//!
//! Two machineries keep the search cheap and let it co-plan replication.
//! Candidate evaluation is *incremental* by default
//! ([`PlannerConfig::incremental`]): a [`crate::serve::EvalCache`] holds
//! the incumbent plan's per-layer holdout activations, so a candidate
//! whose resolutions first diverge at layer `j` re-runs only layers
//! `j..`, and holdout scoring walks the hardest examples first so a
//! candidate that provably cannot reach the accuracy floor aborts early —
//! the selected plan is bit-identical to the uncached search, only the
//! crossbar forwards spent change ([`SearchStats`] records both). And an
//! optional *joint* pass ([`PlannerConfig::replicate_budget`]) trades ADC
//! bits against pipeline replicas under one fabrication budget instead of
//! water-filling replicas only after the bits are fixed.
//!
//! All bit arrays are LSB-first (see the bit-order convention in the
//! [`crate::reram`] module docs).

use std::sync::Arc;

use anyhow::Result;

use crate::data::Dataset;
use crate::quant::N_SLICES;
use crate::serve::{self, CrossbarBackend, DenseLayer, EvalCache, ReferenceBackend};

use super::adc::AdcModel;
use super::device::{DeviceConfig, DeviceModel};
use super::energy;
use super::mapper::MappedModel;
use super::resolution::{self, ResolutionPolicy};
use super::timing;

/// The paper's Table-3 operating point, LSB-first: 3-bit ADCs on
/// XB_0..XB_2, 1-bit on the MSB group XB_3.
pub const PAPER_BITS: [u32; N_SLICES] = [3, 3, 3, 1];

/// Per-slice ADC resolutions of one layer, LSB-first, plus the number of
/// fabricated copies of the layer's crossbars.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanLayer {
    pub name: String,
    pub adc_bits: [u32; N_SLICES],
    /// Fabricated copies of this layer (>= 1). Extra replicas buy pipeline
    /// throughput — the bottleneck stage advances `replicas` examples per
    /// latency — at `replicas` x the layer's area/static cost; per-example
    /// conversion energy is unchanged (each example still converts once).
    /// Chosen by [`crate::reram::timing::fill_replicas`] water-filling an
    /// area budget onto bottleneck layers; replicas share one set of
    /// tiles in simulation ([`super::mapper::MappedModel::replicated`]).
    pub replicas: usize,
}

/// Per-layer x per-slice ADC resolutions for a whole deployment — the
/// generalization of the single global `adc_bits: [u32; N_SLICES]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeploymentPlan {
    pub layers: Vec<PlanLayer>,
}

impl DeploymentPlan {
    /// Every layer at the same per-slice resolutions (the pre-planner
    /// whole-model semantics).
    pub fn uniform_for(model: &MappedModel, adc_bits: [u32; N_SLICES]) -> DeploymentPlan {
        DeploymentPlan {
            layers: model
                .layers
                .iter()
                .map(|l| PlanLayer {
                    name: l.name.clone(),
                    adc_bits,
                    replicas: 1,
                })
                .collect(),
        }
    }

    /// Each layer at the resolutions its own column-current census
    /// requires under `policy` — the planner's starting point.
    pub fn from_policy(model: &MappedModel, policy: ResolutionPolicy) -> DeploymentPlan {
        DeploymentPlan {
            layers: model
                .layers
                .iter()
                .map(|l| PlanLayer {
                    name: l.name.clone(),
                    adc_bits: resolution::layer_required_bits(l, policy),
                    replicas: 1,
                })
                .collect(),
        }
    }

    /// The shared per-slice resolutions if every layer agrees, else `None`.
    pub fn uniform_bits(&self) -> Option<[u32; N_SLICES]> {
        let first = self.layers.first()?.adc_bits;
        self.layers
            .iter()
            .all(|l| l.adc_bits == first)
            .then_some(first)
    }
}

impl std::fmt::Display for DeploymentPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, l) in self.layers.iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{}:{:?}", l.name, l.adc_bits)?;
            if l.replicas > 1 {
                write!(f, "x{}", l.replicas)?;
            }
        }
        Ok(())
    }
}

/// How the search descends (layer, slice-group) resolutions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DescentStrategy {
    /// Lower the best-gain group one bit at a time, re-scoring after
    /// every accepted move — evaluation count is linear in the total
    /// bits shed.
    Linear,
    /// Binary-search each group's lowest budget-holding resolution (best
    /// energy gain first, one group at a time, then freeze it) —
    /// logarithmically many held-out evaluations per group. Within one
    /// group feasibility is monotone in its own bits (fewer bits only
    /// clip more columns), so the search is exact there; it can differ
    /// from [`DescentStrategy::Linear`] only through cross-group
    /// interactions, and either way the selected plan is re-validated
    /// against the budget.
    Binary,
}

/// Instrumentation counters for one planner run — the evidence that the
/// incremental machinery actually saved work.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// candidate accuracy evaluations spent by the search, plus the two
    /// full re-measures (reference and selected plan) of the final
    /// validation when a holdout subsample forces one
    pub evaluations: usize,
    /// (example, layer) crossbar forwards actually executed: the start
    /// plan's full pass, every candidate's re-run tail, every Monte-Carlo
    /// trial pass, and the selected plan's final validation pass
    pub layer_forwards: usize,
    /// (example, layer) forwards *avoided* by reusing cached prefix
    /// activations (zero when [`PlannerConfig::incremental`] is off)
    pub cache_hits: usize,
    /// candidate evaluations cut short because even a perfect remaining
    /// tail could not lift them to the accuracy floor
    pub aborted_evals: usize,
    /// candidates that held the floor on the ideal device but failed the
    /// Monte-Carlo quantile gate ([`PlannerConfig::device`]) — the plans
    /// that only work on hardware that does not exist
    pub noise_rejections: usize,
}

/// Monte-Carlo noise gate for candidate plans ([`PlannerConfig::device`]):
/// a candidate that holds the accuracy floor on the ideal simulator must
/// also hold it on at least `ceil(quantile * trials)` of `trials` seeded
/// device realizations ([`DeviceConfig::trial`]) before the search may
/// accept it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceValidation {
    /// non-ideality knobs; `config.seed` roots the per-trial seeds. An
    /// ideal config (all-zero knobs) disables the gate — there is nothing
    /// to validate against.
    pub config: DeviceConfig,
    /// seeded realizations each ideal-feasible candidate faces
    pub trials: usize,
    /// fraction of trials that must hold the floor; the requirement is
    /// `ceil(quantile * trials)` clamped into `[1, trials]`, so 1.0 =
    /// every trial, 0.5 = the median realization
    pub quantile: f64,
}

impl Default for DeviceValidation {
    fn default() -> Self {
        DeviceValidation {
            config: DeviceConfig::default(),
            trials: 8,
            quantile: 0.75,
        }
    }
}

impl DeviceValidation {
    /// Trials that must pass: `ceil(quantile * trials)` in `[1, trials]`.
    pub fn required_passes(&self) -> usize {
        ((self.quantile * self.trials as f64).ceil() as usize).clamp(1, self.trials.max(1))
    }
}

/// Planner search knobs.
#[derive(Debug, Clone, Copy)]
pub struct PlannerConfig {
    /// Held-out accuracy may drop at most this far below the exact
    /// quantized reference (fraction: 0.005 = 0.5 percentage points).
    pub accuracy_budget: f64,
    /// Floor for any slice-group resolution.
    pub min_bits: u32,
    /// Policy setting each layer's starting resolutions from its census.
    pub start_policy: ResolutionPolicy,
    /// Cap on held-out examples per candidate evaluation (0 = all).
    pub eval_examples: usize,
    /// Map-time wordline/column reordering for the planned deployment
    /// (`None` = natural order). [`plan_deployment`] maps the stack
    /// accordingly, so the census-derived starting plan and every
    /// candidate evaluation run on the reordered tiles and the selected
    /// resolutions size the ADCs the reordered layout actually
    /// fabricates. [`plan_deployment_from`] plans on the caller's
    /// already-mapped backend and *rejects* a config asking for
    /// reordering when that mapping is natural-order — silently sizing
    /// ADCs for the wrong per-tile current distribution is the failure
    /// mode this field exists to prevent.
    pub reorder: Option<super::reorder::ReorderConfig>,
    /// How each (layer, slice-group) resolution descends toward the
    /// budget floor (see [`DescentStrategy`]).
    pub descent: DescentStrategy,
    /// Evaluate candidates through the incremental
    /// [`crate::serve::EvalCache`]: layers upstream of a candidate's
    /// first diverging resolution reuse the incumbent's cached boundary
    /// activations, and holdout scoring aborts early against the
    /// accuracy floor. Selections are bit-identical either way (see the
    /// evaluation-cache convention in [`crate::reram`]); the switch
    /// exists to measure the saving and as an escape hatch.
    pub incremental: bool,
    /// Joint ADC/replica co-optimization: `Some(factor)` grants the
    /// search a replica cell budget of `factor` x the *starting* plan's
    /// bottleneck-layer cells
    /// ([`crate::reram::timing::factor_budget_cells`]), one shared anchor
    /// for every caller, so joint and sequential runs stay comparable.
    /// The search first descends the post-replication bottleneck's
    /// slowest slice groups (throughput-first), then runs the energy
    /// descent, and finally spends the budget on the selected
    /// resolutions; [`PlanSearch::replica_cells`] records the spend.
    /// `None` keeps bits-then-replicas strictly sequential (and spends
    /// nothing).
    pub replicate_budget: Option<f64>,
    /// Monte-Carlo noise validation ([`DeviceValidation`]): every
    /// candidate that holds the floor on the ideal simulator is re-scored
    /// on `trials` seeded device realizations and rejected unless the
    /// floor holds at the configured quantile — so the search cannot
    /// select a plan that only survives on perfect devices. The ideal
    /// evaluation still runs first (through the incremental
    /// [`crate::serve::EvalCache`] when enabled), pruning most candidates
    /// before any noisy pass is spent. `None` = ideal-only validation,
    /// the pre-device behaviour.
    pub device: Option<DeviceValidation>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            accuracy_budget: 0.005,
            min_bits: 1,
            start_policy: ResolutionPolicy::Lossless,
            eval_examples: 256,
            reorder: None,
            descent: DescentStrategy::Binary,
            incremental: true,
            replicate_budget: None,
            device: None,
        }
    }
}

/// Everything one planner run produces.
#[derive(Debug, Clone)]
pub struct PlanSearch {
    /// the selected per-layer operating point
    pub plan: DeploymentPlan,
    /// accuracy of the exact quantized reference on the validation slice
    /// (the unseen holdout tail when the search subsampled, else the full
    /// holdout)
    pub baseline_accuracy: f64,
    /// accuracy at the starting (census-derived) plan, measured on the
    /// search's eval subsample
    pub start_accuracy: f64,
    /// accuracy at the selected plan on the validation slice
    pub accuracy: f64,
    /// cost of the selected plan
    pub cost: energy::DeploymentCost,
    /// cost of the uniform 8-bit ISAAC baseline on the same mapping
    pub baseline_cost: energy::DeploymentCost,
    /// what the search spent: evaluations, crossbar layer forwards,
    /// prefix-cache hits, early-aborted evaluations
    pub stats: SearchStats,
    /// replica cells spent by the joint pass
    /// ([`PlannerConfig::replicate_budget`]); 0 when no budget was
    /// granted
    pub replica_cells: usize,
    /// whether the selected plan holds the accuracy budget on the
    /// validation slice. Can be false even with a lossless
    /// `start_policy`: a lossy start can put the *starting* plan below
    /// the floor, and when `eval_examples` subsamples the holdout, moves
    /// accepted on the search slice can re-measure below the floor on the
    /// unseen tail. The search returns its best plan and flags it here
    /// instead of failing silently.
    pub within_budget: bool,
}

impl PlanSearch {
    /// (energy, time, area) savings of the selected plan vs the 8-bit
    /// baseline.
    pub fn savings(&self) -> (f64, f64, f64) {
        (
            energy::ratio(self.baseline_cost.energy, self.cost.energy),
            energy::ratio(self.baseline_cost.time, self.cost.time),
            energy::ratio(self.baseline_cost.area, self.cost.area),
        )
    }
}

/// Examples `lo..hi` of a dataset.
fn slice(ds: &Dataset, lo: usize, hi: usize) -> Dataset {
    let d = ds.dim();
    Dataset {
        features: std::sync::Arc::new(ds.features[lo * d..hi * d].to_vec()),
        labels: std::sync::Arc::new(ds.labels[lo..hi].to_vec()),
        example_shape: ds.example_shape.clone(),
        num_classes: ds.num_classes,
        source: format!("{}[{lo}..{hi}]", ds.source),
    }
}

/// First `n` examples of a dataset (0 = all) — the planner's evaluation
/// subsample.
fn head(ds: &Dataset, n: usize) -> Dataset {
    if n == 0 || n >= ds.len() {
        ds.clone()
    } else {
        slice(ds, 0, n)
    }
}

/// Smallest value in `[lo, hi]` accepted by `feasible`, assuming
/// feasibility is monotone over the range (everything at or above the
/// answer holds, everything below fails) and that `feasible(hi)` is
/// already known to hold — `hi` itself is never probed. Probes
/// `ceil(log2(hi - lo + 1))` values, the [`DescentStrategy::Binary`]
/// evaluation bound.
fn lowest_feasible(
    lo: u32,
    hi: u32,
    mut feasible: impl FnMut(u32) -> Result<bool>,
) -> Result<u32> {
    let (mut lo, mut hi) = (lo, hi);
    while lo < hi {
        let mid = (lo + hi) / 2;
        if feasible(mid)? {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Ok(hi)
}

/// Candidate scorer shared by every search phase: either the incremental
/// [`EvalCache`] (prefix layers reused, hardest examples first, early
/// abort against the floor) or the plain replan-and-measure path. Both
/// produce bit-identical accuracies and accept/reject verdicts, so the
/// selected plan does not depend on [`PlannerConfig::incremental`] —
/// only [`SearchStats::layer_forwards`] does.
struct Evaluator<'a> {
    base: &'a CrossbarBackend,
    ds: &'a Dataset,
    cache: Option<EvalCache>,
    layers: usize,
    /// one device-attached backend per Monte-Carlo trial, sharing the
    /// base mapping; empty = no noise gate
    noisy: Vec<CrossbarBackend>,
    /// trials that must hold the floor ([`DeviceValidation::required_passes`])
    required: usize,
    stats: SearchStats,
}

impl<'a> Evaluator<'a> {
    fn new(
        base: &'a CrossbarBackend,
        ds: &'a Dataset,
        incremental: bool,
        device: Option<DeviceValidation>,
    ) -> Result<Evaluator<'a>> {
        let mut stats = SearchStats::default();
        let cache = if incremental {
            Some(EvalCache::new(base, ds, &mut stats)?)
        } else {
            None
        };
        // each trial's realization is built once (per-cell sampling over
        // the whole mapping) and Arc-shared across every candidate replan
        let noisy = match device {
            Some(v) if v.trials > 0 && !v.config.is_ideal() => (0..v.trials)
                .map(|i| {
                    let dm = DeviceModel::for_model(base.mapped(), v.config.trial(i));
                    base.with_device(&format!("planner-mc-{i}"), Arc::new(dm))
                })
                .collect::<Result<Vec<_>>>()?,
            _ => Vec::new(),
        };
        let required = device.map_or(0, |v| v.required_passes());
        Ok(Evaluator {
            base,
            ds,
            cache,
            layers: base.mapped().layers.len(),
            noisy,
            required,
            stats,
        })
    }

    /// Accuracy of the starting plan. The cache's build pass already
    /// measured it; the uncached path pays one full accuracy pass — the
    /// same price, so the two modes stay forward-for-forward comparable.
    fn start_accuracy(&mut self) -> Result<f64> {
        match &self.cache {
            Some(c) => Ok(c.accuracy()),
            None => {
                self.stats.layer_forwards += self.layers * self.ds.len();
                Ok(serve::accuracy(self.base, self.ds)?.accuracy)
            }
        }
    }

    /// Score one candidate against `floor`: `(feasible, accuracy)`. The
    /// accuracy is the **ideal-device** measure and is `None` exactly when
    /// the cached scan aborted early — feasible candidates always carry
    /// one. With a noise gate configured, an ideal-feasible candidate must
    /// additionally hold the floor on the required number of Monte-Carlo
    /// device realizations; the gate runs *after* the ideal verdict so the
    /// prefix cache and early abort prune candidates before any noisy
    /// trial pass is spent, and the trial scan itself stops as soon as the
    /// quantile is met or provably unreachable.
    fn eval(&mut self, cand: &DeploymentPlan, floor: f64) -> Result<(bool, Option<f64>)> {
        self.stats.evaluations += 1;
        let (ok, a) = match &mut self.cache {
            Some(c) => {
                let s = c.score(cand, Some(floor), &mut self.stats)?;
                (s.feasible, s.accuracy)
            }
            None => {
                let be = self.base.replan("planner-candidate", cand.clone())?;
                self.stats.layer_forwards += self.layers * self.ds.len();
                let a = serve::accuracy(&be, self.ds)?.accuracy;
                (a >= floor, Some(a))
            }
        };
        if !ok || self.noisy.is_empty() {
            return Ok((ok, a));
        }
        let trials = self.noisy.len();
        let mut passes = 0usize;
        for (i, nb) in self.noisy.iter().enumerate() {
            if passes >= self.required || passes + (trials - i) < self.required {
                break; // verdict already decided either way
            }
            let be = nb.replan("planner-mc-candidate", cand.clone())?;
            self.stats.layer_forwards += self.layers * self.ds.len();
            if serve::accuracy(&be, self.ds)?.accuracy >= floor {
                passes += 1;
            }
        }
        if passes >= self.required {
            Ok((ok, a))
        } else {
            self.stats.noise_rejections += 1;
            Ok((false, None))
        }
    }

    /// Tell the cache the search accepted `cand` as its new incumbent.
    fn promote(&mut self, cand: &DeploymentPlan) -> Result<()> {
        match &mut self.cache {
            Some(c) => c.promote(cand, &mut self.stats),
            None => Ok(()),
        }
    }
}

/// Search a per-layer ADC deployment plan for `stack` under `cfg`,
/// validating every candidate on `holdout`. Maps the stack once — in
/// reordered layout when `cfg.reorder` asks for it — quantizes the
/// reference once, then delegates to [`plan_deployment_from`].
pub fn plan_deployment(
    stack: &[DenseLayer],
    holdout: &Dataset,
    cfg: &PlannerConfig,
) -> Result<PlanSearch> {
    let base = match cfg.reorder {
        Some(rc) => {
            CrossbarBackend::with_layer_policy_reordered("planner", stack, cfg.start_policy, rc)?
        }
        None => CrossbarBackend::with_layer_policy("planner", stack, cfg.start_policy)?,
    };
    let reference = ReferenceBackend::new("planner-reference", stack)?;
    // the reorder pass may normalize to the identity on every layer (tiny
    // or already-clustered stacks) — then the natural mapping *is* the
    // reordered one, and the consistency guard below must not fire
    let mut cfg = *cfg;
    if !base.is_reordered() {
        cfg.reorder = None;
    }
    plan_deployment_from(&base, &reference, holdout, &cfg)
}

/// Search starting from an already-mapped backend and reference — callers
/// that hold both (e.g. the deploy CLI path) reuse their mapping and
/// quantized weights instead of re-mapping the stack. The starting plan is
/// `cfg.start_policy` applied per layer to `base`'s mapping; `base`'s own
/// plan is irrelevant.
///
/// The mapping is shared across every candidate through
/// [`CrossbarBackend::replan`] (`Arc`-shared tiles), so the search
/// re-maps zero times. When `cfg.eval_examples` subsamples `holdout`, the
/// search selects on the head slice and the reported
/// `baseline_accuracy`/`accuracy`/`within_budget` are re-measured on the
/// *unseen tail* (falling back to the full holdout when the tail is too
/// small to be meaningful), so the headline numbers are not
/// selection-biased.
pub fn plan_deployment_from(
    base: &CrossbarBackend,
    reference: &ReferenceBackend,
    holdout: &Dataset,
    cfg: &PlannerConfig,
) -> Result<PlanSearch> {
    anyhow::ensure!(!holdout.is_empty(), "planner needs a non-empty held-out set");
    anyhow::ensure!(cfg.min_bits >= 1, "ADC resolutions start at 1 bit");
    anyhow::ensure!(
        cfg.reorder.is_none() || base.is_reordered(),
        "cfg.reorder asks for a reordered deployment but the supplied mapping is \
         natural-order — map the backend with reordering (or use plan_deployment)"
    );
    let ds = head(holdout, cfg.eval_examples);

    let base = base.replan(
        "planner",
        DeploymentPlan::from_policy(base.mapped(), cfg.start_policy),
    )?;
    let model = base.mapped().clone();
    let baseline_accuracy = serve::accuracy(reference, &ds)?.accuracy;

    // the replica budget is anchored once, at the census-derived starting
    // plan's bottleneck ([`timing::factor_budget_cells`]), so a joint run
    // and a plain run followed by an external fill spend the *same* cell
    // budget and stay comparable
    let budget_cells = match cfg.replicate_budget {
        Some(f) => timing::factor_budget_cells(&model, base.plan(), f),
        None => 0,
    };

    let mut ev = Evaluator::new(&base, &ds, cfg.incremental, cfg.device)?;
    let start_accuracy = ev.start_accuracy()?;
    let floor = baseline_accuracy - cfg.accuracy_budget;

    let mut plan = base.plan().clone();
    let mut accuracy = start_accuracy;

    // candidate-move weights: conversions per (layer, slice group); the
    // tally reads the cached per-tile census, so scoring is O(tiles)
    let conversions: Vec<[f64; N_SLICES]> = model
        .layers
        .iter()
        .map(|l| std::array::from_fn(|k| energy::slice_conversions(l, k)))
        .collect();

    // Paper warm start: the hand-picked Table-3 point, clipped into
    // [min_bits, start bits] per group. If it holds the budget, jump —
    // the greedy descent below can only improve on it.
    let mut warm = plan.clone();
    for l in &mut warm.layers {
        for (k, b) in l.adc_bits.iter_mut().enumerate() {
            *b = (*b).min(PAPER_BITS[k].max(cfg.min_bits));
        }
    }
    if warm != plan {
        let (ok, a) = ev.eval(&warm, floor)?;
        if ok {
            plan = warm;
            accuracy = a.expect("feasible evaluations always carry an accuracy");
            ev.promote(&plan)?;
        }
    }

    // Joint ADC/replica pass, throughput-first leg: with a replica budget
    // on the table, repeatedly water-fill a *trial* copy of the plan to
    // see where the pipeline would bottleneck after replication, then
    // binary-search that layer's slowest slice group down to its accuracy
    // floor. Lower bits shrink the bottleneck's sensing latency directly
    // AND free budget cells for more replicas — the two levers a
    // bits-then-replicas pipeline cannot trade against each other. Every
    // visited group is frozen (floored or refused), so the loop ends
    // after at most layers x N_SLICES visits; the energy descent below
    // shares the frozen set and the final fill spends the budget on the
    // selected resolutions.
    let mut frozen = vec![[false; N_SLICES]; plan.layers.len()];
    if budget_cells > 0 {
        loop {
            let mut trial = plan.clone();
            timing::fill_replicas(&model, &mut trial, budget_cells);
            let Some(b) = timing::plan_timing(&model, &trial).bottleneck() else {
                break;
            };
            let groups = timing::group_latency(&model.layers[b], &trial.layers[b]);
            let Some(k) = (0..N_SLICES)
                .filter(|&k| !frozen[b][k] && plan.layers[b].adc_bits[k] > cfg.min_bits)
                .max_by_key(|&k| groups[k])
            else {
                break; // the post-fill bottleneck has nothing left to lower
            };
            let hi = plan.layers[b].adc_bits[k];
            let mut probed: Vec<(u32, f64)> = Vec::new();
            let best = lowest_feasible(cfg.min_bits, hi, |v| {
                let mut cand = plan.clone();
                cand.layers[b].adc_bits[k] = v;
                let (ok, a) = ev.eval(&cand, floor)?;
                if ok {
                    probed.push((v, a.expect("feasible evaluations always carry an accuracy")));
                }
                Ok(ok)
            })?;
            if best < hi {
                plan.layers[b].adc_bits[k] = best;
                accuracy = probed
                    .iter()
                    .find(|&&(v, _)| v == best)
                    .expect("accepted resolution was probed feasible")
                    .1;
                ev.promote(&plan)?;
            }
            frozen[b][k] = true;
        }
    }

    // Moves are scored by the energy a one-bit reduction buys at the
    // group's current resolution; higher gain descends first.
    let score = |plan: &DeploymentPlan, frozen: &[[bool; N_SLICES]]| {
        let mut moves: Vec<(f64, usize, usize)> = Vec::new();
        for (l, pl) in plan.layers.iter().enumerate() {
            for k in 0..N_SLICES {
                let b = pl.adc_bits[k];
                if frozen[l][k] || b <= cfg.min_bits {
                    continue;
                }
                let gain = conversions[l][k] * (AdcModel::power(b) - AdcModel::power(b - 1));
                moves.push((gain, l, k));
            }
        }
        moves.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
        moves
    };

    match cfg.descent {
        // Greedy descent: repeatedly try to lower one (layer, slice
        // group) by one bit, best energy saving first. A group that fails
        // the budget is frozen — lowering *other* groups never makes it
        // more affordable.
        DescentStrategy::Linear => loop {
            let moves = score(&plan, &frozen);
            let mut progressed = false;
            for &(_, l, k) in &moves {
                let mut cand = plan.clone();
                cand.layers[l].adc_bits[k] -= 1;
                let (ok, a) = ev.eval(&cand, floor)?;
                if ok {
                    plan = cand;
                    accuracy = a.expect("feasible evaluations always carry an accuracy");
                    ev.promote(&plan)?;
                    progressed = true;
                    break; // re-score remaining moves against the new plan
                }
                frozen[l][k] = true;
            }
            if !progressed {
                break;
            }
        },
        // Per-group binary search, best energy gain first. A group's gain
        // depends only on its *own* current bits, so fully descending one
        // group never re-orders the remaining ones — a single sorted pass
        // visits the same groups the greedy loop would.
        DescentStrategy::Binary => {
            for &(_, l, k) in &score(&plan, &frozen) {
                let b = plan.layers[l].adc_bits[k];
                // accuracies of the feasible probes, so the accepted
                // resolution's accuracy needs no re-evaluation
                let mut probed: Vec<(u32, f64)> = Vec::new();
                let best = lowest_feasible(cfg.min_bits, b, |v| {
                    let mut cand = plan.clone();
                    cand.layers[l].adc_bits[k] = v;
                    let (ok, a) = ev.eval(&cand, floor)?;
                    if ok {
                        probed.push((v, a.expect("feasible evaluations always carry an accuracy")));
                    }
                    Ok(ok)
                })?;
                if best < b {
                    plan.layers[l].adc_bits[k] = best;
                    accuracy = probed
                        .iter()
                        .find(|&&(v, _)| v == best)
                        .expect("accepted resolution was probed feasible")
                        .1;
                    ev.promote(&plan)?;
                }
            }
        }
    }

    let mut stats = ev.stats;

    // Joint pass, final leg: spend the replica budget on the selected
    // resolutions (phase-one trials were provisional — only this fill is
    // fabricated). Replicas shard examples without changing any of them,
    // so the validated accuracy below is unaffected.
    let replica_cells = if budget_cells > 0 {
        timing::fill_replicas(&model, &mut plan, budget_cells)
    } else {
        0
    };

    // Final validation: the greedy loop selects on the (possibly
    // subsampled) eval set, so a plan can overfit its accept/reject
    // margins to those exact examples. When a subsample was used,
    // re-measure the selected plan and the reference on the *unseen tail*
    // of the holdout — unless the tail is a statistically meaningless
    // sliver (fewer than 32 examples or under a quarter of the holdout),
    // in which case the full holdout is the stabler validation set even
    // though it includes the search slice.
    let (baseline_accuracy, accuracy) = if ds.len() == holdout.len() {
        (baseline_accuracy, accuracy)
    } else {
        let tail_len = holdout.len() - ds.len();
        let val = if tail_len >= 32 && tail_len * 4 >= holdout.len() {
            slice(holdout, ds.len(), holdout.len())
        } else {
            holdout.clone()
        };
        let selected = base.replan("planner-selected", plan.clone())?;
        // two full accuracy passes run here — the reference and the
        // selected plan — and only the crossbar one executes forwards
        stats.evaluations += 2;
        stats.layer_forwards += model.layers.len() * val.len();
        (
            serve::accuracy(reference, &val)?.accuracy,
            serve::accuracy(&selected, &val)?.accuracy,
        )
    };

    let cost = energy::plan_cost(&model, &plan);
    let baseline_cost = energy::deployment_cost(&model, [super::adc::BASELINE_BITS; N_SLICES]);
    Ok(PlanSearch {
        plan,
        baseline_accuracy,
        start_accuracy,
        accuracy,
        cost,
        baseline_cost,
        stats,
        replica_cells,
        within_budget: accuracy >= baseline_accuracy - cfg.accuracy_budget,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::mapper::map_model;
    use crate::serve::{dense_stack, InferenceBackend};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn toy_stack(rng: &mut Rng) -> Vec<DenseLayer> {
        let w1 = Tensor::new(vec![8, 5], rng.normal_vec(40, 0.2)).unwrap();
        let w2 = Tensor::new(vec![5, 3], rng.normal_vec(15, 0.2)).unwrap();
        let b1 = Tensor::zeros(vec![5]);
        let b2 = Tensor::zeros(vec![3]);
        dense_stack(&[("fc1/w".into(), w1), ("fc2/w".into(), w2)], &[b1, b2]).unwrap()
    }

    /// Held-out set labelled by the exact reference's own argmax, so the
    /// baseline accuracy is 1.0 by construction and the budget measures
    /// pure ADC-clipping disagreement.
    fn oracle_dataset(stack: &[DenseLayer], n: usize, seed: u64) -> Dataset {
        let dim = stack[0].w.shape()[0];
        let classes = stack[stack.len() - 1].w.shape()[1];
        let mut rng = Rng::new(seed);
        let feats: Vec<f32> = (0..n * dim).map(|_| rng.next_f32()).collect();
        let x = Tensor::new(vec![n, dim], feats.clone()).unwrap();
        let reference = ReferenceBackend::new("oracle", stack).unwrap();
        let logits = reference.infer_batch(&x).unwrap();
        let labels: Vec<i32> = (0..n)
            .map(|i| {
                let row = &logits.data()[i * classes..(i + 1) * classes];
                (0..classes)
                    .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
                    .unwrap() as i32
            })
            .collect();
        Dataset {
            features: std::sync::Arc::new(feats),
            labels: std::sync::Arc::new(labels),
            example_shape: vec![dim],
            num_classes: classes,
            source: "oracle".into(),
        }
    }

    #[test]
    fn uniform_plan_reports_uniform_bits() {
        let mut rng = Rng::new(3);
        let w = Tensor::new(vec![20, 9], rng.normal_vec(180, 0.1)).unwrap();
        let m = map_model(&[("a".into(), w.clone()), ("b".into(), w)]).unwrap();
        let plan = DeploymentPlan::uniform_for(&m, [3, 3, 3, 1]);
        assert_eq!(plan.uniform_bits(), Some([3, 3, 3, 1]));
        let mut uneven = plan.clone();
        uneven.layers[1].adc_bits = [2, 2, 2, 1];
        assert_eq!(uneven.uniform_bits(), None);
        let shown = format!("{uneven}");
        assert!(shown.contains("a:[3, 3, 3, 1]"), "{shown}");
        assert!(shown.contains("b:[2, 2, 2, 1]"), "{shown}");
    }

    #[test]
    fn from_policy_uses_each_layers_own_census() {
        // layer "dense" needs many MSB bits, layer "tiny" needs few — a
        // whole-model census would force the max onto both
        let mut rng = Rng::new(5);
        let dense = Tensor::new(
            vec![128, 16],
            (0..128 * 16)
                .map(|_| if rng.next_f32() > 0.5 { 0.99 } else { -0.99 })
                .collect(),
        )
        .unwrap();
        let mut data = vec![0.0f32; 64 * 8];
        data[0] = 1.0;
        let tiny = Tensor::new(vec![64, 8], data).unwrap();
        let m = map_model(&[("dense".into(), dense), ("tiny".into(), tiny)]).unwrap();
        let plan = DeploymentPlan::from_policy(&m, ResolutionPolicy::Lossless);
        assert!(
            plan.layers[0].adc_bits[3] > plan.layers[1].adc_bits[3],
            "dense {:?} vs tiny {:?}",
            plan.layers[0].adc_bits,
            plan.layers[1].adc_bits
        );
        let global = resolution::required_bits(&m, ResolutionPolicy::Lossless);
        assert_eq!(plan.layers[0].adc_bits[3], global[3]);
    }

    #[test]
    fn unlimited_budget_collapses_to_min_bits() {
        let mut rng = Rng::new(11);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 12, 77);
        let cfg = PlannerConfig {
            accuracy_budget: 1.0,
            ..PlannerConfig::default()
        };
        let res = plan_deployment(&stack, &ds, &cfg).unwrap();
        assert_eq!(res.plan.uniform_bits(), Some([1, 1, 1, 1]));
        assert!(res.stats.evaluations > 0);
        assert_eq!(res.replica_cells, 0, "no replica budget was granted");
        assert!(res.cost.energy < res.baseline_cost.energy);
        let (e, t, a) = res.savings();
        assert!(e > 1.0 && t > 1.0 && a > 1.0);
    }

    #[test]
    fn search_respects_budget_and_never_raises_bits() {
        let mut rng = Rng::new(13);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 24, 99);
        let cfg = PlannerConfig::default(); // 0.5 pt budget, lossless start
        let res = plan_deployment(&stack, &ds, &cfg).unwrap();
        assert!((res.baseline_accuracy - 1.0).abs() < 1e-12, "oracle labels");
        assert!(
            res.accuracy >= res.baseline_accuracy - cfg.accuracy_budget - 1e-12,
            "accuracy {} vs baseline {}",
            res.accuracy,
            res.baseline_accuracy
        );
        let start = DeploymentPlan::from_policy(
            &map_model(&[
                ("fc1/w".into(), stack[0].w.clone()),
                ("fc2/w".into(), stack[1].w.clone()),
            ])
            .unwrap(),
            cfg.start_policy,
        );
        for (sel, st) in res.plan.layers.iter().zip(&start.layers) {
            for k in 0..N_SLICES {
                assert!(sel.adc_bits[k] <= st.adc_bits[k], "{:?}", sel);
                assert!(sel.adc_bits[k] >= cfg.min_bits);
            }
        }
        // lossless start agrees with the exact reference bit-for-bit
        assert_eq!(res.start_accuracy, res.baseline_accuracy);
        // no subsampling in this test, so the lossless start guarantees it
        assert!(res.within_budget);
    }

    #[test]
    fn zero_budget_keeps_exact_agreement() {
        let mut rng = Rng::new(17);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 16, 5);
        let cfg = PlannerConfig {
            accuracy_budget: 0.0,
            ..PlannerConfig::default()
        };
        let res = plan_deployment(&stack, &ds, &cfg).unwrap();
        assert_eq!(res.accuracy, res.baseline_accuracy);
    }

    /// The planner's census and search run on reordered tiles when asked:
    /// a lossless start on the reordered mapping still agrees exactly
    /// with the reference at zero budget, and the selected plan never
    /// exceeds the reordered layout's own starting bits.
    #[test]
    fn reordered_planner_search_stays_exact_at_zero_budget() {
        use crate::reram::reorder::ReorderConfig;
        let mut rng = Rng::new(19);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 16, 7);
        let cfg = PlannerConfig {
            accuracy_budget: 0.0,
            reorder: Some(ReorderConfig::default()),
            ..PlannerConfig::default()
        };
        let res = plan_deployment(&stack, &ds, &cfg).unwrap();
        assert_eq!(res.accuracy, res.baseline_accuracy);
        assert!(res.within_budget);
    }

    #[test]
    fn lowest_feasible_is_exact_and_logarithmic() {
        // cliff at 6 within [1, 9]: found in at most ceil(log2(9)) probes
        let mut probes = 0usize;
        let v = lowest_feasible(1, 9, |v| {
            probes += 1;
            Ok(v >= 6)
        })
        .unwrap();
        assert_eq!(v, 6);
        assert!(probes <= 4, "{probes} probes");
        // nothing below hi feasible: stays at the known-good hi
        let mut probes = 0usize;
        let v = lowest_feasible(1, 9, |v| {
            probes += 1;
            Ok(v >= 9)
        })
        .unwrap();
        assert_eq!(v, 9);
        assert!(probes <= 4, "{probes} probes");
        // everything feasible: collapses to lo; degenerate range: 0 probes
        assert_eq!(lowest_feasible(1, 9, |_| Ok(true)).unwrap(), 1);
        assert_eq!(lowest_feasible(3, 3, |_| panic!("no probe")).unwrap(), 3);
    }

    /// Satellite: on the planted class-template fixture (the planner
    /// bench's model, bit-slice sparse by construction) the binary
    /// descent selects exactly the plan the linear descent selects,
    /// without spending more held-out evaluations.
    #[test]
    fn binary_descent_matches_linear_on_planted_fixture() {
        use crate::data::synthetic;
        use crate::util::fixtures;
        let train = synthetic::mnist(600, 11);
        let holdout = synthetic::mnist(160, 12);
        let stack = fixtures::planted_class_stack(&train);
        let run = |descent| {
            let cfg = PlannerConfig {
                eval_examples: 0, // search on the full holdout
                descent,
                ..PlannerConfig::default()
            };
            plan_deployment(&stack, &holdout, &cfg).unwrap()
        };
        let linear = run(DescentStrategy::Linear);
        let binary = run(DescentStrategy::Binary);
        assert_eq!(binary.plan, linear.plan, "descent strategies diverged");
        assert!(
            binary.stats.evaluations <= linear.stats.evaluations,
            "binary spent {} evaluations, linear {}",
            binary.stats.evaluations,
            linear.stats.evaluations
        );
        assert!(binary.within_budget && linear.within_budget);
    }

    /// Tentpole: the incremental evaluator must change the *cost* of the
    /// search, never its outcome — same selected plan, same accuracy,
    /// same evaluation sequence, fewer (or equal) crossbar forwards.
    #[test]
    fn incremental_search_matches_uncached_exactly() {
        let mut rng = Rng::new(23);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 48, 11);
        for budget in [0.0, 0.05] {
            let run = |incremental| {
                let cfg = PlannerConfig {
                    accuracy_budget: budget,
                    incremental,
                    ..PlannerConfig::default()
                };
                plan_deployment(&stack, &ds, &cfg).unwrap()
            };
            let cached = run(true);
            let uncached = run(false);
            assert_eq!(cached.plan, uncached.plan, "budget {budget}");
            assert_eq!(cached.accuracy, uncached.accuracy, "budget {budget}");
            assert_eq!(
                cached.stats.evaluations, uncached.stats.evaluations,
                "budget {budget}"
            );
            assert_eq!(uncached.stats.cache_hits, 0);
            assert_eq!(uncached.stats.aborted_evals, 0);
            assert!(cached.stats.cache_hits > 0, "budget {budget}");
            assert!(
                cached.stats.layer_forwards <= uncached.stats.layer_forwards,
                "budget {budget}: cached spent {} forwards, uncached {}",
                cached.stats.layer_forwards,
                uncached.stats.layer_forwards
            );
        }
    }

    /// Satellite: the final full-holdout re-measure runs *two* accuracy
    /// passes (reference and selected plan); the evaluation counter must
    /// say so, and the selected plan's crossbar pass must land in
    /// `layer_forwards`.
    #[test]
    fn final_validation_counts_its_two_passes() {
        let mut rng = Rng::new(29);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 64, 31);
        // a min_bits floor above the lossless start turns every descent
        // move off and clips the warm start into a no-op: the only
        // accuracy passes left are the tail validation's two
        let cfg = PlannerConfig {
            eval_examples: 16,
            min_bits: 32,
            ..PlannerConfig::default()
        };
        let res = plan_deployment(&stack, &ds, &cfg).unwrap();
        assert_eq!(res.stats.evaluations, 2, "reference + selected re-measure");
        // cache build over the 16-example search slice, then the selected
        // plan's full pass over the 48-example unseen tail
        assert_eq!(res.stats.layer_forwards, 2 * 16 + 2 * 48);
        assert_eq!(res.stats.aborted_evals, 0);
    }

    #[test]
    fn required_passes_rounds_up_and_clamps() {
        let mut v = DeviceValidation {
            trials: 8,
            quantile: 0.75,
            ..DeviceValidation::default()
        };
        assert_eq!(v.required_passes(), 6);
        v.quantile = 1.0;
        assert_eq!(v.required_passes(), 8);
        v.quantile = 0.51;
        assert_eq!(v.required_passes(), 5, "ceil, not round");
        v.quantile = 0.0;
        assert_eq!(v.required_passes(), 1, "at least one trial must pass");
        v.quantile = 7.0;
        assert_eq!(v.required_passes(), 8, "never more than every trial");
    }

    /// An ideal device config (or zero trials) disables the gate: the
    /// search must select exactly the plan the ungated search selects,
    /// with zero noise rejections and no extra forwards.
    #[test]
    fn ideal_device_gate_is_inert() {
        let mut rng = Rng::new(31);
        let stack = toy_stack(&mut rng);
        let ds = oracle_dataset(&stack, 24, 13);
        let cfg = PlannerConfig::default();
        let plain = plan_deployment(&stack, &ds, &cfg).unwrap();
        for device in [
            Some(DeviceValidation::default()), // all-zero knobs = ideal
            Some(DeviceValidation {
                config: DeviceConfig {
                    sigma: 0.3,
                    seed: 5,
                    ..DeviceConfig::default()
                },
                trials: 0,
                quantile: 1.0,
            }),
        ] {
            let gated = plan_deployment(&stack, &ds, &PlannerConfig { device, ..cfg }).unwrap();
            assert_eq!(gated.plan, plain.plan);
            assert_eq!(gated.stats.noise_rejections, 0);
            assert_eq!(gated.stats.layer_forwards, plain.stats.layer_forwards);
        }
    }

    /// Acceptance criterion: on the planted fixture, noise-validated
    /// planning must reject at least one plan the ideal search accepts —
    /// and therefore keep strictly more ADC resolution than the
    /// perfect-device search selects.
    #[test]
    fn noise_validated_search_rejects_perfect_device_plans() {
        use crate::data::synthetic;
        use crate::util::fixtures;
        let train = synthetic::mnist(600, 11);
        let holdout = synthetic::mnist(160, 12);
        let stack = fixtures::planted_class_stack(&train);
        let cfg = PlannerConfig {
            eval_examples: 0,
            ..PlannerConfig::default()
        };
        let ideal = plan_deployment(&stack, &holdout, &cfg).unwrap();
        assert_eq!(ideal.stats.noise_rejections, 0);
        let noisy = plan_deployment(
            &stack,
            &holdout,
            &PlannerConfig {
                device: Some(DeviceValidation {
                    config: DeviceConfig {
                        sigma: 0.6,
                        read_sigma: 2.0,
                        fault_rate: 0.05,
                        seed: 0xD3,
                    },
                    trials: 4,
                    quantile: 1.0,
                }),
                ..cfg
            },
        )
        .unwrap();
        assert!(
            noisy.stats.noise_rejections >= 1,
            "no ideal-accepted candidate was rejected under noise"
        );
        let total_bits = |p: &DeploymentPlan| {
            p.layers
                .iter()
                .map(|l| l.adc_bits.iter().sum::<u32>())
                .sum::<u32>()
        };
        assert!(
            total_bits(&noisy.plan) > total_bits(&ideal.plan),
            "noise validation must keep more resolution: noisy {} vs ideal {}",
            noisy.plan,
            ideal.plan
        );
        // the reported headline accuracy stays the ideal-device measure
        assert_eq!(noisy.baseline_accuracy, ideal.baseline_accuracy);
    }

    /// Tentpole: under one replica cell budget, the joint ADC/replica
    /// pass must meet (or beat) the sequential pipeline — search bits
    /// first, water-fill replicas afterwards — in steady-state pipeline
    /// throughput.
    #[test]
    fn joint_replica_pass_meets_sequential_throughput() {
        use crate::reram::timing;
        use crate::util::fixtures;
        let stack = fixtures::bottleneck_stack(0xBEEF);
        let ds = oracle_dataset(&stack, 32, 9);
        let cfg = PlannerConfig {
            eval_examples: 0,
            ..PlannerConfig::default()
        };
        let seq = plan_deployment(&stack, &ds, &cfg).unwrap();
        let joint = plan_deployment(
            &stack,
            &ds,
            &PlannerConfig {
                replicate_budget: Some(2.0),
                ..cfg
            },
        )
        .unwrap();

        // the budget the joint pass anchored at the shared starting plan
        let named: Vec<(String, Tensor)> = stack
            .iter()
            .map(|l| (l.name.clone(), l.w.clone()))
            .collect();
        let model = map_model(&named).unwrap();
        let start = DeploymentPlan::from_policy(&model, cfg.start_policy);
        let b = timing::plan_timing(&model, &start).bottleneck().unwrap();
        let budget = 2 * model.layers[b].fabricated_cells();
        assert!(joint.replica_cells > 0, "the budget bought replicas");
        assert!(joint.replica_cells <= budget, "budget overspent");
        assert_eq!(seq.replica_cells, 0);

        let mut seq_plan = seq.plan.clone();
        timing::fill_replicas(&model, &mut seq_plan, budget);
        let seq_tp = timing::plan_timing(&model, &seq_plan).throughput_per_kcycle();
        let joint_tp = timing::plan_timing(&model, &joint.plan).throughput_per_kcycle();
        assert!(
            joint_tp >= seq_tp * 0.999,
            "joint {joint_tp} vs sequential {seq_tp}"
        );
        assert!(joint.within_budget);
    }
}

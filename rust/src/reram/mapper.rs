//! Weight -> crossbar mapping.
//!
//! A weight tensor is viewed as a 2-D matrix (fan-in rows x fan-out
//! columns; conv kernels HWIO flatten to (kh*kw*cin) x cout), quantized to
//! 8-bit dynamic fixed point (Eq. 1-2), bit-sliced into the four 2-bit
//! slices (Eq. 3's universe), sign-split onto positive/negative arrays,
//! and tiled into 128x128 [`Crossbar`]s. This is exactly the layout the
//! paper's "4 groups of 128x128 ReRAM crossbars (XBs), with each group
//! storing 2 bits of the 8-bit weights" describes.

use anyhow::Result;

use crate::quant::{self, N_SLICES};
use crate::tensor::Tensor;

use super::crossbar::{Crossbar, XBAR_COLS, XBAR_ROWS};

/// Positive / negative differential halves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sign {
    Pos,
    Neg,
}

/// All crossbars of one layer for one slice group and sign, tiled.
#[derive(Debug, Clone)]
pub struct TileGrid {
    /// `row_tiles x col_tiles`, row-major.
    pub tiles: Vec<Crossbar>,
    pub row_tiles: usize,
    pub col_tiles: usize,
}

impl TileGrid {
    pub fn tile(&self, tr: usize, tc: usize) -> &Crossbar {
        &self.tiles[tr * self.col_tiles + tc]
    }
}

/// One mapped layer: 4 slice groups x 2 signs of tile grids.
#[derive(Debug, Clone)]
pub struct LayerMapping {
    pub name: String,
    /// logical matrix shape (rows = fan-in, cols = fan-out)
    pub rows: usize,
    pub cols: usize,
    /// Qstep of the layer (for recovering real units)
    pub step: f32,
    /// `grids[k]` = (pos, neg) for slice k, LSB-first.
    pub grids: Vec<(TileGrid, TileGrid)>,
}

/// A whole model mapped onto crossbars.
#[derive(Debug, Clone)]
pub struct MappedModel {
    pub layers: Vec<LayerMapping>,
}

/// Interpret a weight tensor as (fan-in x fan-out).
pub fn matrix_view(shape: &[usize]) -> Result<(usize, usize)> {
    match shape.len() {
        2 => Ok((shape[0], shape[1])),
        4 => Ok((shape[0] * shape[1] * shape[2], shape[3])), // HWIO conv
        _ => anyhow::bail!("cannot map tensor of rank {} to a matrix", shape.len()),
    }
}

fn empty_grid(rows: usize, cols: usize) -> TileGrid {
    let row_tiles = rows.div_ceil(XBAR_ROWS);
    let col_tiles = cols.div_ceil(XBAR_COLS);
    let mut tiles = Vec::with_capacity(row_tiles * col_tiles);
    for tr in 0..row_tiles {
        for tc in 0..col_tiles {
            let r = (rows - tr * XBAR_ROWS).min(XBAR_ROWS);
            let c = (cols - tc * XBAR_COLS).min(XBAR_COLS);
            tiles.push(Crossbar::zeros(r, c));
        }
    }
    TileGrid {
        tiles,
        row_tiles,
        col_tiles,
    }
}

/// Map one weight tensor.
pub fn map_layer(name: &str, w: &Tensor) -> Result<LayerMapping> {
    let (rows, cols) = matrix_view(w.shape())?;
    let q = quant::quantize(w);
    let mut grids = Vec::with_capacity(N_SLICES);
    for k in 0..N_SLICES {
        let slice = q.slice(k);
        let mut pos = empty_grid(rows, cols);
        let mut neg = empty_grid(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                let i = r * cols + c;
                let v = slice[i];
                if v == 0 {
                    continue;
                }
                let (tr, rr) = (r / XBAR_ROWS, r % XBAR_ROWS);
                let (tc, cc) = (c / XBAR_COLS, c % XBAR_COLS);
                let grid = if q.signs[i] >= 0 { &mut pos } else { &mut neg };
                grid.tiles[tr * grid.col_tiles + tc].set(rr, cc, v);
            }
        }
        grids.push((pos, neg));
    }
    Ok(LayerMapping {
        name: name.to_string(),
        rows,
        cols,
        step: q.step,
        grids,
    })
}

/// Map a set of named weight tensors (a whole model's qweights).
pub fn map_model(weights: &[(String, Tensor)]) -> Result<MappedModel> {
    let layers = weights
        .iter()
        .map(|(n, w)| map_layer(n, w))
        .collect::<Result<Vec<_>>>()?;
    Ok(MappedModel { layers })
}

impl LayerMapping {
    /// Crossbar count for one slice group (pos + neg).
    pub fn crossbars_per_slice(&self) -> usize {
        let (p, n) = &self.grids[0];
        p.tiles.len() + n.tiles.len()
    }

    /// Programmed-cell census for slice k (pos + neg) — equals the slice's
    /// non-zero element count from the sparsity module.
    pub fn nonzero_cells(&self, k: usize) -> usize {
        let (p, n) = &self.grids[k];
        p.tiles.iter().map(|t| t.nonzero_cells()).sum::<usize>()
            + n.tiles.iter().map(|t| t.nonzero_cells()).sum::<usize>()
    }
}

impl MappedModel {
    /// Total crossbars across all layers and slice groups.
    pub fn total_crossbars(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.crossbars_per_slice() * N_SLICES)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity;
    use crate::util::check::{check, ensure};
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, rng.normal_vec(n, scale)).unwrap()
    }

    #[test]
    fn matrix_view_linear_and_conv() {
        assert_eq!(matrix_view(&[784, 300]).unwrap(), (784, 300));
        assert_eq!(matrix_view(&[3, 3, 64, 128]).unwrap(), (576, 128));
        assert!(matrix_view(&[10]).is_err());
    }

    #[test]
    fn tiling_covers_matrix_exactly() {
        let mut rng = Rng::new(1);
        let w = rand_tensor(&mut rng, vec![300, 200], 0.1);
        let m = map_layer("fc", &w).unwrap();
        let (p, _) = &m.grids[0];
        assert_eq!(p.row_tiles, 3); // ceil(300/128)
        assert_eq!(p.col_tiles, 2); // ceil(200/128)
        assert_eq!(p.tile(0, 0).rows(), 128);
        assert_eq!(p.tile(2, 0).rows(), 44); // 300 - 256
        assert_eq!(p.tile(0, 1).cols(), 72); // 200 - 128
    }

    #[test]
    fn mapped_cells_match_sparsity_census() {
        check(10, |rng| {
            let rows = 1 + rng.below(300);
            let cols = 1 + rng.below(200);
            let w = Tensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 0.1))
                .unwrap();
            let stats = sparsity::census(std::slice::from_ref(&w));
            let m = map_layer("l", &w).unwrap();
            for k in 0..N_SLICES {
                ensure(
                    m.nonzero_cells(k) == stats.nonzero[k],
                    format!("slice {k} cells vs census"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn signs_split_to_differential_arrays() {
        // +1 -> pos grid, -1 -> neg grid, same cell values
        let w = Tensor::new(vec![2, 1], vec![0.5, -0.5]).unwrap();
        let m = map_layer("l", &w).unwrap();
        for k in 0..N_SLICES {
            let (p, n) = &m.grids[k];
            assert_eq!(p.tile(0, 0).get(0, 0), n.tile(0, 0).get(1, 0));
            assert_eq!(p.tile(0, 0).get(1, 0), 0);
            assert_eq!(n.tile(0, 0).get(0, 0), 0);
        }
    }

    #[test]
    fn slices_reconstruct_codes_through_mapping() {
        let mut rng = Rng::new(3);
        let w = rand_tensor(&mut rng, vec![50, 40], 0.2);
        let q = quant::quantize(&w);
        let m = map_layer("l", &w).unwrap();
        for r in 0..50 {
            for c in 0..40 {
                let mut acc = 0u32;
                for k in 0..N_SLICES {
                    let (p, n) = &m.grids[k];
                    let v = p.tile(0, 0).get(r, c).max(n.tile(0, 0).get(r, c));
                    acc += (v as u32) << (2 * k);
                }
                assert_eq!(acc, q.codes[r * 40 + c] as u32, "at ({r},{c})");
            }
        }
    }

    #[test]
    fn conv_kernel_maps_without_error() {
        let mut rng = Rng::new(4);
        let w = rand_tensor(&mut rng, vec![3, 3, 16, 32], 0.1);
        let m = map_layer("conv", &w).unwrap();
        assert_eq!(m.rows, 144);
        assert_eq!(m.cols, 32);
        assert_eq!(m.grids.len(), 4);
        let model = map_model(&[("conv".to_string(), w)]).unwrap();
        assert_eq!(model.total_crossbars(), 4 * m.crossbars_per_slice());
    }
}

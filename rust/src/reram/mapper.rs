//! Weight -> crossbar mapping.
//!
//! A weight tensor is viewed as a 2-D matrix (fan-in rows x fan-out
//! columns; conv kernels HWIO flatten to (kh*kw*cin) x cout), quantized to
//! 8-bit dynamic fixed point (Eq. 1-2), bit-sliced into the four 2-bit
//! slices (Eq. 3's universe), sign-split onto positive/negative arrays,
//! and tiled into 128x128 [`Crossbar`]s. This is exactly the layout the
//! paper's "4 groups of 128x128 ReRAM crossbars (XBs), with each group
//! storing 2 bits of the 8-bit weights" describes.
//!
//! Each tile's storage representation is chosen at map time from its own
//! measured density — the [`crate::reram::crossbar::chosen_format`]
//! three-band policy: the programmed cells are gathered per tile and
//! handed to [`Crossbar::from_cells`], so Bl1-level sparse slices go
//! straight to compressed storage with **no dense intermediate**,
//! mid-band slices (dense-random weights land here) pack into popcount
//! bit-planes, and only near-full tiles keep the row-major byte layout.
//! [`LayerMapping::storage_stats`] reports what was chosen.
//!
//! [`map_layer_with`] optionally runs the wordline/column reorder pass
//! ([`crate::reram::reorder`]) before tiling: cell `(r, c)` is programmed
//! at its permuted position and the permutations are stored in
//! [`LayerMapping::reorder`], where the simulator picks them up (codes
//! permuted on the way in, sums un-permuted on the way out — see the
//! reorder module docs for the full convention).

use std::sync::Arc;

use anyhow::Result;

use crate::quant::{self, N_SLICES};
use crate::tensor::Tensor;

use super::crossbar::{Crossbar, StorageFormat, XBAR_COLS, XBAR_ROWS};
use super::reorder::{self, LayerReorder, ReorderConfig};

/// Positive / negative differential halves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sign {
    Pos,
    Neg,
}

/// All crossbars of one layer for one slice group and sign, tiled.
#[derive(Debug, Clone)]
pub struct TileGrid {
    /// `row_tiles x col_tiles`, row-major.
    pub tiles: Vec<Crossbar>,
    pub row_tiles: usize,
    pub col_tiles: usize,
}

impl TileGrid {
    pub fn tile(&self, tr: usize, tc: usize) -> &Crossbar {
        &self.tiles[tr * self.col_tiles + tc]
    }
}

/// One mapped layer: 4 slice groups x 2 signs of tile grids.
#[derive(Debug, Clone)]
pub struct LayerMapping {
    pub name: String,
    /// logical matrix shape (rows = fan-in, cols = fan-out)
    pub rows: usize,
    pub cols: usize,
    /// Qstep of the layer (for recovering real units)
    pub step: f32,
    /// `grids[k]` = (pos, neg) for slice k, LSB-first.
    pub grids: Vec<(TileGrid, TileGrid)>,
    /// Map-time wordline/column permutations shared by every grid, when
    /// the layer was mapped with reordering (`None` = natural order). The
    /// simulator permutes activation codes in and un-permutes accumulated
    /// sums out through these (see [`crate::reram::reorder`]).
    pub reorder: Option<LayerReorder>,
}

/// A whole model mapped onto crossbars. Layers live behind `Arc` so a
/// replica view ([`MappedModel::replicated`]) and the serving backends can
/// hold extra handles on a layer's tiles without ever deep-cloning them —
/// cloning the model itself is likewise a handle copy, not a re-map.
#[derive(Debug, Clone)]
pub struct MappedModel {
    pub layers: Vec<Arc<LayerMapping>>,
}

/// Replica-expanded view of a mapped model: layer `i` appears once per
/// fabricated copy, every handle an `Arc` on the **same** tiles — in
/// simulation a replica costs a pointer, never a deep clone (the hardware
/// analogy: identical arrays programmed from one weight image). Built by
/// [`MappedModel::replicated`]; the replica-sharded serving path hands one
/// handle to each batch shard.
#[derive(Debug, Clone)]
pub struct ReplicatedModel {
    /// `layers[i]` holds layer i's replica handles (>= 1 entries)
    pub layers: Vec<Vec<Arc<LayerMapping>>>,
}

/// Storage census of a set of mapped tiles (one layer or a whole model):
/// how many tiles each [`StorageFormat`] holds, what the chosen layouts
/// cost in bytes, and how much an all-dense layout would have cost.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StorageStats {
    /// programmed tiles stored row-major
    pub dense_tiles: usize,
    /// programmed tiles stored as packed `(col, val)` pairs
    pub compressed_tiles: usize,
    /// programmed tiles stored as packed popcount bit-planes
    pub bitplane_tiles: usize,
    /// fully-zero tiles: mapped for addressing, never fabricated, and
    /// skipped outright by the simulator's forward path
    pub skipped_tiles: usize,
    /// programmed (non-zero) cells — the cached per-tile census summed
    pub programmed_cells: usize,
    /// logical cells (rows x cols summed over every tile)
    pub cells: usize,
    /// bytes the chosen representations occupy
    pub bytes: usize,
    /// bytes an all-dense layout would occupy (one per cell)
    pub dense_bytes: usize,
    /// wordlines with >= 1 programmed cell, summed over programmed tiles
    /// — what the sparse current scan visits (the reorder engine's target)
    pub active_wordlines: usize,
    /// wordline slots (tile rows) summed over programmed tiles
    pub wordline_slots: usize,
    /// output columns with >= 1 programmed cell, summed over programmed
    /// tiles — the columns whose ADC actually converts
    pub active_columns: usize,
    /// column slots (tile cols) summed over programmed tiles
    pub column_slots: usize,
}

impl StorageStats {
    fn add_tile(&mut self, t: &Crossbar) {
        let cells = t.rows() * t.cols();
        self.cells += cells;
        self.dense_bytes += cells;
        self.programmed_cells += t.nonzero_cells();
        self.bytes += t.storage_bytes();
        if t.nonzero_cells() == 0 {
            self.skipped_tiles += 1;
        } else {
            match t.format() {
                StorageFormat::Dense => self.dense_tiles += 1,
                StorageFormat::Compressed => self.compressed_tiles += 1,
                StorageFormat::BitPlanes => self.bitplane_tiles += 1,
            }
            // fully-zero tiles are never fabricated, so only programmed
            // tiles contribute wordline/column slots to the census
            self.active_wordlines += t.active_wordlines();
            self.wordline_slots += t.rows();
            self.active_columns += t.active_columns();
            self.column_slots += t.cols();
        }
    }

    pub fn merge(&mut self, o: &StorageStats) {
        self.dense_tiles += o.dense_tiles;
        self.compressed_tiles += o.compressed_tiles;
        self.bitplane_tiles += o.bitplane_tiles;
        self.skipped_tiles += o.skipped_tiles;
        self.programmed_cells += o.programmed_cells;
        self.cells += o.cells;
        self.bytes += o.bytes;
        self.dense_bytes += o.dense_bytes;
        self.active_wordlines += o.active_wordlines;
        self.wordline_slots += o.wordline_slots;
        self.active_columns += o.active_columns;
        self.column_slots += o.column_slots;
    }

    /// Tiles actually fabricated — every programmed layout summed
    /// (dense + compressed + bit-planes); skipped tiles excluded.
    pub fn programmed_tiles(&self) -> usize {
        self.dense_tiles + self.compressed_tiles + self.bitplane_tiles
    }

    /// Active wordlines over wordline slots of the programmed tiles
    /// (0.0 when nothing is programmed).
    pub fn wordline_occupancy(&self) -> f64 {
        if self.wordline_slots == 0 {
            0.0
        } else {
            self.active_wordlines as f64 / self.wordline_slots as f64
        }
    }

    /// Active columns over column slots of the programmed tiles.
    pub fn column_occupancy(&self) -> f64 {
        if self.column_slots == 0 {
            0.0
        } else {
            self.active_columns as f64 / self.column_slots as f64
        }
    }

    /// Programmed fraction over all mapped cells.
    pub fn density(&self) -> f64 {
        if self.cells == 0 {
            0.0
        } else {
            self.programmed_cells as f64 / self.cells as f64
        }
    }

    /// Dense bytes / chosen bytes (1.0 = no saving).
    pub fn byte_saving(&self) -> f64 {
        if self.bytes == 0 {
            1.0
        } else {
            self.dense_bytes as f64 / self.bytes as f64
        }
    }
}

/// One layer's storage census — the `report::storage_table` row.
#[derive(Debug, Clone)]
pub struct StorageRow {
    pub layer: String,
    pub stats: StorageStats,
}

/// Interpret a weight tensor as (fan-in x fan-out).
pub fn matrix_view(shape: &[usize]) -> Result<(usize, usize)> {
    match shape.len() {
        2 => Ok((shape[0], shape[1])),
        4 => Ok((shape[0] * shape[1] * shape[2], shape[3])), // HWIO conv
        _ => anyhow::bail!("cannot map tensor of rank {} to a matrix", shape.len()),
    }
}

/// Programmed cells of one tile, as `(row, col, val)` —
/// [`Crossbar::from_cells`]'s input.
type TileCells = Vec<(u16, u16, u8)>;

/// Map one weight tensor in natural (unpermuted) order — thin wrapper
/// over [`map_layer_with`].
pub fn map_layer(name: &str, w: &Tensor) -> Result<LayerMapping> {
    map_layer_with(name, w, None)
}

/// Map one weight tensor. Cells are gathered per (tile, sign) and each
/// tile picks its own storage format from its density. With a
/// [`ReorderConfig`], the wordline/column reorder pass runs first and
/// every cell is programmed at its permuted position (the permutations
/// land in [`LayerMapping::reorder`]; `None` is stored when the plan
/// turns out to be the identity).
pub fn map_layer_with(
    name: &str,
    w: &Tensor,
    reorder_cfg: Option<ReorderConfig>,
) -> Result<LayerMapping> {
    let (rows, cols) = matrix_view(w.shape())?;
    let q = quant::quantize(w);
    // the occupancy union of all slices and signs is exactly "code != 0",
    // so the reorder pass plans straight from the code matrix
    let reorder =
        reorder_cfg.and_then(|cfg| reorder::plan_from_codes(rows, cols, &q.codes, cfg));
    let row_tiles = rows.div_ceil(XBAR_ROWS);
    let col_tiles = cols.div_ceil(XBAR_COLS);
    let n_tiles = row_tiles * col_tiles;
    let mut grids = Vec::with_capacity(N_SLICES);
    for k in 0..N_SLICES {
        let slice = q.slice(k);
        // per-tile programmed-cell lists; `from_cells` sorts each list, so
        // permuted (out-of-order) emission costs nothing extra
        let mut cells: [Vec<TileCells>; 2] =
            [vec![Vec::new(); n_tiles], vec![Vec::new(); n_tiles]];
        for r in 0..rows {
            for c in 0..cols {
                let i = r * cols + c;
                let v = slice[i];
                if v == 0 {
                    continue;
                }
                // physical position: permuted when reordering, else (r, c)
                let (pr, pc) = match &reorder {
                    Some(ro) => (ro.rows.new_of(r), ro.cols.new_of(c)),
                    None => (r, c),
                };
                let (tr, rr) = (pr / XBAR_ROWS, pr % XBAR_ROWS);
                let (tc, cc) = (pc / XBAR_COLS, pc % XBAR_COLS);
                let side = (q.signs[i] < 0) as usize;
                cells[side][tr * col_tiles + tc].push((rr as u16, cc as u16, v));
            }
        }
        let [pos_cells, neg_cells] = cells;
        let build = |tile_cells: Vec<TileCells>| -> TileGrid {
            let mut tiles = Vec::with_capacity(n_tiles);
            for (ti, list) in tile_cells.into_iter().enumerate() {
                let (tr, tc) = (ti / col_tiles, ti % col_tiles);
                let r = (rows - tr * XBAR_ROWS).min(XBAR_ROWS);
                let c = (cols - tc * XBAR_COLS).min(XBAR_COLS);
                tiles.push(Crossbar::from_cells(r, c, list));
            }
            TileGrid {
                tiles,
                row_tiles,
                col_tiles,
            }
        };
        grids.push((build(pos_cells), build(neg_cells)));
    }
    Ok(LayerMapping {
        name: name.to_string(),
        rows,
        cols,
        step: q.step,
        grids,
        reorder,
    })
}

/// Map a set of named weight tensors (a whole model's qweights) in
/// natural order.
pub fn map_model(weights: &[(String, Tensor)]) -> Result<MappedModel> {
    map_model_with(weights, None)
}

/// Map a whole model, optionally running the wordline/column reorder pass
/// per layer (each layer plans its own permutations from its own codes).
pub fn map_model_with(
    weights: &[(String, Tensor)],
    reorder_cfg: Option<ReorderConfig>,
) -> Result<MappedModel> {
    let layers = weights
        .iter()
        .map(|(n, w)| map_layer_with(n, w, reorder_cfg).map(Arc::new))
        .collect::<Result<Vec<_>>>()?;
    let model = MappedModel { layers };
    // a freshly mapped model must satisfy every structural invariant the
    // audit catalogue states — in debug builds, prove it before handing
    // the artifact out (the cheap structural pass; layout round-trips are
    // covered by the deep audit at deploy/serve time)
    #[cfg(debug_assertions)]
    {
        let report = super::audit::quick_audit(&model);
        debug_assert_eq!(report.summary.errors, 0, "mapper emitted a faulty artifact: {report}");
    }
    Ok(model)
}

impl LayerMapping {
    /// Crossbar count for one slice group (pos + neg).
    pub fn crossbars_per_slice(&self) -> usize {
        let (p, n) = &self.grids[0];
        p.tiles.len() + n.tiles.len()
    }

    /// Programmed-cell census for slice k (pos + neg) — equals the slice's
    /// non-zero element count from the sparsity module. Sums the per-tile
    /// cached counts, so it costs O(tiles), not O(cells).
    pub fn nonzero_cells(&self, k: usize) -> usize {
        let (p, n) = &self.grids[k];
        p.tiles.iter().map(|t| t.nonzero_cells()).sum::<usize>()
            + n.tiles.iter().map(|t| t.nonzero_cells()).sum::<usize>()
    }

    /// Storage census over every tile of the layer (all slices, both
    /// signs).
    pub fn storage_stats(&self) -> StorageStats {
        let mut stats = StorageStats::default();
        for (p, n) in &self.grids {
            for grid in [p, n] {
                for tile in &grid.tiles {
                    stats.add_tile(tile);
                }
            }
        }
        stats
    }

    /// A clone with every tile re-laid out in `fmt` — the benches' and
    /// representation tests' handle for comparing both execution paths on
    /// an identical mapping. The reorder permutations (if any) are
    /// preserved: storage format and placement are orthogonal.
    pub fn with_storage(&self, fmt: StorageFormat) -> LayerMapping {
        let mut out = self.clone();
        for (p, n) in &mut out.grids {
            for grid in [p, n] {
                for tile in &mut grid.tiles {
                    tile.convert(fmt);
                }
            }
        }
        out
    }

    /// Whether this layer carries map-time permutations.
    pub fn is_reordered(&self) -> bool {
        self.reorder.is_some()
    }

    /// Fabricated cells of this layer: full tile geometry (rows x cols)
    /// summed over **programmed** tiles across every slice group and both
    /// signs — fully-zero tiles are never fabricated. This is the area
    /// price of one replica, the unit the replication planner
    /// ([`crate::reram::timing::fill_replicas`]) water-fills its budget
    /// in.
    pub fn fabricated_cells(&self) -> usize {
        self.grids
            .iter()
            .flat_map(|(p, n)| [p, n])
            .flat_map(|g| &g.tiles)
            .filter(|t| t.nonzero_cells() > 0)
            .map(|t| t.rows() * t.cols())
            .sum()
    }
}

impl MappedModel {
    /// Total crossbars across all layers and slice groups.
    pub fn total_crossbars(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.crossbars_per_slice() * N_SLICES)
            .sum()
    }

    /// Whole-model storage census.
    pub fn storage_stats(&self) -> StorageStats {
        let mut stats = StorageStats::default();
        for layer in &self.layers {
            stats.merge(&layer.storage_stats());
        }
        stats
    }

    /// Per-layer storage census rows (the `report::storage_table` body).
    pub fn storage_rows(&self) -> Vec<StorageRow> {
        self.layers
            .iter()
            .map(|l| StorageRow {
                layer: l.name.clone(),
                stats: l.storage_stats(),
            })
            .collect()
    }

    /// A clone with every tile re-laid out in `fmt` (see
    /// [`LayerMapping::with_storage`]).
    pub fn with_storage(&self, fmt: StorageFormat) -> MappedModel {
        MappedModel {
            layers: self
                .layers
                .iter()
                .map(|l| Arc::new(l.with_storage(fmt)))
                .collect(),
        }
    }

    /// Whether any layer carries map-time permutations.
    pub fn is_reordered(&self) -> bool {
        self.layers.iter().any(|l| l.is_reordered())
    }

    /// Replica view: layer `i` appears `replicas[i].max(1)` times, every
    /// entry an `Arc` handle on the same tiles — no tile is cloned, ever
    /// (assert with [`Arc::ptr_eq`]). The serving backend shards batch
    /// rows across these handles.
    pub fn replicated(&self, replicas: &[usize]) -> ReplicatedModel {
        assert_eq!(
            replicas.len(),
            self.layers.len(),
            "{} replica counts for {} layers",
            replicas.len(),
            self.layers.len()
        );
        ReplicatedModel {
            layers: self
                .layers
                .iter()
                .zip(replicas)
                .map(|(l, &r)| vec![Arc::clone(l); r.max(1)])
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity;
    use crate::util::check::{check, ensure};
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, rng.normal_vec(n, scale)).unwrap()
    }

    #[test]
    fn matrix_view_linear_and_conv() {
        assert_eq!(matrix_view(&[784, 300]).unwrap(), (784, 300));
        assert_eq!(matrix_view(&[3, 3, 64, 128]).unwrap(), (576, 128));
        assert!(matrix_view(&[10]).is_err());
    }

    #[test]
    fn tiling_covers_matrix_exactly() {
        let mut rng = Rng::new(1);
        let w = rand_tensor(&mut rng, vec![300, 200], 0.1);
        let m = map_layer("fc", &w).unwrap();
        let (p, _) = &m.grids[0];
        assert_eq!(p.row_tiles, 3); // ceil(300/128)
        assert_eq!(p.col_tiles, 2); // ceil(200/128)
        assert_eq!(p.tile(0, 0).rows(), 128);
        assert_eq!(p.tile(2, 0).rows(), 44); // 300 - 256
        assert_eq!(p.tile(0, 1).cols(), 72); // 200 - 128
    }

    #[test]
    fn mapped_cells_match_sparsity_census() {
        check(10, |rng| {
            let rows = 1 + rng.below(300);
            let cols = 1 + rng.below(200);
            let w = Tensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 0.1))
                .unwrap();
            let stats = sparsity::census(std::slice::from_ref(&w));
            let m = map_layer("l", &w).unwrap();
            for k in 0..N_SLICES {
                ensure(
                    m.nonzero_cells(k) == stats.nonzero[k],
                    format!("slice {k} cells vs census"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn signs_split_to_differential_arrays() {
        // +1 -> pos grid, -1 -> neg grid, same cell values
        let w = Tensor::new(vec![2, 1], vec![0.5, -0.5]).unwrap();
        let m = map_layer("l", &w).unwrap();
        for k in 0..N_SLICES {
            let (p, n) = &m.grids[k];
            assert_eq!(p.tile(0, 0).get(0, 0), n.tile(0, 0).get(1, 0));
            assert_eq!(p.tile(0, 0).get(1, 0), 0);
            assert_eq!(n.tile(0, 0).get(0, 0), 0);
        }
    }

    #[test]
    fn slices_reconstruct_codes_through_mapping() {
        let mut rng = Rng::new(3);
        let w = rand_tensor(&mut rng, vec![50, 40], 0.2);
        let q = quant::quantize(&w);
        let m = map_layer("l", &w).unwrap();
        for r in 0..50 {
            for c in 0..40 {
                let mut acc = 0u32;
                for k in 0..N_SLICES {
                    let (p, n) = &m.grids[k];
                    let v = p.tile(0, 0).get(r, c).max(n.tile(0, 0).get(r, c));
                    acc += (v as u32) << (2 * k);
                }
                assert_eq!(acc, q.codes[r * 40 + c] as u32, "at ({r},{c})");
            }
        }
    }

    #[test]
    fn conv_kernel_maps_without_error() {
        let mut rng = Rng::new(4);
        let w = rand_tensor(&mut rng, vec![3, 3, 16, 32], 0.1);
        let m = map_layer("conv", &w).unwrap();
        assert_eq!(m.rows, 144);
        assert_eq!(m.cols, 32);
        assert_eq!(m.grids.len(), 4);
        let model = map_model(&[("conv".to_string(), w)]).unwrap();
        assert_eq!(model.total_crossbars(), 4 * m.crossbars_per_slice());
    }

    /// Format selection: a one-signed saturated layer keeps row-major
    /// tiles, a sign-split 50%-density layer packs into bit-planes, and a
    /// near-empty layer compresses every programmed tile.
    #[test]
    fn map_layer_picks_expected_format_per_density() {
        // all +0.99 -> code 253 = 0b11111101: slices 1..=3 are nonzero on
        // every element and everything lands on the pos grid, so those
        // tiles sit at 100% density -> Dense
        let w = Tensor::new(vec![64, 32], vec![0.99f32; 64 * 32]).unwrap();
        let m = map_layer("full", &w).unwrap();
        for (p, _) in &m.grids[1..] {
            for tile in &p.tiles {
                assert_eq!(tile.density(), 1.0);
                assert_eq!(tile.format(), StorageFormat::Dense, "saturated layer");
            }
        }

        // alternating +-0.99: the same codes split 50/50 across the sign
        // grids, so each programmed tile sits at ~50% density -> the mid
        // band, packed bit-planes everywhere
        let w = Tensor::new(
            vec![64, 32],
            (0..64 * 32)
                .map(|i| if i % 2 == 0 { 0.99f32 } else { -0.99 })
                .collect(),
        )
        .unwrap();
        let m = map_layer("mid", &w).unwrap();
        for (p, n) in &m.grids {
            for grid in [p, n] {
                for tile in &grid.tiles {
                    assert!(tile.nonzero_cells() > 0);
                    assert_eq!(
                        tile.format(),
                        StorageFormat::BitPlanes,
                        "sign-split dense-random layer at density {}",
                        tile.density()
                    );
                }
            }
        }
        let s = m.storage_stats();
        assert_eq!(s.compressed_tiles, 0);
        assert_eq!(s.dense_tiles, 0);
        assert_eq!(s.skipped_tiles, 0);
        assert_eq!(s.bitplane_tiles, 8); // 4 slices x 2 signs x 1 tile
        assert_eq!(s.programmed_tiles(), 8);

        // a handful of programmed cells -> every tile compressed (or
        // fully zero and skipped)
        let mut data = vec![0.0f32; 64 * 32];
        for i in 0..20 {
            data[i * 97 % (64 * 32)] = 0.5;
        }
        let w = Tensor::new(vec![64, 32], data).unwrap();
        let m = map_layer("sparse", &w).unwrap();
        for (p, n) in &m.grids {
            for grid in [p, n] {
                for tile in &grid.tiles {
                    if tile.nonzero_cells() > 0 {
                        assert_eq!(
                            tile.format(),
                            StorageFormat::Compressed,
                            "sparse layer tile at density {}",
                            tile.density()
                        );
                    }
                }
            }
        }
        let s = m.storage_stats();
        assert_eq!(s.dense_tiles, 0);
        assert!(s.compressed_tiles > 0);
        assert!(s.bytes < s.dense_bytes, "{} vs {}", s.bytes, s.dense_bytes);
        assert!(s.byte_saving() > 1.0);
    }

    #[test]
    fn storage_stats_are_internally_consistent() {
        check(8, |rng| {
            let rows = 1 + rng.below(300);
            let cols = 1 + rng.below(200);
            let w = Tensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 0.1))
                .unwrap();
            let m = map_layer("l", &w).unwrap();
            let s = m.storage_stats();
            let tiles = N_SLICES * m.crossbars_per_slice(); // pos+neg across slices
            ensure(
                s.programmed_tiles() + s.skipped_tiles == tiles,
                "tile partition",
            )?;
            let programmed: usize = (0..N_SLICES).map(|k| m.nonzero_cells(k)).sum();
            ensure(s.programmed_cells == programmed, "programmed census")?;
            ensure(
                s.cells == 2 * N_SLICES * rows * cols,
                format!("logical cells {} vs {}", s.cells, 2 * N_SLICES * rows * cols),
            )?;
            ensure(s.dense_bytes == s.cells, "dense bytes = one per cell")?;
            ensure(s.active_wordlines <= s.wordline_slots, "wordline bound")?;
            ensure(s.active_columns <= s.column_slots, "column bound")?;
            ensure(
                s.programmed_cells == 0
                    || (s.active_wordlines > 0 && s.active_columns > 0),
                "programmed cells imply active lines",
            )?;
            ensure(
                (0.0..=1.0).contains(&s.wordline_occupancy())
                    && (0.0..=1.0).contains(&s.column_occupancy()),
                "occupancy fractions",
            )?;
            Ok(())
        });
    }

    /// Property: a reordered mapping is a pure relocation — every logical
    /// cell is found at its permuted position with the same value and
    /// sign, the per-slice census is unchanged, and the active-line totals
    /// never grow.
    #[test]
    fn reordered_mapping_relocates_cells_exactly() {
        use crate::reram::reorder::ReorderConfig;
        check(8, |rng| {
            let rows = 1 + rng.below(300);
            let cols = 1 + rng.below(200);
            let fill = rng.below(101);
            let mut data = vec![0.0f32; rows * cols];
            for v in data.iter_mut() {
                if rng.below(100) < fill {
                    *v = (rng.next_f32() - 0.5) * 2.0;
                }
            }
            let w = Tensor::new(vec![rows, cols], data).unwrap();
            let natural = map_layer("l", &w).unwrap();
            let reordered = map_layer_with("l", &w, Some(ReorderConfig::default())).unwrap();
            for k in 0..N_SLICES {
                ensure(
                    reordered.nonzero_cells(k) == natural.nonzero_cells(k),
                    format!("slice {k} census"),
                )?;
                let (np, nn) = &natural.grids[k];
                let (rp, rn) = &reordered.grids[k];
                for r in 0..rows {
                    for c in 0..cols {
                        let (pr, pc) = match &reordered.reorder {
                            Some(ro) => (ro.rows.new_of(r), ro.cols.new_of(c)),
                            None => (r, c),
                        };
                        for (ng, rg) in [(np, rp), (nn, rn)] {
                            let a = ng.tile(r / 128, c / 128).get(r % 128, c % 128);
                            let b = rg.tile(pr / 128, pc / 128).get(pr % 128, pc % 128);
                            ensure(a == b, format!("cell ({r},{c}) slice {k}"))?;
                        }
                    }
                }
            }
            let (ns, rs) = (natural.storage_stats(), reordered.storage_stats());
            ensure(rs.programmed_cells == ns.programmed_cells, "cell census")?;
            ensure(rs.cells == ns.cells, "logical cells")?;
            // (no monotonicity assertion here: on *unstructured* random
            // fills the greedy heuristic is allowed to tie or lose a
            // little — the golden-stats regression test pins the win on
            // the structured fixture where clustering must pay off)
            Ok(())
        });
    }

    #[test]
    fn with_storage_preserves_reorder() {
        use crate::reram::reorder::ReorderConfig;
        let mut rng = Rng::new(11);
        let mut data = vec![0.0f32; 300 * 150];
        for _ in 0..200 {
            data[rng.below(300 * 150)] = rng.normal() * 0.1;
        }
        data[0] = 0.9;
        let w = Tensor::new(vec![300, 150], data).unwrap();
        let m = map_layer_with("l", &w, Some(ReorderConfig::default())).unwrap();
        assert!(m.is_reordered(), "scattered sparse layer reorders");
        for fmt in [
            StorageFormat::Dense,
            StorageFormat::Compressed,
            StorageFormat::BitPlanes,
        ] {
            let conv = m.with_storage(fmt);
            assert_eq!(conv.reorder, m.reorder, "format change kept placement");
        }
        // natural-order mapping carries no permutations
        assert!(!map_layer("l", &w).unwrap().is_reordered());
    }

    /// Replica views are `Arc` handle fan-outs on the same tiles — never
    /// clones — and a model clone is a handle copy too.
    #[test]
    fn replicated_view_shares_tiles_via_arc() {
        let mut rng = Rng::new(13);
        let w = rand_tensor(&mut rng, vec![100, 40], 0.1);
        let model = map_model(&[("a".into(), w.clone()), ("b".into(), w)]).unwrap();
        let rep = model.replicated(&[3, 1]);
        assert_eq!(rep.layers[0].len(), 3);
        assert_eq!(rep.layers[1].len(), 1);
        for h in &rep.layers[0] {
            assert!(
                Arc::ptr_eq(h, &model.layers[0]),
                "replicas are handles, not clones"
            );
        }
        // a zero count still yields one handle (a layer exists at least once)
        assert_eq!(model.replicated(&[0, 1]).layers[0].len(), 1);
        let clone = model.clone();
        assert!(Arc::ptr_eq(&clone.layers[0], &model.layers[0]));
    }

    #[test]
    fn fabricated_cells_count_programmed_tiles_only() {
        // all-positive layer: the negative-sign grids are fully zero and
        // never fabricated, so only the 4 pos tiles carry area
        let w = Tensor::new(vec![64, 32], vec![0.5; 64 * 32]).unwrap();
        let m = map_layer("p", &w).unwrap();
        assert_eq!(m.fabricated_cells(), 4 * 64 * 32);
        // an all-zero layer fabricates nothing
        let z = map_layer("z", &Tensor::zeros(vec![64, 32])).unwrap();
        assert_eq!(z.fabricated_cells(), 0);
    }

    /// `with_storage` round-trips preserve every cell in both directions,
    /// including the partial edge tiles of a non-multiple-of-128 layer.
    #[test]
    fn with_storage_roundtrip_preserves_cells() {
        let mut rng = Rng::new(9);
        let w = rand_tensor(&mut rng, vec![300, 150], 0.08);
        let m = map_layer("l", &w).unwrap();
        for fmt in [
            StorageFormat::Dense,
            StorageFormat::Compressed,
            StorageFormat::BitPlanes,
        ] {
            let conv = m.with_storage(fmt);
            for k in 0..N_SLICES {
                assert_eq!(conv.nonzero_cells(k), m.nonzero_cells(k), "slice {k}");
                let (p0, n0) = &m.grids[k];
                let (p1, n1) = &conv.grids[k];
                for (a, b) in [(p0, p1), (n0, n1)] {
                    for (ta, tb) in a.tiles.iter().zip(&b.tiles) {
                        assert_eq!(tb.format(), fmt);
                        assert_eq!(
                            ta.column_conductance_sums(),
                            tb.column_conductance_sums()
                        );
                    }
                }
            }
        }
    }
}

//! The ADC cost model behind Table 3.
//!
//! From Saberi et al. [17] (SAR ADCs): power is approximately proportional
//! to `2^N / (N + 1)` and sensing time directly proportional to `N`, where
//! N is the resolution in bits. Area is roughly flat below 6 bits and
//! doubles from 6 to 8 bits (the paper: "the area of a 6-bit ADC is
//! approximately the half of an 8-bit ADC but the area varies little when
//! the resolution is lower than 6").
//!
//! The ISAAC baseline [9] deploys 8-bit ADCs even after its ADC
//! optimizations; Table 3's savings are ratios against that baseline.

/// ISAAC baseline ADC resolution (bits).
pub const BASELINE_BITS: u32 = 8;

/// An ADC resolution outside the cost model's domain (the model prices
/// `bits >= 1`; 0-bit ADCs do not exist). The fallible `try_*` accessors
/// return this instead of panicking, so callers holding unvalidated
/// resolutions — CLI-supplied plans, hand-built configs — can surface a
/// typed error (`audit` reports the same condition as diagnostic A007).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResolutionError {
    pub bits: u32,
}

impl std::fmt::Display for ResolutionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ADC resolution {} bits is outside the cost model's domain (resolutions start at 1 bit)",
            self.bits
        )
    }
}

impl std::error::Error for ResolutionError {}

/// Relative ADC cost model (unitless; everything in Table 3 is a ratio).
#[derive(Debug, Clone, Copy)]
pub struct AdcModel;

impl AdcModel {
    fn check(bits: u32) -> Result<u32, ResolutionError> {
        if bits >= 1 {
            Ok(bits)
        } else {
            Err(ResolutionError { bits })
        }
    }

    /// Power ∝ 2^N / (N+1), Saberi et al. [17]. Fallible form of
    /// [`AdcModel::power`] for unvalidated resolutions.
    pub fn try_power(bits: u32) -> Result<f64, ResolutionError> {
        let bits = Self::check(bits)?;
        Ok((2.0f64).powi(bits as i32) / (bits as f64 + 1.0))
    }

    /// Power ∝ 2^N / (N+1). Panics on a 0-bit resolution — callers with
    /// unvalidated input use [`AdcModel::try_power`].
    pub fn power(bits: u32) -> f64 {
        Self::try_power(bits).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Sensing time ∝ N. Fallible form of [`AdcModel::sensing_time`].
    pub fn try_sensing_time(bits: u32) -> Result<f64, ResolutionError> {
        Ok(Self::check(bits)? as f64)
    }

    /// Sensing time ∝ N. Panics on a 0-bit resolution — callers with
    /// unvalidated input use [`AdcModel::try_sensing_time`].
    pub fn sensing_time(bits: u32) -> f64 {
        Self::try_sensing_time(bits).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Relative area (see [`AdcModel::area`]). Fallible form for
    /// unvalidated resolutions.
    pub fn try_area(bits: u32) -> Result<f64, ResolutionError> {
        let bits = Self::check(bits)?;
        Ok(if bits >= 6 {
            (2.0f64).powf((bits as f64 - BASELINE_BITS as f64) / 2.0)
        } else {
            0.5
        })
    }

    /// Relative area: 1.0 at 8 bits, 0.5 at 6 bits, flat (0.5) below 6
    /// (the paper: "the area of a 6-bit ADC is approximately the half of an
    /// 8-bit ADC but the area varies little when the resolution is lower
    /// than 6"). Between 6 and 8 bits: geometric interpolation, 2^((N-8)/2)
    /// — the same formula continues above 8 bits, where area (and every
    /// saving ratio) exceeds the baseline. Panics on a 0-bit resolution —
    /// callers with unvalidated input use [`AdcModel::try_area`].
    pub fn area(bits: u32) -> f64 {
        Self::try_area(bits).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Energy per conversion ∝ power x sensing time... the paper's Table 3
    /// quotes *energy saving* = power(8)/power(N), and *speedup* =
    /// time(8)/time(N); keep those definitions so the table reproduces
    /// exactly.
    pub fn energy_saving(bits: u32) -> f64 {
        Self::power(BASELINE_BITS) / Self::power(bits)
    }

    pub fn speedup(bits: u32) -> f64 {
        Self::sensing_time(BASELINE_BITS) / Self::sensing_time(bits)
    }

    pub fn area_saving(bits: u32) -> f64 {
        Self::area(BASELINE_BITS) / Self::area(bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table3_msb_slice_1bit() {
        // XB_3: 8-bit -> 1-bit ADC
        let e = AdcModel::energy_saving(1);
        assert!((e - 28.4).abs() < 0.1, "energy saving {e} (paper: 28.4x)");
        let s = AdcModel::speedup(1);
        assert!((s - 8.0).abs() < 1e-12, "speedup {s} (paper: 8x)");
        let a = AdcModel::area_saving(1);
        assert!((a - 2.0).abs() < 1e-12, "area saving {a} (paper: 2x)");
    }

    #[test]
    fn paper_table3_low_slices_3bit() {
        // XB_{2,1,0}: 8-bit -> 3-bit ADC
        let e = AdcModel::energy_saving(3);
        assert!((e - 14.2).abs() < 0.05, "energy saving {e} (paper: 14.2x)");
        let s = AdcModel::speedup(3);
        assert!((s - 8.0 / 3.0).abs() < 1e-12, "speedup {s} (paper: 2.67x)");
        let a = AdcModel::area_saving(3);
        assert!((a - 2.0).abs() < 1e-12, "area saving {a} (paper: 2x)");
    }

    #[test]
    fn power_is_monotone_in_bits() {
        for n in 1..12 {
            assert!(AdcModel::power(n + 1) > AdcModel::power(n));
        }
    }

    #[test]
    fn area_flat_below_6_and_halved_at_6() {
        assert_eq!(AdcModel::area(6), 0.5);
        assert_eq!(AdcModel::area(5), 0.5);
        assert_eq!(AdcModel::area(1), 0.5);
        assert_eq!(AdcModel::area(8), 1.0);
        let a7 = AdcModel::area(7);
        assert!(a7 > 0.5 && a7 < 1.0, "area(7) = {a7}");
    }

    #[test]
    fn baseline_savings_are_identity() {
        assert_eq!(AdcModel::energy_saving(8), 1.0);
        assert_eq!(AdcModel::speedup(8), 1.0);
        assert_eq!(AdcModel::area_saving(8), 1.0);
    }

    #[test]
    fn zero_bits_is_a_typed_error_not_a_panic() {
        let err = ResolutionError { bits: 0 };
        assert_eq!(AdcModel::try_power(0), Err(err));
        assert_eq!(AdcModel::try_sensing_time(0), Err(err));
        assert_eq!(AdcModel::try_area(0), Err(err));
        let msg = err.to_string();
        assert!(msg.contains("0 bits"), "error message: {msg}");
        // Valid resolutions agree with the panicking accessors.
        for n in 1..=12 {
            assert_eq!(AdcModel::try_power(n), Ok(AdcModel::power(n)));
            assert_eq!(AdcModel::try_sensing_time(n), Ok(AdcModel::sensing_time(n)));
            assert_eq!(AdcModel::try_area(n), Ok(AdcModel::area(n)));
        }
    }

    #[test]
    #[should_panic(expected = "outside the cost model's domain")]
    fn power_panics_with_the_typed_message_at_zero_bits() {
        AdcModel::power(0);
    }

    #[test]
    fn above_baseline_resolutions_cost_more_than_the_baseline() {
        // The geometric area interpolation continues above 8 bits...
        let a9 = AdcModel::area(9);
        assert!((a9 - 2.0f64.sqrt()).abs() < 1e-12, "area(9) = {a9}");
        assert_eq!(AdcModel::area(10), 2.0);
        // ...so every "saving" ratio drops below 1: a 9-bit ADC is a cost,
        // not a saving, relative to the 8-bit ISAAC baseline.
        assert!(AdcModel::area_saving(9) < 1.0);
        assert!(AdcModel::energy_saving(9) < 1.0);
        assert!(AdcModel::speedup(9) < 1.0);
        assert!((AdcModel::area_saving(10) - 0.5).abs() < 1e-12);
        assert!((AdcModel::speedup(16) - 0.5).abs() < 1e-12);
    }
}

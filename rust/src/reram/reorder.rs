//! Wordline/column reordering: active-row compaction at map time.
//!
//! Bit-slice L1 training leaves each 2-bit slice mostly zero, but the
//! zeros are scattered: every 128x128 tile still holds a few programmed
//! cells, so the simulator's compressed scan pays for active wordlines in
//! every tile and the ADC loop pays for columns in every programmed tile.
//! Bit-level weight reordering (arXiv:2511.14202) fixes the *placement*:
//! permute the layer's wordlines and bitline columns so nonzero cells
//! cluster into a few tiles — the rest become fully zero and are skipped
//! outright — and so each remaining tile's active wordlines and columns
//! shrink. SME (arXiv:2103.01705) makes the same point from the ADC side:
//! the energy win materializes only when the crossbar-level placement
//! concentrates the bit sparsity.
//!
//! # Permutation convention (where codes are permuted, where sums are
//! un-permuted)
//!
//! One [`LayerReorder`] per layer — a wordline [`Permutation`] and a
//! column [`Permutation`] shared by **all** slice groups and both signs,
//! so the digital recombination still adds aligned physical columns:
//!
//! * **Map time** ([`crate::reram::mapper::map_layer_with`]): logical cell
//!   `(r, c)` is programmed at physical position
//!   `(rows.new_of(r), cols.new_of(c))` in the tiled layout.
//! * **Way in** ([`crate::reram::sim::forward_codes_into`]): activation
//!   codes are permuted once per example into physical wordline order
//!   (`perm[rows.new_of(r)] = a_code[r]`) *before* the bit-planes are
//!   materialized, so the hot loop itself never indexes through the
//!   permutation.
//! * **Way out**: the accumulator runs in physical column order; the final
//!   scatter `out[cols.old_of(j)] = acc[j]` restores logical order once
//!   per example.
//!
//! Column reordering is bit-exact at **any** ADC resolution: a logical
//! column's cells move between tiles as one unit, so its per-row-block
//! partial currents — the quantities the ADC clips — are unchanged.
//! Wordline reordering moves rows *across* 128-row tile blocks, which
//! re-partitions the partial sums; it is bit-exact at resolutions wide
//! enough not to clip (e.g. `Lossless`), and at clipping resolutions it is
//! a different — usually no worse — operating point, exactly as a
//! different physical placement would be on real hardware.
//!
//! # The clustering heuristic
//!
//! Greedy column-similarity chaining, per arXiv:2511.14202: each column is
//! summarized by the bitmask of 128-row blocks its nonzeros occupy, the
//! most-populated column seeds the chain, and each step appends the
//! unplaced column sharing the most blocks with the chain tail (fewest
//! fresh blocks, then population, as tie-breaks). Never-occupied columns
//! sort to the end, where whole tiles of them become fully zero. Rows are
//! then chained the same way against the bitmask of *reordered* column
//! blocks they touch. Both passes are deterministic.

use super::crossbar::{XBAR_COLS, XBAR_ROWS};
use super::mapper::{MappedModel, StorageStats};

/// Which axes the map-time reorder pass permutes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReorderConfig {
    /// permute wordlines (input rows) — active-wordline compaction
    pub rows: bool,
    /// permute bitline columns — zero-column clustering
    pub cols: bool,
}

impl Default for ReorderConfig {
    fn default() -> Self {
        ReorderConfig {
            rows: true,
            cols: true,
        }
    }
}

impl ReorderConfig {
    /// Wordline compaction only — bit-exact under clipping is *not*
    /// guaranteed (rows cross tile-block boundaries).
    pub fn rows_only() -> Self {
        ReorderConfig {
            rows: true,
            cols: false,
        }
    }

    /// Column clustering only — bit-exact at every ADC resolution (see
    /// the module docs).
    pub fn cols_only() -> Self {
        ReorderConfig {
            rows: false,
            cols: true,
        }
    }
}

/// A permutation of `0..len` with both directions materialized: `to_new`
/// maps a logical index to its physical position, `to_old` is the inverse.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Permutation {
    /// `to_new[old] = new`
    to_new: Vec<u32>,
    /// `to_old[new] = old`
    to_old: Vec<u32>,
    /// cached at construction so the simulator's per-example identity
    /// checks are O(1), not O(n)
    ident: bool,
}

impl Permutation {
    pub fn identity(n: usize) -> Permutation {
        let v: Vec<u32> = (0..n as u32).collect();
        Permutation {
            to_new: v.clone(),
            to_old: v,
            ident: true,
        }
    }

    /// Build from a placement order: `order[new] = old`. Panics unless
    /// `order` visits every index exactly once.
    pub fn from_order(order: Vec<u32>) -> Permutation {
        let n = order.len();
        let mut to_new = vec![u32::MAX; n];
        let mut ident = true;
        for (new, &old) in order.iter().enumerate() {
            assert!((old as usize) < n, "order index {old} out of 0..{n}");
            assert!(
                to_new[old as usize] == u32::MAX,
                "order visits index {old} twice"
            );
            to_new[old as usize] = new as u32;
            ident &= old as usize == new;
        }
        Permutation {
            to_new,
            to_old: order,
            ident,
        }
    }

    pub fn len(&self) -> usize {
        self.to_new.len()
    }

    pub fn is_empty(&self) -> bool {
        self.to_new.is_empty()
    }

    /// O(1) — cached at construction.
    pub fn is_identity(&self) -> bool {
        self.ident
    }

    /// Physical position of logical index `old`.
    #[inline]
    pub fn new_of(&self, old: usize) -> usize {
        self.to_new[old] as usize
    }

    /// Logical index stored at physical position `new`.
    #[inline]
    pub fn old_of(&self, new: usize) -> usize {
        self.to_old[new] as usize
    }

    /// `to_new` as a slice (`[old] = new`) — the mapper's direction.
    pub fn to_new(&self) -> &[u32] {
        &self.to_new
    }

    /// `to_old` as a slice (`[new] = old`) — the un-permute direction.
    pub fn to_old(&self) -> &[u32] {
        &self.to_old
    }

    /// Test-only raw constructor, bypassing [`Permutation::from_order`]'s
    /// bijectivity asserts — the audit property tests use it to plant
    /// broken permutations (`reram::audit` code A005).
    #[cfg(any(test, feature = "bench"))]
    pub fn from_raw_parts(to_new: Vec<u32>, to_old: Vec<u32>, ident: bool) -> Permutation {
        Permutation {
            to_new,
            to_old,
            ident,
        }
    }
}

/// One layer's planned permutations, stored in
/// [`crate::reram::mapper::LayerMapping::reorder`]. Both permutations are
/// shared by every slice group and both signs (see the module docs for
/// why).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerReorder {
    /// wordline permutation: logical input row `r` drives physical
    /// wordline `rows.new_of(r)`
    pub rows: Permutation,
    /// column permutation: logical output column `c` accumulates on
    /// physical bitline `cols.new_of(c)`
    pub cols: Permutation,
}

impl LayerReorder {
    pub fn is_identity(&self) -> bool {
        self.rows.is_identity() && self.cols.is_identity()
    }
}

/// Greedy similarity chain over items summarized by block-occupancy
/// bitmasks: seed at the most-populated item, then repeatedly append the
/// unplaced item sharing the most blocks with the chain tail (ties: fewest
/// fresh blocks, largest population, lowest index — fully deterministic).
/// Never-occupied items go last in their original order, so whole tiles of
/// them become fully zero. Returns the placement order (`order[new] =
/// old`).
fn similarity_chain(sigs: &[u64], counts: &[u32]) -> Vec<u32> {
    use std::cmp::Reverse;
    let n = sigs.len();
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut used = vec![false; n];
    let live: Vec<usize> = (0..n).filter(|&i| counts[i] > 0).collect();
    if let Some(&seed) = live.iter().max_by_key(|&&i| (counts[i], Reverse(i))) {
        order.push(seed as u32);
        used[seed] = true;
        let mut last = seed;
        for _ in 1..live.len() {
            let next = live
                .iter()
                .copied()
                .filter(|&i| !used[i])
                .max_by_key(|&i| {
                    let shared = (sigs[last] & sigs[i]).count_ones();
                    let fresh = (sigs[i] & !sigs[last]).count_ones();
                    (shared, Reverse(fresh), counts[i], Reverse(i))
                })
                .expect("unplaced live items remain");
            order.push(next as u32);
            used[next] = true;
            last = next;
        }
    }
    order.extend((0..n).filter(|&i| counts[i] == 0).map(|i| i as u32));
    order
}

/// Plan a layer's permutations from its quantized code matrix (`codes[r *
/// cols + c]`, row-major; an element participates in the occupancy iff its
/// code is nonzero — the union of all four slices and both signs, since
/// one permutation pair serves every grid). Returns `None` when the
/// planned permutations are both the identity, so callers store no
/// reorder and the simulator skips the permute/un-permute copies.
pub fn plan_from_codes(
    rows: usize,
    cols: usize,
    codes: &[u8],
    cfg: ReorderConfig,
) -> Option<LayerReorder> {
    assert_eq!(codes.len(), rows * cols, "code matrix shape");
    // column pass: cluster columns whose nonzeros share 128-row blocks
    // (blocks beyond 64 fold with wrap — coarser signatures, same greedy)
    let col_perm = if cfg.cols {
        let mut sigs = vec![0u64; cols];
        let mut counts = vec![0u32; cols];
        for r in 0..rows {
            let block = 1u64 << ((r / XBAR_ROWS) % 64);
            let row = &codes[r * cols..(r + 1) * cols];
            for (c, &v) in row.iter().enumerate() {
                if v != 0 {
                    sigs[c] |= block;
                    counts[c] += 1;
                }
            }
        }
        Permutation::from_order(similarity_chain(&sigs, &counts))
    } else {
        Permutation::identity(cols)
    };
    // row pass: cluster rows whose nonzeros share *reordered* column
    // blocks — run after the column pass so the signatures see the final
    // column placement
    let row_perm = if cfg.rows {
        let mut sigs = vec![0u64; rows];
        let mut counts = vec![0u32; rows];
        for r in 0..rows {
            let row = &codes[r * cols..(r + 1) * cols];
            for (c, &v) in row.iter().enumerate() {
                if v != 0 {
                    sigs[r] |= 1u64 << ((col_perm.new_of(c) / XBAR_COLS) % 64);
                    counts[r] += 1;
                }
            }
        }
        Permutation::from_order(similarity_chain(&sigs, &counts))
    } else {
        Permutation::identity(rows)
    };
    let ro = LayerReorder {
        rows: row_perm,
        cols: col_perm,
    };
    (!ro.is_identity()).then_some(ro)
}

/// One layer's reorder effect: the storage census of the reordered mapping
/// next to the identical layer mapped in natural order — the
/// `report::reorder_table` row.
#[derive(Debug, Clone)]
pub struct ReorderRow {
    pub layer: String,
    /// census of the layer mapped in natural (unpermuted) order
    pub baseline: StorageStats,
    /// census of the reordered mapping
    pub reordered: StorageStats,
}

/// Savings ratio with the all-zero guard: 1.0 when both sides are zero,
/// infinite when only the reordered side is.
fn saving(base: usize, ours: usize) -> f64 {
    if ours == 0 {
        if base == 0 {
            1.0
        } else {
            f64::INFINITY
        }
    } else {
        base as f64 / ours as f64
    }
}

impl ReorderRow {
    /// Active wordlines, natural / reordered (1.0 = no change).
    pub fn wordline_saving(&self) -> f64 {
        saving(self.baseline.active_wordlines, self.reordered.active_wordlines)
    }

    /// Active output columns, natural / reordered.
    pub fn column_saving(&self) -> f64 {
        saving(self.baseline.active_columns, self.reordered.active_columns)
    }

    /// Programmed (fabricated) tiles, natural / reordered.
    pub fn tile_saving(&self) -> f64 {
        saving(
            self.baseline.programmed_tiles(),
            self.reordered.programmed_tiles(),
        )
    }
}

/// Per-layer reorder-effect rows for a (natural, reordered) mapping pair
/// of the same model.
pub fn reorder_rows(baseline: &MappedModel, reordered: &MappedModel) -> Vec<ReorderRow> {
    assert_eq!(
        baseline.layers.len(),
        reordered.layers.len(),
        "mapping layer count"
    );
    baseline
        .layers
        .iter()
        .zip(&reordered.layers)
        .map(|(b, r)| {
            assert_eq!(b.name, r.name, "mapping layer order");
            ReorderRow {
                layer: b.name.clone(),
                baseline: b.storage_stats(),
                reordered: r.storage_stats(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, ensure};

    #[test]
    fn identity_roundtrip_and_flags() {
        let p = Permutation::identity(5);
        assert_eq!(p.len(), 5);
        assert!(p.is_identity());
        assert!(!p.is_empty());
        for i in 0..5 {
            assert_eq!(p.new_of(i), i);
            assert_eq!(p.old_of(i), i);
        }
        assert!(Permutation::identity(0).is_empty());
    }

    #[test]
    fn from_order_inverts_exactly() {
        let p = Permutation::from_order(vec![2, 0, 3, 1]);
        assert!(!p.is_identity());
        // order[new] = old: position 0 holds old index 2
        assert_eq!(p.old_of(0), 2);
        assert_eq!(p.new_of(2), 0);
        for old in 0..4 {
            assert_eq!(p.old_of(p.new_of(old)), old);
        }
        for new in 0..4 {
            assert_eq!(p.new_of(p.old_of(new)), new);
        }
    }

    #[test]
    #[should_panic]
    fn from_order_rejects_duplicates() {
        let _ = Permutation::from_order(vec![0, 0, 1]);
    }

    #[test]
    #[should_panic]
    fn from_order_rejects_out_of_range() {
        let _ = Permutation::from_order(vec![0, 3]);
    }

    /// Property: permutation ∘ inverse = identity in both directions for
    /// every permutation the planner produces, across random shapes and
    /// densities (including all-zero and fully-dense matrices).
    #[test]
    fn planned_permutations_invert_exactly() {
        check(30, |rng| {
            let rows = 1 + rng.below(300);
            let cols = 1 + rng.below(200);
            let fill = rng.below(101);
            let codes: Vec<u8> = (0..rows * cols)
                .map(|_| {
                    if rng.below(100) < fill {
                        1 + rng.below(255) as u8
                    } else {
                        0
                    }
                })
                .collect();
            let Some(ro) = plan_from_codes(rows, cols, &codes, ReorderConfig::default()) else {
                return Ok(()); // identity plan — nothing to invert
            };
            ensure(ro.rows.len() == rows && ro.cols.len() == cols, "lengths")?;
            for r in 0..rows {
                ensure(ro.rows.old_of(ro.rows.new_of(r)) == r, "row inverse")?;
            }
            for c in 0..cols {
                ensure(ro.cols.new_of(ro.cols.old_of(c)) == c, "col inverse")?;
            }
            // both directions are complete permutations: every physical
            // position is hit exactly once
            let mut seen = vec![false; rows];
            for r in 0..rows {
                let p = ro.rows.new_of(r);
                ensure(!seen[p], "row position hit twice")?;
                seen[p] = true;
            }
            Ok(())
        });
    }

    #[test]
    fn all_zero_and_fully_dense_plan_to_identity() {
        // all-zero: no occupancy anywhere — the chain is empty, the zero
        // tail keeps original order, the plan normalizes away
        assert!(plan_from_codes(10, 8, &[0u8; 80], ReorderConfig::default()).is_none());
        // fully dense: every signature is identical, so the chain keeps
        // falling back to index order after the count tie-break — any
        // non-identity outcome would still be valid, but the single-tile
        // case must normalize away (nothing to move between blocks)
        let dense = vec![1u8; 6 * 4];
        if let Some(ro) = plan_from_codes(6, 4, &dense, ReorderConfig::default()) {
            // a plan is allowed, but it must still be a permutation
            for r in 0..6 {
                assert_eq!(ro.rows.old_of(ro.rows.new_of(r)), r);
            }
        }
    }

    #[test]
    fn disabled_axes_stay_identity() {
        let mut codes = vec![0u8; 256 * 300];
        for i in 0..40 {
            codes[(i * 131) % (256 * 300)] = 3;
        }
        let ro = plan_from_codes(256, 300, &codes, ReorderConfig::cols_only())
            .expect("sparse scattered matrix reorders");
        assert!(ro.rows.is_identity(), "rows frozen under cols_only");
        let ro = plan_from_codes(256, 300, &codes, ReorderConfig::rows_only())
            .expect("sparse scattered matrix reorders");
        assert!(ro.cols.is_identity(), "cols frozen under rows_only");
    }

    #[test]
    fn chain_clusters_structured_columns_into_one_block() {
        // 256 rows (2 blocks), 256 cols (2 blocks): nonzero columns are
        // the even indices, each occupied only in row block 0. Clustering
        // must place every occupied column in the first column block.
        let (rows, cols) = (256usize, 256usize);
        let mut codes = vec![0u8; rows * cols];
        for c in (0..cols).step_by(2) {
            codes[c] = 1; // row 0 only
        }
        let ro = plan_from_codes(rows, cols, &codes, ReorderConfig::default()).unwrap();
        for c in (0..cols).step_by(2) {
            assert!(
                ro.cols.new_of(c) < 128,
                "occupied column {c} placed at {}",
                ro.cols.new_of(c)
            );
        }
        // the single occupied row compacts to wordline 0
        assert_eq!(ro.rows.new_of(0), 0);
    }

    #[test]
    fn never_occupied_items_keep_relative_order_at_the_tail() {
        // columns 0 and 2 occupied, 1 and 3 empty: empties go last, in
        // original order
        let codes = vec![1, 0, 1, 0];
        let ro = plan_from_codes(1, 4, &codes, ReorderConfig::cols_only()).unwrap();
        assert!(ro.cols.new_of(0) < 2 && ro.cols.new_of(2) < 2);
        assert_eq!(ro.cols.new_of(1), 2);
        assert_eq!(ro.cols.new_of(3), 3);
    }
}

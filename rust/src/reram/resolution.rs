//! Bitline-current analysis: what ADC resolution does each crossbar group
//! actually need at the achieved bit-slice sparsity?
//!
//! The worst-case bitline current of a column is its conductance sum (all
//! wordlines driving '1'); the ADC must resolve it losslessly if we demand
//! exactness, or cover a high percentile of columns if we accept clipping
//! on outlier columns (the paper's 1-bit/3-bit operating points clip; the
//! accuracy impact is validated by [`super::sim`] and the
//! `mlp_reram_paper` AOT graph).

use crate::quant::N_SLICES;

use super::mapper::MappedModel;

/// How to choose the resolution from the column-current distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ResolutionPolicy {
    /// Cover the maximum column sum exactly (no clipping anywhere).
    Lossless,
    /// Cover the given fraction (e.g. 0.999) of columns; the rest clip.
    Percentile(f64),
}

/// Column-current census for one slice group across the whole model.
#[derive(Debug, Clone)]
pub struct SliceCurrents {
    /// worst-case current (conductance sum) of every mapped column
    pub sums: Vec<u32>,
}

impl SliceCurrents {
    pub fn max(&self) -> u32 {
        self.sums.iter().copied().max().unwrap_or(0)
    }

    pub fn mean(&self) -> f64 {
        if self.sums.is_empty() {
            0.0
        } else {
            self.sums.iter().map(|&s| s as f64).sum::<f64>() / self.sums.len() as f64
        }
    }

    pub fn percentile(&self, p: f64) -> u32 {
        if self.sums.is_empty() {
            return 0;
        }
        let mut sorted = self.sums.clone();
        sorted.sort_unstable();
        let idx = ((sorted.len() as f64 - 1.0) * p.clamp(0.0, 1.0)).round() as usize;
        sorted[idx]
    }
}

/// Bits needed to represent currents up to `max_current` (one LSB = one
/// minimum-conductance cell current): N = ceil(log2(max + 1)), min 1.
pub fn bits_for_current(max_current: u32) -> u32 {
    // codes 0..=max_current -> ceil(log2(max+1)) bits, at least 1
    ((max_current as u64 + 1).next_power_of_two().trailing_zeros()).max(1)
}

/// Gather the column-current census per slice group over a mapped model.
pub fn slice_currents(model: &MappedModel) -> [SliceCurrents; N_SLICES] {
    let mut out: [SliceCurrents; N_SLICES] = std::array::from_fn(|_| SliceCurrents {
        sums: Vec::new(),
    });
    for layer in &model.layers {
        for (k, (pos, neg)) in layer.grids.iter().enumerate() {
            for grid in [pos, neg] {
                for tile in &grid.tiles {
                    out[k].sums.extend(tile.column_conductance_sums());
                }
            }
        }
    }
    out
}

/// Per-slice ADC resolutions under a policy, LSB-first.
pub fn required_bits(model: &MappedModel, policy: ResolutionPolicy) -> [u32; N_SLICES] {
    let currents = slice_currents(model);
    std::array::from_fn(|k| {
        let cur = match policy {
            ResolutionPolicy::Lossless => currents[k].max(),
            ResolutionPolicy::Percentile(p) => currents[k].percentile(p),
        };
        bits_for_current(cur)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::mapper::map_model;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn bits_for_current_boundaries() {
        assert_eq!(bits_for_current(0), 1);
        assert_eq!(bits_for_current(1), 1);
        assert_eq!(bits_for_current(2), 2);
        assert_eq!(bits_for_current(3), 2);
        assert_eq!(bits_for_current(4), 3);
        assert_eq!(bits_for_current(7), 3);
        assert_eq!(bits_for_current(8), 4);
        assert_eq!(bits_for_current(255), 8);
        assert_eq!(bits_for_current(256), 9);
        assert_eq!(bits_for_current(384), 9); // dense 128x3 column
    }

    #[test]
    fn percentile_is_monotone_and_bounded_by_max() {
        let c = SliceCurrents {
            sums: (0..1000u32).collect(),
        };
        assert!(c.percentile(0.5) <= c.percentile(0.999));
        assert!(c.percentile(0.999) <= c.max());
        assert_eq!(c.percentile(1.0), 999);
        assert_eq!(c.percentile(0.0), 0);
    }

    #[test]
    fn dense_model_needs_many_bits_sparse_needs_few() {
        let mut rng = Rng::new(1);
        // dense: every weight near max magnitude -> MSB slice dense
        let dense = Tensor::new(
            vec![128, 64],
            (0..128 * 64)
                .map(|_| if rng.next_f32() > 0.5 { 0.99 } else { -0.99 })
                .collect(),
        )
        .unwrap();
        let m = map_model(&[("d".into(), dense)]).unwrap();
        let bits = required_bits(&m, ResolutionPolicy::Lossless);
        assert!(bits[3] >= 7, "dense MSB slice got {} bits", bits[3]);

        // sparse: one tiny weight per column (cols 0..32) -> max column sum
        // in the LSB slice is 3 (the dynamic-range pin at code 255)
        let mut data = vec![0.0f32; 128 * 64];
        for c in 0..32 {
            data[c] = 1.0 / 256.0; // code 1 (row 0)
        }
        data[127 * 64 + 63] = 1.0; // pin dynamic range: code 255 at (127,63)
        let sparse = Tensor::new(vec![128, 64], data).unwrap();
        let m = map_model(&[("s".into(), sparse)]).unwrap();
        let bits = required_bits(&m, ResolutionPolicy::Lossless);
        assert!(bits[0] <= 2, "sparse LSB slice got {} bits", bits[0]);
    }

    #[test]
    fn lossless_dominates_percentile() {
        let mut rng = Rng::new(2);
        let w = Tensor::new(vec![256, 100], rng.normal_vec(25600, 0.1)).unwrap();
        let m = map_model(&[("w".into(), w)]).unwrap();
        let lossless = required_bits(&m, ResolutionPolicy::Lossless);
        let p99 = required_bits(&m, ResolutionPolicy::Percentile(0.99));
        for k in 0..N_SLICES {
            assert!(p99[k] <= lossless[k]);
        }
    }

    #[test]
    fn msb_slice_needs_fewest_bits_for_gaussian_weights() {
        let mut rng = Rng::new(3);
        let w = Tensor::new(vec![512, 128], rng.normal_vec(512 * 128, 0.05)).unwrap();
        let m = map_model(&[("w".into(), w)]).unwrap();
        let bits = required_bits(&m, ResolutionPolicy::Percentile(0.999));
        // LSB-first: bits[3] is the MSB slice — the paper's XB_3
        assert!(
            bits[3] <= bits[0],
            "MSB {} vs LSB {} bits",
            bits[3],
            bits[0]
        );
    }
}

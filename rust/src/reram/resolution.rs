//! Bitline-current analysis: what ADC resolution does each crossbar group
//! actually need at the achieved bit-slice sparsity?
//!
//! The worst-case bitline current of a column is its conductance sum (all
//! wordlines driving '1'); the ADC must resolve it losslessly if we demand
//! exactness, or cover a high percentile of columns if we accept clipping
//! on outlier columns (the paper's 1-bit/3-bit operating points clip; the
//! accuracy impact is validated by [`super::sim`] and the
//! `mlp_reram_paper` AOT graph).
//!
//! The census is available per layer ([`layer_slice_currents`],
//! [`layer_required_bits`]) as well as whole-model ([`slice_currents`],
//! [`required_bits`]); the per-layer variant feeds
//! [`super::planner::DeploymentPlan`]. Unprogrammed (fully-zero) tiles are
//! excluded — no array is fabricated for them (see [`super::energy`]), so
//! their all-zero columns must not dilute the percentile statistics. All
//! bit arrays here are LSB-first; see the bit-order convention in the
//! [`crate::reram`] module docs.

use crate::quant::N_SLICES;

use super::mapper::{LayerMapping, MappedModel};

/// How to choose the resolution from the column-current distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ResolutionPolicy {
    /// Cover the maximum column sum exactly (no clipping anywhere).
    Lossless,
    /// Cover the given fraction (e.g. 0.999) of columns; the rest clip.
    Percentile(f64),
}

/// Column-current census for one slice group across the whole model.
#[derive(Debug, Clone)]
pub struct SliceCurrents {
    /// worst-case current (conductance sum) of every mapped column
    pub sums: Vec<u32>,
}

impl SliceCurrents {
    pub fn max(&self) -> u32 {
        self.sums.iter().copied().max().unwrap_or(0)
    }

    pub fn mean(&self) -> f64 {
        if self.sums.is_empty() {
            0.0
        } else {
            self.sums.iter().map(|&s| s as f64).sum::<f64>() / self.sums.len() as f64
        }
    }

    /// Ceiling nearest-rank percentile: the smallest census value `v` such
    /// that at least a fraction `p` of the columns satisfy `sum <= v`. A
    /// rounded rank could land *below* the requested coverage (e.g. 1000
    /// columns at p = 0.9991 rounds to rank 999, covering only 99.9%) and
    /// under-provision the ADC; the ceiling rank guarantees >= p coverage.
    pub fn percentile(&self, p: f64) -> u32 {
        if self.sums.is_empty() {
            return 0;
        }
        let mut sorted = self.sums.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let rank = (p.clamp(0.0, 1.0) * n as f64).ceil() as usize;
        sorted[rank.saturating_sub(1).min(n - 1)]
    }
}

/// Bits needed to represent currents up to `max_current` (one LSB = one
/// minimum-conductance cell current): N = ceil(log2(max + 1)), min 1.
pub fn bits_for_current(max_current: u32) -> u32 {
    // codes 0..=max_current -> ceil(log2(max+1)) bits, at least 1
    ((max_current as u64 + 1).next_power_of_two().trailing_zeros()).max(1)
}

/// Gather the column-current census per slice group for one mapped layer.
/// Unprogrammed (fully-zero) tiles contribute no columns: they carry no
/// ADC, so counting their zero sums would bias percentiles downward (the
/// test is the tile's cached census — O(1), no recount). Structurally-zero
/// columns of *compressed* and *bit-plane* tiles are excluded for the
/// same reason: the per-tile nonzero-column index skips their conversions
/// outright
/// ([`crate::reram::crossbar::Crossbar::bitline_currents_active`]), so no
/// ADC ever sees them — with reordering they additionally cluster into
/// whole skipped tiles. Dense tiles carry no index: every one of their
/// columns converts, so every one enters the census. The census therefore
/// covers exactly the conversions [`crate::reram::energy`] bills.
pub fn layer_slice_currents(layer: &LayerMapping) -> [SliceCurrents; N_SLICES] {
    let mut out: [SliceCurrents; N_SLICES] = std::array::from_fn(|_| SliceCurrents {
        sums: Vec::new(),
    });
    for (k, (pos, neg)) in layer.grids.iter().enumerate() {
        for grid in [pos, neg] {
            for tile in &grid.tiles {
                if tile.nonzero_cells() == 0 {
                    continue;
                }
                let sums = tile.column_conductance_sums();
                if tile.active_cols().is_some() {
                    // indexed layouts: only indexed (converting) columns
                    out[k].sums.extend(sums.into_iter().filter(|&s| s > 0));
                } else {
                    // dense: every column converts, zeros included
                    out[k].sums.extend(sums);
                }
            }
        }
    }
    out
}

/// Gather the column-current census per slice group over a mapped model.
pub fn slice_currents(model: &MappedModel) -> [SliceCurrents; N_SLICES] {
    let mut out: [SliceCurrents; N_SLICES] = std::array::from_fn(|_| SliceCurrents {
        sums: Vec::new(),
    });
    for layer in &model.layers {
        for (k, cur) in layer_slice_currents(layer).into_iter().enumerate() {
            out[k].sums.extend(cur.sums);
        }
    }
    out
}

fn bits_under_policy(
    currents: &[SliceCurrents; N_SLICES],
    policy: ResolutionPolicy,
) -> [u32; N_SLICES] {
    std::array::from_fn(|k| {
        let cur = match policy {
            ResolutionPolicy::Lossless => currents[k].max(),
            ResolutionPolicy::Percentile(p) => currents[k].percentile(p),
        };
        bits_for_current(cur)
    })
}

/// Per-slice ADC resolutions one layer needs under a policy, LSB-first —
/// the per-layer starting point of [`super::planner::plan_deployment`].
pub fn layer_required_bits(layer: &LayerMapping, policy: ResolutionPolicy) -> [u32; N_SLICES] {
    bits_under_policy(&layer_slice_currents(layer), policy)
}

/// Per-slice ADC resolutions under a policy over the whole model,
/// LSB-first (the Table-3 single-operating-point semantics).
pub fn required_bits(model: &MappedModel, policy: ResolutionPolicy) -> [u32; N_SLICES] {
    bits_under_policy(&slice_currents(model), policy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::mapper::map_model;
    use crate::tensor::Tensor;
    use crate::util::check::{check, ensure};
    use crate::util::rng::Rng;

    #[test]
    fn bits_for_current_boundaries() {
        assert_eq!(bits_for_current(0), 1);
        assert_eq!(bits_for_current(1), 1);
        assert_eq!(bits_for_current(2), 2);
        assert_eq!(bits_for_current(3), 2);
        assert_eq!(bits_for_current(4), 3);
        assert_eq!(bits_for_current(7), 3);
        assert_eq!(bits_for_current(8), 4);
        assert_eq!(bits_for_current(255), 8);
        assert_eq!(bits_for_current(256), 9);
        assert_eq!(bits_for_current(384), 9); // dense 128x3 column
    }

    #[test]
    fn percentile_is_monotone_and_bounded_by_max() {
        let c = SliceCurrents {
            sums: (0..1000u32).collect(),
        };
        assert!(c.percentile(0.5) <= c.percentile(0.999));
        assert!(c.percentile(0.999) <= c.max());
        assert_eq!(c.percentile(1.0), 999);
        assert_eq!(c.percentile(0.0), 0);
    }

    #[test]
    fn percentile_never_under_covers() {
        // the old rounded nearest-rank picked rank 999 here (99.9% < p)
        let c = SliceCurrents {
            sums: (0..1000u32).collect(),
        };
        assert_eq!(c.percentile(0.9991), 999);
        // ceiling-rank guarantee on arbitrary (p, n)
        check(50, |rng| {
            let n = 1 + rng.below(40);
            let sums: Vec<u32> = (0..n).map(|_| rng.below(500) as u32).collect();
            let c = SliceCurrents { sums: sums.clone() };
            let p = rng.next_f32() as f64;
            let v = c.percentile(p);
            let covered = sums.iter().filter(|&&s| s <= v).count();
            ensure(
                covered as f64 >= p * n as f64 - 1e-9,
                format!("p={p} n={n}: value {v} covers only {covered}"),
            )?;
            Ok(())
        });
    }

    #[test]
    fn percentile_boundaries_at_small_lengths() {
        let one = SliceCurrents { sums: vec![7] };
        assert_eq!(one.percentile(0.0), 7);
        assert_eq!(one.percentile(0.5), 7);
        assert_eq!(one.percentile(1.0), 7);

        let two = SliceCurrents { sums: vec![9, 1] };
        assert_eq!(two.percentile(0.0), 1);
        // exactly half the columns are <= 1: rank ceil(0.5 * 2) = 1
        assert_eq!(two.percentile(0.5), 1);
        // any coverage beyond half needs the larger value
        assert_eq!(two.percentile(0.51), 9);
        assert_eq!(two.percentile(1.0), 9);

        let empty = SliceCurrents { sums: vec![] };
        assert_eq!(empty.percentile(0.9), 0);
    }

    #[test]
    fn per_layer_census_concatenates_to_model_census() {
        let mut rng = Rng::new(7);
        let w1 = Tensor::new(vec![200, 60], rng.normal_vec(200 * 60, 0.1)).unwrap();
        let w2 = Tensor::new(vec![60, 30], rng.normal_vec(60 * 30, 0.1)).unwrap();
        let m = map_model(&[("a".into(), w1), ("b".into(), w2)]).unwrap();
        let whole = slice_currents(&m);
        for k in 0..N_SLICES {
            let mut concat = Vec::new();
            for layer in &m.layers {
                concat.extend(layer_slice_currents(layer)[k].sums.clone());
            }
            assert_eq!(whole[k].sums, concat, "slice {k}");
        }
    }

    #[test]
    fn census_skips_structurally_zero_columns() {
        // a programmed tile whose columns 1..31 hold no cell: only the
        // converting columns (0 and the pin column) may enter the census
        let mut data = vec![0.0f32; 64 * 32];
        for r in 0..64 {
            data[r * 32] = 0.5; // column 0 fully populated
        }
        data[63 * 32 + 31] = 1.0; // dynamic-range pin in column 31
        let w = Tensor::new(vec![64, 32], data).unwrap();
        let m = map_model(&[("z".into(), w)]).unwrap();
        let currents = layer_slice_currents(&m.layers[0]);
        for (k, cur) in currents.iter().enumerate() {
            assert!(
                cur.sums.len() <= 2,
                "slice {k}: {} columns entered the census",
                cur.sums.len()
            );
            assert!(cur.sums.iter().all(|&s| s > 0), "slice {k}");
        }
        // a zero-heavy census would drag the percentile to 0 bits; the
        // filtered census sizes the ADC for the columns that convert
        let bits = required_bits(&m, ResolutionPolicy::Percentile(0.5));
        assert!(bits.iter().all(|&b| b >= 1));
    }

    #[test]
    fn census_skips_unprogrammed_tiles() {
        // all-positive weights: every negative-sign grid is fully zero and
        // must contribute no columns to the census
        let w = Tensor::new(vec![64, 32], vec![0.5; 64 * 32]).unwrap();
        let m = map_model(&[("p".into(), w)]).unwrap();
        let currents = slice_currents(&m);
        for (k, cur) in currents.iter().enumerate() {
            // one programmed (pos) tile of 32 columns; the neg tile is out
            assert_eq!(cur.sums.len(), 32, "slice {k}");
            assert!(cur.sums.iter().all(|&s| s > 0), "slice {k}");
        }
    }

    #[test]
    fn dense_model_needs_many_bits_sparse_needs_few() {
        let mut rng = Rng::new(1);
        // dense: every weight near max magnitude -> MSB slice dense
        let dense = Tensor::new(
            vec![128, 64],
            (0..128 * 64)
                .map(|_| if rng.next_f32() > 0.5 { 0.99 } else { -0.99 })
                .collect(),
        )
        .unwrap();
        let m = map_model(&[("d".into(), dense)]).unwrap();
        let bits = required_bits(&m, ResolutionPolicy::Lossless);
        assert!(bits[3] >= 7, "dense MSB slice got {} bits", bits[3]);

        // sparse: one tiny weight per column (cols 0..32) -> max column sum
        // in the LSB slice is 3 (the dynamic-range pin at code 255)
        let mut data = vec![0.0f32; 128 * 64];
        for c in 0..32 {
            data[c] = 1.0 / 256.0; // code 1 (row 0)
        }
        data[127 * 64 + 63] = 1.0; // pin dynamic range: code 255 at (127,63)
        let sparse = Tensor::new(vec![128, 64], data).unwrap();
        let m = map_model(&[("s".into(), sparse)]).unwrap();
        let bits = required_bits(&m, ResolutionPolicy::Lossless);
        assert!(bits[0] <= 2, "sparse LSB slice got {} bits", bits[0]);
    }

    #[test]
    fn lossless_dominates_percentile() {
        let mut rng = Rng::new(2);
        let w = Tensor::new(vec![256, 100], rng.normal_vec(25600, 0.1)).unwrap();
        let m = map_model(&[("w".into(), w)]).unwrap();
        let lossless = required_bits(&m, ResolutionPolicy::Lossless);
        let p99 = required_bits(&m, ResolutionPolicy::Percentile(0.99));
        for k in 0..N_SLICES {
            assert!(p99[k] <= lossless[k]);
        }
    }

    #[test]
    fn msb_slice_needs_fewest_bits_for_gaussian_weights() {
        let mut rng = Rng::new(3);
        let w = Tensor::new(vec![512, 128], rng.normal_vec(512 * 128, 0.05)).unwrap();
        let m = map_model(&[("w".into(), w)]).unwrap();
        let bits = required_bits(&m, ResolutionPolicy::Percentile(0.999));
        // LSB-first: bits[3] is the MSB slice — the paper's XB_3
        assert!(
            bits[3] <= bits[0],
            "MSB {} vs LSB {} bits",
            bits[3],
            bits[0]
        );
    }
}

//! Functional crossbar inference simulator.
//!
//! Runs a mapped layer the way the hardware would: activations are
//! quantized to 8-bit codes and driven bit-serially (1-bit DACs); each
//! bit-plane's bitline currents pass through the ADC transfer function
//! (clip at 2^N - 1 LSBs) *per crossbar*; tile partial sums, slice shifts
//! and the sign difference recombine digitally. This mirrors the L1
//! `crossbar.py` Pallas kernel (same clipping point, same recombination
//! order) and is cross-checked against it by the integration tests.

use crate::quant::{self, N_SLICES};
use crate::tensor::Tensor;
use crate::util::pool::{parallel_map, with_scratch, worker_threads};

use super::crossbar::{pack_code_wave, StorageFormat};
use super::device::LayerDevice;
use super::mapper::LayerMapping;

/// Quantize non-negative activations to codes (mirrors L2 `_act_quantize`)
/// into a reusable buffer; returns the quantization step. Callers on the
/// hot path keep one `codes` buffer per worker so repeated quantization
/// does not allocate.
pub fn act_quantize_into(x: &[f32], codes: &mut Vec<u8>) -> f32 {
    let step = quant::qstep(x);
    let inv = 1.0 / step;
    codes.clear();
    codes.extend(
        x.iter()
            .map(|&v| ((v.max(0.0) * inv).floor()).min(quant::CODE_MAX as f32) as u8),
    );
    step
}

/// Allocating convenience wrapper around [`act_quantize_into`].
pub fn act_quantize(x: &[f32]) -> (Vec<u8>, f32) {
    let mut codes = Vec::with_capacity(x.len());
    let step = act_quantize_into(x, &mut codes);
    (codes, step)
}

/// ADC transfer function: clip at full scale. Resolutions of 32 bits or
/// more cover every representable current, so they pass through unclipped
/// (a shifted `(1 << bits) - 1` would overflow there).
#[inline]
pub fn adc_clip(current: u32, bits: u32) -> u32 {
    if bits >= 32 {
        current
    } else {
        current.min((1u32 << bits) - 1)
    }
}

/// Reusable per-example buffers for [`forward_codes_into`]: the 8
/// activation bit-planes (byte and packed wave forms), the per-tile
/// bitline-current accumulator, and — for reordered mappings — the
/// permuted code vector and the physical-column accumulator. One
/// `SimScratch` per worker thread keeps the hot loop allocation-free.
#[derive(Debug, Default)]
pub struct SimScratch {
    /// plane-major: `planes[t * rows + r]` is bit t of activation code r.
    /// Built only when the layer holds a byte-layout (Dense/Compressed)
    /// programmed tile; empty for all-BitPlanes layers.
    planes: Vec<u8>,
    /// the activation bit-planes packed per tile row-span into the
    /// `[u64; 2]` wave-mask form of the BitPlanes convention:
    /// `waves[t * row_tiles + tr]` covers rows `tr * 128 ..` of plane t.
    /// Always built (straight from the codes), in every layout.
    waves: Vec<[u64; 2]>,
    /// current accumulator, sliced per tile to `tile.cols()`
    cur: Vec<u32>,
    /// activation codes permuted into physical wordline order (reordered
    /// mappings only)
    perm_codes: Vec<u8>,
    /// physical-column accumulator, un-permuted into `out` at the end
    /// (reordered mappings only)
    phys: Vec<i64>,
    /// float bitline-current accumulator for the noisy device path,
    /// sliced per tile like `cur`; untouched (never even sized) when no
    /// device model is attached
    fcur: Vec<f32>,
}

/// Run one example (activation code vector) through a mapped layer,
/// writing the integer-domain result (code units) into `out`; multiply by
/// `layer.step * act_step` for real units. `adc_bits[k]` is the resolution
/// of slice group k (LSB-first). The packed activation waves are built
/// once per (plane, tile row-span) straight from the code vector
/// ([`pack_code_wave`]); the 8 byte bit-planes are materialized only when
/// the layer actually holds a byte-layout (Dense/Compressed) programmed
/// tile that will scan them — an all-BitPlanes layer skips the byte
/// transpose it never reads. All buffers live in `scratch` and the
/// current buffer is reused across tiles and storage representations, so
/// repeated calls do not allocate. Fully-zero tiles (e.g. the empty
/// negative grid of an all-positive layer) are skipped outright — they
/// contribute no current, and the cached per-tile census makes the check
/// O(1). Bit-plane tiles consume the wave directly through the popcount
/// path ([`Crossbar::bitline_currents_wave`]), and an all-zero wave skips
/// the whole row-block — no wordline is driven, so every current is
/// identically zero and every ADC conversion of that plane is dropped
/// bit-exactly, in every layout. Within each programmed indexed tile, the
/// ADC/recombination loop walks only the tile's nonzero-column index
/// ([`Crossbar::bitline_currents_active`]): structurally-zero columns
/// carry no current and no conversion, closing the remaining O(cols) term
/// at extreme sparsity.
///
/// Reordered mappings ([`LayerMapping::reorder`]) are handled entirely at
/// the boundaries, per the convention in [`crate::reram::reorder`]: the
/// codes are permuted into physical wordline order once, before the
/// planes are built, and the accumulator runs in physical column order
/// and is scattered back to logical order once at the end — the tile loop
/// itself never indexes through a permutation.
///
/// [`Crossbar::bitline_currents_active`]:
/// crate::reram::crossbar::Crossbar::bitline_currents_active
/// [`Crossbar::bitline_currents_wave`]:
/// crate::reram::crossbar::Crossbar::bitline_currents_wave
pub fn forward_codes_into(
    layer: &LayerMapping,
    a_code: &[u8],
    adc_bits: &[u32; N_SLICES],
    scratch: &mut SimScratch,
    out: &mut Vec<i64>,
) {
    forward_codes_device_into(layer, a_code, adc_bits, None, scratch, out);
}

/// [`forward_codes_into`] with an optional device non-ideality model (the
/// layer's slice of a [`crate::reram::device::DeviceModel`]). With
/// `device` attached, every programmed tile reads through
/// [`TileNoise::bitline_currents`][crate::reram::device::TileNoise]:
/// currents accumulate in float over the tile's perturbed conductances,
/// per-conversion read noise is added, and the result is rounded to the
/// nearest current LSB (clamped at 0 — a bitline cannot source negative
/// current) before the usual ADC clip. Only columns holding at least one
/// programmed cell are sensed, matching the indexed ideal path, and the
/// zero-wave / zero-tile skips stay in force (no wordline driven ⇒ no
/// conversion ⇒ no read noise). `device = None` is byte-for-byte the
/// ideal path: the float buffer is never touched and no branch runs per
/// cell. An all-zero [`DeviceConfig`][crate::reram::device::DeviceConfig]
/// attached is bit-exact to `None`: conductances are the exact integers,
/// float accumulation of ≤ 128 cells × [`CELL_MAX`] is exact, and
/// round-to-nearest is the identity on integers.
///
/// [`CELL_MAX`]: crate::reram::crossbar::CELL_MAX
pub fn forward_codes_device_into(
    layer: &LayerMapping,
    a_code: &[u8],
    adc_bits: &[u32; N_SLICES],
    device: Option<&LayerDevice>,
    scratch: &mut SimScratch,
    out: &mut Vec<i64>,
) {
    assert_eq!(a_code.len(), layer.rows, "activation length");
    let rows = layer.rows;
    out.clear();
    out.resize(layer.cols, 0);
    let SimScratch {
        planes,
        waves,
        cur,
        perm_codes,
        phys,
        fcur,
    } = scratch;
    // way in: permute codes into physical wordline order (reorder only)
    let codes: &[u8] = match &layer.reorder {
        Some(ro) if !ro.rows.is_identity() => {
            perm_codes.clear();
            perm_codes.resize(rows, 0);
            for (old, &new) in ro.rows.to_new().iter().enumerate() {
                perm_codes[new as usize] = a_code[old];
            }
            perm_codes
        }
        _ => a_code,
    };
    // packed wave masks, built straight from the codes once per
    // (plane, tile row-span) — what the bit-plane tiles and the
    // zero-wave skip consume, in every layout
    let row_tiles = rows.div_ceil(super::XBAR_ROWS);
    waves.clear();
    waves.resize(8 * row_tiles, [0u64; 2]);
    for (t, span) in waves.chunks_exact_mut(row_tiles).enumerate() {
        for (tr, wave) in span.iter_mut().enumerate() {
            let r0 = tr * super::XBAR_ROWS;
            let r1 = (r0 + super::XBAR_ROWS).min(rows);
            *wave = pack_code_wave(&codes[r0..r1], t as u32);
        }
    }
    // the byte bit-planes exist only for byte-layout (Dense/Compressed)
    // tiles — an all-BitPlanes layer never reads them, so skip the
    // transpose entirely; the noisy device path reads the packed waves
    // exclusively, so it never needs them either
    let needs_bytes = device.is_none() && layer.grids.iter().any(|(pos, neg)| {
        [pos, neg].into_iter().any(|grid| {
            (0..grid.row_tiles * grid.col_tiles).any(|i| {
                let tile = grid.tile(i / grid.col_tiles, i % grid.col_tiles);
                tile.nonzero_cells() > 0 && tile.format() != StorageFormat::BitPlanes
            })
        })
    });
    planes.clear();
    if needs_bytes {
        planes.resize(8 * rows, 0);
        for (r, &c) in codes.iter().enumerate() {
            for t in 0..8usize {
                planes[t * rows + r] = (c >> t) & 1;
            }
        }
    }
    cur.resize(super::XBAR_COLS, 0);
    if device.is_some() {
        fcur.resize(super::XBAR_COLS, 0.0);
    }
    // the accumulator runs in physical column order; unless the *column*
    // permutation is real, physical == logical and it writes `out`
    // directly (a rows-only reorder needs no output detour)
    let col_permuted = layer
        .reorder
        .as_ref()
        .is_some_and(|ro| !ro.cols.is_identity());
    if col_permuted {
        phys.clear();
        phys.resize(layer.cols, 0);
    }
    let acc: &mut [i64] = if col_permuted { &mut phys[..] } else { &mut out[..] };
    // bit-serial over the 8 activation bit planes
    for t in 0..8u32 {
        // empty when !needs_bytes — the byte branch is unreachable then,
        // since every programmed tile dispatches to the wave path
        let bits: &[u8] = if needs_bytes {
            &planes[t as usize * rows..(t as usize + 1) * rows]
        } else {
            &[]
        };
        let plane_waves = &waves[t as usize * row_tiles..(t as usize + 1) * row_tiles];
        for (k, (pos, neg)) in layer.grids.iter().enumerate() {
            let full = adc_bits[k];
            for (si, (grid, sign)) in [(pos, 1i64), (neg, -1i64)].into_iter().enumerate() {
                for tr in 0..grid.row_tiles {
                    let r0 = tr * super::XBAR_ROWS;
                    let wave = &plane_waves[tr];
                    if *wave == [0, 0] {
                        // zero-wave skip: no wordline of this row-block is
                        // driven on this plane, so every current is
                        // identically zero and adc_clip(0) contributes
                        // nothing — drop the whole block's accumulation
                        // and ADC conversions, in every layout
                        continue;
                    }
                    for tc in 0..grid.col_tiles {
                        let tile = grid.tile(tr, tc);
                        if tile.nonzero_cells() == 0 {
                            continue; // unprogrammed tile: no current
                        }
                        let c0 = tc * super::XBAR_COLS;
                        // noisy device path: accumulate the tile's
                        // perturbed conductances in float over the same
                        // packed wave, round to the nearest current LSB,
                        // then clip as usual — only programmed columns
                        // are sensed, as on the indexed ideal path
                        if let Some(dev) = device {
                            let tn = dev
                                .tile(k, si, tr, tc)
                                .expect("programmed tile has a device realization");
                            let fcur = &mut fcur[..tile.cols()];
                            let active = tn.bitline_currents(wave, dev.read_sigma, t, fcur);
                            for &j in active {
                                let j = j as usize;
                                let i_raw = fcur[j].max(0.0).round() as u32;
                                let i_adc = adc_clip(i_raw, full) as i64;
                                acc[c0 + j] +=
                                    sign * i_adc * (1i64 << t) * (1i64 << (2 * k));
                            }
                            continue;
                        }
                        let cur = &mut cur[..tile.cols()];
                        // bit-plane tiles take the popcount path on the
                        // packed wave; byte layouts scan the byte plane
                        let idx = if tile.format() == StorageFormat::BitPlanes {
                            tile.bitline_currents_wave(wave, cur)
                        } else {
                            tile.bitline_currents_active(&bits[r0..r0 + tile.rows()], cur)
                        };
                        match idx {
                            // indexed tile: convert only the columns
                            // that hold programmed cells — zero columns
                            // contribute nothing by construction
                            Some(active) => {
                                for &j in active {
                                    let j = j as usize;
                                    let i_adc = adc_clip(cur[j], full) as i64;
                                    acc[c0 + j] +=
                                        sign * i_adc * (1i64 << t) * (1i64 << (2 * k));
                                }
                            }
                            // dense tile: every column converts
                            None => {
                                for (j, &i_raw) in cur.iter().enumerate() {
                                    let i_adc = adc_clip(i_raw, full) as i64;
                                    acc[c0 + j] +=
                                        sign * i_adc * (1i64 << t) * (1i64 << (2 * k));
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    // way out: scatter physical-column sums back to logical order
    if col_permuted {
        let ro = layer.reorder.as_ref().expect("col_permuted implies reorder");
        for (new, &old) in ro.cols.to_old().iter().enumerate() {
            out[old as usize] = phys[new];
        }
    }
}

/// Allocating convenience wrapper around [`forward_codes_into`].
pub fn forward_codes(layer: &LayerMapping, a_code: &[u8], adc_bits: &[u32; N_SLICES]) -> Vec<i64> {
    let mut scratch = SimScratch::default();
    let mut out = Vec::new();
    forward_codes_into(layer, a_code, adc_bits, &mut scratch, &mut out);
    out
}

/// Batched real-units forward: `x` is (batch, rows) in [0, ∞), returns
/// (batch, cols) approximating `x @ W`. Examples are processed in parallel
/// (one `forward_codes` per row).
///
/// Activations are quantized **per example row** (each row gets its own
/// qstep), matching `serve::CrossbarBackend` and the backend contract in
/// `serve`: the result is bit-identical however the batch is composed. A
/// batch-global qstep — the previous behaviour — made the simulator's
/// answer depend on which *other* examples shared the batch.
///
/// §Perf note (EXPERIMENTS.md iteration 6): a tile-resident batched variant
/// (accumulate all examples per cell pass) was implemented and measured
/// 0.68x — the per-example current accumulators evict the tile from L1 —
/// so this simpler form is kept; it already runs at ~1e10 cell-ops/s,
/// 100x over the DESIGN.md target. Examples are chunked per worker and
/// each chunk borrows the executor worker's persistent scratch slot
/// ([`crate::util::pool::with_scratch`]), so the [`SimScratch`] wave-pack
/// buffers are reused not just within a batch but **across** batches.
pub fn forward(layer: &LayerMapping, x: &Tensor, adc_bits: &[u32; N_SLICES]) -> Tensor {
    let shape = x.shape();
    assert_eq!(shape.len(), 2);
    let (b, rows) = (shape[0], shape[1]);
    assert_eq!(rows, layer.rows);
    let data = x.data();
    // one worker-count policy with the serving backends (util::pool)
    let threads = worker_threads();
    let chunk = b.div_ceil(threads.max(1)).max(1);
    let parts = parallel_map(b.div_ceil(chunk), threads, |ci| {
        let lo = ci * chunk;
        let hi = (lo + chunk).min(b);
        with_scratch::<(SimScratch, Vec<i64>, Vec<u8>), _>(|state| {
            let (scratch, raw, codes) = state;
            let mut part = Vec::with_capacity((hi - lo) * layer.cols);
            for i in lo..hi {
                let a_step = act_quantize_into(&data[i * rows..(i + 1) * rows], codes);
                let scale = layer.step * a_step;
                forward_codes_into(layer, codes, adc_bits, scratch, raw);
                part.extend(raw.iter().map(|&v| v as f32 * scale));
            }
            part
        })
    });
    let mut data = Vec::with_capacity(b * layer.cols);
    for p in parts {
        data.extend(p);
    }
    Tensor::new(vec![b, layer.cols], data).expect("forward shape")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::mapper::map_layer;
    use crate::util::check::{check, ensure};
    use crate::util::rng::Rng;

    const LOSSLESS: [u32; N_SLICES] = [10, 10, 10, 10];

    #[test]
    fn lossless_sim_matches_quantized_matmul() {
        check(8, |rng| {
            let rows = 1 + rng.below(200);
            let cols = 1 + rng.below(60);
            let b = 1 + rng.below(4);
            let w = Tensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 0.1))
                .unwrap();
            let x = Tensor::new(
                vec![b, rows],
                (0..b * rows).map(|_| rng.next_f32()).collect(),
            )
            .unwrap();
            let layer = map_layer("l", &w).unwrap();
            let out = forward(&layer, &x, &LOSSLESS);

            // the promoted exact quantized matmul (serve::reference)
            let want = crate::serve::reference::quantized_matmul(&x, &w)
                .map_err(|e| e.to_string())?;
            for (got, want) in out.data().iter().zip(want.data()) {
                let tol = 1e-4 * want.abs().max(1.0);
                ensure(
                    (got - want).abs() <= tol,
                    format!("sim {got} vs exact {want}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn adc_clip_boundaries() {
        assert_eq!(adc_clip(0, 1), 0);
        assert_eq!(adc_clip(1, 1), 1);
        assert_eq!(adc_clip(5, 1), 1);
        assert_eq!(adc_clip(7, 3), 7);
        assert_eq!(adc_clip(8, 3), 7);
    }

    #[test]
    fn adc_clip_saturates_at_wide_resolutions() {
        // bits >= 32 covers every u32 current: no clipping, no overflow
        assert_eq!(adc_clip(u32::MAX, 32), u32::MAX);
        assert_eq!(adc_clip(5, 32), 5);
        assert_eq!(adc_clip(u32::MAX, 40), u32::MAX);
        // 31 bits is the widest shifted full scale
        assert_eq!(adc_clip(u32::MAX, 31), (1u32 << 31) - 1);
        assert_eq!(adc_clip((1u32 << 31) - 2, 31), (1u32 << 31) - 2);
    }

    #[test]
    fn forward_codes_into_reuses_buffers_and_matches_wrapper() {
        let mut rng = Rng::new(21);
        let w = Tensor::new(vec![200, 40], rng.normal_vec(200 * 40, 0.1)).unwrap();
        let layer = map_layer("l", &w).unwrap();
        let mut scratch = SimScratch::default();
        let mut out = Vec::new();
        for _ in 0..3 {
            let code: Vec<u8> = (0..200).map(|_| rng.below(256) as u8).collect();
            forward_codes_into(&layer, &code, &LOSSLESS, &mut scratch, &mut out);
            assert_eq!(out, forward_codes(&layer, &code, &LOSSLESS));
        }
    }

    #[test]
    fn reduced_adc_only_loses_on_clipped_columns() {
        // sparse weights: reduced resolution must be exact because no
        // column current ever exceeds the full scale
        let mut data = vec![0.0f32; 128 * 8];
        for c in 0..8 {
            data[c * 128 / 8 * 8 + c] = 0.9; // one big weight per column
        }
        data[0] = 1.0;
        let w = Tensor::new(vec![128, 8], data).unwrap();
        let layer = map_layer("l", &w).unwrap();
        let mut rng = Rng::new(5);
        let x = Tensor::new(vec![2, 128], (0..256).map(|_| rng.next_f32()).collect())
            .unwrap();
        let low = forward(&layer, &x, &[2, 2, 2, 2]);
        let high = forward(&layer, &x, &LOSSLESS);
        // single cell per column => max current 3 => 2 bits lossless
        for (a, b) in low.data().iter().zip(high.data()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn forward_is_batch_composition_invariant() {
        // rows at deliberately different dynamic ranges: a batch-global
        // qstep (the old bug) quantized the small-magnitude rows with the
        // large rows' step, so their outputs depended on batch composition
        let mut rng = Rng::new(33);
        let w = Tensor::new(vec![200, 30], rng.normal_vec(200 * 30, 0.1)).unwrap();
        let layer = map_layer("l", &w).unwrap();
        let scales = [1.0f32, 0.3, 0.07, 0.011];
        let data: Vec<f32> = scales
            .iter()
            .flat_map(|&s| (0..200).map(|_| s * rng.next_f32()).collect::<Vec<_>>())
            .collect();
        let x = Tensor::new(vec![4, 200], data).unwrap();
        let all = forward(&layer, &x, &LOSSLESS);
        for i in 0..4 {
            let row =
                Tensor::new(vec![1, 200], x.data()[i * 200..(i + 1) * 200].to_vec()).unwrap();
            let one = forward(&layer, &row, &LOSSLESS);
            assert_eq!(
                &all.data()[i * 30..(i + 1) * 30],
                one.data(),
                "row {i} (scale {})",
                scales[i]
            );
        }
    }

    #[test]
    fn act_quantize_into_matches_wrapper_and_reuses_buffer() {
        let mut rng = Rng::new(35);
        let mut codes = Vec::new();
        for n in [1usize, 7, 300] {
            let x: Vec<f32> = (0..n).map(|_| rng.next_f32() * 3.0).collect();
            let step = act_quantize_into(&x, &mut codes);
            let (want_codes, want_step) = act_quantize(&x);
            assert_eq!(codes, want_codes);
            assert_eq!(step, want_step);
        }
    }

    #[test]
    fn act_quantize_codes_bounded() {
        let (codes, step) = act_quantize(&[0.0, 0.5, 1.0, 123.0]);
        assert!(step > 0.0);
        assert!(codes.iter().all(|&c| c as u32 <= 255));
        assert_eq!(codes[0], 0);
    }

    /// Property: all three tile layouts agree bit-exactly through the
    /// whole forward path across random weight densities — including
    /// all-zero slices, dense slices, and the partial edge tiles of a
    /// non-multiple-of-128 layer. Integer accumulation commutes, so
    /// identical cells must give identical outputs however they are laid
    /// out.
    #[test]
    fn storage_formats_agree_bit_exactly_through_forward() {
        check(8, |rng| {
            let rows = 1 + rng.below(300);
            let cols = 1 + rng.below(120);
            let n = rows * cols;
            // density 0..=100%: 0 hits the all-zero mapping, 100 the dense
            let fill = rng.below(101);
            let mut data = vec![0.0f32; n];
            for v in data.iter_mut() {
                if rng.below(100) < fill {
                    *v = (rng.next_f32() - 0.5) * 2.0;
                }
            }
            let w = Tensor::new(vec![rows, cols], data).unwrap();
            let layer = map_layer("l", &w).unwrap();
            let b = 1 + rng.below(3);
            let x = Tensor::new(
                vec![b, rows],
                (0..b * rows).map(|_| rng.next_f32()).collect(),
            )
            .unwrap();
            for bits in [LOSSLESS, [3, 3, 3, 1]] {
                let auto = forward(&layer, &x, &bits);
                for fmt in [
                    StorageFormat::Dense,
                    StorageFormat::Compressed,
                    StorageFormat::BitPlanes,
                ] {
                    let forced = forward(&layer.with_storage(fmt), &x, &bits);
                    ensure(
                        forced.data() == auto.data(),
                        format!("{fmt:?} vs density-chosen at {bits:?}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    /// Satellite: the zero-wave skip must be bit-exact. Craft an
    /// activation whose high bit planes are all-zero (codes < 4 ⇒ planes
    /// 2..8 never drive a wordline) and whose nonzero codes sit only in
    /// rows 0..40 of a 200-row layer, so the second row-block's waves —
    /// and the high `u64` word of the first — are all-zero too. All that
    /// skipped work must contribute exactly nothing: the output has to
    /// match a brute-force integer reference, in every storage layout.
    #[test]
    fn zero_wave_skip_is_bit_exact() {
        let mut rng = Rng::new(77);
        let (rows, cols) = (200, 24);
        let w = random_sparse_tensor(&mut rng, rows, cols, 45);
        let layer = map_layer("l", &w).unwrap();
        let mut a = vec![0u8; rows];
        for code in a.iter_mut().take(40) {
            *code = 1 + rng.below(3) as u8; // codes 1..=3: planes 2..8 empty
        }
        // brute-force reference: out[c] = Σ_r a[r] · sign · code[r][c]
        let q = quant::quantize(&w);
        let mut want = vec![0i64; cols];
        for r in 0..rows {
            for c in 0..cols {
                want[c] += a[r] as i64
                    * q.signs[r * cols + c] as i64
                    * q.codes[r * cols + c] as i64;
            }
        }
        assert_eq!(forward_codes(&layer, &a, &LOSSLESS), want);
        for fmt in [
            StorageFormat::Dense,
            StorageFormat::Compressed,
            StorageFormat::BitPlanes,
        ] {
            let m = layer.with_storage(fmt);
            assert_eq!(forward_codes(&m, &a, &LOSSLESS), want, "{fmt:?}");
        }
    }

    /// Satellite: an all-BitPlanes layer never reads the byte bit-planes,
    /// so `forward_codes_into` must not build them — and skipping the
    /// transpose must be invisible in the output.
    #[test]
    fn all_bitplane_layer_skips_byte_planes() {
        let mut rng = Rng::new(83);
        let w = random_sparse_tensor(&mut rng, 200, 40, 45);
        let layer = map_layer("l", &w).unwrap();
        let forced = layer.with_storage(StorageFormat::BitPlanes);
        let code: Vec<u8> = (0..200).map(|_| rng.below(256) as u8).collect();
        let mut scratch = SimScratch::default();
        let mut out = Vec::new();
        forward_codes_into(&forced, &code, &LOSSLESS, &mut scratch, &mut out);
        assert!(
            scratch.planes.is_empty(),
            "all-BitPlanes layer materialized {} byte-plane entries",
            scratch.planes.len()
        );
        assert_eq!(out, forward_codes(&layer, &code, &LOSSLESS));
        // a byte-layout tile in the mix forces the planes back
        let dense = layer.with_storage(StorageFormat::Dense);
        forward_codes_into(&dense, &code, &LOSSLESS, &mut scratch, &mut out);
        assert!(!scratch.planes.is_empty(), "byte layout needs byte planes");
    }

    #[test]
    fn zero_tile_skip_preserves_results() {
        // all-positive weights leave every negative-sign tile fully zero;
        // the skip must be invisible in the output
        let w = Tensor::new(vec![200, 40], vec![0.25; 200 * 40]).unwrap();
        let layer = map_layer("l", &w).unwrap();
        let mut rng = Rng::new(41);
        let x = Tensor::new(vec![2, 200], (0..400).map(|_| rng.next_f32()).collect())
            .unwrap();
        let out = forward(&layer, &x, &LOSSLESS);
        let want = crate::serve::reference::quantized_matmul(&x, &w).unwrap();
        for (got, want) in out.data().iter().zip(want.data()) {
            assert!((got - want).abs() <= 1e-4 * want.abs().max(1.0), "{got} vs {want}");
        }
    }

    #[test]
    fn negative_weights_subtract() {
        let w = Tensor::new(vec![1, 1], vec![-0.5]).unwrap();
        let x = Tensor::new(vec![1, 1], vec![1.0]).unwrap();
        let layer = map_layer("l", &w).unwrap();
        let out = forward(&layer, &x, &LOSSLESS);
        assert!(out.data()[0] < 0.0);
    }

    fn random_sparse_tensor(
        rng: &mut crate::util::rng::Rng,
        rows: usize,
        cols: usize,
        fill: usize,
    ) -> Tensor {
        let mut data = vec![0.0f32; rows * cols];
        for v in data.iter_mut() {
            if rng.below(100) < fill {
                *v = (rng.next_f32() - 0.5) * 2.0;
            }
        }
        Tensor::new(vec![rows, cols], data).unwrap()
    }

    /// Property: a reordered mapping is invisible at lossless resolution —
    /// forward results are bit-exact with the unreordered mapping across
    /// random densities (including all-zero and fully-dense layers) and
    /// the partial edge tiles of non-multiple-of-128 shapes. The permute /
    /// un-permute pair must cancel exactly.
    #[test]
    fn reordered_forward_bit_exact_at_lossless() {
        use crate::reram::mapper::map_layer_with;
        use crate::reram::reorder::ReorderConfig;
        check(8, |rng| {
            let rows = 1 + rng.below(300);
            let cols = 1 + rng.below(150);
            let fill = [0, 100, rng.below(101), rng.below(20)][rng.below(4)];
            let w = random_sparse_tensor(rng, rows, cols, fill);
            let natural = map_layer("l", &w).unwrap();
            let reordered = map_layer_with("l", &w, Some(ReorderConfig::default())).unwrap();
            let b = 1 + rng.below(3);
            let x = Tensor::new(
                vec![b, rows],
                (0..b * rows).map(|_| rng.next_f32()).collect(),
            )
            .unwrap();
            let want = forward(&natural, &x, &LOSSLESS);
            let got = forward(&reordered, &x, &LOSSLESS);
            ensure(got.data() == want.data(), "reordered vs natural at lossless")?;
            Ok(())
        });
    }

    /// Broad sweep of the same property across forced storage formats and
    /// both partial-axis configs — slower, so CI runs it via
    /// `--include-ignored`.
    #[test]
    #[ignore = "broad reorder x format sweep; CI runs it with --include-ignored"]
    fn reordered_forward_broad_format_sweep() {
        use crate::reram::mapper::map_layer_with;
        use crate::reram::reorder::ReorderConfig;
        check(16, |rng| {
            let rows = 1 + rng.below(300);
            let cols = 1 + rng.below(150);
            let fill = rng.below(101);
            let w = random_sparse_tensor(rng, rows, cols, fill);
            let natural = map_layer("l", &w).unwrap();
            let b = 1 + rng.below(3);
            let x = Tensor::new(
                vec![b, rows],
                (0..b * rows).map(|_| rng.next_f32()).collect(),
            )
            .unwrap();
            let want = forward(&natural, &x, &LOSSLESS);
            for cfg in [
                ReorderConfig::default(),
                ReorderConfig::rows_only(),
                ReorderConfig::cols_only(),
            ] {
                let reordered = map_layer_with("l", &w, Some(cfg)).unwrap();
                for fmt in [
                    StorageFormat::Dense,
                    StorageFormat::Compressed,
                    StorageFormat::BitPlanes,
                ] {
                    let m = reordered.with_storage(fmt);
                    let got = forward(&m, &x, &LOSSLESS);
                    ensure(
                        got.data() == want.data(),
                        format!("cfg {cfg:?} fmt {fmt:?} disagrees at lossless"),
                    )?;
                }
            }
            Ok(())
        });
    }

    /// Column-only reordering is bit-exact at **clipping** resolutions
    /// too: a logical column's cells move between tiles as one unit, so
    /// the per-row-block partial currents the ADC clips are unchanged.
    /// (Row reordering crosses block boundaries and re-partitions the
    /// partials, so only lossless exactness is promised there.)
    #[test]
    fn column_reorder_bit_exact_under_clipping() {
        use crate::reram::mapper::map_layer_with;
        use crate::reram::reorder::ReorderConfig;
        check(6, |rng| {
            let rows = 1 + rng.below(300);
            let cols = 1 + rng.below(150);
            let w = random_sparse_tensor(rng, rows, cols, 30);
            let natural = map_layer("l", &w).unwrap();
            let reordered = map_layer_with("l", &w, Some(ReorderConfig::cols_only())).unwrap();
            let b = 1 + rng.below(3);
            let x = Tensor::new(
                vec![b, rows],
                (0..b * rows).map(|_| rng.next_f32()).collect(),
            )
            .unwrap();
            for bits in [[1u32; 4], [3, 3, 3, 1], [2, 4, 1, 3]] {
                let want = forward(&natural, &x, &bits);
                let got = forward(&reordered, &x, &bits);
                ensure(
                    got.data() == want.data(),
                    format!("cols-only reorder diverged at {bits:?}"),
                )?;
            }
            Ok(())
        });
    }

    /// Property (device model satellite): attaching an all-zero
    /// [`DeviceConfig`] must be bit-exact to the unattached ideal path —
    /// conductances are the exact integers, float accumulation of a tile
    /// row-block is exact, rounding is the identity — across all three
    /// storage layouts and at clipping resolutions.
    #[test]
    fn ideal_device_attached_is_bit_exact_across_layouts() {
        use crate::reram::device::{DeviceConfig, DeviceModel};
        check(6, |rng| {
            let rows = 1 + rng.below(300);
            let cols = 1 + rng.below(100);
            let w = random_sparse_tensor(rng, rows, cols, rng.below(101));
            let model = crate::reram::mapper::map_model(&[("l".into(), w)]).unwrap();
            let code: Vec<u8> = (0..rows).map(|_| rng.below(256) as u8).collect();
            let cfg = DeviceConfig {
                seed: rng.next_u64(),
                ..DeviceConfig::default()
            };
            ensure(cfg.is_ideal(), "all-zero knobs are the ideal device")?;
            let mut scratch = SimScratch::default();
            let mut out = Vec::new();
            for bits in [LOSSLESS, [3, 3, 3, 1]] {
                let want = forward_codes(&model.layers[0], &code, &bits);
                for fmt in [
                    StorageFormat::Dense,
                    StorageFormat::Compressed,
                    StorageFormat::BitPlanes,
                ] {
                    let m = model.with_storage(fmt);
                    let dev = DeviceModel::for_model(&m, cfg);
                    forward_codes_device_into(
                        &m.layers[0],
                        &code,
                        &bits,
                        Some(&dev.layers[0]),
                        &mut scratch,
                        &mut out,
                    );
                    ensure(
                        out == want,
                        format!("ideal device diverged in {fmt:?} at {bits:?}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    /// Property (device model satellite): one seed, one noise realization —
    /// the noisy forward is bit-identical across Dense/Compressed/BitPlanes
    /// and across repeated runs, for natural and reordered mappings alike.
    #[test]
    fn noisy_device_forward_is_layout_neutral_and_deterministic() {
        use crate::reram::device::{DeviceConfig, DeviceModel};
        use crate::reram::mapper::map_model_with;
        use crate::reram::reorder::ReorderConfig;
        check(6, |rng| {
            let rows = 1 + rng.below(300);
            let cols = 1 + rng.below(100);
            let w = random_sparse_tensor(rng, rows, cols, 5 + rng.below(90));
            let weights = vec![("l".to_string(), w)];
            let natural = map_model_with(&weights, None).unwrap();
            let reordered = map_model_with(&weights, Some(ReorderConfig::default())).unwrap();
            let cfg = DeviceConfig {
                sigma: 0.3,
                read_sigma: 0.2,
                fault_rate: 0.02,
                seed: rng.next_u64(),
            };
            let code: Vec<u8> = (0..rows).map(|_| rng.below(256) as u8).collect();
            let bits = [3u32, 3, 3, 1];
            let mut scratch = SimScratch::default();
            for model in [&natural, &reordered] {
                let mut outs: Vec<Vec<i64>> = Vec::new();
                for fmt in [
                    StorageFormat::Dense,
                    StorageFormat::Compressed,
                    StorageFormat::BitPlanes,
                ] {
                    let m = model.with_storage(fmt);
                    let dev = DeviceModel::for_model(&m, cfg);
                    let mut a = Vec::new();
                    let mut b = Vec::new();
                    forward_codes_device_into(
                        &m.layers[0],
                        &code,
                        &bits,
                        Some(&dev.layers[0]),
                        &mut scratch,
                        &mut a,
                    );
                    forward_codes_device_into(
                        &m.layers[0],
                        &code,
                        &bits,
                        Some(&dev.layers[0]),
                        &mut scratch,
                        &mut b,
                    );
                    ensure(a == b, format!("{fmt:?} noisy forward not reproducible"))?;
                    outs.push(a);
                }
                ensure(
                    outs[1] == outs[0] && outs[2] == outs[0],
                    "noise realization depends on storage layout",
                )?;
            }
            Ok(())
        });
    }

    /// The ideal path never touches the float buffer — attaching no device
    /// keeps the noisy-path scratch at zero capacity.
    #[test]
    fn ideal_path_never_sizes_the_float_buffer() {
        let mut rng = Rng::new(91);
        let w = random_sparse_tensor(&mut rng, 200, 40, 45);
        let layer = map_layer("l", &w).unwrap();
        let code: Vec<u8> = (0..200).map(|_| rng.below(256) as u8).collect();
        let mut scratch = SimScratch::default();
        let mut out = Vec::new();
        forward_codes_into(&layer, &code, &LOSSLESS, &mut scratch, &mut out);
        assert!(
            scratch.fcur.is_empty(),
            "device-path buffer sized on the ideal path"
        );
    }

    #[test]
    fn zero_column_skip_preserves_results() {
        // structurally-zero columns inside a programmed tile: column 0
        // gets cells, columns 1..39 of the same tile stay empty — the ADC
        // skip must be invisible in the output, including the sign path
        let mut data = vec![0.0f32; 200 * 40];
        for r in 0..200 {
            data[r * 40] = if r % 2 == 0 { 0.25 } else { -0.25 };
        }
        let w = Tensor::new(vec![200, 40], data).unwrap();
        let layer = map_layer("l", &w).unwrap();
        let mut rng = Rng::new(47);
        let x = Tensor::new(vec![2, 200], (0..400).map(|_| rng.next_f32()).collect())
            .unwrap();
        let out = forward(&layer, &x, &LOSSLESS);
        let want = crate::serve::reference::quantized_matmul(&x, &w).unwrap();
        for (got, want) in out.data().iter().zip(want.data()) {
            assert!((got - want).abs() <= 1e-4 * want.abs().max(1.0), "{got} vs {want}");
        }
        // the empty columns really are zero in the output
        for i in 0..2 {
            for c in 1..40 {
                assert_eq!(out.data()[i * 40 + c], 0.0, "column {c}");
            }
        }
    }
}

//! Functional crossbar inference simulator.
//!
//! Runs a mapped layer the way the hardware would: activations are
//! quantized to 8-bit codes and driven bit-serially (1-bit DACs); each
//! bit-plane's bitline currents pass through the ADC transfer function
//! (clip at 2^N - 1 LSBs) *per crossbar*; tile partial sums, slice shifts
//! and the sign difference recombine digitally. This mirrors the L1
//! `crossbar.py` Pallas kernel (same clipping point, same recombination
//! order) and is cross-checked against it by the integration tests.

use crate::quant::{self, N_SLICES};
use crate::tensor::Tensor;
use crate::util::pool::parallel_map;

use super::mapper::LayerMapping;

/// Quantize non-negative activations to codes (mirrors L2 `_act_quantize`).
pub fn act_quantize(x: &[f32]) -> (Vec<u8>, f32) {
    let step = quant::qstep(x);
    let inv = 1.0 / step;
    let codes = x
        .iter()
        .map(|&v| ((v.max(0.0) * inv).floor()).min(quant::CODE_MAX as f32) as u8)
        .collect();
    (codes, step)
}

/// ADC transfer function: clip at full scale.
#[inline]
pub fn adc_clip(current: u32, bits: u32) -> u32 {
    current.min((1u32 << bits) - 1)
}

/// Run one example (activation code vector) through a mapped layer.
///
/// `adc_bits[k]` is the resolution of slice group k (LSB-first). Returns
/// the integer-domain result (code units); multiply by `layer.step *
/// act_step` for real units.
pub fn forward_codes(layer: &LayerMapping, a_code: &[u8], adc_bits: &[u32; N_SLICES]) -> Vec<i64> {
    assert_eq!(a_code.len(), layer.rows, "activation length");
    let mut out = vec![0i64; layer.cols];
    // bit-serial over 8 activation bit planes
    for t in 0..8u32 {
        let bits: Vec<u8> = a_code.iter().map(|&c| (c >> t) & 1).collect();
        for (k, (pos, neg)) in layer.grids.iter().enumerate() {
            let full = adc_bits[k];
            for (grid, sign) in [(pos, 1i64), (neg, -1i64)] {
                for tr in 0..grid.row_tiles {
                    let r0 = tr * super::XBAR_ROWS;
                    for tc in 0..grid.col_tiles {
                        let tile = grid.tile(tr, tc);
                        let c0 = tc * super::XBAR_COLS;
                        let mut cur = vec![0u32; tile.cols()];
                        tile.bitline_currents(&bits[r0..r0 + tile.rows()], &mut cur);
                        for (j, &i_raw) in cur.iter().enumerate() {
                            let i_adc = adc_clip(i_raw, full) as i64;
                            out[c0 + j] +=
                                sign * i_adc * (1i64 << t) * (1i64 << (2 * k));
                        }
                    }
                }
            }
        }
    }
    out
}

/// Batched real-units forward: `x` is (batch, rows) in [0, ∞), returns
/// (batch, cols) approximating `x @ W`. Examples are processed in parallel
/// (one `forward_codes` per row).
///
/// §Perf note (EXPERIMENTS.md iteration 6): a tile-resident batched variant
/// (accumulate all examples per cell pass) was implemented and measured
/// 0.68x — the per-example current accumulators evict the tile from L1 —
/// so this simpler form is kept; it already runs at ~1e10 cell-ops/s,
/// 100x over the DESIGN.md target.
pub fn forward(layer: &LayerMapping, x: &Tensor, adc_bits: &[u32; N_SLICES]) -> Tensor {
    let shape = x.shape();
    assert_eq!(shape.len(), 2);
    let (b, rows) = (shape[0], shape[1]);
    assert_eq!(rows, layer.rows);
    let (codes, a_step) = act_quantize(x.data());
    let scale = layer.step * a_step;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let rows_out = parallel_map(b, threads, |i| {
        let code_row = &codes[i * rows..(i + 1) * rows];
        forward_codes(layer, code_row, adc_bits)
            .into_iter()
            .map(|v| v as f32 * scale)
            .collect::<Vec<f32>>()
    });
    let mut data = Vec::with_capacity(b * layer.cols);
    for r in rows_out {
        data.extend(r);
    }
    Tensor::new(vec![b, layer.cols], data).expect("forward shape")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::mapper::map_layer;
    use crate::util::check::{check, ensure};
    use crate::util::rng::Rng;

    const LOSSLESS: [u32; N_SLICES] = [10, 10, 10, 10];

    fn exact_matmul(x: &Tensor, w: &Tensor) -> Vec<f32> {
        let (b, r) = (x.shape()[0], x.shape()[1]);
        let c = w.shape()[1];
        let mut out = vec![0.0f32; b * c];
        for i in 0..b {
            for j in 0..c {
                let mut acc = 0.0;
                for k in 0..r {
                    acc += x.at2(i, k) * w.at2(k, j);
                }
                out[i * c + j] = acc;
            }
        }
        out
    }

    #[test]
    fn lossless_sim_matches_quantized_matmul() {
        check(8, |rng| {
            let rows = 1 + rng.below(200);
            let cols = 1 + rng.below(60);
            let b = 1 + rng.below(4);
            let w = Tensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 0.1))
                .unwrap();
            let x = Tensor::new(
                vec![b, rows],
                (0..b * rows).map(|_| rng.next_f32()).collect(),
            )
            .unwrap();
            let layer = map_layer("l", &w).unwrap();
            let out = forward(&layer, &x, &LOSSLESS);

            // reference: quantized x @ quantized w
            let qw = crate::quant::quantize(&w).recover();
            let (xc, xs) = act_quantize(x.data());
            let qx = Tensor::new(
                vec![b, rows],
                xc.iter().map(|&c| c as f32 * xs).collect(),
            )
            .unwrap();
            let want = exact_matmul(&qx, &qw);
            for (got, want) in out.data().iter().zip(&want) {
                let tol = 1e-4 * want.abs().max(1.0);
                ensure(
                    (got - want).abs() <= tol,
                    format!("sim {got} vs exact {want}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn adc_clip_boundaries() {
        assert_eq!(adc_clip(0, 1), 0);
        assert_eq!(adc_clip(1, 1), 1);
        assert_eq!(adc_clip(5, 1), 1);
        assert_eq!(adc_clip(7, 3), 7);
        assert_eq!(adc_clip(8, 3), 7);
    }

    #[test]
    fn reduced_adc_only_loses_on_clipped_columns() {
        // sparse weights: reduced resolution must be exact because no
        // column current ever exceeds the full scale
        let mut data = vec![0.0f32; 128 * 8];
        for c in 0..8 {
            data[c * 128 / 8 * 8 + c] = 0.9; // one big weight per column
        }
        data[0] = 1.0;
        let w = Tensor::new(vec![128, 8], data).unwrap();
        let layer = map_layer("l", &w).unwrap();
        let mut rng = Rng::new(5);
        let x = Tensor::new(vec![2, 128], (0..256).map(|_| rng.next_f32()).collect())
            .unwrap();
        let low = forward(&layer, &x, &[2, 2, 2, 2]);
        let high = forward(&layer, &x, &LOSSLESS);
        // single cell per column => max current 3 => 2 bits lossless
        for (a, b) in low.data().iter().zip(high.data()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn act_quantize_codes_bounded() {
        let (codes, step) = act_quantize(&[0.0, 0.5, 1.0, 123.0]);
        assert!(step > 0.0);
        assert!(codes.iter().all(|&c| c as u32 <= 255));
        assert_eq!(codes[0], 0);
    }

    #[test]
    fn negative_weights_subtract() {
        let w = Tensor::new(vec![1, 1], vec![-0.5]).unwrap();
        let x = Tensor::new(vec![1, 1], vec![1.0]).unwrap();
        let layer = map_layer("l", &w).unwrap();
        let out = forward(&layer, &x, &LOSSLESS);
        assert!(out.data()[0] < 0.0);
    }
}

//! Seeded device non-ideality model (ROADMAP item 1).
//!
//! Real ReRAM cells do not read back exactly: programmed conductances
//! spread lognormally around their target (`R_deviation` with
//! `pdf_type='lognorm'` in the HyperMetric RRAM model, arXiv:1904.12008),
//! each sensing operation adds read noise, and a fraction of cells is
//! stuck at ON or OFF. This module materializes those non-idealities as a
//! [`DeviceModel`]: one perturbed conductance per programmed cell plus a
//! read-noise seed per tile, derived deterministically from a
//! [`DeviceConfig`] via `util::rng` so every Monte-Carlo trial is exactly
//! reproducible.
//!
//! The model is *attached* at read time: [`crate::reram::sim`] routes
//! programmed tiles through [`TileNoise::bitline_currents`] when a
//! `DeviceModel` is supplied and takes the untouched integer path when it
//! is not (the ideal path stays bit-exact and zero-overhead). The full
//! convention catalogue — seed derivation, perturbation point, stuck-at
//! semantics for zero cells — lives in the device-model section of the
//! [`crate::reram`] module docs.

use crate::quant::N_SLICES;
use crate::util::rng::Rng;

use super::crossbar::CELL_MAX;
use super::mapper::{LayerMapping, MappedModel};

/// Domain-separation tag for per-tile read-noise seeds, so the read
/// stream never collides with the per-cell programming stream of the
/// same tile.
const READ_TAG: u64 = 0x5EAD_0000_0000_0001;

/// One SplitMix64-finalizer step folding `v` into the running seed `h` —
/// the stateless mixing function every device seed is derived with.
/// Same constants as [`Rng::next_u64`]'s output scrambler, applied to a
/// keyed value instead of a counter.
#[inline]
fn mix(h: u64, v: u64) -> u64 {
    let mut z = h ^ v.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Non-ideality knobs. The all-zero default is the ideal device: a model
/// built from it perturbs nothing and the simulator's outputs stay
/// bit-exact to the unattached path.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DeviceConfig {
    /// Lognormal conductance spread: a programmed cell of value `v` reads
    /// back `v * exp(sigma * N(0,1))` (multiplicative, so the deviation
    /// scales with the conductance level — the lognorm `R_deviation`
    /// shape).
    pub sigma: f32,
    /// Additive per-conversion read noise, in bitline-current LSB units:
    /// each sensed column current gains `read_sigma * N(0,1)` before the
    /// ADC clips it.
    pub read_sigma: f32,
    /// Stuck-at fault rate over programmed cells: a faulty cell is stuck
    /// OFF (conductance 0) or ON (conductance [`CELL_MAX`]) with equal
    /// probability. Structurally-zero cells are never fabricated and
    /// cannot fault (see the stuck-at convention in [`crate::reram`]).
    pub fault_rate: f32,
    /// Root seed every per-cell and per-tile stream derives from.
    pub seed: u64,
}

impl DeviceConfig {
    /// True when the config perturbs nothing — a [`DeviceModel`] built
    /// from it is the identity on every read.
    pub fn is_ideal(&self) -> bool {
        self.sigma == 0.0 && self.read_sigma == 0.0 && self.fault_rate == 0.0
    }

    /// The config of Monte-Carlo trial `i`: same knobs, an independent
    /// derived seed. Trial seeds never equal the root seed itself, so a
    /// deployment device and its MC trials are distinct draws.
    pub fn trial(&self, i: usize) -> DeviceConfig {
        DeviceConfig {
            seed: mix(self.seed, 0x7817_A100_0000_0000 ^ i as u64),
            ..*self
        }
    }
}

/// Per-tile realization of the non-idealities: the perturbed conductance
/// of every programmed cell (layout-neutral — built from the tile's
/// row-major triples, identical across Dense/Compressed/BitPlanes), the
/// columns that hold at least one programmed cell (the only columns a
/// deployment fabricates and senses), and the seed of the tile's
/// read-noise stream.
#[derive(Debug, Clone)]
pub struct TileNoise {
    /// `(row, col, conductance)` per programmed cell, row-major. Stuck-OFF
    /// cells stay listed with conductance 0.
    cells: Vec<(u16, u16, f32)>,
    /// ascending columns with >= 1 programmed cell
    active_cols: Vec<u16>,
    read_seed: u64,
}

impl TileNoise {
    /// Accumulate this tile's noisy bitline currents for one packed
    /// activation wave (the BitPlanes wave convention: wordline `r` is bit
    /// `r & 63` of word `r >> 6`) into `fcur`, and return the columns that
    /// were sensed. Only `active_cols` slots of `fcur` are written (they
    /// are zeroed first); read noise — a pure function of (tile seed,
    /// plane, wave content, column) — is added per sensed column, so the
    /// same activations always see the same noise regardless of batch
    /// composition, evaluation order or storage layout.
    pub(crate) fn bitline_currents(
        &self,
        wave: &[u64; 2],
        read_sigma: f32,
        plane: u32,
        fcur: &mut [f32],
    ) -> &[u16] {
        for &c in &self.active_cols {
            fcur[c as usize] = 0.0;
        }
        for &(r, c, g) in &self.cells {
            if (wave[(r >> 6) as usize] >> (r & 63)) & 1 != 0 {
                fcur[c as usize] += g;
            }
        }
        if read_sigma > 0.0 {
            for &c in &self.active_cols {
                let h = mix(mix(mix(self.read_seed, plane as u64), wave[0]), wave[1] ^ c as u64);
                fcur[c as usize] += read_sigma * Rng::new(h).normal();
            }
        }
        &self.active_cols
    }
}

/// One sign grid's tile noise, parallel to `TileGrid::tiles` (`None` for
/// unprogrammed tiles, which are never fabricated).
#[derive(Debug, Clone)]
struct GridNoise {
    col_tiles: usize,
    tiles: Vec<Option<TileNoise>>,
}

/// Per-layer slice of a [`DeviceModel`], parallel to
/// [`LayerMapping::grids`]: `grids[k][sign]` covers slice group `k`'s
/// positive (`sign = 0`) / negative (`sign = 1`) tile grid.
#[derive(Debug, Clone)]
pub struct LayerDevice {
    pub(crate) read_sigma: f32,
    grids: Vec<[GridNoise; 2]>,
    /// mean squared conductance deviation `(g - v)^2` per slice group, in
    /// LSB² units over the layer's programmed cells (0.0 for empty groups)
    pub variance: [f64; N_SLICES],
}

impl LayerDevice {
    /// The noise realization of tile `(tr, tc)` in slice group `k`, sign
    /// grid `sign` (0 = positive, 1 = negative); `None` iff the tile holds
    /// no programmed cell.
    #[inline]
    pub(crate) fn tile(&self, k: usize, sign: usize, tr: usize, tc: usize) -> Option<&TileNoise> {
        let g = &self.grids[k][sign];
        g.tiles[tr * g.col_tiles + tc].as_ref()
    }

    fn for_layer(layer: &LayerMapping, li: usize, cfg: &DeviceConfig) -> LayerDevice {
        let mut variance = [0.0f64; N_SLICES];
        let mut counts = [0usize; N_SLICES];
        let grids = layer
            .grids
            .iter()
            .enumerate()
            .map(|(k, (pos, neg))| {
                [(0usize, pos), (1usize, neg)].map(|(si, grid)| {
                    let tiles = (0..grid.row_tiles * grid.col_tiles)
                        .map(|i| {
                            let (tr, tc) = (i / grid.col_tiles, i % grid.col_tiles);
                            let tile = grid.tile(tr, tc);
                            if tile.nonzero_cells() == 0 {
                                return None;
                            }
                            let tile_seed = [li, k, si, tr, tc]
                                .iter()
                                .fold(cfg.seed, |h, &v| mix(h, v as u64));
                            let mut cells = Vec::with_capacity(tile.nonzero_cells());
                            let mut seen = vec![false; tile.cols()];
                            for (r, c, v) in tile.triples() {
                                // independent per-cell stream: physical
                                // coordinates in, fault class + lognormal
                                // factor out
                                let mut rng =
                                    Rng::new(mix(mix(tile_seed, r as u64), c as u64));
                                let u = rng.next_f32();
                                let g = if u < cfg.fault_rate * 0.5 {
                                    0.0 // stuck OFF
                                } else if u < cfg.fault_rate {
                                    CELL_MAX as f32 // stuck ON
                                } else {
                                    v as f32 * (cfg.sigma * rng.normal()).exp()
                                };
                                variance[k] += f64::from(g - v as f32).powi(2);
                                counts[k] += 1;
                                cells.push((r as u16, c, g));
                                seen[c as usize] = true;
                            }
                            let active_cols = seen
                                .iter()
                                .enumerate()
                                .filter_map(|(c, &s)| s.then_some(c as u16))
                                .collect();
                            Some(TileNoise {
                                cells,
                                active_cols,
                                read_seed: mix(tile_seed, READ_TAG),
                            })
                        })
                        .collect();
                    GridNoise {
                        col_tiles: grid.col_tiles,
                        tiles,
                    }
                })
            })
            .collect();
        for k in 0..N_SLICES {
            if counts[k] > 0 {
                variance[k] /= counts[k] as f64;
            }
        }
        LayerDevice {
            read_sigma: cfg.read_sigma,
            grids,
            variance,
        }
    }
}

/// One sampled device realization of a whole mapped model: every
/// programmed cell's perturbed conductance plus per-tile read-noise
/// seeds, parallel to `model.layers`. Build once per Monte-Carlo trial
/// ([`DeviceConfig::trial`]) and attach to the serving backend
/// ([`crate::serve::CrossbarBackend::with_device`]).
#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub config: DeviceConfig,
    pub layers: Vec<LayerDevice>,
}

impl DeviceModel {
    /// Sample the non-idealities of `cfg` over every programmed cell of
    /// `model`. Deterministic: per-cell streams are seeded from the cell's
    /// *physical* coordinates (layer, slice group, sign, tile row, tile
    /// col, row, col), so the realization is independent of storage
    /// layout and of the order tiles are visited in — only the mapping
    /// itself (including any reorder permutation, which changes physical
    /// coordinates) and the seed matter.
    pub fn for_model(model: &MappedModel, cfg: DeviceConfig) -> DeviceModel {
        DeviceModel {
            config: cfg,
            layers: model
                .layers
                .iter()
                .enumerate()
                .map(|(li, layer)| LayerDevice::for_layer(layer, li, &cfg))
                .collect(),
        }
    }

    /// Per-layer, per-slice-group mean squared conductance deviation in
    /// LSB² units — the variance decomposition the Monte-Carlo harness
    /// reports (sparser slice groups accumulate less of it per bitline).
    pub fn layer_variances(&self) -> Vec<[f64; N_SLICES]> {
        self.layers.iter().map(|l| l.variance).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::mapper::map_model;
    use crate::tensor::Tensor;
    use crate::util::check::{check, ensure};

    fn toy_model(rng: &mut Rng, rows: usize, cols: usize, fill: usize) -> MappedModel {
        let mut data = vec![0.0f32; rows * cols];
        for v in data.iter_mut() {
            if rng.below(100) < fill {
                *v = (rng.next_f32() - 0.5) * 2.0;
            }
        }
        let w = Tensor::new(vec![rows, cols], data).unwrap();
        map_model(&[("l".into(), w)]).unwrap()
    }

    fn all_cells(dev: &DeviceModel) -> Vec<(usize, usize, usize, usize, usize, u16, u16, f32)> {
        let mut out = Vec::new();
        for (li, layer) in dev.layers.iter().enumerate() {
            for (k, pair) in layer.grids.iter().enumerate() {
                for (si, g) in pair.iter().enumerate() {
                    for (ti, tn) in g.tiles.iter().enumerate() {
                        if let Some(tn) = tn {
                            for &(r, c, v) in &tn.cells {
                                out.push((li, k, si, ti / g.col_tiles, ti % g.col_tiles, r, c, v));
                            }
                        }
                    }
                }
            }
        }
        out
    }

    #[test]
    fn same_seed_is_bit_identical_and_seeds_differ() {
        let mut rng = Rng::new(3);
        let model = toy_model(&mut rng, 200, 40, 30);
        let cfg = DeviceConfig {
            sigma: 0.2,
            read_sigma: 0.1,
            fault_rate: 0.01,
            seed: 42,
        };
        let a = DeviceModel::for_model(&model, cfg);
        let b = DeviceModel::for_model(&model, cfg);
        assert_eq!(all_cells(&a), all_cells(&b), "same seed must reproduce");
        let c = DeviceModel::for_model(&model, DeviceConfig { seed: 43, ..cfg });
        assert_ne!(all_cells(&a), all_cells(&c), "different seeds must differ");
    }

    #[test]
    fn ideal_config_is_identity_on_every_cell() {
        let mut rng = Rng::new(5);
        let model = toy_model(&mut rng, 150, 30, 40);
        let dev = DeviceModel::for_model(&model, DeviceConfig::default());
        assert!(DeviceConfig::default().is_ideal());
        for (li, k, si, tr, tc, r, c, g) in all_cells(&dev) {
            let (pos, neg) = &model.layers[0].grids[k];
            let grid = if si == 0 { pos } else { neg };
            let want = grid.tile(tr, tc).get(r as usize, c as usize);
            assert_ne!(want, 0, "only programmed cells are listed");
            assert_eq!(g, want as f32, "layer {li} ideal cell must read exactly");
        }
        assert_eq!(dev.layer_variances(), vec![[0.0; N_SLICES]]);
    }

    #[test]
    fn fault_rate_one_sticks_every_cell() {
        let mut rng = Rng::new(7);
        let model = toy_model(&mut rng, 100, 20, 50);
        let cfg = DeviceConfig {
            fault_rate: 1.0,
            seed: 9,
            ..DeviceConfig::default()
        };
        let dev = DeviceModel::for_model(&model, cfg);
        let cells = all_cells(&dev);
        assert!(!cells.is_empty());
        let (off, on): (Vec<_>, Vec<_>) = cells.iter().partition(|c| c.7 == 0.0);
        assert!(cells.iter().all(|c| c.7 == 0.0 || c.7 == CELL_MAX as f32));
        // u < 0.5 -> OFF, else ON: both classes show up at any real size
        assert!(!off.is_empty() && !on.is_empty(), "off {} on {}", off.len(), on.len());
    }

    #[test]
    fn lognormal_spread_is_multiplicative_and_unbiased_in_log() {
        let mut rng = Rng::new(11);
        let model = toy_model(&mut rng, 300, 60, 60);
        let cfg = DeviceConfig {
            sigma: 0.25,
            seed: 21,
            ..DeviceConfig::default()
        };
        let dev = DeviceModel::for_model(&model, cfg);
        let cells = all_cells(&dev);
        // every conductance is value * exp(sigma * n): positive, and the
        // log-ratio is N(0, sigma^2)
        let mut ratios = Vec::new();
        for &(_, k, si, tr, tc, r, c, g) in &cells {
            let (pos, neg) = &model.layers[0].grids[k];
            let grid = if si == 0 { pos } else { neg };
            let v = grid.tile(tr, tc).get(r as usize, c as usize) as f32;
            assert!(g > 0.0, "lognormal spread keeps conductance positive");
            ratios.push(f64::from((g / v).ln()));
        }
        let n = ratios.len() as f64;
        let mean = ratios.iter().sum::<f64>() / n;
        let var = ratios.iter().map(|r| (r - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.02, "log-ratio mean {mean}");
        assert!((var.sqrt() - 0.25).abs() < 0.02, "log-ratio std {}", var.sqrt());
        // and the reported per-group variance agrees with a recount
        let vars = dev.layer_variances();
        assert!(vars[0].iter().any(|&v| v > 0.0));
    }

    /// Read noise is a pure function of (tile, plane, wave, column):
    /// repeated senses of the same wave reproduce exactly, different waves
    /// and planes draw independently.
    #[test]
    fn read_noise_is_deterministic_per_wave() {
        let tn = TileNoise {
            cells: vec![(0, 0, 2.0), (1, 0, 1.0), (64, 3, 3.0)],
            active_cols: vec![0, 3],
            read_seed: 77,
        };
        let wave = [0b11u64, 0b1u64];
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        assert_eq!(tn.bitline_currents(&wave, 0.5, 2, &mut a), &[0, 3]);
        tn.bitline_currents(&wave, 0.5, 2, &mut b);
        assert_eq!(a, b, "same wave, same noise");
        // noiseless: exact integer accumulation over driven wordlines
        tn.bitline_currents(&wave, 0.0, 2, &mut b);
        assert_eq!(&b[..], &[3.0, 0.0, 0.0, 3.0]);
        // a different plane draws different noise
        tn.bitline_currents(&wave, 0.5, 3, &mut b);
        assert_ne!(a, b, "plane is part of the read stream");
    }

    /// Property: the realization is independent of the traversal order the
    /// builder happens to use — rebuilding from a converted (different
    /// storage layout) model yields identical noise, because seeds come
    /// from physical coordinates, not enumeration position.
    #[test]
    fn realization_is_storage_layout_neutral() {
        use crate::reram::crossbar::StorageFormat;
        check(4, |rng| {
            let model = toy_model(rng, 1 + rng.below(300), 1 + rng.below(100), rng.below(101));
            let cfg = DeviceConfig {
                sigma: 0.3,
                read_sigma: 0.2,
                fault_rate: 0.05,
                seed: rng.next_u64(),
            };
            let want = all_cells(&DeviceModel::for_model(&model, cfg));
            for fmt in [
                StorageFormat::Dense,
                StorageFormat::Compressed,
                StorageFormat::BitPlanes,
            ] {
                let forced = model.with_storage(fmt);
                let got = all_cells(&DeviceModel::for_model(&forced, cfg));
                ensure(got == want, format!("{fmt:?} realization diverged"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn trial_seeds_are_distinct_and_stable() {
        let cfg = DeviceConfig {
            sigma: 0.1,
            seed: 5,
            ..DeviceConfig::default()
        };
        let seeds: Vec<u64> = (0..32).map(|i| cfg.trial(i).seed).collect();
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "trial seeds collide");
        assert!(!seeds.contains(&cfg.seed), "a trial reuses the root seed");
        assert_eq!(cfg.trial(3), cfg.trial(3), "trials are deterministic");
        assert_eq!(cfg.trial(3).sigma, cfg.sigma, "knobs carry over");
    }
}

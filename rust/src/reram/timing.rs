//! Pipeline timing model: cycles per example, per tile -> per layer ->
//! whole-pipeline throughput — and the replication planner that spends an
//! area budget on the bottleneck layers.
//!
//! The energy model ([`super::energy`]) bills ADC conversions; this module
//! prices the *same* conversions in cycles, so the planner can trade area
//! for speed (SME, arXiv:2103.01705, and the A/D co-design line,
//! arXiv:2402.06164, both identify conversions per active column as the
//! cycle-level bottleneck of ReRAM pipelines).
//!
//! # What a cycle is
//!
//! One cycle = one ADC bit-resolution step (one SAR compare), so one
//! column conversion at resolution `b` costs [`AdcModel::sensing_time`]`(b)
//! = b` cycles. Activations drive bit-serially: each example takes
//! [`PLANES`] (= [`crate::quant::N_BITS`]) wordline activation waves, and
//! within each wave a tile's single column-multiplexed ADC serially
//! converts the tile's **converting** columns
//! ([`Crossbar::converting_columns`] — the cached nonzero-column index for
//! compressed and bit-plane tiles, every column for dense tiles, nothing
//! for fully-zero tiles). The per-tile count is therefore bit-consistent with
//! what [`Crossbar::bitline_currents_active`] actually executes: a column
//! is priced exactly when the simulator converts it.
//!
//! # Latency and throughput roll-up
//!
//! Every programmed tile carries its own ADC and all tiles of a layer run
//! in parallel, so a layer's per-example **latency** is its slowest
//! tile's conversion serialization ([`LayerTiming::latency_cycles`]). The
//! layers form a pipeline (one stage per layer): steady-state
//! **throughput** is set by the bottleneck stage's *effective* latency —
//! `latency / replicas`, since `r` fabricated copies of a layer each take
//! every r-th example ([`PipelineTiming::throughput_per_kcycle`]).
//!
//! # Replication planner
//!
//! [`fill_replicas`] water-fills an area budget (in fabricated crossbar
//! cells, [`LayerMapping::fabricated_cells`]) onto the pipeline: while
//! the current bottleneck layer's copy still fits the remaining budget,
//! it gains one replica — replicating any *other* layer can never raise
//! throughput, which is what makes the greedy fill optimal here. Replica
//! counts land in [`super::planner::PlanLayer::replicas`]; the mapper
//! exposes the replicas as `Arc` handles on the same tiles
//! ([`super::mapper::MappedModel::replicated`]) and the serving backend
//! shards batch rows across them.
//!
//! [`Crossbar::converting_columns`]:
//! crate::reram::crossbar::Crossbar::converting_columns
//! [`Crossbar::bitline_currents_active`]:
//! crate::reram::crossbar::Crossbar::bitline_currents_active

use crate::quant;

use super::adc::AdcModel;
use super::crossbar::Crossbar;
use super::mapper::{LayerMapping, MappedModel};
use super::planner::{DeploymentPlan, PlanLayer};

/// Wordline activation waves per example — one per activation code bit
/// (the same 8 the energy model's conversion counts multiply by).
pub const PLANES: usize = quant::N_BITS as usize;

/// Per-layer replica ceiling: a backstop so a mistakenly huge budget
/// cannot spin [`fill_replicas`] forever, far above any sane deployment.
pub const MAX_REPLICAS: usize = 64;

/// Cycles one tile takes to convert one example at resolution `bits`:
/// `PLANES` waves x converting columns x `sensing_time(bits)` cycles per
/// conversion (the tile's one ADC serializes its columns). Fully-zero
/// tiles are never fabricated and cost nothing.
pub fn tile_cycles(tile: &Crossbar, bits: u32) -> u64 {
    if tile.nonzero_cells() == 0 {
        return 0;
    }
    // sensing_time(b) = b exactly — kept behind the AdcModel name so the
    // cycle price and Table 3's speedup column share one definition
    PLANES as u64 * tile.converting_columns() as u64 * AdcModel::sensing_time(bits) as u64
}

/// One layer's timing under a plan — the `report::timing_table` row.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub layer: String,
    /// fabricated copies of this layer (>= 1)
    pub replicas: usize,
    /// per-example latency: the slowest tile's conversion serialization,
    /// in cycles (tiles run in parallel, each behind its own ADC)
    pub latency_cycles: u64,
    /// total conversion-cycles per example summed over every programmed
    /// tile — the serial-work (and energy-proportional) view
    pub conversion_cycles: u64,
}

impl LayerTiming {
    /// Pipeline-stage latency with replication: `r` copies each take
    /// every r-th example, so the stage advances `r` examples per
    /// `latency_cycles`.
    pub fn effective_cycles(&self) -> f64 {
        self.latency_cycles as f64 / self.replicas.max(1) as f64
    }
}

/// Timing of one layer at its planned per-slice resolutions.
pub fn layer_timing(layer: &LayerMapping, pl: &PlanLayer) -> LayerTiming {
    let mut latency = 0u64;
    let mut total = 0u64;
    for (k, (pos, neg)) in layer.grids.iter().enumerate() {
        let bits = pl.adc_bits[k];
        for grid in [pos, neg] {
            for tile in &grid.tiles {
                let c = tile_cycles(tile, bits);
                latency = latency.max(c);
                total += c;
            }
        }
    }
    LayerTiming {
        layer: layer.name.clone(),
        replicas: pl.replicas.max(1),
        latency_cycles: latency,
        conversion_cycles: total,
    }
}

/// Per-slice-group latency of one layer at its planned resolutions:
/// `group_latency(..)[k]` is the slowest tile of slice group k over both
/// sign grids — the group-resolved view of
/// [`layer_timing`]'s `latency_cycles` (which is the max over groups).
/// The joint ADC/replica pass uses it to pick which group of the
/// bottleneck layer to lower next.
pub fn group_latency(layer: &LayerMapping, pl: &PlanLayer) -> [u64; quant::N_SLICES] {
    let mut out = [0u64; quant::N_SLICES];
    for (k, (pos, neg)) in layer.grids.iter().enumerate() {
        let bits = pl.adc_bits[k];
        for grid in [pos, neg] {
            for tile in &grid.tiles {
                out[k] = out[k].max(tile_cycles(tile, bits));
            }
        }
    }
    out
}

/// Whole-pipeline timing under a plan.
#[derive(Debug, Clone)]
pub struct PipelineTiming {
    pub layers: Vec<LayerTiming>,
}

impl PipelineTiming {
    /// Index of the bottleneck stage — the largest *effective* (replica-
    /// divided) latency; `None` when nothing converts anywhere.
    pub fn bottleneck(&self) -> Option<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.latency_cycles > 0)
            .max_by(|a, b| {
                a.1.effective_cycles()
                    .partial_cmp(&b.1.effective_cycles())
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .map(|(i, _)| i)
    }

    /// Effective cycles of the bottleneck stage (0.0 when nothing
    /// converts): the steady-state cost of one example.
    pub fn bottleneck_cycles(&self) -> f64 {
        self.bottleneck()
            .map(|i| self.layers[i].effective_cycles())
            .unwrap_or(0.0)
    }

    /// Steady-state pipeline throughput, examples per 1000 cycles.
    pub fn throughput_per_kcycle(&self) -> f64 {
        let b = self.bottleneck_cycles();
        if b == 0.0 {
            0.0
        } else {
            1000.0 / b
        }
    }

    /// Cycles for one example to traverse the empty pipeline (the fill
    /// latency): stage latencies summed — replication does not shorten an
    /// individual example's path.
    pub fn pipeline_fill_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.latency_cycles).sum()
    }
}

/// Roll up a mapped model's timing under a per-layer deployment plan.
pub fn plan_timing(model: &MappedModel, plan: &DeploymentPlan) -> PipelineTiming {
    assert_eq!(
        plan.layers.len(),
        model.layers.len(),
        "plan has {} layers, mapping has {}",
        plan.layers.len(),
        model.layers.len()
    );
    PipelineTiming {
        layers: model
            .layers
            .iter()
            .zip(&plan.layers)
            .map(|(layer, pl)| layer_timing(layer, pl))
            .collect(),
    }
}

/// Water-fill `budget_cells` of extra fabricated area onto the plan's
/// bottleneck layers: while the current bottleneck's copy
/// ([`LayerMapping::fabricated_cells`]) still fits the remaining budget
/// (and the layer is under [`MAX_REPLICAS`]), it gains one replica.
/// Returns the cells actually spent. Replicating a non-bottleneck layer
/// can never raise pipeline throughput, so the greedy fill never
/// considers one.
pub fn fill_replicas(model: &MappedModel, plan: &mut DeploymentPlan, budget_cells: usize) -> usize {
    let mut remaining = budget_cells;
    loop {
        let timing = plan_timing(model, plan);
        let Some(b) = timing.bottleneck() else { break };
        let cost = model.layers[b].fabricated_cells();
        if cost == 0 || cost > remaining || plan.layers[b].replicas >= MAX_REPLICAS {
            break;
        }
        plan.layers[b].replicas += 1;
        remaining -= cost;
    }
    budget_cells - remaining
}

/// The CLI's budget unit converted to cells: `factor` multiples of the
/// **bottleneck layer's** fabricated cells under `plan` (so `2.0` buys
/// about two extra copies of the slowest layer). This is the one
/// definition of what `--replicate-budget F` means — the deploy CLI, the
/// harness report, the example and the planner's joint ADC/replica pass
/// all price the factor through it (the planner hands the budget to its
/// own water-fill, everyone else to [`fill_replicas`]). Non-positive
/// factors and models with no bottleneck price to zero cells.
pub fn factor_budget_cells(model: &MappedModel, plan: &DeploymentPlan, factor: f64) -> usize {
    if factor <= 0.0 {
        return 0;
    }
    plan_timing(model, plan)
        .bottleneck()
        .map(|b| (factor * model.layers[b].fabricated_cells() as f64) as usize)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::crossbar::StorageFormat;
    use crate::reram::mapper::{map_layer, map_layer_with, map_model};
    use crate::reram::reorder::ReorderConfig;
    use crate::serve::DenseLayer;
    use crate::tensor::Tensor;
    use crate::util::fixtures;
    use crate::util::rng::Rng;

    /// Hand-computed tile cycles in every layout: a dense tile converts
    /// every column; compressed and bit-plane tiles only their
    /// nonzero-column index; a fully-zero tile nothing.
    #[test]
    fn tile_cycles_by_hand() {
        let mut xb = Crossbar::zeros(4, 4);
        xb.set(0, 1, 2);
        xb.set(3, 1, 1);
        xb.set(2, 3, 3);
        // dense layout: 4 converting columns x 8 waves x 3 cycles
        assert_eq!(tile_cycles(&xb, 3), 8 * 4 * 3);
        // indexed layouts: only columns 1 and 3 hold cells
        for fmt in [StorageFormat::Compressed, StorageFormat::BitPlanes] {
            let ix = xb.in_format(fmt);
            assert_eq!(ix.converting_columns(), 2, "{fmt:?}");
            assert_eq!(tile_cycles(&ix, 3), 8 * 2 * 3, "{fmt:?}");
            assert_eq!(tile_cycles(&ix, 1), 8 * 2, "{fmt:?}");
        }
        // fully-zero tiles cost nothing in any layout
        let z = Crossbar::zeros(4, 4);
        assert_eq!(tile_cycles(&z, 5), 0);
        assert_eq!(tile_cycles(&z.in_format(StorageFormat::Compressed), 5), 0);
        assert_eq!(tile_cycles(&z.in_format(StorageFormat::BitPlanes), 5), 0);
    }

    /// The cycle price counts exactly the conversions
    /// `bitline_currents_active` executes: per tile, the columns the
    /// simulator's ADC loop walks (the returned index for compressed and
    /// bit-plane tiles, every slot for dense ones) times waves times bits.
    #[test]
    fn tile_cycles_match_executed_conversions() {
        let mut rng = Rng::new(17);
        let w = Tensor::new(vec![200, 150], {
            let mut d = vec![0.0f32; 200 * 150];
            for _ in 0..900 {
                d[rng.below(200 * 150)] = (rng.next_f32() - 0.5) * 2.0;
            }
            d
        })
        .unwrap();
        let layer = map_layer("l", &w).unwrap();
        for fmt in [
            StorageFormat::Dense,
            StorageFormat::Compressed,
            StorageFormat::BitPlanes,
        ] {
            let m = layer.with_storage(fmt);
            for (pos, neg) in &m.grids {
                for grid in [pos, neg] {
                    for tile in &grid.tiles {
                        if tile.nonzero_cells() == 0 {
                            continue;
                        }
                        let bits = vec![1u8; tile.rows()];
                        let mut cur = vec![0u32; tile.cols()];
                        // what one wave actually converts under this layout
                        let converted = match tile.bitline_currents_active(&bits, &mut cur) {
                            Some(active) => active.len(),
                            None => tile.cols(),
                        };
                        assert_eq!(
                            tile_cycles(tile, 3),
                            (PLANES * converted * 3) as u64,
                            "layout {fmt:?}"
                        );
                    }
                }
            }
        }
    }

    /// Layer latency is the slowest tile; the roll-up agrees with a direct
    /// recomputation in dense, compressed and reordered layouts (each
    /// layout's own converting-column census drives its price).
    #[test]
    fn layer_timing_is_max_tile_in_every_layout() {
        let mut rng = Rng::new(23);
        let w = fixtures::structured_sparse_weights(&mut rng, 300, 150, 0.2, 0.2, 0.4);
        let natural = map_layer("l", &w).unwrap();
        let reordered = map_layer_with("l", &w, Some(ReorderConfig::default())).unwrap();
        let pl = PlanLayer {
            name: "l".into(),
            adc_bits: [3, 3, 3, 1],
            replicas: 1,
        };
        for m in [
            natural.clone(),
            natural.with_storage(StorageFormat::Dense),
            natural.with_storage(StorageFormat::Compressed),
            natural.with_storage(StorageFormat::BitPlanes),
            reordered,
        ] {
            let t = layer_timing(&m, &pl);
            let mut want_max = 0u64;
            let mut want_sum = 0u64;
            for (k, (pos, neg)) in m.grids.iter().enumerate() {
                for grid in [pos, neg] {
                    for tile in &grid.tiles {
                        let c = tile_cycles(tile, pl.adc_bits[k]);
                        want_max = want_max.max(c);
                        want_sum += c;
                    }
                }
            }
            assert_eq!(t.latency_cycles, want_max);
            assert_eq!(t.conversion_cycles, want_sum);
            assert!(t.latency_cycles > 0);
        }
    }

    /// `group_latency` is the per-group decomposition of
    /// `layer_timing`'s latency: its max over groups is the layer
    /// latency, and each entry recomputes directly from the tiles.
    #[test]
    fn group_latency_decomposes_layer_latency() {
        let mut rng = Rng::new(29);
        let w = fixtures::structured_sparse_weights(&mut rng, 300, 150, 0.2, 0.2, 0.4);
        let m = map_layer("l", &w).unwrap();
        let pl = PlanLayer {
            name: "l".into(),
            adc_bits: [3, 2, 4, 1],
            replicas: 1,
        };
        let groups = group_latency(&m, &pl);
        assert_eq!(
            groups.iter().copied().max().unwrap(),
            layer_timing(&m, &pl).latency_cycles
        );
        for (k, (pos, neg)) in m.grids.iter().enumerate() {
            let want = [pos, neg]
                .into_iter()
                .flat_map(|g| g.tiles.iter())
                .map(|t| tile_cycles(t, pl.adc_bits[k]))
                .max()
                .unwrap_or(0);
            assert_eq!(groups[k], want, "group {k}");
        }
    }

    fn skewed_model() -> (MappedModel, DeploymentPlan) {
        let stack = fixtures::bottleneck_stack(0xBEEF);
        let named: Vec<(String, Tensor)> = stack
            .iter()
            .map(|l: &DenseLayer| (l.name.clone(), l.w.clone()))
            .collect();
        let model = map_model(&named).unwrap();
        let plan = DeploymentPlan::uniform_for(&model, [3, 3, 3, 1]);
        (model, plan)
    }

    /// The bottleneck-skewed fixture really skews: the wide hidden layer
    /// is the bottleneck at ~4x every other layer's latency.
    #[test]
    fn bottleneck_fixture_skews_latency() {
        let (model, plan) = skewed_model();
        let timing = plan_timing(&model, &plan);
        let b = timing.bottleneck().expect("programmed model");
        assert_eq!(timing.layers[b].layer, "fc2/w", "wide layer bottleneck");
        let others = timing
            .layers
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != b)
            .map(|(_, l)| l.latency_cycles)
            .max()
            .unwrap();
        assert!(
            timing.layers[b].latency_cycles as f64 >= 3.5 * others as f64,
            "bottleneck {} vs next {}",
            timing.layers[b].latency_cycles,
            others
        );
        assert!(timing.throughput_per_kcycle() > 0.0);
        assert!(timing.pipeline_fill_cycles() >= timing.layers[b].latency_cycles);
    }

    /// Water-filling spends the budget on the bottleneck: with 2x the
    /// bottleneck layer's cells it fabricates extra copies of exactly that
    /// layer, throughput rises accordingly, and the spend never exceeds
    /// the budget. A zero budget changes nothing.
    #[test]
    fn fill_replicas_water_fills_the_bottleneck() {
        let (model, plan) = skewed_model();
        let timing0 = plan_timing(&model, &plan);
        let b = timing0.bottleneck().unwrap();
        let cells = model.layers[b].fabricated_cells();
        assert!(cells > 0);

        let mut untouched = plan.clone();
        assert_eq!(fill_replicas(&model, &mut untouched, 0), 0);
        assert!(untouched.layers.iter().all(|l| l.replicas == 1));

        let mut filled = plan.clone();
        let spent = fill_replicas(&model, &mut filled, 2 * cells);
        assert!(spent <= 2 * cells);
        assert!(
            filled.layers[b].replicas >= 2,
            "budget of 2x bottleneck cells affords at least one extra copy"
        );
        for (i, l) in filled.layers.iter().enumerate() {
            if i != b {
                // at ~4x skew the bottleneck stays the bottleneck until
                // the budget runs out — no one else is replicated
                assert_eq!(l.replicas, 1, "layer {}", l.layer);
            }
        }
        let timing1 = plan_timing(&model, &filled);
        assert!(
            timing1.throughput_per_kcycle()
                >= timing0.throughput_per_kcycle() * filled.layers[b].replicas as f64 * 0.99
                || timing1.bottleneck().unwrap() != b,
            "replication must raise pipeline throughput"
        );
        assert!(timing1.bottleneck_cycles() < timing0.bottleneck_cycles());
        // an individual example's path is not shortened by replication
        assert_eq!(
            timing1.pipeline_fill_cycles(),
            timing0.pipeline_fill_cycles()
        );
    }

    /// The factor form prices the budget in multiples of the bottleneck
    /// layer's cells — the one definition the CLI/harness/example/planner
    /// share — and water-filling that budget matches an explicit cell
    /// count exactly.
    #[test]
    fn factor_budget_matches_explicit_cells() {
        let (model, plan) = skewed_model();
        let b = plan_timing(&model, &plan).bottleneck().unwrap();
        let cells = model.layers[b].fabricated_cells();
        assert_eq!(factor_budget_cells(&model, &plan, 2.0), 2 * cells);

        let mut by_factor = plan.clone();
        let budget = factor_budget_cells(&model, &by_factor, 2.0);
        let spent_f = fill_replicas(&model, &mut by_factor, budget);
        let mut by_cells = plan.clone();
        let spent_c = fill_replicas(&model, &mut by_cells, 2 * cells);
        assert_eq!(spent_f, spent_c);
        assert_eq!(by_factor, by_cells);

        // non-positive factors price to nothing
        assert_eq!(factor_budget_cells(&model, &plan, 0.0), 0);
        assert_eq!(factor_budget_cells(&model, &plan, -1.0), 0);

        // ...and a model with no bottleneck to nothing either
        let z = map_model(&[("z".into(), Tensor::zeros(vec![64, 32]))]).unwrap();
        let zp = DeploymentPlan::uniform_for(&z, [3, 3, 3, 1]);
        assert_eq!(factor_budget_cells(&z, &zp, 2.0), 0);
    }

    /// The replica ceiling bounds a runaway budget.
    #[test]
    fn fill_replicas_respects_the_ceiling() {
        let (model, mut plan) = skewed_model();
        let total: usize = model.layers.iter().map(|l| l.fabricated_cells()).sum();
        fill_replicas(&model, &mut plan, total * MAX_REPLICAS * 4);
        assert!(plan.layers.iter().all(|l| l.replicas <= MAX_REPLICAS));
        assert!(plan.layers.iter().any(|l| l.replicas > 1));
    }

    /// An all-zero model has no bottleneck and accepts no replication.
    #[test]
    fn empty_model_has_no_bottleneck() {
        let w = Tensor::zeros(vec![64, 32]);
        let model = map_model(&[("z".into(), w)]).unwrap();
        let mut plan = DeploymentPlan::uniform_for(&model, [3, 3, 3, 1]);
        let timing = plan_timing(&model, &plan);
        assert_eq!(timing.bottleneck(), None);
        assert_eq!(timing.bottleneck_cycles(), 0.0);
        assert_eq!(timing.throughput_per_kcycle(), 0.0);
        assert_eq!(fill_replicas(&model, &mut plan, 1_000_000), 0);
    }
}

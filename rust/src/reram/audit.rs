//! Static verifier over deployment artifacts.
//!
//! The conventions the deployment stack lives by — cached censuses,
//! CSR/bit-plane index exactness, per-layer permutations, Arc-shared
//! replicas, the converting-column count that energy and timing both
//! bill — exist as prose in the [`crate::reram`] module docs and as
//! scattered bit-exactness tests. This pass proves a mapped deployment
//! sound **before** anything executes: it walks every tile and layer of
//! a [`MappedModel`] (plus, for a full deployment, its
//! [`DeploymentPlan`] and replica view) without running inference and
//! emits one typed [`Diagnostic`] per violated invariant.
//!
//! Diagnostic codes are stable (tests, CI and downstream tooling key on
//! them); the full catalogue lives in the [`crate::reram`] module docs
//! beside the conventions each code enforces:
//!
//! | code | name | checks |
//! |------|------|--------|
//! | A001 | CellValueOutOfRange | every stored cell in `1..=CELL_MAX` |
//! | A002 | CensusMismatch | cached nonzero census == recount; layouts round-trip identically |
//! | A003 | CompressedIndexInconsistent | CSR offsets/entries/active indexes exact |
//! | A004 | BitPlaneMaskMismatch | plane shapes, zero padding, column index exact |
//! | A005 | PermutationNotBijective | reorder permutations bijective + exact inverses |
//! | A006 | PlanShapeMismatch | plan layers/replicas consistent with the mapping |
//! | A007 | ResolutionOutOfBounds | every planned ADC resolution usable |
//! | A008 | ReplicaAliasBroken | replica handles alias source tiles; area bill matches |
//! | A009 | FormatBandDrift | tile layout matches the density-band policy |
//! | A010 | TimingBillMismatch | converting-column bill == live-column recount |
//! | A011 | ReplicaBudgetUnderflow | a positive replication budget actually buys replicas |
//!
//! Entry points: [`audit_model`] (mapping only, deep), [`audit_deployment`]
//! (mapping + plan + replica view — what the `audit` CLI subcommand and
//! `serve::CrossbarBackend` construction run), `quick_audit`
//! (structural-only, cheap enough for the mapper's debug assertion), and
//! [`audit_replicas`] / [`replica_budget_diagnostic`] for the replication
//! artifacts on their own.

use std::sync::Arc;

use crate::quant::N_SLICES;

use super::crossbar::{chosen_format, Crossbar, StorageFormat, TileFault};
use super::energy;
use super::mapper::{LayerMapping, MappedModel, ReplicatedModel};
use super::planner::DeploymentPlan;
use super::reorder::Permutation;
use super::timing::{self, MAX_REPLICAS};

/// How bad a finding is. `Error` means the artifact would execute
/// incorrectly (or panic) — serving construction rejects it; `Warning`
/// means it is suspicious but functionally sound.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl std::fmt::Display for Severity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// Stable diagnostic codes — one per invariant class. The `A0xx` string
/// form ([`AuditCode::code`]) is the contract tests and CI key on; the
/// enum name matches it one-to-one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum AuditCode {
    /// A001 — a stored cell value outside `1..=CELL_MAX`
    CellValueOutOfRange,
    /// A002 — cached nonzero census != recount over the actual store, or
    /// a layout round-trip diverges
    CensusMismatch,
    /// A003 — compressed (CSR) offsets, entries or active indexes drifted
    CompressedIndexInconsistent,
    /// A004 — bit-plane masks malformed (shape, padding bits, column
    /// index drift)
    BitPlaneMaskMismatch,
    /// A005 — a reorder permutation is not a bijection with an exact
    /// inverse
    PermutationNotBijective,
    /// A006 — plan shape (layer count, names, replica counts) disagrees
    /// with the mapping
    PlanShapeMismatch,
    /// A007 — a planned ADC resolution the cost/timing models cannot
    /// price (0 bits panics them; > 32 saturates the clip)
    ResolutionOutOfBounds,
    /// A008 — a replica handle does not alias its source tiles, or the
    /// fabricated-cell accounting disagrees with `energy`'s static bill
    ReplicaAliasBroken,
    /// A009 — a tile's storage layout is not what the density-band
    /// policy ([`chosen_format`]) would choose for its census
    FormatBandDrift,
    /// A010 — a tile's converting-column count (what `energy` bills and
    /// `timing` prices) disagrees with a recount of its live columns
    TimingBillMismatch,
    /// A011 — a positive replication budget bought zero replicas
    ReplicaBudgetUnderflow,
}

impl AuditCode {
    /// The stable `A0xx` identifier.
    pub fn code(self) -> &'static str {
        match self {
            AuditCode::CellValueOutOfRange => "A001",
            AuditCode::CensusMismatch => "A002",
            AuditCode::CompressedIndexInconsistent => "A003",
            AuditCode::BitPlaneMaskMismatch => "A004",
            AuditCode::PermutationNotBijective => "A005",
            AuditCode::PlanShapeMismatch => "A006",
            AuditCode::ResolutionOutOfBounds => "A007",
            AuditCode::ReplicaAliasBroken => "A008",
            AuditCode::FormatBandDrift => "A009",
            AuditCode::TimingBillMismatch => "A010",
            AuditCode::ReplicaBudgetUnderflow => "A011",
        }
    }

    /// The catalogue name (matches the enum variant).
    pub fn name(self) -> &'static str {
        match self {
            AuditCode::CellValueOutOfRange => "CellValueOutOfRange",
            AuditCode::CensusMismatch => "CensusMismatch",
            AuditCode::CompressedIndexInconsistent => "CompressedIndexInconsistent",
            AuditCode::BitPlaneMaskMismatch => "BitPlaneMaskMismatch",
            AuditCode::PermutationNotBijective => "PermutationNotBijective",
            AuditCode::PlanShapeMismatch => "PlanShapeMismatch",
            AuditCode::ResolutionOutOfBounds => "ResolutionOutOfBounds",
            AuditCode::ReplicaAliasBroken => "ReplicaAliasBroken",
            AuditCode::FormatBandDrift => "FormatBandDrift",
            AuditCode::TimingBillMismatch => "TimingBillMismatch",
            AuditCode::ReplicaBudgetUnderflow => "ReplicaBudgetUnderflow",
        }
    }

    /// One-line statement of the invariant the code enforces (the
    /// catalogue entry; the module docs map each to its convention).
    pub fn invariant(self) -> &'static str {
        match self {
            AuditCode::CellValueOutOfRange => "every stored cell value lies in 1..=CELL_MAX",
            AuditCode::CensusMismatch => {
                "the cached nonzero census equals a recount and survives layout round-trips"
            }
            AuditCode::CompressedIndexInconsistent => {
                "CSR offsets are monotone and entries/active indexes are sorted, deduped, \
                 in-bounds and exact"
            }
            AuditCode::BitPlaneMaskMismatch => {
                "plane masks are tile-shaped with zero padding beyond the tile's rows and an \
                 exact nonzero-column index"
            }
            AuditCode::PermutationNotBijective => {
                "reorder permutations are bijections whose inverse round-trips exactly"
            }
            AuditCode::PlanShapeMismatch => {
                "the plan carries one layer per mapped layer with sane replica counts"
            }
            AuditCode::ResolutionOutOfBounds => {
                "every planned ADC resolution is priceable (1..=32 bits)"
            }
            AuditCode::ReplicaAliasBroken => {
                "replica handles alias their source tiles and the fabricated-crossbar \
                 accounting matches energy's static bill"
            }
            AuditCode::FormatBandDrift => {
                "each tile's storage layout is the density-band policy's choice"
            }
            AuditCode::TimingBillMismatch => {
                "the converting-column count billed by energy/timing equals the live-column \
                 recount"
            }
            AuditCode::ReplicaBudgetUnderflow => {
                "a positive replication budget fabricates at least one replica"
            }
        }
    }

    /// Default severity of a violation of this code.
    fn severity(self) -> Severity {
        match self {
            AuditCode::FormatBandDrift => Severity::Warning,
            _ => Severity::Error,
        }
    }
}

/// One audit finding, locatable down to the tile.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub code: AuditCode,
    pub severity: Severity,
    /// mapped layer name (`-` for model-wide findings)
    pub layer: String,
    /// tile label `XB_{k}/{pos|neg}[{tr},{tc}]` (`-` for layer-wide
    /// findings)
    pub tile: String,
    pub message: String,
}

impl Diagnostic {
    fn new(code: AuditCode, layer: &str, tile: &str, message: String) -> Diagnostic {
        Diagnostic {
            code,
            severity: code.severity(),
            layer: layer.to_string(),
            tile: tile.to_string(),
            message,
        }
    }

    fn warning(code: AuditCode, layer: &str, tile: &str, message: String) -> Diagnostic {
        Diagnostic {
            severity: Severity::Warning,
            ..Diagnostic::new(code, layer, tile, message)
        }
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} {} [{}] layer {} tile {}: {}",
            self.code.code(),
            self.code.name(),
            self.severity,
            self.layer,
            self.tile,
            self.message
        )
    }
}

/// Roll-up counts of one audit run (what bench artifacts and
/// `harness::deploy_report` record).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AuditSummary {
    /// tiles scanned (all slice groups, both signs, every layer)
    pub tiles: usize,
    pub errors: usize,
    pub warnings: usize,
}

/// Everything one audit run found.
#[derive(Debug, Clone)]
pub struct AuditReport {
    pub summary: AuditSummary,
    pub diagnostics: Vec<Diagnostic>,
}

impl AuditReport {
    /// No findings at any severity.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// The distinct codes that fired (sorted, deduped) — what the
    /// planted-violation property tests assert on.
    pub fn codes(&self) -> Vec<AuditCode> {
        let mut v: Vec<AuditCode> = self.diagnostics.iter().map(|d| d.code).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Whether any diagnostic carries `code`.
    pub fn has(&self, code: AuditCode) -> bool {
        self.diagnostics.iter().any(|d| d.code == code)
    }

    /// Append a finding produced outside the walk (e.g. the A011 budget
    /// check), keeping the summary counts consistent.
    pub fn push(&mut self, d: Diagnostic) {
        match d.severity {
            Severity::Error => self.summary.errors += 1,
            Severity::Warning => self.summary.warnings += 1,
        }
        self.diagnostics.push(d);
    }
}

impl std::fmt::Display for AuditReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "audit: {} tiles scanned, {} errors, {} warnings",
            self.summary.tiles, self.summary.errors, self.summary.warnings
        )?;
        for d in &self.diagnostics {
            write!(f, "\n  {d}")?;
        }
        Ok(())
    }
}

fn finalize(diagnostics: Vec<Diagnostic>, tiles: usize) -> AuditReport {
    let errors = diagnostics
        .iter()
        .filter(|d| d.severity == Severity::Error)
        .count();
    AuditReport {
        summary: AuditSummary {
            tiles,
            errors,
            warnings: diagnostics.len() - errors,
        },
        diagnostics,
    }
}

fn tile_label(k: usize, sign: &str, tr: usize, tc: usize) -> String {
    format!("XB_{k}/{sign}[{tr},{tc}]")
}

/// Lower one storage-level [`TileFault`] into its typed diagnostic.
fn fault_diag(layer: &str, tile: &str, fault: TileFault) -> Diagnostic {
    match fault {
        TileFault::ValueOutOfRange { row, col, value } => Diagnostic::new(
            AuditCode::CellValueOutOfRange,
            layer,
            tile,
            format!("cell ({row},{col}) holds {value}, outside 1..=3"),
        ),
        TileFault::CensusMismatch { cached, actual } => Diagnostic::new(
            AuditCode::CensusMismatch,
            layer,
            tile,
            format!("cached census {cached} != store recount {actual}"),
        ),
        TileFault::IndexInconsistent(msg) => {
            Diagnostic::new(AuditCode::CompressedIndexInconsistent, layer, tile, msg)
        }
        TileFault::PlaneMaskInconsistent(msg) => {
            Diagnostic::new(AuditCode::BitPlaneMaskMismatch, layer, tile, msg)
        }
    }
}

/// Audit one tile: structural faults (A001–A004), the timing/energy
/// bill (A010), the format band (A009, warning), and — when `deep` —
/// the cross-layout round-trip (A002).
fn audit_tile(layer: &str, label: &str, tile: &Crossbar, deep: bool, diags: &mut Vec<Diagnostic>) {
    for fault in tile.verify_cells() {
        diags.push(fault_diag(layer, label, fault));
    }

    // A010: the converting-column count — the exact quantity
    // energy::slice_conversions bills and timing::tile_cycles prices —
    // against an independent recount of columns that actually hold
    // conductance (the cached index never feeds this sum).
    let live = tile
        .column_conductance_sums()
        .iter()
        .filter(|&&s| s > 0)
        .count();
    let billed = tile.converting_columns();
    let expected = if tile.active_cols().is_some() {
        live
    } else {
        tile.cols() // dense tiles convert every column by convention
    };
    if billed != expected {
        diags.push(Diagnostic::new(
            AuditCode::TimingBillMismatch,
            layer,
            label,
            format!(
                "energy/timing bill {billed} converting columns, {live} columns hold \
                 programmed cells"
            ),
        ));
    }

    if tile.nonzero_cells() == 0 {
        return; // fully-zero tiles are never fabricated; no band, no trips
    }

    // A009 (warning): the layout is not what the density-band policy
    // would choose — legal after an explicit `with_storage`/`in_format`
    // conversion, but drift a mapper path should never produce.
    let want = chosen_format(tile.nonzero_cells(), tile.rows(), tile.cols());
    if tile.format() != want {
        diags.push(Diagnostic::warning(
            AuditCode::FormatBandDrift,
            layer,
            label,
            format!(
                "stored {:?} where the density band ({:.1}%) chooses {want:?}",
                tile.format(),
                tile.density() * 100.0
            ),
        ));
    }

    // A002 (deep): all three layouts must round-trip to identical
    // logical cells — compared through the conductance sums, which every
    // layout recomputes from its own raw store.
    if deep {
        let sums = tile.column_conductance_sums();
        for fmt in [
            StorageFormat::Dense,
            StorageFormat::Compressed,
            StorageFormat::BitPlanes,
        ] {
            if fmt == tile.format() {
                continue;
            }
            let rt = tile.in_format(fmt);
            if rt.column_conductance_sums() != sums {
                diags.push(Diagnostic::new(
                    AuditCode::CensusMismatch,
                    layer,
                    label,
                    format!("layout round-trip through {fmt:?} changes the logical cells"),
                ));
            }
        }
    }
}

/// Audit one permutation (A005): lengths, bijectivity, exact inverse,
/// and the cached identity flag.
fn audit_permutation(layer: &str, what: &str, n: usize, p: &Permutation, diags: &mut Vec<Diagnostic>) {
    let (tn, to) = (p.to_new(), p.to_old());
    if tn.len() != n || to.len() != n {
        diags.push(Diagnostic::new(
            AuditCode::PermutationNotBijective,
            layer,
            "-",
            format!(
                "{what} permutation covers {}/{} positions of {n} {what}s",
                tn.len(),
                to.len()
            ),
        ));
        return;
    }
    let mut seen = vec![false; n];
    for (old, &new) in tn.iter().enumerate() {
        let new = new as usize;
        if new >= n {
            diags.push(Diagnostic::new(
                AuditCode::PermutationNotBijective,
                layer,
                "-",
                format!("{what} {old} maps to position {new}, outside 0..{n}"),
            ));
            return;
        }
        if seen[new] {
            diags.push(Diagnostic::new(
                AuditCode::PermutationNotBijective,
                layer,
                "-",
                format!("two {what}s map to position {new}"),
            ));
            return;
        }
        seen[new] = true;
        if to[new] as usize != old {
            diags.push(Diagnostic::new(
                AuditCode::PermutationNotBijective,
                layer,
                "-",
                format!(
                    "{what} inverse drifts: to_old[to_new[{old}]] = {}",
                    to[new]
                ),
            ));
            return;
        }
    }
    let really_identity = tn.iter().enumerate().all(|(i, &v)| v as usize == i);
    if p.is_identity() != really_identity {
        diags.push(Diagnostic::new(
            AuditCode::PermutationNotBijective,
            layer,
            "-",
            format!(
                "cached identity flag {} disagrees with the {what} contents",
                p.is_identity()
            ),
        ));
    }
}

/// Audit one mapped layer: every tile of every slice group and sign,
/// plus its reorder permutations. Returns the tiles scanned.
fn audit_layer(layer: &LayerMapping, deep: bool, diags: &mut Vec<Diagnostic>) -> usize {
    let mut tiles = 0usize;
    if layer.grids.len() != N_SLICES {
        diags.push(Diagnostic::new(
            AuditCode::PlanShapeMismatch,
            &layer.name,
            "-",
            format!("{} slice grids for {N_SLICES} slices", layer.grids.len()),
        ));
    }
    for (k, (pos, neg)) in layer.grids.iter().enumerate() {
        for (sign, grid) in [("pos", pos), ("neg", neg)] {
            if grid.tiles.len() != grid.row_tiles * grid.col_tiles {
                diags.push(Diagnostic::new(
                    AuditCode::PlanShapeMismatch,
                    &layer.name,
                    "-",
                    format!(
                        "XB_{k}/{sign} grid holds {} tiles for a {}x{} tiling",
                        grid.tiles.len(),
                        grid.row_tiles,
                        grid.col_tiles
                    ),
                ));
                continue;
            }
            for tr in 0..grid.row_tiles {
                for tc in 0..grid.col_tiles {
                    tiles += 1;
                    let label = tile_label(k, sign, tr, tc);
                    audit_tile(&layer.name, &label, grid.tile(tr, tc), deep, diags);
                }
            }
        }
    }
    if let Some(ro) = &layer.reorder {
        audit_permutation(&layer.name, "wordline", layer.rows, &ro.rows, diags);
        audit_permutation(&layer.name, "column", layer.cols, &ro.cols, diags);
    }
    tiles
}

fn audit_model_impl(model: &MappedModel, deep: bool) -> AuditReport {
    let mut diags = Vec::new();
    let mut tiles = 0usize;
    for layer in &model.layers {
        tiles += audit_layer(layer, deep, &mut diags);
    }
    finalize(diags, tiles)
}

/// Deep audit of a mapping alone: structural tile checks, the
/// timing/energy bill, format bands, permutations, and the three-layout
/// round-trip.
pub fn audit_model(model: &MappedModel) -> AuditReport {
    audit_model_impl(model, true)
}

/// Structural-only audit (no layout round-trips): cheap enough for the
/// mapper's post-map debug assertion.
pub(crate) fn quick_audit(model: &MappedModel) -> AuditReport {
    audit_model_impl(model, false)
}

/// Audit a plan against its mapping (A006 shape/replicas, A007
/// resolutions). Emits no tile scans of its own.
pub fn audit_plan(model: &MappedModel, plan: &DeploymentPlan) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if plan.layers.len() != model.layers.len() {
        diags.push(Diagnostic::new(
            AuditCode::PlanShapeMismatch,
            "-",
            "-",
            format!(
                "plan carries {} layers, mapping has {}",
                plan.layers.len(),
                model.layers.len()
            ),
        ));
        return diags;
    }
    for (layer, pl) in model.layers.iter().zip(&plan.layers) {
        if pl.name != layer.name {
            diags.push(Diagnostic::warning(
                AuditCode::PlanShapeMismatch,
                &layer.name,
                "-",
                format!("plan names this layer {:?}", pl.name),
            ));
        }
        if pl.replicas == 0 {
            diags.push(Diagnostic::warning(
                AuditCode::PlanShapeMismatch,
                &layer.name,
                "-",
                "plan asks for 0 replicas (treated as 1 everywhere)".to_string(),
            ));
        } else if pl.replicas > MAX_REPLICAS {
            diags.push(Diagnostic::new(
                AuditCode::PlanShapeMismatch,
                &layer.name,
                "-",
                format!(
                    "plan asks for {} replicas, above the {MAX_REPLICAS} ceiling",
                    pl.replicas
                ),
            ));
        }
        for (k, &bits) in pl.adc_bits.iter().enumerate() {
            if bits == 0 {
                diags.push(Diagnostic::new(
                    AuditCode::ResolutionOutOfBounds,
                    &layer.name,
                    "-",
                    format!("XB_{k} planned at 0 bits — the ADC cost model cannot price it"),
                ));
            } else if bits > 32 {
                diags.push(Diagnostic::warning(
                    AuditCode::ResolutionOutOfBounds,
                    &layer.name,
                    "-",
                    format!("XB_{k} planned at {bits} bits, beyond the 32-bit clip saturation"),
                ));
            }
        }
    }
    diags
}

/// Count a layer's programmed tiles (the crossbars `energy` fabricates
/// for one replica).
fn programmed_tiles(layer: &LayerMapping) -> usize {
    layer
        .grids
        .iter()
        .flat_map(|(p, n)| [p, n])
        .flat_map(|g| &g.tiles)
        .filter(|t| t.nonzero_cells() > 0)
        .count()
}

/// Audit a replica view against its mapping and plan (A008): every
/// handle must `Arc::ptr_eq` its source layer (a replica is an alias,
/// never a deep clone), handle counts must match the plan, and the
/// fabricated-crossbar accounting the view implies must equal
/// [`energy::plan_cost`]'s static bill. The plan must already be
/// shape-valid with usable resolutions (run [`audit_plan`] first).
pub fn audit_replicas(
    model: &MappedModel,
    plan: &DeploymentPlan,
    rep: &ReplicatedModel,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if rep.layers.len() != model.layers.len() {
        diags.push(Diagnostic::new(
            AuditCode::ReplicaAliasBroken,
            "-",
            "-",
            format!(
                "replica view carries {} layers, mapping has {}",
                rep.layers.len(),
                model.layers.len()
            ),
        ));
        return diags;
    }
    let mut fabricated = 0usize;
    for ((layer, pl), handles) in model.layers.iter().zip(&plan.layers).zip(&rep.layers) {
        let want = pl.replicas.max(1);
        if handles.len() != want {
            diags.push(Diagnostic::new(
                AuditCode::ReplicaAliasBroken,
                &layer.name,
                "-",
                format!(
                    "replica view holds {} handles, plan fabricates {want}",
                    handles.len()
                ),
            ));
        }
        for (i, h) in handles.iter().enumerate() {
            if !Arc::ptr_eq(h, layer) {
                diags.push(Diagnostic::new(
                    AuditCode::ReplicaAliasBroken,
                    &layer.name,
                    "-",
                    format!("replica handle {i} does not alias the source tiles"),
                ));
            }
        }
        fabricated += handles.len() * programmed_tiles(layer);
    }
    let billed = energy::plan_cost(model, plan).crossbars;
    if fabricated != billed {
        diags.push(Diagnostic::new(
            AuditCode::ReplicaAliasBroken,
            "-",
            "-",
            format!(
                "replica view fabricates {fabricated} crossbars, energy bills {billed}"
            ),
        ));
    }
    diags
}

/// The A011 diagnostic for a replication budget that bought nothing:
/// `factor` was positive but the water-fill spent `spent_cells` = 0.
/// Returns `None` when the budget is non-positive or something was
/// actually bought. `deploy --replicate-budget` turns this into a hard
/// CLI error instead of shipping a silently unreplicated plan.
pub fn replica_budget_diagnostic(
    model: &MappedModel,
    plan: &DeploymentPlan,
    factor: f64,
    spent_cells: usize,
) -> Option<Diagnostic> {
    if factor <= 0.0 || spent_cells > 0 {
        return None;
    }
    let d = match timing::plan_timing(model, plan).bottleneck() {
        Some(b) => {
            let layer = &model.layers[b];
            let cells = layer.fabricated_cells();
            let budget = (factor * cells as f64) as usize;
            Diagnostic::new(
                AuditCode::ReplicaBudgetUnderflow,
                &layer.name,
                "-",
                format!(
                    "replication budget {factor}x allots {budget} fabricated cells but one \
                     extra copy of the bottleneck layer costs {cells}; no replicas fabricated"
                ),
            )
        }
        None => Diagnostic::new(
            AuditCode::ReplicaBudgetUnderflow,
            "-",
            "-",
            format!(
                "replication budget {factor}x requested but the model has no programmed tiles \
                 to replicate"
            ),
        ),
    };
    Some(d)
}

/// Full deployment audit: the deep mapping walk, the plan checks, and —
/// when the plan is shape-valid with priceable resolutions — the
/// replica-view alias/accounting checks on the view the plan implies.
/// This is what the `audit` CLI subcommand runs and what
/// `serve::CrossbarBackend` construction rejects `Error` findings from.
pub fn audit_deployment(model: &MappedModel, plan: &DeploymentPlan) -> AuditReport {
    let mut report = audit_model(model);
    let tiles = report.summary.tiles;
    let mut diags = std::mem::take(&mut report.diagnostics);
    let plan_diags = audit_plan(model, plan);
    // the replica/energy cross-check prices the plan, which panics on a
    // malformed shape or a 0-bit resolution — skip it when the plan
    // checks already found errors
    let plan_ok = !plan_diags.iter().any(|d| d.severity == Severity::Error);
    diags.extend(plan_diags);
    if plan_ok {
        let replicas: Vec<usize> = plan.layers.iter().map(|l| l.replicas).collect();
        let rep = model.replicated(&replicas);
        diags.extend(audit_replicas(model, plan, &rep));
    }
    finalize(diags, tiles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reram::mapper;
    use crate::reram::planner::PAPER_BITS;
    use crate::reram::reorder::ReorderConfig;
    use crate::tensor::Tensor;
    use crate::util::fixtures;
    use crate::util::rng::Rng;

    fn mapped_fixture(seed: u64) -> MappedModel {
        let stack = fixtures::sparse_stack(seed, &[64, 32, 10], 0.12);
        let named: Vec<(String, Tensor)> =
            stack.iter().map(|l| (l.name.clone(), l.w.clone())).collect();
        mapper::map_model_with(&named, Some(ReorderConfig::default())).unwrap()
    }

    #[test]
    fn clean_mapping_audits_clean() {
        let model = mapped_fixture(0xA0D1);
        let report = audit_model(&model);
        assert!(report.is_clean(), "{report}");
        assert!(report.summary.tiles > 0);
        let plan = DeploymentPlan::uniform_for(&model, PAPER_BITS);
        let dep = audit_deployment(&model, &plan);
        assert!(dep.is_clean(), "{dep}");
    }

    #[test]
    fn plan_shape_and_resolution_checks() {
        let model = mapped_fixture(0xA0D2);
        let mut plan = DeploymentPlan::uniform_for(&model, PAPER_BITS);

        // short plan: A006 error, and audit_deployment still terminates
        plan.layers.pop();
        let report = audit_deployment(&model, &plan);
        assert!(report.has(AuditCode::PlanShapeMismatch), "{report}");
        assert!(report.summary.errors > 0);

        // 0-bit resolution: A007 error, replica cross-check skipped
        let mut plan = DeploymentPlan::uniform_for(&model, PAPER_BITS);
        plan.layers[0].adc_bits[2] = 0;
        let report = audit_deployment(&model, &plan);
        assert!(report.has(AuditCode::ResolutionOutOfBounds), "{report}");
        assert!(report.summary.errors > 0);

        // absurd replica count: A006 error
        let mut plan = DeploymentPlan::uniform_for(&model, PAPER_BITS);
        plan.layers[0].replicas = MAX_REPLICAS + 1;
        let report = audit_deployment(&model, &plan);
        assert!(report.has(AuditCode::PlanShapeMismatch), "{report}");

        // oversized bits: warning only — construction-legal
        let mut plan = DeploymentPlan::uniform_for(&model, PAPER_BITS);
        plan.layers[0].adc_bits[0] = 33;
        let report = audit_deployment(&model, &plan);
        assert!(report.has(AuditCode::ResolutionOutOfBounds));
        assert_eq!(report.summary.errors, 0, "{report}");
    }

    #[test]
    fn replica_budget_diagnostic_fires_only_on_underflow() {
        let model = mapped_fixture(0xA0D3);
        let plan = DeploymentPlan::uniform_for(&model, PAPER_BITS);
        // non-positive factor or something spent: no diagnostic
        assert!(replica_budget_diagnostic(&model, &plan, 0.0, 0).is_none());
        assert!(replica_budget_diagnostic(&model, &plan, 2.0, 1000).is_none());
        // positive factor, nothing spent: A011
        let d = replica_budget_diagnostic(&model, &plan, 0.1, 0).expect("underflow diagnostic");
        assert_eq!(d.code, AuditCode::ReplicaBudgetUnderflow);
        assert_eq!(d.severity, Severity::Error);
    }

    #[test]
    fn codes_are_stable_and_distinct() {
        let all = [
            AuditCode::CellValueOutOfRange,
            AuditCode::CensusMismatch,
            AuditCode::CompressedIndexInconsistent,
            AuditCode::BitPlaneMaskMismatch,
            AuditCode::PermutationNotBijective,
            AuditCode::PlanShapeMismatch,
            AuditCode::ResolutionOutOfBounds,
            AuditCode::ReplicaAliasBroken,
            AuditCode::FormatBandDrift,
            AuditCode::TimingBillMismatch,
            AuditCode::ReplicaBudgetUnderflow,
        ];
        for (i, c) in all.iter().enumerate() {
            assert_eq!(c.code(), format!("A{:03}", i + 1));
            assert!(!c.name().is_empty() && !c.invariant().is_empty());
        }
    }

    /// Warnings and errors land in the right summary buckets and the
    /// Display form carries the stable code.
    #[test]
    fn report_summary_counts_severities() {
        let model = mapped_fixture(0xA0D4);
        let mut plan = DeploymentPlan::uniform_for(&model, PAPER_BITS);
        plan.layers[0].name = "mislabeled".into(); // A006 warning
        let report = audit_deployment(&model, &plan);
        assert_eq!(report.summary.errors, 0);
        assert!(report.summary.warnings >= 1);
        let shown = format!("{report}");
        assert!(shown.contains("A006"), "{shown}");
    }

    /// Sanity for the seeded-random path the property suites build on:
    /// a freshly mapped random model is clean at any density.
    #[test]
    fn random_densities_audit_clean() {
        let mut rng = Rng::new(0xA0D5);
        for density in [0.05, 0.3, 0.5, 0.8] {
            let w = fixtures::weights_at_density(&mut rng, 96, 40, density);
            let layer = mapper::map_layer("w", &w).unwrap();
            let model = MappedModel {
                layers: vec![Arc::new(layer)],
            };
            let report = audit_model(&model);
            assert!(report.is_clean(), "density {density}: {report}");
        }
    }
}
